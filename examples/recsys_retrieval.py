"""RecSys retrieval through the paper's index — the retrieval_cand cell.

    PYTHONPATH=src python examples/recsys_retrieval.py

Trains a small FM on synthetic CTR data, takes one field's item-embedding
table as the candidate corpus (the "arbitrary dense vectors"), and compares:

  * brute-force dot scoring (the serving baseline),
  * fake-words index scoring + exact rerank (the paper's technique).

This is the DIRECT application family from DESIGN.md §6: candidate scoring
IS inner-product search over item embeddings.
"""

import jax

from repro.core import bruteforce, eval as ev, fakewords
from repro.core.types import FakeWordsConfig
from repro.data import recsys as rec_data
from repro.models import recsys as rec
from repro.train import optimizer as opt_mod
from repro.train.train_loop import build_train_step, make_train_state


def main():
    table = rec.TableSpec(rec.criteo_row_counts(8, 65536), 16)
    cfg = rec.RecsysConfig(name="fm-small", model="fm", table=table)
    data = rec_data.RecsysDataConfig(table=table, batch=256, seed=0)
    params = rec.init_params(jax.random.key(0), cfg)
    opt = opt_mod.adamw(lr=1e-2)
    state = make_train_state(params, opt)
    step = jax.jit(build_train_step(
        lambda p, b: rec.bce_loss(p, cfg, b["sparse"], b["label"]), opt))
    print("== training FM (200 steps, synthetic CTR)")
    for i in range(200):
        state, m = step(state, rec_data.batch_at(data, i))
        if i % 50 == 0:
            print(f"  step {i}: bce {float(m['loss']):.4f}")
    params = state.params

    # Candidate corpus: the largest field's item embeddings.
    f0_rows = table.row_counts[0]
    items = params["table"][: f0_rows]  # field 0 occupies rows [0, c0)
    print(f"== candidate corpus: {f0_rows} item embeddings (dim {cfg.dim})")

    # Query side: user context vectors from held-out batches.
    b = rec_data.batch_at(data, 10_000)
    users = rec.user_tower(params, cfg, b["sparse"])[:64]

    # Baseline: brute-force top-10 by inner product.
    gt_s, gt_i = bruteforce.exact_topk(items, users, 10)

    # Paper technique: fake-words index + depth-100 match + exact rerank.
    fw = FakeWordsConfig(quantization=50)
    idx = fakewords.build(items, fw)
    q_tf = fakewords.encode_queries(users, fw)
    s, ids = fakewords.search(
        idx, q_tf, bruteforce.l2_normalize(users), k=10, depth=100, rerank=True)
    r = float(ev.recall_at(gt_i, ids))
    print(f"== fake-words retrieval R@(10,100)+rerank vs brute force: {r:.3f}")
    print(f"   index {idx.nbytes()/1e6:.1f} MB vs raw vectors "
          f"{items.size*4/1e6:.1f} MB")
    # NOTE: cosine vs inner-product — fake words requires unit vectors, so
    # recall is w.r.t. cosine neighbors; FM scores are inner products.  For
    # norm-skewed tables add the classic norm-augmentation dimension.
    assert r > 0.6


if __name__ == "__main__":
    main()
