"""End-to-end training driver demo: ~100M-param LM, a few hundred steps,
with a mid-run crash + restart proving checkpoint fault tolerance.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]

(The same driver trains the assigned full-size archs on a pod; this is the
container-scale run of deliverable (b).)
"""
import argparse
import subprocess
import sys
import os
import tempfile

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="tiny-lm", help="tiny-lm (~100M) | micro-lm (~3M)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    env = dict(os.environ, PYTHONPATH=SRC)
    with tempfile.TemporaryDirectory() as ck:
        base = [
            sys.executable, "-m", "repro.launch.train",
            "--arch", args.arch, "--steps", str(args.steps),
            "--global-batch", str(args.batch), "--seq-len", str(args.seq),
            "--ckpt-dir", ck, "--ckpt-every", str(max(10, args.steps // 6)),
            "--log-every", "20",
        ]
        kill_at = args.steps // 2
        print(f"== phase 1: train until simulated crash at step {kill_at}")
        r = subprocess.run(base + ["--kill-at", str(kill_at)], env=env)
        assert r.returncode == 42, "expected simulated crash"
        print("== phase 2: restart — resumes from the latest atomic checkpoint")
        r = subprocess.run(base, env=env)
        assert r.returncode == 0
        print("== done: loss curve continued through the crash (stateless "
              "data + checkpoint restore; see launch/train.py)")


if __name__ == "__main__":
    main()
