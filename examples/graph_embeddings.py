"""GraphSAGE node embeddings -> fake-words ANN (post-hoc applicability).

    PYTHONPATH=src python examples/graph_embeddings.py

Trains 2-layer mean-SAGE on a synthetic power-law graph (full-batch), then
indexes the trained node embeddings with the paper's fake-words encoding
and checks neighbor retrieval against brute force.
"""
import jax
import jax.numpy as jnp

from repro.core import bruteforce, eval as ev, fakewords
from repro.core.types import FakeWordsConfig
from repro.data import graph as gd
from repro.models import gnn
from repro.train import optimizer as opt_mod
from repro.train.train_loop import build_train_step, make_train_state


def main():
    g = gd.make_graph(gd.GraphConfig(n_nodes=3000, n_edges=15000, d_feat=64,
                                     n_classes=10))
    src, dst = g.edge_list()
    cfg = gnn.SageConfig(n_layers=2, d_in=64, d_hidden=64, n_classes=10,
                         fanouts=(25, 10))
    params = gnn.init_params(jax.random.key(0), cfg)
    opt = opt_mod.adamw(lr=1e-2)
    state = make_train_state(params, opt)
    mask = jnp.ones((g.n_nodes,), jnp.float32)

    def loss_of(p, batch):
        return gnn.loss_full(p, g.feats, src, dst, g.labels, mask, cfg)

    step = jax.jit(build_train_step(loss_of, opt))
    print("== training GraphSAGE (100 full-batch steps)")
    for i in range(100):
        state, m = step(state, {})
        if i % 25 == 0:
            print(f"  step {i}: xent {float(m['loss']):.4f}")

    emb = gnn.embeddings_full(state.params, g.feats, src, dst, cfg)
    print(f"== node embeddings: {emb.shape}")
    queries = emb[:64]
    _, gt = bruteforce.exact_topk(emb, queries, 10)
    fw = FakeWordsConfig(quantization=50)
    idx = fakewords.build(emb, fw)
    q_tf = fakewords.encode_queries(queries, fw)
    _, ids = fakewords.search(
        idx, q_tf, bruteforce.l2_normalize(queries), k=10, depth=100, rerank=True)
    r = float(ev.recall_at(gt, ids))
    print(f"== fake-words neighbor recall vs brute force: {r:.3f}")
    assert r > 0.8


if __name__ == "__main__":
    main()
