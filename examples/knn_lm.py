"""kNN-LM: the paper's ANN layer serving a language model's embeddings.

    PYTHONPATH=src python examples/knn_lm.py

Trains a micro LM for a few hundred steps, then uses the FAKE-WORDS index
over the model's (datastore) hidden states to interpolate next-token
probabilities (Khandelwal et al. 2020 style):

    p(y|x) = (1-lam) p_LM(y|x) + lam p_kNN(y|x)

The datastore maps hidden state h_t -> next token y_{t+1}; retrieval is the
paper's technique end to end (encode, match at depth d, exact rerank).
This is the LM-family integration noted in DESIGN.md §6 (indirect
applicability: the ANN layer serves the embeddings, not the train step).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bruteforce, fakewords
from repro.core.types import FakeWordsConfig
from repro.data import lm as lm_data
from repro.launch.train import micro_lm_config
from repro.models import transformer as tfm
from repro.train import optimizer as opt_mod
from repro.train.train_loop import build_train_step, make_train_state


def hidden_states(params, tokens, cfg):
    """Last-layer hidden states (B, S, d) (pre-head)."""
    # reuse prefill's stack but keep all positions: forward minus head
    b, s = tokens.shape
    x = params["embed"].astype(cfg.dtype)[tokens]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def block(x, layer):
        return tfm._dense_layer(x, layer, cfg, positions), None

    x, _ = jax.lax.scan(block, x, params["layers"])
    return tfm.rms_norm(x, params["final_ln"], cfg.norm_eps)


def main():
    cfg = micro_lm_config()
    data = lm_data.LmDataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=0)
    opt = opt_mod.adamw(lr=1e-3)
    params = tfm.init_params(jax.random.key(0), cfg)
    state = make_train_state(params, opt)
    step = jax.jit(build_train_step(
        lambda p, b: tfm.loss_fn(p, b["tokens"], b["labels"], cfg), opt))
    print("== training micro-LM (200 steps)")
    for i in range(200):
        state, m = step(state, lm_data.batch_at(data, i))
        if i % 50 == 0:
            print(f"  step {i}: loss {float(m['loss']):.3f}")
    params = state.params

    print("== building kNN datastore (hidden state -> next token)")
    keys_list, vals_list = [], []
    hs_fn = jax.jit(lambda p, t: hidden_states(p, t, cfg))
    for i in range(300, 316):  # held-out batches
        b = lm_data.batch_at(data, i)
        h = hs_fn(params, b["tokens"])
        keys_list.append(np.asarray(h.reshape(-1, cfg.d_model), np.float32))
        vals_list.append(np.asarray(b["labels"].reshape(-1)))
    keys = np.concatenate(keys_list)
    vals = np.concatenate(vals_list)
    print(f"  datastore: {keys.shape[0]} entries x {keys.shape[1]}d")

    fw_cfg = FakeWordsConfig(quantization=50)
    index = fakewords.build(jnp.asarray(keys), fw_cfg)

    print("== kNN-LM eval on a fresh batch")
    b = lm_data.batch_at(data, 999)
    h = hs_fn(params, b["tokens"])
    logits = tfm.forward(params, b["tokens"], cfg)
    q = h.reshape(-1, cfg.d_model)
    q_tf = fakewords.encode_queries(q, fw_cfg)
    s, ids = fakewords.search(
        index, q_tf, bruteforce.l2_normalize(q), k=16, depth=64, rerank=True)
    # p_kNN: softmax over retrieved distances onto their stored next-tokens
    w = jax.nn.softmax(s * 10.0, axis=-1)  # (T, k)
    knn_tokens = jnp.asarray(vals)[ids]  # (T, k)
    p_knn = jnp.zeros((q.shape[0], cfg.vocab))
    p_knn = p_knn.at[jnp.arange(q.shape[0])[:, None], knn_tokens].add(w)
    p_lm = jax.nn.softmax(logits.reshape(-1, cfg.vocab), axis=-1)
    labels = b["labels"].reshape(-1)

    def nll(p):
        pt = p[jnp.arange(labels.shape[0]), labels]
        return float(-jnp.mean(jnp.log(jnp.maximum(pt, 1e-9))))

    for lam in (0.0, 0.25, 0.5):
        p = (1 - lam) * p_lm + lam * p_knn
        print(f"  lambda={lam:.2f}: NLL {nll(p):.4f}")
    print("(kNN interpolation over the fake-words index; Zipf-synthetic "
          "data so gains are modest — the plumbing is the point)")


if __name__ == "__main__":
    main()
