"""Quickstart: ANN search on dense vectors through the staged pipeline API.

    PYTHONPATH=src python examples/quickstart.py

Builds all three paper encodings (plus the exact brute-force oracle) over a
synthetic word2vec-like corpus via the one entry point — ``AnnIndex`` —
searches each through the shared ``SearchPipeline`` (encode -> match ->
exact rerank), prints R@(10,d) against the oracle (a miniature of paper
Table 1), and round-trips one index through ``save``/``load`` (the
ship-to-serving-process path).
"""
import dataclasses
import os
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import bruteforce, eval as ev
from repro.core.index import AnnIndex
from repro.core.types import (
    BruteForceConfig,
    FakeWordsConfig,
    KdTreeConfig,
    LexicalLshConfig,
    SearchParams,
)
from repro.data import embeddings


def main():
    n_docs = int(os.environ.get("QUICKSTART_DOCS", 20_000))
    print(f"== corpus: {n_docs} synthetic word2vec-like vectors (300-d)")
    corpus_np = embeddings.make_corpus(
        dataclasses.replace(embeddings.WORD2VEC_LIKE, n_vectors=n_docs))
    corpus = jnp.asarray(corpus_np)
    queries_np, _ = embeddings.make_queries(corpus_np, 64)
    queries = jnp.asarray(queries_np)
    _, gt = bruteforce.exact_topk(corpus, queries, 10)

    for cfg in [
        FakeWordsConfig(quantization=50),                 # best (paper)
        LexicalLshConfig(buckets=300, hashes=1),          # middle
        KdTreeConfig(dims=8, reduction="pca"),            # fast, collapsed
        BruteForceConfig(),                               # the oracle itself
    ]:
        idx = AnnIndex.build(corpus, cfg)
        _, ids = idx.search(queries, params=SearchParams(k=100, depth=100))
        r10 = float(ev.recall_at(gt, ids[:, :10]))
        r100 = float(ev.recall_at(gt, ids))
        # two-phase: depth-100 match + exact rerank (the refinement step)
        _, ids_rr = idx.search(
            queries, params=SearchParams(k=10, depth=100, rerank=True))
        r_rr = float(ev.recall_at(gt, ids_rr))
        print(f"{idx.method:12s} R@(10,10)={r10:.3f} R@(10,100)={r100:.3f} "
              f"rerank@100->10={r_rr:.3f} index={idx.nbytes()/1e6:.0f}MB")

    # Persistence: a built index ships to a serving process as npz + JSON.
    idx = AnnIndex.build(corpus, FakeWordsConfig(quantization=50))
    s0, i0 = idx.search(queries, k=10, depth=100, rerank=True)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "fakewords.ann")
        idx.save(path)
        loaded = AnnIndex.load(path)
        s1, i1 = loaded.search(queries, k=10, depth=100, rerank=True)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    print("save/load round trip: search output identical bit-for-bit")


if __name__ == "__main__":
    main()
