"""Quickstart: ANN search on dense vectors through the writer API.

    PYTHONPATH=src python examples/quickstart.py

Feeds a synthetic word2vec-like corpus through the Lucene-style
``IndexWriter`` (docs/DESIGN.md §11) for all three paper encodings (plus
the exact brute-force oracle): ``add`` buffers rows, ``refresh()`` returns
a searchable near-real-time reader, and every reader searches through the
shared staged ``SearchPipeline`` (encode -> match -> exact rerank).
Prints R@(10,d) against the oracle (a miniature of paper Table 1), then
walks the full segment lifecycle — incremental adds, deletes, a
generation-numbered ``commit``, reload, and a forced merge — asserting the
segmented index stays bit-for-bit identical to a fresh monolithic build of
the live corpus.  (``AnnIndex.build`` remains the one-shot offline path;
a writer with a single flush produces exactly the same results.)  Closes
with the quantized read path under a memory budget (§12) and the §13
match-stage extensions: filtered kNN from a ``DocMetadata`` predicate
bitmap (masked inside the kernel, one pass) and hybrid lexical+dense
retrieval through ``plan.FusionStage`` (reciprocal-rank fusion).
"""
import dataclasses
import os
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import bruteforce, eval as ev
from repro.core.index import AnnIndex
from repro.core.segments import IndexWriter, SegmentedAnnIndex
from repro.core.types import (
    BruteForceConfig,
    FakeWordsConfig,
    GraphConfig,
    KdTreeConfig,
    LexicalLshConfig,
    SearchParams,
)
from repro.data import embeddings


def main():
    n_docs = int(os.environ.get("QUICKSTART_DOCS", 20_000))
    print(f"== corpus: {n_docs} synthetic word2vec-like vectors (300-d)")
    corpus_np = embeddings.make_corpus(
        dataclasses.replace(embeddings.WORD2VEC_LIKE, n_vectors=n_docs))
    corpus = jnp.asarray(corpus_np)
    queries_np, _ = embeddings.make_queries(corpus_np, 64)
    queries = jnp.asarray(queries_np)
    _, gt = bruteforce.exact_topk(corpus, queries, 10)

    for cfg in [
        FakeWordsConfig(quantization=50),                 # best (paper)
        LexicalLshConfig(buckets=300, hashes=1),          # middle
        KdTreeConfig(dims=8, reduction="pca"),            # fast, collapsed
        GraphConfig(ef=128, beam=16, iters=12),           # graph (§15)
        BruteForceConfig(),                               # the oracle itself
    ]:
        writer = IndexWriter(cfg)
        writer.add(corpus_np)
        idx = writer.refresh()  # NRT reader over the flushed segment
        _, ids = idx.search(queries, params=SearchParams(k=100, depth=100))
        r10 = float(ev.recall_at(gt, ids[:, :10]))
        r100 = float(ev.recall_at(gt, ids))
        # two-phase: depth-100 match + exact rerank (the refinement step)
        _, ids_rr = idx.search(
            queries, params=SearchParams(k=10, depth=100, rerank=True))
        r_rr = float(ev.recall_at(gt, ids_rr))
        print(f"{idx.method:12s} R@(10,10)={r10:.3f} R@(10,100)={r100:.3f} "
              f"rerank@100->10={r_rr:.3f} index={idx.nbytes()/1e6:.0f}MB")

    # The segment lifecycle: ingest-while-serving, deletes, commit, merge.
    cfg = FakeWordsConfig(quantization=50)
    split = n_docs // 2
    writer = IndexWriter(cfg)
    writer.add(corpus_np[:split])
    writer.flush()                      # segment 1
    writer.add(corpus_np[split:])       # segment 2 (flushed by refresh)
    writer.delete(np.arange(0, n_docs, 10))  # kill every 10th doc
    reader = writer.refresh()
    print(f"segments={reader.num_segments} live={reader.num_docs} "
          f"deleted={reader.del_count} epoch={reader.epoch}")

    # Bit-for-bit parity with a fresh monolithic build of the live corpus.
    live = np.ones(n_docs, bool)
    live[::10] = False
    mono = AnnIndex.build(jnp.asarray(corpus_np[live]), cfg)
    s_seg, i_seg = reader.search(queries, k=10, depth=100, rerank=True)
    s_mono, i_mono = mono.search(queries, k=10, depth=100, rerank=True)
    gmap = reader.live_global_ids()  # monolithic id j <-> gmap[j]
    assert (gmap[np.asarray(i_mono)] == np.asarray(i_seg)).all()
    assert (np.asarray(s_mono) == np.asarray(s_seg)).all()
    print("segmented == monolithic live-corpus build: bit-for-bit")

    # Commit points are durable and generation-numbered; merges compact.
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "fakewords.ann")
        gen = writer.commit(path)
        writer.force_merge(1)           # drop deletes, remap ids
        gen2 = writer.commit()
        loaded = SegmentedAnnIndex.load(path)          # latest generation
        s2, i2 = loaded.search(queries, k=10, depth=100, rerank=True)
        assert (np.asarray(i2) == np.asarray(i_mono)).all()  # merged == mono
        old = SegmentedAnnIndex.load(path, generation=gen)   # point-in-time
        print(f"commit gens {gen}->{gen2}: merged reload identical to the "
              f"monolithic build; gen {gen} still readable "
              f"({old.num_segments} segments, {old.del_count} deletes)")

    # The quantized read path under a memory budget (docs/DESIGN.md §12):
    # state ONE resident-bytes number and the planner picks the best-recall
    # {fp32,int8,int4} postings x {exact,int8,none} rerank that fits —
    # here ~3x below the fp32+exact footprint, so it lands on a quantized
    # store with dequant fused into the score stage.
    full = AnnIndex.build(corpus, cfg)  # fp32 postings + fp32 rerank store
    budget = int(full.nbytes() / 3)
    ann_q = AnnIndex.build(corpus, cfg, memory_budget_bytes=budget)
    can_rerank = ann_q.index.vectors is not None or ann_q.index.vq is not None
    _, ids_q = ann_q.search(
        queries, params=SearchParams(k=10, depth=100, rerank=can_rerank))
    r_q = float(ev.recall_at(gt, ids_q))
    store = ("int" + str(ann_q.index.pq.bits)) if ann_q.index.pq is not None \
        else "fp32"
    print(f"memory_budget_bytes={budget/1e6:.1f}MB -> {store} postings, "
          f"{ann_q.nbytes()/1e6:.1f}MB resident "
          f"({full.nbytes()/1e6:.1f}MB unquantized), R@10={r_q:.3f}")

    # Filtered kNN (docs/DESIGN.md §13): attach per-doc metadata at build
    # time, derive a predicate bitmap, and search WITH it — the mask is
    # applied inside the match-stage kernel (one pass), so filtered docs
    # can never surface and depth semantics survive.
    year = np.random.default_rng(3).integers(2000, 2020, n_docs)
    ann_f = AnnIndex.build(corpus, cfg, metadata={"year": year})
    fmask = ann_f.metadata.range_mask("year", 2010, 2020)  # ~half the docs
    _, ids_f = ann_f.search(queries, k=10, depth=100, filt=fmask)
    kept = np.flatnonzero(np.asarray(fmask))
    _, gt_f = bruteforce.exact_topk(corpus[jnp.asarray(kept)], queries, 10)
    r_f = float(ev.recall_at(jnp.asarray(kept[np.asarray(gt_f)]), ids_f))
    got = np.asarray(ids_f)
    assert (year[got[got >= 0]] >= 2010).all()  # predicate honored exactly
    print(f"filtered search (year >= 2010, {len(kept)}/{n_docs} docs): "
          f"R@10={r_f:.3f} vs the filtered oracle")

    # Hybrid retrieval: RRF-fuse two retrievers that make different
    # mistakes (classic fake-words ~ lexical; dot-int8 ~ dense inner
    # product).  Sub-lists deeper than k give RRF room to promote docs
    # both retrievers rank moderately.
    from repro.core import plan

    dense = AnnIndex.build(corpus, FakeWordsConfig(quantization=50,
                                                   scoring="dot"))
    fusion = plan.FusionStage(plans=(
        plan.QueryPlan(search=lambda q: ann_f.search(q, k=30, depth=100),
                       label="classic"),
        plan.QueryPlan(search=lambda q: dense.search(q, k=30, depth=100),
                       label="dot"),
    ), k=10)
    _, ids_h = fusion.run(queries)
    r_lex = float(ev.recall_at(gt, ann_f.search(queries, k=10, depth=100)[1]))
    r_den = float(ev.recall_at(gt, dense.search(queries, k=10, depth=100)[1]))
    r_rrf = float(ev.recall_at(gt, ids_h))
    print(f"hybrid RRF(classic, dot) R@10={r_rrf:.3f} "
          f"(classic {r_lex:.3f}, dot {r_den:.3f})")

    # The graph encoding end to end (docs/DESIGN.md §15): method="hnsw"
    # traverses a fixed-degree adjacency with a batched beam search, so a
    # query scores O(iters * beam * degree) gathered rows instead of
    # streaming all N postings — the sublinear point on the Pareto curve
    # (BENCH_9.json).  Serving rides the same AnnService as every encoding.
    from repro.serve.ann_service import AnnService, AnnServiceConfig

    g = AnnIndex.build(corpus, GraphConfig(ef=128, beam=16, iters=12))
    svc = AnnService(g, AnnServiceConfig(k=10, depth=10, rerank=False))
    _, ids_g = svc.search_batch(queries)
    r_g = float(ev.recall_at(gt, jnp.asarray(ids_g)))
    print(f"hnsw served through AnnService: R@10={r_g:.3f} "
          f"(adjacency {g.index.neighbors.shape}, "
          f"entries {np.asarray(g.index.entry).tolist()})")


if __name__ == "__main__":
    main()
