"""Quickstart: ANN search on dense vectors with the fake-words index.

    PYTHONPATH=src python examples/quickstart.py

Builds all three paper encodings over a synthetic word2vec-like corpus,
searches, and prints R@(10,d) against the exact brute-force oracle —
a miniature of paper Table 1 through the public API.
"""
import dataclasses

import jax.numpy as jnp

from repro.core import bruteforce, eval as ev
from repro.core.index import AnnIndex
from repro.core.types import FakeWordsConfig, KdTreeConfig, LexicalLshConfig
from repro.data import embeddings


def main():
    print("== corpus: 20k synthetic word2vec-like vectors (300-d)")
    corpus_np = embeddings.make_corpus(
        dataclasses.replace(embeddings.WORD2VEC_LIKE, n_vectors=20_000))
    corpus = jnp.asarray(corpus_np)
    queries_np, _ = embeddings.make_queries(corpus_np, 64)
    queries = jnp.asarray(queries_np)
    _, gt = bruteforce.exact_topk(corpus, queries, 10)

    for cfg in [
        FakeWordsConfig(quantization=50),                 # best (paper)
        LexicalLshConfig(buckets=300, hashes=1),          # middle
        KdTreeConfig(dims=8, reduction="pca"),            # fast, collapsed
    ]:
        idx = AnnIndex.build(corpus, cfg)
        _, ids = idx.search(queries, k=100, depth=100)
        r10 = float(ev.recall_at(gt, ids[:, :10]))
        r100 = float(ev.recall_at(gt, ids))
        # two-phase: depth-100 match + exact rerank (the refinement step)
        _, ids_rr = idx.search(queries, k=10, depth=100, rerank=True)
        r_rr = float(ev.recall_at(gt, ids_rr))
        print(f"{idx.method:12s} R@(10,10)={r10:.3f} R@(10,100)={r100:.3f} "
              f"rerank@100->10={r_rr:.3f} index={idx.nbytes()/1e6:.0f}MB")


if __name__ == "__main__":
    main()
