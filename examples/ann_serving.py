"""ANN serving end to end: index build -> batched query service -> metrics.

    PYTHONPATH=src python examples/ann_serving.py

Thin wrapper over launch/serve.py (deliverable (b)'s serving driver) with a
smaller default corpus; on a pod the identical service runs over the
sharded index (core/distributed.py + serve/ann_service.py).
"""
from repro.launch import serve


def main():
    out = serve.main([
        "--n-docs", "50000", "--queries", "256", "--batch", "64", "--q", "50",
    ])
    assert out["recall@k"] > 0.9  # depth-100 + rerank on 50k docs


if __name__ == "__main__":
    main()
