"""ANN serving end to end: index build -> batched query service -> metrics.

    PYTHONPATH=src python examples/ann_serving.py

Thin wrapper over launch/serve.py (the serving driver) with a smaller
default corpus.  The service runs the same staged SearchPipeline as offline
search and serves ANY AnnIndex — swap ``--method`` for lsh / kdtree /
bruteforce; on a pod the identical service runs over the sharded index
(core/distributed.py + serve/ann_service.py).  ``stats()`` reports the
service's own p50/p99 batch latency from its wall-time ring buffer.
"""
from repro.launch import serve


def main():
    out = serve.main([
        "--n-docs", "50000", "--queries", "256", "--batch", "64", "--q", "50",
    ])
    assert out["recall@k"] > 0.9  # depth-100 + rerank on 50k docs
    assert out["p50_ms_per_batch"] is not None  # latency ring buffer filled


if __name__ == "__main__":
    main()
