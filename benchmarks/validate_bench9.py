"""CI gate for the graph Pareto-frontier artifact (docs/DESIGN.md §15).

    PYTHONPATH=src python benchmarks/validate_bench9.py [path]

Checks that ``benchmarks/BENCH_9.json`` carries the recall@10-vs-p50
sweep (brute force + fake words + hnsw on one corpus, one process), that
some hnsw operating point Pareto-dominates the best fake-words row
(recall@10 >= it at STRICTLY lower p50 — the acceptance bar for shipping
the graph encoding), that segmented hnsw rows exist at 1 / 4 / 16
segments with recall within 0.01 of the monolithic winner, that the
scored-candidate count is sublinear in N (a 4x corpus step moves it by
<= 2x and it stays under 5% of the corpus), and that the offline build
wall time is recorded.
"""
import json
import sys

SEGMENTS = (1, 4, 16)
PARETO_KEYS = {"method", "params", "segments", "n_docs", "recall_at_10",
               "p50_ms", "scored_candidates"}
SUBLINEAR_KEYS = {"n_docs", "scored_candidates", "frac_of_corpus"}
SEG_RECALL_TOL = 0.01


def validate(path: str) -> None:
    with open(path) as f:
        bench = json.load(f)
    assert bench.get("bench") == 9, bench.get("bench")

    rows = bench.get("pareto")
    assert rows, "no pareto rows"
    by_method = {}
    for row in rows:
        missing = PARETO_KEYS - set(row)
        assert not missing, f"pareto row {row} missing {missing}"
        assert row["p50_ms"] > 0 and 0.0 <= row["recall_at_10"] <= 1.0
        by_method.setdefault(row["method"], []).append(row)
    assert set(by_method) == {"bruteforce", "fakewords", "hnsw"}, (
        sorted(by_method))

    # Streaming rows must admit they score the whole corpus.
    for row in by_method["bruteforce"] + by_method["fakewords"]:
        assert row["scored_candidates"] == row["n_docs"], row

    # The Pareto gate, recomputed from the rows (not trusted from the
    # summary): some monolithic hnsw row ties-or-beats the best fake-words
    # recall at strictly lower p50.
    best_fw = max(by_method["fakewords"],
                  key=lambda r: (r["recall_at_10"], -r["p50_ms"]))
    mono = [r for r in by_method["hnsw"] if r["segments"] == 1]
    assert mono, "no monolithic hnsw rows"
    dominating = [r for r in mono
                  if r["recall_at_10"] >= best_fw["recall_at_10"]
                  and r["p50_ms"] < best_fw["p50_ms"]]
    assert dominating, (
        f"pareto gate: no hnsw row dominates fakewords "
        f"{best_fw['params']} ({best_fw['recall_at_10']} @ "
        f"{best_fw['p50_ms']}ms)")
    winner = min(dominating, key=lambda r: r["p50_ms"])

    # Segment tiers: 1/4/16 at the dedicated segmented operating point
    # (smaller per-segment graphs search at higher ef to hold recall —
    # Lucene's per-segment-HNSW deal), recall within tolerance of the
    # monolithic tier through the NRT per-segment loop.
    seg_params = bench["summary"]["segments_params"]
    seg_rows = {r["segments"]: r for r in by_method["hnsw"]
                if r["params"] == seg_params}
    assert set(SEGMENTS) <= set(seg_rows), sorted(seg_rows)
    for n_seg in SEGMENTS:
        drift = abs(seg_rows[n_seg]["recall_at_10"]
                    - seg_rows[1]["recall_at_10"])
        assert drift <= SEG_RECALL_TOL, (n_seg, seg_rows[n_seg])

    # Sublinearity: two corpus tiers 4x apart, scored candidates nearly
    # flat and a small corpus fraction.
    sub = bench.get("sublinear")
    assert sub and len(sub) == 2, sub
    for row in sub:
        missing = SUBLINEAR_KEYS - set(row)
        assert not missing, f"sublinear row {row} missing {missing}"
    small, full = sorted(sub, key=lambda r: r["n_docs"])
    assert full["n_docs"] == 4 * small["n_docs"], (small, full)
    assert full["scored_candidates"] <= 2 * small["scored_candidates"], (
        small, full)
    assert full["scored_candidates"] <= 0.05 * full["n_docs"], full

    summary = bench["summary"]
    assert summary["build_s"] > 0, summary
    assert summary["gate_pareto"] is True, summary
    assert summary["gate_sublinear"] is True, summary

    print(f"{path} ok: hnsw {winner['params']} "
          f"recall {winner['recall_at_10']} @ {winner['p50_ms']}ms beats "
          f"fakewords {best_fw['params']} ({best_fw['recall_at_10']} @ "
          f"{best_fw['p50_ms']}ms); scored "
          f"{full['scored_candidates']}/{full['n_docs']} docs "
          f"({small['scored_candidates']} at the 4x-smaller tier); "
          f"segments 1/4/16 within {SEG_RECALL_TOL} recall; "
          f"build {summary['build_s']}s")


if __name__ == "__main__":
    validate(sys.argv[1] if len(sys.argv) > 1 else "benchmarks/BENCH_9.json")
