"""Kernel micro-bench: wall-clock of jnp reference paths on CPU (relative
numbers; the Pallas kernels target TPU and are validated in interpret mode —
timing interpret mode is meaningless, so we time the XLA fallback and report
bytes/flops per call for the roofline narrative)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fakewords, lexical_lsh
from repro.core.types import FakeWordsConfig, LexicalLshConfig


def _time(f, *args, n=5) -> float:
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else None
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run(n_docs: int = 50_000, dim: int = 300, batch: int = 64) -> List[Dict]:
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(n_docs, dim)).astype(np.float32))
    rows = []

    cfg = FakeWordsConfig(quantization=50)
    idx = fakewords.build(vecs, cfg)
    q_tf = fakewords.encode_queries(vecs[:batch], cfg)
    f = jax.jit(lambda i, q: fakewords.classic_scores(i, q))
    dt = _time(f, idx, q_tf)
    gemm_bytes = idx.scored.size * 2 + q_tf.size * 4
    rows.append({
        "kernel": "fakewords_score(classic)", "us_per_call": dt * 1e6,
        "gflops": 2 * batch * n_docs * 2 * dim / dt / 1e9,
        "stream_mb": gemm_bytes / 1e6,
    })

    cfg_d = FakeWordsConfig(quantization=50, scoring="dot")
    idx_d = fakewords.build(vecs, cfg_d)
    f = jax.jit(lambda i, q: fakewords.dot_scores(i, q))
    dt = _time(f, idx_d, q_tf)
    rows.append({
        "kernel": "fakewords_score(dot-int8)", "us_per_call": dt * 1e6,
        "gflops": 2 * batch * n_docs * 2 * dim / dt / 1e9,
        "stream_mb": idx_d.tf.size / 1e6,
    })

    lcfg = LexicalLshConfig(buckets=300, hashes=1)
    sig = lexical_lsh.encode(vecs, lcfg)
    sq = sig[:batch]
    f = jax.jit(lexical_lsh.match_scores)
    dt = _time(f, sq, sig)
    rows.append({
        "kernel": "lsh_match", "us_per_call": dt * 1e6,
        "stream_mb": sig.size * 4 / 1e6,
    })

    from repro.core import bruteforce
    f = jax.jit(lambda c, q: bruteforce.exact_topk(c, q, 10))
    dt = _time(f, vecs, vecs[:batch])
    rows.append({
        "kernel": "bruteforce_topk", "us_per_call": dt * 1e6,
        "gflops": 2 * batch * n_docs * dim / dt / 1e9,
    })
    return rows


def main():
    rows = run()
    for r in rows:
        print(",".join(f"{k}={v:.1f}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in r.items()))
    return rows


if __name__ == "__main__":
    main()
