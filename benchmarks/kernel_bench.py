"""Kernel micro-bench: wall-clock of jnp reference paths on CPU (relative
numbers; the Pallas kernels target TPU and are validated in interpret mode —
timing interpret mode is meaningless, so off-TPU the fused rows time the XLA
online-reduction reference and report bytes/flops per call for the roofline
narrative).

The fused-vs-unfused section quantifies the HBM-traffic win of the fused
streaming score->top-k kernel (docs/DESIGN.md §4): unfused search writes and
re-reads a (B, N) f32 score matrix; fused search streams the index once and
emits only O(B * depth) — its ``stream_mb`` EXCLUDES the score matrix by
construction.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blockmax, bruteforce, fakewords, lexical_lsh
from repro.core.index import AnnIndex
from repro.core.types import (
    BruteForceConfig,
    FakeWordsConfig,
    KdTreeConfig,
    LexicalLshConfig,
)
from repro.kernels.fused_topk import ops as fused_ops
from repro.kernels.fused_topk import ref as fused_ref


def _time(f, *args, n=5) -> float:
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def _nbytes(*arrays) -> int:
    return sum(a.size * a.dtype.itemsize for a in arrays)


def fused_vs_unfused(
    n_docs: int, dim: int, batch: int, depth: int = 100
) -> Tuple[List[Dict], Dict]:
    """Fused streaming top-k vs unfused score-matrix + top_k, both scoring
    modes.  Returns (rows, summary).  Off-TPU the fused timing uses the XLA
    streaming reference (same memory behavior, timeable); on TPU it is the
    Pallas kernel itself."""
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(n_docs, dim)).astype(np.float32))
    on_tpu = jax.default_backend() == "tpu"
    rows: List[Dict] = []
    summary: Dict = {"depth": depth, "on_tpu": on_tpu}

    for scoring in ("classic", "dot"):
        cfg = FakeWordsConfig(quantization=50, scoring=scoring)
        idx = fakewords.build(vecs, cfg)
        q_tf = fakewords.encode_queries(vecs[:batch], cfg)
        docs = idx.scored if scoring == "classic" else idx.tf
        if scoring == "classic":
            qv = fakewords.classic_query(idx, q_tf)
        else:
            qv = fakewords.dot_query(idx, q_tf, dtype=jnp.int8)

        # unfused: dense (B, N) f32 scores written + re-read by top_k
        unfused = jax.jit(
            lambda q, d: jax.lax.top_k(fused_ref.scores_ref(q, d), depth)
        )
        dt_un = _time(unfused, qv, docs)
        score_matrix = batch * n_docs * 4 * 2  # write + top_k read-back
        un_mb = (_nbytes(docs, qv) + score_matrix) / 1e6
        rows.append({
            "kernel": f"search({scoring}) unfused einsum+top_k",
            "us_per_call": dt_un * 1e6, "stream_mb": un_mb,
        })

        # fused: index stream + O(B*depth) result; NO (B, N) matrix
        if on_tpu:
            fused_f = jax.jit(
                lambda q, d: fused_ops.fused_topk(q, d, depth)
            )
            impl = "pallas"
        else:
            fused_f = jax.jit(
                lambda q, d: fused_ref.streaming_topk_ref(q, d, depth)
            )
            impl = "xla-stream"
        dt_f = _time(fused_f, qv, docs)
        f_mb = (_nbytes(docs, qv) + batch * depth * (4 + 4)) / 1e6
        rows.append({
            "kernel": f"search({scoring}) fused top-k [{impl}]",
            "us_per_call": dt_f * 1e6, "stream_mb": f_mb,
        })
        # Measured regression check: the streamed path must retrieve the
        # same ids as the unfused oracle (the analytic byte formulas above
        # cannot fail; this can).
        _, i_un = unfused(qv, docs)
        _, i_f = fused_f(qv, docs)
        summary[scoring] = {
            "unfused_mb": un_mb, "fused_mb": f_mb,
            "stream_cut": un_mb / f_mb,
            "speedup": dt_un / dt_f,
            "ids_match": bool((np.asarray(i_un) == np.asarray(i_f)).all()),
        }
    return rows, summary


def pruned_vs_full(
    n_docs: int, dim: int, batch: int = 8, depth: int = 100,
    beta: float = 0.1, block_size: int = 256,
) -> Tuple[List[Dict], Dict]:
    """Blockmax two-stage pruning vs the full scan, all three scoring modes
    (classic / dot-int8 / LSH).  Off-TPU both sides time their XLA reference
    realizations; on TPU they route through the fused kernels.

    Byte accounting is per batch: the full scan streams the whole stored
    matrix once per batch; the pruned path streams the block upper bounds
    plus each query's gathered kept-block rows (B * n_keep * block_size).
    Pruning therefore wins bytes when batch * beta < 1 — the low-QPS
    latency-sensitive serving regime the paper's filtering targets — and
    wins compute (the stage-2 GEMM is a beta-fraction of the work) broadly.
    """
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(n_docs, dim)).astype(np.float32))
    vecs = bruteforce.l2_normalize(vecs)
    on_tpu = jax.default_backend() == "tpu"
    uk = None if on_tpu else False  # Pallas on TPU; timeable XLA ref on CPU
    n_keep = max(1, int(beta * -(-n_docs // block_size)))
    rows: List[Dict] = []
    summary: Dict = {
        "depth": depth, "beta": beta, "n_keep": n_keep, "on_tpu": on_tpu,
    }

    def add(mode: str, full_fn, full_mb: float, pruned_fn, pruned_mb: float):
        dt_full = _time(full_fn)
        dt_pr = _time(pruned_fn)
        rows.append({
            "kernel": f"search({mode}) full scan",
            "us_per_call": dt_full * 1e6, "stream_mb": full_mb,
        })
        rows.append({
            "kernel": f"search({mode}) blockmax beta={beta}",
            "us_per_call": dt_pr * 1e6, "stream_mb": pruned_mb,
        })
        summary[mode] = {
            "full_mb": full_mb, "pruned_mb": pruned_mb,
            "byte_cut": full_mb / pruned_mb, "speedup": dt_full / dt_pr,
        }

    for scoring in ("classic", "dot"):
        cfg = FakeWordsConfig(quantization=50, scoring=scoring)
        idx = fakewords.build(vecs, cfg, normalized=True)
        q_tf = fakewords.encode_queries(vecs[:batch], cfg, normalized=True)
        bm = blockmax.build_blockmax(idx, block_size)
        mat = idx.scored if scoring == "classic" else idx.tf
        add(
            scoring,
            lambda i=idx, q=q_tf, s=scoring: fakewords.search(
                i, q, None, k=depth, depth=depth, scoring=s, use_kernel=uk),
            (_nbytes(mat, q_tf) + batch * depth * 8) / 1e6,
            lambda i=idx, b=bm, q=q_tf: blockmax.pruned_search(
                i, b, q, n_keep=n_keep, depth=depth, use_kernel=uk),
            (_nbytes(bm.ub, q_tf)
             + batch * n_keep * block_size * mat.shape[1] * mat.dtype.itemsize
             + batch * depth * 8) / 1e6,
        )

    lcfg = LexicalLshConfig(buckets=300, hashes=1)
    lidx = lexical_lsh.build(vecs, lcfg, normalized=True)
    sig_q = lexical_lsh.encode(vecs[:batch], lcfg)
    bm_l = blockmax.build_blockmax(lidx, block_size)
    add(
        "lsh",
        lambda: lexical_lsh.search(
            lidx, sig_q, None, k=depth, depth=depth, use_kernel=uk),
        (_nbytes(lidx.sig, sig_q) + batch * depth * 8) / 1e6,
        lambda: blockmax.pruned_search(
            lidx, bm_l, sig_q, n_keep=n_keep, depth=depth, use_kernel=uk),
        (_nbytes(bm_l.ub, sig_q)
         + batch * n_keep * block_size * lidx.sig.shape[1] * 4
         + batch * depth * 8) / 1e6,
    )
    return rows, summary


def pipeline_latency(
    n_docs: int, dim: int, batch: int, depth: int = 100, k: int = 10
) -> List[Dict]:
    """End-to-end latency rows for every encoding through the shared staged
    SearchPipeline (AnnIndex.search: encode -> match -> exact rerank) — the
    same code path the serving layer runs.  Off-TPU the match stage times
    the XLA reference; on TPU the fused Pallas kernel."""
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(n_docs, dim)).astype(np.float32))
    queries = vecs[:batch]
    uk = None if jax.default_backend() == "tpu" else False
    rows: List[Dict] = []
    for cfg in (
        FakeWordsConfig(quantization=50),
        FakeWordsConfig(quantization=50, scoring="dot"),
        LexicalLshConfig(buckets=300, hashes=1),
        KdTreeConfig(dims=8, backend="scan"),
        BruteForceConfig(),
    ):
        ann = AnnIndex.build(vecs, cfg, use_kernel=uk)
        tag = ann.method
        if isinstance(cfg, FakeWordsConfig):
            tag = f"{ann.method}/{cfg.scoring}"
        dt = _time(lambda a=ann, q=queries: a.search(q, k=k, depth=depth, rerank=True))
        rows.append({
            "kernel": f"pipeline({tag}) encode+match+rerank",
            "us_per_call": dt * 1e6,
            "index_mb": ann.nbytes() / 1e6,
        })
    return rows


def build_bench(n_docs: int, dim: int) -> List[Dict]:
    """Build-time rows, local vs mesh-sharded, for every encoding through
    the staged BuildPipeline (docs/DESIGN.md §8).  The sharded build runs
    the SAME stages row-parallel under ``shard_map`` over every available
    device (1 device still exercises the psum path)."""
    from repro.core import builder

    rng = np.random.default_rng(0)
    n_dev = len(jax.devices())
    n_docs -= n_docs % n_dev  # divisibility for the doc shards
    vecs = jnp.asarray(rng.normal(size=(n_docs, dim)).astype(np.float32))
    mesh = jax.make_mesh((n_dev,), ("data",))
    rows: List[Dict] = []
    for cfg in (
        FakeWordsConfig(quantization=50),
        LexicalLshConfig(buckets=300, hashes=1),
        KdTreeConfig(dims=8, backend="scan"),
        BruteForceConfig(),
    ):
        tag = type(cfg).__name__.replace("Config", "")
        bp = builder.make_build_pipeline(cfg)
        # Jit BOTH sides so the rows compare steady-state compiled builds
        # (_time's warmup call pays each compile); an eager local build
        # would otherwise lose on per-op dispatch, not on sharding.
        local_fn = jax.jit(bp.build_local)
        sharded_fn = jax.jit(bp.sharded_build_fn(mesh, ("data",), n_docs))

        def local(fn=local_fn):
            idx = fn(vecs)
            jax.block_until_ready(jax.tree_util.tree_leaves(idx))
            return idx

        def sharded(fn=sharded_fn):
            idx = fn(vecs)
            jax.block_until_ready(jax.tree_util.tree_leaves(idx))
            return idx

        dt_l = _time(local, n=2)
        dt_s = _time(sharded, n=2)
        rows.append({
            "kernel": f"build({tag}) local", "us_per_call": dt_l * 1e6,
            "docs_per_s": n_docs / dt_l,
        })
        rows.append({
            "kernel": f"build({tag}) sharded x{n_dev}",
            "us_per_call": dt_s * 1e6, "docs_per_s": n_docs / dt_s,
        })
    return rows


def rerank_bench(
    n_docs: int, dim: int, batch: int, depth: int = 100, k: int = 10
) -> Tuple[List[Dict], Dict]:
    """fp32 vs int8 rerank store: latency, gather bytes, recall@10 against
    the exact oracle.  The int8 gather moves ~(4 dim)/(dim + 4) ~= 4x fewer
    bytes per candidate (docs/DESIGN.md §8); the measured recall delta is
    the price, bounded by the ||q||_1 * scale/2 score-error bound."""
    from repro.core import eval as ev

    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(n_docs, dim)).astype(np.float32))
    queries = vecs[:batch] + 0.01 * jnp.asarray(
        rng.normal(size=(batch, dim)).astype(np.float32))
    uk = None if jax.default_backend() == "tpu" else False
    _, gt = bruteforce.exact_topk(vecs, queries, k, use_kernel=uk)
    cfg = FakeWordsConfig(quantization=50)
    rows: List[Dict] = []
    summary: Dict = {"depth": depth}
    for store in ("exact", "int8"):
        ann = AnnIndex.build(vecs, cfg, rerank_store=store, use_kernel=uk)
        dt = _time(lambda a=ann: a.search(queries, k=k, depth=depth, rerank=True))
        _, ids = ann.search(queries, k=k, depth=depth, rerank=True)
        recall = float(ev.recall_at(gt, ids))
        # Gather bytes per batch: depth candidate rows per query.
        per_row = dim * 4 if store == "exact" else dim + 4
        gather_mb = batch * depth * per_row / 1e6
        rows.append({
            "kernel": f"rerank({store}) gather+cosine+topk",
            "us_per_call": dt * 1e6, "gather_mb": gather_mb,
            "recall_at_10": recall,
        })
        summary[store] = {"gather_mb": gather_mb, "recall": recall,
                          "us": dt * 1e6}
    summary["byte_cut"] = summary["exact"]["gather_mb"] / summary["int8"]["gather_mb"]
    summary["recall_delta"] = summary["exact"]["recall"] - summary["int8"]["recall"]
    return rows, summary


def segments_bench(
    n_docs: int, dim: int, batch: int, depth: int = 100, k: int = 10,
) -> Tuple[List[Dict], Dict]:
    """Segmented (Lucene-lifecycle) serving cost (docs/DESIGN.md §11):
    search latency at 1 / 4 / 16 segments over the same corpus, full-merge
    wall time from 16 segments, and post-merge recall@10 (which must equal
    the 1-segment recall — the merge rebuilds through the same
    BuildPipeline).  The latency spread IS the price of segment fan-out
    (per-segment dispatch + merge) that a background merge policy buys
    back."""
    from repro.core import eval as ev
    from repro.core.segments import IndexWriter
    from repro.core.types import FakeWordsConfig as FWC

    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(n_docs, dim)).astype(np.float32)
    queries = jnp.asarray(vecs[:batch])
    uk = None if jax.default_backend() == "tpu" else False
    _, gt = bruteforce.exact_topk(jnp.asarray(vecs), queries, k, use_kernel=uk)
    cfg = FWC(quantization=50)
    rows: List[Dict] = []
    summary: Dict = {"depth": depth}
    w = None
    for n_seg in (1, 4, 16):
        # One writer at a time: each holds a full index copy (originals +
        # tf/scored), and only the last (16-segment) one feeds the merge
        # timing below.
        w = IndexWriter(cfg, use_kernel=uk, merge_policy=None)
        for chunk in np.array_split(vecs, n_seg):
            w.add(chunk)
            w.flush()
        reader = w.refresh()

        def search(r=reader):
            return r.search(queries, k=k, depth=depth, rerank=True)

        dt = _time(search)
        _, ids = search()
        recall = float(ev.recall_at(gt, jnp.asarray(np.asarray(ids))))
        rows.append({
            "kernel": f"segments({n_seg}) search encode+match+merge+rerank",
            "us_per_call": dt * 1e6, "recall_at_10": recall,
        })
        summary[n_seg] = {"us": dt * 1e6, "recall": recall}
    t0 = time.perf_counter()
    w.force_merge(1)
    merged = w.refresh()
    merge_s = time.perf_counter() - t0
    _, ids = merged.search(queries, k=k, depth=depth, rerank=True)
    post_recall = float(ev.recall_at(gt, jnp.asarray(np.asarray(ids))))
    rows.append({
        "kernel": "segments merge 16->1", "us_per_call": merge_s * 1e6,
        "recall_at_10": post_recall,
    })
    summary["merge_s"] = merge_s
    summary["post_merge_recall"] = post_recall
    summary["fanout_cost"] = summary[16]["us"] / summary[1]["us"]
    return rows, summary


def quantized_ab(
    n_docs: int, dim: int, batch: int, depth: int = 100, k: int = 10,
    group: int = 32, n_calls: int = 20,
) -> Tuple[List[Dict], Dict]:
    """fp32 vs int8 vs int4 primary postings A/B (docs/DESIGN.md §12):
    build wall time, match-only QPS and p50/p99 latency, recall@10 against
    the exact oracle, and match-stage bytes streamed per full scan.

    Two method families: the cosine path (FlatIndex; a genuine 4-byte/elem
    fp32 baseline, so the byte cuts are the headline 4x / 6x numbers) and
    fake-words classic (whose fp32 store is the bf16 ``scored`` matrix plus
    the int8 tf).  Every row serves the full read path the budget planner
    pairs with a quantized store — match at ``depth`` candidates, rerank
    through the SAME int8 store — so ``recall_at_10`` isolates the match
    encoding (the rerank cost is constant across rows) and
    ``match_recall_at_10`` keeps the raw pre-rerank stage number.  Byte
    accounting reuses
    :func:`repro.core.memory_budget.postings_bytes_per_doc` so the A/B rows
    and the budget planner can never disagree.  The acceptance bars — int8
    >= 3.5x fewer match bytes within 0.02 recall of fp32, int4 >= 6x within
    0.05 — are recorded per row as ``bytes_cut_vs_fp32`` /
    ``recall_delta_vs_fp32`` on the cosine family."""
    from repro.core import eval as ev
    from repro.core import memory_budget as mb

    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(n_docs, dim)).astype(np.float32))
    queries = vecs[:batch] + 0.01 * jnp.asarray(
        rng.normal(size=(batch, dim)).astype(np.float32))
    uk = None if jax.default_backend() == "tpu" else False
    _, gt = bruteforce.exact_topk(vecs, queries, k, use_kernel=uk)
    rows: List[Dict] = []
    summary: Dict = {"depth": depth, "group": group, "k": k}
    for cfg in (BruteForceConfig(), FakeWordsConfig(quantization=50)):
        base: Dict = {}
        for pp in ("fp32", "int8", "int4"):
            t0 = time.perf_counter()
            ann = AnnIndex.build(
                vecs, cfg, rerank_store="int8", primary_postings=pp,
                postings_group=group, use_kernel=uk,
            )
            jax.block_until_ready(jax.tree_util.tree_leaves(ann.index))
            build_s = time.perf_counter() - t0

            def search(a=ann, rerank=True):
                return a.search(queries, k=k, depth=depth, rerank=rerank)

            jax.block_until_ready(search())  # compile
            lat = []
            for _ in range(n_calls):
                t1 = time.perf_counter()
                jax.block_until_ready(search())
                lat.append(time.perf_counter() - t1)
            lat_ms = np.asarray(lat, np.float64) * 1e3
            _, ids = search()
            recall = float(ev.recall_at(gt, ids))
            _, ids_m = search(rerank=False)
            match_recall = float(ev.recall_at(gt, ids_m))
            match_mb = (
                n_docs * mb.postings_bytes_per_doc(cfg, dim, pp, group) / 1e6
            )
            row = {
                "method": ann.method,
                "postings": pp,
                "build_s": round(build_s, 3),
                "qps": round(batch / float(np.percentile(lat_ms, 50)) * 1e3, 1),
                "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
                "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
                "recall_at_10": round(recall, 4),
                "match_recall_at_10": round(match_recall, 4),
                "match_mb": round(match_mb, 3),
            }
            if pp == "fp32":
                base = {"mb": match_mb, "recall": recall}
            row["bytes_cut_vs_fp32"] = round(base["mb"] / match_mb, 2)
            row["recall_delta_vs_fp32"] = round(base["recall"] - recall, 4)
            rows.append(row)
            summary.setdefault(ann.method, {})[pp] = {
                "bytes_cut": row["bytes_cut_vs_fp32"],
                "recall_delta": row["recall_delta_vs_fp32"],
            }
    return rows, summary


def filtered_ab(
    n_docs: int, dim: int, batch: int, depth: int = 100, k: int = 10,
    ratios: Tuple[float, ...] = (0.01, 0.1, 0.5), n_calls: int = 20,
) -> Tuple[List[Dict], Dict]:
    """Filtered vs unfiltered serving A/B (docs/DESIGN.md §13): QPS,
    p50/p99 latency, and recall@10 at 1% / 10% / 50% selectivity for the
    classic fake-words path over fp32 / int8 / int4 primary postings.

    The filter is applied INSIDE the match stage (one kernel pass — the
    bitmap operand masks scores to -inf in the tile loop), so filtered
    latency must track unfiltered latency, not the depth-inflated
    post-filter cost.  Recall is scored against the exact oracle over the
    kept sub-corpus (mapped back to global ids), so every tier's number is
    a true filtered recall, comparable across selectivities."""
    from repro.core import eval as ev

    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(n_docs, dim)).astype(np.float32))
    queries = vecs[:batch] + 0.01 * jnp.asarray(
        rng.normal(size=(batch, dim)).astype(np.float32))
    uk = None if jax.default_backend() == "tpu" else False
    rows: List[Dict] = []
    summary: Dict = {"depth": depth, "k": k, "ratios": list(ratios)}

    def truth_under(mask: np.ndarray) -> jax.Array:
        kept = np.flatnonzero(mask)
        _, gi = bruteforce.exact_topk(vecs[kept], queries, k, use_kernel=uk)
        return jnp.asarray(kept[np.asarray(gi)])

    masks = {}
    for ratio in ratios:
        m = (np.random.default_rng(int(ratio * 1000) + 7).random(n_docs)
             < ratio).astype(np.int32)
        m[: 2 * depth] = 1  # degenerate-draw floor: >= depth survivors
        masks[ratio] = m

    cfg = FakeWordsConfig(quantization=50)
    for pp in ("fp32", "int8", "int4"):
        ann = AnnIndex.build(vecs, cfg, rerank_store="int8",
                             primary_postings=pp, use_kernel=uk)

        def timed(filt):
            f = lambda: ann.search(queries, k=k, depth=depth, rerank=True,
                                   filt=filt)
            jax.block_until_ready(f())  # compile
            lat = []
            for _ in range(n_calls):
                t0 = time.perf_counter()
                jax.block_until_ready(f())
                lat.append(time.perf_counter() - t0)
            lat_ms = np.asarray(lat, np.float64) * 1e3
            _, ids = f()
            return lat_ms, ids

        lat_ms, ids = timed(None)
        _, gt = bruteforce.exact_topk(vecs, queries, k, use_kernel=uk)
        base = {
            "postings": pp, "selectivity": 1.0,
            "qps": round(batch / float(np.percentile(lat_ms, 50)) * 1e3, 1),
            "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
            "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
            "recall_at_10": round(float(ev.recall_at(gt, ids)), 4),
        }
        rows.append(base)
        for ratio in ratios:
            m = masks[ratio]
            lat_ms, ids = timed(jnp.asarray(m))
            assert ((np.asarray(ids) < 0)
                    | (m[np.maximum(np.asarray(ids), 0)] != 0)).all()
            p50 = float(np.percentile(lat_ms, 50))
            rows.append({
                "postings": pp, "selectivity": ratio,
                "qps": round(batch / p50 * 1e3, 1),
                "p50_ms": round(p50, 3),
                "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
                "recall_at_10": round(
                    float(ev.recall_at(truth_under(m), ids)), 4),
                "p50_vs_unfiltered": round(p50 / base["p50_ms"], 2),
            })
        summary[pp] = {
            "unfiltered_p50_ms": base["p50_ms"],
            "max_filtered_overhead": max(
                r["p50_vs_unfiltered"] for r in rows
                if r["postings"] == pp and r["selectivity"] < 1.0),
        }
    return rows, summary


def hybrid_ab(
    n_docs: int = 20_000, dim: int = 100, n_queries: int = 128,
    k: int = 10, k_sub: int = 30, depth: int = 100, n_calls: int = 10,
) -> Tuple[List[Dict], Dict]:
    """Hybrid lexical+dense fusion vs each retriever alone: RRF over the
    classic fake-words retriever (lexical surrogate) and the dot-scoring
    retriever (dense inner-product), k_sub-deep sub-lists fused to k
    (docs/DESIGN.md §13).  The acceptance gate — RRF recall@10 >= the best
    single retriever — needs k_sub well past k: RRF promotes docs that rank
    moderately in BOTH lists, which a k-deep sub-list truncates away.

    Runs on the word2vec-like synthetic corpus (queries are corpus words,
    the paper's setup) so the two retrievers make DIFFERENT mistakes —
    fusion has signal to exploit; on pure-noise corpora the lists correlate
    and RRF can only tie."""
    from repro.core import eval as ev
    from repro.core import plan as qp
    from repro.data import embeddings

    corpus = embeddings.make_corpus(
        embeddings.CorpusConfig(n_vectors=n_docs, dim=dim))
    queries, _ = embeddings.make_queries(corpus, n_queries)
    vecs = jnp.asarray(corpus)
    qs = jnp.asarray(queries)
    uk = None if jax.default_backend() == "tpu" else False
    _, gt = bruteforce.exact_topk(vecs, qs, k, use_kernel=uk)

    lex = AnnIndex.build(vecs, FakeWordsConfig(quantization=30), use_kernel=uk)
    dense = AnnIndex.build(
        vecs, FakeWordsConfig(quantization=30, scoring="dot"), use_kernel=uk)
    plans = (
        qp.QueryPlan(search=lambda q: lex.search(q, k=k_sub, depth=depth),
                     label="classic"),
        qp.QueryPlan(search=lambda q: dense.search(q, k=k_sub, depth=depth),
                     label="dense-dot"),
    )
    stage = qp.FusionStage(plans=plans, k=k)

    def timed(f):
        jax.block_until_ready(f())
        lat = []
        for _ in range(n_calls):
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            lat.append(time.perf_counter() - t0)
        lat_ms = np.asarray(lat, np.float64) * 1e3
        _, ids = f()
        return lat_ms, ids

    rows: List[Dict] = []
    for label, f in (
        ("classic", lambda: lex.search(qs, k=k, depth=depth)),
        ("dense-dot", lambda: dense.search(qs, k=k, depth=depth)),
        ("rrf-fusion", lambda: stage.run(qs)),
    ):
        lat_ms, ids = timed(f)
        p50 = float(np.percentile(lat_ms, 50))
        rows.append({
            "retriever": label,
            "qps": round(n_queries / p50 * 1e3, 1),
            "p50_ms": round(p50, 3),
            "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
            "recall_at_10": round(float(ev.recall_at(gt, ids[:, :k])), 4),
        })
    by = {r["retriever"]: r["recall_at_10"] for r in rows}
    summary = {
        "k": k, "k_sub": k_sub, "depth": depth,
        "classic": by["classic"], "dense": by["dense-dot"],
        "rrf": by["rrf-fusion"],
        "gate_rrf_ge_max": by["rrf-fusion"] >= max(by["classic"],
                                                   by["dense-dot"]),
    }
    return rows, summary


def packed_ab(
    n_docs: int = 8192, dim: int = 64, batch: int = 64, depth: int = 100,
    k: int = 10, n_calls: int = 20,
) -> Tuple[List[Dict], Dict]:
    """Packed single-launch vs per-segment loop (docs/DESIGN.md §14): QPS
    and p50/p99 at 1 / 4 / 16 segments over the same corpus, with the ids
    asserted identical pair-wise — the packed superbuffer is an execution
    strategy, not an approximation.  The per-segment loop pays one launch
    (encode + match + top-k + rerank + merge) per segment; packed pays one
    launch total, so the A/B spread at 16 segments IS the launch tax the
    superbuffer erases."""
    from repro.core.segments import IndexWriter

    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(n_docs, dim)).astype(np.float32)
    queries = jnp.asarray(vecs[:batch])
    uk = None if jax.default_backend() == "tpu" else False
    cfg = FakeWordsConfig(quantization=50)
    rows: List[Dict] = []
    summary: Dict = {"depth": depth, "k": k, "n_docs": n_docs}

    def timed(f):
        jax.block_until_ready(f())  # compile
        lat = []
        for _ in range(n_calls):
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            lat.append(time.perf_counter() - t0)
        lat_ms = np.asarray(lat, np.float64) * 1e3
        _, ids = f()
        return lat_ms, np.asarray(ids)

    for n_seg in (1, 4, 16):
        w = IndexWriter(cfg, use_kernel=uk, merge_policy=None)
        for chunk in np.array_split(vecs, n_seg):
            w.add(chunk)
            w.flush()
        reader = w.refresh()
        per_mode = {}
        for mode, flag in (("loop", False), ("packed", True)):
            lat_ms, ids = timed(
                lambda flag=flag: reader.search(
                    queries, k=k, depth=depth, rerank=True, packed=flag))
            p50 = float(np.percentile(lat_ms, 50))
            row = {
                "mode": mode, "segments": n_seg,
                "qps": round(batch / p50 * 1e3, 1),
                "p50_ms": round(p50, 3),
                "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
            }
            rows.append(row)
            per_mode[mode] = (row, ids)
        ids_match = bool(
            np.array_equal(per_mode["loop"][1], per_mode["packed"][1]))
        for row, _ in per_mode.values():
            row["ids_match"] = ids_match
        summary[n_seg] = {
            "loop_qps": per_mode["loop"][0]["qps"],
            "packed_qps": per_mode["packed"][0]["qps"],
            "speedup": round(per_mode["packed"][0]["qps"]
                             / per_mode["loop"][0]["qps"], 3),
            "ids_match": ids_match,
        }
    summary["gate_16seg_speedup"] = summary[16]["speedup"]
    return rows, summary


def async_ab(
    n_docs: int = 8192, dim: int = 64, n_queries: int = 256, depth: int = 100,
    k: int = 10, max_wait_ms: float = 2.0, max_batch: int = 16,
) -> Tuple[List[Dict], Dict]:
    """Async micro-batching vs sequential single-query serving at a fixed
    latency SLO (docs/DESIGN.md §14): the same ``n_queries`` singles are
    served once as back-to-back ``search_batch`` calls (one launch each)
    and once through the admission queue, where backlogged singles coalesce
    into up-to-``max_batch``-row launches.  Both run the packed segmented
    path over the same 4-segment index, so results are identical rows and
    the QPS delta is pure launch amortization."""
    from repro.core.segments import IndexWriter
    from repro.serve.ann_service import AnnService, AnnServiceConfig

    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(n_docs, dim)).astype(np.float32)
    pool = np.asarray(vecs[:n_queries])
    uk = None if jax.default_backend() == "tpu" else False
    cfg = FakeWordsConfig(quantization=50)
    w = IndexWriter(cfg, use_kernel=uk, merge_policy=None)
    for chunk in np.array_split(vecs, 4):
        w.add(chunk)
        w.flush()
    w.refresh()
    svc = AnnService(
        writer=w,
        service=AnnServiceConfig(k=k, depth=depth, rerank=True,
                                 max_batch=max_batch,
                                 max_wait_s=max_wait_ms / 1e3,
                                 queue_depth=2 * n_queries),
    )
    svc.search_batch(jnp.asarray(pool[:1]))  # compile
    svc.reset_latency()

    t0 = time.perf_counter()
    seq_ids = [np.asarray(svc.search_batch(jnp.asarray(q[None, :]))[1])
               for q in pool]
    seq_s = time.perf_counter() - t0
    seq_stats = svc.stats()

    svc.reset_latency()
    svc.start_async()
    try:
        t0 = time.perf_counter()
        futs = [svc.search_async(q) for q in pool]
        async_ids = [np.asarray(f.result(timeout=60)[1]) for f in futs]
        async_s = time.perf_counter() - t0
        st = svc.stats()
    finally:
        svc.stop_async()
    ids_match = bool(np.array_equal(np.concatenate(seq_ids),
                                    np.concatenate(async_ids)))

    rows = [
        {"mode": "sequential", "qps": round(n_queries / seq_s, 1),
         "p50_ms": seq_stats["lat_p50_ms"], "p99_ms": seq_stats["lat_p99_ms"],
         "launches": n_queries, "ids_match": ids_match},
        {"mode": "async-batched", "qps": round(n_queries / async_s, 1),
         "p50_ms": st["req_p50_ms"], "p99_ms": st["req_p99_ms"],
         "launches": st["async_launches"], "ids_match": ids_match},
    ]
    summary = {
        "n_queries": n_queries, "max_batch": max_batch,
        "max_wait_ms": max_wait_ms,
        "sequential_qps": rows[0]["qps"], "async_qps": rows[1]["qps"],
        "speedup": round(rows[1]["qps"] / rows[0]["qps"], 3),
        "batch_per_launch": round(n_queries / max(1, st["async_launches"]),
                                  2),
        "rejected": st["rejected"],
        "ids_match": ids_match,
    }
    return rows, summary


def graph_pareto(
    n_docs: int = 65_536, dim: int = 64, batch: int = 64, k: int = 10,
    n_calls: int = 15,
) -> Tuple[List[Dict], List[Dict], Dict]:
    """Recall@10-vs-p50 Pareto frontier (docs/DESIGN.md §15): the graph
    (hnsw) encoding against the paper's fake-words sweep and the exact
    oracle, all measured in ONE process on the same corpus and queries.

    Streaming encodings score every posting, so their scored-candidate
    count IS the corpus size; graph traversal scores
    ``entries + iters * beam * total_degree`` gathered rows regardless of
    N — the ``sublinear`` section records the measured counts at two
    corpus tiers (4x apart) to pin that down.  Segmented rows (1/4/16 via
    ``IndexWriter``) show the NRT fan-out price at the winning operating
    point.  Queries are in-distribution (``embeddings.make_queries``),
    the same protocol every other bench uses."""
    import dataclasses as _dc

    from repro.core import eval as ev, graph
    from repro.core.segments import IndexWriter
    from repro.core.types import GraphConfig
    from repro.data import embeddings

    uk = None if jax.default_backend() == "tpu" else False
    corpus_np = embeddings.make_corpus(
        _dc.replace(embeddings.WORD2VEC_LIKE, n_vectors=n_docs, dim=dim))
    vecs = jnp.asarray(corpus_np)
    q_np, _ = embeddings.make_queries(corpus_np, batch)
    queries = jnp.asarray(q_np)
    _, gt = bruteforce.exact_topk(vecs, queries, k, use_kernel=uk)

    def p50_of(f):
        jax.block_until_ready(f())  # compile
        lat = []
        for _ in range(n_calls):
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            lat.append(time.perf_counter() - t0)
        return float(np.percentile(np.asarray(lat, np.float64) * 1e3, 50))

    rows: List[Dict] = []

    def add_row(method, params, segments, scored, ids, p50):
        rec = float(ev.recall_at(gt, jnp.asarray(ids)[:, :k]))
        rows.append({
            "method": method, "params": params, "segments": segments,
            "n_docs": n_docs, "recall_at_10": round(rec, 4),
            "p50_ms": round(p50, 2), "scored_candidates": scored,
        })
        return rows[-1]

    bf = AnnIndex.build(vecs, BruteForceConfig(), use_kernel=uk)
    f = lambda: bf.search(queries, k=k, depth=k)  # noqa: E731
    add_row("bruteforce", "exact", 1, n_docs, f()[1], p50_of(f))

    for qz, depth in ((30, 100), (50, 100), (50, 400)):
        idx = AnnIndex.build(
            vecs, FakeWordsConfig(quantization=qz), use_kernel=uk)
        f = lambda idx=idx, depth=depth: idx.search(  # noqa: E731
            queries, k=k, depth=depth, rerank=True)
        add_row("fakewords", f"q={qz},depth={depth}", 1, n_docs,
                f()[1], p50_of(f))
    fw_rows = [r for r in rows if r["method"] == "fakewords"]
    best_fw = max(fw_rows, key=lambda r: (r["recall_at_10"], -r["p50_ms"]))

    # One strong offline build, then the search-time sweep rides it — the
    # adjacency is the index, ef/beam/iters are query-time knobs.
    vn = bruteforce.l2_normalize(vecs)
    qn = bruteforce.l2_normalize(queries)
    bcfg = GraphConfig(degree=32, reverse_degree=32, ef_construction=128,
                       entries=16)
    t0 = time.perf_counter()
    nb, entry = graph.build_graph(vn, bcfg)
    jax.block_until_ready(nb)
    build_s = time.perf_counter() - t0

    def g_search(ef, beam, iters, with_stats=False):
        return graph.search_graph(
            vn, nb, entry, qn, k, ef=ef, beam=beam, iters=iters,
            n_docs=n_docs, use_kernel=uk, with_stats=with_stats)

    hnsw_rows = []
    sweep = ((16, 2, 6), (32, 4, 8), (64, 4, 8), (64, 4, 10),
             (64, 2, 16), (64, 8, 8))
    for ef, beam, iters in sweep:
        f = jax.jit(lambda ef=ef, beam=beam, iters=iters:  # noqa: E731
                    g_search(ef, beam, iters))
        _, _, sc = g_search(ef, beam, iters, with_stats=True)
        row = add_row("hnsw", f"ef={ef},beam={beam},iters={iters}", 1,
                      int(np.asarray(sc).max()), f()[1], p50_of(f))
        hnsw_rows.append((row, (ef, beam, iters)))

    dominating = [(r, p) for r, p in hnsw_rows
                  if r["recall_at_10"] >= best_fw["recall_at_10"]]
    pool = dominating or hnsw_rows
    winner, w_params = min(pool, key=lambda rp: rp[0]["p50_ms"])
    gate_pareto = bool(
        winner["recall_at_10"] >= best_fw["recall_at_10"]
        and winner["p50_ms"] < best_fw["p50_ms"])

    # NRT fan-out: same corpus split into 1 / 4 / 16 flushed segments,
    # searched through the per-segment loop (graphs have no packed layout
    # — PackedUnsupported fallback).  Smaller per-segment graphs need a
    # higher ef to hold recall — contiguous NRT slices of a clustered
    # corpus leave most queries out-of-distribution for 3 of 4 segments,
    # exactly Lucene's per-segment-HNSW cost — so the tiers run one
    # dedicated higher-effort operating point, measured at every tier.
    s_ef, s_beam, s_iters = 128, 8, 12
    seg_params = f"ef={s_ef},beam={s_beam},iters={s_iters}"
    seg_cfg = _dc.replace(bcfg, ef=s_ef, beam=s_beam, iters=s_iters)
    segments_p50 = {}
    segments_recall = {}
    f = jax.jit(lambda: g_search(s_ef, s_beam, s_iters))
    _, _, sc = g_search(s_ef, s_beam, s_iters, with_stats=True)
    row = add_row("hnsw", seg_params, 1, int(np.asarray(sc).max()),
                  f()[1], p50_of(f))
    segments_p50["1"] = row["p50_ms"]
    segments_recall["1"] = row["recall_at_10"]
    for n_seg in (4, 16):
        w = IndexWriter(seg_cfg, use_kernel=uk, merge_policy=None)
        for chunk in np.array_split(np.asarray(corpus_np), n_seg):
            w.add(chunk)
            w.flush()
        reader = w.refresh()
        f = lambda reader=reader: reader.search(queries, k=k, depth=k)  # noqa: E731,E501
        row = add_row("hnsw", seg_params, n_seg, None, f()[1], p50_of(f))
        segments_p50[str(n_seg)] = row["p50_ms"]
        segments_recall[str(n_seg)] = row["recall_at_10"]

    # Sublinearity: the same build+search params on a 4x-smaller tier of
    # the same corpus — scored candidates should barely move while the
    # streamed count drops 4x by construction.
    n_small = n_docs // 4
    w_ef, w_beam, w_iters = w_params
    vn_small = bruteforce.l2_normalize(vecs[:n_small])
    nb_s, entry_s = graph.build_graph(vn_small, bcfg)
    _, _, sc_small = graph.search_graph(
        vn_small, nb_s, entry_s, qn, k, ef=w_ef, beam=w_beam,
        iters=w_iters, n_docs=n_small, use_kernel=uk, with_stats=True)
    scored_small = int(np.asarray(sc_small).max())
    scored_full = winner["scored_candidates"]
    sub_rows = [
        {"n_docs": n_small, "scored_candidates": scored_small,
         "frac_of_corpus": round(scored_small / n_small, 4)},
        {"n_docs": n_docs, "scored_candidates": scored_full,
         "frac_of_corpus": round(scored_full / n_docs, 4)},
    ]
    gate_sublinear = bool(scored_full <= 2 * scored_small
                          and scored_full <= 0.05 * n_docs)

    summary = {
        "n_docs": n_docs, "dim": dim, "batch": batch, "k": k,
        "build_s": round(build_s, 1),
        "build_params": ("degree=32,reverse_degree=32,"
                         "ef_construction=128,entries=16"),
        "best_fakewords": {"params": best_fw["params"],
                           "recall_at_10": best_fw["recall_at_10"],
                           "p50_ms": best_fw["p50_ms"]},
        "best_hnsw": {"params": winner["params"],
                      "recall_at_10": winner["recall_at_10"],
                      "p50_ms": winner["p50_ms"],
                      "scored_candidates": winner["scored_candidates"]},
        "segments_params": seg_params,
        "segments_p50_ms": segments_p50,
        "segments_recall": segments_recall,
        "gate_pareto": gate_pareto,
        "gate_sublinear": gate_sublinear,
    }
    return rows, sub_rows, summary


def emit_bench9(
    path: str, n_docs: int = 65_536, dim: int = 64, batch: int = 64,
) -> Dict:
    """Write the graph Pareto-frontier artifact validated in CI
    (benchmarks/validate_bench9.py): recall@10 vs p50 for hnsw / fake
    words / brute force on one corpus, segmented hnsw at 1/4/16, graph
    build wall time, and scored-candidate counts at two corpus tiers."""
    rows, sub_rows, summary = graph_pareto(n_docs, dim, batch)
    bench = {
        "bench": 9,
        "backend": jax.default_backend(),
        "n_docs": n_docs,
        "dim": dim,
        "batch": batch,
        "pareto": rows,
        "sublinear": sub_rows,
        "summary": summary,
    }
    with open(path, "w") as f:
        json.dump(bench, f, indent=2)
        f.write("\n")
    return bench


def emit_bench8(
    path: str, n_docs: int = 8192, dim: int = 64, batch: int = 64,
) -> Dict:
    """Write the packed single-launch + async micro-batching artifact
    validated in CI (benchmarks/validate_bench8.py): packed-vs-looped
    QPS/p50/p99 at 1/4/16 segments with identical ids, and async-batched
    vs sequential single-query QPS at a fixed 2 ms coalescing SLO."""
    p_rows, p_summary = packed_ab(n_docs, dim, batch)
    a_rows, a_summary = async_ab(n_docs, dim)
    bench = {
        "bench": 8,
        "backend": jax.default_backend(),
        "n_docs": n_docs,
        "dim": dim,
        "batch": batch,
        "packed_ab": p_rows,
        "async_ab": a_rows,
        "summary": {"packed": p_summary, "async": a_summary},
    }
    with open(path, "w") as f:
        json.dump(bench, f, indent=2)
        f.write("\n")
    return bench


def emit_bench7(
    path: str, n_docs: int = 20_000, dim: int = 300, batch: int = 64,
) -> Dict:
    """Write the filtered + hybrid A/B artifact validated in CI
    (benchmarks/validate_bench7.py): filtered-vs-unfiltered serving at
    1%/10%/50% selectivity and RRF(classic, dense) vs each alone."""
    f_rows, f_summary = filtered_ab(n_docs, dim, batch)
    h_rows, h_summary = hybrid_ab()
    bench = {
        "bench": 7,
        "backend": jax.default_backend(),
        "n_docs": n_docs,
        "dim": dim,
        "batch": batch,
        "filtered_ab": f_rows,
        "hybrid_ab": h_rows,
        "summary": {"filtered": f_summary, "hybrid": h_summary},
    }
    with open(path, "w") as f:
        json.dump(bench, f, indent=2)
        f.write("\n")
    return bench


def emit_bench6(
    path: str, n_docs: int = 20_000, dim: int = 300, batch: int = 64,
) -> Dict:
    """Write the quantized-read-path A/B artifact consumed by
    :func:`repro.core.memory_budget.load_frontier` and validated in CI."""
    rows, summary = quantized_ab(n_docs, dim, batch)
    bench = {
        "bench": 6,
        "backend": jax.default_backend(),
        "n_docs": n_docs,
        "dim": dim,
        "batch": batch,
        "quantized_ab": rows,
        "summary": summary,
    }
    with open(path, "w") as f:
        json.dump(bench, f, indent=2)
        f.write("\n")
    return bench


def run(n_docs: int = 50_000, dim: int = 300, batch: int = 64) -> List[Dict]:
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(n_docs, dim)).astype(np.float32))
    rows = []

    cfg = FakeWordsConfig(quantization=50)
    idx = fakewords.build(vecs, cfg)
    q_tf = fakewords.encode_queries(vecs[:batch], cfg)
    f = jax.jit(lambda i, q: fakewords.classic_scores(i, q))
    dt = _time(f, idx, q_tf)
    gemm_bytes = idx.scored.size * 2 + q_tf.size * 4
    rows.append({
        "kernel": "fakewords_score(classic)", "us_per_call": dt * 1e6,
        "gflops": 2 * batch * n_docs * 2 * dim / dt / 1e9,
        "stream_mb": gemm_bytes / 1e6,
    })

    cfg_d = FakeWordsConfig(quantization=50, scoring="dot")
    idx_d = fakewords.build(vecs, cfg_d)
    f = jax.jit(lambda i, q: fakewords.dot_scores(i, q))
    dt = _time(f, idx_d, q_tf)
    rows.append({
        "kernel": "fakewords_score(dot-int8)", "us_per_call": dt * 1e6,
        "gflops": 2 * batch * n_docs * 2 * dim / dt / 1e9,
        "stream_mb": idx_d.tf.size / 1e6,
    })

    lcfg = LexicalLshConfig(buckets=300, hashes=1)
    sig = lexical_lsh.encode(vecs, lcfg)
    sq = sig[:batch]
    f = jax.jit(lexical_lsh.match_scores)
    dt = _time(f, sq, sig)
    rows.append({
        "kernel": "lsh_match", "us_per_call": dt * 1e6,
        "stream_mb": sig.size * 4 / 1e6,
    })

    from repro.core import bruteforce
    f = jax.jit(lambda c, q: bruteforce.exact_topk(c, q, 10, use_kernel=False))
    dt = _time(f, vecs, vecs[:batch])
    rows.append({
        "kernel": "bruteforce_topk", "us_per_call": dt * 1e6,
        "gflops": 2 * batch * n_docs * dim / dt / 1e9,
    })
    return rows


def _print_rows(rows: List[Dict]) -> None:
    for r in rows:
        print(",".join(f"{k}={v:.1f}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in r.items()))


def main(n_docs: int = 50_000, dim: int = 300, batch: int = 64):
    rows = run(n_docs, dim, batch)
    _print_rows(rows)
    pl_rows = pipeline_latency(n_docs, dim, batch)
    _print_rows(pl_rows)
    f_rows, summary = fused_vs_unfused(n_docs, dim, batch)
    _print_rows(f_rows)
    for scoring in ("classic", "dot"):
        s = summary[scoring]
        print(
            f"fused[{scoring}]: streams {s['fused_mb']:.1f} MB vs "
            f"{s['unfused_mb']:.1f} MB unfused "
            f"({s['stream_cut']:.1f}x less HBM traffic, no (B,N) score "
            f"matrix; wall-clock {s['speedup']:.2f}x"
            f"{' on-TPU' if summary['on_tpu'] else ' via XLA streaming ref'}; "
            f"ids_match={s['ids_match']})"
        )
    p_rows, p_summary = pruned_vs_full(n_docs, dim)
    _print_rows(p_rows)
    for mode in ("classic", "dot", "lsh"):
        s = p_summary[mode]
        print(
            f"blockmax[{mode}]: beta={p_summary['beta']} streams "
            f"{s['pruned_mb']:.1f} MB vs {s['full_mb']:.1f} MB full "
            f"({s['byte_cut']:.1f}x byte cut; wall-clock {s['speedup']:.2f}x"
            f"{' on-TPU' if p_summary['on_tpu'] else ' via XLA ref'})"
        )
    b_rows = build_bench(min(n_docs, 20_000), dim)
    _print_rows(b_rows)
    r_rows, r_summary = rerank_bench(n_docs, dim, batch)
    _print_rows(r_rows)
    print(
        f"rerank[int8]: gathers {r_summary['int8']['gather_mb']:.2f} MB vs "
        f"{r_summary['exact']['gather_mb']:.2f} MB fp32 "
        f"({r_summary['byte_cut']:.1f}x fewer rerank gather bytes; "
        f"recall@10 delta {r_summary['recall_delta']:+.4f})"
    )
    s_rows, s_summary = segments_bench(min(n_docs, 20_000), dim, min(batch, 16))
    _print_rows(s_rows)
    print(
        f"segments: 16-seg search {s_summary['fanout_cost']:.2f}x the "
        f"1-seg latency (fan-out price a background merge buys back); "
        f"merge 16->1 in {s_summary['merge_s']:.2f}s; post-merge recall@10 "
        f"{s_summary['post_merge_recall']:.3f} "
        f"(1-seg {s_summary[1]['recall']:.3f})"
    )
    q_rows, q_summary = quantized_ab(min(n_docs, 20_000), dim, batch)
    _print_rows(q_rows)
    for method, per_pp in q_summary.items():
        if not isinstance(per_pp, dict) or "int8" not in per_pp:
            continue
        print(
            f"quantized[{method}]: int8 {per_pp['int8']['bytes_cut']:.1f}x "
            f"fewer match bytes (recall@10 delta "
            f"{per_pp['int8']['recall_delta']:+.4f}), int4 "
            f"{per_pp['int4']['bytes_cut']:.1f}x (delta "
            f"{per_pp['int4']['recall_delta']:+.4f}) vs fp32"
        )
    return (
        rows + pl_rows + f_rows + p_rows + b_rows + r_rows + s_rows + q_rows,
        {**summary, "blockmax": p_summary, "rerank": r_summary,
         "segments": s_summary, "quantized": q_summary},
    )


if __name__ == "__main__":
    import sys

    if "--bench6" in sys.argv:
        out = os.path.join(os.path.dirname(__file__), "BENCH_6.json")
        bench = emit_bench6(out)
        _print_rows(bench["quantized_ab"])
        print(f"wrote {out}")
    elif "--bench7" in sys.argv:
        out = os.path.join(os.path.dirname(__file__), "BENCH_7.json")
        bench = emit_bench7(out)
        _print_rows(bench["filtered_ab"])
        _print_rows(bench["hybrid_ab"])
        h = bench["summary"]["hybrid"]
        print(f"hybrid: rrf {h['rrf']} vs classic {h['classic']} / "
              f"dense {h['dense']} (gate {h['gate_rrf_ge_max']})")
        print(f"wrote {out}")
    elif "--bench8" in sys.argv:
        out = os.path.join(os.path.dirname(__file__), "BENCH_8.json")
        bench = emit_bench8(out)
        _print_rows(bench["packed_ab"])
        _print_rows(bench["async_ab"])
        p = bench["summary"]["packed"]
        a = bench["summary"]["async"]
        print(f"packed: {p[16]['speedup']:.2f}x QPS over the per-segment "
              f"loop at 16 segments (ids_match={p[16]['ids_match']}); "
              f"async: {a['speedup']:.2f}x sequential at "
              f"{a['batch_per_launch']:.1f} rows/launch "
              f"(SLO {a['max_wait_ms']}ms)")
        print(f"wrote {out}")
    elif "--bench9" in sys.argv:
        out = os.path.join(os.path.dirname(__file__), "BENCH_9.json")
        bench = emit_bench9(out)
        _print_rows(bench["pareto"])
        _print_rows(bench["sublinear"])
        s = bench["summary"]
        print(f"pareto: hnsw {s['best_hnsw']['params']} recall "
              f"{s['best_hnsw']['recall_at_10']} @ "
              f"{s['best_hnsw']['p50_ms']}ms vs fakewords "
              f"{s['best_fakewords']['params']} "
              f"{s['best_fakewords']['recall_at_10']} @ "
              f"{s['best_fakewords']['p50_ms']}ms "
              f"(gate {s['gate_pareto']}); build {s['build_s']}s; "
              f"sublinear gate {s['gate_sublinear']}")
        print(f"wrote {out}")
    else:
        main()
