"""Beyond-Table-1 ablations: the paper's knobs plus our system levers.

  * df-pruning sweep (the paper's "filter high-frequency terms": efficiency
    AND effectiveness — tuned per collection);
  * rerank on/off at each depth (the refinement step the paper describes
    but does not implement);
  * blockmax beta sweep (WAND-style block pruning: bytes saved vs recall);
  * classic vs dot scoring (paper-faithful tf-idf vs idealized int8 dot).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax.numpy as jnp

from repro.core import blockmax, bruteforce, eval as ev, fakewords
from repro.core.types import FakeWordsConfig
from repro.data import embeddings

K = 10


def run(n_docs: int = 50_000, n_queries: int = 256) -> List[Dict]:
    corpus_np = embeddings.make_corpus(
        dataclasses.replace(embeddings.WORD2VEC_LIKE, n_vectors=n_docs))
    corpus = jnp.asarray(corpus_np)
    queries_np, _ = embeddings.make_queries(corpus_np, n_queries)
    queries = bruteforce.l2_normalize(jnp.asarray(queries_np))
    _, gt_i = bruteforce.exact_topk(corpus, queries, K)
    rows: List[Dict] = []

    # -- df-pruning sweep (classic scoring)
    cfg = FakeWordsConfig(quantization=50)
    idx = fakewords.build(corpus, cfg)
    q_tf = fakewords.encode_queries(queries, cfg)
    for ratio in (1.0, 0.5, 0.25, 0.1, 0.05):
        _, ids = fakewords.search(idx, q_tf, queries, k=K, depth=100,
                                  df_max_ratio=ratio)
        keep = fakewords.df_prune_mask(idx.df, idx.num_docs, ratio)
        rows.append({
            "experiment": "df_prune", "config": f"ratio={ratio}",
            "recall@100": float(ev.recall_at(gt_i, ids[:, :K])),
            "terms_kept": int(keep.sum()), "terms_total": int(keep.shape[0]),
        })

    # -- rerank on/off
    for depth in (10, 20, 50, 100):
        _, ids_plain = fakewords.search(idx, q_tf, queries, k=K, depth=depth)
        _, ids_rr = fakewords.search(idx, q_tf, queries, k=K, depth=depth, rerank=True)
        rows.append({
            "experiment": "rerank", "config": f"d={depth}",
            "recall_plain": float(ev.recall_at(gt_i, ids_plain)),
            "recall_rerank": float(ev.recall_at(gt_i, ids_rr)),
        })

    # -- blockmax beta sweep
    bm = blockmax.build_blockmax(idx, block_size=256)
    n_blocks = bm.ub.shape[0]
    for frac in (1.0, 0.5, 0.25, 0.1):
        n_keep = max(1, int(frac * n_blocks))
        _, ids = blockmax.pruned_search(idx, bm, q_tf, n_keep=n_keep, depth=100)
        rows.append({
            "experiment": "blockmax", "config": f"keep={frac}",
            "recall@100": float(ev.recall_at(gt_i, ids[:, :K])),
            "bytes_frac": n_keep / n_blocks,
        })

    # -- scoring mode
    for scoring in ("classic", "dot"):
        c = FakeWordsConfig(quantization=50, scoring=scoring)
        ix = fakewords.build(corpus, c)
        qt = fakewords.encode_queries(queries, c)
        _, ids = fakewords.search(ix, qt, queries, k=K, depth=100, scoring=scoring)
        rows.append({
            "experiment": "scoring", "config": scoring,
            "recall@100": float(ev.recall_at(gt_i, ids[:, :K])),
            "index_mb": ix.nbytes() / 1e6,
        })
    return rows


def main():
    rows = run()
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    return rows


if __name__ == "__main__":
    main()
