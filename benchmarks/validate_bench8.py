"""CI gate for the packed single-launch + async micro-batching artifact
(docs/DESIGN.md §14).

    PYTHONPATH=src python benchmarks/validate_bench8.py [path]

Checks that ``benchmarks/BENCH_8.json`` carries the packed-vs-looped A/B
rows at every segment count (1 / 4 / 16), that packed and looped ids are
IDENTICAL at every tier (the superbuffer is an execution strategy, not an
approximation — any drift is a packing bug), that packed beats the
per-segment loop by >= 1.5x QPS at 16 segments (the launch-tax acceptance
bar), and that the async micro-batcher beats sequential single-query
``search_batch`` throughput on the same index with identical ids and no
shed requests.
"""
import json
import sys

SEGMENTS = (1, 4, 16)
PACKED_KEYS = {"mode", "segments", "qps", "p50_ms", "p99_ms", "ids_match"}
ASYNC_KEYS = {"mode", "qps", "p50_ms", "p99_ms", "launches", "ids_match"}
MIN_16SEG_SPEEDUP = 1.5


def validate(path: str) -> None:
    with open(path) as f:
        bench = json.load(f)
    assert bench.get("bench") == 8, bench.get("bench")

    rows = bench.get("packed_ab")
    assert rows, "no packed_ab rows"
    by_seg = {}
    for row in rows:
        missing = PACKED_KEYS - set(row)
        assert not missing, f"packed row {row} missing {missing}"
        assert row["qps"] > 0 and row["p50_ms"] > 0
        by_seg.setdefault(row["segments"], {})[row["mode"]] = row
    assert set(by_seg) == set(SEGMENTS), sorted(by_seg)
    for n_seg, modes in by_seg.items():
        assert set(modes) == {"loop", "packed"}, (n_seg, sorted(modes))
        for row in modes.values():
            assert row["ids_match"] is True, (n_seg, row)
    speedup = by_seg[16]["packed"]["qps"] / by_seg[16]["loop"]["qps"]
    assert speedup >= MIN_16SEG_SPEEDUP, (
        f"packed gate: {speedup:.2f}x < {MIN_16SEG_SPEEDUP}x at 16 segments")
    # JSON stringifies the int segment keys in the summary.
    p_sum = bench["summary"]["packed"]
    assert p_sum["gate_16seg_speedup"] >= MIN_16SEG_SPEEDUP, p_sum

    a_rows = bench.get("async_ab")
    assert a_rows, "no async_ab rows"
    for row in a_rows:
        missing = ASYNC_KEYS - set(row)
        assert not missing, f"async row {row} missing {missing}"
        assert row["ids_match"] is True, row
    by_mode = {r["mode"]: r for r in a_rows}
    assert set(by_mode) == {"sequential", "async-batched"}, sorted(by_mode)
    seq, asy = by_mode["sequential"], by_mode["async-batched"]
    assert asy["qps"] > seq["qps"], (
        f"async gate: batched {asy['qps']} <= sequential {seq['qps']}")
    assert asy["launches"] < seq["launches"], (asy, seq)
    a_sum = bench["summary"]["async"]
    assert a_sum["rejected"] == 0, a_sum
    assert a_sum["batch_per_launch"] > 1.0, a_sum

    print(f"{path} ok: packed {speedup:.2f}x loop at 16 segments "
          f"(ids identical at {len(by_seg)} tiers), async "
          f"{asy['qps']}/{seq['qps']} qps at "
          f"{a_sum['batch_per_launch']:.1f} rows/launch")


if __name__ == "__main__":
    validate(sys.argv[1] if len(sys.argv) > 1 else "benchmarks/BENCH_8.json")
