"""Paper Table 1 reproduction: R@(10,d), query latency, index size for the
three methods on word2vec-like and GloVe-like corpora.

No internet in this container, so corpora are synthesized with matched
statistics (data/embeddings.py; DESIGN.md §6).  The validated claims are the
paper's RELATIVE orderings and trends, which are distribution-robust:

  * fake words  > lexical LSH >> k-d tree on recall;
  * k-d tree fastest / smallest; recall collapses after 300->8-dim reduction;
  * fake-words recall rises with Q (and index grows);
  * recall rises with retrieval depth d.

Corpus size defaults to 100k vectors (laptop-CPU-friendly; the paper's 3M /
1.2M sizes are exercised abstractly by the dry-run ann-* configs).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp

from repro.core import bruteforce, eval as ev
from repro.core.index import AnnIndex
from repro.core.types import FakeWordsConfig, KdTreeConfig, LexicalLshConfig
from repro.data import embeddings

DEPTHS = (10, 20, 50, 100)
K = 10


def _eval_method(corpus, queries, gt_i, config, n_timing=64) -> Dict:
    idx = AnnIndex.build(corpus, config)
    # recall at depths from ONE depth-100 retrieval (prefix property)
    _, ids = idx.search(queries, k=max(DEPTHS), depth=max(DEPTHS))
    recalls = {d: float(ev.recall_at(gt_i, ids[:, :d])) for d in DEPTHS}
    # latency at d=100, one query at a time (paper's worst-case protocol)
    idx.search(queries[:1], k=K, depth=100)  # warmup/compile
    t0 = time.perf_counter()
    for i in range(n_timing):
        s, _ = idx.search(queries[i : i + 1], k=K, depth=100)
    s.block_until_ready()
    lat_ms = (time.perf_counter() - t0) / n_timing * 1e3
    return {"recalls": recalls, "latency_ms": lat_ms, "index_mb": idx.nbytes() / 1e6}


def run(n_docs: int = 100_000, n_queries: int = 256, fast: bool = False) -> List[Dict]:
    corpora = {
        "word2vec-like": embeddings.WORD2VEC_LIKE,
        "glove-like": embeddings.GLOVE_LIKE,
    }
    rows = []
    qs = [70, 50, 30] if not fast else [50]
    lsh_settings = (
        [(300, 1, 1), (300, 1, 2), (50, 30, 1)] if not fast else [(300, 1, 1)]
    )
    for cname, ccfg in corpora.items():
        import dataclasses
        corpus_np = embeddings.make_corpus(
            dataclasses.replace(ccfg, n_vectors=n_docs))
        corpus = jnp.asarray(corpus_np)
        queries_np, _ = embeddings.make_queries(corpus_np, n_queries)
        queries = jnp.asarray(queries_np)
        _, gt_i = bruteforce.exact_topk(corpus, queries, K)

        for q in qs:
            r = _eval_method(corpus, queries, gt_i, FakeWordsConfig(quantization=q))
            rows.append({"corpus": cname, "model": "fake words", "config": f"q={q}", **r})
        for b, h, n in lsh_settings:
            r = _eval_method(
                corpus, queries, gt_i, LexicalLshConfig(buckets=b, hashes=h, ngram=n))
            rows.append({
                "corpus": cname, "model": "lexical LSH",
                "config": f"b={b},h={h},n={n}", **r})
        for red in (["pca", "ppa-pca-ppa"] if not fast else ["pca"]):
            r = _eval_method(
                corpus, queries, gt_i, KdTreeConfig(dims=8, reduction=red, backend="scan"))
            rows.append({"corpus": cname, "model": "k-d tree", "config": red, **r})
    return rows


def format_table(rows: List[Dict]) -> str:
    out = ["corpus,model,config,R@(10,10),R@(10,20),R@(10,50),R@(10,100),latency_ms,index_MB"]
    for r in rows:
        rc = r["recalls"]
        out.append(
            f"{r['corpus']},{r['model']},{r['config']},"
            f"{rc[10]:.3f},{rc[20]:.3f},{rc[50]:.3f},{rc[100]:.3f},"
            f"{r['latency_ms']:.1f},{r['index_mb']:.0f}"
        )
    return "\n".join(out)


def validate_claims(rows: List[Dict]) -> List[str]:
    """Check the paper's qualitative claims; returns failures (empty=ok)."""
    problems = []
    for corpus in {r["corpus"] for r in rows}:
        sub = [r for r in rows if r["corpus"] == corpus]
        by_model = {}
        for r in sub:
            by_model.setdefault(r["model"], []).append(r)
        best = {m: max(rs, key=lambda r: r["recalls"][100]) for m, rs in by_model.items()}
        # Paper ordering: fake words strictly best; k-d tree collapsed.  On
        # the synthetic corpora LSH and k-d tree land close together (the
        # 1-decimal quantization is harsh when |w_i| ~ 1/sqrt(300)), so LSH
        # is only required not to fall meaningfully below the k-d tree.
        if not (best["fake words"]["recalls"][100]
                > best["lexical LSH"]["recalls"][100] - 1e-6):
            problems.append(f"{corpus}: fake words not best")
        if not (best["lexical LSH"]["recalls"][100]
                >= best["k-d tree"]["recalls"][100] - 0.1):
            problems.append(f"{corpus}: LSH fell below k-d tree")
        if best["k-d tree"]["recalls"][10] > 0.3:
            problems.append(f"{corpus}: k-d tree recall did not collapse")
        if min(r["latency_ms"] for r in by_model["k-d tree"]) > max(
                r["latency_ms"] for r in by_model["fake words"]):
            problems.append(f"{corpus}: k-d tree not fastest")
        fw = sorted(by_model["fake words"], key=lambda r: int(r["config"][2:]))
        recs = [r["recalls"][100] for r in fw]
        if any(b < a - 0.02 for a, b in zip(recs, recs[1:])):
            problems.append(f"{corpus}: fake-words recall not rising with Q")
        for r in sub:
            rc = r["recalls"]
            if not (rc[10] <= rc[20] + 1e-6 <= rc[50] + 2e-6 <= rc[100] + 3e-6):
                problems.append(f"{corpus}/{r['model']}: recall not rising with d")
    return problems


def main(fast: bool = False):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=100_000)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args([]) if fast else ap.parse_args()
    rows = run(n_docs=args.n_docs if not fast else 20_000, fast=fast or args.fast)
    print(format_table(rows))
    problems = validate_claims(rows)
    print("\nclaims:", "ALL OK" if not problems else problems)
    return rows, problems


if __name__ == "__main__":
    main()
