"""Render EXPERIMENTS.md tables from results/*.json artifacts.

    PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
"""
from __future__ import annotations

import json
import sys


def dryrun_table(path="results/dryrun.json") -> str:
    rows = json.load(open(path))
    out = [
        "| arch | cell | mesh | compile | GB/dev | fits 16GB | coll ops |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["cell"], r["mesh"])):
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['cell']} | {r['mesh']} | FAIL | - | - | - |")
            continue
        gb = r["memory"]["total_per_device_bytes"] / 1e9
        alias = r["memory"].get("alias_bytes", 0) / 1e9
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} | {r['compile_s']}s "
            f"| {gb:.2f} | {'yes' if r.get('hbm_ok') else 'NO'} "
            f"| {r.get('collective_ops', '-')} |"
        )
    return "\n".join(out)


def roofline_table(path="results/roofline_opt.json") -> str:
    rows = [r for r in json.load(open(path)) if "error" not in r]
    out = [
        "| arch | cell | compute_s | memory_s | collective_s | dominant | "
        "MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["cell"])):
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def perf_compare(base="results/roofline_baseline.json",
                 opt="results/roofline_opt.json") -> str:
    b = {(r["arch"], r["cell"]): r for r in json.load(open(base)) if "error" not in r}
    o = {(r["arch"], r["cell"]): r for r in json.load(open(opt)) if "error" not in r}
    out = [
        "| arch | cell | bound before | bound after | speedup | frac before | frac after |",
        "|---|---|---|---|---|---|---|",
    ]
    for key in sorted(set(b) & set(o)):
        rb, ro = b[key], o[key]
        sp = rb["bound_s"] / ro["bound_s"] if ro["bound_s"] else float("inf")
        if abs(sp - 1) < 0.02:
            continue  # unchanged cells skipped
        out.append(
            f"| {key[0]} | {key[1]} | {rb['bound_s']*1e3:.2f}ms | "
            f"{ro['bound_s']*1e3:.2f}ms | {sp:.2f}x | "
            f"{rb['roofline_fraction']:.3f} | {ro['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run matrix\n")
        print(dryrun_table())
    if which in ("all", "roofline"):
        print("\n### Roofline (optimized)\n")
        print(roofline_table())
    if which in ("all", "perf"):
        print("\n### Before/after\n")
        print(perf_compare())
