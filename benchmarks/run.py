"""Benchmark aggregator: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure + system ablations:
  table1     — paper Table 1 (R@(10,d) / latency / index size, both corpora)
  ablations  — df-pruning, rerank, blockmax, scoring mode
  kernels    — scoring-path micro-bench (CPU wall-clock, relative), plus the
               fused-vs-unfused streaming top-k comparison: latency and
               streamed bytes with and without the (B, N) score matrix
               (docs/DESIGN.md §4)

Roofline terms come from the dry-run artifacts (results/*.json via
launch/roofline.py), not from this CPU — see EXPERIMENTS.md §Roofline.

``--fast`` shrinks corpora for CI-speed runs.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None, choices=[None, "table1", "ablations", "kernels"])
    args = ap.parse_args()

    t0 = time.time()
    failures = []

    if args.only in (None, "table1"):
        print("=" * 72)
        print("== Table 1 reproduction (paper §3)")
        print("=" * 72, flush=True)
        from benchmarks import table1
        rows, problems = table1.main(fast=args.fast)
        failures += problems

    if args.only in (None, "ablations"):
        print()
        print("=" * 72)
        print("== Ablations: df-pruning / rerank / blockmax / scoring")
        print("=" * 72, flush=True)
        from benchmarks import ablations
        ablations.main()

    if args.only in (None, "kernels"):
        print()
        print("=" * 72)
        print("== Kernel micro-bench (CPU relative) + fused-vs-unfused top-k")
        print("=" * 72, flush=True)
        from benchmarks import kernel_bench
        if args.fast:
            _, summary = kernel_bench.main(n_docs=10_000, dim=128, batch=16)
        else:
            _, summary = kernel_bench.main()
        for mode in ("classic", "dot"):
            if not summary[mode]["ids_match"]:
                failures.append(
                    f"fused {mode} search ids diverge from unfused oracle")

    print(f"\ntotal bench time: {time.time() - t0:.0f}s")
    if failures:
        print("CLAIM FAILURES:", failures)
        sys.exit(1)
    print("all paper claims validated")


if __name__ == "__main__":
    main()
