"""CI gate for the quantized read-path A/B artifact (docs/DESIGN.md §12).

    PYTHONPATH=src python benchmarks/validate_bench6.py [path]

Checks that ``benchmarks/BENCH_6.json`` carries every key the memory-budget
planner and the perf narrative depend on, that all three encodings are
present, that :func:`repro.core.memory_budget.load_frontier` can re-order
the frontier from it, and that the recorded cosine-family A/B clears the
acceptance bars: int8 >= 3.5x fewer match-stage bytes within 0.02 recall@10
of fp32, int4 >= 6x within 0.05.
"""
import json
import sys

from repro.core import memory_budget as mb

REQUIRED_ROW_KEYS = {
    "method", "postings", "build_s", "qps", "p50_ms", "p99_ms",
    "recall_at_10", "match_recall_at_10", "match_mb",
    "bytes_cut_vs_fp32", "recall_delta_vs_fp32",
}


def validate(path: str) -> None:
    with open(path) as f:
        bench = json.load(f)
    rows = bench.get("quantized_ab")
    assert rows, "no quantized_ab rows"
    for row in rows:
        missing = REQUIRED_ROW_KEYS - set(row)
        assert not missing, f"row {row.get('method')}/{row.get('postings')} missing {missing}"
    assert {r["postings"] for r in rows} == {"fp32", "int8", "int4"}
    frontier = mb.load_frontier(path)
    assert len(frontier) == len(mb.DEFAULT_FRONTIER), frontier
    cos = {r["postings"]: r for r in rows if r["method"] == "bruteforce"}
    assert cos, "no cosine-family (bruteforce) rows"
    assert cos["int8"]["bytes_cut_vs_fp32"] >= 3.5, cos["int8"]
    assert cos["int8"]["recall_delta_vs_fp32"] <= 0.02, cos["int8"]
    assert cos["int4"]["bytes_cut_vs_fp32"] >= 6.0, cos["int4"]
    assert cos["int4"]["recall_delta_vs_fp32"] <= 0.05, cos["int4"]
    print(f"{path} ok: {len(rows)} A/B rows, "
          f"int8 {cos['int8']['bytes_cut_vs_fp32']}x / "
          f"int4 {cos['int4']['bytes_cut_vs_fp32']}x match-byte cut")


if __name__ == "__main__":
    validate(sys.argv[1] if len(sys.argv) > 1 else "benchmarks/BENCH_6.json")
