"""CI gate for the filtered + hybrid A/B artifact (docs/DESIGN.md §13).

    PYTHONPATH=src python benchmarks/validate_bench7.py [path]

Checks that ``benchmarks/BENCH_7.json`` carries the filtered-vs-unfiltered
serving rows at every selectivity tier (1% / 10% / 50%) for every postings
encoding, that filtered recall@10 holds up against the filtered oracle
(>= the unfiltered baseline minus 0.05 — the one-pass in-match filter must
not silently degrade into a lossy post-filter), that filtered p50 stays
within 1.5x of unfiltered (one kernel pass, not depth inflation), and the
hybrid acceptance bar: RRF(classic, dense) recall@10 >= the best single
retriever alone.
"""
import json
import sys

RATIOS = (0.01, 0.1, 0.5)
FILTERED_KEYS = {"postings", "selectivity", "qps", "p50_ms", "p99_ms",
                 "recall_at_10"}
HYBRID_KEYS = {"retriever", "qps", "p50_ms", "p99_ms", "recall_at_10"}


def validate(path: str) -> None:
    with open(path) as f:
        bench = json.load(f)
    assert bench.get("bench") == 7, bench.get("bench")

    rows = bench.get("filtered_ab")
    assert rows, "no filtered_ab rows"
    for row in rows:
        missing = FILTERED_KEYS - set(row)
        assert not missing, f"filtered row {row} missing {missing}"
        assert row["qps"] > 0 and row["p50_ms"] > 0
        assert 0.0 <= row["recall_at_10"] <= 1.0
    by_pp = {}
    for row in rows:
        by_pp.setdefault(row["postings"], {})[row["selectivity"]] = row
    assert set(by_pp) == {"fp32", "int8", "int4"}, sorted(by_pp)
    for pp, tiers in by_pp.items():
        assert set(tiers) == {1.0, *RATIOS}, (pp, sorted(tiers))
        base = tiers[1.0]
        for ratio in RATIOS:
            r = tiers[ratio]
            assert r["recall_at_10"] >= base["recall_at_10"] - 0.05, (pp, r)
            assert r["p50_ms"] <= 1.5 * base["p50_ms"], (pp, r)

    h_rows = bench.get("hybrid_ab")
    assert h_rows, "no hybrid_ab rows"
    for row in h_rows:
        missing = HYBRID_KEYS - set(row)
        assert not missing, f"hybrid row {row} missing {missing}"
    by_r = {r["retriever"]: r for r in h_rows}
    assert set(by_r) == {"classic", "dense-dot", "rrf-fusion"}, sorted(by_r)
    rrf = by_r["rrf-fusion"]["recall_at_10"]
    best = max(by_r["classic"]["recall_at_10"],
               by_r["dense-dot"]["recall_at_10"])
    assert rrf >= best, f"hybrid gate: rrf {rrf} < best single {best}"
    assert bench["summary"]["hybrid"]["gate_rrf_ge_max"] is True

    print(f"{path} ok: {len(rows)} filtered rows "
          f"({len(by_pp)} encodings x {1 + len(RATIOS)} tiers), "
          f"hybrid rrf {rrf} >= best single {best}")


if __name__ == "__main__":
    validate(sys.argv[1] if len(sys.argv) > 1 else "benchmarks/BENCH_7.json")
