"""Fake-words encoding: Lucene semantics + paper behaviours."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bruteforce, eval as ev, fakewords
from repro.core.types import FakeWordsConfig


def test_encode_sign_split_proportionality(rng):
    v = bruteforce.l2_normalize(jnp.asarray(rng.normal(size=(10, 16)).astype(np.float32)))
    q = 50
    tf = fakewords.encode(v, q)
    assert tf.shape == (10, 32)
    assert tf.dtype == jnp.int8
    # tf = round(Q*relu(w)) / round(Q*relu(-w)): reconstruct within 0.5/Q
    recon = (tf[:, :16].astype(np.float32) - tf[:, 16:].astype(np.float32)) / q
    assert np.max(np.abs(recon - np.asarray(v))) <= 0.5 / q + 1e-6
    # at most one of (pos, neg) is nonzero per feature
    both = (np.asarray(tf[:, :16]) > 0) & (np.asarray(tf[:, 16:]) > 0)
    assert not both.any()


def test_doc_stats_match_lucene_formulas(rng):
    v = bruteforce.l2_normalize(jnp.asarray(rng.normal(size=(50, 8)).astype(np.float32)))
    tf = fakewords.encode(v, 30)
    df, idf, norm = fakewords.doc_stats(tf)
    tf_np = np.asarray(tf, dtype=np.float64)
    np.testing.assert_array_equal(np.asarray(df), (tf_np > 0).sum(0))
    np.testing.assert_allclose(
        np.asarray(idf), 1.0 + np.log(50 / (np.asarray(df) + 1.0)), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(norm), 1.0 / np.sqrt(np.maximum(tf_np.sum(-1), 1.0)), rtol=1e-6)


def test_classic_score_matches_manual_tfidf(rng):
    v = bruteforce.l2_normalize(jnp.asarray(rng.normal(size=(20, 8)).astype(np.float32)))
    cfg = FakeWordsConfig(quantization=40, scoring="classic")
    idx = fakewords.build(v, cfg)
    q_tf = fakewords.encode_queries(v[:3], cfg)
    scores = fakewords.classic_scores(idx, q_tf)
    # manual: sum_t tf_q * sqrt(tf_d) * idf^2 * norm_d
    tf_d = np.asarray(idx.tf, np.float64)
    idf = np.asarray(idx.idf, np.float64)
    norm = np.asarray(idx.norm, np.float64)
    man = np.einsum(
        "qt,dt->qd", np.asarray(q_tf, np.float64),
        np.sqrt(tf_d) * idf[None] ** 2 * norm[:, None],
    )
    np.testing.assert_allclose(np.asarray(scores), man, rtol=2e-2, atol=1e-2)


def test_dot_scores_approximate_cosine(rng):
    v = bruteforce.l2_normalize(jnp.asarray(rng.normal(size=(200, 32)).astype(np.float32)))
    cfg = FakeWordsConfig(quantization=80, scoring="dot")
    idx = fakewords.build(v, cfg)
    q_tf = fakewords.encode_queries(v[:4], cfg)
    scores = np.asarray(fakewords.dot_scores(idx, q_tf)) / 80.0**2
    cos = np.asarray(v[:4] @ v.T)
    assert np.max(np.abs(scores - cos)) < 0.05  # quantization error bound


def test_search_recall_and_rerank(small_corpus):
    v = jnp.asarray(small_corpus)
    gt_s, gt_i = bruteforce.exact_topk(v, v[:32], 10)
    cfg = FakeWordsConfig(quantization=50)
    idx = fakewords.build(v, cfg)
    q_tf = fakewords.encode_queries(v[:32], cfg)
    _, i10 = fakewords.search(idx, q_tf, v[:32], k=10, depth=10)
    _, i100 = fakewords.search(idx, q_tf, v[:32], k=100, depth=100)
    r10 = float(ev.recall_at(gt_i, i10))
    r100 = float(ev.recall_at(gt_i, i100))
    assert r100 >= r10  # paper: recall rises with depth
    assert r100 > 0.8
    # rerank at depth 100 -> near-exact top-10
    _, i_rr = fakewords.search(idx, q_tf, v[:32], k=10, depth=100, rerank=True)
    assert float(ev.recall_at(gt_i, i_rr)) >= r100 - 1e-6


def test_quantization_monotonicity(small_corpus):
    """Paper Table 1: recall rises with Q."""
    v = jnp.asarray(small_corpus)
    gt_s, gt_i = bruteforce.exact_topk(v, v[:32], 10)
    recalls = []
    for q in (10, 30, 70):
        cfg = FakeWordsConfig(quantization=q)
        idx = fakewords.build(v, cfg)
        q_tf = fakewords.encode_queries(v[:32], cfg)
        _, ids = fakewords.search(idx, q_tf, v[:32], k=10, depth=50)
        recalls.append(float(ev.recall_at(gt_i, ids)))
    assert recalls[0] <= recalls[1] + 0.05 <= recalls[2] + 0.1


def test_df_pruning_mechanism(small_corpus):
    """df-pruning == zeroing the pruned terms in the QUERY (Lucene drops the
    terms from the query, never touches the index).  The effectiveness gain
    the paper reports is corpus/threshold-dependent and is swept in
    benchmarks/table1.py on the word2vec-like corpus; here we verify the
    mechanism and that mild pruning degrades gracefully."""
    v = jnp.asarray(small_corpus)
    cfg = FakeWordsConfig(quantization=50)
    idx = fakewords.build(v, cfg)
    q_tf = fakewords.encode_queries(v[:16], cfg)
    ratio = 0.9
    keep = fakewords.df_prune_mask(idx.df, idx.num_docs, ratio)
    pruned = fakewords.classic_scores(idx, q_tf, df_max_ratio=ratio)
    manual = fakewords.classic_scores(idx, q_tf * keep, df_max_ratio=1.0)
    np.testing.assert_allclose(np.asarray(pruned), np.asarray(manual), rtol=1e-5)

    gt_s, gt_i = bruteforce.exact_topk(v, v[:16], 10)
    _, ids_full = fakewords.search(idx, q_tf, v[:16], k=10, depth=100)
    _, ids_mild = fakewords.search(
        idx, q_tf, v[:16], k=10, depth=100, df_max_ratio=0.97)
    r_full = float(ev.recall_at(gt_i, ids_full))
    r_mild = float(ev.recall_at(gt_i, ids_mild))
    assert r_mild >= r_full - 0.15  # graceful under mild pruning


def test_quantization_bounds():
    with pytest.raises(ValueError):
        FakeWordsConfig(quantization=200)
    with pytest.raises(ValueError):
        FakeWordsConfig(quantization=0)
