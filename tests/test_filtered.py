"""FilterMask / QueryPlan coverage (docs/DESIGN.md §13): predicate bitmaps
masked INSIDE the match stage, kernel==XLA exact-id parity at every
selectivity tier (including quantized postings and blockmax), degenerate
all-filtered padding, deletes∧predicate composition, and fusion math.

The no-filter paths must stay bitwise identical to pre-filter main: the
``filt=None`` dispatch shares the exact unfiltered kernels, asserted here by
comparing all-ones-mask output against the unfiltered call.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bruteforce, eval as ev, plan
from repro.core import pipeline as pl
from repro.core.index import AnnIndex
from repro.core.segments import IndexWriter
from repro.core.types import (
    BruteForceConfig,
    FakeWordsConfig,
    KdTreeConfig,
    LexicalLshConfig,
)

RNG = np.random.default_rng(31)
SELECTIVITIES = (0.01, 0.1, 0.5)


def _mask(n, ratio, rng=None, min_keep=16):
    """Random keep-bitmap at ``ratio`` selectivity with >= min_keep kept."""
    rng = rng or np.random.default_rng(int(ratio * 1000) + 7)
    m = (rng.random(n) < ratio).astype(np.int32)
    short = min_keep - int(m.sum())
    if short > 0:
        m[rng.choice(np.flatnonzero(m == 0), short, replace=False)] = 1
    return jnp.asarray(m)


def _exact_filtered_ids(vectors, queries, mask, k):
    """Brute-force ground truth over the kept sub-corpus, in global ids."""
    kept = np.flatnonzero(np.asarray(mask))
    vn = bruteforce.l2_normalize(jnp.asarray(vectors)[kept])
    qn = bruteforce.l2_normalize(jnp.asarray(queries))
    _, gi = jax.lax.top_k(qn @ vn.T, min(k, len(kept)))
    return kept[np.asarray(gi)]


# -- kernel == XLA exact ids at every selectivity tier -----------------------


FILTER_CONFIGS = [
    (FakeWordsConfig(quantization=40), "fp32"),
    (FakeWordsConfig(quantization=40), "int8"),
    (FakeWordsConfig(quantization=40), "int4"),
    (FakeWordsConfig(quantization=40, scoring="dot"), "fp32"),
    (FakeWordsConfig(quantization=40, scoring="dot"), "int8"),
    (LexicalLshConfig(buckets=64, hashes=2), "fp32"),
    (KdTreeConfig(dims=8, backend="scan"), "fp32"),
    (BruteForceConfig(), "fp32"),
]


def _cfg_id(p):
    cfg, pp = p
    name = f"fakewords-{cfg.scoring}" if isinstance(cfg, FakeWordsConfig) \
        else type(cfg).__name__
    return f"{name}-{pp}"


@pytest.mark.parametrize("ratio", SELECTIVITIES)
@pytest.mark.parametrize("cfg_pp", FILTER_CONFIGS, ids=_cfg_id)
def test_filtered_kernel_equals_xla_ids(small_corpus, cfg_pp, ratio):
    """One-pass in-kernel filtering must return EXACTLY the ids the XLA
    reference path returns, at 1% / 10% / 50% selectivity, for every
    encoding and for int8/int4 quantized primary postings."""
    cfg, pp = cfg_pp
    v = jnp.asarray(small_corpus[:1024])
    q = jnp.asarray(small_corpus[:8])
    kwargs = {} if pp == "fp32" else {"primary_postings": pp,
                                      "rerank_store": "int8"}
    ann = AnnIndex.build(v, cfg, **kwargs)
    filt = _mask(1024, ratio)
    s_x, i_x = ann.search(q, k=10, depth=64, use_kernel=False, filt=filt)
    s_k, i_k = ann.search(q, k=10, depth=64, use_kernel=True, filt=filt)
    np.testing.assert_array_equal(np.asarray(i_x), np.asarray(i_k))
    # every returned id is kept by the mask (or the -1 pad)
    ids = np.asarray(i_x)
    keep = np.asarray(filt)
    assert ((ids < 0) | (keep[np.maximum(ids, 0)] != 0)).all()


@pytest.mark.parametrize("ratio", SELECTIVITIES)
def test_filtered_blockmax_beta1_equals_dense(small_corpus, ratio):
    """beta=1.0 (all blocks kept) blockmax + filter == dense filtered search
    exactly: stage-1 bounds stay unfiltered (admissible), stage-2 masks."""
    v = jnp.asarray(small_corpus[:512])
    cfg = FakeWordsConfig(quantization=40)
    dense = AnnIndex.build(v, cfg)
    bm = AnnIndex.build(v, cfg, blockmax_keep=8, blockmax_block_size=64)
    assert bm.bm.num_blocks == 8  # keep == num_blocks: beta = 1.0
    filt = _mask(512, ratio)
    q = jnp.asarray(small_corpus[:8])
    for uk in (False, True):
        s_d, i_d = dense.search(q, k=10, depth=50, use_kernel=uk, filt=filt)
        s_b, i_b = bm.search(q, k=10, depth=50, use_kernel=uk, filt=filt)
        np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_b))


def test_filtered_recall_exact_on_bruteforce(small_corpus):
    """Filtered brute-force == exact top-k over the kept sub-corpus, and
    eval.recall_at(filter_mask=) scores it 1.0."""
    v = small_corpus[:1024]
    q = small_corpus[:8]
    ann = AnnIndex.build(jnp.asarray(v), BruteForceConfig())
    for ratio in SELECTIVITIES:
        filt = _mask(1024, ratio)
        _, ids = ann.search(jnp.asarray(q), k=10, depth=64,
                            use_kernel=False, filt=filt)
        truth = _exact_filtered_ids(v, q, filt, 10)
        kk = truth.shape[1]
        np.testing.assert_array_equal(np.asarray(ids)[:, :kk], truth)
        full = AnnIndex.build(jnp.asarray(v), BruteForceConfig()).search(
            jnp.asarray(q), k=10, depth=64, use_kernel=False)[1]
        # unfiltered truth scored under the mask: perfect filtered recall
        r = float(ev.recall_at(jnp.asarray(truth), ids[:, :kk],
                               filter_mask=filt))
        assert r == 1.0
        assert float(ev.recall_at(full, ids, filter_mask=filt)) <= 1.0


# -- no-filter and all-ones regression ---------------------------------------


@pytest.mark.parametrize("cfg_pp", FILTER_CONFIGS, ids=_cfg_id)
def test_all_ones_mask_matches_unfiltered_bitwise(small_corpus, cfg_pp):
    """An all-keep mask must reproduce the unfiltered search bit-for-bit
    (scores AND ids) — the in-loop masking is exactly a no-op then."""
    cfg, pp = cfg_pp
    v = jnp.asarray(small_corpus[:1024])
    q = jnp.asarray(small_corpus[:8])
    kwargs = {} if pp == "fp32" else {"primary_postings": pp,
                                      "rerank_store": "int8"}
    ann = AnnIndex.build(v, cfg, **kwargs)
    ones = jnp.ones((1024,), jnp.int32)
    for uk in (False, True):
        s0, i0 = ann.search(q, k=10, depth=64, use_kernel=uk)
        s1, i1 = ann.search(q, k=10, depth=64, use_kernel=uk, filt=ones)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


# -- degenerate masks ---------------------------------------------------------


@pytest.mark.parametrize("rerank", [False, True])
def test_all_docs_filtered_returns_padding_no_nans(small_corpus, rerank):
    """All-zeros mask: every slot is the (-1, -inf) pad, no NaNs anywhere,
    through match AND rerank, kernel and XLA."""
    v = jnp.asarray(small_corpus[:512])
    ann = AnnIndex.build(v, FakeWordsConfig(quantization=40))
    zeros = jnp.zeros((512,), jnp.int32)
    q = jnp.asarray(small_corpus[:4])
    for uk in (False, True):
        s, i = ann.search(q, k=10, depth=50, rerank=rerank,
                          use_kernel=uk, filt=zeros)
        assert (np.asarray(i) == -1).all()
        assert not np.isnan(np.asarray(s)).any()
        assert (np.asarray(s) == -np.inf).all()


def test_all_filtered_segmented_no_nans(rng):
    v = rng.normal(size=(600, 32)).astype(np.float32)
    w = IndexWriter(FakeWordsConfig(quantization=30), merge_policy=None)
    w.add(jnp.asarray(v[:300]))
    w.add(jnp.asarray(v[300:]))
    reader = w.refresh()
    zeros = jnp.zeros((reader.max_doc,), jnp.int32)
    s, i = reader.search(jnp.asarray(v[:4]), k=10, depth=40,
                         use_kernel=False, filter_mask=zeros)
    assert (np.asarray(i) == -1).all()
    assert not np.isnan(np.asarray(s)).any()


# -- deletes ∧ predicate composition -----------------------------------------


def test_filter_and_deletes_compose_to_one_mask(rng):
    """A predicate filter over a segmented index with deletes must equal
    applying both restrictions sequentially: exact top-k over the docs that
    are BOTH live and predicate-kept."""
    v = rng.normal(size=(800, 32)).astype(np.float32)
    w = IndexWriter(BruteForceConfig(), merge_policy=None)
    w.add(jnp.asarray(v[:400]))
    w.add(jnp.asarray(v[400:]))
    dead = rng.choice(800, 120, replace=False)
    w.delete(dead.tolist())
    reader = w.refresh()
    pred = np.asarray(_mask(800, 0.5, rng))
    q = jnp.asarray(v[:6])
    _, ids = reader.search(q, k=10, depth=128, use_kernel=False,
                           filter_mask=jnp.asarray(pred))
    live = np.ones(800, bool)
    live[dead] = False
    both = pred.astype(bool) & live
    truth = _exact_filtered_ids(v, v[:6], both.astype(np.int32), 10)
    np.testing.assert_array_equal(np.asarray(ids), truth)
    # deleted or predicate-rejected docs never surface
    assert not np.isin(np.asarray(ids), np.flatnonzero(~both)).any()


def test_native_filter_equals_depth_inflated_fallback(small_corpus):
    """FilterMask native=True (one kernel pass) returns the ids of the
    native=False historical path (depth inflation + post-mask)."""
    v = jnp.asarray(small_corpus[:512])
    cfg = FakeWordsConfig(quantization=40)
    ann = AnnIndex.build(v, cfg)
    from repro.core import fakewords
    qn = bruteforce.l2_normalize(jnp.asarray(small_corpus[:8]))
    q_tf = fakewords.encode_queries(qn, cfg, normalized=True)
    fm = pl.FilterMask(inner=pl.make_matcher(cfg), extra=512)
    mask = _mask(512, 0.1)
    s_n, i_n = fm(ann.index, q_tf, 50, mask, use_kernel=False, native=True)
    s_f, i_f = fm(ann.index, q_tf, 50, mask, use_kernel=False, native=False)
    np.testing.assert_array_equal(np.asarray(i_n), np.asarray(i_f))


# -- per-query (B, N) masks ---------------------------------------------------


def test_per_query_masks_match_per_row_single_masks(small_corpus):
    """(B, N) batched masks == running each row with its own (N,) mask."""
    v = jnp.asarray(small_corpus[:512])
    ann = AnnIndex.build(v, FakeWordsConfig(quantization=40))
    q = jnp.asarray(small_corpus[:4])
    rows = [np.asarray(_mask(512, r, np.random.default_rng(i)))
            for i, r in enumerate((0.05, 0.1, 0.3, 0.8))]
    fm = jnp.asarray(np.stack(rows))
    for uk in (False, True):
        s_b, i_b = ann.search(q, k=10, depth=50, use_kernel=uk, filt=fm)
        for r in range(4):
            s_1, i_1 = ann.search(q[r:r + 1], k=10, depth=50, use_kernel=uk,
                                  filt=jnp.asarray(rows[r]))
            np.testing.assert_array_equal(np.asarray(i_b)[r], np.asarray(i_1)[0])


# -- DocMetadata predicates ---------------------------------------------------


def test_doc_metadata_predicates_and_persistence(small_corpus, tmp_path):
    """Predicate bitmaps built from DocMetadata fields drive filtered
    search, and metadata round-trips through save/load."""
    n = 512
    v = jnp.asarray(small_corpus[:n])
    cat = RNG.integers(0, 4, n)
    year = RNG.integers(2000, 2020, n)
    ann = AnnIndex.build(v, FakeWordsConfig(quantization=40),
                         metadata={"cat": cat, "year": year})
    md = ann.metadata
    assert md.field_names == ("cat", "year") and md.num_docs == n
    np.testing.assert_array_equal(np.asarray(md.eq_mask("cat", 2)), cat == 2)
    np.testing.assert_array_equal(
        np.asarray(md.range_mask("year", 2005, 2010)),
        (year >= 2005) & (year < 2010))
    np.testing.assert_array_equal(
        np.asarray(md.in_mask("cat", (0, 3))), np.isin(cat, [0, 3]))
    filt = md.eq_mask("cat", 2).astype(jnp.int32)
    _, ids = ann.search(v[:4], k=10, depth=64, use_kernel=False, filt=filt)
    kept = np.asarray(ids)
    assert (cat[kept[kept >= 0]] == 2).all()
    # save/load round trip carries the metadata and the filtered results
    path = str(tmp_path / "md.ann")
    ann.save(path)
    loaded = AnnIndex.load(path)
    assert loaded.metadata.field_names == ("cat", "year")
    _, ids2 = loaded.search(v[:4], k=10, depth=64, use_kernel=False, filt=filt)
    np.testing.assert_array_equal(kept, np.asarray(ids2))


def test_doc_metadata_through_writer_flush_and_merge(rng):
    """Metadata rides per segment through flush and merge; the merged
    reader's global_metadata() drops deleted rows' influence correctly."""
    v = rng.normal(size=(400, 32)).astype(np.float32)
    cat = rng.integers(0, 3, 400)
    w = IndexWriter(FakeWordsConfig(quantization=30), merge_policy=None)
    w.add(jnp.asarray(v[:200]), metadata={"cat": cat[:200]})
    w.add(jnp.asarray(v[200:]), metadata={"cat": cat[200:]})
    reader = w.refresh()
    md = reader.global_metadata()
    np.testing.assert_array_equal(np.asarray(md.values[:, 0]), cat)
    filt = md.eq_mask("cat", 1).astype(jnp.int32)
    _, ids = reader.search(jnp.asarray(v[:4]), k=10, depth=64,
                           use_kernel=False, filter_mask=filt)
    kept = np.asarray(ids)
    assert (cat[kept[kept >= 0]] == 1).all()


# -- fusion math (QueryPlan / FusionStage) -----------------------------------


def test_combine_by_id_sum_and_max():
    ids = jnp.asarray([[3, 1, 3, -1]])
    vals = jnp.asarray([[1.0, 5.0, 2.0, 9.0]])
    s, i = plan.combine_by_id(ids, vals, k=2, agg="sum")
    np.testing.assert_array_equal(np.asarray(i), [[1, 3]])
    np.testing.assert_allclose(np.asarray(s), [[5.0, 3.0]])
    s, i = plan.combine_by_id(ids, vals, k=3, agg="max")
    np.testing.assert_array_equal(np.asarray(i)[0, :2], [1, 3])
    np.testing.assert_allclose(np.asarray(s)[0, :2], [5.0, 2.0])
    assert np.asarray(i)[0, 2] == -1 and np.asarray(s)[0, 2] == -np.inf


def test_rrf_formula_exact():
    """fuse(method='rrf') computes sum_p w_p / (rrf_k + rank_p), rank 1."""
    ids_a = jnp.asarray([[7, 3, 5]])
    ids_b = jnp.asarray([[3, 9, -1]])
    sc = jnp.asarray([[0.9, 0.8, 0.7]])
    s, i = plan.fuse([(sc, ids_a), (sc, ids_b)], k=4,
                     method="rrf", rrf_k=60.0)
    exp = {7: 1 / 61, 3: 1 / 62 + 1 / 61, 5: 1 / 63, 9: 1 / 62}
    order = sorted(exp, key=exp.get, reverse=True)
    np.testing.assert_array_equal(np.asarray(i)[0], order)
    np.testing.assert_allclose(
        np.asarray(s)[0], [exp[d] for d in order], rtol=1e-6)


def test_fusion_stage_hybrid_beats_weaker_retriever(small_corpus):
    """RRF of two retrievers >= the weaker one alone on recall@10 (sanity
    floor; the >= max gate runs on the full benchmark in BENCH_7.json)."""
    v = jnp.asarray(small_corpus)
    q = small_corpus[:32]
    lex = AnnIndex.build(v, FakeWordsConfig(quantization=30))
    dense = AnnIndex.build(v, FakeWordsConfig(quantization=30, scoring="dot"))
    k_sub = 30
    plans = [
        plan.QueryPlan(search=lambda qq, idx=lex: idx.search(
            qq, k=k_sub, depth=100, use_kernel=False), label="lex"),
        plan.QueryPlan(search=lambda qq, idx=dense: idx.search(
            qq, k=k_sub, depth=100, use_kernel=False), label="dense"),
    ]
    stage = plan.FusionStage(plans=tuple(plans), k=10)
    s, i = stage.run(jnp.asarray(q))
    assert i.shape == (32, 10)
    _, truth = bruteforce.exact_topk(v, jnp.asarray(q), 10, use_kernel=False)
    r_fused = float(ev.recall_at(truth, i))
    recalls = [float(ev.recall_at(truth, p.run(jnp.asarray(q))[1][:, :10]))
               for p in plans]
    assert r_fused >= min(recalls), (r_fused, recalls)


def test_multi_vector_aggregation_max_and_sum():
    """Multi-vector docs: vector-level hits aggregate to doc level via the
    doc_map, max-sim picks the best vector, sum adds them."""
    # 6 vectors -> 3 docs: doc_map[v] = v // 2
    doc_map = jnp.asarray([0, 0, 1, 1, 2, 2])
    scores = jnp.asarray([[0.9, 0.5, 0.8, 0.1]])
    vec_ids = jnp.asarray([[0, 1, 2, 5]])
    s, i = plan.aggregate_by_doc(scores, vec_ids, doc_map, k=3, agg="max")
    np.testing.assert_array_equal(np.asarray(i), [[0, 1, 2]])
    np.testing.assert_allclose(np.asarray(s), [[0.9, 0.8, 0.1]])
    s, i = plan.aggregate_by_doc(scores, vec_ids, doc_map, k=3, agg="sum")
    np.testing.assert_array_equal(np.asarray(i)[0, 0], 0)
    np.testing.assert_allclose(np.asarray(s)[0, 0], 1.4)


def test_multi_vector_plan_end_to_end(small_corpus):
    """MultiVectorPlan over a 2-vectors-per-doc corpus: searching with a
    doc's own vector surfaces that doc first under max-sim."""
    vecs = jnp.asarray(small_corpus[:256])  # 256 vectors = 128 docs
    doc_map = jnp.arange(256) // 2
    ann = AnnIndex.build(vecs, BruteForceConfig())
    inner = plan.QueryPlan(search=lambda q: ann.search(
        q, k=20, depth=20, use_kernel=False))
    mv = plan.MultiVectorPlan(inner=inner, doc_map=doc_map, k=5, agg="max")
    s, i = mv.run(jnp.asarray(small_corpus[:8]))
    np.testing.assert_array_equal(np.asarray(i)[:, 0], np.arange(8) // 2)


def test_multi_vector_underfill_refills_to_k():
    """Regression: aggregation can collapse a k_sub-deep vector list into
    fewer than k docs (all top vectors belong to one doc).  The plan must
    re-run the inner search deeper (via ``run_at``) until k docs fill."""
    rng = np.random.default_rng(0)
    n_docs, per, dim = 8, 8, 16
    base = np.eye(n_docs, dim, dtype=np.float32)
    rows = np.repeat(base, per, axis=0)
    rows = rows + 0.01 * rng.standard_normal(rows.shape).astype(np.float32)
    doc_map = jnp.arange(n_docs * per) // per
    ann = AnnIndex.build(jnp.asarray(rows), BruteForceConfig())
    q = jnp.asarray(base[:1])  # doc 0's centroid: its 8 vectors rank first

    inner = plan.QueryPlan(
        search=lambda qq: ann.search(qq, k=per, depth=per, use_kernel=False),
        search_at=lambda qq, kk: ann.search(
            qq, k=kk, depth=kk, use_kernel=False),
    )
    # The raw single-pass reduction under-fills: 8 vector hits -> 1 doc.
    s_raw, i_raw = inner.run(q)
    _, agg_i = plan.aggregate_by_doc(s_raw, i_raw, doc_map, k=4, agg="max")
    assert int((np.asarray(agg_i) >= 0).sum()) < 4

    mv = plan.MultiVectorPlan(inner=inner, doc_map=doc_map, k=4, agg="max")
    s, i = mv.run(q)
    i = np.asarray(i)
    assert i.shape == (1, 4)
    assert int((i >= 0).sum()) == 4, i
    assert i[0, 0] == 0
    assert len(np.unique(i[0])) == 4

    # A fixed-depth inner (no search_at) cannot deepen: the loop must
    # terminate and return the honest under-filled list.
    fixed = plan.QueryPlan(
        search=lambda qq: ann.search(qq, k=per, depth=per, use_kernel=False))
    mv_fixed = plan.MultiVectorPlan(inner=fixed, doc_map=doc_map, k=4)
    _, i_fixed = mv_fixed.run(q)
    assert int((np.asarray(i_fixed) >= 0).sum()) == 1
