"""Training stack: optimizers, accumulation, checkpointing, fault tolerance."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.train_loop import Watchdog, build_train_step, make_train_state

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _quadratic_batchless():
    key = jax.random.key(0)
    w_true = jax.random.normal(key, (8, 4))

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"] + p["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    def batch_at(i):
        x = jax.random.normal(jax.random.fold_in(key, i), (16, 8))
        return {"x": x, "y": x @ w_true}

    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    return loss_fn, batch_at, params


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizers_converge(kind):
    loss_fn, batch_at, params = _quadratic_batchless()
    opt = opt_mod.adamw(lr=1e-2) if kind == "adamw" else opt_mod.adafactor(lr=5e-2)
    state = make_train_state(params, opt)
    step = jax.jit(build_train_step(loss_fn, opt))
    first = None
    for i in range(300):
        state, m = step(state, batch_at(i))
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < 0.05 * first


def test_microbatch_accumulation_matches_full_batch():
    loss_fn, batch_at, params = _quadratic_batchless()
    opt = opt_mod.adamw(lr=1e-2)
    s1 = make_train_state(params, opt)
    s4 = make_train_state(params, opt)
    step1 = jax.jit(build_train_step(loss_fn, opt, n_microbatches=1))
    step4 = jax.jit(build_train_step(loss_fn, opt, n_microbatches=4))
    for i in range(5):
        s1, m1 = step1(s1, batch_at(i))
        s4, m4 = step4(s4, batch_at(i))
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_grad_clipping():
    g = {"w": jnp.full((10,), 100.0)}
    clipped, norm = opt_mod.clip_by_global_norm(g, 1.0)
    assert float(jnp.linalg.norm(clipped["w"])) <= 1.0 + 1e-5
    assert float(norm) > 100.0


def test_checkpoint_atomicity_prune_and_restore():
    loss_fn, batch_at, params = _quadratic_batchless()
    opt = opt_mod.adamw()
    state = make_train_state(params, opt)
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3):
            ckpt.save(d, s, state, keep=2)
        assert ckpt.list_steps(d) == [2, 3]
        # a stale .tmp dir must be invisible
        os.makedirs(os.path.join(d, "step_00000099.tmp"))
        assert ckpt.latest_step(d) == 3
        restored, step = ckpt.restore(d, state)
        assert step == 3
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # shape mismatch is rejected (not silently loaded)
        bad = {"params": {"w": jnp.zeros((9, 4)), "b": jnp.zeros((4,))}}
        with pytest.raises((ValueError, KeyError)):
            ckpt.restore(d, bad)


def test_train_driver_crash_restart_is_deterministic(tmp_path):
    """Fault tolerance end-to-end: run 60 steps; run again with a simulated
    crash at step 30 + restart; final losses must match exactly (stateless
    data + checkpoint restore)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    base = [
        sys.executable, "-m", "repro.launch.train", "--arch", "micro-lm",
        "--steps", "60", "--global-batch", "2", "--seq-len", "32",
        "--ckpt-every", "20", "--log-every", "59",
    ]

    def run(args, ckdir):
        return subprocess.run(
            base + ["--ckpt-dir", str(ckdir)] + args,
            capture_output=True, text=True, env=env, timeout=600,
        )

    r1 = run([], tmp_path / "a")
    assert r1.returncode == 0, r1.stdout + r1.stderr
    r2a = run(["--kill-at", "25"], tmp_path / "b")
    assert r2a.returncode == 42  # simulated crash
    r2b = run([], tmp_path / "b")
    assert r2b.returncode == 0, r2b.stdout + r2b.stderr
    assert "resumed from step 20" in r2b.stdout

    def final_loss(out):
        for line in reversed(out.splitlines()):
            if "last_loss" in line:
                return float(line.split("'last_loss':")[1].split(",")[0])
        raise AssertionError(out)

    assert abs(final_loss(r1.stdout) - final_loss(r2b.stdout)) < 1e-4


def test_watchdog_flags_stragglers():
    import time
    wd = Watchdog(threshold=1.5)
    logs = []
    for i in range(5):
        wd.start()
        time.sleep(0.01)
        wd.stop(i, log=logs.append)
    wd.start()
    time.sleep(0.1)  # straggler step
    wd.stop(5, log=logs.append)
    assert wd.flagged == 1 and "straggler" in logs[-1]
