"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Every kernel sweeps shapes (unaligned sizes included — the pad paths) and
dtypes, asserting allclose against the ref.py oracle per the brief.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fakewords, lexical_lsh
from repro.core.types import FakeWordsConfig, LexicalLshConfig
from repro.kernels.cosine_score.kernel import cosine_scores
from repro.kernels.cosine_score.ref import cosine_scores_ref
from repro.kernels.fakewords_score.kernel import score_matmul
from repro.kernels.fakewords_score import ops as fw_ops
from repro.kernels.fakewords_score.ref import score_matmul_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.lsh_match.kernel import lsh_match_scores
from repro.kernels.lsh_match.ref import lsh_match_scores_ref

RNG = np.random.default_rng(7)


# -- fakewords_score ---------------------------------------------------------


@pytest.mark.parametrize("b,n,t", [(4, 64, 32), (8, 300, 100), (3, 513, 257)])
@pytest.mark.parametrize("dtype", ["int8", "bf16"])
def test_score_matmul_shapes_dtypes(b, n, t, dtype):
    if dtype == "int8":
        q = jnp.asarray(RNG.integers(-50, 50, (b, t)), jnp.int8)
        d = jnp.asarray(RNG.integers(-50, 50, (n, t)), jnp.int8)
        out = score_matmul(q, d, out_dtype=jnp.int32, interpret=True)
        ref = score_matmul_ref(q, d)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    else:
        q = jnp.asarray(RNG.normal(size=(b, t)), jnp.bfloat16)
        d = jnp.asarray(RNG.normal(size=(n, t)), jnp.bfloat16)
        out = score_matmul(q, d, interpret=True)
        ref = score_matmul_ref(q, d)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=2e-2, atol=2e-2)


def test_kernel_classic_scores_matches_core(small_corpus):
    v = jnp.asarray(small_corpus[:256])
    cfg = FakeWordsConfig(quantization=40)
    idx = fakewords.build(v, cfg)
    q_tf = fakewords.encode_queries(v[:4], cfg)
    ref = fakewords.classic_scores(idx, q_tf)
    out = fw_ops.classic_scores(idx, q_tf)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=5e-2, atol=5e-1)


def test_kernel_dot_scores_matches_core(small_corpus):
    v = jnp.asarray(small_corpus[:256])
    cfg = FakeWordsConfig(quantization=50, scoring="dot")
    idx = fakewords.build(v, cfg)
    q_tf = fakewords.encode_queries(v[:4], cfg)
    ref = fakewords.dot_scores(idx, q_tf)
    out = fw_ops.dot_scores(idx, q_tf)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# -- cosine_score ------------------------------------------------------------


@pytest.mark.parametrize("b,n,dim", [(4, 128, 64), (2, 300, 33), (5, 1000, 300)])
def test_cosine_scores_vs_ref(b, n, dim):
    q = jnp.asarray(RNG.normal(size=(b, dim)), jnp.float32)
    docs = jnp.asarray(RNG.normal(size=(n, dim)), jnp.float32)
    qn = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    inv = 1.0 / jnp.linalg.norm(docs, axis=-1)
    out = cosine_scores(qn, docs, inv, interpret=True)
    ref = cosine_scores_ref(qn, docs, inv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


# -- lsh_match ---------------------------------------------------------------


@pytest.mark.parametrize("b,n,s", [(4, 100, 64), (2, 257, 300)])
def test_lsh_match_vs_ref(b, n, s):
    sig_d = jnp.asarray(RNG.integers(0, 1 << 31, (n, s)), jnp.uint32)
    sig_q = sig_d[:b]
    # plant some sentinels
    sig_q = sig_q.at[:, ::7].set(jnp.uint32(0xFFFFFFFF))
    out = lsh_match_scores(sig_q, sig_d, interpret=True)
    ref = lsh_match_scores_ref(sig_q, sig_d)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_lsh_kernel_matches_core_scores(small_corpus):
    v = jnp.asarray(small_corpus[:128])
    cfg = LexicalLshConfig(buckets=64, hashes=2)
    sig = lexical_lsh.encode(v, cfg)
    ref = lexical_lsh.match_scores(sig[:4], sig)
    out = lsh_match_scores(sig[:4], sig, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# -- flash_attention ---------------------------------------------------------


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 4, 4, 128, 64),   # MHA
    (2, 4, 2, 256, 32),   # GQA group 2
    (1, 8, 1, 130, 64),   # MQA, unaligned seq
])
def test_flash_attention_vs_ref(b, hq, hkv, s, d):
    q = jnp.asarray(RNG.normal(size=(b, hq, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    out = flash_attention(q, k, v, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(RNG.normal(size=(1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    out = flash_attention(q, k, v, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=3e-2, atol=3e-2)
