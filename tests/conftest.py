"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single device; only launch/dryrun.py forces 512 host devices.
Tests that need a small multi-device mesh run in a subprocess
(tests/test_distributed.py) so they don't poison this process's jax init.
"""
import os
import sys

import numpy as np
import pytest

# tools/ (reprolint + the dynamic trace audit) lives at the repo root,
# which isn't on sys.path when pytest runs with PYTHONPATH=src.
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)

from tools.reprolint.trace_audit import trace_audit  # noqa: E402,F401


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_corpus(rng):
    """(2000, 64) unit-ish vectors with a planted mean component."""
    x = rng.normal(size=(2000, 64)).astype(np.float32)
    x += 0.5 * rng.normal(size=(1, 64)).astype(np.float32)
    return x
