"""Lucene-style segmented mutable index (core/segments.py, docs/DESIGN.md
§11): IndexWriter add/delete/flush/commit/merge, liveDocs masking inside the
match stage, generation-numbered commit points with v1 read-compat, and
epoch-invalidated serving.

The load-bearing property (the whole point of scoring every segment under
global collection statistics): a segmented index — any segment geometry,
with deletes — returns BITWISE the results of a fresh monolithic build of
the equivalent live corpus, for every encoding, before and after merges.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pipeline as pl
from repro.core.index import AnnIndex
from repro.core.segments import (
    IndexWriter,
    Segment,
    SegmentedAnnIndex,
    TieredMergePolicy,
    find_commits,
)
from repro.core.types import (
    BruteForceConfig,
    FakeWordsConfig,
    KdTreeConfig,
    LexicalLshConfig,
)
from repro.serve.ann_service import AnnService, AnnServiceConfig

ALL_CONFIGS = [
    FakeWordsConfig(quantization=50),
    FakeWordsConfig(quantization=50, scoring="dot"),
    LexicalLshConfig(buckets=64, hashes=2),
    KdTreeConfig(dims=8, backend="scan"),
    KdTreeConfig(dims=8, backend="scan", reduction="ppa-pca-ppa"),
    BruteForceConfig(),
]


def _ids(cfg):
    tag = type(cfg).__name__
    if isinstance(cfg, FakeWordsConfig):
        tag = f"fakewords-{cfg.scoring}"
    if isinstance(cfg, KdTreeConfig):
        tag = f"kdtree-{cfg.reduction}"
    return tag


def _corpora(rng):
    a = rng.normal(size=(600, 32)).astype(np.float32)
    b = rng.normal(size=(412, 32)).astype(np.float32)
    return a, b


def _map_mono_ids(gmap, mono_ids):
    """Monolithic live-corpus ids -> segmented stable global ids."""
    mono_ids = np.asarray(mono_ids)
    return np.where(mono_ids >= 0, gmap[np.maximum(mono_ids, 0)], -1)


def _assert_parity(reader, mono, queries, k=10, depth=50):
    """Segmented search == monolithic search on the live corpus: scores
    bitwise, ids exact (through the live-id mapping), rerank on AND off."""
    gmap = reader.live_global_ids()
    for rerank in (False, True):
        s0, i0 = mono.search(queries, k=k, depth=depth, rerank=rerank,
                             use_kernel=False)
        s1, i1 = reader.search(queries, k=k, depth=depth, rerank=rerank,
                               use_kernel=False)
        np.testing.assert_array_equal(
            _map_mono_ids(gmap, np.asarray(i0)), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


# -- the acceptance flow: add / add / delete / commit / reload / merge -------


@pytest.mark.parametrize("cfg", ALL_CONFIGS, ids=_ids)
def test_segmented_equals_monolithic_with_deletes(cfg, rng, tmp_path):
    """Build corpus A, writer.add corpus B, delete a random 10%, commit,
    reload — results identical to a fresh monolithic build of the live
    corpus (scores bitwise, ids exact), before AND after a full merge."""
    a, b = _corpora(rng)
    queries = jnp.asarray(a[:8])
    w = IndexWriter(cfg, merge_policy=None)
    ids_a = w.add(a)
    assert w.flush() and w.num_segments == 1
    ids_b = w.add(b)
    np.testing.assert_array_equal(ids_a, np.arange(len(a)))
    np.testing.assert_array_equal(ids_b, np.arange(len(a), len(a) + len(b)))
    n = len(a) + len(b)
    dead = rng.choice(n, size=n // 10, replace=False)
    assert w.delete(dead) == len(dead)
    assert w.delete(dead) == 0  # idempotent

    live = np.ones(n, bool)
    live[dead] = False
    mono = AnnIndex.build(jnp.asarray(np.concatenate([a, b])[live]), cfg)

    path = os.path.join(tmp_path, "seg.ann")
    gen = w.commit(path)
    assert gen == 1
    reader = SegmentedAnnIndex.load(path)
    assert reader.num_segments == 2
    assert reader.num_docs == live.sum() and reader.max_doc == n
    np.testing.assert_array_equal(reader.live_global_ids(), np.flatnonzero(live))
    _assert_parity(reader, mono, queries)

    # forced full merge: one fully-live segment, ids now == monolithic ids
    w.force_merge(1)
    merged = w.refresh()
    assert merged.num_segments == 1 and merged.del_count == 0
    assert merged.num_docs == live.sum()
    _assert_parity(merged, mono, queries)
    # and the merged commit round-trips too
    gen2 = w.commit()
    assert gen2 == 2
    _assert_parity(SegmentedAnnIndex.load(path), mono, queries)


@pytest.mark.parametrize(
    "cfg",
    [FakeWordsConfig(quantization=50), BruteForceConfig()],
    ids=_ids,
)
def test_segmented_parity_on_kernel_path(cfg, rng):
    """The fused-kernel match path (interpret mode on CPU) preserves the
    same segmented-vs-monolithic parity."""
    a, b = _corpora(rng)
    a, b = a[:256], b[:200]
    queries = jnp.asarray(a[:4])
    w = IndexWriter(cfg, merge_policy=None)
    w.add(a)
    w.flush()
    w.add(b)
    n = len(a) + len(b)
    dead = rng.choice(n, size=n // 10, replace=False)
    w.delete(dead)
    live = np.ones(n, bool)
    live[dead] = False
    mono = AnnIndex.build(jnp.asarray(np.concatenate([a, b])[live]), cfg)
    reader = w.refresh()
    gmap = reader.live_global_ids()
    s0, i0 = mono.search(queries, k=10, depth=40, rerank=True, use_kernel=True)
    s1, i1 = reader.search(queries, k=10, depth=40, rerank=True, use_kernel=True)
    np.testing.assert_array_equal(_map_mono_ids(gmap, np.asarray(i0)), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


@pytest.mark.parametrize("cfg", ALL_CONFIGS, ids=_ids)
def test_one_segment_equals_many_segments_after_merge(cfg, rng):
    """Same corpus via one flush == via N flushes + full merge, bit-for-bit
    (the merge rebuilds from stored normalized originals without drift)."""
    a, b = _corpora(rng)
    corpus = np.concatenate([a, b])
    queries = jnp.asarray(a[:8])
    w1 = IndexWriter(cfg, merge_policy=None)
    w1.add(corpus)
    one = w1.refresh()
    wn = IndexWriter(cfg, merge_policy=None)
    for chunk in np.array_split(corpus, 4):
        wn.add(chunk)
        wn.flush()
    assert wn.num_segments == 4
    wn.force_merge(1)
    many = wn.refresh()
    assert many.num_segments == 1
    for rerank in (False, True):
        s0, i0 = one.search(queries, k=10, depth=50, rerank=rerank, use_kernel=False)
        s1, i1 = many.search(queries, k=10, depth=50, rerank=rerank, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


# -- deletes -----------------------------------------------------------------


def test_delete_commit_load_round_trip(rng, tmp_path):
    """Deletes persist through commit points; deleted docs never surface;
    later generations stack further deletes."""
    a, _ = _corpora(rng)
    cfg = BruteForceConfig()
    path = os.path.join(tmp_path, "del.ann")
    w = IndexWriter(cfg, path=path, merge_policy=None)
    w.add(a)
    w.commit()
    # delete the exact nearest neighbors of the first 4 queries
    queries = jnp.asarray(a[:4])
    _, top = AnnIndex.build(jnp.asarray(a), cfg).search(
        queries, k=1, depth=1, use_kernel=False)
    victims = np.asarray(top)[:, 0]
    w.delete(victims)
    gen = w.commit()
    assert gen == 2
    loaded = SegmentedAnnIndex.load(path)
    assert loaded.del_count == len(set(victims.tolist()))
    _, ids = loaded.search(queries, k=10, depth=50, rerank=True, use_kernel=False)
    assert not set(victims.tolist()) & set(np.asarray(ids).ravel().tolist())
    # the pre-delete generation is still readable (point-in-time commits)
    old = SegmentedAnnIndex.load(path, generation=1)
    assert old.del_count == 0
    _, old_ids = old.search(queries, k=1, depth=1, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(old_ids)[:, 0], victims)


def test_delete_in_buffer_and_depth_semantics(rng):
    """Deleting buffered (unflushed) docs works, and liveDocs masking keeps
    depth semantics: depth-d still returns d LIVE candidates when d live
    docs exist (deletes masked inside the match stage, not post-filtered)."""
    a, _ = _corpora(rng)
    w = IndexWriter(BruteForceConfig(), merge_policy=None)
    ids = w.add(a)
    w.delete(ids[10:20])  # still in the buffer
    reader = w.refresh()
    assert reader.del_count == 10
    q = jnp.asarray(a[:2])
    depth = len(a) - 10  # exactly the live count
    s, i = reader.search(q, k=depth, depth=depth, use_kernel=False)
    ids_np = np.asarray(i)
    assert (ids_np >= 0).all(), "masked deletes must not shrink the depth"
    assert not (np.isin(ids_np, np.arange(10, 20))).any()
    with pytest.raises(IndexError):
        w.delete([len(a) + 5])


def test_live_docs_matcher_is_a_match_stage(rng):
    """LiveDocsMatcher unit semantics: masking happens before the stage's
    top-k, so the output is the top-depth over LIVE docs only."""
    v = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    ann = AnnIndex.build(v, BruteForceConfig())
    q = v[:1]
    inner = pl.make_matcher(BruteForceConfig())
    s_all, i_all = inner(ann.index, q, 64, use_kernel=False)
    top = np.asarray(i_all)[0]
    live = np.ones(64, bool)
    live[top[:3]] = False  # kill the 3 best docs
    m = pl.LiveDocsMatcher(inner=inner, extra=4)
    s, i = m(ann.index, q, 5, jnp.asarray(live), use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i)[0], top[3:8])


# -- merge policy ------------------------------------------------------------


def test_tiered_merge_policy_geometry():
    pol = TieredMergePolicy(merge_factor=4, floor_docs=100)
    assert pol.tier(50) == 0 and pol.tier(100) == 0
    assert pol.tier(101) == 1 and pol.tier(400) == 1 and pol.tier(401) == 2

    def seg(n_live, n_total=None):
        n_total = n_total if n_total is not None else n_live
        live = np.zeros(n_total, bool)
        live[:n_live] = True
        ann = AnnIndex.build(
            jnp.zeros((n_total, 4)) + np.arange(n_total)[:, None],
            BruteForceConfig())
        return Segment(ann=ann, live=live, name="t")

    # 3 same-tier segments: stable; 4: merge the run
    assert pol.find_merge([seg(50)] * 3) is None
    assert pol.find_merge([seg(50)] * 4) == (0, 4)
    # adjacent-only: a tier-1 segment breaks the run
    assert pol.find_merge([seg(50), seg(50), seg(200), seg(50), seg(50)]) is None
    # expunge: >= 50% deleted is rewritten alone
    assert pol.find_merge([seg(200), seg(40, 100)]) == (1, 2)


def test_writer_auto_merge_and_id_remap(rng):
    """Flush-triggered tiered merging keeps the segment count logarithmic,
    and a merge drops deleted rows and remaps ids compactly."""
    a, _ = _corpora(rng)
    w = IndexWriter(
        BruteForceConfig(),
        merge_policy=TieredMergePolicy(merge_factor=4, floor_docs=128),
    )
    for chunk in np.array_split(a[:512], 8):  # 8 x 64-doc flushes
        w.add(chunk)
        w.flush()
    assert w.num_segments <= 3  # 8 floor flushes collapse through the tiers
    total_before = w.total_docs
    w.delete(np.arange(0, 32))
    w.force_merge(1)
    assert w.num_segments == 1
    assert w.total_docs == total_before - 32  # dead rows really dropped
    reader = w.refresh()
    assert reader.num_docs == total_before - 32 and reader.del_count == 0


def test_merge_fully_dead_segments_are_dropped(rng):
    a, _ = _corpora(rng)
    w = IndexWriter(BruteForceConfig(), merge_policy=None)
    ids = w.add(a[:64])
    w.flush()
    w.add(a[64:128])
    w.flush()
    w.delete(ids)  # first segment fully dead
    w.force_merge(1)
    assert w.num_segments == 1 and w.total_docs == 64
    reader = w.refresh()
    np.testing.assert_array_equal(reader.live_global_ids(), np.arange(64))


# -- epoch-keyed serving -----------------------------------------------------


def test_service_nrt_refresh_zero_stale_hits(rng):
    """AnnService(writer=...) serves across refresh() with ZERO stale cache
    hits: a doc added after the first query round must surface immediately
    post-refresh even with the result cache on."""
    a, _ = _corpora(rng)
    w = IndexWriter(BruteForceConfig(), merge_policy=None)
    w.add(a)
    svc = AnnService(writer=w, service=AnnServiceConfig(
        k=5, depth=20, rerank=True, max_batch=8, cache_size=16))
    qs = a[:8]
    _, i1 = svc.search_batch(qs)
    _, i1b = svc.search_batch(qs)
    assert svc.cache_hits == 1  # warm within an epoch
    np.testing.assert_array_equal(i1, i1b)
    # a near-duplicate of query 0: the new exact-match doc must win
    new_id = int(w.add(a[0:1] * 3.0)[0])
    old_epoch = svc.ann.epoch
    new_epoch = svc.refresh()
    assert new_epoch != old_epoch
    _, i2 = svc.search_batch(qs)
    assert new_id in np.asarray(i2)[0]
    # deletes invalidate the same way
    w.delete([new_id])
    svc.refresh()
    _, i3 = svc.search_batch(qs)
    assert new_id not in np.asarray(i3)
    # zero stale hits: every post-mutation answer was recomputed
    assert svc.cache_hits == 1 and svc.cache_misses == 3
    # an unchanged refresh keeps the epoch AND the warm cache
    assert svc.refresh() == svc.ann.epoch
    _, i3b = svc.search_batch(qs)
    np.testing.assert_array_equal(i3, i3b)
    assert svc.cache_hits == 2
    stats = svc.stats()
    assert stats["segments"] == svc.ann.num_segments
    assert stats["epoch"] == svc.ann.epoch


def test_service_cache_key_includes_index_epoch(small_corpus):
    """Regression: _cache_key used to omit index identity — a service whose
    index was swapped in place kept serving the OLD index's cached
    results."""
    v = jnp.asarray(small_corpus[:512])
    cfg = BruteForceConfig()
    ann1 = AnnIndex.build(v, cfg)
    ann2 = AnnIndex.build(jnp.asarray(small_corpus[:512][::-1].copy()), cfg)
    assert ann1.epoch != ann2.epoch
    svc = AnnService(ann1, AnnServiceConfig(
        k=5, depth=20, rerank=True, max_batch=8, cache_size=8))
    qs = small_corpus[:8]
    _, ia = svc.search_batch(qs)
    assert svc.set_index(ann2) == ann2.epoch
    _, ib = svc.search_batch(qs)
    assert svc.cache_hits == 0, "stale hit across an index swap"
    assert not np.array_equal(ia, ib)
    # swapping back revives the first index's still-resident entries
    svc.set_index(ann1)
    _, ic = svc.search_batch(qs)
    assert svc.cache_hits == 1
    np.testing.assert_array_equal(ia, ic)


def test_service_serves_segmented_index_directly(rng):
    """A SegmentedAnnIndex (e.g. loaded from a commit point) serves through
    AnnService like any index; unsupported combos fail loudly."""
    a, _ = _corpora(rng)
    w = IndexWriter(FakeWordsConfig(quantization=50), merge_policy=None)
    w.add(a[:300])
    w.flush()
    w.add(a[300:])
    reader = w.refresh()
    svc = AnnService(reader, AnnServiceConfig(
        k=10, depth=50, rerank=True, max_batch=8))
    s_svc, i_svc = svc.search_batch(a[:8])
    s_dir, i_dir = reader.search(
        jnp.asarray(a[:8]), k=10, depth=50, rerank=True, use_kernel=None)
    np.testing.assert_array_equal(np.asarray(i_dir), i_svc)
    np.testing.assert_array_equal(np.asarray(s_dir), s_svc)
    # blockmax now rides the packed superbuffer for fake-words/LSH
    # (tests/test_serve.py); encodings without blockmax bounds still fail
    # loudly at bind time.
    w_bf = IndexWriter(BruteForceConfig(), merge_policy=None)
    w_bf.add(a[:64])
    w_bf.flush()
    with pytest.raises(ValueError):
        AnnService(w_bf.refresh(), AnnServiceConfig(blockmax_keep=4))
    with pytest.raises(TypeError):
        svc.set_index("not an index")  # type: ignore[arg-type]


def test_max_wait_s_is_back():
    """``max_wait_s`` returned as the async micro-batcher's coalescing SLO
    (docs/DESIGN.md §14): positive default, paired with a bounded admission
    queue."""
    assert AnnServiceConfig().max_wait_s > 0
    assert AnnServiceConfig().queue_depth > 0


# -- persistence formats -----------------------------------------------------


def test_commit_points_are_generation_numbered_and_atomic(rng, tmp_path):
    a, _ = _corpora(rng)
    path = os.path.join(tmp_path, "gen.ann")
    w = IndexWriter(BruteForceConfig(), path=path, merge_policy=None)
    w.add(a[:100])
    assert w.commit() == 1
    w.add(a[100:200])
    assert w.commit() == 2
    assert [g for g, _ in find_commits(path)] == [1, 2]
    with open(os.path.join(path, "segments_2.json")) as f:
        meta = json.load(f)
    assert meta["format_version"] == 2 and meta["generation"] == 2
    assert len(meta["segments"]) == 2
    # segment dirs are immutable: gen-2 reuses gen-1's segment dir
    assert meta["segments"][0]["name"] == "seg0"
    # no torn tmp files left behind
    assert not [f for f in os.listdir(path) if f.endswith(".tmp")]
    r1 = SegmentedAnnIndex.load(path, generation=1)
    r2 = SegmentedAnnIndex.load(path)
    assert (r1.num_docs, r2.num_docs) == (100, 200)
    with pytest.raises(FileNotFoundError):
        SegmentedAnnIndex.load(path, generation=7)


def test_commit_lineage_guard(rng, tmp_path):
    """A writer that never read a directory's commits must not commit over
    them (its segment names would collide with the foreign dirs and the
    new manifest would silently reference another writer's data);
    IndexWriter.open adopts the lineage and may continue it."""
    a, _ = _corpora(rng)
    path = os.path.join(tmp_path, "lineage.ann")
    w1 = IndexWriter(BruteForceConfig(), merge_policy=None)
    w1.add(a[:64])
    assert w1.commit(path) == 1
    w2 = IndexWriter(BruteForceConfig(), merge_policy=None)
    w2.add(a[64:128])
    with pytest.raises(ValueError, match="foreign commit history"):
        w2.commit(path)
    # the durable state is untouched and still opens at gen 1
    assert [g for g, _ in find_commits(path)] == [1]
    w3 = IndexWriter.open(path)
    w3.add(a[64:128])
    assert w3.commit() == 2
    assert SegmentedAnnIndex.load(path).num_docs == 128


def test_v1_dir_loads_as_single_segment_and_upgrades(rng, tmp_path):
    """v1 read-compat: a plain AnnIndex.save dir opens as one fully-live
    segment, and IndexWriter.open upgrades it to the segmented lifecycle."""
    a, _ = _corpora(rng)
    cfg = FakeWordsConfig(quantization=50)
    ann = AnnIndex.build(jnp.asarray(a), cfg)
    path = os.path.join(tmp_path, "v1.ann")
    ann.save(path)
    reader = SegmentedAnnIndex.load(path)
    assert reader.num_segments == 1 and reader.num_docs == len(a)
    with pytest.raises(FileNotFoundError, match="v1 single-index"):
        SegmentedAnnIndex.load(path, generation=3)
    qs = jnp.asarray(a[:8])
    s0, i0 = ann.search(qs, k=10, depth=50, rerank=True, use_kernel=False)
    s1, i1 = reader.search(qs, k=10, depth=50, rerank=True, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    w = IndexWriter.open(path)
    w.add(a[:10])
    w.delete([0])
    gen = w.commit()
    upgraded = SegmentedAnnIndex.load(path)
    assert gen == 1 and upgraded.num_segments == 2
    assert upgraded.num_docs == len(a) + 10 - 1


def test_format_version_is_validated(rng, tmp_path):
    """Satellite bugfix: AnnIndex.load fails with a clear 'newer format'
    error instead of a KeyError deep in _rebuild_index; commit points
    validate the same way; a commit dir pointed at AnnIndex.load explains
    itself."""
    a, _ = _corpora(rng)
    path = os.path.join(tmp_path, "fv.ann")
    ann = AnnIndex.build(jnp.asarray(a[:64]), BruteForceConfig())
    ann.save(path)
    cfg_path = os.path.join(path, "config.json")
    with open(cfg_path) as f:
        meta = json.load(f)
    meta["format_version"] = 99
    with open(cfg_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="format_version 99.*newer"):
        AnnIndex.load(path)

    seg_path = os.path.join(tmp_path, "seg.ann")
    w = IndexWriter(BruteForceConfig(), path=seg_path, merge_policy=None)
    w.add(a[:64])
    w.commit()
    with pytest.raises(ValueError, match="segmented commit point"):
        AnnIndex.load(seg_path)
    commit_file = os.path.join(seg_path, "segments_1.json")
    with open(commit_file) as f:
        meta = json.load(f)
    meta["format_version"] = 99
    with open(commit_file, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="format_version 99"):
        SegmentedAnnIndex.load(seg_path)


# -- guard rails -------------------------------------------------------------


def test_writer_guard_rails(rng):
    a, _ = _corpora(rng)
    with pytest.raises(ValueError, match="rerank_store"):
        IndexWriter(BruteForceConfig(), rerank_store="fp16")
    with pytest.raises(ValueError, match="backend='scan'"):
        IndexWriter(KdTreeConfig(dims=8, backend="tree"))
    w = IndexWriter(BruteForceConfig(), merge_policy=None)
    with pytest.raises(ValueError):
        w.add(np.zeros((0, 8), np.float32))
    with pytest.raises(ValueError, match="no live docs"):
        w.refresh().search(jnp.asarray(a[:1]))
    with pytest.raises(ValueError, match="commit needs a path"):
        w.commit()
    w.add(a[:64])
    reader = w.refresh()
    with pytest.raises(ValueError, match="single-process"):
        AnnService(reader, mesh=object())  # type: ignore[arg-type]


def test_auto_flush_on_buffer_threshold(rng):
    a, _ = _corpora(rng)
    w = IndexWriter(
        BruteForceConfig(), merge_policy=None, max_buffered_docs=128)
    for chunk in np.array_split(a[:512], 16):  # 32 docs per add
        w.add(chunk)
    assert w.num_segments == 4 and w.buffered_docs == 0
