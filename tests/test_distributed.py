"""Multi-device correctness, run in subprocesses with 8 fake host devices
(so this process's single-device jax init stays clean).

Each scenario asserts the SHARDED computation equals its single-device
reference: that's the strongest evidence the production sharding config is
semantically sound, short of real hardware.
"""
import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(body: str):
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro import compat
        """
    ) + textwrap.dedent(body)
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=900,
        env=dict(os.environ, PYTHONPATH=SRC),
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_fakewords_search_equals_single_device():
    run_subprocess("""
    from repro.core import bruteforce, distributed, fakewords
    from repro.core.types import FakeWordsConfig
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(1024, 32)).astype(np.float32))
    qs = vecs[:8]
    cfg = FakeWordsConfig(quantization=50)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    idx_sh = distributed.build_sharded(mesh, vecs, cfg, ("data", "model"))
    search = distributed.make_sharded_search(mesh, cfg, ("data", "model"), k=10, depth=50, rerank=True)
    q_tf = fakewords.encode_queries(qs, cfg)
    s_sh, i_sh = search(idx_sh, q_tf, bruteforce.l2_normalize(qs))
    # single-device reference
    idx = fakewords.build(vecs, cfg)
    s_1, i_1 = fakewords.search(idx, q_tf, bruteforce.l2_normalize(qs), k=10, depth=50, rerank=True)
    # idf must match exactly (psum'd df == global df)
    np.testing.assert_allclose(np.asarray(idx_sh.idf), np.asarray(idx.idf), rtol=1e-6)
    from repro.core import eval as ev
    ov = float(ev.overlap(i_1, i_sh))
    assert ov > 0.95, f"overlap {ov}"
    print("sharded search ok", ov)
    """)


def test_sharded_blockmax_search_and_rerank_padding_mask():
    run_subprocess("""
    from repro.core import blockmax, bruteforce, distributed, fakewords
    from repro.core import eval as ev
    from repro.core.types import FakeWordsConfig
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(1024, 32)).astype(np.float32)
    q = rng.normal(size=(1, 32)).astype(np.float32)
    # plant shard-local doc 0 == the query on EVERY shard: with the old
    # unmasked rerank, -1 padding slots gathered local doc 0 and returned
    # perfect cosine scores under fake ids (-1 + shard * n_local)
    for sh in range(8):
        vecs[sh * 128] = q[0]
    vecs = jnp.asarray(vecs)
    cfg = FakeWordsConfig(quantization=50)
    mesh = jax.make_mesh((8,), ("data",))
    # deprecated alias of the generic BuildPipeline build_sharded
    idx_sh = distributed.build_fakewords_sharded(mesh, vecs, cfg, ("data",))
    # ragged per-shard blocks: 128 docs/shard, block 48 -> 3 blocks, 16 pad
    bm_sh = distributed.build_blockmax_sharded(mesh, idx_sh, ("data",), block_size=48)
    assert bm_sh.ub.shape[0] == 24 and bm_sh.mode == "classic"
    qn = bruteforce.l2_normalize(jnp.asarray(q))
    q_tf = fakewords.encode_queries(qn, cfg)
    # depth > n_local AND all blocks kept: every shard deterministically
    # returns 16 padded (-1) slots into the rerank + merge
    search = distributed.make_sharded_search(
        mesh, cfg, ("data",), k=20, depth=200, rerank=True, blockmax_keep=3)
    s, i = search(idx_sh, bm_sh, q_tf, qn)
    ii, ss = np.asarray(i)[0], np.asarray(s)[0]
    assert ((ii >= -1) & (ii < 1024)).all()
    # exactly the 8 planted docs earn ~1.0; fake ids 127, 255, ... must not
    planted = set(range(0, 1024, 128))
    assert set(ii[ss > 0.999].tolist()) == planted, ii[ss > 0.999]
    # every returned score must be the true cosine of its claimed doc id
    vn = np.asarray(bruteforce.l2_normalize(vecs)); qv = np.asarray(qn)[0]
    for idd, sc in zip(ii, ss):
        if idd >= 0:
            np.testing.assert_allclose(sc, qv @ vn[idd], rtol=1e-4, atol=1e-5)
    # keep-all blockmax matches the dense sharded search results
    idx = fakewords.build(vecs, cfg)
    s1, i1 = fakewords.search(idx, q_tf, qn, k=20, depth=200, rerank=True)
    ov = float(ev.overlap(i1, jnp.asarray(ii[None, :])))
    assert ov > 0.9, ov
    print("sharded blockmax ok", ov)
    """)


def test_sharded_filtered_search_equals_local_filtered():
    run_subprocess("""
    from repro.core import bruteforce, distributed, fakewords
    from repro.core import pipeline as pl
    from repro.core.types import FakeWordsConfig
    rng = np.random.default_rng(5)
    vecs = jnp.asarray(rng.normal(size=(1024, 32)).astype(np.float32))
    qs = vecs[:8]
    cfg = FakeWordsConfig(quantization=50)
    mesh = jax.make_mesh((8,), ("data",))
    idx_sh = distributed.build_sharded(mesh, vecs, cfg, ("data",))
    search = distributed.make_sharded_search(
        mesh, cfg, ("data",), k=10, depth=64, rerank=True, filtered=True)
    qn = bruteforce.l2_normalize(qs)
    q_tf = fakewords.encode_queries(qn, cfg)
    idx = fakewords.build(vecs, cfg)
    matcher = pl.make_matcher(cfg)
    for ratio in (0.01, 0.1, 0.5):
        m = (rng.random(1024) < ratio).astype(np.int32)
        m[:16] = 1  # guarantee >= k survivors
        filt = jnp.asarray(m)
        s_sh, i_sh = search(idx_sh, q_tf, qn, filt)
        # local reference: the same one-pass in-match filter
        s_l, i_l = pl.match_rerank(matcher, idx, q_tf, qn, k=10, depth=64,
                                   rerank=True, filt=filt)
        np.testing.assert_array_equal(np.asarray(i_sh), np.asarray(i_l))
        assert ((np.asarray(i_sh) < 0) |
                (m[np.maximum(np.asarray(i_sh), 0)] != 0)).all()
    # all-ones == the unfiltered sharded search bit-for-bit
    plain = distributed.make_sharded_search(
        mesh, cfg, ("data",), k=10, depth=64, rerank=True)
    s0, i0 = plain(idx_sh, q_tf, qn)
    s1, i1 = search(idx_sh, q_tf, qn, jnp.ones((1024,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    # all-zeros: padded, never NaN
    s2, i2 = search(idx_sh, q_tf, qn, jnp.zeros((1024,), jnp.int32))
    assert (np.asarray(i2) == -1).all() and not np.isnan(np.asarray(s2)).any()
    print("sharded filtered ok")
    """)


def test_sharded_gnn_full_graph_equals_single_device():
    run_subprocess("""
    from repro.models import gnn
    from repro.data import graph as gd
    g = gd.make_graph(gd.GraphConfig(n_nodes=200, n_edges=800, d_feat=16, n_classes=5))
    src, dst = g.edge_list()
    cfg = gnn.SageConfig(n_layers=2, d_in=16, d_hidden=32, n_classes=5, fanouts=(5, 3))
    params = gnn.init_params(jax.random.key(0), cfg)
    mask = jnp.ones((200,), jnp.float32)
    ref = gnn.loss_full(params, g.feats, src, dst, g.labels, mask, cfg)
    mesh = jax.make_mesh((8,), ("dev",))
    # shard edges over all devices (uneven 800/8 is fine)
    es = NamedSharding(mesh, P("dev"))
    srcs = jax.device_put(src, es); dsts = jax.device_put(dst, es)
    out = jax.jit(gnn.loss_full, static_argnames="cfg")(params, g.feats, srcs, dsts, g.labels, mask, cfg)
    np.testing.assert_allclose(float(out), float(ref), rtol=1e-5)
    print("gnn sharded ok", float(out))
    """)


def test_sharded_recsys_table_equals_single_device():
    run_subprocess("""
    from repro.models import recsys as rec
    table_spec = rec.TableSpec(rec.criteo_row_counts(8, 4096), 16)
    cfg = rec.RecsysConfig(model="deepfm", table=table_spec, mlp=(32, 32))
    params = rec.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    rows = np.asarray(table_spec.row_counts)
    idx = jnp.asarray(rng.integers(0, rows[None, :, None], (16, 8, 1)), jnp.int32)
    ref = rec.forward(params, cfg, idx)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    p_sh = dict(params)
    p_sh["table"] = jax.device_put(params["table"], NamedSharding(mesh, P("model", None)))
    p_sh["linear"] = jax.device_put(params["linear"], NamedSharding(mesh, P("model", None)))
    idx_sh = jax.device_put(idx, NamedSharding(mesh, P("data", None, None)))
    out = jax.jit(lambda p, i: rec.forward(p, cfg, i))(p_sh, idx_sh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)
    print("recsys sharded ok")
    """)


def test_sharded_lm_train_step_equals_single_device():
    run_subprocess("""
    import dataclasses
    from repro.models import transformer as tfm
    cfg = tfm.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                                d_ff=128, vocab=128, dtype=jnp.float32)
    params = tfm.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (8, 16), 0, 128)
    ref = tfm.loss_fn(params, toks, toks, cfg)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg_sh = dataclasses.replace(cfg, batch_axes=("data",), tp_axis="model")
    from repro.sharding import rules
    specs = rules.lm_param_specs(tfm.param_shapes(cfg))
    p_sh = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                        params, specs, is_leaf=lambda x: hasattr(x, "shape"))
    t_sh = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
    with compat.set_mesh(mesh):
        out = jax.jit(lambda p, t: tfm.loss_fn(p, t, t, cfg_sh))(p_sh, t_sh)
    np.testing.assert_allclose(float(out), float(ref), rtol=2e-4)
    print("lm sharded loss ok", float(out), float(ref))
    """)


def test_compressed_allreduce_and_gpipe():
    run_subprocess("""
    from repro.train import compression, pipeline
    mesh = jax.make_mesh((8,), ("data",))
    g = jnp.arange(8 * 64, dtype=jnp.float32).reshape(8, 64) / 100.0
    def f(gs, r):
        return compression.compressed_psum(gs, r, "data")
    out, new_r = jax.jit(compat.shard_map(
        f, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P(), P("data"))))({"w": g}, {"w": jnp.zeros((8, 64))})
    exact = jnp.mean(g, axis=0)
    err = float(jnp.max(jnp.abs(out["w"].reshape(-1, 64)[0] - exact)))
    assert err < 5e-3 * float(jnp.max(jnp.abs(exact))) + 1e-4, err
    # error feedback: residual equals quantization error
    assert new_r["w"].shape == (8, 64)

    n_layers, d, M, mb = 8, 16, 4, 2
    ws = jax.random.normal(jax.random.key(0), (n_layers, d, d)) * (1.0 / np.sqrt(d))
    x = jax.random.normal(jax.random.key(1), (M, mb, d))
    layer_fn = lambda h, w: jnp.tanh(h @ w)
    mesh_p = jax.make_mesh((4,), ("pipe",))
    out_p = jax.jit(pipeline.build_gpipe_fn(mesh_p, layer_fn, n_stages=4))(ws, x)
    ref = x
    for i in range(n_layers):
        ref = layer_fn(ref, ws[i])
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(ref), atol=1e-6)
    print("compression + gpipe ok")
    """)


def test_elastic_checkpoint_restore_across_meshes():
    run_subprocess("""
    import tempfile
    from repro.train import checkpoint as ckpt
    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mesh_a = jax.make_mesh((8,), ("data",))
    sharded = {"w": jax.device_put(state["w"], NamedSharding(mesh_a, P("data", None)))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, sharded)
        # restore onto a DIFFERENT mesh shape (elastic restart)
        mesh_b = jax.make_mesh((2, 4), ("x", "y"))
        out, step = ckpt.restore(
            d, state,
            sharding_fn=lambda k, a: NamedSharding(mesh_b, P("x", "y")))
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))
        assert out["w"].sharding.mesh.shape == {"x": 2, "y": 4}
    print("elastic restore ok")
    """)
