"""Cell builder & sharding rules: structural checks that run WITHOUT the
512-device env (no lowering here — that's the dry-run's job; these verify
the abstract problem statement is well-formed on the real single device).
"""
import jax
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.models import transformer as tfm
from repro.sharding import rules


def test_lm_param_specs_cover_every_leaf():
    cfg = configs.get("llama4-maverick-400b-a17b").make_model(None)
    shapes = tfm.param_shapes(cfg)
    specs = rules.lm_param_specs(shapes)
    flat_shapes = jax.tree_util.tree_leaves(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    flat_specs = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    for s, p in zip(flat_shapes, flat_specs):
        assert len(p) <= len(s)  # spec rank never exceeds leaf rank


def test_lm_param_specs_shard_big_dims_divisibly():
    """Every sharded dim of every full-size LM arch must divide 16 (the
    data/model axis size) — else the input sharding is rejected at lower."""
    for arch_id in configs.ASSIGNED:
        spec = configs.get(arch_id)
        if spec.family != "lm":
            continue
        cfg = spec.make_model(None)
        shapes = tfm.param_shapes(cfg)
        specs = rules.lm_param_specs(shapes)

        def check(shape, pspec):
            for dim, ax in zip(shape, tuple(pspec) + (None,) * len(shape)):
                if ax is not None:
                    assert dim % 16 == 0, (arch_id, shape, pspec)

        jax.tree_util.tree_map(
            check, shapes, specs, is_leaf=lambda x: isinstance(x, tuple))


def test_opt_state_specs_mirror_params():
    cfg = configs.get("phi3-mini-3.8b").make_model(None)
    shapes = tfm.param_shapes(cfg)
    pspecs = rules.lm_param_specs(shapes)
    adamw = rules.opt_state_specs("adamw", pspecs, shapes)
    assert jax.tree_util.tree_structure(adamw["mu"]) == jax.tree_util.tree_structure(
        pspecs)
    adaf = rules.opt_state_specs("adafactor", pspecs, shapes)
    # factored leaves: vr spec = param spec minus last dim
    flat_p = jax.tree_util.tree_leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_v = jax.tree_util.tree_leaves(
        adaf["v"], is_leaf=lambda x: isinstance(x, P))
    assert len(flat_v) >= len(flat_p)  # vr+vc per matrix leaf


def test_batch_and_cache_specs():
    assert rules.lm_batch_spec(False) == P(("data",), None)
    assert rules.lm_batch_spec(True) == P(("pod", "data"), None)
    assert rules.lm_cache_spec(False) == P(None, ("data",), "model", None, None)
    assert rules.lm_cache_spec(True, long_context=True) == P(
        None, None, ("pod", "data", "model"), None, None)


def test_cell_divisibility_constraints():
    """Every cell's sharded input dims divide the production meshes."""
    for arch_id in configs.ASSIGNED:
        spec = configs.get(arch_id)
        for cell in spec.cells:
            if cell.kind in ("train", "prefill", "decode") and cell.batch > 1:
                assert cell.batch % 32 == 0, (arch_id, cell.name)  # pod*data
            if cell.kind == "decode":
                assert cell.seq % 512 == 0  # KV length over all axes (long)


def test_model_flops_positive_and_ordered():
    """MODEL_FLOPS sanity: train > prefill > decode for every LM arch."""
    from repro.launch import cells as cm
    for arch_id in configs.ASSIGNED:
        spec = configs.get(arch_id)
        if spec.family != "lm":
            continue
        cfg = spec.make_model(None)
        f = {c.name: cm.lm_model_flops(cfg, c) for c in spec.cells}
        assert f["train_4k"] > f["prefill_32k"] > f["decode_32k"] > 0
    # recsys: bulk > p99
    for arch_id in ("fm", "dlrm-rm2"):
        spec = configs.get(arch_id)
        cfg = spec.make_model(None)
        f = {c.name: cm.recsys_model_flops(cfg, c) for c in spec.cells}
        assert f["serve_bulk"] > f["serve_p99"] > 0
        assert f["retrieval_cand"] > 0


def test_ann_web1b_index_fits_pod():
    """1B-doc index bytes per device stay under HBM (the sizing claim in
    DESIGN.md §2)."""
    spec = configs.get("ann-web1b")
    cell = spec.cells[0]
    n, dim = cell.get("n_docs"), cell.get("dim")
    per_dev = (n * 2 * dim * 1 + n * dim * 2 + n * 4) / 256  # tf + bf16 vecs + norm
    assert per_dev < 16e9
