"""The unified staged retrieval path: SearchPipeline / AnnIndex / AnnService
serve every encoding through one code path, and indexes persist."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bruteforce, fakewords, kdtree, lexical_lsh
from repro.core import pipeline as pl
from repro.core.index import AnnIndex
from repro.core.types import (
    BruteForceConfig,
    FakeWordsConfig,
    KdTreeConfig,
    LexicalLshConfig,
    SearchParams,
)
from repro.serve.ann_service import AnnService, AnnServiceConfig

ALL_CONFIGS = [
    FakeWordsConfig(quantization=50),
    FakeWordsConfig(quantization=50, scoring="dot"),
    LexicalLshConfig(buckets=64, hashes=2),
    KdTreeConfig(dims=8, backend="scan"),
    BruteForceConfig(),
]


def _ids(name):
    if isinstance(name, FakeWordsConfig):
        return f"fakewords-{name.scoring}"
    return type(name).__name__


# -- service == facade over every encoding -----------------------------------


@pytest.mark.parametrize("cfg", ALL_CONFIGS, ids=_ids)
def test_ann_service_matches_ann_index(small_corpus, cfg):
    """The serving layer must return exactly what the AnnIndex facade
    returns for ANY encoding — one retrieval architecture, no per-method
    serving branches."""
    v = jnp.asarray(small_corpus)
    qs = small_corpus[:24]
    ann = AnnIndex.build(v, cfg)
    s_direct, i_direct = ann.search(
        jnp.asarray(qs), k=10, depth=100, rerank=True, use_kernel=False)
    svc = AnnService(ann, AnnServiceConfig(
        k=10, depth=100, rerank=True, max_batch=8, use_kernel=False))
    s_srv, i_srv = svc.search_batch(qs)
    np.testing.assert_array_equal(np.asarray(i_direct), i_srv)
    np.testing.assert_array_equal(np.asarray(s_direct), s_srv)
    stats = svc.stats()
    assert stats["queries"] == 24 and stats["method"] == ann.method


def test_ann_service_raw_index_back_compat(small_corpus):
    """AnnService(raw_index, method_config, service_config) still works."""
    v = jnp.asarray(small_corpus)
    cfg = FakeWordsConfig(quantization=50)
    idx = fakewords.build(v, cfg)
    svc = AnnService(idx, cfg, AnnServiceConfig(k=5, depth=50, max_batch=16))
    s, ids = svc.search_batch(small_corpus[:16])
    assert ids.shape == (16, 5)


def test_ann_service_inherits_index_level_knobs(small_corpus):
    """Regression: an AnnIndex carrying its own blockmax/use_kernel knobs
    (e.g. loaded from disk) must serve with them even when the service
    config leaves them unset — this used to crash with min(None, int)."""
    v = jnp.asarray(small_corpus[:512])
    ann = AnnIndex.build(
        v, FakeWordsConfig(quantization=40),
        blockmax_keep=4, blockmax_block_size=64, use_kernel=False)
    svc = AnnService(ann, AnnServiceConfig(k=10, depth=50, rerank=False, max_batch=8))
    s_srv, i_srv = svc.search_batch(small_corpus[:8])
    assert svc._bm is ann.bm  # reuses the index's structure, no rebuild
    s_d, i_d = ann.search(jnp.asarray(small_corpus[:8]), k=10, depth=50)
    np.testing.assert_array_equal(np.asarray(i_d), i_srv)
    # the service config still wins when it sets its own knobs
    svc2 = AnnService(ann, AnnServiceConfig(
        k=10, depth=50, rerank=False, max_batch=8,
        blockmax_keep=2, blockmax_block_size=128))
    assert svc2._bm.block_size == 128 and svc2._bm_keep == 2
    svc2.search_batch(small_corpus[:8])


def test_ann_service_latency_stats(small_corpus):
    v = jnp.asarray(small_corpus)
    svc = AnnService(
        AnnIndex.build(v, FakeWordsConfig(quantization=50)),
        AnnServiceConfig(k=10, depth=50, max_batch=8, latency_window=4),
    )
    assert svc.stats()["lat_p50_ms"] is None  # nothing served yet
    svc.search_batch(small_corpus[:48])  # 6 batches through a window of 4
    stats = svc.stats()
    assert stats["batches"] == 6
    assert len(svc._lat_s) == 4  # ring buffer, not unbounded
    assert stats["lat_p50_ms"] > 0 and stats["lat_p99_ms"] >= stats["lat_p50_ms"]
    svc.reset_latency()  # warmup exclusion hook: drops latencies, not counts
    assert svc.stats()["lat_p50_ms"] is None and svc.stats()["batches"] == 6


# -- persistence -------------------------------------------------------------


@pytest.mark.parametrize("cfg", ALL_CONFIGS, ids=_ids)
def test_save_load_search_bit_for_bit(small_corpus, cfg, tmp_path):
    """A save->load round trip must preserve search output exactly for
    every index type (scores AND ids, rerank on and off)."""
    v = jnp.asarray(small_corpus)
    qs = jnp.asarray(small_corpus[:16])
    ann = AnnIndex.build(v, cfg)
    path = os.path.join(tmp_path, "idx.ann")
    ann.save(path)
    loaded = AnnIndex.load(path)
    assert loaded.method == ann.method
    assert loaded.config == ann.config
    for params in (SearchParams(k=10, depth=100),
                   SearchParams(k=10, depth=100, rerank=True)):
        s0, i0 = ann.search(qs, params=params, use_kernel=False)
        s1, i1 = loaded.search(qs, params=params, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_save_load_kdtree_ppa_and_tree_backend(small_corpus, tmp_path):
    """The nested PPA->PCA->PPA reduction model and the tree-backend arrays
    survive the round trip."""
    v = jnp.asarray(small_corpus[:512])
    cfg = KdTreeConfig(dims=8, backend="tree", reduction="ppa-pca-ppa")
    ann = AnnIndex.build(v, cfg)
    path = os.path.join(tmp_path, "kd.ann")
    ann.save(path)
    loaded = AnnIndex.load(path)
    qs = jnp.asarray(small_corpus[:8])
    s0, i0 = ann.search(qs, k=5, depth=20)
    s1, i1 = loaded.search(qs, k=5, depth=20)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_save_load_preserves_blockmax_knobs(small_corpus, tmp_path):
    """Serving knobs (blockmax_keep / block size / use_kernel) persist and
    the blockmax structure is rebuilt identically on load."""
    v = jnp.asarray(small_corpus[:512])
    ann = AnnIndex.build(
        v, FakeWordsConfig(quantization=40),
        blockmax_keep=4, blockmax_block_size=64, use_kernel=False)
    path = os.path.join(tmp_path, "bm.ann")
    ann.save(path)
    loaded = AnnIndex.load(path)
    assert loaded.blockmax_keep == 4 and loaded.blockmax_block_size == 64
    assert loaded.use_kernel is False
    assert loaded.bm is not None and loaded.bm.num_blocks == ann.bm.num_blocks
    qs = jnp.asarray(small_corpus[:8])
    s0, i0 = ann.search(qs, k=10, depth=50)
    s1, i1 = loaded.search(qs, k=10, depth=50)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    # and the knobs can be overridden at load time
    dense = AnnIndex.load(path, blockmax_keep=None)
    assert dense.bm is None


# -- pipeline parity with the per-method wrappers ----------------------------


def test_pipeline_matches_method_wrappers(small_corpus):
    """AnnIndex.search (the pipeline) must agree exactly with the thin
    per-method search() wrappers — no scoring drift through the refactor."""
    v = jnp.asarray(small_corpus)
    q = jnp.asarray(small_corpus[:16])
    qn = bruteforce.l2_normalize(q)

    cfg = FakeWordsConfig(quantization=50)
    ann = AnnIndex.build(v, cfg)
    q_tf = fakewords.encode_queries(qn, cfg, normalized=True)
    s_w, i_w = fakewords.search(
        ann.index, q_tf, qn, k=10, depth=100, rerank=True, use_kernel=False)
    s_p, i_p = ann.search(q, k=10, depth=100, rerank=True, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_w), np.asarray(i_p))
    np.testing.assert_array_equal(np.asarray(s_w), np.asarray(s_p))

    lcfg = LexicalLshConfig(buckets=64, hashes=2)
    ann_l = AnnIndex.build(v, lcfg)
    sig_q = lexical_lsh.encode(qn, lcfg)
    s_w, i_w = lexical_lsh.search(
        ann_l.index, sig_q, qn, k=10, depth=100, rerank=True, use_kernel=False)
    s_p, i_p = ann_l.search(q, k=10, depth=100, rerank=True, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_w), np.asarray(i_p))

    kcfg = KdTreeConfig(dims=8, backend="scan")
    ann_k = AnnIndex.build(v, kcfg)
    s_w, i_w = kdtree.search(
        ann_k.index, qn, k=10, depth=100, rerank=True, normalized=True,
        use_kernel=False)
    s_p, i_p = ann_k.search(q, k=10, depth=100, rerank=True, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_w), np.asarray(i_p))


def test_bruteforce_pipeline_is_exact(small_corpus):
    v = jnp.asarray(small_corpus)
    q = jnp.asarray(small_corpus[:16])
    ann = AnnIndex.build(v, BruteForceConfig())
    s_p, i_p = ann.search(q, k=10, depth=10, use_kernel=False)
    s_e, i_e = bruteforce.exact_topk(v, q, 10, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_p), np.asarray(i_e))


def test_blockmax_through_facade_matches_pruned_search(small_corpus):
    v = jnp.asarray(small_corpus[:512])
    cfg = FakeWordsConfig(quantization=40)
    ann = AnnIndex.build(v, cfg, blockmax_keep=4, blockmax_block_size=64)
    from repro.core import blockmax

    qn = bruteforce.l2_normalize(jnp.asarray(small_corpus[:8]))
    q_tf = fakewords.encode_queries(qn, cfg, normalized=True)
    s_ref, i_ref = blockmax.pruned_search(
        ann.index, ann.bm, q_tf, n_keep=4, depth=50, use_kernel=False)
    s_p, i_p = ann.search(
        jnp.asarray(small_corpus[:8]), k=50, depth=50, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_p))


def test_pipeline_stages_are_static_hashable():
    """Stages and pipelines are frozen/hashable: valid jit static args."""
    p1 = pl.build_pipeline(FakeWordsConfig(quantization=50))
    p2 = pl.build_pipeline(FakeWordsConfig(quantization=50))
    assert p1 == p2 and hash(p1) == hash(p2)
    assert pl.make_matcher(LexicalLshConfig()) == pl.LshMatcher()
