"""The quantized read path (docs/DESIGN.md §12): int8/int4 primary postings
with dequant fused into the score stage.

Covers kernel==XLA bit-parity per encoding, the int4 per-element dequant
error bound (hypothesis + deterministic fallback), recall@10 within 0.02 of
fp32 through the served read path (kernel AND XLA), segmented-vs-monolithic
bitwise parity for quantized stores (the PR's IndexWriter fix), blockmax
beta=1.0 pruned-vs-full parity on dequantized bounds, save/load
round-trips, the memory-budget planner, and sharded int4 parity (8 fake
host devices, subprocess — same pattern as tests/test_distributed.py).
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bruteforce, builder, eval as ev
from repro.core import memory_budget as mb
from repro.core.index import AnnIndex
from repro.core.segments import IndexWriter, SegmentedAnnIndex
from repro.core.types import (
    BruteForceConfig,
    FakeWordsConfig,
    KdTreeConfig,
    LexicalLshConfig,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# Encodings with a quantized primary-postings store.  LSH/kd-tree have
# none (signature/reduced-point stores) and must refuse loudly.
QUANT_CONFIGS = [
    FakeWordsConfig(quantization=50),
    FakeWordsConfig(quantization=50, scoring="dot"),
    BruteForceConfig(),
]


def _ids(cfg):
    if isinstance(cfg, FakeWordsConfig):
        return f"fakewords-{cfg.scoring}"
    return type(cfg).__name__


def run_subprocess(body: str):
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro import compat
        """
    ) + textwrap.dedent(body)
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=900,
        env=dict(os.environ, PYTHONPATH=SRC),
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


# -- fused kernel == XLA reference, per encoding x bit width -----------------


@pytest.mark.parametrize("pp", ["int8", "int4"])
@pytest.mark.parametrize("cfg", QUANT_CONFIGS, ids=_ids)
def test_quantized_kernel_matches_xla(small_corpus, cfg, pp):
    """The Pallas fused-dequant score stage (interpret mode on CPU) must
    return the exact ids and allclose scores of the XLA reference."""
    v = jnp.asarray(small_corpus[:512])
    q = jnp.asarray(small_corpus[:8])
    ann = AnnIndex.build(v, cfg, rerank_store="none", primary_postings=pp)
    assert ann.index.pq is not None or (
        isinstance(cfg, FakeWordsConfig) and cfg.scoring == "dot"
        and pp == "int8"  # dot-int8 IS the native int8 tf: no pq leaf
    )
    s_k, i_k = ann.search(q, k=10, depth=50, use_kernel=True)
    s_x, i_x = ann.search(q, k=10, depth=50, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_x))
    np.testing.assert_allclose(
        np.asarray(s_k), np.asarray(s_x), rtol=1e-5, atol=1e-5
    )


def test_unquantizable_encodings_refuse():
    v = jnp.asarray(np.random.default_rng(13).normal(size=(64, 32)).astype(np.float32))
    for cfg in (LexicalLshConfig(buckets=64, hashes=2),
                KdTreeConfig(dims=8, backend="scan")):
        with pytest.raises((ValueError, NotImplementedError)):
            AnnIndex.build(v, cfg, primary_postings="int8")


# -- int4 per-element dequant error bound ------------------------------------


def _check_int4_error_bound(n, t, group, seed):
    rng = np.random.default_rng(seed)
    m = rng.normal(size=(n, t)).astype(np.float32) * rng.uniform(
        0.01, 10.0, size=(n, 1)
    ).astype(np.float32)
    pq = builder.quantize_postings(jnp.asarray(m), bits=4, group=group)
    deq = np.asarray(builder.dequantize_postings(pq, jnp.float32))
    # Per-element |v - deq| <= group_scale/2: round-to-nearest with step
    # ``scale`` over a range the scale covers by construction.
    tg = ((t + group - 1) // group) * group
    scales = np.asarray(pq.scale)  # (n, tg/group)
    per_col = np.repeat(scales, group, axis=1)[:, :t]
    err = np.abs(m - deq)
    assert (err <= per_col / 2 + 1e-6).all(), float((err - per_col / 2).max())


def test_int4_dequant_error_bound_deterministic():
    for seed in range(8):
        _check_int4_error_bound(4 + 3 * seed, 5 + 11 * seed, 32 if seed % 2 else 64, seed)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        st.integers(2, 24), st.integers(2, 90),
        st.sampled_from([32, 64]), st.integers(0, 2**31 - 1),
    )
    def test_int4_dequant_error_bounded_by_half_group_scale(n, t, group, seed):
        _check_int4_error_bound(n, t, group, seed)
except ImportError:  # pragma: no cover - hypothesis is a dev dependency
    pass


# -- recall@10 within 0.02 of fp32 through the served read path --------------


@pytest.mark.parametrize("use_kernel", [False, True], ids=["xla", "kernel"])
@pytest.mark.parametrize("cfg", QUANT_CONFIGS, ids=_ids)
def test_quantized_recall_within_002_of_fp32(cfg, use_kernel):
    """int8/int4 postings with the frontier-paired int8 rerank must stay
    within 0.02 recall@10 of the fp32 postings serving the same rerank
    (the store the memory-budget planner actually pairs them with).  Data
    is drawn in-test: the shared ``rng`` fixture is stateful across the
    suite and a recall property this tight must not move with test order."""
    rng = np.random.default_rng(7)
    corpus = rng.normal(size=(1024, 64)).astype(np.float32)
    corpus += 0.5 * rng.normal(size=(1, 64)).astype(np.float32)
    v = jnp.asarray(corpus)
    q = jnp.asarray(corpus[:32] + 0.01 * rng.normal(size=(32, 64))
                    .astype(np.float32))
    _, gt = bruteforce.exact_topk(v, q, 10, use_kernel=False)
    recalls = {}
    for pp in ("fp32", "int8", "int4"):
        ann = AnnIndex.build(v, cfg, rerank_store="int8", primary_postings=pp)
        _, ids = ann.search(q, k=10, depth=150, rerank=True,
                            use_kernel=use_kernel)
        recalls[pp] = float(ev.recall_at(gt, ids))
    assert recalls["fp32"] - recalls["int8"] <= 0.02, recalls
    assert recalls["fp32"] - recalls["int4"] <= 0.02, recalls


# -- segmented quantized builds: bitwise == monolithic (IndexWriter fix) -----


@pytest.mark.parametrize(
    "cfg,pp",
    [
        (FakeWordsConfig(quantization=50), "int8"),
        (FakeWordsConfig(quantization=50), "int4"),
        (FakeWordsConfig(quantization=50, scoring="dot"), "int4"),
        (BruteForceConfig(), "int8"),
    ],
    ids=["classic-int8", "classic-int4", "dot-int4", "bruteforce-int8"],
)
def test_segmented_quantized_bitwise_equals_monolithic(small_corpus, cfg, pp, tmp_path):
    """A flushed + merged segmented index with the int8 rerank store and
    quantized postings must search bitwise-identically to a monolithic
    build of the same rows — the writer's store choice now plumbs through
    to the BuildPipeline and merges rebuild from the source sidecar."""
    v = small_corpus[:240]
    q = jnp.asarray(small_corpus[:7])
    mono = AnnIndex.build(jnp.asarray(v), cfg, rerank_store="int8",
                          primary_postings=pp)
    w = IndexWriter(cfg, rerank_store="int8", primary_postings=pp)
    w.add(v[:100])
    w.flush()
    w.add(v[100:])
    w.flush()
    w._merge_range(0, 2)
    reader = w.refresh()
    s_m, i_m = mono.search(q, k=10, depth=60, rerank=True)
    s_r, i_r = reader.search(q, k=10, depth=60, rerank=True)
    np.testing.assert_array_equal(np.asarray(i_m), np.asarray(i_r))
    np.testing.assert_array_equal(np.asarray(s_m), np.asarray(s_r))
    # Commit persists the source sidecar (vectors were dropped); reload
    # serves identically and the reopened writer can keep merging.
    path = str(tmp_path / "idx")
    w.path = path
    w.commit()
    assert os.path.exists(os.path.join(path, w._segments[0].name, "source.npz"))
    r2 = SegmentedAnnIndex.load(path)
    s_2, i_2 = r2.search(q, k=10, depth=60, rerank=True)
    np.testing.assert_array_equal(np.asarray(i_m), np.asarray(i_2))
    np.testing.assert_array_equal(np.asarray(s_m), np.asarray(s_2))
    w2 = IndexWriter.open(path)
    assert w2.rerank_store == "int8" and w2.primary_postings == pp


def test_writer_rejects_unknown_rerank_store():
    with pytest.raises(ValueError):
        IndexWriter(FakeWordsConfig(quantization=50), rerank_store="fp16")


# -- blockmax on dequantized bounds: beta=1.0 parity (satellite 6) -----------


@pytest.mark.parametrize("use_kernel", [False, True], ids=["xla", "kernel"])
@pytest.mark.parametrize("pp", ["int8", "int4"])
@pytest.mark.parametrize("scoring", ["classic", "dot"])
def test_blockmax_quantized_beta1_parity(small_corpus, scoring, pp, use_kernel):
    """Keeping every block must reproduce the dense quantized search
    exactly: the block upper bounds are maxima over DEQUANTIZED values, so
    no true candidate can be pruned at beta=1.0."""
    from repro.core import blockmax

    cfg = FakeWordsConfig(quantization=50, scoring=scoring)
    v = jnp.asarray(small_corpus[:512])
    q = jnp.asarray(small_corpus[:6])
    ann = AnnIndex.build(v, cfg, rerank_store="none", primary_postings=pp)
    bm = blockmax.build_blockmax(ann.index, block_size=64)
    if ann.index.pq is not None:
        # Dequantized f32 bounds; dot-int8 has no pq leaf (native int8 tf)
        # and keeps the exact integer bound path.
        assert jnp.issubdtype(bm.ub.dtype, jnp.floating)
    s_full, i_full = ann.search(q, k=10, depth=50, use_kernel=use_kernel)
    q_tf = ann.encode_queries(bruteforce.l2_normalize(q))
    s_pr, i_pr = blockmax.pruned_search(
        ann.index, bm, q_tf, n_keep=bm.ub.shape[0], depth=50,
        use_kernel=use_kernel,
    )
    np.testing.assert_array_equal(np.asarray(i_full), np.asarray(i_pr[:, :10]))
    np.testing.assert_allclose(
        np.asarray(s_full), np.asarray(s_pr[:, :10]), rtol=1e-5, atol=1e-5
    )


# -- persistence -------------------------------------------------------------


@pytest.mark.parametrize("pp", ["int8", "int4"])
@pytest.mark.parametrize("cfg", QUANT_CONFIGS, ids=_ids)
def test_quantized_save_load_bit_for_bit(small_corpus, cfg, pp, tmp_path):
    v = jnp.asarray(small_corpus[:256])
    q = jnp.asarray(small_corpus[:5])
    ann = AnnIndex.build(v, cfg, rerank_store="int8", primary_postings=pp)
    ann.save(str(tmp_path / "idx"))
    back = AnnIndex.load(str(tmp_path / "idx"))
    if ann.index.pq is not None:
        np.testing.assert_array_equal(
            np.asarray(ann.index.pq.q), np.asarray(back.index.pq.q))
        np.testing.assert_array_equal(
            np.asarray(ann.index.pq.scale), np.asarray(back.index.pq.scale))
        assert (back.index.pq.bits, back.index.pq.group, back.index.pq.cols) \
            == (ann.index.pq.bits, ann.index.pq.group, ann.index.pq.cols)
    s0, i0 = ann.search(q, k=10, depth=40, rerank=True)
    s1, i1 = back.search(q, k=10, depth=40, rerank=True)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


# -- memory-budget planner ---------------------------------------------------


def test_budget_planner_walks_the_frontier():
    cfg = FakeWordsConfig(quantization=50)
    n, d = 2000, 64
    huge = mb.plan_for_budget(cfg, n, d, 10**12)
    assert (huge["primary_postings"], huge["rerank_store"]) == ("fp32", "exact")
    picks = []
    for budget in (10**12, 900_000, 600_000, 450_000):
        p = mb.plan_for_budget(cfg, n, d, budget)
        assert p["estimated_bytes"] <= budget
        picks.append((p["primary_postings"], p["rerank_store"]))
    # Monotone walk down the frontier as the budget shrinks.
    order = [(e["primary_postings"], e["rerank_store"])
             for e in mb.DEFAULT_FRONTIER]
    assert [order.index(p) for p in picks] == sorted(
        order.index(p) for p in picks)
    with pytest.raises(ValueError):
        mb.plan_for_budget(cfg, n, d, 1000)


def test_budget_planner_pins_caller_knobs():
    cfg = BruteForceConfig()
    p = mb.plan_for_budget(cfg, 1000, 64, 10**12, primary_postings="int4")
    assert p["primary_postings"] == "int4"
    p = mb.plan_for_budget(cfg, 1000, 64, 10**12, rerank_store="none")
    assert p["rerank_store"] == "none"


def test_budget_estimate_matches_actual_store(small_corpus):
    """The analytic per-doc byte formula must track what the builder
    actually materializes (within the replicated-statistics epsilon)."""
    v = jnp.asarray(small_corpus[:512])
    for cfg in QUANT_CONFIGS:
        for pp, rs in (("int8", "none"), ("int4", "int8")):
            ann = AnnIndex.build(v, cfg, rerank_store=rs, primary_postings=pp)
            est = mb.estimate_bytes(cfg, 512, 64, pp, rs)
            actual = ann.nbytes()
            assert est <= actual  # estimate excludes O(T) statistics
            assert actual - est <= 64 * 64 * 8, (cfg, pp, rs, est, actual)


def test_build_with_memory_budget_picks_and_serves(small_corpus):
    cfg = FakeWordsConfig(quantization=50)
    v = jnp.asarray(small_corpus[:1000])
    ann = AnnIndex.build(v, cfg, memory_budget_bytes=300_000)
    assert ann.index.pq is not None  # budget forced a quantized store
    s, i = ann.search(jnp.asarray(small_corpus[:4]), k=10, depth=50)
    assert np.asarray(i).shape == (4, 10)


def test_load_frontier_orders_by_measured_recall(tmp_path):
    import json

    bench = {"quantized_ab": [
        {"postings": "int4", "recall_at_10": 0.99},
        {"postings": "fp32", "recall_at_10": 0.95},
        {"postings": "int8", "recall_at_10": 0.97},
    ]}
    p = tmp_path / "BENCH_6.json"
    p.write_text(json.dumps(bench))
    frontier = mb.load_frontier(str(p))
    assert frontier[0]["primary_postings"] == "int4"
    # every default entry survives (rerank/pruning variants keep analytic order)
    assert len(frontier) == len(mb.DEFAULT_FRONTIER)


# -- sharded int4 parity (multihost-sim job) ---------------------------------


def test_sharded_int4_build_and_search_parity():
    """8 fake host devices: the sharded int4 build must equal the local
    build bit-for-bit (row-local grouped scales shard freely) and the
    sharded search must return the local ids/scores."""
    run_subprocess(
        """
        from repro.core import distributed
        from repro.core.index import AnnIndex
        from repro.core.types import FakeWordsConfig

        rng = np.random.default_rng(13)
        V = rng.normal(size=(512, 64)).astype(np.float32)
        Q = rng.normal(size=(8, 64)).astype(np.float32)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("doc",))
        cfg = FakeWordsConfig(quantization=50)
        local = AnnIndex.build(jnp.asarray(V), cfg, rerank_store="int8",
                               primary_postings="int4")
        idx = distributed.build_sharded(
            mesh, jnp.asarray(V), cfg, ("doc",), rerank_store="int8",
            primary_postings="int4")
        np.testing.assert_array_equal(
            np.asarray(local.index.pq.q), np.asarray(idx.pq.q))
        np.testing.assert_array_equal(
            np.asarray(local.index.pq.scale), np.asarray(idx.pq.scale))
        fn = distributed.make_sharded_search(
            mesh, cfg, ("doc",), k=10, depth=512, rerank=True,
            rerank_store="int8", postings_bits=4)
        from repro.core import bruteforce
        q = bruteforce.l2_normalize(jnp.asarray(Q))
        ann = AnnIndex(config=cfg, index=idx)
        q_rep = ann.pipeline.encoder(idx, q)
        s, i = fn(idx, q_rep, q)
        ls, li = local.search(jnp.asarray(Q), k=10, depth=512, rerank=True)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(li))
        np.testing.assert_allclose(np.asarray(s), np.asarray(ls),
                                   rtol=1e-5, atol=1e-5)
        print("SHARDED-INT4-OK")
        """
    )
