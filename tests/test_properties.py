"""Property-based tests (hypothesis) on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import bruteforce, eval as ev, fakewords
from repro.models import recsys as rec
from repro.models.recsys import TableSpec, criteo_row_counts

SET = dict(max_examples=25, deadline=None)

floats = st.floats(-1e3, 1e3, allow_nan=False, width=32)


@settings(**SET)
@given(
    st.integers(2, 30), st.integers(2, 24),
    st.integers(1, 127), st.integers(0, 2**31 - 1),
)
def test_fakewords_encode_invariants(n, m, q, seed):
    rng = np.random.default_rng(seed)
    v = bruteforce.l2_normalize(jnp.asarray(rng.normal(size=(n, m)).astype(np.float32)))
    tf = fakewords.encode(v, q)
    tf_np = np.asarray(tf, np.int32)
    # 1) non-negative; 2) bounded by Q; 3) sign-split exclusivity
    assert (tf_np >= 0).all()
    assert (tf_np <= q).all()
    assert not ((tf_np[:, :m] > 0) & (tf_np[:, m:] > 0)).any()


@settings(**SET)
@given(st.integers(4, 64), st.integers(2, 16), st.integers(0, 2**31 - 1))
def test_l2_normalize_unit_and_idempotent(n, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)) * 100
    nx = bruteforce.l2_normalize(x)
    norms = np.linalg.norm(np.asarray(nx), axis=-1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(bruteforce.l2_normalize(nx)), np.asarray(nx), atol=1e-6)


@settings(**SET)
@given(st.integers(16, 200), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_tiled_topk_equals_exact(n, b, seed):
    rng = np.random.default_rng(seed)
    corpus = jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(b, 16)).astype(np.float32))
    k = min(10, n)
    s1, i1 = bruteforce.exact_topk(corpus, q, k)
    s2, i2 = bruteforce.exact_topk_tiled(corpus, q, k, tile=32)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-5)
    # ids may differ on exact ties; compare via scores of the ids
    assert float(ev.overlap(i1, i2)) > 0.95


@settings(**SET)
@given(st.integers(1, 20), st.integers(1, 10))
def test_recall_at_bounds(k, extra):
    ids = jnp.arange(k)[None, :]
    assert float(ev.recall_at(ids, ids)) == 1.0
    disjoint = jnp.arange(k, 2 * k)[None, :]
    assert float(ev.recall_at(ids, disjoint)) == 0.0


@settings(**SET)
@given(
    st.integers(2, 8), st.integers(2, 6), st.integers(1, 4),
    st.integers(0, 2**31 - 1),
)
def test_embedding_bag_dense_equals_ragged(b, f, nnz, seed):
    rng = np.random.default_rng(seed)
    table_spec = TableSpec(tuple(int(x) for x in rng.integers(4, 20, f)), 8)
    table = jnp.asarray(rng.normal(size=(table_spec.total_rows, 8)).astype(np.float32))
    local = np.stack(
        [rng.integers(0, c, (b, nnz)) for c in table_spec.row_counts], axis=1
    ).astype(np.int32)
    gidx = table_spec.globalize(jnp.asarray(local))
    dense = rec.embedding_bag_dense(table, gidx)
    vals = gidx.reshape(-1)
    bags = jnp.repeat(jnp.arange(b * f), nnz)
    ragged = rec.embedding_bag_ragged(table, vals, bags, b * f).reshape(b, f, 8)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ragged), rtol=1e-5, atol=1e-5)


@settings(**SET)
@given(st.integers(2, 10), st.integers(2, 8), st.integers(0, 2**31 - 1))
def test_fm_sum_square_trick_equals_pairwise(b, f, seed):
    rng = np.random.default_rng(seed)
    emb = jnp.asarray(rng.normal(size=(b, f, 6)).astype(np.float32))
    fast = rec.fm_interaction(emb)
    e = np.asarray(emb, np.float64)
    slow = np.zeros(b)
    for i in range(f):
        for j in range(i + 1, f):
            slow += (e[:, i] * e[:, j]).sum(-1)
    np.testing.assert_allclose(np.asarray(fast), slow, rtol=1e-4, atol=1e-4)


@settings(**SET)
@given(st.integers(2, 40), st.integers(1000, 10_000_000))
def test_criteo_row_counts_invariants(f, total):
    counts = criteo_row_counts(f, total)
    assert len(counts) == f
    assert all(c >= 4 for c in counts)
    assert sum(counts) % 512 == 0  # mesh divisibility
    assert counts == tuple(sorted(counts, reverse=True))  # power law sorted


@settings(**SET)
@given(st.integers(1, 8), st.integers(10, 60), st.integers(0, 2**31 - 1))
def test_rerank_exact_returns_true_topk_of_candidates(b, d, seed):
    rng = np.random.default_rng(seed)
    vecs = bruteforce.l2_normalize(
        jnp.asarray(rng.normal(size=(100, 8)).astype(np.float32)))
    q = bruteforce.l2_normalize(jnp.asarray(rng.normal(size=(b, 8)).astype(np.float32)))
    cand = jnp.asarray(rng.choice(100, size=(b, d), replace=True).astype(np.int32))
    s, i = bruteforce.rerank_exact(vecs, q, cand, k=5, normalized=True)
    # brute-force over the SAME candidate set
    full = np.einsum("bd,bcd->bc", np.asarray(q), np.asarray(vecs)[np.asarray(cand)])
    best = np.sort(full, axis=-1)[:, ::-1][:, :5]
    np.testing.assert_allclose(np.sort(np.asarray(s))[:, ::-1], best, rtol=1e-4, atol=1e-5)


@settings(**SET)
@given(st.integers(0, 2**31 - 1))
def test_moe_identical_experts_equal_dense_ffn(seed):
    """With every expert holding the SAME weights, routing is irrelevant
    (combine weights renormalize to 1): moe_ffn == the dense SwiGLU FFN.
    Verifies dispatch/combine round-trip exactly."""
    from repro.models import transformer as tfm
    rng = np.random.default_rng(seed)
    d, ff, e = 16, 24, 4
    cfg = tfm.TransformerConfig(
        n_layers=2, d_model=d, n_heads=2, n_kv_heads=2, d_ff=ff, vocab=32,
        moe=tfm.MoEConfig(num_experts=e, top_k=2, d_ff=ff, period=1),
        dtype=jnp.float32,
    )
    x = jnp.asarray(rng.normal(size=(2, 8, d)).astype(np.float32))
    wg = jnp.asarray(rng.normal(size=(d, ff)).astype(np.float32))
    wu = jnp.asarray(rng.normal(size=(d, ff)).astype(np.float32))
    wd = jnp.asarray(rng.normal(size=(ff, d)).astype(np.float32))
    layer = {
        "router": jnp.asarray(rng.normal(size=(d, e)).astype(np.float32)),
        "moe_gate": jnp.broadcast_to(wg, (e, d, ff)),
        "moe_up": jnp.broadcast_to(wu, (e, d, ff)),
        "moe_down": jnp.broadcast_to(wd, (e, ff, d)),
    }
    out = tfm.moe_ffn(x, layer, cfg, dropless=True)
    dense = tfm.swiglu(x, {"w_gate": wg, "w_up": wu, "w_down": wd})
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), rtol=1e-4, atol=1e-4)
