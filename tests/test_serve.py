"""Serving: continuous-batching engine + ANN service."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bruteforce, eval as ev, fakewords
from repro.core.types import FakeWordsConfig
from repro.models import transformer as tfm
from repro.serve.ann_service import AnnService, AnnServiceConfig
from repro.serve.engine import DecodeEngine, EngineConfig, Request

RNG = np.random.default_rng(11)


def _tiny():
    cfg = tfm.TransformerConfig(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab=64)
    return cfg, tfm.init_params(jax.random.key(1), cfg)


def test_engine_matches_greedy_reference():
    cfg, params = _tiny()
    eng = DecodeEngine(params, cfg, EngineConfig(batch_slots=2, max_len=32, eos_id=1))
    prompt = RNG.integers(2, 64, 6).astype(np.int32)
    req = Request(uid=0, prompt=prompt, max_new_tokens=5)
    eng.submit(req)
    eng.run(max_steps=30)
    cur = list(prompt)
    ref = []
    for _ in range(5):
        _, lg = tfm.prefill(params, jnp.asarray(cur, jnp.int32)[None], cfg)
        nxt = int(jnp.argmax(lg[0]))
        ref.append(nxt)
        if nxt == 1:
            break
        cur.append(nxt)
    assert req.out_tokens[: len(ref)] == ref


def test_engine_continuous_batching_slot_reuse():
    cfg, params = _tiny()
    eng = DecodeEngine(params, cfg, EngineConfig(batch_slots=2, max_len=64, eos_id=0))
    reqs = [Request(uid=i, prompt=RNG.integers(2, 64, 4).astype(np.int32),
                    max_new_tokens=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=60)
    assert all(r.done for r in reqs)          # all 5 served through 2 slots
    assert all(len(r.out_tokens) <= 3 for r in reqs)


def test_engine_isolation_between_concurrent_requests():
    """A request's output must not depend on what shares the batch."""
    cfg, params = _tiny()
    prompt = RNG.integers(2, 64, 6).astype(np.int32)
    # alone
    e1 = DecodeEngine(params, cfg, EngineConfig(batch_slots=2, max_len=32, eos_id=1))
    r_alone = Request(uid=0, prompt=prompt, max_new_tokens=4)
    e1.submit(r_alone)
    e1.run(max_steps=30)
    # with a neighbor
    e2 = DecodeEngine(params, cfg, EngineConfig(batch_slots=2, max_len=32, eos_id=1))
    r_shared = Request(uid=0, prompt=prompt, max_new_tokens=4)
    other = Request(uid=1, prompt=RNG.integers(2, 64, 9).astype(np.int32), max_new_tokens=4)
    e2.submit(r_shared)
    e2.submit(other)
    e2.run(max_steps=30)
    assert r_alone.out_tokens == r_shared.out_tokens


def test_engine_second_run_and_direct_step_drain():
    """Regression: run() compared the CUMULATIVE step counter against
    max_steps, so a second run() with work queued returned immediately; and
    requests retired via direct step() calls leaked (or double-returned) on
    the next run()."""
    cfg, params = _tiny()
    eng = DecodeEngine(params, cfg, EngineConfig(batch_slots=2, max_len=64, eos_id=0))
    r1 = Request(uid=0, prompt=RNG.integers(2, 64, 4).astype(np.int32),
                 max_new_tokens=3)
    eng.submit(r1)
    done1 = eng.run(max_steps=10)
    assert r1 in done1 and r1.done
    # retire a request via direct step() calls: run() must hand it back
    # exactly once, not leak it
    r2 = Request(uid=1, prompt=RNG.integers(2, 64, 4).astype(np.int32),
                 max_new_tokens=2)
    eng.submit(r2)
    while not r2.done:
        eng.step()
    done2 = eng.run(max_steps=10)
    assert done2 == [r2]
    # later run with the CUMULATIVE counter far past max_steps: must still
    # make progress (the bound applies to steps taken within the call)
    eng.steps = 10_000  # long-lived engine
    r3 = Request(uid=2, prompt=RNG.integers(2, 64, 4).astype(np.int32),
                 max_new_tokens=3)
    eng.submit(r3)
    done3 = eng.run(max_steps=10)
    assert r3 in done3 and r3.done
    assert eng.run(max_steps=10) == []  # drained: nothing to return


def test_ann_service_recall_and_batching(small_corpus):
    v = jnp.asarray(small_corpus)
    cfg = FakeWordsConfig(quantization=50)
    idx = fakewords.build(v, cfg)
    svc = AnnService(idx, cfg, AnnServiceConfig(k=10, depth=100, rerank=True, max_batch=16))
    qs = small_corpus[:40]  # not a multiple of max_batch: exercises padding
    s, ids = svc.search_batch(qs)
    assert ids.shape == (40, 10)
    gt_s, gt_i = bruteforce.exact_topk(v, jnp.asarray(qs), 10)
    assert float(ev.recall_at(jnp.asarray(np.asarray(gt_i)), jnp.asarray(ids))) > 0.85
    assert svc.stats()["queries"] == 40


def test_ann_service_blockmax_pruned(small_corpus):
    """Blockmax-pruned serving: keeping half the blocks preserves most
    recall; keeping all blocks matches the unpruned service results."""
    v = jnp.asarray(small_corpus)
    cfg = FakeWordsConfig(quantization=50)
    idx = fakewords.build(v, cfg)
    qs = small_corpus[:24]
    gt_s, gt_i = bruteforce.exact_topk(v, jnp.asarray(qs), 10)
    n_blocks = -(-v.shape[0] // 256)
    svc_all = AnnService(idx, cfg, AnnServiceConfig(
        k=10, depth=100, rerank=True, max_batch=16, blockmax_keep=n_blocks))
    _, ids_all = svc_all.search_batch(qs)
    svc_half = AnnService(idx, cfg, AnnServiceConfig(
        k=10, depth=100, rerank=True, max_batch=16,
        blockmax_keep=max(1, n_blocks // 2)))
    _, ids_half = svc_half.search_batch(qs)
    r_all = float(ev.recall_at(jnp.asarray(np.asarray(gt_i)), jnp.asarray(ids_all)))
    r_half = float(ev.recall_at(jnp.asarray(np.asarray(gt_i)), jnp.asarray(ids_half)))
    assert r_all > 0.85
    assert r_half > 0.3  # graceful degradation at beta=0.5
    assert r_all >= r_half


# -- async micro-batching loop (docs/DESIGN.md §14) --------------------------


def test_ann_service_async_matches_sync(small_corpus):
    """search_async results == search_batch results, request-for-request,
    and the micro-batcher coalesces singles into fewer launches."""
    v = jnp.asarray(small_corpus)
    cfg = FakeWordsConfig(quantization=50)
    idx = fakewords.build(v, cfg)
    svc = AnnService(idx, cfg, AnnServiceConfig(
        k=10, depth=100, rerank=True, max_batch=16, max_wait_s=0.05))
    qs = small_corpus[:24]
    s_ref, i_ref = svc.search_batch(qs)
    svc.start_async()
    futs = [svc.search_async(qs[i]) for i in range(24)]
    out = [f.result(timeout=30) for f in futs]
    svc.stop_async()
    s_async = np.concatenate([o[0] for o in out])
    i_async = np.concatenate([o[1] for o in out])
    np.testing.assert_array_equal(i_ref, i_async)
    np.testing.assert_allclose(s_ref, s_async, rtol=1e-5, atol=1e-6)
    st = svc.stats()
    # 24 singles coalesced under the 50ms window: strictly fewer launches
    # than requests, and per-request latency percentiles are recorded.
    assert 1 <= st["async_launches"] < 24
    assert st["req_p50_ms"] is not None and st["req_p99_ms"] is not None
    assert st["req_p99_ms"] >= st["req_p50_ms"]
    assert st["rejected"] == 0


def test_ann_service_async_backpressure(small_corpus):
    """A full admission queue rejects at the door (queue.Full) and counts
    the shed requests in stats()."""
    import queue as queue_mod

    v = jnp.asarray(small_corpus)
    cfg = FakeWordsConfig(quantization=50)
    idx = fakewords.build(v, cfg)
    svc = AnnService(idx, cfg, AnnServiceConfig(
        k=5, depth=50, rerank=False, max_batch=1, max_wait_s=0.0,
        queue_depth=2))
    svc.start_async()
    rejected = 0
    futs = []
    with svc._lock:  # worker blocks on the service lock: queue backs up
        for i in range(32):
            try:
                futs.append(svc.search_async(small_corpus[i % 8]))
            except queue_mod.Full:
                rejected += 1
    assert rejected >= 1
    for f in futs:
        f.result(timeout=30)
    svc.stop_async()
    assert svc.stats()["rejected"] == rejected


def test_ann_service_async_with_nrt_refresh(small_corpus):
    """refresh() (a _bind swap) interleaves safely with the async worker;
    results always come from a coherent snapshot."""
    from repro.core.segments import IndexWriter

    cfg = FakeWordsConfig(quantization=50)
    w = IndexWriter(cfg, merge_policy=None, use_kernel=False)
    w.add(small_corpus[:500])
    svc = AnnService(writer=w, service=AnnServiceConfig(
        k=5, depth=50, rerank=False, max_batch=8, max_wait_s=0.005))
    svc.start_async()
    futs = [svc.search_async(small_corpus[i]) for i in range(8)]
    w.add(small_corpus[500:600])
    svc.refresh()
    futs += [svc.search_async(small_corpus[i]) for i in range(8, 16)]
    for f in futs:
        s, ids = f.result(timeout=30)
        assert ids.shape == (1, 5) and (ids >= 0).all()
    svc.stop_async()


def test_ann_service_segmented_blockmax(small_corpus):
    """Segmented blockmax serving rides the packed superbuffer: keeping
    every block matches the unpruned segmented service exactly."""
    from repro.core.segments import IndexWriter

    cfg = FakeWordsConfig(quantization=50)
    w = IndexWriter(cfg, merge_policy=None, use_kernel=False)
    w.add(small_corpus[:700])
    w.flush()
    w.add(small_corpus[700:1100])
    qs = small_corpus[:16]
    svc = AnnService(writer=w, service=AnnServiceConfig(
        k=10, depth=100, rerank=True, max_batch=16))
    s0, i0 = svc.search_batch(qs)
    reader = svc.ann
    n_blocks = reader.packed_segments().bucket // 256
    svc_bm = AnnService(reader, service=AnnServiceConfig(
        k=10, depth=100, rerank=True, max_batch=16,
        blockmax_keep=n_blocks, blockmax_block_size=256))
    s1, i1 = svc_bm.search_batch(qs)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_allclose(s0, s1, rtol=1e-5, atol=1e-6)


def test_ann_service_stats_mutations_hold_lock(small_corpus):
    """Regression (reprolint rule ``lockdiscipline``): the worker thread
    bumped ``async_launches`` and appended request latencies off-lock, and
    ``rejected`` / ``reset_latency`` mutated shared stats from caller
    threads off-lock.  Instrument the lock and the mutation points, then
    drive every path: any off-lock mutation is recorded as a violation."""
    import collections
    import queue as queue_mod
    import threading

    violations = []

    class CheckedLock:
        """RLock wrapper that knows whether the current thread holds it."""

        def __init__(self):
            self._lock = threading.RLock()
            self._local = threading.local()

        def __enter__(self):
            self._lock.acquire()
            self._local.depth = getattr(self._local, "depth", 0) + 1
            return self

        def __exit__(self, *exc):
            self._local.depth -= 1
            self._lock.release()

        @property
        def held(self):
            return getattr(self._local, "depth", 0) > 0

    class GuardedDeque(collections.deque):
        def __init__(self, name, lock, maxlen=None):
            super().__init__(maxlen=maxlen)
            self._name = name
            self._guard = lock

        def append(self, x):
            if not self._guard.held:
                violations.append(f"{self._name}.append")
            super().append(x)

        def clear(self):
            if not self._guard.held:
                violations.append(f"{self._name}.clear")
            super().clear()

    guarded_ints = {"async_launches", "rejected", "batches",
                    "queries_served"}

    class GuardedService(AnnService):
        def __setattr__(self, name, value):
            if name in guarded_ints and getattr(self, "_armed", False) \
                    and not self._lock.held:
                violations.append(name)
            object.__setattr__(self, name, value)

    v = jnp.asarray(small_corpus[:400])
    cfg = FakeWordsConfig(quantization=50)
    idx = fakewords.build(v, cfg)
    svc = GuardedService(idx, cfg, AnnServiceConfig(
        k=5, depth=50, rerank=False, max_batch=4, max_wait_s=0.005,
        queue_depth=8))
    lock = CheckedLock()
    svc._lock = lock
    svc._lat_s = GuardedDeque("_lat_s", lock)
    svc._req_lat_s = GuardedDeque("_req_lat_s", lock)
    svc._armed = True

    svc.search_batch(small_corpus[:8])           # sync path
    svc.start_async()
    futs = [svc.search_async(small_corpus[i]) for i in range(4)]
    for f in futs:
        f.result(timeout=30)                     # worker path
    with svc._lock:                              # back the queue up
        rejected = 0
        for i in range(32):
            try:
                svc.search_async(small_corpus[i % 8])
            except queue_mod.Full:
                rejected += 1                    # rejection path
    svc.stop_async()
    svc.reset_latency()                          # ring-clear path
    assert rejected >= 1
    assert svc.stats()["rejected"] == rejected
    assert violations == []
