"""The staged BuildPipeline (core/builder.py, docs/DESIGN.md §8): build
parity (local == sharded, wrapper == pipeline), the int8 QuantizedStore
rerank path, and the AnnService result cache.

Sharded scenarios run in subprocesses with 8 fake host devices (same
pattern as tests/test_distributed.py) so this process's single-device jax
init stays clean.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bruteforce, builder, eval as ev, fakewords
from repro.core import pipeline as pl
from repro.core.index import AnnIndex
from repro.core.types import (
    BruteForceConfig,
    FakeWordsConfig,
    KdTreeConfig,
    LexicalLshConfig,
)
from repro.serve.ann_service import AnnService, AnnServiceConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

ALL_CONFIGS = [
    FakeWordsConfig(quantization=50),
    FakeWordsConfig(quantization=50, scoring="dot"),
    LexicalLshConfig(buckets=64, hashes=2),
    KdTreeConfig(dims=8, backend="scan"),
    BruteForceConfig(),
]


def _ids(cfg):
    if isinstance(cfg, FakeWordsConfig):
        return f"fakewords-{cfg.scoring}"
    return type(cfg).__name__


def run_subprocess(body: str):
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro import compat
        """
    ) + textwrap.dedent(body)
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=900,
        env=dict(os.environ, PYTHONPATH=SRC),
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


# -- local BuildPipeline == the thin per-method wrappers ---------------------


@pytest.mark.parametrize("cfg", ALL_CONFIGS, ids=_ids)
def test_build_pipeline_matches_wrappers_bit_for_bit(small_corpus, cfg):
    """make_build_pipeline(cfg).build_local must equal AnnIndex.build's
    index leaf-for-leaf (the wrappers ARE the pipeline)."""
    v = jnp.asarray(small_corpus[:512])
    a = builder.make_build_pipeline(cfg).build_local(v)
    b = AnnIndex.build(v, cfg).index
    import dataclasses

    for f in dataclasses.fields(a):
        x, y = getattr(a, f.name), getattr(b, f.name)
        if f.name == "reduction" or x is None:
            assert (x is None) == (y is None)
            continue
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=f.name)


def test_build_pipeline_stages_are_static_hashable():
    p1 = builder.make_build_pipeline(FakeWordsConfig(quantization=50))
    p2 = builder.make_build_pipeline(FakeWordsConfig(quantization=50))
    assert p1 == p2 and hash(p1) == hash(p2)
    assert builder.make_build_pipeline(LexicalLshConfig()).postings == builder.LshPostings()


def test_rerank_store_selection(small_corpus):
    v = jnp.asarray(small_corpus[:256])
    cfg = FakeWordsConfig(quantization=50)
    exact = AnnIndex.build(v, cfg, rerank_store="exact").index
    assert exact.vectors is not None and exact.vq is None
    q8 = AnnIndex.build(v, cfg, rerank_store="int8").index
    assert q8.vectors is None and q8.vq is not None
    assert q8.vq.q.dtype == jnp.int8 and q8.vq.scale.shape == (256,)
    none = AnnIndex.build(v, cfg, rerank_store="none").index
    assert none.vectors is None and none.vq is None
    # brute force keeps the fp32 match operand regardless of the store
    bf = AnnIndex.build(v, BruteForceConfig(), rerank_store="int8").index
    assert bf.vectors is not None and bf.vq is not None
    with pytest.raises(ValueError):
        builder.make_build_pipeline(cfg, "fp7")


# -- sharded build == local build (the acceptance bar) -----------------------


def test_sharded_build_parity_all_encodings():
    """For every encoding + bruteforce: the mesh-sharded BuildPipeline build
    equals the single-host build — bit-for-bit leaves for the row-local
    encodings, identical top-k ids (lowest-doc-id ties) and fp-tolerant
    scores through the SAME sharded search for the kd-tree (whose reduction
    is eigendecomposed from psum'd moments)."""
    run_subprocess("""
    from repro.core import bruteforce, distributed
    from repro.core.index import AnnIndex
    from repro.core.types import (BruteForceConfig, FakeWordsConfig,
                                  KdTreeConfig, LexicalLshConfig)
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(1024, 32)).astype(np.float32))
    qs = vecs[:8]
    qn = bruteforce.l2_normalize(qs)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    axes = ("data", "model")
    for cfg in (FakeWordsConfig(quantization=50),
                FakeWordsConfig(quantization=50, scoring="dot"),
                LexicalLshConfig(buckets=64, hashes=2),
                KdTreeConfig(dims=8, backend="scan"),
                KdTreeConfig(dims=8, backend="scan", reduction="ppa-pca-ppa"),
                BruteForceConfig()):
        local = AnnIndex.build(vecs, cfg)
        sh = distributed.build_sharded(mesh, vecs, cfg, axes)
        exact = not isinstance(cfg, KdTreeConfig)
        for f in dataclasses.fields(local.index):
            x, y = getattr(local.index, f.name), getattr(sh, f.name)
            if f.name == "reduction" or x is None:
                continue
            if exact:
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y), err_msg=f.name)
            elif f.name in ("reduced", "lifted", "vectors"):
                a_np, b_np = np.asarray(x), np.asarray(y)
                if f.name != "vectors":
                    # eigh's per-eigenvector sign is an arbitrary convention;
                    # align columns before comparing (L2 geometry invariant).
                    sign = np.sign(np.sum(a_np * b_np, axis=0))
                    sign[sign == 0] = 1.0
                    b_np = b_np * sign
                np.testing.assert_allclose(
                    a_np, b_np, atol=1e-4, err_msg=f.name)
        search = distributed.make_sharded_search(
            mesh, cfg, axes, k=10, depth=50, rerank=True)
        # Encode queries through EACH build's own model: eigh's eigenvector
        # signs are an arbitrary convention, so the sharded reduction may be
        # sign-flipped vs the local one — search results are invariant only
        # when queries project through the same model as the index.
        s_a, i_a = search(sh, AnnIndex(config=cfg, index=sh).encode_queries(qs), qn)
        s_b, i_b = search(
            distributed.shard_index(mesh, local.index, axes),
            local.encode_queries(qs), qn)
        np.testing.assert_array_equal(np.asarray(i_a), np.asarray(i_b))
        np.testing.assert_allclose(
            np.asarray(s_a), np.asarray(s_b), rtol=1e-5, atol=1e-6)
        print("parity ok", type(cfg).__name__, getattr(cfg, "scoring", ""),
              getattr(cfg, "reduction", ""))
    """)


def test_sharded_quantized_rerank_end_to_end():
    """--quantized-rerank's pod path: sharded int8-store build, sharded
    search with the quantized local rerank gather, served through
    AnnService; recall@10 within 0.01 of the fp32-rerank service."""
    run_subprocess("""
    from repro.core import bruteforce, distributed, eval as ev
    from repro.core.index import AnnIndex
    from repro.core.types import FakeWordsConfig
    from repro.serve.ann_service import AnnService, AnnServiceConfig
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(2048, 32)).astype(np.float32))
    qs = np.asarray(vecs[:64]) + 0.01 * rng.normal(size=(64, 32)).astype(np.float32)
    cfg = FakeWordsConfig(quantization=50)
    mesh = jax.make_mesh((8,), ("data",))
    scfg = AnnServiceConfig(k=10, depth=100, rerank=True, max_batch=32)
    _, gt = bruteforce.exact_topk(vecs, jnp.asarray(qs), 10)
    recalls = {}
    for store in ("exact", "int8"):
        ann = AnnIndex.build(vecs, cfg, rerank_store=store,
                             mesh=mesh, shard_axes=("data",))
        assert (ann.index.vq is None) == (store == "exact")
        svc = AnnService(ann, scfg, mesh=mesh, shard_axes=("data",))
        _, ids = svc.search_batch(qs)
        recalls[store] = float(ev.recall_at(gt, jnp.asarray(ids)))
    print("recalls", recalls)
    assert recalls["exact"] > 0.9, recalls
    assert abs(recalls["exact"] - recalls["int8"]) <= 0.01, recalls
    """)


# -- QuantizedStore: quality, persistence, error bound -----------------------


def test_quantized_rerank_recall_within_001_of_fp32(small_corpus):
    """Acceptance: int8 rerank serves end-to-end through AnnService with
    recall@10 within 0.01 of fp32 rerank (single-device path)."""
    v = jnp.asarray(small_corpus)
    qs = small_corpus[:64] + 0.01 * np.random.default_rng(1).normal(
        size=(64, small_corpus.shape[1])).astype(np.float32)
    _, gt = bruteforce.exact_topk(v, jnp.asarray(qs), 10)
    scfg = AnnServiceConfig(k=10, depth=100, rerank=True, max_batch=32,
                            use_kernel=False)
    recalls = {}
    for store in ("exact", "int8"):
        ann = AnnIndex.build(v, FakeWordsConfig(quantization=50),
                             rerank_store=store)
        svc = AnnService(ann, scfg)
        _, ids = svc.search_batch(qs)
        recalls[store] = float(ev.recall_at(gt, jnp.asarray(ids)))
    assert recalls["exact"] > 0.9, recalls
    assert abs(recalls["exact"] - recalls["int8"]) <= 0.01, recalls


@pytest.mark.parametrize("cfg", ALL_CONFIGS, ids=_ids)
def test_quantized_store_save_load_bit_for_bit(small_corpus, cfg, tmp_path):
    """An int8-store index round-trips through save/load: the store, the
    quantized_rerank knob, and the search output all survive exactly."""
    v = jnp.asarray(small_corpus[:512])
    qs = jnp.asarray(small_corpus[:16])
    ann = AnnIndex.build(v, cfg, rerank_store="int8")
    assert ann.quantized_rerank
    assert isinstance(ann.pipeline.reranker, pl.QuantizedCosineReranker)
    path = os.path.join(tmp_path, "q.ann")
    ann.save(path)
    loaded = AnnIndex.load(path)
    assert loaded.quantized_rerank
    np.testing.assert_array_equal(
        np.asarray(loaded.index.vq.q), np.asarray(ann.index.vq.q))
    np.testing.assert_array_equal(
        np.asarray(loaded.index.vq.scale), np.asarray(ann.index.vq.scale))
    s0, i0 = ann.search(qs, k=10, depth=100, rerank=True, use_kernel=False)
    s1, i1 = loaded.search(qs, k=10, depth=100, rerank=True, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def _check_int8_error_bound(n: int, d: int, seed: int) -> None:
    """Per-candidate int8 rerank score error is bounded by the quantization
    step: |q.v_hat - q.v| <= ||q||_1 * scale/2 (+fp slack), with
    v_hat = vq.q * vq.scale and unit-normalized queries."""
    rng = np.random.default_rng(seed)
    v = bruteforce.l2_normalize(
        jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)))
    q = bruteforce.l2_normalize(
        jnp.asarray(rng.normal(size=(4, d)).astype(np.float32)))
    vq = builder.quantize_store(v)
    cand = jnp.tile(jnp.arange(n, dtype=jnp.int32)[None, :], (4, 1))
    s_q = np.asarray(pl.candidate_scores(
        type("I", (), {"vq": vq, "vectors": None})(), q, cand, quantized=True))
    s_f = np.asarray(q @ v.T)
    bound = (
        np.sum(np.abs(np.asarray(q)), axis=1, keepdims=True)
        * np.asarray(vq.scale)[None, :] / 2.0
    )
    assert (np.abs(s_q - s_f) <= bound + 1e-5).all(), (
        np.max(np.abs(s_q - s_f) - bound))


def test_int8_rerank_error_bound_deterministic():
    for seed in range(8):
        _check_int8_error_bound(2 + 5 * seed, 3 + 7 * seed, seed)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 32), st.integers(2, 48), st.integers(0, 2**31 - 1))
    def test_int8_rerank_error_bounded_by_quantization_step(n, d, seed):
        _check_int8_error_bound(n, d, seed)
except ImportError:  # pragma: no cover - hypothesis is a dev dependency
    pass


def test_service_honors_quantized_knob_when_both_stores_present(small_corpus):
    """Brute force keeps fp32 vectors (the match operand) even with the
    int8 store; the service must still rerank through the knob's store and
    agree with the facade exactly."""
    v = jnp.asarray(small_corpus[:256])
    ann = AnnIndex.build(v, BruteForceConfig(), rerank_store="int8",
                         use_kernel=False)
    assert ann.index.vectors is not None and ann.quantized_rerank
    svc = AnnService(ann, AnnServiceConfig(
        k=10, depth=50, rerank=True, max_batch=8, use_kernel=False))
    s_srv, i_srv = svc.search_batch(small_corpus[:8])
    s_d, i_d = ann.search(jnp.asarray(small_corpus[:8]), k=10, depth=50,
                          rerank=True, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_d), i_srv)
    np.testing.assert_array_equal(np.asarray(s_d), s_srv)


def test_quantize_store_reconstruction_is_symmetric(small_corpus):
    v = bruteforce.l2_normalize(jnp.asarray(small_corpus[:128]))
    vq = builder.quantize_store(v)
    v_hat = np.asarray(vq.q, np.float32) * np.asarray(vq.scale)[:, None]
    # per-component reconstruction within half a step; zero maps to zero
    assert (np.abs(v_hat - np.asarray(v)) <= np.asarray(vq.scale)[:, None] / 2 + 1e-6).all()
    z = builder.quantize_store(jnp.zeros((3, 8), jnp.float32))
    assert (np.asarray(z.q) == 0).all()


# -- AnnService result cache -------------------------------------------------


def test_ann_service_result_cache_hits_and_counters(small_corpus):
    v = jnp.asarray(small_corpus[:512])
    ann = AnnIndex.build(v, FakeWordsConfig(quantization=50), use_kernel=False)
    svc = AnnService(ann, AnnServiceConfig(
        k=10, depth=50, rerank=True, max_batch=8, cache_size=4))
    qs = small_corpus[:8]
    s0, i0 = svc.search_batch(qs)
    assert svc.stats()["cache_misses"] == 1 and svc.stats()["cache_hits"] == 0
    s1, i1 = svc.search_batch(qs)  # identical batch -> pure cache hit
    assert svc.stats()["cache_hits"] == 1
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(s0, s1)
    # distinct queries miss; LRU stays bounded at cache_size
    for j in range(6):
        svc.search_batch(small_corpus[8 * (j + 1): 8 * (j + 2)])
    st = svc.stats()
    assert st["cache_misses"] == 7 and st["cache_entries"] <= 4
    # cached results equal uncached results (cache off)
    svc_off = AnnService(ann, AnnServiceConfig(
        k=10, depth=50, rerank=True, max_batch=8))
    s2, i2 = svc_off.search_batch(qs)
    np.testing.assert_array_equal(i1, i2)
    assert svc_off.stats()["cache_entries"] == 0


def test_ann_service_cache_respects_rerank_on_rep_collisions(small_corpus):
    """Two distinct raw queries can share a quantized tf row; with rerank on
    the cache must NOT serve one query's exact scores for the other."""
    v = jnp.asarray(small_corpus[:256])
    ann = AnnIndex.build(v, FakeWordsConfig(quantization=2), use_kernel=False)
    svc = AnnService(ann, AnnServiceConfig(
        k=5, depth=50, rerank=True, max_batch=4, cache_size=8))
    qa = small_corpus[:4]
    qb = qa + 1e-4  # same tf row at Q=2, different exact cosine
    ra = fakewords.encode_queries(jnp.asarray(qa), ann.config)
    rb = fakewords.encode_queries(jnp.asarray(qb), ann.config)
    np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))
    s_a, _ = svc.search_batch(qa)
    s_b, _ = svc.search_batch(qb)
    assert svc.stats()["cache_hits"] == 0  # rep collided, raw queries didn't
    assert not np.array_equal(s_a, s_b)
