"""HLO collective parser + data-pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import lm as lm_data
from repro.data import recsys as rec_data
from repro.data import graph as graph_data
from repro.launch import hlo_collectives as hc
from repro.models.recsys import TableSpec, criteo_row_counts


SYNTH = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256] get-tuple-element(%p), index=1
  %ar = f32[128,256] all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,256]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128,256]) -> f32[128,256] {
  %x = f32[128,256] parameter(0)
  %ag = f32[128,256] all-gather(%x), dimensions={0}, replica_groups=[2,4]<=[8]
  %init = s32[] constant(0)
  %t0 = (s32[], f32[128,256]) tuple(%init, %ag)
  %w = (s32[], f32[128,256]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[128,256] get-tuple-element(%w), index=1
}
"""


def test_collective_parser_factors_and_trip_counts():
    out = hc.collective_bytes(SYNTH, total_devices=8)
    nbytes = 128 * 256 * 4
    # all-gather: groups of 4 -> (3/4) * result bytes, once
    expect_ag = 0.75 * nbytes
    # all-reduce in while body: groups of 4 -> 2*(3/4)*bytes, x10 trips
    expect_ar = 10 * 2 * 0.75 * nbytes
    np.testing.assert_allclose(out["all-gather"], expect_ag, rtol=1e-6)
    np.testing.assert_allclose(out["all-reduce"], expect_ar, rtol=1e-6)
    np.testing.assert_allclose(out["total"], expect_ag + expect_ar, rtol=1e-6)


def test_collective_parser_on_real_lowering():
    """Parse a real sharded matmul's HLO (subprocess-free: 1 device mesh
    trivially has no collectives; assert zero)."""
    x = jnp.zeros((8, 8))
    c = jax.jit(lambda a: a @ a).lower(x).compile()
    out = hc.collective_bytes(c.as_text(), total_devices=1)
    assert out["total"] == 0.0


def test_shape_bytes_parsing():
    assert hc._shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert hc._shape_bytes("bf16[2,3]") == 12
    assert hc._shape_bytes("(f32[4], s8[8])") == 24
    assert hc._shape_bytes("pred[]") == 1


# -- data determinism ---------------------------------------------------------


def test_lm_batches_deterministic_and_shardable():
    cfg = lm_data.LmDataConfig(vocab=500, seq_len=16, global_batch=8, seed=3)
    b1, b2 = lm_data.batch_at(cfg, 5), lm_data.batch_at(cfg, 5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(
        np.asarray(lm_data.batch_at(cfg, 6)["tokens"]), np.asarray(b1["tokens"]))
    # host shards tile the global batch exactly
    parts = [lm_data.host_shard_at(cfg, 5, s, 4)["tokens"] for s in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(p) for p in parts]), np.asarray(b1["tokens"]))
    # labels are next-token shifted
    full_cfg = lm_data.LmDataConfig(vocab=500, seq_len=16, global_batch=2, seed=0)
    b = lm_data.batch_at(full_cfg, 0)
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1]))


def test_recsys_batches_in_range():
    table = TableSpec(criteo_row_counts(10, 8192), 8)
    cfg = rec_data.RecsysDataConfig(table=table, batch=64, n_dense=4, seed=1)
    b = rec_data.batch_at(cfg, 7)
    rows = np.asarray(table.row_counts)
    assert (np.asarray(b["sparse"]) < rows[None, :, None]).all()
    assert (np.asarray(b["sparse"]) >= 0).all()
    assert set(np.unique(np.asarray(b["label"]))) <= {0.0, 1.0}
    # Zipf skew: in the largest field the 10 hottest ids hold far more
    # than their uniform share
    s0 = np.asarray(b["sparse"])[:, 0]  # field 0 = largest id space
    frac_small = (s0 < 10).mean()
    assert frac_small > 20 * (10.0 / table.row_counts[0])


def test_graph_sampler_correctness_and_padding():
    g = graph_data.make_graph(graph_data.GraphConfig(
        n_nodes=300, n_edges=1200, d_feat=4, n_classes=3))
    ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
    seeds = graph_data.batch_seeds(jax.random.key(0), 300, 32)
    nbr = graph_data.sample_neighbors(jax.random.key(1), g.indptr, g.indices, seeds, 7)
    for i, s in enumerate(np.asarray(seeds)):
        neigh = set(ind[ip[s]: ip[s + 1]])
        for x in np.asarray(nbr)[i]:
            if x >= 0:
                assert x in neigh
            else:
                assert len(neigh) == 0  # -1 only for isolated nodes
    # degree distribution is heavy-tailed (power-law generator)
    deg = ip[1:] - ip[:-1]
    assert deg.max() > 10 * max(1, int(np.median(deg)))
