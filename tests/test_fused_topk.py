"""Fused streaming score->top-k kernel: interpret-mode parity vs
``jax.lax.top_k`` over the reference scores, plus regression tests that the
``use_kernel`` routing in every search hot path matches the XLA path.

Small ``bn``/``bk`` overrides force multiple doc/reduce tiles so the
cross-tile running-merge (the online-reduction part) is actually exercised.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blockmax, bruteforce, fakewords, kdtree, lexical_lsh
from repro.core.types import FakeWordsConfig, KdTreeConfig, LexicalLshConfig
from repro.kernels.fused_topk import ops as fused
from repro.kernels.fused_topk import ref as fused_ref
from repro.kernels.fused_topk.kernel import fused_topk, fused_topk_gathered

RNG = np.random.default_rng(13)


# -- raw kernel vs top_k-over-reference-scores -------------------------------


@pytest.mark.parametrize(
    "b,n,t,depth",
    [
        (4, 256, 64, 32),    # aligned
        (3, 513, 257, 37),   # everything unaligned: pad paths + ragged N
        (8, 300, 100, 100),  # depth == paper default
    ],
)
@pytest.mark.parametrize("dtype", ["bf16", "int8", "f32"])
@pytest.mark.parametrize("merge", ["bitonic", "extract"])
def test_fused_topk_parity_modes_and_shapes(b, n, t, depth, dtype, merge):
    if dtype == "int8":
        q = jnp.asarray(RNG.integers(-50, 50, (b, t)), jnp.int8)
        d = jnp.asarray(RNG.integers(-50, 50, (n, t)), jnp.int8)
    elif dtype == "bf16":
        q = jnp.asarray(RNG.normal(size=(b, t)), jnp.bfloat16)
        d = jnp.asarray(RNG.normal(size=(n, t)), jnp.bfloat16)
    else:
        q = jnp.asarray(RNG.normal(size=(b, t)), jnp.float32)
        d = jnp.asarray(RNG.normal(size=(n, t)), jnp.float32)
    # small tiles => several doc tiles and reduce tiles stream through VMEM
    s, i = fused_topk(q, d, depth, bn=128, bk=128, merge=merge, interpret=True)
    ref_s, ref_i = jax.lax.top_k(fused_ref.scores_ref(q, d), depth)
    if dtype == "int8":  # integer scores: bitwise identical
        np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))
    else:
        np.testing.assert_allclose(
            np.asarray(s), np.asarray(ref_s), rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))


def test_fused_topk_lsh_mode_parity():
    sig_d = jnp.asarray(RNG.integers(0, 7, (357, 96)), jnp.uint32)
    sig_q = sig_d[:5].at[:, ::5].set(jnp.uint32(0xFFFFFFFF))  # sentinels
    s, i = fused_topk(sig_q, sig_d, 40, mode="lsh", bn=128, bk=64,
                      interpret=True)
    ref_s, ref_i = jax.lax.top_k(
        fused_ref.scores_ref(sig_q, sig_d, mode="lsh"), 40)
    # collision counts tie constantly: exact lowest-index tie-break required
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))


@pytest.mark.parametrize("merge", ["bitonic", "extract"])
def test_fused_topk_tie_break_and_ragged_padding(merge):
    """Massive integer ties + ragged N: ids must follow top_k's lowest-index
    tie order and padded docs must never surface."""
    b, n, t = 3, 130, 16  # n pads up to 256 with bn=128 -> ~half the tile fake
    q = jnp.asarray(RNG.integers(0, 2, (b, t)), jnp.int8)
    d = jnp.asarray(RNG.integers(0, 2, (n, t)), jnp.int8)
    s, i = fused_topk(q, d, n, bn=128, bk=128, merge=merge, interpret=True)
    ref_s, ref_i = jax.lax.top_k(fused_ref.scores_ref(q, d), n)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))
    assert (np.asarray(i) < n).all()  # no padded id leaks


@pytest.mark.parametrize("merge", ["bitonic", "extract"])
def test_fused_topk_gathered_parity_and_padding(merge):
    """Blockmax stage-2 variant: per-query candidate sets, invalid rows
    (row_id >= n_docs) masked to -inf and reported as id -1."""
    b, r, t, n_docs = 4, 96, 33, 64
    q = jnp.asarray(RNG.normal(size=(b, t)), jnp.float32)
    rows = jnp.asarray(RNG.normal(size=(b, r, t)), jnp.float32)
    # force many invalid candidates so -inf slots reach the output
    row_ids = jnp.asarray(RNG.integers(0, 2 * n_docs, (b, r)), jnp.int32)
    s, i = fused_topk_gathered(q, rows, row_ids, 60, n_docs, bn=64, bk=32,
                               merge=merge, interpret=True)
    ref_s, ref_i = fused_ref.gathered_topk_ref(q, rows, row_ids, 60, n_docs)
    np.testing.assert_allclose(
        np.asarray(s), np.asarray(ref_s), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))
    assert (np.asarray(i)[np.asarray(s) == -np.inf] == -1).all()


@pytest.mark.parametrize("merge", ["bitonic", "extract"])
def test_fused_topk_gathered_tied_tiles_keep_smaller_ids(merge):
    """Regression: gathered row ids are NOT ordered across doc tiles (blocks
    arrive in stage-1 bound order), so a later tile whose best score only
    TIES the running depth-th best may hold the smaller — winning — ids;
    the WAND tile skip must not drop it (>= for the gathered variant)."""
    b, r, t, n_docs = 1, 256, 16, 2048
    q = jnp.ones((b, t), jnp.int8)
    rows = jnp.ones((b, r, t), jnp.int8)  # every candidate scores exactly t
    # first tile (bn=128): ids 1000..1127; second tile: ids 0..127
    row_ids = jnp.concatenate(
        [jnp.arange(1000, 1128), jnp.arange(0, 128)])[None, :].astype(jnp.int32)
    s, i = fused_topk_gathered(q, rows, row_ids, 128, n_docs, bn=128, bk=128,
                               merge=merge, interpret=True)
    ref_s, ref_i = fused_ref.gathered_topk_ref(q, rows, row_ids, 128, n_docs)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))
    np.testing.assert_array_equal(np.asarray(i)[0], np.arange(128))


def test_fused_topk_gathered_int8_mode():
    """int8 gathered operands take the int32-accumulate path bit-exactly
    (blockmax stage 2 for the dot/int8 scoring mode)."""
    b, r, t, n_docs = 3, 96, 40, 80
    q = jnp.asarray(RNG.integers(-50, 50, (b, t)), jnp.int8)
    rows = jnp.asarray(RNG.integers(-50, 50, (b, r, t)), jnp.int8)
    row_ids = jnp.asarray(RNG.integers(0, 2 * n_docs, (b, r)), jnp.int32)
    s, i = fused_topk_gathered(q, rows, row_ids, 50, n_docs, bn=64, bk=32,
                               interpret=True)
    ref_s, ref_i = fused_ref.gathered_topk_ref(q, rows, row_ids, 50, n_docs)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))


def test_fused_topk_gathered_lsh_mode():
    """uint32 signature rows in lsh mode: sentinel-aware collision counts
    with constant integer ties (exact lowest-doc-id tie order required)."""
    b, r, t, n_docs = 3, 96, 48, 80
    q = jnp.asarray(RNG.integers(0, 6, (b, t)), jnp.uint32)
    q = q.at[:, ::7].set(jnp.uint32(0xFFFFFFFF))  # query sentinels masked
    rows = jnp.asarray(RNG.integers(0, 6, (b, r, t)), jnp.uint32)
    row_ids = jnp.asarray(RNG.integers(0, 2 * n_docs, (b, r)), jnp.int32)
    s, i = fused_topk_gathered(q, rows, row_ids, 50, n_docs, mode="lsh",
                               bn=64, bk=32, interpret=True)
    ref_s, ref_i = fused_ref.gathered_topk_ref(
        q, rows, row_ids, 50, n_docs, mode="lsh")
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))


# -- index-level wrappers: df-prune mask folding -----------------------------


@pytest.mark.parametrize("scoring", ["classic", "dot"])
@pytest.mark.parametrize("df_max_ratio", [1.0, 0.3])
def test_fused_wrappers_match_core_scores(small_corpus, scoring, df_max_ratio):
    v = jnp.asarray(small_corpus[:384])
    cfg = FakeWordsConfig(quantization=40, scoring=scoring)
    idx = fakewords.build(v, cfg)
    q_tf = fakewords.encode_queries(v[:4], cfg)
    if scoring == "classic":
        out_s, out_i = fused.classic_topk(
            idx, q_tf, 50, df_max_ratio, interpret=True)
        ref = fakewords.classic_scores(idx, q_tf, df_max_ratio)
    else:
        out_s, out_i = fused.dot_topk(
            idx, q_tf, 50, df_max_ratio, interpret=True)
        ref = fakewords.dot_scores(idx, q_tf, df_max_ratio)
    ref_s, ref_i = jax.lax.top_k(ref, 50)
    np.testing.assert_allclose(
        np.asarray(out_s), np.asarray(ref_s), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(out_i), np.asarray(ref_i))


# -- hot-path routing regressions: use_kernel=True == use_kernel=False -------


@pytest.mark.parametrize("scoring", ["classic", "dot"])
def test_fakewords_search_kernel_routing_exact(small_corpus, scoring):
    v = jnp.asarray(small_corpus[:512])
    cfg = FakeWordsConfig(quantization=50, scoring=scoring)
    idx = fakewords.build(v, cfg)
    q_tf = fakewords.encode_queries(v[:8], cfg)
    s_k, i_k = fakewords.search(
        idx, q_tf, None, k=10, depth=64, scoring=scoring, use_kernel=True)
    s_x, i_x = fakewords.search(
        idx, q_tf, None, k=10, depth=64, scoring=scoring, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_x))
    np.testing.assert_allclose(
        np.asarray(s_k), np.asarray(s_x), rtol=1e-5, atol=1e-5)


def test_lexical_lsh_search_kernel_routing_exact(small_corpus):
    v = jnp.asarray(small_corpus[:256])
    cfg = LexicalLshConfig(buckets=64, hashes=2)
    idx = lexical_lsh.build(v, cfg)
    sig_q = lexical_lsh.encode(
        bruteforce.l2_normalize(v[:4]), cfg)
    s_k, i_k = lexical_lsh.search(idx, sig_q, None, k=10, depth=30,
                                  use_kernel=True)
    s_x, i_x = lexical_lsh.search(idx, sig_q, None, k=10, depth=30,
                                  use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_x))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_x))


def test_bruteforce_exact_topk_kernel_routing(small_corpus):
    v = jnp.asarray(small_corpus[:512])
    s_k, i_k = bruteforce.exact_topk(v, v[:6], 10, use_kernel=True)
    s_x, i_x = bruteforce.exact_topk(v, v[:6], 10, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_x))
    np.testing.assert_allclose(
        np.asarray(s_k), np.asarray(s_x), rtol=1e-5, atol=1e-5)


def test_blockmax_pruned_search_kernel_routing(small_corpus):
    v = jnp.asarray(small_corpus[:512])
    cfg = FakeWordsConfig(quantization=50)
    idx = fakewords.build(v, cfg)
    bm = blockmax.build_blockmax(idx, block_size=64)
    q_tf = fakewords.encode_queries(v[:4], cfg)
    s_k, i_k = blockmax.pruned_search(idx, bm, q_tf, n_keep=4, depth=50,
                                      use_kernel=True)
    s_x, i_x = blockmax.pruned_search(idx, bm, q_tf, n_keep=4, depth=50,
                                      use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_x))
    np.testing.assert_allclose(
        np.asarray(s_k), np.asarray(s_x), rtol=1e-5, atol=1e-5)


def test_blockmax_pruned_search_dot_kernel_routing(small_corpus):
    """Generalized blockmax: int8-dot stage 2 through the gathered kernel
    must bit-match the XLA gathered reference."""
    v = jnp.asarray(small_corpus[:512])
    cfg = FakeWordsConfig(quantization=50, scoring="dot")
    idx = fakewords.build(v, cfg)
    bm = blockmax.build_blockmax(idx, block_size=64)
    assert bm.mode == "dot"
    q_tf = fakewords.encode_queries(v[:4], cfg)
    s_k, i_k = blockmax.pruned_search(idx, bm, q_tf, n_keep=4, depth=50,
                                      use_kernel=True)
    s_x, i_x = blockmax.pruned_search(idx, bm, q_tf, n_keep=4, depth=50,
                                      use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_x))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_x))


def test_blockmax_pruned_search_lsh_kernel_routing(small_corpus):
    """Generalized blockmax: LSH collision-count stage 2 through the
    gathered kernel must bit-match the XLA gathered reference."""
    v = jnp.asarray(small_corpus[:512])
    cfg = LexicalLshConfig(buckets=64, hashes=2)
    idx = lexical_lsh.build(v, cfg)
    bm = blockmax.build_blockmax(idx, block_size=64)
    assert bm.mode == "lsh"
    sig_q = lexical_lsh.encode(bruteforce.l2_normalize(v[:4]), cfg)
    s_k, i_k = blockmax.pruned_search(idx, bm, sig_q, n_keep=4, depth=50,
                                      use_kernel=True)
    s_x, i_x = blockmax.pruned_search(idx, bm, sig_q, n_keep=4, depth=50,
                                      use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_x))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_x))


def test_kdtree_scan_search_kernel_routing(small_corpus):
    """kd-tree scan backend through the fused kernel ([2q; 1] lift): same
    neighbors and negated squared distances as the XLA scan, with no (B, N)
    matrix on the kernel path."""
    v = jnp.asarray(small_corpus[:512])
    idx = kdtree.build(v, KdTreeConfig(dims=8, backend="scan"))
    qr = kdtree.reduce_queries(idx, v[:6])
    s_k, i_k = kdtree.scan_search(idx, qr, 10, use_kernel=True)
    s_x, i_x = kdtree.scan_search(idx, qr, 10, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_x))
    np.testing.assert_allclose(
        np.asarray(s_k), np.asarray(s_x), rtol=1e-5, atol=1e-5)
