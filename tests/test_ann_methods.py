"""Lexical LSH, k-d tree, blockmax, and the AnnIndex facade."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blockmax, bruteforce, eval as ev, fakewords, lexical_lsh, pca
from repro.core.index import AnnIndex
from repro.core.types import FakeWordsConfig, KdTreeConfig, LexicalLshConfig


# -- lexical LSH -------------------------------------------------------------


def test_lsh_tokenize_deterministic_and_tagged(rng):
    v = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    cfg = LexicalLshConfig(buckets=32, hashes=2)
    t1, t2 = lexical_lsh.tokenize(v, cfg), lexical_lsh.tokenize(v, cfg)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    # same value in different feature positions -> different tokens
    vv = jnp.zeros((1, 8)).at[0, 0].set(0.4).at[0, 3].set(0.4)
    toks = np.asarray(lexical_lsh.tokenize(vv, cfg))[0]
    assert toks[0] != toks[3]


def test_lsh_identical_vectors_full_collision(rng):
    v = bruteforce.l2_normalize(jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32)))
    cfg = LexicalLshConfig(buckets=64, hashes=2)
    sig = lexical_lsh.encode(v, cfg)
    scores = lexical_lsh.match_scores(sig, sig)
    diag = np.diag(np.asarray(scores))
    off = np.asarray(scores) - np.diag(diag)
    assert (diag >= off.max(-1)).all()  # self-collision count is maximal


def test_lsh_recall_between_kdtree_and_fakewords(small_corpus):
    """Paper Table 1 ordering: fake words > lexical LSH >> k-d tree."""
    v = jnp.asarray(small_corpus)
    q = v[:32]
    gt_s, gt_i = bruteforce.exact_topk(v, q, 10)

    r = {}
    for name, cfg in [
        ("fw", FakeWordsConfig(quantization=50)),
        ("lsh", LexicalLshConfig(buckets=300, hashes=1)),
        ("kd", KdTreeConfig(dims=8, backend="scan")),
    ]:
        idx = AnnIndex.build(v, cfg)
        _, ids = idx.search(q, k=10, depth=100)
        r[name] = float(ev.recall_at(gt_i, ids))
    # fake words strictly dominates; LSH and k-d tree land close together
    # on this small isotropic corpus (see benchmarks/table1.py for the
    # paper-shaped corpora where the full ordering holds with margin).
    assert r["fw"] > r["lsh"] + 0.1 and r["fw"] > r["kd"] + 0.1
    assert r["lsh"] >= r["kd"] - 0.05
    assert r["kd"] < 0.5  # recall collapse (paper: <= 0.03 at 300d->8d)


# -- eval metrics ------------------------------------------------------------


def test_recall_at_ignores_truth_padding():
    """Regression: -1 padding in truth rows must shrink the denominator,
    not count as misses (it understated recall before)."""
    truth = jnp.asarray([[0, 1, -1, -1], [2, 3, 4, 5]])
    retrieved = jnp.asarray([[0, 1, 7, 9], [2, 3, 4, 5]])
    # query 0: both valid truths retrieved -> 1.0 (was 0.5); query 1: 1.0
    assert float(ev.recall_at(truth, retrieved)) == 1.0
    partial = jnp.asarray([[0, 8, -1, -1], [2, 3, 9, 9]])
    got = float(ev.recall_at(truth, partial))
    np.testing.assert_allclose(got, (0.5 + 0.5) / 2)


def test_overlap_ignores_padding():
    a = jnp.asarray([[0, 1, -1, -1]])
    b = jnp.asarray([[1, 0, 5, 6]])
    assert float(ev.overlap(a, b)) == 1.0  # both valid ids shared


# -- PCA / PPA ---------------------------------------------------------------


def test_pca_reconstruction_quality(rng):
    # low-rank data: PCA to the true rank loses ~nothing
    w = rng.normal(size=(5, 32)).astype(np.float32)
    z = rng.normal(size=(500, 5)).astype(np.float32)
    x = jnp.asarray(z @ w)
    model = pca.pca_fit(x, 5)
    proj = pca.pca_apply(model, x)
    # distances preserved
    d_orig = np.linalg.norm(np.asarray(x[:50])[:, None] - np.asarray(x[:50])[None], axis=-1)
    d_proj = np.linalg.norm(np.asarray(proj[:50])[:, None] - np.asarray(proj[:50])[None], axis=-1)
    np.testing.assert_allclose(d_proj, d_orig, rtol=1e-3, atol=1e-3)


def test_ppa_removes_common_mean(rng):
    x = rng.normal(size=(400, 32)).astype(np.float32)
    x += 5.0 * rng.normal(size=(1, 32)).astype(np.float32)  # strong common component
    model = pca.ppa_fit(jnp.asarray(x), remove=2)
    out = pca.ppa_apply(model, jnp.asarray(x))
    assert float(jnp.linalg.norm(jnp.mean(out, axis=0))) < 1e-3


# -- k-d tree ---------------------------------------------------------------


def test_kdtree_tree_equals_scan(rng):
    v = jnp.asarray(rng.normal(size=(500, 32)).astype(np.float32))
    q = v[:8]
    for reduction in ("pca", "ppa-pca-ppa"):
        cfg_t = KdTreeConfig(dims=8, backend="tree", reduction=reduction)
        cfg_s = KdTreeConfig(dims=8, backend="scan", reduction=reduction)
        it = AnnIndex.build(v, cfg_t)
        is_ = AnnIndex.build(v, cfg_s)
        st, idt = it.search(q, k=5, depth=5)
        ss, ids = is_.search(q, k=5, depth=5)
        # same neighbors in the reduced space (exact L2 both ways)
        assert float(ev.overlap(idt, ids)) > 0.99


# -- blockmax ---------------------------------------------------------------


def test_blockmax_upper_bound_admissible(small_corpus):
    v = jnp.asarray(small_corpus[:512])
    cfg = FakeWordsConfig(quantization=40)
    idx = fakewords.build(v, cfg)
    bm = blockmax.build_blockmax(idx, block_size=64)
    q_tf = fakewords.encode_queries(v[:8], cfg)
    exact = np.asarray(fakewords.classic_scores(idx, q_tf), np.float32)  # (B, N)
    qv = np.asarray(q_tf, np.float32)
    ub = qv @ np.asarray(bm.ub, np.float32).T  # (B, n_blocks) optimistic
    for b in range(ub.shape[1]):
        blk = exact[:, b * 64 : (b + 1) * 64]
        if blk.size:
            assert (ub[:, b] >= blk.max(-1) - 0.5).all()  # bf16 slack


def test_blockmax_full_keep_matches_exact(small_corpus):
    v = jnp.asarray(small_corpus[:512])
    cfg = FakeWordsConfig(quantization=40)
    idx = fakewords.build(v, cfg)
    bm = blockmax.build_blockmax(idx, block_size=64)
    n_blocks = bm.ub.shape[0]
    q_tf = fakewords.encode_queries(v[:8], cfg)
    s_full, i_full = fakewords.search(idx, q_tf, v[:8], k=10, depth=10)
    s_bm, i_bm = blockmax.pruned_search(idx, bm, q_tf, n_keep=n_blocks, depth=10)
    assert float(ev.overlap(i_full, i_bm[:, :10])) > 0.99


def test_blockmax_pruned_keeps_recall(small_corpus):
    v = jnp.asarray(small_corpus[:512])
    cfg = FakeWordsConfig(quantization=50)
    idx = fakewords.build(v, cfg)
    bm = blockmax.build_blockmax(idx, block_size=64)
    n_blocks = bm.ub.shape[0]
    q_tf = fakewords.encode_queries(v[:16], cfg)
    gt_s, gt_i = bruteforce.exact_topk(v, v[:16], 10)
    recalls = []
    for frac in (1.0, 0.75, 0.5):
        _, ids = blockmax.pruned_search(
            idx, bm, q_tf, n_keep=max(1, int(frac * n_blocks)), depth=50)
        recalls.append(float(ev.recall_at(gt_i, ids)))
    # graceful monotone degradation; half the blocks keep most recall
    assert recalls[0] >= recalls[1] - 0.02 >= recalls[2] - 0.04
    assert recalls[2] > 0.3


def test_blockmax_dot_bound_admissible(small_corpus):
    """The [max(s); max(-s)] dot bound must dominate every in-block score
    (signed per-term values make a single max inadmissible; the sign-split
    query lift restores a one-GEMM bound)."""
    v = jnp.asarray(small_corpus[:512])
    cfg = FakeWordsConfig(quantization=40, scoring="dot")
    idx = fakewords.build(v, cfg)
    bm = blockmax.build_blockmax(idx, block_size=64)
    q_tf = fakewords.encode_queries(v[:8], cfg)
    exact = np.asarray(fakewords.dot_scores(idx, q_tf))  # (B, N)
    bounds = np.asarray(blockmax.block_bounds(bm, q_tf))  # (B, n_blocks)
    for b in range(bounds.shape[1]):
        blk = exact[:, b * 64 : (b + 1) * 64]
        assert (bounds[:, b] >= blk.max(-1)).all()


def test_blockmax_lsh_bound_admissible(small_corpus):
    """Presence-bitmap bounds must dominate in-block collision counts
    (membership is a superset test: hash collisions only loosen it)."""
    v = jnp.asarray(small_corpus[:512])
    cfg = LexicalLshConfig(buckets=64, hashes=2)
    idx = lexical_lsh.build(v, cfg)
    bm = blockmax.build_blockmax(idx, block_size=64)
    sig_q = lexical_lsh.encode(bruteforce.l2_normalize(v[:8]), cfg)
    exact = np.asarray(lexical_lsh.match_scores(sig_q, idx.sig))
    bounds = np.asarray(blockmax.block_bounds(bm, sig_q))
    for b in range(bounds.shape[1]):
        blk = exact[:, b * 64 : (b + 1) * 64]
        assert (bounds[:, b] >= blk.max(-1)).all()


@pytest.mark.parametrize("use_kernel", [False, True])
def test_blockmax_dot_beta1_exact_id_parity(small_corpus, use_kernel):
    """At beta=1.0 (all blocks kept) the pruned dot/int8 path must return
    IDENTICAL ids and scores to the dense reference path — integer scores,
    lowest-doc-id tie-break on both sides."""
    v = jnp.asarray(small_corpus[:512])
    cfg = FakeWordsConfig(quantization=50, scoring="dot")
    idx = fakewords.build(v, cfg)
    bm = blockmax.build_blockmax(idx, block_size=64)
    q_tf = fakewords.encode_queries(v[:8], cfg)
    s_ref, i_ref = fakewords.search(
        idx, q_tf, None, k=50, depth=50, scoring="dot", use_kernel=False)
    s_p, i_p = blockmax.pruned_search(
        idx, bm, q_tf, n_keep=bm.num_blocks, depth=50, use_kernel=use_kernel)
    np.testing.assert_array_equal(np.asarray(i_p), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_ref))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_blockmax_lsh_beta1_exact_id_parity(small_corpus, use_kernel):
    """At beta=1.0 the pruned LSH path must return IDENTICAL ids to the
    dense collision-count reference (constant integer ties make this the
    strictest tie-order check)."""
    v = jnp.asarray(small_corpus[:512])
    cfg = LexicalLshConfig(buckets=64, hashes=2)
    idx = lexical_lsh.build(v, cfg)
    bm = blockmax.build_blockmax(idx, block_size=64)
    sig_q = lexical_lsh.encode(bruteforce.l2_normalize(v[:8]), cfg)
    s_ref, i_ref = lexical_lsh.search(
        idx, sig_q, None, k=40, depth=40, use_kernel=False)
    s_p, i_p = blockmax.pruned_search(
        idx, bm, sig_q, n_keep=bm.num_blocks, depth=40, use_kernel=use_kernel)
    np.testing.assert_array_equal(np.asarray(i_p[:, :40]), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(s_p[:, :40]), np.asarray(s_ref))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_blockmax_clamps_n_keep_and_depth(small_corpus, use_kernel):
    """Regression: n_keep > n_blocks crashed lax.top_k and
    depth > n_keep*block_size crashed the gathered top-k; both now clamp,
    padding the output back to the requested depth with (-inf, -1)."""
    v = jnp.asarray(small_corpus[:70])  # 2 blocks of 64, second one ragged
    cfg = FakeWordsConfig(quantization=40)
    idx = fakewords.build(v, cfg)
    bm = blockmax.build_blockmax(idx, block_size=64)
    assert bm.num_blocks == 2
    q_tf = fakewords.encode_queries(v[:3], cfg)
    s, i = blockmax.pruned_search(
        idx, bm, q_tf, n_keep=10, depth=200, use_kernel=use_kernel)
    assert s.shape == (3, 200) and i.shape == (3, 200)
    ii, ss = np.asarray(i), np.asarray(s)
    assert ((ii >= -1) & (ii < 70)).all()  # no padded/fake doc ids
    assert (ii[:, :70] >= 0).all()         # every real doc is returned
    assert (ii[:, 70:] == -1).all() and (ss[:, 70:] == -np.inf).all()
