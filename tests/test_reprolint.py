"""tools/reprolint: each rule on a firing, a clean, and a waived snippet,
plus the waiver framework and the dynamic trace audit (tier 1 — the CI
gate is only trustworthy if the analyzers themselves are pinned by tests).
"""
import textwrap

import pytest

from tools.reprolint.config import Config, LockContract
from tools.reprolint.framework import FileContext
from tools.reprolint.rules.hostsync import HostSyncRule
from tools.reprolint.rules.lockdiscipline import LockDisciplineRule
from tools.reprolint.rules.retrace import RetraceRule
from tools.reprolint.rules.vmem import VmemBudgetRule
from tools.reprolint.trace_audit import assert_max_traces

HOT = "src/repro/serve/svc.py"        # matches hot_path_globs
KERNEL = "src/repro/kernels/x/kernel.py"  # matches kernel_globs


def run_rule(rule, path, src, cfg=None):
    ctx = FileContext(path, textwrap.dedent(src), cfg or Config())
    return rule.check(ctx)


def unwaived(findings):
    return [f for f in findings if not f.waived]


# -- retrace -----------------------------------------------------------------


def test_retrace_fires_on_local_jit_and_closure_array():
    src = """
    def serve(x):
        w = np.zeros((4,))
        def f(y):
            return y + w
        return jax.jit(f)(x)
    """
    found = run_rule(RetraceRule(), HOT, src)
    msgs = " ".join(f.message for f in found)
    assert any("locally-defined" in f.message for f in found)
    assert "captures array 'w'" in msgs


def test_retrace_fires_on_jit_in_loop():
    src = """
    def serve(fns, x):
        outs = []
        for f in fns:
            outs.append(jax.jit(f)(x))
        return outs
    """
    found = run_rule(RetraceRule(), HOT, src)
    assert any("loop" in f.message for f in found)


def test_retrace_clean_on_module_scope_and_builders():
    src = """
    def _impl(x):
        return x * 2

    top = jax.jit(_impl)

    def make_search(index):
        def f(q):
            return q @ index
        return jax.jit(f)
    """
    assert run_rule(RetraceRule(), HOT, src) == []


def test_retrace_waived():
    src = """
    def serve(x):
        def f(y):
            return y * 2
        return jax.jit(f)(x)  # reprolint: disable=retrace
    """
    found = run_rule(RetraceRule(), HOT, src)
    assert found and all(f.waived for f in found)


# -- vmem --------------------------------------------------------------------

_KERNEL_TMPL = """
def mykernel(x, bq=None):
    bq = bq or {bq}
    return pl.pallas_call(
        _kern,
        in_specs=[pl.BlockSpec((bq, {bn}), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bq, {bn}), lambda i: (i, 0)),
    )(x)
"""


def test_vmem_fires_over_budget():
    # 1024*4096*4B = 16 MiB per spec, x2 specs x2 double-buffer = 64 MiB.
    src = _KERNEL_TMPL.format(bq=1024, bn=4096)
    found = run_rule(VmemBudgetRule(), KERNEL, src)
    assert len(found) == 1
    assert "exceeds" in found[0].message
    assert "64.00 MiB" in found[0].message


def test_vmem_clean_under_budget_and_non_kernel_paths_skipped():
    src = _KERNEL_TMPL.format(bq=128, bn=512)
    assert run_rule(VmemBudgetRule(), KERNEL, src) == []
    big = _KERNEL_TMPL.format(bq=1024, bn=4096)
    assert run_rule(VmemBudgetRule(), HOT, big) == []  # not a kernel file


def test_vmem_unbounded_dim_is_a_finding():
    src = """
    def mykernel(x, mystery):
        return pl.pallas_call(
            _kern,
            in_specs=[pl.BlockSpec((mystery, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        )(x)
    """
    found = run_rule(VmemBudgetRule(), KERNEL, src)
    assert any("cannot bound" in f.message for f in found)


def test_vmem_evaluator_tile_clamps_and_scratch():
    # min() clamp + round_up + or-default, plus a VMEM scratch allocation:
    # bq = min(1024 or 1024, round_up(9, 8)=16) -> 16; blocks 2*16*128*4B
    # = 16 KiB -> x2 = 32 KiB; scratch 16*128*4 = 8 KiB.  Budget 64 KiB
    # passes; 32 KiB fails (proves the estimate tracks the clamped tile).
    src = """
    def mykernel(x, bq=None):
        b = 9
        bq = min(bq or 1024, common.round_up(b, 8))
        return pl.pallas_call(
            _kern,
            in_specs=[pl.BlockSpec((bq, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((bq, 128), lambda i: (i, 0)),
            scratch_shapes=[common.MemorySpace.VMEM((bq, 128), jnp.float32)],
        )(x)
    """
    cfg_pass = Config(vmem_budget_bytes=64 * 1024)
    cfg_fail = Config(vmem_budget_bytes=32 * 1024)
    assert run_rule(VmemBudgetRule(), KERNEL, src, cfg_pass) == []
    found = run_rule(VmemBudgetRule(), KERNEL, src, cfg_fail)
    assert len(found) == 1 and "0.04 MiB" in found[0].message


def test_vmem_waived():
    src = """
    # reprolint: disable=vmem
    def mykernel(x, bq=None):
        bq = bq or 1024
        return pl.pallas_call(
            _kern,
            in_specs=[pl.BlockSpec((bq, 4096), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((bq, 4096), lambda i: (i, 0)),
        )(x)
    """
    found = run_rule(VmemBudgetRule(), KERNEL, src)
    assert found and all(f.waived for f in found)


# -- hostsync ----------------------------------------------------------------


def test_hostsync_fires_on_item_float_and_asarray():
    src = """
    def serve(x, scores):
        a = x.item()
        b = float(scores)
        c = np.asarray(scores)
        return a + b, c
    """
    found = run_rule(HostSyncRule(), HOT, src)
    assert len(found) == 3


def test_hostsync_clean_forms():
    src = """
    V = np.asarray(RAW_TABLE)  # module scope: import-time is not hot

    def serve(x, q):
        n = len(q)
        m = int(x.shape[0])
        lst = np.array([r is not None for r in q])
        t = float(time.perf_counter())
        return n + m, lst, t
    """
    assert run_rule(HostSyncRule(), HOT, src) == []


def test_hostsync_matcher_call_scope():
    src = """
    class FooMatcher:
        def __call__(self, q):
            return q.item()

    class Helper:
        def __call__(self, q):
            return q.item()

    def free(q):
        return q.item()
    """
    found = run_rule(HostSyncRule(), "src/repro/core/pipeline.py", src)
    # only the matcher-class __call__ is hot in pipeline.py
    assert len(found) == 1
    assert found[0].line == 4  # FooMatcher.__call__'s body


def test_hostsync_waived():
    src = """
    def serve(x):
        return x.item()  # reprolint: disable=hostsync
    """
    found = run_rule(HostSyncRule(), HOT, src)
    assert found and all(f.waived for f in found)


# -- lockdiscipline ----------------------------------------------------------

_CONTRACT = Config(lock_contracts=(
    LockContract(
        path_glob="src/x.py", class_name="Svc", lock_attr="_lock",
        worker_entries=("_loop",), exempt_methods=("__init__",),
        threadsafe_attrs=("_queue",),
    ),
))

_SVC_TMPL = """
class Svc:
    def __init__(self):
        self._lock = threading.RLock()
        self.count = 0
        self.ring = []

    def _loop(self):
        {worker_body}

    def caller(self):
        {caller_body}

    def locked_caller(self):
        with self._lock:
            self._sink(1)

    def _sink(self, v):
        self.ring.append(v)
"""


def _svc(worker_body, caller_body):
    return _SVC_TMPL.format(worker_body=worker_body, caller_body=caller_body)


def test_lockdiscipline_fires_on_unlocked_mutations():
    src = _svc("self.count += 1\n        self._sink(2)",
               "self.count += 1")
    found = run_rule(LockDisciplineRule(), "src/x.py", src, _CONTRACT)
    # worker bumps count off-lock; caller bumps count off-lock.  _sink is
    # NOT lock-held (one of its call sites is the unlocked worker), so its
    # ring.append is an off-lock worker-reachable mutation too.
    lines = {f.line for f in found}
    assert len(found) == 3
    assert any("worker thread" in f.message for f in found)
    assert any("caller threads" in f.message for f in found)
    assert lines  # every finding carries a real location


def test_lockdiscipline_clean_with_lock_and_helper_propagation():
    src = _svc(
        "with self._lock:\n            self.count += 1",
        "with self._lock:\n            self.count += 1",
    )
    # _sink's only call site is locked_caller's with-block -> lock-held.
    assert run_rule(LockDisciplineRule(), "src/x.py", src, _CONTRACT) == []


def test_lockdiscipline_threadsafe_attrs_exempt():
    src = _svc("self._queue.put(1)", "pass")
    assert run_rule(LockDisciplineRule(), "src/x.py", src, _CONTRACT) == []


def test_lockdiscipline_waived():
    src = _svc("self.count += 1  # reprolint: disable=lockdiscipline",
               "pass")
    found = run_rule(LockDisciplineRule(), "src/x.py", src, _CONTRACT)
    assert found and all(f.waived for f in found)


# -- waiver framework --------------------------------------------------------


def test_scope_waiver_covers_whole_function():
    src = """
    # reprolint: disable=hostsync
    def serve(x):
        a = x.item()
        return float(a)
    """
    found = run_rule(HostSyncRule(), HOT, src)
    assert len(found) == 2 and all(f.waived for f in found)


def test_waiver_trailing_prose_and_multi_rule():
    src = """
    def serve(x):
        a = x.item()  # reprolint: disable=hostsync, retrace  hand-off point
        return a
    """
    found = run_rule(HostSyncRule(), HOT, src)
    assert found and all(f.waived for f in found)


def test_waived_findings_stay_visible():
    """A waiver must never make a finding disappear entirely — stale
    waivers are caught in review because the finding still reports."""
    src = """
    def serve(x):
        return x.item()  # reprolint: disable=hostsync
    """
    found = run_rule(HostSyncRule(), HOT, src)
    assert len(found) == 1
    assert found[0].waived and "item" in found[0].message


# -- dynamic trace audit -----------------------------------------------------


def test_assert_max_traces_flags_fresh_compiles():
    import jax
    import jax.numpy as jnp

    with pytest.raises(AssertionError, match="backend compile"):
        with assert_max_traces(0):
            # a brand-new jitted callable always reaches the backend
            jax.jit(lambda x: x * 3.0 + 41.5)(jnp.ones((3,)))


def test_assert_max_traces_passes_on_cache_hits():
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda x: x * 2.0 - 7.25)
    x = jnp.ones((4,))
    f(x)  # warm
    with assert_max_traces(0) as audit:
        for _ in range(5):
            f(x)
    assert audit.compiles == 0
