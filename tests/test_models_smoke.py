"""Per-assigned-architecture smoke tests (brief requirement).

Each instantiates a REDUCED config of the same family — small layers/width,
few experts, tiny tables, small graphs — and runs one forward/train step on
CPU asserting output shapes + no NaNs.  The FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import graph as graph_data
from repro.models import gnn, recsys, transformer as tfm

RNG = np.random.default_rng(3)


def _shrink_lm(cfg: tfm.TransformerConfig) -> tfm.TransformerConfig:
    moe = cfg.moe and dataclasses.replace(
        cfg.moe, num_experts=4, d_ff=64, period=cfg.moe.period)
    return dataclasses.replace(
        cfg,
        n_layers=2 * (cfg.moe.period if cfg.moe else 1),
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, 4 * cfg.n_kv_heads // cfg.n_heads),
        head_dim=16,
        d_ff=128,
        vocab=256,
        moe=moe,
        param_dtype=jnp.float32,
    )


LM_ARCHS = [a for a in configs.ASSIGNED if configs.get(a).family == "lm"]
REC_ARCHS = [a for a in configs.ASSIGNED if configs.get(a).family == "recsys"]


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_arch_smoke(arch_id):
    spec = configs.get(arch_id)
    cfg = _shrink_lm(spec.make_model(spec.cells[0]))
    params = tfm.init_params(jax.random.key(0), cfg)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    # train step: loss + grads finite
    loss, grads = jax.value_and_grad(tfm.loss_fn)(params, toks, toks, cfg)
    assert jnp.isfinite(loss)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))
    # serve: prefill + one decode step
    cache, logits = tfm.prefill(params, toks, cfg)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    full = tfm.make_cache(cfg, 2, 32)
    full = {
        "k": full["k"].at[:, :, :16].set(cache["k"]),
        "v": full["v"].at[:, :, :16].set(cache["v"]),
        "length": jnp.int32(16),
    }
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    c2, lg2 = tfm.decode_step(params, full, nxt, cfg)
    assert lg2.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg2)))
    assert int(c2["length"]) == 17


def test_lm_param_count_budgets():
    """Full configs land near their nameplate sizes."""
    expect = {
        "phi3-medium-14b": (14e9, None),
        "phi3-mini-3.8b": (3.8e9, None),
        "deepseek-coder-33b": (33e9, None),
        "phi3.5-moe-42b-a6.6b": (42e9, 6.6e9),
        "llama4-maverick-400b-a17b": (400e9, 17e9),
    }
    for arch_id, (want_total, want_active) in expect.items():
        cfg = configs.get(arch_id).make_model(None)
        total, active = cfg.param_count()
        assert abs(total - want_total) / want_total < 0.15, (arch_id, total)
        if want_active:
            assert abs(active - want_active) / want_active < 0.25, (arch_id, active)


def test_gnn_arch_smoke_all_cells():
    spec = configs.get("graphsage-reddit")
    for cell in spec.cells:
        cfg_full = spec.make_model(cell)
        cfg = dataclasses.replace(cfg_full, d_in=12, d_hidden=16, n_classes=5)
        params = gnn.init_params(jax.random.key(0), cfg)
        if cell.kind == "full_graph":
            g = graph_data.make_graph(graph_data.GraphConfig(
                n_nodes=60, n_edges=240, d_feat=12, n_classes=5))
            src, dst = g.edge_list()
            logits = gnn.forward_full(params, g.feats, src, dst, cfg)
            assert logits.shape == (60, 5)
            mask = jnp.ones((60,), jnp.float32)
            loss, grads = jax.value_and_grad(gnn.loss_full)(
                params, g.feats, src, dst, g.labels, mask, cfg)
        elif cell.kind == "minibatch":
            g = graph_data.make_graph(graph_data.GraphConfig(
                n_nodes=100, n_edges=500, d_feat=12, n_classes=5))
            seeds = graph_data.batch_seeds(jax.random.key(1), 100, 8)
            n1, n2 = graph_data.sample_two_hop(
                jax.random.key(2), g.indptr, g.indices, seeds, cfg.fanouts)
            loss, grads = jax.value_and_grad(gnn.loss_sampled)(
                params, g.feats, seeds, n1, n2, g.labels[seeds], cfg)
        else:  # molecule
            mb = graph_data.make_molecule_batch(jax.random.key(3), 4, 10, 20, 12, 5)
            loss, grads = jax.value_and_grad(gnn.loss_batched)(
                params, mb["feats"], mb["src"], mb["dst"], mb["labels"], cfg)
        assert jnp.isfinite(loss), cell.name
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch_id", REC_ARCHS)
def test_recsys_arch_smoke(arch_id):
    spec = configs.get(arch_id)
    full = spec.make_model(None)
    small_table = recsys.TableSpec(
        recsys.criteo_row_counts(full.n_fields, 4096), full.dim)
    cfg = dataclasses.replace(full, table=small_table)
    params = recsys.init_params(jax.random.key(0), cfg)
    b = 8
    rows = np.asarray(small_table.row_counts)
    idx = jnp.asarray(
        RNG.integers(0, rows[None, :, None], (b, cfg.n_fields, cfg.nnz)), jnp.int32)
    dense = (jnp.asarray(RNG.normal(size=(b, cfg.n_dense)), jnp.float32)
             if cfg.n_dense else None)
    logit = recsys.forward(params, cfg, idx, dense)
    assert logit.shape == (b,)
    assert bool(jnp.all(jnp.isfinite(logit)))
    loss, grads = jax.value_and_grad(recsys.bce_loss)(
        params, cfg, idx, jnp.ones((b,)), dense)
    assert jnp.isfinite(loss)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))
    # retrieval path
    u = recsys.user_tower(params, cfg, idx, dense)
    cand = jnp.asarray(RNG.normal(size=(1000, cfg.dim)), jnp.float32)
    s, ids = recsys.retrieval_topk(u, cand, k=10)
    assert s.shape == (b, 10) and bool(jnp.all(ids >= 0))


def test_registry_covers_assignment():
    assert len(configs.ASSIGNED) == 10
    n_cells = sum(len(configs.get(a).cells) for a in configs.ASSIGNED)
    assert n_cells == 40  # the full dry-run matrix
    for a in configs.ASSIGNED:
        spec = configs.get(a)
        assert spec.family in ("lm", "gnn", "recsys")
        assert spec.make_model(spec.cells[0]) is not None
