"""Graph (hnsw) encoding coverage: deterministic build, jit-stable batched
beam search, filtered traversal (masked nodes traversable, never emitted),
segmented-vs-monolithic recall parity through deletes and merge, save/load,
and sharded-build parity (subprocess, 8 fake devices).

The search loop is a fixed-iteration ``fori_loop`` with static ef/beam, so
one compilation serves every same-shape query batch — asserted against the
pipeline jit cache directly.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bruteforce, eval as ev, graph
from repro.core import pipeline as pl
from repro.core.index import AnnIndex
from repro.core.segments import IndexWriter
from repro.core.types import BruteForceConfig, GraphConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# Operating point for the filtered-parity test: at N=2000 / 10% selectivity
# the traversal list must hold enough masked-but-traversable nodes to reach
# every filtered neighborhood (docs/DESIGN.md §15); ef=320/beam=16 keeps
# recall within 0.01 of filtered brute force.
WIDE = GraphConfig(ef=320, beam=16)


def _corpus(n=2000, dim=64, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, dim)).astype(np.float32)
    x += 0.5 * rng.normal(size=(1, dim)).astype(np.float32)
    return x


def test_graph_build_deterministic(small_corpus):
    """Same rows -> bitwise-identical adjacency and entry points: the build
    has no RNG (exact kNN pools + deterministic prune + sort-based reverse
    fill), so two builds must agree exactly."""
    v = bruteforce.l2_normalize(jnp.asarray(small_corpus))
    cfg = GraphConfig()
    nb1, e1 = graph.build_graph(v, cfg)
    nb2, e2 = graph.build_graph(v, cfg)
    np.testing.assert_array_equal(np.asarray(nb1), np.asarray(nb2))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    nb = np.asarray(nb1)
    assert nb.shape == (v.shape[0], cfg.total_degree)
    assert nb.dtype == np.int32
    # no self-loops, ids in range (or -1 padding)
    assert ((nb >= -1) & (nb < v.shape[0])).all()
    assert (nb != np.arange(v.shape[0])[:, None]).all()


def test_graph_search_recall_and_jit_stability(small_corpus):
    """Batched beam search hits high recall at modest ef, and repeated
    same-shape query batches reuse ONE compiled executable (static
    ef/beam/iters + fixed-width loop state -> no retrace)."""
    v = jnp.asarray(small_corpus)
    ann = AnnIndex.build(v, GraphConfig(ef=128, beam=8))
    q = jnp.asarray(small_corpus[:32] + 0.01)
    _, gt_i = bruteforce.exact_topk(v, q, 10, use_kernel=False)
    s, i = ann.search(q, k=10, depth=10)
    assert float(ev.recall_at(gt_i, i)) >= 0.95
    # warm, then assert the pipeline jit cache stops growing
    ann.search(q, k=10, depth=10)
    size = pl._pipeline_search._cache_size()
    for _ in range(3):
        ann.search(jnp.asarray(np.roll(small_corpus[:32], 1, axis=0)),
                   k=10, depth=10)
    assert pl._pipeline_search._cache_size() == size
    # sorted scores, ids valid
    s = np.asarray(s)
    assert (np.diff(s, axis=1) <= 1e-6).all()
    assert ((np.asarray(i) >= 0) & (np.asarray(i) < v.shape[0])).all()


def test_graph_filtered_traversal_parity(small_corpus):
    """10%-selectivity predicate: masked nodes stay traversable (recall
    matches filtered brute force within 0.01) but are NEVER emitted."""
    rng = np.random.default_rng(7)
    v = jnp.asarray(small_corpus)
    n = v.shape[0]
    ann = AnnIndex.build(v, WIDE)
    q = jnp.asarray(_corpus(16, 64, seed=3))
    mask = rng.random(n) < 0.10
    filt = jnp.asarray(mask.astype(np.int32))
    kept = np.flatnonzero(mask)
    _, gt_i = bruteforce.exact_topk(v[jnp.asarray(kept)], q, 10,
                                    use_kernel=False)
    gt_global = kept[np.asarray(gt_i)]
    s, i = ann.search(q, k=10, depth=10, filt=filt)
    i = np.asarray(i)
    emitted = i[i >= 0]
    assert mask[emitted].all(), "masked doc emitted"
    rec = float(ev.recall_at(jnp.asarray(gt_global), jnp.asarray(i)))
    assert rec >= 0.99, rec
    # connectivity: every query fills all k slots from the 10% subset
    assert (i >= 0).all()


def test_graph_segmented_matches_monolithic(small_corpus):
    """Segment lifecycle parity (the acceptance gate): 4 segments + 10%
    deletes, before AND after force-merge, recall@10 within 0.01 of a
    monolithic rebuild over the same live rows at the same ef."""
    rng = np.random.default_rng(5)
    v = np.asarray(small_corpus)
    n = v.shape[0]
    cfg = GraphConfig(ef=192, beam=8)
    w = IndexWriter(cfg)
    for chunk in np.array_split(v, 4):
        w.add(chunk)
        w.flush()
    dels = rng.choice(n, n // 10, replace=False)
    w.delete(dels.tolist())
    live = np.ones(n, bool)
    live[dels] = False
    q = jnp.asarray(_corpus(16, 64, seed=9))
    mono = AnnIndex.build(jnp.asarray(v[live]), cfg)
    oracle = AnnIndex.build(jnp.asarray(v[live]), BruteForceConfig())
    _, gt_i = oracle.search(q, k=10, depth=10)
    _, mono_i = mono.search(q, k=10, depth=100)
    r_mono = float(ev.recall_at(gt_i, mono_i[:, :10]))

    gid_to_live = -np.ones(n, np.int64)
    gid_to_live[live] = np.arange(live.sum())
    reader = w.refresh()
    _, seg_i = reader.search(q, k=10, depth=100)
    seg_i = np.asarray(seg_i)
    assert not np.isin(seg_i[seg_i >= 0], dels).any(), "deleted doc emitted"
    seg_live = np.where(seg_i >= 0, gid_to_live[np.maximum(seg_i, 0)], -1)
    r_seg = float(ev.recall_at(gt_i, jnp.asarray(seg_live[:, :10])))
    assert abs(r_seg - r_mono) <= 0.01, (r_seg, r_mono)

    # merge compacts + remaps ids: merged global ids == live-row order
    w.force_merge(1)
    merged = w.refresh()
    assert merged.num_segments == 1
    _, mrg_i = merged.search(q, k=10, depth=100)
    r_mrg = float(ev.recall_at(gt_i, jnp.asarray(np.asarray(mrg_i)[:, :10])))
    assert abs(r_mrg - r_mono) <= 0.01, (r_mrg, r_mono)


def test_graph_save_load_roundtrip(tmp_path, small_corpus):
    """hnsw persists through the npz+JSON format: loaded index returns
    bitwise-identical results and the same config."""
    v = jnp.asarray(small_corpus[:512])
    ann = AnnIndex.build(v, GraphConfig(ef=64, beam=4))
    path = str(tmp_path / "g.ann")
    ann.save(path)
    back = AnnIndex.load(path)
    assert back.method == "hnsw"
    assert back.config == ann.config
    q = jnp.asarray(small_corpus[:8])
    s1, i1 = ann.search(q, k=10, depth=10)
    s2, i2 = back.search(q, k=10, depth=10)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_graph_scored_candidates_sublinear(small_corpus):
    """Per-query scored-candidate count is bounded by the traversal shape
    (entries + iters * beam * degree), independent of corpus size — the
    sublinearity the Pareto gate in BENCH_9 reports."""
    cfg = GraphConfig(ef=64, beam=4)
    q = jnp.asarray(small_corpus[:8])
    counts = {}
    for n in (1000, 2000):
        v = bruteforce.l2_normalize(jnp.asarray(small_corpus[:n]))
        nb, entry = graph.build_graph(v, cfg)
        _, _, scored = graph.search_graph(
            v, nb, entry, bruteforce.l2_normalize(q), 10,
            ef=cfg.ef, beam=cfg.beam, iters=cfg.search_iters, n_docs=n,
            use_kernel=False, with_stats=True)
        counts[n] = int(np.asarray(scored).max())
    bound = cfg.entries + cfg.search_iters * cfg.beam * cfg.total_degree
    assert counts[1000] <= bound and counts[2000] <= bound, (counts, bound)
    # doubling N must not double the work
    assert counts[2000] <= int(1.2 * counts[1000]) + bound // 10, counts


def test_graph_sharded_build_parity():
    """Distributed build (ring neighbor-exchange under shard_map, 8 fake
    host devices) produces the SAME adjacency and entry points as the
    single-device build — subprocess so this process's jax init stays
    single-device."""
    body = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.core import distributed
        from repro.core.graph import build_graph
        from repro.core.types import GraphConfig
        rng = np.random.default_rng(0)
        v = rng.normal(size=(1024, 64)).astype(np.float32)
        cfg = GraphConfig(ef=128, beam=8)
        mesh = jax.make_mesh((8,), ("data",))
        idx = distributed.build_sharded(mesh, jnp.asarray(v), cfg, ("data",))
        vn = jnp.asarray(v)
        vn = vn / jnp.linalg.norm(vn, axis=1, keepdims=True)
        nb, entry = build_graph(vn, cfg)
        assert np.array_equal(np.asarray(idx.neighbors), np.asarray(nb))
        assert np.array_equal(np.asarray(idx.entry), np.asarray(entry))
        print("sharded graph build parity ok")
    """)
    r = subprocess.run(
        [sys.executable, "-c", body],
        capture_output=True, text=True, timeout=900,
        env=dict(os.environ, PYTHONPATH=SRC),
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"


def test_graph_sharded_search_raises():
    """Shard-local traversal is NOT the graph algorithm (edges cross shard
    boundaries); make_sharded_search must refuse loudly."""
    from repro.core import distributed

    with pytest.raises(TypeError, match="shard-local"):
        distributed.make_sharded_search(None, GraphConfig(), ("data",))
