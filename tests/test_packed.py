"""Packed single-launch segmented search (core/packed.py, docs/DESIGN.md
§14): the packed superbuffer path returns EXACTLY the per-segment loop's
results — ids equal, scores allclose — across segment counts, encodings,
and filters, while the shape-bucketed executable cache keeps recompiles
bounded across refresh cycles.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bruteforce
from repro.core import packed as packed_mod
from repro.core.segments import IndexWriter
from repro.core.types import FakeWordsConfig, KdTreeConfig, LexicalLshConfig
from tools.reprolint.trace_audit import assert_max_traces

# The encodings the ISSUE's parity matrix names: classic fp32 postings,
# dot-mode int8 postings, int4 quantized-classic postings, LSH signatures.
MATRIX = [
    ("classic", FakeWordsConfig(quantization=50), "fp32", "exact"),
    ("dot-int8", FakeWordsConfig(quantization=50, scoring="dot"), "int8", "int8"),
    ("int4", FakeWordsConfig(quantization=50), "int4", "exact"),
    ("lsh", LexicalLshConfig(buckets=64, hashes=2), "fp32", "exact"),
]


def _writer(cfg, postings, store, n_segments, rng, dim=32, seg_docs=40):
    w = IndexWriter(
        cfg, rerank_store=store, primary_postings=postings,
        merge_policy=None, use_kernel=False,
    )
    for _ in range(n_segments):
        w.add(rng.normal(size=(seg_docs, dim)).astype(np.float32))
        w.flush()
    return w


def _assert_packed_equals_loop(reader, queries, fm=None, k=10, depth=50):
    for rerank in (False, True):
        s0, i0 = reader.search(
            queries, k=k, depth=depth, rerank=rerank, packed=False,
            filter_mask=fm,
        )
        s1, i1 = reader.search(
            queries, k=k, depth=depth, rerank=rerank, packed=True,
            filter_mask=fm,
        )
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(
            np.asarray(s0), np.asarray(s1), rtol=1e-5, atol=1e-6
        )


@pytest.mark.parametrize("n_segments", [1, 4, 16])
@pytest.mark.parametrize(
    "name,cfg,postings,store", MATRIX, ids=[m[0] for m in MATRIX]
)
def test_packed_parity(name, cfg, postings, store, n_segments, rng):
    """Packed single-launch == per-segment loop: exact ids, allclose
    scores, rerank on and off — unfiltered AND under deletes ∧ predicate."""
    w = _writer(cfg, postings, store, n_segments, rng)
    reader = w.refresh()
    queries = jnp.asarray(rng.normal(size=(6, 32)).astype(np.float32))
    _assert_packed_equals_loop(reader, queries)

    # Deletes ∧ predicate: drop 10% of docs, keep a random 70% predicate.
    n = reader.max_doc
    w.delete(rng.choice(n, size=max(1, n // 10), replace=False))
    reader = w.refresh()
    fm = jnp.asarray(rng.random(n) < 0.7)
    _assert_packed_equals_loop(reader, queries, fm=fm)


def test_packed_parity_per_query_filter(rng):
    """(B, max_doc) per-query predicate bitmaps ride the packed path too."""
    w = _writer(FakeWordsConfig(quantization=50), "fp32", "exact", 4, rng)
    reader = w.refresh()
    queries = jnp.asarray(rng.normal(size=(5, 32)).astype(np.float32))
    fm = jnp.asarray(rng.random((5, reader.max_doc)) < 0.6)
    _assert_packed_equals_loop(reader, queries, fm=fm)


def test_packed_kdtree_scan_parity(rng):
    """The kd-scan encoding (global-stats refit) packs and matches too."""
    w = _writer(
        KdTreeConfig(dims=8, backend="scan"), "fp32", "exact", 4, rng
    )
    reader = w.refresh()
    queries = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    _assert_packed_equals_loop(reader, queries)


def test_bucket_ladder():
    assert packed_mod.bucket_rows(1) == 256
    assert packed_mod.bucket_rows(256) == 256
    assert packed_mod.bucket_rows(257) == 384
    assert packed_mod.bucket_rows(600) == 768
    assert packed_mod.bucket_rows(769) == 1024
    assert packed_mod.bucket_rows(1025) == 1536
    # ladder overhead never exceeds 50% (geometric with 1.5x midpoints)
    for n in range(1, 5000, 37):
        b = packed_mod.bucket_rows(n)
        assert n <= b <= max(256, int(n * 1.5))


def test_recompile_guard(rng):
    """≤ 1 search compile per (bucket, encoding) across 10 NRT refresh
    cycles — asserted on ACTUAL backend-compile events via the trace
    audit, not the executable cache's own bookkeeping (which cannot see
    retraces that bypass it)."""
    cache = packed_mod.EXEC_CACHE
    cache.clear()
    cfg = LexicalLshConfig(buckets=64, hashes=2)
    # 560 docs -> bucket 768 with room for all nine 8-row appends in the
    # preferred 128-row block rung (no rung narrowing inside this test —
    # that edge has its own test below).
    w = _writer(cfg, "fp32", "exact", 1, rng, seg_docs=560)
    queries = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))

    def cycle(i):
        if i:
            w.add(rng.normal(size=(8, 32)).astype(np.float32))
            w.flush()
        reader = w.refresh()
        reader.search(queries, k=10, depth=50, packed=True)
        assert reader.packed_segments().bucket == 768

    # Cycle 0 compiles the search executable; cycle 1 adds the donated
    # append executable.  Everything after must reuse both.
    cycle(0)
    cycle(1)
    with assert_max_traces(0, "steady-state NRT cycles inside one bucket"):
        for i in range(2, 10):
            cycle(i)
    assert cache.hits >= 8, cache.stats()


def test_append_rung_narrowing(rng):
    """Near the top of a bucket the donated append narrows its block rung
    (128 -> 64 -> ...) instead of falling back to full repacks — which
    would recompile a growing-arity concatenate on EVERY later refresh.
    The narrower rung costs one compile burst; after that, steady state is
    compile-free again."""
    cfg = LexicalLshConfig(buckets=64, hashes=2)
    # 700 docs -> bucket 768: only 68 rows of room, so the preferred
    # 128-row rung never fits and appends must narrow (64, then 32).
    w = _writer(cfg, "fp32", "exact", 1, rng, seg_docs=700)
    queries = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))

    def cycle():
        w.add(rng.normal(size=(8, 32)).astype(np.float32))
        w.flush()
        reader = w.refresh()
        reader.search(queries, k=10, depth=50, packed=True)
        return reader.packed_segments()

    w.refresh().search(queries, k=10, depth=50, packed=True)  # warm search
    for _ in range(3):  # rungs 64, 32, 32(hit)
        pk = cycle()
    assert pk.bucket == 768
    assert pk.appends == 3, "appends near the bucket edge must absorb"
    with assert_max_traces(0, "warmed narrow rung must be a cache hit"):
        pk = cycle()
    assert pk.appends == 4


def test_donated_incremental_append(rng):
    """Append-only refreshes of a stats-static encoding absorb the prior
    snapshot's packed buffers in place instead of re-concatenating."""
    cfg = LexicalLshConfig(buckets=64, hashes=2)
    # 600 docs -> bucket 768, and 620 stays in the same rung with room
    # for the 128-row append block.
    w = _writer(cfg, "fp32", "exact", 1, rng, seg_docs=600)
    r0 = w.refresh()
    assert r0.packed_segments().appends == 0
    w.add(rng.normal(size=(20, 32)).astype(np.float32))
    w.flush()
    r1 = w.refresh()
    pk = r1.packed_segments()
    assert pk.appends == 1  # donated dynamic_update_slice, not a repack
    queries = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    _assert_packed_equals_loop(r1, queries)
    # The donation neutered the old reader's pack; it lazily repacks.
    assert r0._packed is None
    r0_again = r0.packed_segments()
    assert r0_again is not None and r0_again.appends == 0
    _assert_packed_equals_loop(r0, queries)


def test_classic_repacks_fully_and_stays_exact(rng):
    """Classic scoring rebuilds per-row state under new global idf, so a
    refresh must NOT incrementally append — and stays loop-exact."""
    cfg = FakeWordsConfig(quantization=50)
    w = _writer(cfg, "fp32", "exact", 2, rng)
    r0 = w.refresh()
    r0.packed_segments()
    w.add(rng.normal(size=(30, 32)).astype(np.float32))
    w.flush()
    r1 = w.refresh()
    pk = r1.packed_segments()
    assert pk.appends == 0
    queries = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    _assert_packed_equals_loop(r1, queries)


def test_packed_false_forces_loop_and_env_kill_switch(rng, monkeypatch):
    """packed=False serves the reference loop; REPRO_PACKED=0 flips the
    process default (checked via the module flag, set at import)."""
    w = _writer(FakeWordsConfig(quantization=50), "fp32", "exact", 2, rng)
    reader = w.refresh()
    queries = jnp.asarray(rng.normal(size=(3, 32)).astype(np.float32))
    reader.search(queries, packed=False)
    assert reader._packed is None  # the loop never built the superbuffer
    reader.search(queries, packed=True)
    assert reader._packed is not None


def test_packed_blockmax_exact_at_full_keep(rng):
    """blockmax_keep = every block is a pure reshuffle of the exact scan:
    segmented blockmax (over the packed view) == the unpruned loop."""
    for cfg in (
        FakeWordsConfig(quantization=50),
        LexicalLshConfig(buckets=64, hashes=2),
    ):
        w = _writer(cfg, "fp32", "exact", 4, rng, seg_docs=40)
        reader = w.refresh()
        queries = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
        s0, i0 = reader.search(queries, k=10, depth=50, packed=False)
        pk = reader.packed_segments()
        keep = pk.bucket // 64  # block_size=64 -> keep ALL blocks
        s1, i1 = reader.search(
            queries, k=10, depth=50, packed=True,
            blockmax_keep=keep, blockmax_block_size=64,
        )
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(
            np.asarray(s0), np.asarray(s1), rtol=1e-5, atol=1e-6
        )


def test_packed_static_rows_bound(rng):
    """static_rows=True masks pad rows through the kernels' static n_docs
    bound instead of a bitmap — same results (shape-static callers)."""
    w = _writer(LexicalLshConfig(buckets=64, hashes=2), "fp32", "exact",
                2, rng, seg_docs=150)  # 300 rows, bucket 384: padded tail
    reader = w.refresh()
    pk = reader.packed_segments()
    assert pk.n_rows < pk.bucket and not pk.any_deleted
    q = bruteforce.l2_normalize(
        jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32)))
    s1, i1 = packed_mod.packed_search(
        pk, reader.pipeline, reader._packed_matcher(), q,
        k=10, depth=50, rerank=False, quantized=False, use_kernel=False,
        static_rows=True,
    )
    s0, i0 = reader.search(q, k=10, depth=50, packed=False)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=1e-5)


def test_packed_unsupported_falls_back_and_true_raises(rng):
    """global_stats=False (per-segment statistics) cannot pack for
    fake-words: the default silently serves the loop, packed=True raises
    with the reason."""
    cfg = FakeWordsConfig(quantization=50)
    w = IndexWriter(cfg, merge_policy=None, use_kernel=False,
                    global_stats=False)
    w.add(np.random.default_rng(1).normal(size=(80, 32)).astype(np.float32))
    w.flush()
    w.add(np.random.default_rng(2).normal(size=(60, 32)).astype(np.float32))
    w.flush()
    reader = w.refresh()
    queries = jnp.asarray(
        np.random.default_rng(3).normal(size=(3, 32)).astype(np.float32))
    s, i = reader.search(queries, k=5, depth=20)  # default: falls back
    assert reader.packed_segments() is None and reader._packed_err
    with pytest.raises(ValueError, match="packed single-launch"):
        reader.search(queries, k=5, depth=20, packed=True)


def test_packed_sharded_composition(rng):
    """make_packed_segmented_search: pack -> doc-shard -> pod fan-out with
    the live∧predicate bitmap sharded with the rows (subprocess with 8
    fake host devices, like tests/test_distributed.py)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import bruteforce, distributed
        from repro.core.segments import IndexWriter
        from repro.core.types import FakeWordsConfig

        rng = np.random.default_rng(0)
        w = IndexWriter(FakeWordsConfig(quantization=50), merge_policy=None,
                        use_kernel=False)
        w.add(rng.normal(size=(300, 32)).astype(np.float32)); w.flush()
        w.add(rng.normal(size=(212, 32)).astype(np.float32)); w.flush()
        w.delete(rng.choice(512, size=40, replace=False))
        reader = w.refresh()  # 512 rows -> bucket 512: divisible by 4
        queries = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
        mesh = jax.make_mesh((4,), ("data",))
        fn, idx_sh, filt_sh = distributed.make_packed_segmented_search(
            mesh, reader, ("data",), k=10, depth=50, rerank=True,
            use_kernel=False)
        q_rep = reader.encode_queries(queries)
        s_sh, i_sh = fn(idx_sh, q_rep, bruteforce.l2_normalize(queries),
                        filt_sh)
        s_1, i_1 = reader.search(queries, k=10, depth=50, rerank=True,
                                 packed=False)
        # Rerank fp rounding differs per shard partition; like the other
        # sharded suites, assert set overlap + score closeness, not
        # bitwise id order.
        from repro.core import eval as ev
        ov = float(ev.overlap(i_1, i_sh))
        assert ov >= 0.95, ov
        np.testing.assert_allclose(np.asarray(s_1)[:, :8],
                                   np.asarray(s_sh)[:, :8],
                                   rtol=1e-4, atol=1e-5)
        print("packed sharded ok", ov)
    """)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=900, env=dict(os.environ, PYTHONPATH=src),
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
