"""dlrm-rm2 [arXiv:1906.00091]: 13 dense + 26 sparse fields, embed_dim=64,
bot_mlp=13-512-256-64, top_mlp=512-512-256-1, dot interaction."""
from repro.configs.common import ArchSpec, RECSYS_CELLS
from repro.models.recsys import RecsysConfig, TableSpec, criteo_row_counts

# RM-2 class tables: ~54M rows x 64 — the 13.8 GB table is the model.
TABLE = TableSpec(criteo_row_counts(26, 53_687_091), 64)


def make_model(cell=None) -> RecsysConfig:
    return RecsysConfig(
        name="dlrm-rm2",
        model="dlrm",
        table=TABLE,
        nnz=1,
        n_dense=13,
        bot_mlp=(512, 256, 64),
        top_mlp=(512, 512, 256, 1),
    )


ARCH = ArchSpec(
    id="dlrm-rm2",
    family="recsys",
    make_model=make_model,
    cells=RECSYS_CELLS,
    optimizer="adamw",
    source="arXiv:1906.00091",
)
