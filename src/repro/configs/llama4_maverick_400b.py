"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4 family]: 48L d=5120
40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts top-1.

Interpretation (DESIGN.md §6): all-layer MoE would give ~780B total,
contradicting the 400B name; Llama-4 interleaves MoE every other layer
(moe period=2), giving ~394B total / ~17B active — matching 400b-a17b.
bf16 params + Adafactor keep states inside the pod's 4 TB HBM.
"""
import jax.numpy as jnp

from repro.configs.common import ArchSpec, LM_CELLS
from repro.models.transformer import MoEConfig, TransformerConfig


def make_model(cell=None) -> TransformerConfig:
    return TransformerConfig(
        name="llama4-maverick-400b-a17b",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,  # dense (non-MoE) layers are 2x wider (Maverick)
        vocab=202048,
        moe=MoEConfig(num_experts=128, top_k=1, d_ff=8192, period=2,
                      shared_expert=True),
        param_dtype=jnp.bfloat16,  # 394B params: f32 would not fit one pod
    )


ARCH = ArchSpec(
    id="llama4-maverick-400b-a17b",
    family="lm",
    make_model=make_model,
    cells=LM_CELLS,
    optimizer="adafactor",
    source="hf:meta-llama/Llama-4-Scout-17B-16E (family)",
    notes="moe_layer_period=2 + shared-expert + 16384-wide dense FFN "
    "interpretation: yields 400.6B total / 17.2B active, matching the "
    "nameplate; early-fusion frontend stubbed (input_specs provide token "
    "ids; vision patches would enter as embeddings)",
)
