"""fm [Rendle, ICDM'10]: 39 sparse fields, embed_dim=10, 2-way FM
interaction via the O(nk) sum-square trick."""
from repro.configs.common import ArchSpec, RECSYS_CELLS
from repro.models.recsys import RecsysConfig, TableSpec, criteo_row_counts

# Criteo-scale id spaces: 39 fields, ~33.6M total rows (power-law split —
# a few multi-million-row fields plus a long tail).
TABLE = TableSpec(criteo_row_counts(39, 33_554_432), 10)


def make_model(cell=None) -> RecsysConfig:
    return RecsysConfig(name="fm", model="fm", table=TABLE, nnz=1)


ARCH = ArchSpec(
    id="fm",
    family="recsys",
    make_model=make_model,
    cells=RECSYS_CELLS,
    optimizer="adamw",
    source="ICDM'10 (Rendle)",
)
