"""phi3-medium-14b [arXiv:2404.14219]: 40L d=5120 40H (GQA kv=10)
d_ff=17920 vocab=100352 — RoPE SwiGLU GQA."""
from repro.configs.common import ArchSpec, LM_CELLS
from repro.models.transformer import TransformerConfig


def make_model(cell=None) -> TransformerConfig:
    return TransformerConfig(
        name="phi3-medium-14b",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=10,
        head_dim=128,
        d_ff=17920,
        vocab=100352,
    )


ARCH = ArchSpec(
    id="phi3-medium-14b",
    family="lm",
    make_model=make_model,
    cells=LM_CELLS,
    optimizer="adamw",
    source="arXiv:2404.14219",
)
