"""phi3-mini-3.8b [arXiv:2404.14219]: 32L d=3072 32H (kv=32 -> MHA)
d_ff=8192 vocab=32064."""
from repro.configs.common import ArchSpec, LM_CELLS
from repro.models.transformer import TransformerConfig


def make_model(cell=None) -> TransformerConfig:
    return TransformerConfig(
        name="phi3-mini-3.8b",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,  # GQA group 1 == MHA (spec: kv=32)
        head_dim=96,
        d_ff=8192,
        vocab=32064,
    )


ARCH = ArchSpec(
    id="phi3-mini-3.8b",
    family="lm",
    make_model=make_model,
    cells=LM_CELLS,
    optimizer="adamw",
    source="arXiv:2404.14219",
)
