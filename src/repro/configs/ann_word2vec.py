"""Paper-own config: fake-words ANN over a word2vec-scale corpus
(3M x 300, GoogleNews-sized)."""
from repro.configs.common import ArchSpec, Cell
from repro.core.types import FakeWordsConfig

CELLS = (
    Cell("ann_search", "ann_search", batch=256, extra={
        "n_docs": 2_999_808,  # 3M rounded to a 512-divisible doc count
        "dim": 300, "depth": 100, "k": 10,
    }),
)


def make_model(cell=None) -> FakeWordsConfig:
    return FakeWordsConfig(quantization=50, scoring="classic", df_max_ratio=1.0)


ARCH = ArchSpec(
    id="ann-word2vec",
    family="ann",
    make_model=make_model,
    cells=CELLS,
    source="paper §3 (word2vec GoogleNews 3M x 300)",
)
