"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct]: 32L d=4096
32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2 (every layer)."""
from repro.configs.common import ArchSpec, LM_CELLS
from repro.models.transformer import MoEConfig, TransformerConfig


def make_model(cell=None) -> TransformerConfig:
    return TransformerConfig(
        name="phi3.5-moe-42b-a6.6b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6400,  # unused (all layers MoE); kept for the record
        vocab=32064,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff=6400, period=1),
    )


ARCH = ArchSpec(
    id="phi3.5-moe-42b-a6.6b",
    family="lm",
    make_model=make_model,
    cells=LM_CELLS,
    optimizer="adafactor",  # factored 2nd moments: 42B opt state fits the pod
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
