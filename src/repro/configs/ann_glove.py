"""Paper-own config: fake-words ANN over a GloVe-Twitter-scale corpus
(1.2M x 300)."""
from repro.configs.common import ArchSpec, Cell
from repro.core.types import FakeWordsConfig

CELLS = (
    Cell("ann_search", "ann_search", batch=256, extra={
        "n_docs": 1_193_472,  # 1.2M rounded to a 512-divisible doc count
        "dim": 300, "depth": 100, "k": 10,
    }),
)


def make_model(cell=None) -> FakeWordsConfig:
    return FakeWordsConfig(quantization=50, scoring="classic", df_max_ratio=1.0)


ARCH = ArchSpec(
    id="ann-glove",
    family="ann",
    make_model=make_model,
    cells=CELLS,
    source="paper §3 (GloVe Twitter 1.2M x 300)",
)
