"""xdeepfm [arXiv:1803.05170]: 39 sparse fields, embed_dim=10,
cin_layers=200-200-200, mlp=400-400, CIN interaction."""
from repro.configs.common import ArchSpec, RECSYS_CELLS
from repro.models.recsys import RecsysConfig, TableSpec, criteo_row_counts

TABLE = TableSpec(criteo_row_counts(39, 33_554_432), 10)


def make_model(cell=None) -> RecsysConfig:
    return RecsysConfig(
        name="xdeepfm",
        model="xdeepfm",
        table=TABLE,
        nnz=1,
        mlp=(400, 400),
        cin_layers=(200, 200, 200),
    )


ARCH = ArchSpec(
    id="xdeepfm",
    family="recsys",
    make_model=make_model,
    cells=RECSYS_CELLS,
    optimizer="adamw",
    source="arXiv:1803.05170",
)
