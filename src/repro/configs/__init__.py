"""Architecture registry: ``get(arch_id)`` / ``all_ids()``.

Ten assigned architectures + the paper's own ANN configs; every cell of the
dry-run matrix is (ARCHES[id], cell) — see launch/cells.py.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.common import ArchSpec, Cell

_MODULES = [
    "phi3_medium_14b",
    "phi3_mini_3_8b",
    "deepseek_coder_33b",
    "phi3_5_moe_42b",
    "llama4_maverick_400b",
    "graphsage_reddit",
    "fm",
    "deepfm",
    "dlrm_rm2",
    "xdeepfm",
    "ann_word2vec",
    "ann_glove",
    "ann_web1b",
]


def _load() -> Dict[str, ArchSpec]:
    out = {}
    for m in _MODULES:
        arch = importlib.import_module(f"repro.configs.{m}").ARCH
        out[arch.id] = arch
    return out


ARCHES: Dict[str, ArchSpec] = _load()

# The ten assigned architectures (the 40-cell dry-run matrix).
ASSIGNED: List[str] = [a for a in ARCHES if not a.startswith("ann-")]


def get(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHES)}")
    return ARCHES[arch_id]


def all_ids(include_ann: bool = True) -> List[str]:
    return list(ARCHES) if include_ann else list(ASSIGNED)
