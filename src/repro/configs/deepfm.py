"""deepfm [arXiv:1703.04247]: 39 sparse fields, embed_dim=10,
mlp=400-400-400, FM interaction (shared embeddings)."""
from repro.configs.common import ArchSpec, RECSYS_CELLS
from repro.models.recsys import RecsysConfig, TableSpec, criteo_row_counts

TABLE = TableSpec(criteo_row_counts(39, 33_554_432), 10)


def make_model(cell=None) -> RecsysConfig:
    return RecsysConfig(
        name="deepfm", model="deepfm", table=TABLE, nnz=1, mlp=(400, 400, 400)
    )


ARCH = ArchSpec(
    id="deepfm",
    family="recsys",
    make_model=make_model,
    cells=RECSYS_CELLS,
    optimizer="adamw",
    source="arXiv:1703.04247",
)
