"""Beyond-paper scale config: fake-words ANN over a 1B-document corpus —
the pod-scale workload that motivates the TPU adaptation (DESIGN.md §2).

dot scoring (int8 index only, no bf16 scored matrix): 1B x 600 int8 =
600 GB tf matrix + 1.2 TB originals (bf16) for rerank, sharded over all
mesh axes.
"""

from repro.configs.common import ArchSpec, Cell
from repro.core.types import FakeWordsConfig

CELLS = (
    Cell("ann_search", "ann_search", batch=256, extra={
        "n_docs": 1_073_741_824, "dim": 300, "depth": 100, "k": 10,
        "rerank_dtype": "bfloat16",
    }),
)


def make_model(cell=None) -> FakeWordsConfig:
    return FakeWordsConfig(quantization=50, scoring="dot", df_max_ratio=1.0,
                           signed_store=True)


ARCH = ArchSpec(
    id="ann-web1b",
    family="ann",
    make_model=make_model,
    cells=CELLS,
    source="beyond-paper scale target (1B docs)",
)
