"""graphsage-reddit [arXiv:1706.02216]: 2 layers, d_hidden=128, mean
aggregator, sample_sizes=25-10.

The GNN shape cells pin the GRAPH, so feature/class dims come from the
cell (Cora / Reddit / ogbn-products / synthetic molecules); the
architecture (2x128 mean-SAGE) is constant.
"""
from repro.configs.common import ArchSpec, Cell
from repro.models.gnn import SageConfig

CELLS = (
    # Cora: full-batch
    Cell("full_graph_sm", "full_graph", extra={
        "n_nodes": 2_708, "n_edges": 10_556, "d_feat": 1_433, "n_classes": 7,
    }),
    # Reddit: sampled training, fanout 15-10 per the assignment
    Cell("minibatch_lg", "minibatch", batch=1024, extra={
        "n_nodes": 232_965, "n_edges": 114_615_892, "d_feat": 602,
        "n_classes": 41, "fanouts": (15, 10),
    }),
    # ogbn-products: full-batch large
    Cell("ogb_products", "full_graph", extra={
        "n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100,
        "n_classes": 47,
    }),
    # batched small graphs
    Cell("molecule", "molecule", batch=128, extra={
        "n_nodes": 30, "n_edges": 64, "d_feat": 32, "n_classes": 2,
    }),
)


def make_model(cell: Cell) -> SageConfig:
    assert cell is not None, "GNN model dims depend on the cell's graph"
    fanouts = tuple(cell.get("fanouts", (25, 10)))
    return SageConfig(
        name="graphsage-reddit",
        n_layers=2,
        d_in=cell.get("d_feat"),
        d_hidden=128,
        n_classes=cell.get("n_classes"),
        aggregator="mean",
        fanouts=fanouts,
    )


ARCH = ArchSpec(
    id="graphsage-reddit",
    family="gnn",
    make_model=make_model,
    cells=CELLS,
    optimizer="adamw",
    source="arXiv:1706.02216",
)
