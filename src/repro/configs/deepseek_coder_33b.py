"""deepseek-coder-33b [arXiv:2401.14196]: 62L d=7168 56H (GQA kv=8)
d_ff=19200 vocab=32256 — llama arch."""
from repro.configs.common import ArchSpec, LM_CELLS
from repro.models.transformer import TransformerConfig


def make_model(cell=None) -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-coder-33b",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=19200,
        vocab=32256,
    )


ARCH = ArchSpec(
    id="deepseek-coder-33b",
    family="lm",
    make_model=make_model,
    cells=LM_CELLS,
    optimizer="adamw",
    source="arXiv:2401.14196",
)
