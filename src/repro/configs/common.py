"""Config schema: an architecture = model hyperparams + its shape cells.

Each assigned architecture file exports ``ARCH: ArchSpec`` with the EXACT
published configuration plus the input-shape cells assigned to its family.
``make_model(cell)`` builds the model config (GNN feature dims vary per
cell; LM/recsys models are cell-independent).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Cell:
    """One (input-shape) cell of the dry-run matrix."""

    name: str
    kind: str  # train | prefill | decode | serve | retrieval |
    #            full_graph | minibatch | molecule | ann_search
    batch: int = 0
    seq: int = 0
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def get(self, key: str, default=None):
        return self.extra.get(key, default)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    id: str
    family: str  # "lm" | "gnn" | "recsys" | "ann"
    make_model: Callable[[Optional[Cell]], Any]
    cells: Tuple[Cell, ...]
    optimizer: str = "adamw"  # "adamw" | "adafactor"
    source: str = ""
    notes: str = ""

    def cell(self, name: str) -> Cell:
        for c in self.cells:
            if c.name == name:
                return c
        raise KeyError(f"{self.id} has no cell {name!r}; have {[c.name for c in self.cells]}")


# The four LM shapes shared by all five LM architectures.
LM_CELLS = (
    Cell("train_4k", "train", batch=256, seq=4096),
    Cell("prefill_32k", "prefill", batch=32, seq=32768),
    Cell("decode_32k", "decode", batch=128, seq=32768),
    # long_500k: O(L) decode against a length-sharded KV cache (engineering
    # feasibility; full-attention archs — see DESIGN.md §6 caveat).
    Cell("long_500k", "decode", batch=1, seq=524288, extra={"long": True}),
)

# The four recsys shapes shared by all four recsys architectures.
RECSYS_CELLS = (
    Cell("train_batch", "train", batch=65536),
    Cell("serve_p99", "serve", batch=512),
    Cell("serve_bulk", "serve", batch=262144),
    Cell("retrieval_cand", "retrieval", batch=1, extra={"n_candidates": 1_000_000}),
)
