"""JAX version-compat shims (non-Pallas; the Pallas ones live in
``kernels/common.py``).

The codebase is written against the current JAX API surface; this module
backfills the handful of names that moved between the 0.4.x line the CI pins
and newer releases:

  * ``shard_map`` — ``jax.shard_map`` (new) vs
    ``jax.experimental.shard_map.shard_map`` (0.4.x), where the replication
    check kwarg is spelled ``check_vma`` vs ``check_rep``;
  * ``set_mesh`` — ``jax.set_mesh(mesh)`` (new) vs entering the mesh's own
    context manager (0.4.x).
"""
from __future__ import annotations

import contextlib
from typing import Any, Iterator

import jax


def shard_map(
    f: Any, *, mesh: Any, in_specs: Any, out_specs: Any, check_vma: bool = True
) -> Any:
    """Dispatch to whichever shard_map this JAX exposes."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def axis_size(axis_name: Any) -> Any:
    """``jax.lax.axis_size`` (new) with a ``psum(1, axis)`` fallback for
    0.4.x (traced rather than static, which every call site tolerates)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    import jax.numpy as jnp

    return jax.lax.psum(jnp.int32(1), axis_name)


@contextlib.contextmanager
def set_mesh(mesh: Any) -> Iterator[Any]:
    """Context manager form of ``jax.set_mesh`` that also works on 0.4.x
    (where entering the Mesh object itself sets the ambient mesh)."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh
