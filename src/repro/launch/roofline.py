import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# (module may also be imported from dryrun, which already set the flag)

"""Roofline-term extraction (EXPERIMENTS.md §Roofline).

XLA's HLO cost analysis counts a while-loop body ONCE regardless of trip
count, so scanned layer stacks under-report flops/bytes by ~n_layers.  For
LM cells we therefore lower two shallow UNROLLED variants (depth d1, d2)
and extrapolate linearly to the full depth:

    per_layer = (cost(d2) - cost(d1)) / (d2 - d1)
    total     = cost(d1) + per_layer * (L - d1)

which is exact because every per-layer cost term is layer-linear.  GNN /
recsys / ANN cells have no layer scans — their single lowering is already
exact.  Collective bytes always come from the HLO parser
(launch/hlo_collectives.py), which multiplies while-loop trip counts.

Terms (per device; cost_analysis of an SPMD module is per-device):

    compute    = flops / 197e12          (bf16 peak / chip)
    memory     = bytes / 819e9           (HBM bw / chip)
    collective = wire_bytes / 100e9      (2 usable ICI links x 50 GB/s)

Analysis-mode fidelity notes: blockwise attention lowers with 8192-token
blocks (the unrolled 32k x 1k grid would explode the HLO); the memory term
for prefill cells reflects that tiling.
"""
import argparse
import dataclasses
import json
import time
from typing import Dict


from repro import configs
from repro.configs.common import ArchSpec, Cell
from repro.launch import cells as cells_mod
from repro.launch import hlo_collectives
from repro.launch.mesh import make_production_mesh

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 2 * 50e9

_ANALYSIS_BLOCK = 8192


def model_bytes(arch, cell, n_dev: int) -> float:
    """Analytic per-device HBM traffic (fused-TPU estimate).

    XLA:CPU's cost_analysis 'bytes accessed' sums every unfused op's
    operands — a 10-30x overestimate of TPU HBM traffic where fusion keeps
    intermediates in VMEM/registers.  This model counts only buffers that
    MUST cross HBM: parameters (+grad/opt traffic for train), the residual
    stream per layer, attention KV, the KV cache / index / embedding-table
    streams.  Reported alongside the raw HLO bytes; the §Roofline memory
    term uses this estimate (EXPERIMENTS.md documents both).
    """
    fam = arch.family
    if fam == "lm":
        cfg = arch.make_model(cell)
        total, active = cfg.param_count()
        pbytes = 2 if cfg.param_dtype.__name__ == "bfloat16" else 4
        d = cfg.d_model
        if cell.kind == "train":
            b_loc = cell.batch / (n_dev / 16)  # batch rows per device (model axis excluded)
            acts = cfg.n_layers * b_loc * cell.seq * d * 2 * 2  # ckpt w+r (bf16)
            # params: fwd read + bwd read (remat re-read) + grad write + opt r/w
            par = active / n_dev * 16 * pbytes  # model-axis shard resident per device... conservative: full pass over local shards
            par_traffic = (total / n_dev) * (3 * pbytes + 3 * 4)
            logits = b_loc * cell.seq * (cfg.vocab / 16) * 4 * 3
            return par_traffic + acts + logits
        if cell.kind == "prefill":
            b_loc = cell.batch / (n_dev / 16)
            acts = cfg.n_layers * b_loc * cell.seq * d * 2 * 2
            kv = cfg.n_layers * b_loc * cell.seq * cfg.n_kv_heads * cfg.dh * 2 * 2
            # blockwise attention re-reads KV nq times per layer
            nq = max(1, cell.seq // _ANALYSIS_BLOCK)
            kv_reread = kv * nq / 2
            par = (active / n_dev) * pbytes
            return par + acts + kv + kv_reread
        # decode: stream the whole local cache once + params once
        cache = 2 * cfg.n_layers * cell.batch * cell.seq * cfg.n_kv_heads * cfg.dh * 2 / n_dev
        par = (active / n_dev) * pbytes
        return cache + par
    if fam == "gnn":
        cfg = arch.make_model(cell)
        if cell.kind == "full_graph":
            n, e = cell.get("n_nodes"), cell.get("n_edges")
            # gather features per edge (dominant), 2 layers fwd + bwd ~ 3x
            msg = (e / n_dev) * (cfg.d_in + cfg.d_hidden) * 4 * 3
            nodes = n * (cfg.d_in + 2 * cfg.d_hidden) * 4 * 3  # replicated acts
            return msg + nodes
        if cell.kind == "minibatch":
            b = cell.batch / n_dev
            f1, f2 = cfg.fanouts
            return b * (1 + f1 + f1 * f2) * cfg.d_in * 4 * 3
        g = cell.batch / (n_dev / 16)
        return g * cell.get("n_nodes") * cfg.d_in * 4 * 3
    if fam == "recsys":
        cfg = arch.make_model(cell)
        f, dim = cfg.n_fields, cfg.dim
        if cell.kind == "retrieval":
            n_cand = cell.get("n_candidates")
            return (n_cand / n_dev) * dim * 4  # stream candidates once
        b = cell.batch / (n_dev / 16)
        look = b * f * cfg.nnz * dim * 4  # gathered rows
        mlpw = sum(
            4 * a * bb for a, bb in zip(
                (f * dim,) + tuple(cfg.mlp), tuple(cfg.mlp) + (1,))
        ) if cfg.mlp else 0
        act = b * f * dim * 4 * 3
        if cell.kind == "train":
            # embedding grad scatter + adamw moments over touched rows
            return 3 * look + act + 3 * mlpw
        return look + act + mlpw
    # ann: stream the local index slice once.  Scoring is fused with the
    # running top-d merge (core/distributed._local_topk_tiled), so the
    # (B, N_local) score matrix never crosses HBM; signed_store halves the
    # dot-mode matrix width.
    cell_n = cell.get("n_docs") / n_dev
    m2 = 2 * cell.get("dim")
    cfgm = arch.make_model(cell)
    if cfgm.scoring == "classic":
        per_doc = m2 * (1 + 2)  # int8 tf + bf16 scored
    else:
        per_doc = (m2 // 2) if getattr(cfgm, "signed_store", False) else m2
    tile = 262_144
    scores = cell.batch * min(cell_n, tile) * 4  # one resident tile
    return cell_n * per_doc + scores


def _lower_costs(built: cells_mod.CellBuild, n_dev: int) -> Dict[str, float]:
    compiled = built.lower().compile()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = hlo_collectives.collective_bytes(text, n_dev)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": coll["total"],
        "coll_by_kind": {k: v for k, v in coll.items() if k != "total"},
    }


def _lm_depth_variant(arch: ArchSpec, cell: Cell, mesh, multi_pod: bool, depth: int):
    cfg = arch.make_model(cell)
    cfg = dataclasses.replace(
        cfg,
        n_layers=depth,
        scan_unroll=True,
        blockwise_q=_ANALYSIS_BLOCK,
        blockwise_kv=_ANALYSIS_BLOCK,
    )
    return cells_mod.build_cell(arch, cell, mesh, multi_pod, cfg=cfg)


def lm_costs(arch: ArchSpec, cell: Cell, mesh, multi_pod: bool) -> Dict[str, float]:
    """Two-point depth extrapolation for scanned LM stacks."""
    cfg_full = arch.make_model(cell)
    L = cfg_full.n_layers
    period = cfg_full.moe.period if cfg_full.moe else 1
    d1, d2 = period, 2 * period
    n_dev = mesh.size
    c1 = _lower_costs(_lm_depth_variant(arch, cell, mesh, multi_pod, d1), n_dev)
    c2 = _lower_costs(_lm_depth_variant(arch, cell, mesh, multi_pod, d2), n_dev)
    out = {}
    for key in ("flops", "bytes", "collective_bytes"):
        per = (c2[key] - c1[key]) / (d2 - d1)
        out[key] = c1[key] + per * (L - d1)
        out[f"{key}_per_layer"] = per
        out[f"{key}_fixed"] = c1[key] - per * d1
    return out


def cell_costs(arch_id: str, cell_name: str, multi_pod: bool = False) -> Dict:
    arch = configs.get(arch_id)
    cell = arch.cell(cell_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if arch.family == "lm":
        costs = lm_costs(arch, cell, mesh, multi_pod)
    else:
        if arch.family == "ann":
            cell = dataclasses.replace(
                cell, extra={**cell.extra, "tile_unroll": True})
        built = cells_mod.build_cell(arch, cell, mesh, multi_pod)
        costs = _lower_costs(built, mesh.size)
    built_info = cells_mod.build_cell(arch, cell, mesh, multi_pod).static

    compute_s = costs["flops"] / PEAK_FLOPS
    memory_hlo_s = costs["bytes"] / HBM_BW
    mb = model_bytes(arch, cell, mesh.size)
    memory_s = mb / HBM_BW  # fused-TPU estimate (see model_bytes docstring)
    collective_s = costs["collective_bytes"] / LINK_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    model_flops = built_info.get("model_flops", 0.0)
    hlo_flops_global = costs["flops"] * mesh.size
    bound = max(compute_s, memory_s, collective_s)
    rec = {
        "arch": arch_id, "cell": cell_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": mesh.size,
        "flops_per_device": costs["flops"],
        "bytes_per_device_hlo": costs["bytes"],
        "bytes_per_device_model": mb,
        "collective_bytes_per_device": costs["collective_bytes"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_hlo_s": memory_hlo_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / hlo_flops_global) if hlo_flops_global else 0.0,
        "roofline_fraction": (
            (model_flops / mesh.size / PEAK_FLOPS) / bound if bound > 0 else 0.0
        ),
        "analysis_s": round(time.time() - t0, 1),
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--include-ann", action="store_true")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["cell"], r["mesh"]) for r in results if "error" not in r}

    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    arch_ids = [args.arch] if args.arch else configs.all_ids(include_ann=args.include_ann)
    for arch_id in arch_ids:
        arch = configs.get(arch_id)
        for cell in arch.cells:
            if args.shape and cell.name != args.shape:
                continue
            if (arch_id, cell.name, mesh_name) in done:
                continue
            try:
                rec = cell_costs(arch_id, cell.name, args.multi_pod)
                print(
                    f"[ok] {arch_id} x {cell.name}: dominant={rec['dominant']} "
                    f"bound={rec['bound_s']*1e3:.2f}ms useful={rec['useful_flops_ratio']:.2f} "
                    f"roofline_frac={rec['roofline_fraction']:.3f}",
                    flush=True,
                )
            except Exception as e:
                rec = {"arch": arch_id, "cell": cell.name, "mesh": mesh_name,
                       "error": f"{type(e).__name__}: {e}"}
                print(f"[FAIL] {arch_id} x {cell.name}: {str(e)[:200]}", flush=True)
            results = [r for r in results
                       if (r["arch"], r["cell"], r["mesh"]) != (arch_id, cell.name, mesh_name)]
            results.append(rec)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
