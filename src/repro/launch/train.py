"""End-to-end training driver with checkpoint/restart fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch tiny-lm --steps 300 \
        --ckpt-dir /tmp/ckpt --ckpt-every 50

Runs REAL training on the available devices (CPU here, pod on real
hardware): deterministic stateless data (step -> batch), AdamW/Adafactor,
async atomic checkpoints, automatic resume from the latest manifest, and a
straggler watchdog.  ``--kill-at`` injects a mid-run crash to demonstrate
restart (used by tests/test_train_driver.py and examples).

``--arch tiny-lm`` is a ~100M-param config runnable on this container;
assigned LM archs run with the same code path on a pod.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.data import lm as lm_data
from repro.models import transformer as tfm
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.train_loop import Watchdog, build_train_step, make_train_state


def tiny_lm_config() -> tfm.TransformerConfig:
    """~100M params: 12L x 768d x 12H, vocab 32064 (phi-mini tokenizer
    scale) — the end-to-end example model."""
    return tfm.TransformerConfig(
        name="tiny-lm", n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=2048, vocab=32064,
    )


def micro_lm_config() -> tfm.TransformerConfig:
    """~3M params: CI-scale model for fault-tolerance tests."""
    return tfm.TransformerConfig(
        name="micro-lm", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=2048,
    )


def get_model(arch: str) -> tfm.TransformerConfig:
    if arch == "tiny-lm":
        return tiny_lm_config()
    if arch == "micro-lm":
        return micro_lm_config()
    spec = configs.get(arch)
    assert spec.family == "lm", "train driver covers LM archs"
    return spec.make_model(None)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--kill-at", type=int, default=-1,
                    help="simulate a crash after this step (fault-tolerance demo)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_model(args.arch)
    total, active = cfg.param_count()
    print(f"[train] {cfg.name}: {total/1e6:.1f}M params ({active/1e6:.1f}M active)")

    data_cfg = lm_data.LmDataConfig(
        vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.global_batch,
        seed=args.seed,
    )
    opt = opt_mod.adamw(
        lr=opt_mod.cosine_schedule(args.lr, args.warmup, args.steps),
    )

    def loss_of(params, batch):
        return tfm.loss_fn(params, batch["tokens"], batch["labels"], cfg)

    step_fn = jax.jit(build_train_step(loss_of, opt, args.microbatches))

    # Init or resume (restore re-shards onto whatever mesh is active now —
    # elastic restart).
    start_step = 0
    params = tfm.init_params(jax.random.key(args.seed), cfg)
    state = make_train_state(params, opt)
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state, start_step = ckpt.restore(args.ckpt_dir, state)
        print(f"[train] resumed from step {start_step}")

    watchdog = Watchdog()
    losses = []
    pending = None
    for step in range(start_step, args.steps):
        batch = lm_data.batch_at(data_cfg, step)  # stateless: f(seed, step)
        watchdog.start()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = watchdog.stop(step)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step}: loss {loss:.4f} ({dt*1e3:.0f} ms)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            if pending is not None:
                pending.join()  # one in-flight async save at a time
            pending = ckpt.save_async(args.ckpt_dir, step + 1, state)
        if args.kill_at == step:
            if pending is not None:
                pending.join()
            print(f"[train] simulated crash at step {step}")
            raise SystemExit(42)
    if pending is not None:
        pending.join()
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, state)
    summary = {
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "steps_run": len(losses),
        "stragglers_flagged": watchdog.flagged,
    }
    print(f"[train] done: {summary}")
    return summary


if __name__ == "__main__":
    main()
