"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state: the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; tests and benches see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading pod=2 axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Shrunk mesh with the same axis names for CPU multi-device tests
    (requires >= 8 host devices via XLA_FLAGS)."""
    n = len(jax.devices())
    if multi_pod:
        assert n >= 8
        return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    assert n >= 4
    return jax.make_mesh((2, 2), ("data", "model"))
