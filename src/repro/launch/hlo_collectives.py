"""Collective-byte extraction from compiled HLO text.

``compiled.cost_analysis()`` has no collective information, so we parse the
HLO: every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute contributes per-device wire bytes with the standard
ring-model volume factors over its replica-group size n:

    all-reduce          2 (n-1)/n * operand bytes
    all-gather            (n-1)/n * result bytes
    reduce-scatter        (n-1)/n * operand bytes
    all-to-all            (n-1)/n * operand bytes
    collective-permute              operand bytes

While-loop awareness: XLA prints each computation once, but a collective in
a scanned layer body executes trip-count times.  We build the computation
call graph (while/call/conditional/fusion), extract trip counts from the
loop condition's comparison constant, and multiply.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# Param lists may contain nested parens (tuple-typed params) — match them
# greedily up to the '->' return annotation.
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")
_CALL_RE = re.compile(
    r"(?:to_apply|body|condition|true_computation|false_computation|called_computations=\{)"
    r"=?%?([\w\.\-]+)"
)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string like 'f32[16,128]' or a tuple
    '(f32[2], s32[2])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    operand_bytes: int
    group_size: int
    computation: str
    line: str

    def wire_bytes(self) -> float:
        n = max(self.group_size, 1)
        f = (n - 1) / n
        if self.kind == "all-reduce":
            return 2.0 * f * self.operand_bytes
        if self.kind == "all-gather":
            return f * self.result_bytes
        if self.kind == "reduce-scatter":
            return f * self.operand_bytes
        if self.kind == "all-to-all":
            return f * self.operand_bytes
        return float(self.operand_bytes)  # collective-permute


_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m and stripped.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
        else:
            if stripped == "}" or stripped.startswith("} "):
                cur = None
            else:
                comps[cur].append(stripped)
    return comps


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # replica_groups=[G,S]<=[...]: G groups of size S
        return int(m.group(2))
    return total_devices


def _parse_line(line: str, comp: str, total_devices: int) -> Optional[CollectiveOp]:
    # "[ROOT] %name = TYPE op-name(OPERANDS), ..."
    m = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\([^)]*\)|[\w\[\],]+)\s+([\w\-]+)", line)
    if not m:
        return None
    rtype, opname = m.group(1), m.group(2)
    kind = None
    for k in _COLLECTIVE_KINDS:
        if opname == k or opname.startswith(k + "-start") or opname == k + "-start":
            kind = k
            break
    if kind is None:
        return None
    result_bytes = _shape_bytes(rtype)
    # operand types: parse the argument list's shapes
    args = line[m.end():]
    paren = args.find("(")
    operand_bytes = _shape_bytes(args[paren: args.find(")", paren) + 1]) if paren >= 0 else 0
    if operand_bytes == 0:
        operand_bytes = result_bytes
    return CollectiveOp(
        kind=kind, result_bytes=result_bytes, operand_bytes=operand_bytes,
        group_size=_group_size(line, total_devices), computation=comp, line=line,
    )


def _trip_count(cond_lines: List[str]) -> int:
    """Heuristic: largest integer constant in the while condition (scan
    conditions compare the induction var against the trip count)."""
    best = 1
    for line in cond_lines:
        if "constant(" in line and ("compare" in line or "constant" in line):
            for m in re.finditer(r"constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
    return best


def collective_bytes(hlo: str, total_devices: int) -> Dict[str, float]:
    """Per-device wire bytes by collective kind, while-trip-count aware."""
    comps = _split_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.strip().startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip())
            if m:
                entry = m.group(1)
    if entry is None:
        entry = next(iter(comps), None)

    # multipliers per computation: BFS from entry
    mult: Dict[str, float] = {entry: 1.0} if entry else {}
    frontier = [entry] if entry else []
    visited = set()
    while frontier:
        name = frontier.pop()
        if name in visited or name not in comps:
            continue
        visited.add(name)
        base = mult.get(name, 1.0)
        for line in comps[name]:
            trips = 1.0
            if re.search(r"\bwhile\(", line):
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                if mc and mc.group(1) in comps:
                    trips = float(_trip_count(comps[mc.group(1)]))
                if mb:
                    child = mb.group(1)
                    mult[child] = mult.get(child, 0.0) + base * trips
                    frontier.append(child)
                continue
            for cm in _CALL_RE.finditer(line):
                child = cm.group(1)
                if child in comps and child != name:
                    mult[child] = mult.get(child, 0.0) + base
                    frontier.append(child)

    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVE_KINDS}
    out["total"] = 0.0
    for name, lines in comps.items():
        m = mult.get(name)
        if m is None:
            continue  # unreachable (e.g. dead computations)
        for line in lines:
            op = _parse_line(line, name, total_devices)
            if op is not None:
                b = op.wire_bytes() * m
                out[op.kind] += b
                out["total"] += b
    return out


def collective_op_count(hlo: str) -> int:
    n = 0
    for line in hlo.splitlines():
        s = line.strip()
        if re.match(r"%?[\w\.\-]+\s*=", s) and any(
            f" {k}" in s or f"{k}(" in s for k in _COLLECTIVE_KINDS
        ):
            n += 1
    return n
