"""End-to-end ANN serving driver (the paper's system, running for real).

    PYTHONPATH=src python -m repro.launch.serve --n-docs 100000 --queries 512

Builds a fake-words index over a synthetic word2vec-like corpus, stands up
the batched AnnService, replays a query stream, and reports R@(k,d) against
the brute-force oracle plus latency percentiles.  On a pod the same service
runs over the sharded index (core/distributed.py); here it exercises the
single-device path end to end.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core import bruteforce, eval as ev, fakewords
from repro.core.types import FakeWordsConfig
from repro.data import embeddings
from repro.serve.ann_service import AnnService, AnnServiceConfig


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=300)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--q", type=int, default=50, help="fake-words quantization")
    ap.add_argument("--depth", type=int, default=100)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--rerank", action="store_true", default=True)
    args = ap.parse_args(argv)

    corpus = embeddings.make_corpus(
        embeddings.CorpusConfig(n_vectors=args.n_docs, dim=args.dim)
    )
    queries, qids = embeddings.make_queries(corpus, args.queries)

    config = FakeWordsConfig(quantization=args.q, df_max_ratio=0.25)
    t0 = time.time()
    index = fakewords.build(jnp.asarray(corpus), config)
    build_s = time.time() - t0
    print(f"[serve] indexed {args.n_docs} docs in {build_s:.1f}s "
          f"({index.nbytes()/1e6:.0f} MB)")

    svc = AnnService(index, config, AnnServiceConfig(
        k=args.k, depth=args.depth, rerank=args.rerank, max_batch=args.batch))

    # Warmup (compile) then timed replay.
    svc.search_batch(queries[: args.batch])
    lat = []
    ids_all = []
    for i in range(0, len(queries), args.batch):
        chunk = queries[i : i + args.batch]
        t = time.time()
        _, ids = svc.search_batch(chunk)
        lat.append((time.time() - t) / len(chunk))
        ids_all.append(ids)
    ids_all = np.concatenate(ids_all)

    gt_s, gt_i = bruteforce.exact_topk(jnp.asarray(corpus), jnp.asarray(queries), args.k)
    recall = float(ev.recall_at(jnp.asarray(np.asarray(gt_i)), jnp.asarray(ids_all)))
    lat_ms = np.array(lat) * 1e3
    out = {
        "recall@k": round(recall, 4),
        "p50_ms_per_query": round(float(np.percentile(lat_ms, 50)), 3),
        "p99_ms_per_query": round(float(np.percentile(lat_ms, 99)), 3),
        "index_mb": round(index.nbytes() / 1e6, 1),
        "queries": int(svc.queries_served),
    }
    print(f"[serve] {out}")
    return out


if __name__ == "__main__":
    main()
