"""End-to-end ANN serving driver (the paper's system, running for real).

    PYTHONPATH=src python -m repro.launch.serve --n-docs 100000 --queries 512
    PYTHONPATH=src python -m repro.launch.serve --method lsh
    PYTHONPATH=src python -m repro.launch.serve --method hnsw --ef 128
    PYTHONPATH=src python -m repro.launch.serve --save-index /tmp/idx.ann
    PYTHONPATH=src python -m repro.launch.serve --quantized-rerank
    PYTHONPATH=src python -m repro.launch.serve --segments 8
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m repro.launch.serve --shards 8

Builds an AnnIndex (any encoding: fake words / lexical LSH / kd-scan /
brute force) over a synthetic word2vec-like corpus, stands up the batched
AnnService over it, replays a query stream, and reports R@(k,d) against the
brute-force oracle plus the service's own latency percentiles.  With
``--save-index`` the index round-trips through ``AnnIndex.save`` /
``AnnIndex.load`` first — the ship-to-serving-process path.  With
``--shards N`` the index builds THROUGH the distributed BuildPipeline
(docs/DESIGN.md §8: row-parallel under ``shard_map``, no full-corpus
materialization on any shard) and serves through the pod fan-out/merge
path; ``--quantized-rerank`` swaps the rerank store for the int8 + per-doc
scale QuantizedStore (~4x fewer rerank gather bytes).

With ``--segments N`` the corpus is INGESTED ONLINE through the Lucene-style
``IndexWriter`` (docs/DESIGN.md §11): the service starts on the first chunk
and the remaining chunks arrive between query rounds via
``writer.add`` + ``service.refresh()`` — near-real-time serving with the
epoch-keyed result cache; 10% of the corpus is then deleted and the index
force-merged to one segment, demonstrating the full segment lifecycle the
frozen facade cannot express.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bruteforce, eval as ev
from repro.core.index import AnnIndex
from repro.core.segments import IndexWriter
from repro.core.types import (
    BruteForceConfig,
    FakeWordsConfig,
    GraphConfig,
    KdTreeConfig,
    LexicalLshConfig,
)
from repro.data import embeddings
from repro.serve.ann_service import AnnService, AnnServiceConfig


def make_config(args):
    if args.method == "fakewords":
        # df_max_ratio defaults OFF: the paper's high-df filtering threshold
        # is corpus-dependent, and on the dense synthetic corpora every term
        # exceeds df = 0.25*N — a hard-coded 0.25 zeroed every query term
        # (recall 0).  Sweep it via benchmarks/ablations.py instead.
        return FakeWordsConfig(quantization=args.q, df_max_ratio=args.df_max_ratio)
    if args.method == "lsh":
        return LexicalLshConfig(buckets=300, hashes=1)
    if args.method == "kdtree":
        return KdTreeConfig(dims=8, backend="scan")
    if args.method == "bruteforce":
        return BruteForceConfig()
    if args.method == "hnsw":
        return GraphConfig(ef=args.ef, beam=args.beam)
    raise ValueError(f"unknown method {args.method}")


def serve_segmented(args, corpus, queries) -> dict:
    """Online-ingestion serving loop: start on the first chunk, stream the
    rest through ``writer.add`` + ``service.refresh()`` between query
    rounds, then delete 10% and force-merge — the segment lifecycle end to
    end, with recall measured against the final live corpus."""
    rng = np.random.default_rng(0)
    config = make_config(args)
    writer = IndexWriter(
        config,
        rerank_store="int8" if args.quantized_rerank else "exact",
        primary_postings=args.postings or "fp32",
    )
    chunks = np.array_split(np.asarray(corpus), args.segments)
    t0 = time.time()
    writer.add(chunks[0])
    svc = AnnService(writer=writer, service=AnnServiceConfig(
        k=args.k, depth=args.depth, rerank=args.rerank,
        max_batch=args.batch, cache_size=64))
    svc.search_batch(queries[: args.batch])  # warmup/compile
    svc.reset_latency()
    for chunk in chunks[1:]:
        writer.add(chunk)
        svc.refresh()
        svc.search_batch(queries[: args.batch])  # serve between ingests
    ingest_s = time.time() - t0
    # Delete a random 10% of everything ingested, then serve the rest.
    dead = rng.choice(args.n_docs, size=args.n_docs // 10, replace=False)
    writer.delete(dead)
    svc.refresh()
    n_seg_before = svc.ann.num_segments
    ids_all = []
    for i in range(0, len(queries), args.batch):
        _, ids = svc.search_batch(queries[i : i + args.batch])
        ids_all.append(ids)
    ids_all = np.concatenate(ids_all)
    # Ground truth over the LIVE corpus, mapped to stable global ids.
    live = np.ones(args.n_docs, bool)
    live[dead] = False
    gmap = svc.ann.live_global_ids()
    _, gt_i = bruteforce.exact_topk(
        jnp.asarray(np.asarray(corpus)[live]), jnp.asarray(queries), args.k)
    gt_global = gmap[np.asarray(gt_i)]
    recall = float(ev.recall_at(jnp.asarray(gt_global), jnp.asarray(ids_all)))
    t1 = time.time()
    writer.force_merge(1)
    svc.refresh()
    merge_s = time.time() - t1
    stats = svc.stats()
    out = {
        "method": svc.ann.method,
        "recall@k": round(recall, 4),
        "p50_ms_per_batch": stats["lat_p50_ms"],
        "p99_ms_per_batch": stats["lat_p99_ms"],
        "segments_before_merge": n_seg_before,
        "merge_s": round(merge_s, 2),
        "ingest_s": round(ingest_s, 2),
        "live_docs": stats["num_docs"],
        "epoch": stats["epoch"],
        "cache": (stats["cache_hits"], stats["cache_misses"]),
    }
    print(f"[serve] segmented NRT {out}")
    return out


def zipf_sampler(rng, pool: int, s: float):
    """Zipfian rank-frequency sampler over a query pool — real query
    streams are heavily head-skewed, which is what makes result caches and
    micro-batch coalescing pay."""
    ranks = np.arange(1, pool + 1, dtype=np.float64)
    p = ranks ** -s
    p /= p.sum()
    return lambda n: rng.choice(pool, size=n, p=p)


def serve_openloop(args, corpus, queries) -> dict:
    """Open-loop traffic generator (docs/DESIGN.md §14): arrivals at a
    FIXED ``--qps`` schedule (independent of service speed — the honest
    way to measure tail latency), Zipfian reuse over a query pool, and
    mixed add/delete/search against the NRT writer.  Reports sustained
    QPS + per-request p50/p99 for the async micro-batcher next to a
    sequential single-query A/B over the same workload."""
    import queue as queue_mod

    rng = np.random.default_rng(13)
    config = make_config(args)
    writer = IndexWriter(
        config,
        rerank_store="int8" if args.quantized_rerank else "exact",
        primary_postings=args.postings or "fp32",
    )
    n0 = max(args.batch, int(args.n_docs * 0.9))
    corpus = np.asarray(corpus)
    writer.add(corpus[:n0])
    ingest_ptr = n0
    svc = AnnService(writer=writer, service=AnnServiceConfig(
        k=args.k, depth=args.depth, rerank=args.rerank,
        max_batch=args.batch,
        max_wait_s=args.max_wait_ms / 1e3, queue_depth=args.queue_depth))
    pool = min(args.query_pool, len(queries))
    pool_q = np.asarray(queries)[:pool]
    sample = zipf_sampler(rng, pool, args.zipf_s)
    svc.search_batch(pool_q[: args.batch])  # warmup/compile
    svc.reset_latency()

    # -- sequential A/B: the same Zipfian stream, one query per launch ----
    seq_n = max(32, min(512, int(args.qps * args.duration / 4)))
    seq_idx = sample(seq_n)
    t0 = time.perf_counter()
    for i in seq_idx:
        svc.search_batch(pool_q[int(i) : int(i) + 1])
    seq_qps = seq_n / (time.perf_counter() - t0)
    svc.reset_latency()

    # -- open loop: submit on the wall-clock schedule, never wait ---------
    svc.start_async()
    period = 1.0 / args.qps
    futs, shed, sent = [], 0, 0
    start = time.perf_counter()
    next_t = start
    t_end = start + args.duration
    while time.perf_counter() < t_end:
        now = time.perf_counter()
        if now < next_t:
            time.sleep(min(next_t - now, 1e-3))
            continue
        next_t += period
        i = int(sample(1)[0])
        try:
            futs.append(svc.search_async(pool_q[i]))
            sent += 1
        except queue_mod.Full:
            shed += 1
        if args.mutate_every and sent and sent % args.mutate_every == 0:
            # Mixed workload: ingest a small chunk + delete a few docs,
            # then refresh — the packed executable cache keeps these
            # NRT cycles compile-free (same bucket rung).
            if ingest_ptr < len(corpus):
                writer.add(corpus[ingest_ptr : ingest_ptr + 32])
                ingest_ptr += 32
            writer.delete(rng.choice(ingest_ptr, size=4, replace=False))
            svc.refresh()
    for f in futs:
        f.result(timeout=120)
    elapsed = time.perf_counter() - start
    svc.stop_async()
    stats = svc.stats()
    out = {
        "method": svc.ann.method,
        "offered_qps": args.qps,
        "sustained_qps": round(len(futs) / elapsed, 1),
        "sequential_qps": round(seq_qps, 1),
        "req_p50_ms": stats["req_p50_ms"],
        "req_p99_ms": stats["req_p99_ms"],
        "async_launches": stats["async_launches"],
        "batch_per_launch": round(len(futs) / max(1, stats["async_launches"]), 1),
        "shed": shed,
        "live_docs": stats["num_docs"],
        "segments": stats["segments"],
    }
    print(f"[serve] open-loop {out}")
    return out


def serve_filtered(args, svc, corpus, queries, ratios, unfiltered) -> list:
    """Filtered smoke: replay the SAME query stream under random predicate
    bitmaps at each selectivity, through the match stage's single in-kernel
    filtered pass (docs/DESIGN.md §13).  Recall is measured against exact
    brute force over the FILTERED corpus; latency percentiles print next to
    the unfiltered ones from the main replay."""
    rng = np.random.default_rng(7)
    results = []
    for ratio in ratios:
        mask = rng.random(args.n_docs) < ratio
        if mask.sum() < args.k:  # degenerate draw at tiny selectivity
            mask[rng.choice(args.n_docs, size=args.k, replace=False)] = True
        filt = mask.astype(np.int32)
        svc.search_batch(queries[: args.batch], filter=filt)  # compile
        svc.reset_latency()
        ids_all = []
        for i in range(0, len(queries), args.batch):
            _, ids = svc.search_batch(queries[i : i + args.batch], filter=filt)
            ids_all.append(ids)
        ids_all = np.concatenate(ids_all)
        kept = np.flatnonzero(mask)
        _, gt_i = bruteforce.exact_topk(
            jnp.asarray(np.asarray(corpus)[kept]), jnp.asarray(queries), args.k
        )
        gt_global = kept[np.asarray(gt_i)]
        recall = float(
            ev.recall_at(jnp.asarray(gt_global), jnp.asarray(ids_all))
        )
        stats = svc.stats()
        row = {
            "selectivity": ratio,
            "recall@k": round(recall, 4),
            "p50_ms_per_batch": stats["lat_p50_ms"],
            "p99_ms_per_batch": stats["lat_p99_ms"],
        }
        results.append(row)
        print(
            f"[serve] filtered {ratio:.0%}: recall@k {row['recall@k']} "
            f"p50 {row['p50_ms_per_batch']}ms p99 {row['p99_ms_per_batch']}ms"
            f" (unfiltered: p50 {unfiltered['p50_ms_per_batch']}ms "
            f"p99 {unfiltered['p99_ms_per_batch']}ms)"
        )
    return results


def serve_hybrid(args, ann, corpus, queries) -> dict:
    """Hybrid smoke: RRF-fuse a lexical classic fake-words retriever with a
    dense kd-scan retriever over the same corpus (core/plan.py FusionStage)
    and report recall@k of the fusion next to each retriever alone."""
    from repro.core import plan as qplan

    cv = jnp.asarray(corpus)
    lex = (
        ann
        if isinstance(ann.config, FakeWordsConfig)
        and ann.config.scoring == "classic"
        else AnnIndex.build(cv, FakeWordsConfig(quantization=args.q))
    )
    dense = AnnIndex.build(cv, KdTreeConfig(dims=8, backend="scan"))
    sub = {
        "classic": qplan.QueryPlan(
            search=lambda q: lex.search(q, k=args.k, depth=args.depth),
            label="classic",
        ),
        "dense": qplan.QueryPlan(
            search=lambda q: dense.search(q, k=args.k, depth=args.depth),
            label="dense",
        ),
    }
    fusion = qplan.FusionStage(plans=tuple(sub.values()), k=args.k)
    qv = jnp.asarray(queries)
    _, gt_i = bruteforce.exact_topk(cv, qv, args.k)
    gt = jnp.asarray(np.asarray(gt_i))
    rec = {
        name: round(float(ev.recall_at(gt, p.run(qv)[1])), 4)
        for name, p in sub.items()
    }
    _, fused_i = fusion.run(qv)
    rec["hybrid_rrf"] = round(float(ev.recall_at(gt, fused_i)), 4)
    print(
        f"[serve] hybrid recall@{args.k}: classic {rec['classic']} "
        f"dense {rec['dense']} rrf {rec['hybrid_rrf']}"
    )
    return rec


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=300)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument(
        "--method",
        choices=("fakewords", "lsh", "kdtree", "bruteforce", "hnsw"),
        default="fakewords",
    )
    ap.add_argument("--q", type=int, default=50, help="fake-words quantization")
    ap.add_argument("--ef", type=int, default=64,
                    help="hnsw search list width (recall/latency knob)")
    ap.add_argument("--beam", type=int, default=4,
                    help="hnsw nodes expanded per traversal iteration")
    ap.add_argument("--df-max-ratio", type=float, default=1.0,
                    help="search-time high-df term filtering (1.0 = off)")
    ap.add_argument("--depth", type=int, default=100)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--rerank", action="store_true", default=True)
    ap.add_argument("--blockmax-keep", type=int, default=None)
    ap.add_argument(
        "--save-index", default=None,
        help="save the built index here and serve from the loaded copy",
    )
    ap.add_argument(
        "--shards", type=int, default=0,
        help="build AND serve doc-sharded over this many devices "
             "(distributed BuildPipeline; needs >= N jax devices, e.g. "
             "XLA_FLAGS=--xla_force_host_platform_device_count=N)",
    )
    ap.add_argument(
        "--quantized-rerank", action="store_true",
        help="rerank from the int8 + per-doc-scale QuantizedStore instead "
             "of fp32 originals (~4x fewer rerank gather bytes)",
    )
    ap.add_argument(
        "--postings", choices=("fp32", "int8", "int4"), default=None,
        help="primary postings encoding: int8 (per-doc scale) or int4 "
             "(grouped scales), dequantized inside the fused score stage "
             "(docs/DESIGN.md §12); default fp32 unless --memory-budget "
             "picks otherwise",
    )
    ap.add_argument(
        "--memory-budget", type=float, default=None, metavar="MB",
        help="resident index budget in MB; picks the best-recall "
             "{postings, rerank store, blockmax keep} that fits "
             "(core/memory_budget.py); knobs set explicitly are pinned",
    )
    ap.add_argument(
        "--segments", type=int, default=0,
        help="ingest the corpus ONLINE in this many chunks through the "
             "Lucene-style IndexWriter (segmented NRT serving with "
             "deletes + a forced merge; docs/DESIGN.md §11)",
    )
    ap.add_argument(
        "--filter-ratio", type=float, nargs="*", default=None,
        metavar="RATIO",
        help="filtered-search smoke: replay the query stream under random "
             "predicate bitmaps at these selectivities (bare flag = "
             "1%%/10%%/50%%), logging filtered p50/p99 and recall next to "
             "the unfiltered numbers (docs/DESIGN.md §13)",
    )
    ap.add_argument(
        "--qps", type=float, default=0,
        help="open-loop traffic generator: submit single queries to the "
             "async micro-batcher at this fixed arrival rate (Zipfian "
             "reuse over --query-pool, mixed add/delete/search via "
             "--mutate-every) and report sustained QPS + per-request "
             "p50/p99 next to a sequential single-query A/B",
    )
    ap.add_argument("--duration", type=float, default=5.0,
                    help="open-loop run length in seconds")
    ap.add_argument("--query-pool", type=int, default=256,
                    help="distinct queries in the Zipfian reuse pool")
    ap.add_argument("--zipf-s", type=float, default=1.1,
                    help="Zipf skew exponent for query reuse")
    ap.add_argument(
        "--mutate-every", type=int, default=200,
        help="every N requests: add a 32-doc chunk, delete 4 docs, "
             "refresh (0 = search-only traffic)",
    )
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="async micro-batch window (the SLO's donation)")
    ap.add_argument("--queue-depth", type=int, default=256,
                    help="async admission queue bound (backpressure)")
    ap.add_argument(
        "--hybrid", action="store_true",
        help="hybrid smoke: RRF-fuse the lexical classic fake-words "
             "retriever with a dense kd-scan retriever over the same "
             "corpus (core/plan.py FusionStage) and log recall@k of the "
             "fusion next to each retriever alone",
    )
    args = ap.parse_args(argv)

    corpus = embeddings.make_corpus(
        embeddings.CorpusConfig(n_vectors=args.n_docs, dim=args.dim)
    )
    queries, qids = embeddings.make_queries(corpus, args.queries)

    if args.qps:
        if args.shards or args.segments:
            raise SystemExit(
                "--qps drives the async NRT writer path; it is not "
                "combined with --shards/--segments"
            )
        return serve_openloop(args, corpus, queries)

    if args.segments:
        if args.shards:
            raise SystemExit("--segments and --shards are mutually exclusive")
        if args.filter_ratio is not None or args.hybrid:
            raise SystemExit(
                "--filter-ratio/--hybrid smoke modes run on the monolithic "
                "serving path; drop --segments (segmented filtering is "
                "exercised by tests/test_filtered.py)"
            )
        if args.save_index:
            raise SystemExit(
                "--segments persists via IndexWriter.commit, not "
                "--save-index; use writer.commit(path) / "
                "SegmentedAnnIndex.load(path)"
            )
        if args.memory_budget is not None:
            raise SystemExit(
                "--memory-budget plans a monolithic build; with --segments "
                "pass --postings/--quantized-rerank explicitly"
            )
        return serve_segmented(args, corpus, queries)

    mesh = None
    if args.shards:
        if args.method == "hnsw":
            raise SystemExit(
                "--shards serves shard-local match + merge, which graph "
                "traversal cannot do (adjacency edges cross shard "
                "boundaries); serve hnsw with --segments N or single-device "
                "(the sharded BUILD is exercised by tests/test_graph.py)"
            )
        n_dev = len(jax.devices())
        if n_dev < args.shards:
            raise SystemExit(
                f"--shards {args.shards} needs >= {args.shards} devices, "
                f"found {n_dev}; on CPU set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={args.shards}"
            )
        mesh = jax.make_mesh((args.shards,), ("data",))

    config = make_config(args)
    rerank_store = "int8" if args.quantized_rerank else (
        None if args.memory_budget is not None else "exact")
    budget = (int(args.memory_budget * 1e6)
              if args.memory_budget is not None else None)
    t0 = time.time()
    ann = AnnIndex.build(
        jnp.asarray(corpus), config,
        rerank_store=rerank_store, mesh=mesh, shard_axes=("data",),
        primary_postings=args.postings,
        memory_budget_bytes=budget,
    )
    jax.block_until_ready(jax.tree_util.tree_leaves(ann.index))
    build_s = time.time() - t0
    if mesh is not None:
        # On a real multi-host mesh shards build concurrently, so this wall
        # time IS the per-shard build time; under simulated host devices
        # the shards share one host's cores and it is the total.
        print(f"[serve] sharded build: {args.shards} shards x "
              f"{args.n_docs // args.shards} docs, build wall time "
              f"{build_s:.2f}s (= per-shard on a multi-host mesh; "
              f"no full-corpus materialization)")
    print(f"[serve] indexed {args.n_docs} docs ({ann.method}"
          f"{', int8 rerank store' if args.quantized_rerank else ''}) "
          f"in {build_s:.1f}s ({ann.nbytes()/1e6:.0f} MB)")

    if args.save_index:
        ann.save(args.save_index)
        ann = AnnIndex.load(args.save_index)
        print(f"[serve] round-tripped index through {args.save_index}")

    # A budget plan may select rerank_store="none"; serving then runs
    # match-only regardless of --rerank.
    do_rerank = args.rerank and (
        ann.index.vectors is not None
        or getattr(ann.index, "vq", None) is not None
    )
    svc = AnnService(ann, AnnServiceConfig(
        k=args.k, depth=args.depth, rerank=do_rerank, max_batch=args.batch,
        blockmax_keep=args.blockmax_keep),
        mesh=mesh, shard_axes=("data",) if mesh is not None else ())

    # Warmup (compile) then timed replay; drop the compile batch's wall time
    # so the reported percentiles reflect steady-state serving latency.
    svc.search_batch(queries[: args.batch])
    svc.reset_latency()
    ids_all = []
    for i in range(0, len(queries), args.batch):
        _, ids = svc.search_batch(queries[i : i + args.batch])
        ids_all.append(ids)
    ids_all = np.concatenate(ids_all)

    gt_s, gt_i = bruteforce.exact_topk(jnp.asarray(corpus), jnp.asarray(queries), args.k)
    recall = float(ev.recall_at(jnp.asarray(np.asarray(gt_i)), jnp.asarray(ids_all)))
    stats = svc.stats()
    out = {
        "method": ann.method,
        "recall@k": round(recall, 4),
        "p50_ms_per_batch": stats["lat_p50_ms"],
        "p99_ms_per_batch": stats["lat_p99_ms"],
        "index_mb": round(ann.nbytes() / 1e6, 1),
        "queries": int(svc.queries_served),
    }
    print(f"[serve] {out}")

    if args.filter_ratio is not None:
        ratios = args.filter_ratio if args.filter_ratio else [0.01, 0.1, 0.5]
        out["filtered"] = serve_filtered(
            args, svc, corpus, queries, ratios, out
        )
    if args.hybrid:
        out["hybrid"] = serve_hybrid(args, ann, corpus, queries)
    return out


if __name__ == "__main__":
    main()
