import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# Set here (and ONLY here): smoke tests and benches see the real device.

"""Multi-pod dry-run: lower + compile EVERY (architecture x input-shape)
cell on the production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --out results/dryrun.json
    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k

Success of ``.lower().compile()`` for the 16x16 (single-pod, 256-chip) and
2x16x16 (multi-pod, 512-chip) meshes is the deliverable: sharding
mismatches, compile-time OOM, or unsupported collectives are bugs in the
framework.  Results append incrementally to the JSON so a crash resumes.
"""
import argparse
import json
import time
import traceback


from repro import compat, configs
from repro.launch import cells as cells_mod
from repro.launch import hlo_collectives
from repro.launch.mesh import make_production_mesh

# TPU v5e-ish constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
LINK_BW = 2 * 50e9        # 2 usable ICI links per axis in a 2-axis torus


def run_cell(arch_id: str, cell_name: str, multi_pod: bool, keep_text: bool = False) -> dict:
    arch = configs.get(arch_id)
    cell = arch.cell(cell_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    rec = {
        "arch": arch_id, "cell": cell_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "devices": n_dev,
    }
    t0 = time.time()
    built = cells_mod.build_cell(arch, cell, mesh, multi_pod)
    with compat.set_mesh(mesh):  # context for bare-PartitionSpec constraints
        lowered = built.lower()
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
    }
    # donated buffers (train state, KV caches) are input/output-aliased:
    # they exist once, so the aliased bytes are subtracted.
    rec["memory"]["total_per_device_bytes"] = (
        rec["memory"]["argument_bytes"] + rec["memory"]["output_bytes"]
        + rec["memory"]["temp_bytes"] - rec["memory"]["alias_bytes"]
    )
    ca = compiled.cost_analysis() or {}
    rec["cost"] = {
        "flops": float(ca.get("flops", -1)),
        "bytes_accessed": float(ca.get("bytes accessed", -1)),
    }
    text = compiled.as_text()
    rec["collectives"] = hlo_collectives.collective_bytes(text, n_dev)
    rec["collective_ops"] = hlo_collectives.collective_op_count(text)
    rec["static"] = {k: (float(v) if isinstance(v, (int, float)) else v)
                     for k, v in built.static.items()}
    # NOTE: scanned layer stacks are counted ONCE by HLO cost analysis; the
    # exact roofline terms come from launch/roofline.py (unrolled two-point
    # depth extrapolation).  Collective bytes above already multiply
    # while-loop trip counts.
    rec["hbm_ok"] = rec["memory"]["total_per_device_bytes"] < 16e9
    if keep_text:
        rec["hlo_text"] = text
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="cell name (default: all)")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--include-ann", action="store_true",
                    help="also run the paper-own ANN configs")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["cell"], r["mesh"]) for r in results if r.get("ok")}

    arch_ids = [args.arch] if args.arch else configs.all_ids(include_ann=args.include_ann)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch_id in arch_ids:
        arch = configs.get(arch_id)
        for cell in arch.cells:
            if args.shape and cell.name != args.shape:
                continue
            for multi_pod in meshes:
                mesh_name = "2x16x16" if multi_pod else "16x16"
                if (arch_id, cell.name, mesh_name) in done:
                    continue
                tag = f"{arch_id} x {cell.name} x {mesh_name}"
                try:
                    rec = run_cell(arch_id, cell.name, multi_pod)
                    rec["ok"] = True
                    gb = rec["memory"]["total_per_device_bytes"] / 1e9
                    print(
                        f"[ok]   {tag}: compile {rec['compile_s']}s, "
                        f"{gb:.2f} GB/dev, flops(1-iter) {rec['cost']['flops']:.3g}, "
                        f"coll {rec['collectives']['total'] / 1e6:.1f} MB/dev"
                    , flush=True)
                except Exception as e:
                    rec = {
                        "arch": arch_id, "cell": cell.name, "mesh": mesh_name,
                        "ok": False, "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    n_fail += 1
                    print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:300]}", flush=True)
                results = [r for r in results
                           if (r["arch"], r["cell"], r["mesh"]) != (arch_id, cell.name, mesh_name)]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    print(f"done: {len(results)} records, {n_fail} failures -> {args.out}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
