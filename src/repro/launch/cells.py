"""Cell builder: (architecture x input-shape x mesh) -> lowerable problem.

For every cell of the dry-run matrix this produces:

  * ``fn``            — the step function (train_step / serve_step / ...)
  * ``args``          — ShapeDtypeStruct stand-ins with NamedShardings
                        attached (weak-type-correct, shardable, ZERO device
                        allocation — 400B-param trees stay abstract)
  * ``out_shardings`` — explicit output placement (params/opt keep their
                        input sharding; metrics replicate)
  * ``static``        — bookkeeping: model/active param counts, MODEL_FLOPS
                        (6ND / 2ND conventions), bytes-level notes

``kind`` semantics: ``decode_*``/``long_*`` lower **serve_step** (one new
token against a seq_len KV cache), NOT train_step; encoder/serve recsys
cells lower forward-only steps (see the assignment brief).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.common import ArchSpec, Cell
from repro.core import distributed as ann_dist
from repro.core.types import FakeWordsIndex
from repro.models import gnn as gnn_mod
from repro.models import recsys as rec_mod
from repro.models import transformer as tfm
from repro.sharding import rules
from repro.train import optimizer as opt_mod

Pytree = Any


@dataclasses.dataclass
class CellBuild:
    arch_id: str
    cell: Cell
    fn: Callable
    args: Tuple
    out_shardings: Any
    static: Dict[str, Any]
    donate: Tuple[int, ...] = ()  # donated arg positions (state buffers
    #                               update in place: train state, KV cache)
    mesh: Optional[Mesh] = None

    def jitted(self):
        if hasattr(self.fn, "lower"):  # pre-jitted (ANN shard_map path)
            return self.fn
        return jax.jit(
            self.fn, out_shardings=self.out_shardings, donate_argnums=self.donate
        )

    def lower(self):
        # Mesh context: the step fns constrain activations with bare
        # PartitionSpecs (models don't hold mesh objects).
        with compat.set_mesh(self.mesh):
            return self.jitted().lower(*self.args)


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _replicated_like(struct_tree, mesh):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), struct_tree)


def _make_opt(arch: ArchSpec) -> opt_mod.Optimizer:
    return opt_mod.adamw() if arch.optimizer == "adamw" else opt_mod.adafactor()


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _param_structs(shapes, specs, dtype, mesh):
    return jax.tree_util.tree_map(
        lambda s, p: _sds(s, dtype, mesh, p), shapes, specs,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def _opt_structs(opt, param_structs, opt_specs, mesh):
    shapes = jax.eval_shape(opt.init, param_structs)
    return jax.tree_util.tree_map(
        lambda st, sp: _sds(st.shape, st.dtype, mesh, sp), shapes,
        _to_tree_of_specs(opt_specs),
    )


def _to_tree_of_specs(tree):
    return tree


# --------------------------------------------------------------------------
# MODEL_FLOPS conventions (per §Roofline)
# --------------------------------------------------------------------------


def lm_model_flops(cfg: tfm.TransformerConfig, cell: Cell) -> float:
    total, active = cfg.param_count()
    b, s = cell.batch, cell.seq
    hqd = cfg.n_heads * cfg.dh
    if cell.kind == "train":
        tokens = b * s
        attn = 3 * 2 * b * s * s * hqd * cfg.n_layers  # fwd+bwd, causal-halved
        return 6.0 * active * tokens + attn
    if cell.kind == "prefill":
        tokens = b * s
        attn = 2 * b * s * s * hqd * cfg.n_layers * 0.5 * 2  # qk+av causal
        return 2.0 * active * tokens + attn
    # decode: one token per sequence against a seq_len cache
    attn = 4.0 * b * cell.seq * hqd * cfg.n_layers
    return 2.0 * active * b + attn


def gnn_model_flops(cfg: gnn_mod.SageConfig, cell: Cell) -> float:
    d0, dh, c = cfg.d_in, cfg.d_hidden, cfg.n_classes
    if cell.kind in ("full_graph",):
        n, e = cell.get("n_nodes"), cell.get("n_edges")
        mm = 2 * n * (d0 * dh * 2 + dh * dh * 2 + dh * c)
        agg = e * (d0 + dh)
        return 3.0 * (mm + agg)  # fwd + bwd ~ 3x fwd
    if cell.kind == "minibatch":
        b = cell.batch
        f1, f2 = cell.get("fanouts")
        rows0 = b * (1 + f1 + f1 * f2)  # layer-0 combines
        rows1 = b * (1 + f1)
        mm = 2 * rows0 * d0 * dh * 2 + 2 * rows1 * dh * dh * 2 + 2 * b * dh * c
        return 3.0 * mm
    # molecule: batched small graphs
    g, n, e = cell.batch, cell.get("n_nodes"), cell.get("n_edges")
    mm = 2 * g * n * (d0 * dh * 2 + dh * dh * 2) + 2 * g * dh * c
    agg = g * e * (d0 + dh)
    return 3.0 * (mm + agg)


def recsys_model_flops(cfg: rec_mod.RecsysConfig, cell: Cell) -> float:
    f, d = cfg.n_fields, cfg.dim

    def mlp_flops(widths, d_in):
        fl, prev = 0, d_in
        for w in widths:
            fl += 2 * prev * w
            prev = w
        return fl

    per_ex = 2 * f * d  # embedding reduce + fm trick
    if cfg.model == "deepfm":
        per_ex += mlp_flops(cfg.mlp + (1,), f * d)
    elif cfg.model == "dlrm":
        per_ex = mlp_flops(cfg.bot_mlp, cfg.n_dense)
        n_vec = f + 1
        per_ex += 2 * n_vec * n_vec * d  # gram
        per_ex += mlp_flops(cfg.top_mlp, n_vec * (n_vec - 1) // 2 + cfg.bot_mlp[-1])
    elif cfg.model == "xdeepfm":
        per_ex += mlp_flops(cfg.mlp + (1,), f * d)
        prev = f
        for h in cfg.cin_layers:
            per_ex += 2 * prev * f * d * h
            prev = h
    if cell.kind == "train":
        return 3.0 * cell.batch * per_ex
    if cell.kind == "retrieval":
        n_cand = cell.get("n_candidates")
        return cell.batch * per_ex + 2.0 * cell.batch * n_cand * d
    return float(cell.batch * per_ex)


# --------------------------------------------------------------------------
# LM cells
# --------------------------------------------------------------------------


def _build_lm(arch: ArchSpec, cell: Cell, mesh: Mesh, multi_pod: bool,
              cfg: Optional[tfm.TransformerConfig] = None) -> CellBuild:
    cfg = cfg or arch.make_model(cell)
    # Pin activation shardings (residual/logits/KV) to the production mesh;
    # long-context decode spreads the KV length over every axis.
    long = bool(cell.get("long"))
    cfg = dataclasses.replace(
        cfg,
        batch_axes=() if cell.batch == 1 else rules.batch_axes(multi_pod),
        tp_axis=rules.TP,
        kv_axes=(rules.all_axes(multi_pod) if long else rules.TP)
        if cell.kind in ("prefill", "decode") else None,
        # Flat-GQA whenever kv heads don't fill the TP axis: avoids GSPMD
        # splitting the GQA group dim into partial-reduce groups (§Perf A2).
        attn_flat_heads=cfg.n_kv_heads < 16 and cell.kind in ("train", "prefill"),
    )
    opt = _make_opt(arch)
    shapes = tfm.param_shapes(cfg)
    pspecs = rules.lm_param_specs(shapes)
    params = _param_structs(shapes, pspecs, cfg.param_dtype, mesh)
    batch_sp = rules.lm_batch_spec(multi_pod)
    total, active = cfg.param_count()
    static = {
        "params_total": total, "params_active": active,
        "model_flops": lm_model_flops(cfg, cell),
    }

    if cell.kind == "train":
        ospecs = rules.opt_state_specs(arch.optimizer, pspecs, shapes)
        opt_state = _opt_structs(opt, params, ospecs, mesh)
        tokens = _sds((cell.batch, cell.seq), jnp.int32, mesh, batch_sp)
        labels = _sds((cell.batch, cell.seq), jnp.int32, mesh, batch_sp)
        # Microbatch accumulation: per-device remat checkpoints are
        # L x (B_local/m) x S x d x 2 bytes; pick m so they stay <= ~4 GB
        # (global batch and numerics unchanged; m is a §Perf lever).
        dp_shards = 1
        for ax in rules.batch_axes(multi_pod):
            dp_shards *= mesh.shape[ax]
        ckpt_bytes = (
            cfg.n_layers * (cell.batch / dp_shards) * cell.seq * cfg.d_model * 2
        )
        n_micro = int(cell.get("n_microbatches", 0))
        if not n_micro:
            n_micro = 1
            while ckpt_bytes / n_micro > 4e9 and n_micro < cell.batch // dp_shards:
                n_micro *= 2
        static["n_microbatches"] = n_micro

        # ZeRO-2 + mixed precision (§Perf iterations 2-3): the f32 master +
        # optimizer states stay fully sharded (model x data); ONE bf16
        # compute copy per step is constrained data-REPLICATED, so weights
        # all-gather once (bf16) instead of per-layer/per-pass, and GSPMD
        # stops AR-ing (b,s,d) activations over 'data' (measured: the
        # dominant collective).  Grads are constrained back to the master
        # sharding => reduce-scatter over 'data'.
        # ZeRO-2 only if the data-replicated bf16 copy fits comfortably:
        # per-device copy = 2 bytes x total params / model-axis shards (<=3GB).
        # llama4-maverick (400B): 50 GB/dev => keep the compute copy FSDP-
        # sharded there (weights re-gather per layer, the standard FSDP
        # cost) — recorded in EXPERIMENTS.md §Perf A3.
        zero2_ok = 2.0 * total / mesh.shape[rules.TP] <= 3e9
        zero2_specs = jax.tree_util.tree_map(
            lambda sp: (rules.drop_axis(sp, rules.FSDP) if zero2_ok else sp),
            pspecs,
            is_leaf=lambda x: isinstance(x, P),
        )
        static["zero2"] = bool(zero2_ok)

        def train_step(params, opt_state, tokens, labels):
            def compute_cast(p, sp):
                pc = p.astype(cfg.dtype) if p.ndim >= 2 else p
                return jax.lax.with_sharding_constraint(pc, sp)

            def loss_cast(params_c, tokens, labels):
                return tfm.loss_fn(params_c, tokens, labels, cfg)

            params_c = jax.tree_util.tree_map(compute_cast, params, zero2_specs)
            if n_micro == 1:
                loss, grads = jax.value_and_grad(loss_cast)(
                    params_c, tokens, labels
                )
            else:
                tok_m = tokens.reshape(n_micro, cell.batch // n_micro, cell.seq)
                lab_m = labels.reshape(n_micro, cell.batch // n_micro, cell.seq)

                def acc(carry, tl):
                    loss_acc, grad_acc = carry
                    t, l = tl
                    t = jax.lax.with_sharding_constraint(t, batch_sp)
                    l = jax.lax.with_sharding_constraint(l, batch_sp)
                    loss_i, grads_i = jax.value_and_grad(loss_cast)(params_c, t, l)
                    grads_i = jax.tree_util.tree_map(
                        lambda g: g.astype(jnp.float32), grads_i)
                    return (
                        loss_acc + loss_i,
                        jax.tree_util.tree_map(jnp.add, grad_acc, grads_i),
                    ), None

                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                (loss, grads), _ = jax.lax.scan(
                    acc, (jnp.zeros((), jnp.float32), zeros), (tok_m, lab_m)
                )
                loss = loss / n_micro
                grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            # reduce-scatter grads back to the master's FSDP sharding
            grads = jax.tree_util.tree_map(
                lambda g, sp: jax.lax.with_sharding_constraint(
                    g.astype(jnp.float32), sp),
                grads, pspecs,
            )
            new_p, new_s, info = opt.update(grads, opt_state, params)
            return new_p, new_s, {"loss": loss, **info}

        metrics_struct = jax.eval_shape(
            train_step, params, opt_state, tokens, labels
        )[2]
        out_sh = (
            _named(mesh, pspecs),
            _named(mesh, ospecs),
            _replicated_like(metrics_struct, mesh),
        )
        return CellBuild(arch.id, cell, train_step, (params, opt_state, tokens, labels), out_sh, static, donate=(0, 1))

    if cell.kind == "prefill":
        tokens = _sds((cell.batch, cell.seq), jnp.int32, mesh, batch_sp)

        def serve_step(params, tokens):
            return tfm.prefill(params, tokens, cfg)

        cache_spec = rules.lm_cache_spec(multi_pod)
        out_sh = (
            {
                "k": NamedSharding(mesh, cache_spec),
                "v": NamedSharding(mesh, cache_spec),
                "length": NamedSharding(mesh, P()),
            },
            NamedSharding(mesh, rules.lm_logit_spec(multi_pod)),
        )
        return CellBuild(arch.id, cell, serve_step, (params, tokens), out_sh, static)

    # decode (decode_32k / long_500k): one new token against a seq_len cache
    cache_spec = rules.lm_cache_spec(multi_pod, long_context=long)
    cache = {
        "k": _sds((cfg.n_layers, cell.batch, cell.seq, cfg.n_kv_heads, cfg.dh),
                  cfg.dtype, mesh, cache_spec),
        "v": _sds((cfg.n_layers, cell.batch, cell.seq, cfg.n_kv_heads, cfg.dh),
                  cfg.dtype, mesh, cache_spec),
        "length": jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P())),
    }
    token = _sds((cell.batch,), jnp.int32, mesh,
                 P(rules.batch_axes(multi_pod)) if cell.batch > 1 else P())

    def serve_step(params, cache, token):
        return tfm.decode_step(params, cache, token, cfg)

    out_sh = (
        {
            "k": NamedSharding(mesh, cache_spec),
            "v": NamedSharding(mesh, cache_spec),
            "length": NamedSharding(mesh, P()),
        },
        NamedSharding(
            mesh,
            P(rules.batch_axes(multi_pod), rules.TP) if cell.batch > 1 else P(None, rules.TP),
        ),
    )
    return CellBuild(arch.id, cell, serve_step, (params, cache, token), out_sh, static, donate=(1,))


# --------------------------------------------------------------------------
# GNN cells
# --------------------------------------------------------------------------


def _build_gnn(arch: ArchSpec, cell: Cell, mesh: Mesh, multi_pod: bool) -> CellBuild:
    cfg = arch.make_model(cell)
    opt = _make_opt(arch)
    shapes = gnn_mod.param_shapes(cfg)
    pspecs = rules.gnn_param_specs(shapes)
    params = _param_structs(shapes, pspecs, jnp.float32, mesh)
    ospecs = rules.opt_state_specs(arch.optimizer, pspecs, shapes)
    opt_state = _opt_structs(opt, params, ospecs, mesh)
    static = {
        "params_total": sum(
            int(jnp.prod(jnp.asarray(s))) for s in jax.tree_util.tree_leaves(
                shapes, is_leaf=lambda x: isinstance(x, tuple))
        ),
        "model_flops": gnn_model_flops(cfg, cell),
    }
    static["params_active"] = static["params_total"]

    def finish(loss_fn_args, fn_args):
        def train_step(params, opt_state, *args):
            loss, grads = jax.value_and_grad(loss_fn_args)(params, *args)
            new_p, new_s, info = opt.update(grads, opt_state, params)
            return new_p, new_s, {"loss": loss, **info}

        metrics_struct = jax.eval_shape(train_step, params, opt_state, *fn_args)[2]
        out_sh = (
            _named(mesh, pspecs),
            _named(mesh, ospecs),
            _replicated_like(metrics_struct, mesh),
        )
        return CellBuild(
            arch.id, cell, train_step, (params, opt_state) + fn_args, out_sh,
            static, donate=(0, 1),
        )

    if cell.kind == "full_graph":
        n, e = cell.get("n_nodes"), cell.get("n_edges")
        # Pad the edge list to a mesh-divisible length; pad edges carry
        # dst = n_nodes, which segment_sum (num_segments = n) drops — they
        # contribute nothing to messages or degrees.
        e_pad = -(-e // 512) * 512
        edge_sp = rules.gnn_edge_spec(multi_pod)
        feats = _sds((n, cfg.d_in), jnp.float32, mesh, P())
        src = _sds((e_pad,), jnp.int32, mesh, edge_sp)
        dst = _sds((e_pad,), jnp.int32, mesh, edge_sp)
        labels = _sds((n,), jnp.int32, mesh, P())
        mask = _sds((n,), jnp.float32, mesh, P())

        def loss(params, feats, src, dst, labels, mask):
            return gnn_mod.loss_full(params, feats, src, dst, labels, mask, cfg)

        return finish(loss, (feats, src, dst, labels, mask))

    if cell.kind == "minibatch":
        n, b = cell.get("n_nodes"), cell.batch
        f1, f2 = cfg.fanouts
        bsp = rules.gnn_minibatch_spec(multi_pod, 1)
        feats = _sds((n, cfg.d_in), jnp.float32, mesh, P())
        batch_nodes = _sds((b,), jnp.int32, mesh, bsp)
        nbr1 = _sds((b, f1), jnp.int32, mesh, rules.gnn_minibatch_spec(multi_pod, 2))
        nbr2 = _sds((b, f1, f2), jnp.int32, mesh, rules.gnn_minibatch_spec(multi_pod, 3))
        labels = _sds((b,), jnp.int32, mesh, bsp)

        def loss(params, feats, batch_nodes, nbr1, nbr2, labels):
            return gnn_mod.loss_sampled(params, feats, batch_nodes, nbr1, nbr2, labels, cfg)

        return finish(loss, (feats, batch_nodes, nbr1, nbr2, labels))

    # molecule: batched small graphs
    g, n, e = cell.batch, cell.get("n_nodes"), cell.get("n_edges")
    bsp = rules.batch_axes(multi_pod)
    feats = _sds((g, n, cfg.d_in), jnp.float32, mesh, P(bsp, None, None))
    src = _sds((g, e), jnp.int32, mesh, P(bsp, None))
    dst = _sds((g, e), jnp.int32, mesh, P(bsp, None))
    labels = _sds((g,), jnp.int32, mesh, P(bsp))

    def loss(params, feats, src, dst, labels):
        return gnn_mod.loss_batched(params, feats, src, dst, labels, cfg)

    return finish(loss, (feats, src, dst, labels))


# --------------------------------------------------------------------------
# RecSys cells
# --------------------------------------------------------------------------


def _build_recsys(arch: ArchSpec, cell: Cell, mesh: Mesh, multi_pod: bool) -> CellBuild:
    cfg = arch.make_model(cell)
    opt = _make_opt(arch)
    shapes = rec_mod.param_shapes(cfg)
    pspecs = rules.recsys_param_specs(shapes)
    params = _param_structs(shapes, pspecs, cfg.param_dtype, mesh)
    static = {
        "params_total": cfg.param_count(),
        "params_active": cfg.param_count(),
        "model_flops": recsys_model_flops(cfg, cell),
    }
    b = cell.batch
    bsp2 = rules.recsys_batch_spec(multi_pod, 2)
    bsp3 = rules.recsys_batch_spec(multi_pod, 3)
    bsp1 = rules.recsys_batch_spec(multi_pod, 1)

    def batch_structs(batch_size, spec_batched=True):
        mk = lambda shape, dt, sp: _sds(shape, dt, mesh, sp)
        rep = P(*(None,) * 3)
        out = {
            "sparse": mk((batch_size, cfg.n_fields, cfg.nnz), jnp.int32,
                         bsp3 if spec_batched else rep),
        }
        if cfg.n_dense:
            out["dense"] = mk((batch_size, cfg.n_dense), jnp.float32,
                              bsp2 if spec_batched else P(None, None))
        return out

    if cell.kind == "train":
        ospecs = rules.opt_state_specs(arch.optimizer, pspecs, shapes)
        opt_state = _opt_structs(opt, params, ospecs, mesh)
        batch = batch_structs(b)
        label = _sds((b,), jnp.float32, mesh, bsp1)

        def train_step(params, opt_state, batch, label):
            def loss_of(p, batch):
                return rec_mod.bce_loss(p, cfg, batch["sparse"], label, batch.get("dense"))

            loss, grads = jax.value_and_grad(loss_of)(params, batch)
            new_p, new_s, info = opt.update(grads, opt_state, params)
            return new_p, new_s, {"loss": loss, **info}

        metrics_struct = jax.eval_shape(train_step, params, opt_state, batch, label)[2]
        out_sh = (
            _named(mesh, pspecs),
            _named(mesh, ospecs),
            _replicated_like(metrics_struct, mesh),
        )
        return CellBuild(arch.id, cell, train_step, (params, opt_state, batch, label), out_sh, static, donate=(0, 1))

    if cell.kind == "serve":
        batch = batch_structs(b)

        def serve_step(params, batch):
            logit = rec_mod.forward(params, cfg, batch["sparse"], batch.get("dense"))
            return jax.nn.sigmoid(logit)

        out_sh = NamedSharding(mesh, P(rules.batch_axes(multi_pod)))
        return CellBuild(arch.id, cell, serve_step, (params, batch), out_sh, static)

    # retrieval_cand: one query context vs n_candidates item vectors.
    # The candidate buffer is padded up to a mesh-divisible row count
    # (pad rows are zeros) and pad scores are masked to -inf before top-k.
    n_cand = cell.get("n_candidates")
    n_pad = -(-n_cand // 512) * 512
    batch = batch_structs(b, spec_batched=False)  # B=1: replicate
    cand = _sds((n_pad, cfg.dim), jnp.float32, mesh, rules.recsys_cand_spec(multi_pod))

    def retrieval_step(params, batch, cand):
        u = rec_mod.user_tower(params, cfg, batch["sparse"], batch.get("dense"))
        scores = rec_mod.retrieval_scores(u, cand)  # (B, n_pad)
        valid = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) < n_cand
        scores = jnp.where(valid, scores, -jnp.inf)
        top_s, top_i = jax.lax.top_k(scores, 100)
        return top_s, top_i  # force tuple (lax.top_k yields a list pytree)

    out_sh = (NamedSharding(mesh, P()), NamedSharding(mesh, P()))
    return CellBuild(arch.id, cell, retrieval_step, (params, batch, cand), out_sh, static)


# --------------------------------------------------------------------------
# ANN (paper-own) cells
# --------------------------------------------------------------------------


def _build_ann(arch: ArchSpec, cell: Cell, mesh: Mesh, multi_pod: bool) -> CellBuild:
    config = arch.make_model(cell)
    n, dim = cell.get("n_docs"), cell.get("dim")
    m2 = 2 * dim
    b = cell.batch
    axes = rules.all_axes(multi_pod)
    doc_sp = P(axes, None)
    rerank_dtype = jnp.bfloat16 if cell.get("rerank_dtype") == "bfloat16" else jnp.float32

    tf_cols = (m2 // 2) if getattr(config, "signed_store", False) else m2
    index = FakeWordsIndex(
        tf=_sds((n, tf_cols), jnp.int8, mesh, doc_sp),
        idf=_sds((m2,), jnp.float32, mesh, P()),
        norm=_sds((n,), jnp.float32, mesh, P(axes)),
        df=_sds((m2,), jnp.int32, mesh, P()),
        scored=(_sds((n, m2), jnp.bfloat16, mesh, doc_sp)
                if config.scoring == "classic" else None),
        vectors=_sds((n, dim), rerank_dtype, mesh, doc_sp),
    )
    q_tf = _sds((b, m2), jnp.int32, mesh, P())
    queries = _sds((b, dim), rerank_dtype, mesh, P())

    fn = ann_dist.make_sharded_search(
        mesh, config, axes, k=cell.get("k", 10), depth=cell.get("depth", 100),
        rerank=True, tile_unroll=bool(cell.get("tile_unroll", False)),
    )
    static = {
        "params_total": 0, "params_active": 0,
        # §Roofline convention: 2 * N_q * N_d * dims (the ideal dot-scoring
        # work; the sign-split GEMM does 2x this, the signed store 1x).
        "model_flops": 2.0 * b * n * dim,
    }
    return CellBuild(arch.id, cell, fn, (index, q_tf, queries), None, static)


# --------------------------------------------------------------------------
# Entry
# --------------------------------------------------------------------------

_BUILDERS = {
    "lm": _build_lm,
    "gnn": _build_gnn,
    "recsys": _build_recsys,
    "ann": _build_ann,
}


def build_cell(arch: ArchSpec, cell: Cell, mesh: Mesh, multi_pod: bool,
               **kw) -> CellBuild:
    with compat.set_mesh(mesh):  # builders eval_shape through constrained fns
        built = _BUILDERS[arch.family](arch, cell, mesh, multi_pod, **kw)
    built.mesh = mesh
    return built


def input_specs(arch: ArchSpec, cell_name: str, mesh: Mesh, multi_pod: bool = False):
    """ShapeDtypeStruct stand-ins for every input of the cell's step
    (params, optimizer state, batch/cache), shardings attached."""
    cell = arch.cell(cell_name)
    return build_cell(arch, cell, mesh, multi_pod).args
