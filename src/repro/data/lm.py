"""Stateless LM token pipeline: batch = f(seed, step).

Synthetic token streams with a Zipfian unigram distribution (real vocab
usage is Zipf; this exercises the embedding gather exactly like real data).
Deterministic per (seed, step, shard) so restarts and elastic re-sharding
reproduce the same global batch (fault-tolerance requirement).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LmDataConfig:
    vocab: int = 32064
    seq_len: int = 4096
    global_batch: int = 256
    seed: int = 0
    zipf_a: float = 1.1


def _zipf_tokens(key: jax.Array, shape, vocab: int, a: float) -> jax.Array:
    """Inverse-CDF Zipf sampling: rank ~ u^(-1/(a-1)) truncated to vocab."""
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    ranks = jnp.floor(u ** (-1.0 / (a - 1.0))).astype(jnp.int32)
    return jnp.clip(ranks, 0, vocab - 1)


def batch_at(cfg: LmDataConfig, step: int) -> dict:
    """Global batch for ``step``: {'tokens': (B, S), 'labels': (B, S)}.
    labels = next-token shifted tokens."""
    key = jax.random.fold_in(jax.random.key(cfg.seed), step)
    toks = _zipf_tokens(
        key, (cfg.global_batch, cfg.seq_len + 1), cfg.vocab, cfg.zipf_a
    )
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def host_shard_at(cfg: LmDataConfig, step: int, shard: int, n_shards: int) -> dict:
    """Per-host slice of the global batch (multi-host input pipeline: each
    host materializes only its rows; rows are globally consistent because
    the key depends only on (seed, step))."""
    assert cfg.global_batch % n_shards == 0
    per = cfg.global_batch // n_shards
    full = batch_at(cfg, step)
    sl = slice(shard * per, (shard + 1) * per)
    return {k: v[sl] for k, v in full.items()}
