"""Synthetic graphs in CSR + a real uniform neighbor sampler.

Graphs are generated with power-law degrees (preferential-attachment-like)
to match Reddit/ogbn-products degree skew.  The sampler is the GraphSAGE
with-replacement uniform sampler, fully on-device (jit-able): for each seed
node it draws ``fanout`` uniform positions in [0, deg) and gathers column
ids from CSR — isolated nodes yield -1 padding.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    n_nodes: int = 10_000
    n_edges: int = 200_000
    d_feat: int = 128
    n_classes: int = 41
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class CsrGraph:
    """Compressed sparse row adjacency + features + labels (host arrays or
    device arrays; all dense, shard-friendly)."""

    indptr: jax.Array   # (N+1,) int64-safe int32
    indices: jax.Array  # (E,) int32 — neighbor ids
    feats: jax.Array    # (N, d_feat) float32
    labels: jax.Array   # (N,) int32

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def n_edges(self) -> int:
        return self.indices.shape[0]

    def edge_list(self) -> Tuple[jax.Array, jax.Array]:
        """(src, dst) arrays for full-batch message passing (dst = CSR row)."""
        deg = np.asarray(self.indptr[1:]) - np.asarray(self.indptr[:-1])
        dst = np.repeat(np.arange(self.n_nodes, dtype=np.int32), deg)
        return jnp.asarray(np.asarray(self.indices)), jnp.asarray(dst)


def make_graph(cfg: GraphConfig) -> CsrGraph:
    """Power-law multigraph: endpoint sampling ~ Zipf over node ids (hub
    formation), self-loops removed by +1 shift."""
    rng = np.random.default_rng(cfg.seed)
    a = 1.3
    u = rng.random(cfg.n_edges * 2).astype(np.float64)
    ranks = np.floor(u ** (-1.0 / (a - 1.0))).astype(np.int64)
    nodes = np.minimum(ranks, cfg.n_nodes - 1).astype(np.int32)
    perm = rng.permutation(cfg.n_nodes).astype(np.int32)  # decorrelate hubs
    nodes = perm[nodes]
    src, dst = nodes[: cfg.n_edges], nodes[cfg.n_edges :]
    dst = np.where(src == dst, (dst + 1) % cfg.n_nodes, dst)
    # CSR by dst (incoming neighbors define the aggregation set).
    order = np.argsort(dst, kind="stable")
    src_s, dst_s = src[order], dst[order]
    counts = np.bincount(dst_s, minlength=cfg.n_nodes)
    indptr = np.zeros(cfg.n_nodes + 1, np.int32)
    np.cumsum(counts, out=indptr[1:])
    feats = rng.standard_normal((cfg.n_nodes, cfg.d_feat)).astype(np.float32)
    labels = rng.integers(0, cfg.n_classes, cfg.n_nodes).astype(np.int32)
    return CsrGraph(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(src_s),
        feats=jnp.asarray(feats),
        labels=jnp.asarray(labels),
    )


def sample_neighbors(
    key: jax.Array,
    indptr: jax.Array,
    indices: jax.Array,
    seeds: jax.Array,   # (B,) int32 node ids
    fanout: int,
) -> jax.Array:
    """(B, fanout) uniform with-replacement samples of incoming neighbors;
    -1 where the node has no neighbors.  Pure gather — jit/vmap-friendly."""
    start = indptr[seeds]                 # (B,)
    deg = indptr[seeds + 1] - start       # (B,)
    u = jax.random.randint(key, (seeds.shape[0], fanout), 0, 1 << 30)
    pos = u % jnp.maximum(deg, 1)[:, None]
    nbr = indices[start[:, None] + pos]
    return jnp.where(deg[:, None] > 0, nbr, -1)


def sample_two_hop(
    key: jax.Array,
    indptr: jax.Array,
    indices: jax.Array,
    seeds: jax.Array,
    fanouts: Tuple[int, int],
) -> Tuple[jax.Array, jax.Array]:
    """GraphSAGE 2-layer sampling: (B, f1) and (B, f1, f2) index blocks."""
    k1, k2 = jax.random.split(key)
    f1, f2 = fanouts
    nbr1 = sample_neighbors(k1, indptr, indices, seeds, f1)  # (B, f1)
    flat = jnp.maximum(nbr1.reshape(-1), 0)
    nbr2 = sample_neighbors(k2, indptr, indices, flat, f2)
    nbr2 = jnp.where((nbr1.reshape(-1) >= 0)[:, None], nbr2, -1)
    return nbr1, nbr2.reshape(seeds.shape[0], f1, f2)


def batch_seeds(key: jax.Array, n_nodes: int, batch: int) -> jax.Array:
    return jax.random.randint(key, (batch,), 0, n_nodes, dtype=jnp.int32)


def make_molecule_batch(
    key: jax.Array, batch: int, n_nodes: int, n_edges: int, d_feat: int,
    n_classes: int,
) -> dict:
    """Batch of small fixed-size random graphs (molecule cell)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "feats": jax.random.normal(k1, (batch, n_nodes, d_feat), jnp.float32),
        "src": jax.random.randint(k2, (batch, n_edges), 0, n_nodes, dtype=jnp.int32),
        "dst": jax.random.randint(k3, (batch, n_edges), 0, n_nodes, dtype=jnp.int32),
        "labels": jax.random.randint(k4, (batch,), 0, n_classes, dtype=jnp.int32),
    }
