"""Criteo-shaped recsys batches: multi-hot categorical ids + dense floats.

Per-field ids are Zipf-distributed inside each field's row range (real CTR id
spaces are heavy-tailed — this stresses the embedding gather with realistic
hot rows).  Stateless: batch = f(seed, step).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.recsys import TableSpec


@dataclasses.dataclass(frozen=True)
class RecsysDataConfig:
    table: TableSpec = None  # type: ignore[assignment]
    batch: int = 65536
    nnz: int = 1
    n_dense: int = 0
    seed: int = 0
    zipf_a: float = 1.2


def _zipf_in_range(key, shape, n_rows: jax.Array, a: float) -> jax.Array:
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    ranks = jnp.floor(u ** (-1.0 / (a - 1.0))).astype(jnp.int32)
    return jnp.minimum(ranks, n_rows - 1)


def batch_at(cfg: RecsysDataConfig, step: int) -> dict:
    """{'sparse': (B, F, nnz) local ids, 'dense': (B, n_dense)?, 'label': (B,)}"""
    key = jax.random.fold_in(jax.random.key(cfg.seed), step)
    k_sp, k_de, k_lb = jax.random.split(key, 3)
    rows = jnp.asarray(cfg.table.row_counts, jnp.int32)  # (F,)
    sparse = _zipf_in_range(
        k_sp, (cfg.batch, cfg.table.n_fields, cfg.nnz), rows[None, :, None],
        cfg.zipf_a,
    )
    out = {"sparse": sparse, "label": jax.random.bernoulli(k_lb, 0.25, (cfg.batch,)).astype(jnp.float32)}
    if cfg.n_dense:
        out["dense"] = jax.random.normal(k_de, (cfg.batch, cfg.n_dense), jnp.float32)
    return out
