"""Synthetic embedding corpora with word2vec-like spectral statistics.

No internet in this container, so the paper's corpora (word2vec GoogleNews,
GloVe Twitter — both 300-d) are synthesized with matched statistics
(validated in benchmarks/table1.py; DESIGN.md §6):

  * power-law singular-value spectrum sigma_i ~ i^-alpha (word embedding
    matrices empirically show alpha ~ 1);
  * a non-zero common mean component — the thing PPA ("all-but-the-top",
    Mu et al.) removes; without it ppa-pca-ppa would be indistinguishable
    from pca;
  * heavy-tailed per-vector norms (frequent words have larger norms).

The *claims* validated on this data are the paper's relative orderings and
parameter trends (fake words > LSH > k-d tree; recall rises with Q and d),
which are robust to the exact distribution.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    name: str = "word2vec-like"
    n_vectors: int = 100_000
    dim: int = 300
    alpha: float = 1.0         # spectrum decay
    mean_strength: float = 0.6  # common-component magnitude (PPA target)
    seed: int = 0


def make_corpus(cfg: CorpusConfig) -> np.ndarray:
    """(N, dim) float32 with the statistics above.  NumPy on host (this is
    offline data prep, not device compute)."""
    rng = np.random.default_rng(cfg.seed)
    # Low-rank-ish spectral shaping: Z @ diag(s) @ Q, Q orthogonal.
    z = rng.standard_normal((cfg.n_vectors, cfg.dim)).astype(np.float32)
    s = (np.arange(1, cfg.dim + 1, dtype=np.float32)) ** (-cfg.alpha)
    s = s / np.sqrt(np.mean(s**2))
    q, _ = np.linalg.qr(rng.standard_normal((cfg.dim, cfg.dim)).astype(np.float32))
    x = (z * s[None, :]) @ q
    # Common mean component (what PPA strips).
    mu = rng.standard_normal(cfg.dim).astype(np.float32)
    mu = mu / np.linalg.norm(mu) * cfg.mean_strength
    x = x + mu[None, :]
    # Heavy-tailed norms (Zipfian word frequency -> norm correlation).
    scale = rng.pareto(3.0, cfg.n_vectors).astype(np.float32) + 1.0
    x = x * scale[:, None]
    return x


def make_queries(
    corpus: np.ndarray, n_queries: int, seed: int = 1, jitter: float = 0.0
) -> tuple[np.ndarray, np.ndarray]:
    """Queries drawn from the corpus (the paper's word-similarity setup:
    query terms are corpus words — TREC Robust04 title words).  Returns
    (queries, query_ids) so self-matches can be excluded in eval."""
    rng = np.random.default_rng(seed)
    ids = rng.choice(corpus.shape[0], size=n_queries, replace=False)
    q = corpus[ids].copy()
    if jitter > 0:
        q += jitter * rng.standard_normal(q.shape).astype(np.float32)
    return q, ids


# alpha calibration (see EXPERIMENTS.md §Calibration): variance_i ~ i^-2a.
# a=0.3 puts fake-words R@(10,10) at ~0.63 for q=50 — matching the paper's
# 0.62 band on word2vec — while collapsing 8-dim PCA recall (the top-8
# components hold only ~25-30% of variance, like real 300-d embeddings).
WORD2VEC_LIKE = CorpusConfig(name="word2vec-like", alpha=0.3, mean_strength=0.6, seed=0)
GLOVE_LIKE = CorpusConfig(name="glove-like", alpha=0.4, mean_strength=0.9, seed=7)
