"""Synthetic but production-shaped data pipelines.

Every generator is **seeded and stateless**: batch(step) is a pure function
of (seed, step), so a restarted job resumes mid-epoch deterministically
(fault-tolerance requirement — no iterator state to checkpoint).
"""
