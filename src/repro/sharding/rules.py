"""Per-family PartitionSpec rules (DESIGN.md §5).

One function per family maps the parameter/activation trees onto the
production mesh axes:

    pod   — replica axis across pods (pure DP; params replicated)
    data  — FSDP/DP within a pod (params sharded for FSDP; batch sharded)
    model — TP (attention/FFN inner dims), EP (experts), KV-length shards,
            embedding-table rows, corpus docs

Specs are name-based over the parameter tree produced by each model's
``param_shapes`` so they track structure changes automatically; leading
stack axes ((n_blocks,) or (n_blocks, dense_per_block)) get None's padded.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

FSDP = "data"
TP = "model"


def batch_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def all_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data", "model") if multi_pod else ("data", "model")


# --------------------------------------------------------------------------
# LM transformer
# --------------------------------------------------------------------------

_LM_TRAILING = {
    # name -> trailing-dims spec (applied right-aligned to the leaf shape)
    "embed": (TP, FSDP),       # (V, d): vocab->TP, d->FSDP
    "lm_head": (FSDP, TP),     # (d, V)
    "final_ln": (None,),
    "ln1": (None,),
    "ln2": (None,),
    "wq": (FSDP, TP),          # (d, H*dh)
    "wk": (FSDP, TP),
    "wv": (FSDP, TP),
    "wo": (TP, FSDP),          # (H*dh, d)
    "w_gate": (FSDP, TP),      # (d, f)
    "w_up": (FSDP, TP),
    "w_down": (TP, FSDP),      # (f, d)
    "router": (FSDP, None),    # (d, E)
    "moe_gate": (TP, FSDP, None),  # (E, d, f): experts->TP (EP)
    "moe_up": (TP, FSDP, None),
    "moe_down": (TP, None, FSDP),  # (E, f, d)
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return entry.key
    raise ValueError(f"no key in path {path}")


def _spec_for(name: str, ndim: int, table) -> P:
    trailing = table[name]
    lead = (None,) * (ndim - len(trailing))
    return P(*lead, *trailing)


def lm_param_specs(shapes: Pytree) -> Pytree:
    """PartitionSpec tree mirroring transformer.param_shapes(cfg)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, s: _spec_for(_leaf_name(path), len(s), _LM_TRAILING),
        shapes,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def lm_batch_spec(multi_pod: bool) -> P:
    return P(batch_axes(multi_pod), None)  # (B, S)


def lm_cache_spec(multi_pod: bool, long_context: bool = False) -> P:
    """KV cache (L, B, T, Hkv, dh).  Normal decode: batch->DP axes,
    length->TP (flash-decoding split-K).  Long-context (B=1): length over
    ALL axes — the only way 524288-token caches spread across the pod."""
    if long_context:
        return P(None, None, all_axes(multi_pod), None, None)
    return P(None, batch_axes(multi_pod), TP, None, None)


def lm_logit_spec(multi_pod: bool) -> P:
    return P(batch_axes(multi_pod), TP)  # (B, V)


# --------------------------------------------------------------------------
# Optimizer-state specs mirror the parameter specs
# --------------------------------------------------------------------------


def adamw_state_specs(param_specs: Pytree) -> Pytree:
    return {
        "mu": param_specs,
        "nu": param_specs,
        "step": P(),
    }


def adafactor_state_specs(param_specs: Pytree, param_shapes: Pytree) -> Pytree:
    def leaf(spec: P, shape) -> Any:
        if len(shape) >= 2:
            return {"vr": P(*spec[:-1]), "vc": P(*spec[:-2], spec[-1])}
        return {"v": spec}

    v = jax.tree_util.tree_map(
        leaf, param_specs, param_shapes,
        is_leaf=lambda x: isinstance(x, P),
    )
    return {"v": v, "step": P()}


def opt_state_specs(kind: str, param_specs: Pytree, param_shapes: Pytree) -> Pytree:
    if kind == "adamw":
        return adamw_state_specs(param_specs)
    return adafactor_state_specs(param_specs, param_shapes)


# --------------------------------------------------------------------------
# GNN
# --------------------------------------------------------------------------


def gnn_param_specs(shapes: Pytree) -> Pytree:
    """GraphSAGE params are < 1 MB — replicate everything."""
    return jax.tree_util.tree_map(
        lambda s: P(), shapes, is_leaf=lambda x: isinstance(x, tuple)
    )


def gnn_edge_spec(multi_pod: bool) -> P:
    return P(all_axes(multi_pod))  # (E,) sharded over every device


def gnn_minibatch_spec(multi_pod: bool, ndim: int) -> P:
    return P(all_axes(multi_pod), *(None,) * (ndim - 1))


# --------------------------------------------------------------------------
# RecSys
# --------------------------------------------------------------------------


def recsys_param_specs(shapes: Pytree) -> Pytree:
    """Embedding tables row-shard over TP ('model'); dense MLPs replicate."""

    def rule(path, s):
        name = _leaf_name(path)
        if name in ("table", "linear"):
            return P(TP, None)
        return P(*(None,) * len(s))

    return jax.tree_util.tree_map_with_path(
        rule, shapes, is_leaf=lambda x: isinstance(x, tuple)
    )


def recsys_batch_spec(multi_pod: bool, ndim: int) -> P:
    return P(batch_axes(multi_pod), *(None,) * (ndim - 1))


def recsys_cand_spec(multi_pod: bool) -> P:
    return P(all_axes(multi_pod), None)  # (N_cand, d) docs over everything


def drop_axis(spec: P, name: str) -> P:
    """Remove one mesh axis from every entry of a PartitionSpec (ZeRO-2:
    the bf16 compute copy replicates over the FSDP axis while the f32
    master + optimizer states stay fully sharded)."""
    out = []
    for entry in spec:
        if entry == name:
            out.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a != name)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            out.append(entry)
    return P(*out)


# --------------------------------------------------------------------------
# Helpers
# --------------------------------------------------------------------------


def named(mesh: Mesh, spec_tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def struct_with_sharding(shape_tree: Pytree, dtype_tree, mesh: Mesh, spec_tree: Pytree):
    """ShapeDtypeStruct pytree with NamedShardings attached (dry-run
    stand-ins: weak-type-correct, shardable, no allocation)."""

    def mk(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(
        mk, shape_tree, dtype_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )
