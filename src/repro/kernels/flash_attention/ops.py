"""jit'd wrapper selecting the flash kernel or the XLA fallback.

Models call ``causal_attention``; on TPU it routes to the Pallas kernel, on
CPU (tests, smoke runs) it uses the jnp reference so nothing depends on
interpret-mode speed.
"""
from __future__ import annotations

import jax

from repro.kernels import common
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def causal_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, use_kernel: bool | None = None
) -> jax.Array:
    if use_kernel is None:
        use_kernel = not common.INTERPRET
    if use_kernel:
        return flash_attention(q, k, v)
    return attention_ref(q, k, v)
