"""Pallas TPU kernel: causal GQA flash attention (prefill hot path).

Online-softmax tiling (Dao et al., adapted to TPU VMEM/MXU): the query tile
(bq x d) stays resident; key/value tiles stream through VMEM; running
(max, sum, acc) statistics live in f32 scratch carried across the innermost
KV grid axis.  Causality is exploited structurally: KV tiles strictly above
the diagonal are skipped with ``pl.when`` (no wasted MXU work), and the
intra-tile diagonal is masked.

GQA: query head h reads KV head h // group via the K/V BlockSpec index maps -
no KV replication in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, bq, bk, n_k, scale, true_len
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causal structure: KV tile fully above the diagonal contributes nothing.
    needed = ki * bk <= qi * bq + bq - 1

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = scale * jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = (q_pos >= k_pos) & (k_pos < true_len)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]  # (bq, 1) broadcast over lanes
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)  # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bq", "bk", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Causal GQA flash attention.  S is padded to the tile size internally;
    D should be MXU-friendly (it is 128 for every assigned arch)."""
    if interpret is None:
        interpret = common.INTERPRET
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = 1.0 / (d**0.5)
    bq = min(bq, common.round_up(s, 8))
    bk = min(bk, common.round_up(s, common.LANE))
    qp = common.pad_dim(q, 2, bq)
    kp = common.pad_dim(k, 2, bk)
    vp = common.pad_dim(v, 2, bk)
    n_q, n_k = qp.shape[2] // bq, kp.shape[2] // bk
    grid = (b, hq, n_q, n_k)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            bq=bq,
            bk=bk,
            n_k=n_k,
            scale=scale,
            true_len=s,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, d), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, d), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            common.MemorySpace.VMEM((bq, d), jnp.float32),
            common.MemorySpace.VMEM((bq, 1), jnp.float32),
            common.MemorySpace.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :s, :]
