from repro.kernels.flash_attention.kernel import flash_attention  # noqa: F401
from repro.kernels.flash_attention.ops import causal_attention  # noqa: F401
