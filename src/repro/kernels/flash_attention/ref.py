"""Pure-jnp oracle: causal GQA attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scale = 1.0 / (d**0.5)
    logits = scale * jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    )
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)
