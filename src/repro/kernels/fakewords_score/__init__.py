from repro.kernels.fakewords_score.kernel import score_matmul  # noqa: F401
from repro.kernels.fakewords_score.ops import classic_scores, dot_scores  # noqa: F401
