"""Pure-jnp oracle for the fake-words scoring kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def score_matmul_ref(q: jax.Array, docs: jax.Array) -> jax.Array:
    acc = jnp.int32 if q.dtype in (jnp.int8, jnp.int32) else jnp.float32
    out = jnp.einsum("bt,nt->bn", q, docs, preferred_element_type=acc)
    return out.astype(jnp.float32) if acc == jnp.int32 else out


def classic_scores_ref(
    q_tf: jax.Array, scored: jax.Array, keep: jax.Array
) -> jax.Array:
    """End-to-end classic-similarity reference (mirrors core.fakewords)."""
    qv = (q_tf * keep).astype(jnp.bfloat16)
    return jnp.einsum("bt,nt->bn", qv, scored, preferred_element_type=jnp.float32)
