"""jit'd public wrappers around the fake-words scoring kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fakewords
from repro.core.types import FakeWordsIndex
from repro.kernels.fakewords_score.kernel import score_matmul


def classic_scores(
    index: FakeWordsIndex, q_tf: jax.Array, df_max_ratio: float = 1.0
) -> jax.Array:
    """Kernel-backed drop-in for core.fakewords.classic_scores."""
    keep = fakewords.df_prune_mask(index.df, index.num_docs, df_max_ratio)
    qv = (q_tf * keep).astype(jnp.bfloat16)
    return score_matmul(qv, index.scored)


def dot_scores(
    index: FakeWordsIndex, q_tf: jax.Array, df_max_ratio: float = 1.0
) -> jax.Array:
    """Kernel-backed drop-in for core.fakewords.dot_scores (int8 MXU path)."""
    keep = fakewords.df_prune_mask(index.df, index.num_docs, df_max_ratio)
    m = index.num_terms // 2
    u = q_tf[:, :m] - q_tf[:, m:]
    q_lift = (jnp.concatenate([u, -u], axis=-1) * keep).astype(jnp.int8)
    return score_matmul(q_lift, index.tf)
