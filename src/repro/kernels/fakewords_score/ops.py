"""jit'd public wrappers around the fake-words scoring kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fakewords
from repro.core.types import FakeWordsIndex
from repro.kernels.fakewords_score.kernel import score_matmul


def classic_scores(
    index: FakeWordsIndex, q_tf: jax.Array, df_max_ratio: float = 1.0
) -> jax.Array:
    """Kernel-backed drop-in for core.fakewords.classic_scores."""
    qv = fakewords.classic_query(index, q_tf, df_max_ratio)
    return score_matmul(qv, index.scored)


def dot_scores(
    index: FakeWordsIndex, q_tf: jax.Array, df_max_ratio: float = 1.0
) -> jax.Array:
    """Kernel-backed drop-in for core.fakewords.dot_scores (int8 MXU path)."""
    q_lift = fakewords.dot_query(index, q_tf, df_max_ratio, dtype=jnp.int8)
    return score_matmul(q_lift, index.tf)
