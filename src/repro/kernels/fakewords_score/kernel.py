"""Pallas TPU kernel: fake-words index-scan GEMM.

The inverted-index scoring loop of the paper's fake-words method, realized as
a tiled GEMM over the stored term-frequency matrix (docs/DESIGN.md §3):

  * classic mode - scores = q_tf @ scored.T where ``scored`` already folds
    sqrt(tf_d) * idf^2 * norm_d (bf16 operands, f32 accumulate on the MXU);
  * dot mode    - scores = q_lift @ tf.T with int8 operands and int32
    accumulate (the MXU's 4x-throughput integer path); q_lift = [u; -u],
    u = q+ - q-.

Grid = (query tiles, doc tiles, dim tiles); the dim (K) axis is innermost and
marked "arbitrary" so the accumulator scratch carries across K steps.  Doc
blocks stream HBM->VMEM once per query tile: the op is memory-bound at
production corpus sizes, which is why the df-pruning / blockmax levers in
core/ matter (they cut streamed bytes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common


def _score_kernel(q_ref, d_ref, o_ref, acc_ref, *, n_k: int, acc_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        q_ref[...], d_ref[...].T, preferred_element_type=acc_dtype
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bq", "bn", "bk", "out_dtype", "interpret")
)
def score_matmul(
    q: jax.Array,  # (B, T)  bf16 (classic) or int8 (dot)
    docs: jax.Array,  # (N, T)  bf16 (classic) or int8 (dot)
    bq: int = 128,
    bn: int = 512,
    bk: int = 512,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:
    """Tiled scores = q @ docs.T with MXU-aligned VMEM blocks."""
    if interpret is None:
        interpret = common.INTERPRET
    b, t = q.shape
    n = docs.shape[0]
    bq = min(bq, common.round_up(b, 8))
    bn = min(bn, common.round_up(n, common.LANE))
    bk = min(bk, common.round_up(t, common.LANE))
    qp = common.pad_dim(common.pad_dim(q, 0, bq), 1, bk)
    dp = common.pad_dim(common.pad_dim(docs, 0, bn), 1, bk)
    acc_dtype = jnp.int32 if q.dtype in (jnp.int8, jnp.int32) else jnp.float32
    grid = (qp.shape[0] // bq, dp.shape[0] // bn, qp.shape[1] // bk)

    out = pl.pallas_call(
        functools.partial(_score_kernel, n_k=grid[2], acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], dp.shape[0]), out_dtype),
        scratch_shapes=[common.MemorySpace.VMEM((bq, bn), acc_dtype)],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, dp)
    return out[:b, :n]
