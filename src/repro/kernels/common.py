"""Shared Pallas kernel utilities.

Kernels here target TPU (MXU 128x128 systolic matmul, VMEM tiling via
BlockSpec) but are validated on CPU with ``interpret=True``, which executes
the kernel body in Python.  ``INTERPRET`` flips globally for tests.
"""
from __future__ import annotations

import os

import jax

# CPU containers run every kernel in interpret mode; on a real TPU leave unset.
INTERPRET = jax.default_backend() != "tpu" or bool(
    int(os.environ.get("REPRO_PALLAS_INTERPRET", "0"))
)

# MXU/VPU-aligned default tiles.
LANE = 128
SUBLANE_F32 = 8
SUBLANE_BF16 = 16
SUBLANE_INT8 = 32


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_dim(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    """Zero-pad ``axis`` of x up to a multiple (kernels want aligned tiles)."""
    import jax.numpy as jnp

    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)
