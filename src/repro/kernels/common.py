"""Shared Pallas kernel utilities.

Kernels here target TPU (MXU 128x128 systolic matmul, VMEM tiling via
BlockSpec) but are validated on CPU with ``interpret=True``, which executes
the kernel body in Python.  ``INTERPRET`` flips globally for tests.
"""
from __future__ import annotations

import os

import jax
from jax.experimental.pallas import tpu as pltpu

# CPU containers run every kernel in interpret mode; on a real TPU leave unset.
INTERPRET = jax.default_backend() != "tpu" or bool(
    int(os.environ.get("REPRO_PALLAS_INTERPRET", "0"))
)

# --------------------------------------------------------------------------
# Pallas TPU API version shim.  JAX renamed ``pltpu.TPUMemorySpace`` /
# ``pltpu.TPUCompilerParams`` to ``MemorySpace`` / ``CompilerParams``; kernels
# import the names from here so both JAX generations work (0.4.x pins the old
# spelling).
# --------------------------------------------------------------------------
MemorySpace = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# Default for the ``use_kernel`` routing flags on the search hot paths: the
# fused Pallas path on real TPUs, the XLA reference path elsewhere (tests
# opt in explicitly and run the kernels in interpret mode).
# ``REPRO_USE_KERNEL=1`` forces the kernel path off-TPU too (paired with
# interpret mode this lets CI exercise the Pallas kernel bodies on CPU).
USE_KERNEL_DEFAULT = jax.default_backend() == "tpu" or bool(
    int(os.environ.get("REPRO_USE_KERNEL", "0"))
)

# MXU/VPU-aligned default tiles.
LANE = 128
SUBLANE_F32 = 8
SUBLANE_BF16 = 16
SUBLANE_INT8 = 32


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (bitonic networks need pow2 lengths)."""
    return 1 << max(0, (x - 1).bit_length())


def pad_dim(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    """Zero-pad ``axis`` of x up to a multiple (kernels want aligned tiles)."""
    import jax.numpy as jnp

    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)
