"""Shared Pallas kernel utilities.

Kernels here target TPU (MXU 128x128 systolic matmul, VMEM tiling via
BlockSpec) but are validated on CPU with ``interpret=True``, which executes
the kernel body in Python.  ``INTERPRET`` flips globally for tests.
"""
from __future__ import annotations

import os
from typing import Any

import jax
from jax.experimental.pallas import tpu as pltpu

# CPU containers run every kernel in interpret mode; on a real TPU leave unset.
INTERPRET = jax.default_backend() != "tpu" or bool(
    int(os.environ.get("REPRO_PALLAS_INTERPRET", "0"))
)

# --------------------------------------------------------------------------
# Pallas TPU API version shim.  JAX renamed ``pltpu.TPUMemorySpace`` /
# ``pltpu.TPUCompilerParams`` to ``MemorySpace`` / ``CompilerParams``; kernels
# import the names from here so both JAX generations work (0.4.x pins the old
# spelling).
# --------------------------------------------------------------------------
MemorySpace = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# Default for the ``use_kernel`` routing flags on the search hot paths: the
# fused Pallas path on real TPUs, the XLA reference path elsewhere (tests
# opt in explicitly and run the kernels in interpret mode).
# ``REPRO_USE_KERNEL=1`` forces the kernel path off-TPU too (paired with
# interpret mode this lets CI exercise the Pallas kernel bodies on CPU).
USE_KERNEL_DEFAULT = jax.default_backend() == "tpu" or bool(
    int(os.environ.get("REPRO_USE_KERNEL", "0"))
)

# MXU/VPU-aligned default tiles.
LANE = 128
SUBLANE_F32 = 8
SUBLANE_BF16 = 16
SUBLANE_INT8 = 32


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (bitonic networks need pow2 lengths)."""
    return 1 << max(0, (x - 1).bit_length())


# --------------------------------------------------------------------------
# Canonical int4 nibble unpack / grouped-scale dequantization
# (docs/DESIGN.md §12).  Lives here — the dependency-free kernel utility
# module — so the Pallas kernel tiles, the XLA reference scoring paths and
# the build-time quantizer (core/builder.py) all run the EXACT same
# operation sequence: bit-for-bit identical dequantized operands.
# --------------------------------------------------------------------------


def unpack_int4(packed: jax.Array) -> jax.Array:
    """uint8 nibble pairs -> interleaved nibble columns (..., 2C) uint8.

    Low nibble = even column, high nibble = odd column; interleaving is a
    stack + reshape (pairwise, gather-free — the same trick as the bitonic
    network's compare-exchange pairing)."""
    import jax.numpy as jnp

    lo = packed & jnp.uint8(0xF)
    hi = packed >> 4
    shape = packed.shape[:-1] + (2 * packed.shape[-1],)
    return jnp.stack([lo, hi], axis=-1).reshape(shape)


def expand_group_scale(scale: jax.Array, group: int) -> jax.Array:
    """(..., G) per-group scales -> (..., G*group) per-column, via broadcast
    + reshape (no gathers)."""
    import jax.numpy as jnp

    shape = scale.shape[:-1] + (scale.shape[-1], group)
    return jnp.broadcast_to(scale[..., None], shape).reshape(
        scale.shape[:-1] + (scale.shape[-1] * group,)
    )


def dequant_int4(
    packed: jax.Array, scale: jax.Array, group: int, dtype: Any
) -> jax.Array:
    """THE canonical int4 grouped-scale dequant ordering: f32 (nibble - 8)
    * group_scale, then ONE cast to the compute dtype.  (..., C) packed +
    (..., 2C/group) scales -> (..., 2C) values."""
    import jax.numpy as jnp

    nib = unpack_int4(packed).astype(jnp.float32) - 8.0
    return (nib * expand_group_scale(scale, group)).astype(dtype)


def pad_dim(x: jax.Array, axis: int, multiple: int, value: Any = 0) -> jax.Array:
    """Zero-pad ``axis`` of x up to a multiple (kernels want aligned tiles)."""
    import jax.numpy as jnp

    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)
