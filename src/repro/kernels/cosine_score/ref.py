"""Pure-jnp oracle for the cosine scoring kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cosine_scores_ref(
    q: jax.Array, docs: jax.Array, inv_norm: jax.Array
) -> jax.Array:
    return (
        jnp.einsum("bd,nd->bn", q, docs, preferred_element_type=jnp.float32)
        * inv_norm[None, :]
    )
