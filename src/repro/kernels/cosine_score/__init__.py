from repro.kernels.cosine_score.kernel import cosine_scores  # noqa: F401
from repro.kernels.cosine_score.ops import cosine_topk  # noqa: F401
