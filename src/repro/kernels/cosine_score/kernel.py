"""Pallas TPU kernel: cosine scoring GEMM with fused normalization epilogue.

scores = (q @ docs.T) * inv_norm_d  - the exact-rerank / brute-force /
``retrieval_cand`` hot path.  Queries are pre-normalized (cheap, B rows);
document norms fold into the epilogue so the docs matrix streams HBM->VMEM
once, unmodified (no materialized normalized copy).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common


def _cosine_kernel(q_ref, d_ref, inv_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        q_ref[...], d_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * inv_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bn", "bk", "interpret"))
def cosine_scores(
    q: jax.Array,  # (B, dim), unit-normalized
    docs: jax.Array,  # (N, dim), raw
    inv_norm: jax.Array,  # (N,) 1/||doc||
    bq: int = 128,
    bn: int = 512,
    bk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = common.INTERPRET
    b, dim = q.shape
    n = docs.shape[0]
    bq = min(bq, common.round_up(b, 8))
    bn = min(bn, common.round_up(n, common.LANE))
    bk = min(bk, common.round_up(dim, common.LANE))
    qp = common.pad_dim(common.pad_dim(q, 0, bq), 1, bk)
    dp = common.pad_dim(common.pad_dim(docs, 0, bn), 1, bk)
    ip = common.pad_dim(inv_norm[None, :], 1, bn)  # (1, N_pad)
    grid = (qp.shape[0] // bq, dp.shape[0] // bn, qp.shape[1] // bk)

    out = pl.pallas_call(
        functools.partial(_cosine_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], dp.shape[0]), jnp.float32),
        scratch_shapes=[common.MemorySpace.VMEM((bq, bn), jnp.float32)],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, dp, ip)
    return out[:b, :n]
