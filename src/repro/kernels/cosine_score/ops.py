"""jit'd public wrappers for cosine scoring."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.cosine_score.kernel import cosine_scores


@functools.partial(jax.jit, static_argnames=("k",))
def cosine_topk(
    q: jax.Array, docs: jax.Array, k: int
) -> Tuple[jax.Array, jax.Array]:
    """Exact cosine top-k via the fused kernel (normalizes both sides)."""
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    inv = 1.0 / jnp.maximum(jnp.linalg.norm(docs, axis=-1), 1e-12)
    scores = cosine_scores(qn, docs, inv)
    return jax.lax.top_k(scores, k)
