"""Fused streaming score -> top-k Pallas kernel (docs/DESIGN.md §4)."""
from repro.kernels.fused_topk.kernel import fused_topk, fused_topk_gathered
from repro.kernels.fused_topk import ops, ref

__all__ = ["fused_topk", "fused_topk_gathered", "ops", "ref"]
