"""Pallas TPU kernel: fused streaming score -> top-k (docs/DESIGN.md §4).

Every search hot path used to materialize a dense (B, N) f32 score matrix in
HBM and only then run ``jax.lax.top_k`` — at production corpus sizes the
score-matrix write+read dominates HBM traffic, not the index scan.  This
kernel applies the flash-attention online-reduction trick to retrieval: a
tiled GEMM over doc blocks keeps a per-query running top-``depth``
(scores + global doc ids) in VMEM scratch across the doc-tile grid axis, so
the only HBM traffic is the index stream plus an O(B * depth) result.

Score stages (selected by ``mode`` / operand dtypes):

  * gemm  — scores = q @ docs.T.  bf16 operands with f32 accumulate covers
    the classic-similarity path (q = tf_q * keep against the precomputed
    ``scored`` matrix); int8 operands with int32 accumulate cover the dot
    path (q lifted to [u; -u], the MXU's 4x-throughput integer pipe); f32
    covers brute-force cosine and the kd-tree reduced-space L2 lift.
  * lsh   — scores = MinHash collision counts (equality + popcount-style
    reduce on the VPU; sentinel-aware like ``lsh_match``).

Grid = (query tiles, doc tiles, reduce tiles); the reduce (K) axis is the
innermost "arbitrary" axis so the (bq, bn) accumulator carries across K
steps, and the doc axis is also "arbitrary" so the running top-``depth``
scratch carries across doc tiles.  After the last K step of each doc tile the
tile's scores are merged into the running best — a whole tile is skipped when
its best score cannot beat any query's current depth-th best (the dense-GEMM
analogue of WAND block skipping).  Two merge strategies (``merge``):

  * "bitonic" (default) — bitonic per-tile pre-reduction: a vectorized
    bitonic sort network (reshape-paired compare-exchanges, no gathers)
    sorts the tile by (score desc, id asc), the top ``depth`` columns are
    kept, and one bitonic merge stage folds them into the (sorted) running
    best.  O(log^2 bn + log depth) vectorized steps per tile instead of
    ``depth`` sequential max-extractions.
  * "extract" — the original exact iterative max-extraction (kept for A/B
    profiling; identical results).

Both strategies order ties by the minimum id, which equals ``jax.lax.top_k``'s
lowest-index tie-break because candidate ids are globally unique and id-sorted
in the dense variant; the gathered variant merges on GLOBAL doc ids so its tie
behavior matches the dense reference paths exactly.  Padded / ragged N is
masked to -inf inside the kernel, so callers can stream any corpus size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import common

# Sentinel id for empty / padded top-k slots (replaced by -1 on the host).
BIG_ID = np.int32(2**30)
LSH_SENTINEL = np.uint32(0xFFFFFFFF)

_INT_DTYPES = (jnp.int8, jnp.int32, jnp.uint32)


# --------------------------------------------------------------------------
# Bitonic sorting network (vectorized, gather-free)
# --------------------------------------------------------------------------


def _cmp_exchange(s, i, j: int, k: int):
    """One compare-exchange stage at stride ``j`` over lane axis 1.

    Partner pairing is done by reshape (elements ``x`` and ``x + j`` pair up),
    never by gather — TPU-friendly.  Direction follows the standard bitonic
    network: descending where ``(index & k) == 0`` (``k == 0`` means a merge
    stage: descending everywhere).  The comparator is the total order
    (score desc, id asc), so equal scores order by minimum id.
    """
    bq, n = s.shape
    s4 = s.reshape(bq, n // (2 * j), 2, j)
    i4 = i.reshape(bq, n // (2 * j), 2, j)
    sa, sb = s4[:, :, 0], s4[:, :, 1]
    ia, ib = i4[:, :, 0], i4[:, :, 1]
    a_first = (sa > sb) | ((sa == sb) & (ia < ib))  # a precedes b in DESC
    if k:
        idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
        desc = ((idx & k) == 0).reshape(1, n // (2 * j), 2, j)[:, :, 0]
        keep = jnp.where(desc, a_first, ~a_first)
    else:
        keep = a_first
    new_sa = jnp.where(keep, sa, sb)
    new_sb = jnp.where(keep, sb, sa)
    new_ia = jnp.where(keep, ia, ib)
    new_ib = jnp.where(keep, ib, ia)
    s = jnp.stack([new_sa, new_sb], axis=2).reshape(bq, n)
    i = jnp.stack([new_ia, new_ib], axis=2).reshape(bq, n)
    return s, i


def _bitonic_sort_desc(s, i):
    """Full bitonic sort of (bq, L) pairs by (score desc, id asc); L pow2."""
    n = s.shape[1]
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            s, i = _cmp_exchange(s, i, j, k if k < n else 0)
            j //= 2
        k *= 2
    return s, i


def _bitonic_merge_desc(s, i):
    """Merge a (bq, L) bitonic sequence (desc run ++ asc tail) to sorted
    descending; L pow2."""
    j = s.shape[1] // 2
    while j >= 1:
        s, i = _cmp_exchange(s, i, j, 0)
        j //= 2
    return s, i


def _merge_topk_bitonic(rs_ref, ri_ref, tile_s, tile_i) -> None:
    """Bitonic per-tile pre-reduction merge.

    Sort the candidate tile, keep its top ``dpad`` columns, then bitonic-merge
    against the running best (kept sorted descending as an invariant — both
    the init fill and this merge preserve it).  ``dpad`` (the running width)
    is a power of two on this path.
    """
    bq, dpad = rs_ref.shape
    pad_to = max(common.next_pow2(tile_s.shape[1]), dpad)
    pad = pad_to - tile_s.shape[1]
    if pad:
        tile_s = jnp.concatenate(
            [tile_s, jnp.full((bq, pad), -jnp.inf, tile_s.dtype)], axis=1
        )
        tile_i = jnp.concatenate(
            [tile_i, jnp.full((bq, pad), BIG_ID, tile_i.dtype)], axis=1
        )
    tile_s, tile_i = _bitonic_sort_desc(tile_s, tile_i)
    comb_s = jnp.concatenate([rs_ref[...], tile_s[:, dpad - 1 :: -1]], axis=1)
    comb_i = jnp.concatenate([ri_ref[...], tile_i[:, dpad - 1 :: -1]], axis=1)
    comb_s, comb_i = _bitonic_merge_desc(comb_s, comb_i)
    rs_ref[...] = comb_s[:, :dpad]
    ri_ref[...] = comb_i[:, :dpad]


# --------------------------------------------------------------------------
# Iterative max-extraction merge (legacy strategy, kept for A/B profiling)
# --------------------------------------------------------------------------


def _merge_topk_extract(rs_ref, ri_ref, tile_s, tile_i, depth: int) -> None:
    """Merge a (bq, bn) candidate tile into the running (bq, depth) best.

    Exact iterative max-extraction over the concatenated candidates.  Ties
    select the minimum id, which equals ``jax.lax.top_k``'s lowest-index
    tie-break over id-ordered candidates.  Extracted entries are retired to
    (-inf, BIG_ID) so -inf padding can never resurrect a stale id.
    """
    run_s = rs_ref[:, :depth]
    run_i = ri_ref[:, :depth]
    comb_s = jnp.concatenate([run_s, tile_s], axis=1)
    comb_i = jnp.concatenate([run_i, tile_i], axis=1)
    init = (
        comb_s,
        comb_i,
        jnp.full_like(run_s, -jnp.inf),
        jnp.full_like(run_i, BIG_ID),
    )

    def extract(d, carry):
        cs, ci, ns, ni = carry
        best = jnp.max(cs, axis=1, keepdims=True)  # (bq, 1)
        sel = jnp.min(
            jnp.where(cs == best, ci, BIG_ID), axis=1, keepdims=True
        )  # (bq, 1) min id among argmaxes
        col = jax.lax.broadcasted_iota(jnp.int32, ns.shape, 1) == d
        ns = jnp.where(col, best, ns)
        ni = jnp.where(col, sel, ni)
        kill = (cs == best) & (ci == sel)
        cs = jnp.where(kill, -jnp.inf, cs)
        ci = jnp.where(kill, BIG_ID, ci)
        return cs, ci, ns, ni

    _, _, new_s, new_i = jax.lax.fori_loop(0, depth, extract, init)
    rs_ref[:, :depth] = new_s
    ri_ref[:, :depth] = new_i


def _merge_if_improves(
    rs_ref, ri_ref, tile_s, tile_i, depth: int, merge: str, strict: bool
) -> None:
    """WAND-style tile skip: merging is wasted work unless some query's tile
    best can beat its current depth-th best.  ``strict`` (dense variant) is
    exact because ids ascend across doc tiles, so ties lose to the running
    set's smaller ids; the gathered variant merges on UNORDERED global doc
    ids (blocks arrive in stage-1 bound order), where a tying tile may hold
    the smaller — winning — id, so it must compare with ``>=``."""
    thresh = jnp.min(rs_ref[:, :depth], axis=1)
    best = jnp.max(tile_s, axis=1)
    improves = jnp.any(best > thresh if strict else best >= thresh)

    @pl.when(improves)
    def _():
        if merge == "bitonic":
            _merge_topk_bitonic(rs_ref, ri_ref, tile_s, tile_i)
        else:
            _merge_topk_extract(rs_ref, ri_ref, tile_s, tile_i, depth)


def _score_tile(q, d, mode: str, acc_dtype):
    if mode == "lsh":
        eq = (q[:, None, :] == d[None, :, :]) & (q[:, None, :] != LSH_SENTINEL)
        return jnp.sum(eq.astype(jnp.int32), axis=-1)
    return jnp.dot(q, d.T, preferred_element_type=acc_dtype)


def _fused_topk_kernel(
    q_ref, d_ref, *refs,
    n_j: int, n_k: int, n_docs: int, bn: int, depth: int, mode: str,
    merge: str, acc_dtype, has_filt: bool = False,
):
    if has_filt:
        f_ref, s_ref, i_ref, acc_ref, rs_ref, ri_ref = refs
    else:
        f_ref = None
        s_ref, i_ref, acc_ref, rs_ref, ri_ref = refs
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_running():
        rs_ref[...] = jnp.full_like(rs_ref, -jnp.inf)
        ri_ref[...] = jnp.full_like(ri_ref, BIG_ID)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _score_tile(q_ref[...], d_ref[...], mode, acc_dtype)

    @pl.when(k == n_k - 1)
    def _merge():
        tile_s = acc_ref[...].astype(jnp.float32)
        ids = j * bn + jax.lax.broadcasted_iota(jnp.int32, tile_s.shape, 1)
        valid = ids < n_docs  # ragged N: padded docs can never rank
        if has_filt:
            # Predicate bitmap applied INSIDE the streaming merge: filtered
            # docs score but can never rank, so the (B, N) matrix still
            # never exists and filtering costs one extra VPU AND per tile.
            valid = valid & (f_ref[...] != 0)
        tile_s = jnp.where(valid, tile_s, -jnp.inf)
        ids = jnp.where(valid, ids, BIG_ID)
        _merge_if_improves(rs_ref, ri_ref, tile_s, ids, depth, merge,
                           strict=True)

    @pl.when(jnp.logical_and(j == n_j - 1, k == n_k - 1))
    def _flush():
        s_ref[...] = rs_ref[...]
        i_ref[...] = ri_ref[...]


def _filt_operand(filt, bq: int, bn: int):
    """Normalize a per-doc predicate bitmap to a padded int32 kernel operand
    plus its BlockSpec.  Accepts (N,) (shared across the batch) or (B, N)
    (per-query); padding docs get 0 (already masked by the n_docs check,
    but keep the invariant anyway)."""
    f = filt.astype(jnp.int32)
    if f.ndim == 1:
        fp = common.pad_dim(f[None, :], 1, bn)
        return fp, pl.BlockSpec((1, bn), lambda i, j, k: (0, j))
    fp = common.pad_dim(common.pad_dim(f, 0, bq), 1, bn)
    return fp, pl.BlockSpec((bq, bn), lambda i, j, k: (i, j))


def _depth_pad(depth: int, merge: str) -> int:
    """Running-best lane width: LANE-aligned, and a power of two on the
    bitonic path (the merge network needs pow2 sequence lengths)."""
    dpad = common.round_up(depth, common.LANE)
    return common.next_pow2(dpad) if merge == "bitonic" else dpad


@functools.partial(
    jax.jit,
    static_argnames=(
        "depth", "mode", "merge", "bq", "bn", "bk", "interpret", "n_docs"
    ),
)
def fused_topk(
    q: jax.Array,  # (B, T)  bf16 / f32 (gemm), int8 (dot), uint32 (lsh)
    docs: jax.Array,  # (N, T) same reduce-axis dtype family as q
    depth: int,
    mode: str = "gemm",
    merge: str = "bitonic",
    bq: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
    filt: jax.Array | None = None,  # (N,) | (B, N) predicate bitmap
    n_docs: int | None = None,  # logical rows; rows >= n_docs never rank
) -> tuple[jax.Array, jax.Array]:
    """Streaming top-``depth`` of q @ docs.T (or LSH collision counts).

    Returns (scores f32 (B, depth), ids int32 (B, depth)), sorted descending
    with ``jax.lax.top_k`` tie semantics; id -1 marks empty (-inf) slots.
    The (B, N) score matrix never exists in HBM.

    ``filt`` (optional): per-doc predicate bitmap, (N,) shared or (B, N)
    per-query; nonzero = keep.  Applied as -inf inside the tile merge, so
    filtered search stays one kernel pass.  ``filt=None`` dispatches the
    exact unfiltered call graph (bitwise identical to not having the arg).

    ``n_docs`` (optional): logical row count when ``docs`` carries tail
    padding beyond the real corpus (the packed segment superbuffer of
    ``core/packed.py`` pads totals to a bucket ladder so executables recur
    across flush/merge cycles).  Rows >= ``n_docs`` ride the exact ragged-N
    mask the kernel already applies, so the padded tail can never rank and
    no bitmap operand is streamed.  Static: shape-stable callers only.
    """
    if interpret is None:
        interpret = common.INTERPRET
    if mode == "lsh":
        # The compare stage materializes a (bq, bn, bk) equality tensor in
        # VMEM — size tiles like ``lsh_match`` (~4 MB), not like the GEMM.
        bq, bn, bk = bq or 16, bn or 128, bk or 512
    else:
        bq, bn, bk = bq or 128, bn or 512, bk or 512
    b, t = q.shape
    n = docs.shape[0]
    if n_docs is None:
        n_docs = n
    assert 0 < n_docs <= n, f"n_docs {n_docs} outside (0, {n}]"
    assert depth <= n_docs, f"depth {depth} > corpus size {n_docs}"
    bq = min(bq, common.round_up(b, 8))
    bn = min(bn, common.round_up(n, common.LANE))
    bk = min(bk, common.round_up(t, common.LANE))
    if mode == "lsh":
        # Distinct fillers so padding never matches (query pad is masked).
        qp = common.pad_dim(common.pad_dim(q, 0, bq), 1, bk, value=LSH_SENTINEL)
        dp = common.pad_dim(
            common.pad_dim(docs, 0, bn), 1, bk, value=np.uint32(LSH_SENTINEL - 1)
        )
        acc_dtype = jnp.int32
    else:
        qp = common.pad_dim(common.pad_dim(q, 0, bq), 1, bk)
        dp = common.pad_dim(common.pad_dim(docs, 0, bn), 1, bk)
        acc_dtype = jnp.int32 if q.dtype in _INT_DTYPES else jnp.float32
    dpad = _depth_pad(depth, merge)
    grid = (qp.shape[0] // bq, dp.shape[0] // bn, qp.shape[1] // bk)
    operands = [qp, dp]
    in_specs = [
        pl.BlockSpec((bq, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
    ]
    if filt is not None:
        fp, f_spec = _filt_operand(filt, bq, bn)
        operands.append(fp)
        in_specs.append(f_spec)

    scores, ids = pl.pallas_call(
        functools.partial(
            _fused_topk_kernel,
            n_j=grid[1], n_k=grid[2], n_docs=n_docs, bn=bn, depth=depth,
            mode=mode, merge=merge, acc_dtype=acc_dtype,
            has_filt=filt is not None,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bq, dpad), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bq, dpad), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], dpad), jnp.float32),
            jax.ShapeDtypeStruct((qp.shape[0], dpad), jnp.int32),
        ],
        scratch_shapes=[
            common.MemorySpace.VMEM((bq, bn), acc_dtype),
            common.MemorySpace.VMEM((bq, dpad), jnp.float32),
            common.MemorySpace.VMEM((bq, dpad), jnp.int32),
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    scores = scores[:b, :depth]
    ids = ids[:b, :depth]
    return scores, jnp.where(scores == -jnp.inf, -1, ids)


def _fused_gathered_kernel(
    q_ref, d_ref, rid_ref, s_ref, i_ref, acc_ref, rs_ref, ri_ref,
    *, n_j: int, n_k: int, n_docs: int, depth: int, mode: str, merge: str,
    acc_dtype,
):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_running():
        rs_ref[...] = jnp.full_like(rs_ref, -jnp.inf)
        ri_ref[...] = jnp.full_like(ri_ref, BIG_ID)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _score_tile(q_ref[...], d_ref[0], mode, acc_dtype)

    @pl.when(k == n_k - 1)
    def _merge():
        tile_s = acc_ref[...].astype(jnp.float32)  # (1, bn)
        # Merge key = GLOBAL doc id: ties then resolve exactly like the dense
        # reference paths (lowest doc id), independent of the block-gather
        # order blockmax stage 1 produced.
        ids = rid_ref[...]
        valid = ids < n_docs  # folds the blockmax padding mask
        tile_s = jnp.where(valid, tile_s, -jnp.inf)
        ids = jnp.where(valid, ids, BIG_ID)
        _merge_if_improves(rs_ref, ri_ref, tile_s, ids, depth, merge,
                           strict=False)

    @pl.when(jnp.logical_and(j == n_j - 1, k == n_k - 1))
    def _flush():
        s_ref[...] = rs_ref[...]
        i_ref[...] = ri_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("depth", "n_docs", "mode", "merge", "bn", "bk", "interpret"),
)
def fused_topk_gathered(
    q: jax.Array,  # (B, T)
    docs: jax.Array,  # (B, R, T) per-query gathered candidate rows
    row_ids: jax.Array,  # (B, R) int32 global doc ids; >= n_docs = padding
    depth: int,
    n_docs: int,
    mode: str = "gemm",
    merge: str = "bitonic",
    bn: int = 512,
    bk: int = 512,
    interpret: bool | None = None,
    filt: jax.Array | None = None,  # (B, R) keep-bitmap aligned with row_ids
) -> tuple[jax.Array, jax.Array]:
    """Per-query streaming top-``depth`` over gathered candidate matrices
    (blockmax stage 2: each query scores only its own kept blocks' rows).

    ``mode`` selects the score stage exactly like :func:`fused_topk`: "gemm"
    (bf16/f32/int8 operands) or "lsh" (uint32 signature collision counts).
    Returns (scores f32 (B, depth), ids int32 (B, depth)); id -1 marks
    padded / -inf slots.  Ties break on the lowest GLOBAL doc id, matching
    the dense reference paths.  The (B, R) stage-2 score matrix never exists
    in HBM.

    ``filt`` (optional): (B, R) keep-bitmap aligned with ``row_ids``.  The
    mask folds into the row-id operand (filtered rows take the same
    out-of-range id the in-kernel padding mask drops), so filtering rides
    the existing merge-time mask — still one kernel pass, and ``filt=None``
    leaves the call graph untouched.
    """
    if interpret is None:
        interpret = common.INTERPRET
    b, r, t = docs.shape
    if filt is not None:
        row_ids = jnp.where(filt != 0, row_ids.astype(jnp.int32), BIG_ID)
    assert depth <= r, f"depth {depth} > candidate count {r}"
    bn = min(bn, common.round_up(r, common.LANE))
    bk = min(bk, common.round_up(t, common.LANE))
    if mode == "lsh":
        qp = common.pad_dim(q, 1, bk, value=LSH_SENTINEL)
        dp = common.pad_dim(
            common.pad_dim(docs, 1, bn), 2, bk, value=np.uint32(LSH_SENTINEL - 1)
        )
        acc_dtype = jnp.int32
    else:
        qp = common.pad_dim(q, 1, bk)
        dp = common.pad_dim(common.pad_dim(docs, 1, bn), 2, bk)
        acc_dtype = jnp.int32 if q.dtype in _INT_DTYPES else jnp.float32
    # Padding rows get an out-of-range id so the in-kernel mask drops them.
    rp = common.pad_dim(row_ids.astype(jnp.int32), 1, bn, value=BIG_ID)
    dpad = _depth_pad(depth, merge)
    grid = (b, dp.shape[1] // bn, qp.shape[1] // bk)

    scores, ids = pl.pallas_call(
        functools.partial(
            _fused_gathered_kernel,
            n_j=grid[1], n_k=grid[2], n_docs=n_docs, depth=depth,
            mode=mode, merge=merge, acc_dtype=acc_dtype,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, bn, bk), lambda i, j, k: (i, j, k)),
            pl.BlockSpec((1, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, dpad), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, dpad), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, dpad), jnp.float32),
            jax.ShapeDtypeStruct((b, dpad), jnp.int32),
        ],
        scratch_shapes=[
            common.MemorySpace.VMEM((1, bn), acc_dtype),
            common.MemorySpace.VMEM((1, dpad), jnp.float32),
            common.MemorySpace.VMEM((1, dpad), jnp.int32),
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, dp, rp)
    scores = scores[:, :depth]
    ids = ids[:, :depth]
    return scores, jnp.where(scores == -jnp.inf, -1, ids)


# --------------------------------------------------------------------------
# Quantized-postings variants: dequantization fused into the score stage
# (docs/DESIGN.md §12).  The packed int8/int4 store streams from HBM; tiles
# are unpacked and rescaled in VMEM registers, so the fp32/bf16 posting
# matrix never exists in HBM.
#
#   * bits=8 — the per-doc scale is constant along the reduce axis, so it
#     factorizes out of the dot: the int8 tile is cast to the query dtype
#     (exact: |q| <= 127 is representable in bf16), f32-accumulated across
#     K tiles, and the scale is applied ONCE per (query, doc) at merge time.
#   * bits=4 — nibbles are unpacked (``common.unpack_int4``) and rescaled
#     per group (``common.dequant_int4``) IN REGISTERS before the tile dot;
#     the canonical ordering (f32 (nibble-8) * group_scale, one cast to the
#     query dtype) is shared bit-for-bit with the XLA references and the
#     build-time ``dequantize_postings``.
#
# Padding invariants: packed pad byte 0x88 decodes to nibble 8 on both
# halves -> dequantized 0; scale pads are 0 (so any stray nibble still
# dequantizes to 0); query column pads are 0.  Padded doc ROWS are masked
# to (-inf, BIG_ID) by the n_docs check like the fp paths.
# --------------------------------------------------------------------------

INT4_PAD_BYTE = np.uint8(0x88)


def _dequant_tile(d, s, bits: int, group: int, q_dtype):
    """Unpack + rescale one packed doc tile in registers.

    bits=8: (bn, bk) int8 -> q_dtype (scale applied later, post-reduction).
    bits=4: (bn, bk//2) packed + (bn, bk//group) scales -> (bn, bk) q_dtype.
    """
    if bits == 8:
        return d.astype(q_dtype)
    return common.dequant_int4(d, s, group, q_dtype)


def _fused_topk_quantized_kernel(
    q_ref, d_ref, s_ref, *refs,
    n_j: int, n_k: int, n_docs: int, bn: int, depth: int, merge: str,
    bits: int, group: int, has_filt: bool = False,
):
    if has_filt:
        f_ref, s_out_ref, i_out_ref, acc_ref, rs_ref, ri_ref = refs
    else:
        f_ref = None
        s_out_ref, i_out_ref, acc_ref, rs_ref, ri_ref = refs
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_running():
        rs_ref[...] = jnp.full_like(rs_ref, -jnp.inf)
        ri_ref[...] = jnp.full_like(ri_ref, BIG_ID)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]
    d = _dequant_tile(d_ref[...], s_ref[...], bits, group, q.dtype)
    acc_ref[...] += jnp.dot(q, d.T, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _merge():
        tile_s = acc_ref[...]
        if bits == 8:
            # Per-doc dequant applied once, after the full K reduction.
            tile_s = tile_s * s_ref[...][:, 0][None, :]
        ids = j * bn + jax.lax.broadcasted_iota(jnp.int32, tile_s.shape, 1)
        valid = ids < n_docs
        if has_filt:
            valid = valid & (f_ref[...] != 0)
        tile_s = jnp.where(valid, tile_s, -jnp.inf)
        ids = jnp.where(valid, ids, BIG_ID)
        _merge_if_improves(rs_ref, ri_ref, tile_s, ids, depth, merge,
                           strict=True)

    @pl.when(jnp.logical_and(j == n_j - 1, k == n_k - 1))
    def _flush():
        s_out_ref[...] = rs_ref[...]
        i_out_ref[...] = ri_ref[...]


def _quantized_operands(q, docs, scale, bits, group, bq, bn, bk):
    """Pad the query / packed store / scales to tile multiples.

    Returns (qp, dp, sp) plus the padded logical column count.  For int4 the
    query pads to the PACKED width (2 * packed cols per bk block); packed
    pads with 0x88 and scales with 0 so padding always dequantizes to 0."""
    qp = common.pad_dim(common.pad_dim(q, 0, bq), 1, bk)
    if bits == 8:
        dp = common.pad_dim(common.pad_dim(docs, 0, bn), 1, bk)
        sp = common.pad_dim(scale, 0, bn)  # (N', 1) f32
        assert dp.shape[1] == qp.shape[1], (dp.shape, qp.shape)
        return qp, dp, sp
    dp = common.pad_dim(
        common.pad_dim(docs, 0, bn, value=INT4_PAD_BYTE),
        1, bk // 2, value=INT4_PAD_BYTE,
    )
    sp = common.pad_dim(common.pad_dim(scale, 0, bn), 1, bk // group)
    assert 2 * dp.shape[1] == qp.shape[1], (dp.shape, qp.shape)
    assert sp.shape[1] * group == qp.shape[1], (sp.shape, qp.shape)
    return qp, dp, sp


@functools.partial(
    jax.jit,
    static_argnames=(
        "depth", "bits", "group", "merge", "bq", "bn", "bk", "interpret",
        "n_docs",
    ),
)
def fused_topk_quantized(
    q: jax.Array,  # (B, T) bf16 / f32 query operand
    docs: jax.Array,  # (N, T) int8 | (N, Tg/2) uint8 packed nibbles
    scale: jax.Array,  # (N, 1) | (N, Tg/group) f32 dequant scales
    depth: int,
    bits: int = 8,
    group: int = 0,
    merge: str = "bitonic",
    bq: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
    filt: jax.Array | None = None,  # (N,) | (B, N) predicate bitmap
    n_docs: int | None = None,  # logical rows; rows >= n_docs never rank
) -> tuple[jax.Array, jax.Array]:
    """Streaming top-``depth`` of q @ dequant(docs, scale).T with the
    dequantization fused into the score stage — only the packed store and
    the scales ever stream from HBM.  Same output contract (and ``filt`` /
    ``n_docs`` semantics) as :func:`fused_topk`."""
    if interpret is None:
        interpret = common.INTERPRET
    bq, bn, bk = bq or 128, bn or 512, bk or 512
    b, t = q.shape
    n = docs.shape[0]
    if n_docs is None:
        n_docs = n
    assert 0 < n_docs <= n, f"n_docs {n_docs} outside (0, {n}]"
    assert depth <= n_docs, f"depth {depth} > corpus size {n_docs}"
    bq = min(bq, common.round_up(b, 8))
    bn = min(bn, common.round_up(n, common.LANE))
    bk = min(bk, common.round_up(t, common.LANE))
    if bits == 4:
        assert group and bk % group == 0, (
            f"doc-tile reduce width {bk} must be a multiple of the int4 "
            f"scale group {group}"
        )
    qp, dp, sp = _quantized_operands(q, docs, scale, bits, group, bq, bn, bk)
    dpad = _depth_pad(depth, merge)
    grid = (qp.shape[0] // bq, dp.shape[0] // bn, qp.shape[1] // bk)

    if bits == 8:
        d_spec = pl.BlockSpec((bn, bk), lambda i, j, k: (j, k))
        s_spec = pl.BlockSpec((bn, 1), lambda i, j, k: (j, 0))
    else:
        d_spec = pl.BlockSpec((bn, bk // 2), lambda i, j, k: (j, k))
        s_spec = pl.BlockSpec((bn, bk // group), lambda i, j, k: (j, k))
    operands = [qp, dp, sp]
    in_specs = [
        pl.BlockSpec((bq, bk), lambda i, j, k: (i, k)),
        d_spec,
        s_spec,
    ]
    if filt is not None:
        fp, f_spec = _filt_operand(filt, bq, bn)
        operands.append(fp)
        in_specs.append(f_spec)

    scores, ids = pl.pallas_call(
        functools.partial(
            _fused_topk_quantized_kernel,
            n_j=grid[1], n_k=grid[2], n_docs=n_docs, bn=bn, depth=depth,
            merge=merge, bits=bits, group=group, has_filt=filt is not None,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bq, dpad), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bq, dpad), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], dpad), jnp.float32),
            jax.ShapeDtypeStruct((qp.shape[0], dpad), jnp.int32),
        ],
        scratch_shapes=[
            common.MemorySpace.VMEM((bq, bn), jnp.float32),
            common.MemorySpace.VMEM((bq, dpad), jnp.float32),
            common.MemorySpace.VMEM((bq, dpad), jnp.int32),
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    scores = scores[:b, :depth]
    ids = ids[:b, :depth]
    return scores, jnp.where(scores == -jnp.inf, -1, ids)


def _fused_gathered_quantized_kernel(
    q_ref, d_ref, s_ref, rid_ref, s_out_ref, i_out_ref, acc_ref, rs_ref,
    ri_ref, *, n_j: int, n_k: int, n_docs: int, depth: int, merge: str,
    bits: int, group: int,
):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_running():
        rs_ref[...] = jnp.full_like(rs_ref, -jnp.inf)
        ri_ref[...] = jnp.full_like(ri_ref, BIG_ID)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]
    d = _dequant_tile(d_ref[0], s_ref[0], bits, group, q.dtype)
    acc_ref[...] += jnp.dot(q, d.T, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _merge():
        tile_s = acc_ref[...]  # (1, bn)
        if bits == 8:
            tile_s = tile_s * s_ref[0][:, 0][None, :]
        ids = rid_ref[...]
        valid = ids < n_docs
        tile_s = jnp.where(valid, tile_s, -jnp.inf)
        ids = jnp.where(valid, ids, BIG_ID)
        _merge_if_improves(rs_ref, ri_ref, tile_s, ids, depth, merge,
                           strict=False)

    @pl.when(jnp.logical_and(j == n_j - 1, k == n_k - 1))
    def _flush():
        s_out_ref[...] = rs_ref[...]
        i_out_ref[...] = ri_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=(
        "depth", "n_docs", "bits", "group", "merge", "bn", "bk", "interpret"
    ),
)
def fused_topk_gathered_quantized(
    q: jax.Array,  # (B, T)
    docs: jax.Array,  # (B, R, T) int8 | (B, R, Tg/2) packed candidate rows
    scale: jax.Array,  # (B, R, 1) | (B, R, Tg/group) f32 scales
    row_ids: jax.Array,  # (B, R) int32 global doc ids; >= n_docs = padding
    depth: int,
    n_docs: int,
    bits: int = 8,
    group: int = 0,
    merge: str = "bitonic",
    bn: int = 512,
    bk: int = 512,
    interpret: bool | None = None,
    filt: jax.Array | None = None,  # (B, R) keep-bitmap aligned with row_ids
) -> tuple[jax.Array, jax.Array]:
    """Quantized-store variant of :func:`fused_topk_gathered` (blockmax
    stage 2): per-query gathered packed rows + scales are dequantized in
    registers and streamed through the same running top-``depth`` merge on
    GLOBAL doc ids.  ``filt`` folds into the row-id operand exactly like
    :func:`fused_topk_gathered`."""
    if interpret is None:
        interpret = common.INTERPRET
    b, r, tc = docs.shape
    if filt is not None:
        row_ids = jnp.where(filt != 0, row_ids.astype(jnp.int32), BIG_ID)
    t = q.shape[1]
    assert depth <= r, f"depth {depth} > candidate count {r}"
    bn = min(bn, common.round_up(r, common.LANE))
    bk = min(bk, common.round_up(t, common.LANE))
    if bits == 4:
        assert group and bk % group == 0, (
            f"doc-tile reduce width {bk} must be a multiple of the int4 "
            f"scale group {group}"
        )
    qp = common.pad_dim(q, 1, bk)
    if bits == 8:
        dp = common.pad_dim(common.pad_dim(docs, 1, bn), 2, bk)
        sp = common.pad_dim(scale, 1, bn)
        d_spec = pl.BlockSpec((1, bn, bk), lambda i, j, k: (i, j, k))
        s_spec = pl.BlockSpec((1, bn, 1), lambda i, j, k: (i, j, 0))
    else:
        dp = common.pad_dim(
            common.pad_dim(docs, 1, bn, value=INT4_PAD_BYTE),
            2, bk // 2, value=INT4_PAD_BYTE,
        )
        sp = common.pad_dim(common.pad_dim(scale, 1, bn), 2, bk // group)
        assert 2 * dp.shape[2] == qp.shape[1], (dp.shape, qp.shape)
        d_spec = pl.BlockSpec((1, bn, bk // 2), lambda i, j, k: (i, j, k))
        s_spec = pl.BlockSpec((1, bn, bk // group), lambda i, j, k: (i, j, k))
    rp = common.pad_dim(row_ids.astype(jnp.int32), 1, bn, value=BIG_ID)
    dpad = _depth_pad(depth, merge)
    grid = (b, dp.shape[1] // bn, qp.shape[1] // bk)

    scores, ids = pl.pallas_call(
        functools.partial(
            _fused_gathered_quantized_kernel,
            n_j=grid[1], n_k=grid[2], n_docs=n_docs, depth=depth,
            merge=merge, bits=bits, group=group,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk), lambda i, j, k: (i, k)),
            d_spec,
            s_spec,
            pl.BlockSpec((1, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, dpad), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, dpad), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, dpad), jnp.float32),
            jax.ShapeDtypeStruct((b, dpad), jnp.int32),
        ],
        scratch_shapes=[
            common.MemorySpace.VMEM((1, bn), jnp.float32),
            common.MemorySpace.VMEM((1, dpad), jnp.float32),
            common.MemorySpace.VMEM((1, dpad), jnp.int32),
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, dp, sp, rp)
    scores = scores[:, :depth]
    ids = ids[:, :depth]
    return scores, jnp.where(scores == -jnp.inf, -1, ids)
