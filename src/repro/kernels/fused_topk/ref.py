"""Pure-XLA oracles for the fused streaming top-k kernel.

``fused_topk_ref`` / ``gathered_topk_ref`` are the unfused einsum + top_k
paths (the exact computation the kernel replaces — they DO materialize the
(B, N) score matrix).  ``streaming_topk_ref`` is an XLA realization of the
same online reduction (scan over doc tiles with a running merge); it is the
timeable stand-in for the kernel on non-TPU backends.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import common

LSH_SENTINEL = np.uint32(0xFFFFFFFF)


def scores_ref(q: jax.Array, docs: jax.Array, mode: str = "gemm") -> jax.Array:
    """Dense (B, N) scores, f32 — the matrix the fused kernel never writes."""
    if mode == "lsh":
        eq = (q[:, None, :] == docs[None, :, :]) & (q[:, None, :] != LSH_SENTINEL)
        return jnp.sum(eq, axis=-1, dtype=jnp.int32).astype(jnp.float32)
    acc = jnp.int32 if q.dtype in (jnp.int8, jnp.int32) else jnp.float32
    out = jnp.einsum("bt,nt->bn", q, docs, preferred_element_type=acc)
    return out.astype(jnp.float32)


def apply_filt(scores: jax.Array, filt) -> jax.Array:
    """Mask a dense (B, N) score matrix with a predicate bitmap ((N,) shared
    or (B, N) per-query; nonzero = keep) — the XLA realization of the
    kernel's merge-time mask.  ``filt=None`` is the identity."""
    if filt is None:
        return scores
    f = filt if filt.ndim == 2 else filt[None, :]
    return jnp.where(f != 0, scores, -jnp.inf)


def fused_topk_ref(
    q: jax.Array, docs: jax.Array, depth: int, mode: str = "gemm",
    filt=None, n_docs: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Unfused reference: full score matrix + ``jax.lax.top_k``.  With
    ``filt``, masked slots follow the kernel contract (-inf score, id -1).
    ``n_docs`` drops tail-padded rows exactly like the kernel's ragged-N
    mask (the result is the top-k over ``docs[:n_docs]``)."""
    scores = scores_ref(q, docs, mode)
    if n_docs is not None and n_docs < docs.shape[0]:
        scores = scores[:, :n_docs]
        filt = None if filt is None else filt[..., :n_docs]
    if filt is None:
        return jax.lax.top_k(scores, depth)
    s, i = jax.lax.top_k(apply_filt(scores, filt), depth)
    return s, jnp.where(s == -jnp.inf, -1, i)


def gathered_scores_ref(
    q: jax.Array, docs: jax.Array, mode: str = "gemm"
) -> jax.Array:
    """Dense (B, R) scores over per-query gathered candidate rows."""
    if mode == "lsh":
        eq = (q[:, None, :] == docs) & (q[:, None, :] != LSH_SENTINEL)
        return jnp.sum(eq, axis=-1, dtype=jnp.int32).astype(jnp.float32)
    acc = jnp.int32 if q.dtype in (jnp.int8, jnp.int32) else jnp.float32
    out = jnp.einsum("bt,brt->br", q, docs, preferred_element_type=acc)
    return out.astype(jnp.float32)


def topk_by_id_ref(
    scores: jax.Array, ids: jax.Array, depth: int
) -> Tuple[jax.Array, jax.Array]:
    """Top-``depth`` by (score desc, id asc) — the gathered kernel's tie
    order, equal to ``lax.top_k`` over id-ordered dense candidates."""
    _, d_i, d_s = jax.lax.sort(
        (-scores, ids.astype(jnp.int32), scores), dimension=-1, num_keys=2
    )
    d_s, d_i = d_s[:, :depth], d_i[:, :depth]
    return d_s, jnp.where(d_s > -jnp.inf, d_i, -1)


def gathered_topk_ref(
    q: jax.Array,
    docs: jax.Array,
    row_ids: jax.Array,
    depth: int,
    n_docs: int,
    mode: str = "gemm",
    filt=None,
) -> Tuple[jax.Array, jax.Array]:
    """Unfused blockmax stage-2 reference (mirrors core.blockmax).  Ties
    break on the lowest GLOBAL doc id (not gathered position), matching the
    dense reference paths.  ``filt`` is a (B, R) keep-bitmap aligned with
    ``row_ids`` (like the gathered kernel's)."""
    valid = row_ids < n_docs
    if filt is not None:
        valid = valid & (filt != 0)
    scores = jnp.where(valid, gathered_scores_ref(q, docs, mode), -jnp.inf)
    ids = jnp.where(valid, row_ids, np.int32(2**30))
    return topk_by_id_ref(scores, ids, depth)


# --------------------------------------------------------------------------
# Quantized-postings references (docs/DESIGN.md §12).  These implement the
# EXACT dequant ordering the fused kernels run — int8: cast-to-query-dtype
# dot, per-doc scale applied AFTER the reduction; int4: the canonical
# ``common.dequant_int4`` sequence (f32 (nibble-8) * group_scale, one cast
# to the query dtype) before the dot — so the dequantized operands match
# bit-for-bit and scores agree to f32 summation order.
# --------------------------------------------------------------------------


def quantized_scores_ref(
    q: jax.Array, docs: jax.Array, scale: jax.Array, bits: int, group: int = 0
) -> jax.Array:
    """Dense (B, N) f32 scores over a packed int8/int4 postings store."""
    if bits == 8:
        out = jnp.einsum(
            "bt,nt->bn", q, docs.astype(q.dtype),
            preferred_element_type=jnp.float32,
        )
        return out * scale[:, 0][None, :]
    deq = common.dequant_int4(docs, scale, group, q.dtype)  # (N, Tg)
    return jnp.einsum(
        "bt,nt->bn", q, deq[:, : q.shape[1]],
        preferred_element_type=jnp.float32,
    )


def quantized_topk_ref(
    q: jax.Array, docs: jax.Array, scale: jax.Array, depth: int,
    bits: int, group: int = 0, filt=None, n_docs: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Unfused quantized reference: dense scores + ``jax.lax.top_k``.
    ``n_docs`` drops tail-padded rows like :func:`fused_topk_ref`."""
    scores = quantized_scores_ref(q, docs, scale, bits, group)
    if n_docs is not None and n_docs < docs.shape[0]:
        scores = scores[:, :n_docs]
        filt = None if filt is None else filt[..., :n_docs]
    if filt is None:
        return jax.lax.top_k(scores, depth)
    s, i = jax.lax.top_k(apply_filt(scores, filt), depth)
    return s, jnp.where(s == -jnp.inf, -1, i)


def quantized_gathered_scores_ref(
    q: jax.Array, docs: jax.Array, scale: jax.Array, bits: int, group: int = 0
) -> jax.Array:
    """Dense (B, R) f32 scores over per-query gathered packed rows."""
    if bits == 8:
        out = jnp.einsum(
            "bt,brt->br", q, docs.astype(q.dtype),
            preferred_element_type=jnp.float32,
        )
        return out * scale[:, :, 0]
    deq = common.dequant_int4(docs, scale, group, q.dtype)  # (B, R, Tg)
    return jnp.einsum(
        "bt,brt->br", q, deq[:, :, : q.shape[1]],
        preferred_element_type=jnp.float32,
    )


def quantized_gathered_topk_ref(
    q: jax.Array,
    docs: jax.Array,
    scale: jax.Array,
    row_ids: jax.Array,
    depth: int,
    n_docs: int,
    bits: int,
    group: int = 0,
    filt=None,
) -> Tuple[jax.Array, jax.Array]:
    """Unfused quantized blockmax stage-2 reference (global-id ties).
    ``filt`` is a (B, R) keep-bitmap aligned with ``row_ids``."""
    valid = row_ids < n_docs
    if filt is not None:
        valid = valid & (filt != 0)
    scores = jnp.where(
        valid, quantized_gathered_scores_ref(q, docs, scale, bits, group),
        -jnp.inf,
    )
    ids = jnp.where(valid, row_ids, np.int32(2**30))
    return topk_by_id_ref(scores, ids, depth)


def _filt_tiles(filt, n: int, tile: int) -> jax.Array:
    """Predicate bitmap as per-doc-tile scan slices: (n_tiles, 1|B, tile)
    int32, padded tail = 0 (already dropped by the ragged-N mask)."""
    f = filt.astype(jnp.int32)
    if f.ndim == 1:
        f = f[None, :]
    pad = (-n) % tile
    if pad:
        f = jnp.concatenate([f, jnp.zeros((f.shape[0], pad), f.dtype)], axis=1)
    return jnp.moveaxis(f.reshape(f.shape[0], -1, tile), 1, 0)


@functools.partial(
    jax.jit, static_argnames=("depth", "bits", "group", "tile", "n_docs")
)
def streaming_topk_quantized_ref(
    q: jax.Array,
    docs: jax.Array,
    scale: jax.Array,
    depth: int,
    bits: int,
    group: int = 0,
    tile: int = 4096,
    filt=None,
    n_docs: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """XLA online-reduction equivalent over a packed store: scan doc tiles,
    dequantize each tile transiently, merge a running top-``depth``.  The
    dequantized matrix is only ever (tile, T) — the timeable stand-in for
    :func:`..kernel.fused_topk_quantized` off-TPU, and the XLA path for
    corpora too large for a dense (B, N) score matrix.  ``n_docs`` tightens
    the ragged-N mask for tail-bucket-padded stores."""
    n_rows = docs.shape[0]
    n = n_rows if n_docs is None else n_docs
    b = q.shape[0]
    pad = (-n_rows) % tile
    if pad:
        docs = jnp.concatenate(
            [docs, jnp.zeros((pad, docs.shape[1]), docs.dtype)], axis=0
        )
        scale = jnp.concatenate(
            [scale, jnp.zeros((pad, scale.shape[1]), scale.dtype)], axis=0
        )
    d_tiles = docs.reshape(-1, tile, docs.shape[1])
    s_tiles = scale.reshape(-1, tile, scale.shape[1])

    init_s = jnp.full((b, depth), -jnp.inf, jnp.float32)
    init_i = jnp.full((b, depth), -1, jnp.int32)

    def body(carry, xs):
        best_s, best_i = carry
        if filt is None:
            t_idx, d_tile, s_tile = xs
        else:
            t_idx, d_tile, s_tile, f_tile = xs
        s = quantized_scores_ref(q, d_tile, s_tile, bits, group)
        ids = t_idx * tile + jnp.arange(tile, dtype=jnp.int32)[None, :]
        valid = ids < n
        if filt is not None:
            valid = valid & (f_tile != 0)
        s = jnp.where(valid, s, -jnp.inf)
        loc_s, pos = jax.lax.top_k(s, min(depth, tile))
        loc_i = jnp.take_along_axis(jnp.broadcast_to(ids, s.shape), pos, axis=-1)
        if filt is not None:
            # All-filtered tiles must pad with -1, never a masked doc's id.
            loc_i = jnp.where(loc_s == -jnp.inf, -1, loc_i)
        all_s = jnp.concatenate([best_s, loc_s], axis=-1)
        all_i = jnp.concatenate([best_i, loc_i], axis=-1)
        top_s, top_pos = jax.lax.top_k(all_s, depth)
        return (top_s, jnp.take_along_axis(all_i, top_pos, axis=-1)), None

    xs = (jnp.arange(d_tiles.shape[0], dtype=jnp.int32), d_tiles, s_tiles)
    if filt is not None:
        xs = xs + (_filt_tiles(filt, n_rows, tile),)
    (best_s, best_i), _ = jax.lax.scan(body, (init_s, init_i), xs)
    return best_s, best_i


@functools.partial(
    jax.jit, static_argnames=("depth", "tile", "mode", "n_docs")
)
def streaming_topk_ref(
    q: jax.Array,
    docs: jax.Array,
    depth: int,
    tile: int = 4096,
    mode: str = "gemm",
    filt=None,
    n_docs: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """XLA online-reduction equivalent: scan doc tiles, merge a running
    top-``depth``.  Peak live scores are O(B * (tile + depth)), never (B, N).
    ``n_docs`` tightens the ragged-N mask for tail-bucket-padded stores."""
    n_rows, t = docs.shape
    n = n_rows if n_docs is None else n_docs
    b = q.shape[0]
    pad = (-n_rows) % tile
    if pad:
        fill = LSH_SENTINEL - 1 if mode == "lsh" else 0
        docs = jnp.concatenate(
            [docs, jnp.full((pad, t), fill, docs.dtype)], axis=0
        )
    tiles = docs.reshape(-1, tile, t)

    init_s = jnp.full((b, depth), -jnp.inf, jnp.float32)
    init_i = jnp.full((b, depth), -1, jnp.int32)

    def body(carry, xs):
        best_s, best_i = carry
        if filt is None:
            t_idx, d_tile = xs
        else:
            t_idx, d_tile, f_tile = xs
        s = scores_ref(q, d_tile, mode)
        ids = t_idx * tile + jnp.arange(tile, dtype=jnp.int32)[None, :]
        valid = ids < n
        if filt is not None:
            valid = valid & (f_tile != 0)
        s = jnp.where(valid, s, -jnp.inf)
        loc_s, pos = jax.lax.top_k(s, min(depth, tile))
        loc_i = jnp.take_along_axis(jnp.broadcast_to(ids, s.shape), pos, axis=-1)
        if filt is not None:
            loc_i = jnp.where(loc_s == -jnp.inf, -1, loc_i)
        all_s = jnp.concatenate([best_s, loc_s], axis=-1)
        all_i = jnp.concatenate([best_i, loc_i], axis=-1)
        top_s, top_pos = jax.lax.top_k(all_s, depth)
        return (top_s, jnp.take_along_axis(all_i, top_pos, axis=-1)), None

    xs = (jnp.arange(tiles.shape[0], dtype=jnp.int32), tiles)
    if filt is not None:
        xs = xs + (_filt_tiles(filt, n_rows, tile),)
    (best_s, best_i), _ = jax.lax.scan(body, (init_s, init_i), xs)
    return best_s, best_i
