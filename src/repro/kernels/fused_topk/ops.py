"""Public wrappers routing the search hot paths onto the fused top-k kernel.

Each wrapper prepares the query operand exactly like its ``core/`` reference
path (df-prune keep-mask folded into the query tile, [u; -u] int8 lift for
dot mode, unit-normalization for cosine) and then streams the stored index
through :func:`repro.kernels.fused_topk.kernel.fused_topk` — the (B, N)
score matrix never materializes.  ``repro.core`` imports these lazily to
avoid an import cycle.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.fused_topk.kernel import (
    fused_topk,
    fused_topk_gathered,
    fused_topk_gathered_quantized,
    fused_topk_quantized,
)

__all__ = [
    "resolve_use_kernel",
    "gather_filt",
    "classic_topk",
    "dot_topk",
    "cosine_topk",
    "lsh_topk",
    "scan_l2_topk",
    "fused_topk",
    "fused_topk_gathered",
    "fused_topk_quantized",
    "fused_topk_gathered_quantized",
    "postings_topk",
    "postings_topk_gathered",
]


def resolve_use_kernel(use_kernel: Optional[bool]) -> bool:
    """None -> fused Pallas path on TPU, XLA reference path elsewhere."""
    return common.USE_KERNEL_DEFAULT if use_kernel is None else use_kernel


def gather_filt(
    filt: Optional[jax.Array], row_ids: jax.Array, n_docs: int
) -> Optional[jax.Array]:
    """Gather a per-doc predicate bitmap ((N,) shared or (B, N) per-query)
    into the (B, R) row-aligned keep-bitmap the gathered kernels / refs
    take.  Out-of-range padding rows gather doc 0's bit but stay masked by
    the kernels' own ``row_ids < n_docs`` check."""
    if filt is None:
        return None
    safe = jnp.minimum(row_ids, n_docs - 1)
    if filt.ndim == 1:
        return filt[safe]
    return jnp.take_along_axis(filt, safe, axis=1)


def classic_topk(
    index, q_tf: jax.Array, depth: int, df_max_ratio: float = 1.0,
    interpret: bool | None = None, filt: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused ClassicSimilarity top-depth over a FakeWordsIndex (bf16 GEMM
    against the precomputed ``scored`` matrix, keep-mask folded into q)."""
    from repro.core import fakewords

    qv = fakewords.classic_query(index, q_tf, df_max_ratio)
    return fused_topk(qv, index.scored, depth, interpret=interpret, filt=filt)


def dot_topk(
    index, q_tf: jax.Array, depth: int, df_max_ratio: float = 1.0,
    interpret: bool | None = None, filt: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused integer-dot top-depth (int8 MXU path, [u; -u] query lift)."""
    from repro.core import fakewords

    qv = fakewords.dot_query(index, q_tf, df_max_ratio, dtype=jnp.int8)
    return fused_topk(qv, index.tf, depth, interpret=interpret, filt=filt)


def cosine_topk(
    corpus: jax.Array, queries: jax.Array, depth: int,
    interpret: bool | None = None, filt: jax.Array | None = None,
    n_docs: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused exact-cosine top-depth (operands must be unit-normalized)."""
    return fused_topk(
        queries, corpus, depth, interpret=interpret, filt=filt, n_docs=n_docs
    )


def lsh_topk(
    sig_q: jax.Array, sig_d: jax.Array, depth: int,
    interpret: bool | None = None, filt: jax.Array | None = None,
    n_docs: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused MinHash collision-count top-depth (VPU compare+reduce stage)."""
    return fused_topk(
        sig_q, sig_d, depth, mode="lsh", interpret=interpret, filt=filt,
        n_docs=n_docs,
    )


def postings_topk(
    pq, qv: jax.Array, depth: int, interpret: bool | None = None,
    filt: jax.Array | None = None, n_docs: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused top-depth over a packed :class:`repro.core.types.
    QuantizedPostings` store — dequantization happens in VMEM registers
    (docs/DESIGN.md §12).  ``qv`` is the mode's float query operand."""
    return fused_topk_quantized(
        qv, pq.q, pq.scale, depth, bits=pq.bits, group=pq.group,
        interpret=interpret, filt=filt, n_docs=n_docs,
    )


def postings_topk_gathered(
    pq, qv: jax.Array, row_ids: jax.Array, depth: int, n_docs: int,
    interpret: bool | None = None, filt: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused gathered-candidates top-depth over packed rows of a
    :class:`repro.core.types.QuantizedPostings` store (blockmax stage 2).
    Gathers the packed rows + scales here so callers stay one-liners.
    ``filt`` is per-doc ((N,) | (B, N)); it gathers alongside the rows."""
    import jax.numpy as jnp

    safe = jnp.minimum(row_ids, pq.num_docs - 1)
    return fused_topk_gathered_quantized(
        qv, pq.q[safe], pq.scale[safe], row_ids, depth, n_docs,
        bits=pq.bits, group=pq.group, interpret=interpret,
        filt=gather_filt(filt, row_ids, n_docs),
    )


def lift_l2(points: jax.Array) -> jax.Array:
    """``[d; -||d||^2]`` doc-side lift for :func:`scan_l2_topk`.  Precompute
    at index build time — lifting per search would re-materialize a full
    index copy on a path whose point is cutting HBM traffic."""
    d2 = jnp.sum(points * points, axis=-1)  # (N,)
    return jnp.concatenate([points, -d2[:, None]], axis=-1)


def scan_l2_topk(
    lifted: jax.Array, q_reduced: jax.Array, depth: int,
    interpret: bool | None = None, filt: jax.Array | None = None,
    n_docs: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused exact reduced-space L2 top-depth (kd-tree scan backend).

    -||q - d||^2 + ||q||^2 = 2 q.d - ||d||^2 is a plain GEMM after the lift
    q' = [2q; 1], d' = [d; -||d||^2] (``lifted``, from :func:`lift_l2`), so
    the negated-squared-distance scores stream through the fused kernel and
    the (B, N) matrix never hits HBM."""
    qa = jnp.concatenate(
        [2.0 * q_reduced, jnp.ones((q_reduced.shape[0], 1), q_reduced.dtype)],
        axis=-1,
    )
    return fused_topk(
        qa, lifted, depth, interpret=interpret, filt=filt, n_docs=n_docs
    )
