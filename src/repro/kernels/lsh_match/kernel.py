"""Pallas TPU kernel: MinHash signature collision counting.

scores[i,j] = #{s : sig_q[i,s] == sig_d[j,s] != SENTINEL} - the lexical-LSH
match score.  Integer equality + popcount-style reduce: a VPU workload with
no MXU use (docs/DESIGN.md §10).  The signature axis is tiled through the grid so
the (bq, bn, bs) broadcast-compare stays inside VMEM; partial counts
accumulate in an int32 scratch across signature tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import common

SENTINEL = np.uint32(0xFFFFFFFF)


def _lsh_kernel(q_ref, d_ref, o_ref, acc_ref, *, n_s: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]  # (bq, bs) uint32
    d = d_ref[...]  # (bn, bs) uint32
    eq = (q[:, None, :] == d[None, :, :]) & (q[:, None, :] != SENTINEL)
    acc_ref[...] += jnp.sum(eq.astype(jnp.int32), axis=-1)

    @pl.when(s == n_s - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bq", "bn", "bs", "interpret"))
def lsh_match_scores(
    sig_q: jax.Array,  # (B, S) uint32
    sig_d: jax.Array,  # (N, S) uint32
    bq: int = 16,
    bn: int = 128,
    bs: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = common.INTERPRET
    b, s = sig_q.shape
    n = sig_d.shape[0]
    bq = min(bq, common.round_up(b, 8))
    bn = min(bn, common.round_up(n, 8))
    bs = min(bs, common.round_up(s, common.LANE))
    # Pad signature axis with DISTINCT fillers so padding never matches:
    # queries get SENTINEL (masked), docs get SENTINEL-1.
    qp = common.pad_dim(common.pad_dim(sig_q, 0, bq), 1, bs, value=SENTINEL)
    dp = common.pad_dim(
        common.pad_dim(sig_d, 0, bn), 1, bs, value=np.uint32(SENTINEL - 1)
    )
    grid = (qp.shape[0] // bq, dp.shape[0] // bn, qp.shape[1] // bs)

    out = pl.pallas_call(
        functools.partial(_lsh_kernel, n_s=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bs), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bs), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], dp.shape[0]), jnp.int32),
        scratch_shapes=[common.MemorySpace.VMEM((bq, bn), jnp.int32)],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, dp)
    return out[:b, :n]
