from repro.kernels.lsh_match.kernel import lsh_match_scores  # noqa: F401
from repro.kernels.lsh_match.ops import lsh_topk  # noqa: F401
