"""jit'd public wrappers for LSH signature matching."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import LshIndex
from repro.kernels.lsh_match.kernel import lsh_match_scores


@functools.partial(jax.jit, static_argnames=("k",))
def lsh_topk(index: LshIndex, sig_q: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    scores = lsh_match_scores(sig_q, index.sig).astype(jnp.float32)
    return jax.lax.top_k(scores, k)
