"""Pure-jnp oracle for the LSH match kernel (same math as
core.lexical_lsh.match_scores, untiled)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

SENTINEL = np.uint32(0xFFFFFFFF)


def lsh_match_scores_ref(sig_q: jax.Array, sig_d: jax.Array) -> jax.Array:
    eq = (sig_q[:, None, :] == sig_d[None, :, :]) & (
        sig_q[:, None, :] != SENTINEL
    )
    return jnp.sum(eq, axis=-1, dtype=jnp.int32)
