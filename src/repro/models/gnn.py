"""GraphSAGE (Hamilton et al. 2017) — mean aggregator, full-batch + sampled.

Kernel regime (taxonomy §GNN): SpMM via ``jax.ops.segment_sum`` over an
edge-index → node scatter.  JAX sparse is BCOO-only, so message passing is
implemented directly as gather(src) → segment_sum(dst) → divide(degree):

    h_neigh[v] = mean_{u in N(v)} h[u]
    h'[v]      = relu(W_self h[v] + W_neigh h_neigh[v])      (+ l2 normalize)

Three execution paths cover the assigned shapes:

* ``forward_full``      — whole-graph message passing (full_graph_sm /
  ogb_products).  Edges shard over devices: each shard computes a partial
  segment_sum over its edge slice and the partials are summed by GSPMD
  (the scatter's natural psum); features/params replicated.
* ``forward_sampled``   — GraphSAGE minibatch: dense (B, f1) / (B, f1, f2)
  sampled neighbor indices, gathered from the (N, F) feature table
  (minibatch_lg; the real neighbor sampler lives in data/graph.py).
* ``forward_batched``   — vmap over a batch of small fixed-size graphs with
  mean-pool readout (molecule).

Applicability note (DESIGN.md §6): trained node embeddings are exactly the
"arbitrary dense vectors" the paper indexes — examples/graph_embeddings.py
feeds them to the fake-words index.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class SageConfig:
    name: str = "graphsage"
    n_layers: int = 2
    d_in: int = 602
    d_hidden: int = 128
    n_classes: int = 41
    aggregator: str = "mean"
    fanouts: Tuple[int, ...] = (25, 10)  # paper's sample_sizes, hop 1..L
    l2_normalize: bool = True
    dtype: Any = jnp.float32

    def __post_init__(self):
        if self.aggregator != "mean":
            raise ValueError("only the mean aggregator is implemented")
        if len(self.fanouts) != self.n_layers:
            raise ValueError("need one fanout per layer")


def param_shapes(cfg: SageConfig) -> Params:
    shapes: Params = {}
    d_prev = cfg.d_in
    for l in range(cfg.n_layers):
        d_out = cfg.d_hidden
        shapes[f"layer{l}"] = {
            "w_self": (d_prev, d_out),
            "w_neigh": (d_prev, d_out),
            "bias": (d_out,),
        }
        d_prev = d_out
    shapes["classifier"] = {"w": (d_prev, cfg.n_classes), "b": (cfg.n_classes,)}
    return shapes


def init_params(key: jax.Array, cfg: SageConfig) -> Params:
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten(
        shapes, is_leaf=lambda x: isinstance(x, tuple)
    )
    keys = jax.random.split(key, len(flat))

    def one(k, s):
        if len(s) == 2:
            return jax.random.normal(k, s, jnp.float32) / math.sqrt(s[0])
        return jnp.zeros(s, jnp.float32)

    return jax.tree_util.tree_unflatten(
        treedef, [one(k, s) for k, s in zip(keys, flat)]
    )


def _sage_combine(h_self, h_neigh, layer, last: bool, cfg: SageConfig):
    h = h_self @ layer["w_self"] + h_neigh @ layer["w_neigh"] + layer["bias"]
    if not last:
        h = jax.nn.relu(h)
    if cfg.l2_normalize:
        h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-12)
    return h


# --------------------------------------------------------------------------
# Full-batch message passing (segment_sum SpMM)
# --------------------------------------------------------------------------


def mean_aggregate(
    h: jax.Array, src: jax.Array, dst: jax.Array, num_nodes: int
) -> jax.Array:
    """h_neigh[v] = mean of h[src] over edges (src -> dst=v).

    gather + segment_sum; degree recomputed with the same scatter so that
    isolated nodes get 0 (GraphSAGE convention: empty neighborhood -> zeros).
    Under pjit, src/dst sharded over devices => per-shard partial sums that
    GSPMD all-reduces.
    """
    msgs = jnp.take(h, src, axis=0)  # (E, d)
    agg = jax.ops.segment_sum(msgs, dst, num_segments=num_nodes)
    deg = jax.ops.segment_sum(
        jnp.ones_like(dst, dtype=h.dtype), dst, num_segments=num_nodes
    )
    return agg / jnp.maximum(deg, 1.0)[:, None]


def forward_full(
    params: Params, feats: jax.Array, src: jax.Array, dst: jax.Array,
    cfg: SageConfig,
) -> jax.Array:
    """feats: (N, d_in); src/dst: (E,) int32 -> logits (N, n_classes)."""
    n = feats.shape[0]
    h = feats.astype(cfg.dtype)
    for l in range(cfg.n_layers):
        h_neigh = mean_aggregate(h, src, dst, n)
        h = _sage_combine(h, h_neigh, params[f"layer{l}"], l == cfg.n_layers - 1, cfg)
    return h @ params["classifier"]["w"] + params["classifier"]["b"]


def embeddings_full(params, feats, src, dst, cfg: SageConfig) -> jax.Array:
    """Node embeddings (pre-classifier) — the dense vectors the paper's ANN
    layer indexes."""
    n = feats.shape[0]
    h = feats.astype(cfg.dtype)
    for l in range(cfg.n_layers):
        h_neigh = mean_aggregate(h, src, dst, n)
        h = _sage_combine(h, h_neigh, params[f"layer{l}"], l == cfg.n_layers - 1, cfg)
    return h


# --------------------------------------------------------------------------
# Sampled minibatch (GraphSAGE alg. 2): dense neighbor blocks
# --------------------------------------------------------------------------


def forward_sampled(
    params: Params,
    feats: jax.Array,        # (N, d_in) full feature table (replicated/sharded)
    batch_nodes: jax.Array,  # (B,) int32
    nbr1: jax.Array,         # (B, f1) int32 — hop-1 samples of batch nodes
    nbr2: jax.Array,         # (B, f1, f2) int32 — hop-2 samples of nbr1
    cfg: SageConfig,
) -> jax.Array:
    """Two-layer sampled forward (fanouts f1, f2). -1 indices = padding
    (isolated-node slots) and contribute zeros to the mean."""
    assert cfg.n_layers == 2, "sampled path implements the paper's 2-layer setting"
    b, f1 = nbr1.shape
    f2 = nbr2.shape[-1]

    def gather(table, idx):
        safe = jnp.maximum(idx, 0)
        x = jnp.take(table, safe.reshape(-1), axis=0).reshape(*idx.shape, -1)
        return jnp.where((idx >= 0)[..., None], x, 0.0).astype(cfg.dtype)

    def masked_mean(x, idx):
        cnt = jnp.sum(idx >= 0, axis=-1, keepdims=True).astype(x.dtype)
        return jnp.sum(x, axis=-2) / jnp.maximum(cnt, 1.0)

    x_b = gather(feats, batch_nodes)          # (B, d)
    x_1 = gather(feats, nbr1)                 # (B, f1, d)
    x_2 = gather(feats, nbr2)                 # (B, f1, f2, d)

    # Layer 0: update batch nodes (from nbr1) and nbr1 nodes (from nbr2).
    l0 = params["layer0"]
    h_b = _sage_combine(x_b, masked_mean(x_1, nbr1), l0, False, cfg)
    h_1 = _sage_combine(x_1, masked_mean(x_2, nbr2), l0, False, cfg)
    # Layer 1: final update of batch nodes from updated nbr1.
    l1 = params["layer1"]
    h = _sage_combine(h_b, masked_mean(h_1, nbr1), l1, True, cfg)
    return h @ params["classifier"]["w"] + params["classifier"]["b"]


# --------------------------------------------------------------------------
# Batched small graphs (molecule): vmap + mean-pool readout
# --------------------------------------------------------------------------


def forward_batched(
    params: Params,
    feats: jax.Array,  # (G, n_nodes, d_in)
    src: jax.Array,    # (G, n_edges) int32
    dst: jax.Array,    # (G, n_edges) int32
    cfg: SageConfig,
) -> jax.Array:
    """Graph-level logits (G, n_classes) via per-graph message passing and
    mean-pool readout."""
    n = feats.shape[1]

    def one_graph(f, s, d):
        h = f.astype(cfg.dtype)
        for l in range(cfg.n_layers):
            h_neigh = mean_aggregate(h, s, d, n)
            h = _sage_combine(h, h_neigh, params[f"layer{l}"], l == cfg.n_layers - 1, cfg)
        return jnp.mean(h, axis=0)  # readout

    pooled = jax.vmap(one_graph)(feats, src, dst)
    return pooled @ params["classifier"]["w"] + params["classifier"]["b"]


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------


def softmax_xent(logits: jax.Array, labels: jax.Array, mask=None) -> jax.Array:
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def loss_full(params, feats, src, dst, labels, mask, cfg: SageConfig):
    return softmax_xent(forward_full(params, feats, src, dst, cfg), labels, mask)


def loss_sampled(params, feats, batch_nodes, nbr1, nbr2, labels, cfg: SageConfig):
    return softmax_xent(
        forward_sampled(params, feats, batch_nodes, nbr1, nbr2, cfg), labels
    )


def loss_batched(params, feats, src, dst, labels, cfg: SageConfig):
    return softmax_xent(forward_batched(params, feats, src, dst, cfg), labels)
