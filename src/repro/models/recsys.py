"""RecSys towers: FM, DeepFM, DLRM (RM-2), xDeepFM over huge sparse tables.

The hot path is the embedding lookup.  JAX has no native EmbeddingBag, so we
build one (taxonomy §RecSys): all categorical fields share ONE concatenated
(total_rows, dim) table with per-field row offsets — this is what lets the
table row-shard over the ``model`` axis as a single array — and a bag lookup
is ``jnp.take`` + ``jax.ops.segment_sum`` over a (B*nnz,) flattened index
stream (ragged, CSR-style) or a sum over a dense (B, F, nnz) index block
(fixed-nnz fast path used by the training/serving steps; the ragged path is
the general API and the two are property-tested equal).

Feature interactions:
  * FM      — pairwise <v_i, v_j> x_i x_j via the O(nk) sum-square trick
              0.5 * ((Σ v)² − Σ v²) (Rendle 2010).
  * DeepFM  — FM + shared-embedding MLP (400-400-400).
  * DLRM    — bottom MLP on 13 dense feats → dot-interaction among
              27 vectors (upper triangle) → top MLP (512-512-256-1).
  * xDeepFM — CIN (200-200-200): x^k_{h} = Σ_{i,j} W^k_{h,ij} (x^{k-1}_i ∘
              x^0_j), realized as einsum over the outer product, + DNN.

``retrieval_cand`` (1 query × 10⁶ candidates) is the paper-representative
cell: candidate scoring is an inner product over item embeddings — served
either brute-force (cosine_score kernel) or through the fake-words index
(core/): see serve/ann_service.py and examples/recsys_retrieval.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# Table spec + EmbeddingBag
# --------------------------------------------------------------------------


def criteo_row_counts(n_fields: int, total_rows: int, alpha: float = 1.6) -> Tuple[int, ...]:
    """Deterministic power-law per-field row counts summing to ~total_rows
    (Criteo-like: a few huge id spaces, a long tail of small ones).  The
    total is padded up to a multiple of 512 so the concatenated table's rows
    shard evenly over any production mesh axis."""
    raw = [(i + 1) ** (-alpha) for i in range(n_fields)]
    s = sum(raw)
    counts = [max(4, int(total_rows * r / s)) for r in raw]
    counts[0] += (-sum(counts)) % 512
    return tuple(counts)


@dataclasses.dataclass(frozen=True)
class TableSpec:
    """One concatenated embedding table for all categorical fields."""

    row_counts: Tuple[int, ...]
    dim: int

    @property
    def n_fields(self) -> int:
        return len(self.row_counts)

    @property
    def total_rows(self) -> int:
        return sum(self.row_counts)

    @property
    def offsets(self) -> Tuple[int, ...]:
        out, acc = [], 0
        for c in self.row_counts:
            out.append(acc)
            acc += c
        return tuple(out)

    def globalize(self, idx: jax.Array) -> jax.Array:
        """Per-field local ids (B, F, ...) -> global row ids in the
        concatenated table (field axis must be axis 1)."""
        off = jnp.asarray(self.offsets, jnp.int32)
        shape = (1, self.n_fields) + (1,) * (idx.ndim - 2)
        return idx + off.reshape(shape)


def embedding_bag_dense(
    table: jax.Array, idx: jax.Array, weights: Optional[jax.Array] = None,
    combine: str = "sum",
) -> jax.Array:
    """Fixed-nnz bag lookup: idx (B, F, nnz) global rows -> (B, F, dim).

    -1 indices are padding.  This is the fast TPU path: one gather plus a
    dense reduction (XLA lowers the gather efficiently; under pjit with the
    table row-sharded over 'model' it becomes the classic DLRM
    gather + all-to-all pattern).
    """
    safe = jnp.maximum(idx, 0)
    vecs = jnp.take(table, safe.reshape(-1), axis=0).reshape(*idx.shape, -1)
    mask = (idx >= 0)[..., None].astype(vecs.dtype)
    if weights is not None:
        mask = mask * weights[..., None]
    out = jnp.sum(vecs * mask, axis=-2)
    if combine == "mean":
        cnt = jnp.sum((idx >= 0), axis=-1, keepdims=True).astype(vecs.dtype)
        out = out / jnp.maximum(cnt, 1.0)
    return out


def embedding_bag_ragged(
    table: jax.Array,
    values: jax.Array,    # (NNZ,) int32 global row ids
    bag_ids: jax.Array,   # (NNZ,) int32 target bag per value, in [0, n_bags)
    n_bags: int,
    weights: Optional[jax.Array] = None,
    combine: str = "sum",
) -> jax.Array:
    """Ragged EmbeddingBag: take + segment_sum (the general CSR-style API)."""
    vecs = jnp.take(table, values, axis=0)  # (NNZ, dim)
    if weights is not None:
        vecs = vecs * weights[:, None]
    out = jax.ops.segment_sum(vecs, bag_ids, num_segments=n_bags)
    if combine == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(values, dtype=vecs.dtype), bag_ids, num_segments=n_bags
        )
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


# --------------------------------------------------------------------------
# Configs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str = "fm"
    model: str = "fm"  # "fm" | "deepfm" | "dlrm" | "xdeepfm"
    table: TableSpec = dataclasses.field(
        default_factory=lambda: TableSpec(criteo_row_counts(39, 1_300_000), 10)
    )
    nnz: int = 1              # multi-hot width per field
    n_dense: int = 0          # dense (continuous) features (DLRM: 13)
    bot_mlp: Tuple[int, ...] = ()        # DLRM bottom MLP widths
    top_mlp: Tuple[int, ...] = ()        # DLRM top MLP widths (last = 1)
    mlp: Tuple[int, ...] = ()            # DeepFM / xDeepFM DNN widths
    cin_layers: Tuple[int, ...] = ()     # xDeepFM CIN feature-map counts
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    @property
    def n_fields(self) -> int:
        return self.table.n_fields

    @property
    def dim(self) -> int:
        return self.table.dim

    def param_count(self) -> int:
        shapes = param_shapes(self)
        return sum(
            math.prod(s)
            for s in jax.tree_util.tree_leaves(
                shapes, is_leaf=lambda x: isinstance(x, tuple)
            )
        )


def _mlp_shapes(widths: Sequence[int], d_in: int, prefix: str) -> Params:
    shapes: Params = {}
    prev = d_in
    for i, w in enumerate(widths):
        shapes[f"{prefix}{i}"] = {"w": (prev, w), "b": (w,)}
        prev = w
    return shapes


def param_shapes(cfg: RecsysConfig) -> Params:
    f, d = cfg.n_fields, cfg.dim
    shapes: Params = {
        "table": (cfg.table.total_rows, d),
        "linear": (cfg.table.total_rows, 1),
        "bias": (1,),
    }
    if cfg.model == "fm":
        pass
    elif cfg.model == "deepfm":
        shapes.update(_mlp_shapes(cfg.mlp + (1,), f * d, "mlp"))
    elif cfg.model == "dlrm":
        shapes.pop("linear")
        shapes.update(_mlp_shapes(cfg.bot_mlp, cfg.n_dense, "bot"))
        n_vec = f + 1
        d_inter = n_vec * (n_vec - 1) // 2 + cfg.bot_mlp[-1]
        shapes.update(_mlp_shapes(cfg.top_mlp, d_inter, "top"))
    elif cfg.model == "xdeepfm":
        shapes.update(_mlp_shapes(cfg.mlp + (1,), f * d, "mlp"))
        prev_maps = f
        for i, h in enumerate(cfg.cin_layers):
            shapes[f"cin{i}"] = {"w": (prev_maps * f, h)}
            prev_maps = h
        shapes["cin_out"] = {"w": (sum(cfg.cin_layers), 1)}
    else:
        raise ValueError(cfg.model)
    return shapes


def init_params(key: jax.Array, cfg: RecsysConfig) -> Params:
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten(
        shapes, is_leaf=lambda x: isinstance(x, tuple)
    )
    keys = jax.random.split(key, len(flat))

    def one(k, s):
        if len(s) == 1:
            return jnp.zeros(s, cfg.param_dtype)
        scale = 1.0 / math.sqrt(s[0])
        return (jax.random.normal(k, s, jnp.float32) * scale).astype(cfg.param_dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [one(k, s) for k, s in zip(keys, flat)]
    )


# --------------------------------------------------------------------------
# Interactions
# --------------------------------------------------------------------------


def fm_interaction(emb: jax.Array) -> jax.Array:
    """emb (B, F, d) -> (B,) second-order FM term via the sum-square trick:
    0.5 * Σ_d ((Σ_i v_id)² − Σ_i v_id²)   — O(F·d), not O(F²·d)."""
    s = jnp.sum(emb, axis=1)          # (B, d)
    s2 = jnp.sum(emb * emb, axis=1)   # (B, d)
    return 0.5 * jnp.sum(s * s - s2, axis=-1)


def dot_interaction(vecs: jax.Array) -> jax.Array:
    """vecs (B, n, d) -> (B, n(n-1)/2) pairwise dots (upper triangle,
    DLRM's interaction)."""
    b, n, _ = vecs.shape
    gram = jnp.einsum("bnd,bmd->bnm", vecs, vecs)
    iu, ju = jnp.triu_indices(n, k=1)
    return gram[:, iu, ju]


def cin(emb: jax.Array, params: Params, layer_maps: Sequence[int]) -> jax.Array:
    """Compressed Interaction Network (xDeepFM).  emb (B, F, d).

    x^k[h] = Σ_{i,j} W^k[h,(i,j)] * (x^{k-1}[i] ∘ x^0[j]); sum-pool each
    layer's maps over d and concatenate."""
    b, f, d = emb.shape
    x0 = emb
    xk = emb
    pooled = []
    for i, h in enumerate(layer_maps):
        outer = jnp.einsum("bid,bjd->bijd", xk, x0)  # (B, Hk-1, F, d)
        flat = outer.reshape(b, -1, d)               # (B, Hk-1*F, d)
        xk = jnp.einsum("bmd,mh->bhd", flat, params[f"cin{i}"]["w"])
        pooled.append(jnp.sum(xk, axis=-1))          # (B, Hk)
    return jnp.concatenate(pooled, axis=-1)


def mlp_apply(x: jax.Array, params: Params, n: int, prefix: str,
              final_act: bool = False) -> jax.Array:
    for i in range(n):
        p = params[f"{prefix}{i}"]
        x = x @ p["w"].astype(x.dtype) + p["b"].astype(x.dtype)
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# --------------------------------------------------------------------------
# Forward passes  (logits, pre-sigmoid)
# --------------------------------------------------------------------------


def _lookup(params: Params, cfg: RecsysConfig, sparse_idx: jax.Array) -> jax.Array:
    """sparse_idx: (B, F, nnz) local per-field ids -> (B, F, dim)."""
    gidx = cfg.table.globalize(sparse_idx)
    return embedding_bag_dense(params["table"].astype(cfg.dtype), gidx)


def _linear_term(params: Params, cfg: RecsysConfig, sparse_idx: jax.Array) -> jax.Array:
    gidx = cfg.table.globalize(sparse_idx)
    w = embedding_bag_dense(params["linear"].astype(cfg.dtype), gidx)  # (B,F,1)
    return jnp.sum(w[..., 0], axis=-1)


def forward(
    params: Params,
    cfg: RecsysConfig,
    sparse_idx: jax.Array,                 # (B, F, nnz) int32, -1 pad
    dense_feats: Optional[jax.Array] = None,  # (B, n_dense) float
) -> jax.Array:
    """CTR logit (B,)."""
    emb = _lookup(params, cfg, sparse_idx)  # (B, F, d)
    b = emb.shape[0]

    if cfg.model == "fm":
        return params["bias"][0] + _linear_term(params, cfg, sparse_idx) + fm_interaction(emb)

    if cfg.model == "deepfm":
        y_fm = _linear_term(params, cfg, sparse_idx) + fm_interaction(emb)
        y_dnn = mlp_apply(emb.reshape(b, -1), params, len(cfg.mlp) + 1, "mlp")[:, 0]
        return params["bias"][0] + y_fm + y_dnn

    if cfg.model == "dlrm":
        assert dense_feats is not None
        x_bot = mlp_apply(
            dense_feats.astype(cfg.dtype), params, len(cfg.bot_mlp), "bot",
            final_act=True,
        )  # (B, d)
        vecs = jnp.concatenate([x_bot[:, None, :], emb], axis=1)  # (B, F+1, d)
        inter = jnp.concatenate([dot_interaction(vecs), x_bot], axis=-1)
        return mlp_apply(inter, params, len(cfg.top_mlp), "top")[:, 0]

    if cfg.model == "xdeepfm":
        y_lin = _linear_term(params, cfg, sparse_idx)
        y_cin = (cin(emb, params, cfg.cin_layers) @ params["cin_out"]["w"])[:, 0]
        y_dnn = mlp_apply(emb.reshape(b, -1), params, len(cfg.mlp) + 1, "mlp")[:, 0]
        return params["bias"][0] + y_lin + y_cin + y_dnn

    raise ValueError(cfg.model)


def bce_loss(
    params: Params,
    cfg: RecsysConfig,
    sparse_idx: jax.Array,
    labels: jax.Array,
    dense_feats: Optional[jax.Array] = None,
) -> jax.Array:
    logit = forward(params, cfg, sparse_idx, dense_feats)
    y = labels.astype(jnp.float32)
    z = logit.astype(jnp.float32)
    # numerically-stable BCE-with-logits
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


# --------------------------------------------------------------------------
# Retrieval scoring (retrieval_cand): 1 query vs 10^6 candidates
# --------------------------------------------------------------------------


def retrieval_scores(
    user_vec: jax.Array,       # (B, d) pooled query-side embedding
    cand_table: jax.Array,     # (N_cand, d) candidate item embeddings
) -> jax.Array:
    """Batched dot scoring — NOT a loop.  (B, N_cand)."""
    return jnp.einsum(
        "bd,nd->bn", user_vec, cand_table, preferred_element_type=jnp.float32
    )


def retrieval_topk(
    user_vec: jax.Array, cand_table: jax.Array, k: int = 100
) -> Tuple[jax.Array, jax.Array]:
    return jax.lax.top_k(retrieval_scores(user_vec, cand_table), k)


def user_tower(
    params: Params, cfg: RecsysConfig, sparse_idx: jax.Array,
    dense_feats: Optional[jax.Array] = None,
) -> jax.Array:
    """Query-side embedding for retrieval: mean of field embeddings (+ DLRM
    bottom-MLP dense vector when present)."""
    emb = _lookup(params, cfg, sparse_idx)
    u = jnp.mean(emb, axis=1)
    if cfg.model == "dlrm" and dense_feats is not None:
        u = u + mlp_apply(
            dense_feats.astype(cfg.dtype), params, len(cfg.bot_mlp), "bot",
            final_act=True,
        )
    return u
