"""Decoder-only transformer LM: GQA + RoPE + SwiGLU, optional interleaved MoE.

Covers the five assigned LM architectures (phi3-mini/medium, deepseek-coder,
phi3.5-moe, llama4-maverick).  Design points:

* Layers are **scan-stacked**: every per-layer parameter has a leading
  ``n_blocks`` axis and the layer stack runs under ``lax.scan`` +
  ``jax.checkpoint`` - O(1) HLO size for 62-layer models and
  activation-checkpointed memory.
* A scan "block" holds ``moe_period - 1`` dense layers plus one MoE layer
  (llama4 interleaves MoE every other layer; phi3.5-moe is all-MoE,
  period=1; dense archs have no MoE).
* MoE dispatch is **sort-based with static capacity** (GShard-style): tokens
  are argsorted by expert, truncated to capacity C, processed with a grouped
  einsum over an (E, C, d) buffer that shards cleanly over the ``model``
  (expert) axis, and combined via the inverse permutation.  No (T, E, C)
  one-hot tensors.
* Attention is switchable: "einsum" (masked logits; short seq) or
  "blockwise" (double-scan online softmax; O(bq*bk) memory - the pure-JAX
  flash attention used for 32k prefill).  The Pallas flash kernel is the TPU
  drop-in for the same contract.
* Decode runs against a (layers, B, Hkv, S_max, dh) KV cache; the cache is
  length-sharded on the ``model`` axis (flash-decoding split-K: GSPMD turns
  the masked softmax into per-shard partials + psum).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _scan(cfg, f, init, xs):
    """lax.scan that fully unrolls in analysis mode (cfg.scan_unroll)."""
    return jax.lax.scan(f, init, xs, unroll=bool(cfg.scan_unroll))


def _constrained(x: jax.Array, cfg: "TransformerConfig", *dims) -> jax.Array:
    """with_sharding_constraint if any activation axis is configured.
    ``dims`` are PartitionSpec entries (axis name / tuple / None)."""
    if not (cfg.batch_axes or cfg.tp_axis or cfg.seq_axis or cfg.kv_axes):
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(*dims))


def _res_spec(cfg):  # residual stream (B, S, d)
    return (cfg.batch_axes or None, cfg.seq_axis, None)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 16
    top_k: int = 2
    d_ff: int = 6400
    period: int = 1  # an MoE layer every `period` layers
    capacity_factor: float = 1.25
    shared_expert: bool = False  # always-active expert beside the routed one
    #                              (Llama-4 Maverick style)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "tiny"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 256
    vocab: int = 1024
    head_dim: Optional[int] = None  # default d_model // n_heads
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    dtype: Any = jnp.bfloat16  # compute/activation dtype
    param_dtype: Any = jnp.float32
    attn_impl: str = "auto"  # "einsum" | "blockwise" | "auto"
    blockwise_q: int = 1024
    blockwise_kv: int = 1024
    tie_embeddings: bool = False
    # Analysis mode: fully unroll every lax.scan.  XLA's HLO cost analysis
    # counts a while body ONCE regardless of trip count, so roofline-term
    # extraction lowers shallow unrolled variants (launch/roofline.py);
    # production keeps scan (O(1) HLO size).
    scan_unroll: bool = False
    # Activation sharding constraints (mesh axis names).  GSPMD propagation
    # alone loses the batch sharding through the layer stack (observed:
    # logits replicated over 'data' => 134 GB/dev); explicit constraints on
    # the residual stream / logits / KV cache pin it.  Empty tuples / None
    # disable (single-device tests).  Set by launch/cells.py per cell.
    batch_axes: Tuple[str, ...] = ()   # DP axes for activations
    tp_axis: Optional[str] = None      # tensor axis (vocab dim of logits)
    # Flat-GQA: materialize K/V at full query-head count before attention so
    # the head dim shards cleanly over TP.  With n_kv_heads < TP size, GSPMD
    # otherwise splits the GQA group dim to fill the axis and emits
    # logits-sized partial all-reduces in the backward (measured: 60 GB AR
    # per layer for deepseek train_4k).  Costs a K/V repeat + head padding.
    attn_flat_heads: bool = False
    seq_axis: Optional[Any] = None     # sequence axis (SP) — perf lever
    kv_axes: Optional[Any] = None      # KV-cache length axis (decode split-K)

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def moe_period(self) -> int:
        return self.moe.period if self.moe else 0

    @property
    def n_blocks(self) -> int:
        if not self.moe:
            return self.n_layers
        assert self.n_layers % self.moe.period == 0
        return self.n_layers // self.moe.period

    @property
    def dense_per_block(self) -> int:
        return 0 if not self.moe else self.moe.period - 1

    def param_count(self) -> Tuple[int, int]:
        """(total, active) parameter counts (active differs for MoE)."""
        d, dh = self.d_model, self.dh
        attn = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) + (
            self.n_heads * dh
        ) * d
        dense_ffn = 3 * d * self.d_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        norms = 2 * d
        if not self.moe:
            per_layer = attn + dense_ffn + norms
            total = self.n_layers * per_layer + emb + d
            return total, total
        moe_ffn = 3 * d * self.moe.d_ff
        shared = moe_ffn if self.moe.shared_expert else 0
        router = d * self.moe.num_experts
        n_moe = self.n_blocks
        n_dense = self.n_layers - n_moe
        total = (
            n_dense * (attn + dense_ffn + norms)
            + n_moe * (attn + router + self.moe.num_experts * moe_ffn + shared + norms)
            + emb
            + d
        )
        active = (
            n_dense * (attn + dense_ffn + norms)
            + n_moe * (attn + router + self.moe.top_k * moe_ffn + shared + norms)
            + emb
            + d
        )
        return total, active


# --------------------------------------------------------------------------
# Initialization
# --------------------------------------------------------------------------


def _dense_layer_shapes(cfg: TransformerConfig, d_ff: int) -> Dict[str, tuple]:
    d, dh = cfg.d_model, cfg.dh
    return {
        "ln1": (d,),
        "ln2": (d,),
        "wq": (d, cfg.n_heads * dh),
        "wk": (d, cfg.n_kv_heads * dh),
        "wv": (d, cfg.n_kv_heads * dh),
        "wo": (cfg.n_heads * dh, d),
        "w_gate": (d, d_ff),
        "w_up": (d, d_ff),
        "w_down": (d_ff, d),
    }


def _moe_layer_shapes(cfg: TransformerConfig) -> Dict[str, tuple]:
    d, dh, m = cfg.d_model, cfg.dh, cfg.moe
    return {
        "ln1": (d,),
        "ln2": (d,),
        "wq": (d, cfg.n_heads * dh),
        "wk": (d, cfg.n_kv_heads * dh),
        "wv": (d, cfg.n_kv_heads * dh),
        "wo": (cfg.n_heads * dh, d),
        "router": (d, m.num_experts),
        "moe_gate": (m.num_experts, d, m.d_ff),
        "moe_up": (m.num_experts, d, m.d_ff),
        "moe_down": (m.num_experts, m.d_ff, d),
        **({"w_gate": (d, m.d_ff), "w_up": (d, m.d_ff),
            "w_down": (m.d_ff, d)} if m.shared_expert else {}),
    }


def param_shapes(cfg: TransformerConfig) -> Params:
    """Abstract parameter tree (shapes only) - used by init and the dry-run
    (jax.eval_shape avoids materializing 400B parameters on the host)."""
    nb = cfg.n_blocks
    shapes: Params = {
        "embed": (cfg.vocab, cfg.d_model),
        "final_ln": (cfg.d_model,),
    }
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (cfg.d_model, cfg.vocab)
    if cfg.moe:
        if cfg.dense_per_block:
            shapes["dense_layers"] = {
                k: (nb, cfg.dense_per_block) + s
                for k, s in _dense_layer_shapes(cfg, cfg.d_ff).items()
            }
        shapes["moe_layers"] = {
            k: (nb,) + s for k, s in _moe_layer_shapes(cfg).items()
        }
    else:
        shapes["layers"] = {
            k: (nb,) + s for k, s in _dense_layer_shapes(cfg, cfg.d_ff).items()
        }
    return shapes


def init_params(key: jax.Array, cfg: TransformerConfig) -> Params:
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(flat))

    def init_one(k, shape):
        if len(shape) >= 2:
            fan_in = shape[-2]
            std = 1.0 / math.sqrt(fan_in)
            return (jax.random.normal(k, shape, jnp.float32) * std).astype(
                cfg.param_dtype
            )
        return jnp.ones(shape, cfg.param_dtype)  # norms

    leaves = [init_one(k, s) for k, s in zip(keys, flat)]
    params = jax.tree_util.tree_unflatten(treedef, leaves)
    # Embedding init: std 0.02, norms ones.
    params["embed"] = (
        jax.random.normal(jax.random.fold_in(key, 999), shapes["embed"], jnp.float32)
        * 0.02
    ).astype(cfg.param_dtype)
    return params


# --------------------------------------------------------------------------
# Primitives
# --------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    # Variance accumulates in f32 WITHOUT materializing an f32 copy of x:
    # an f32 x would make the residual-stream cotangents f32 too, doubling
    # every TP all-reduce in the backward (measured on deepseek train_4k).
    var = jnp.einsum(
        "...d,...d->...", x, x, preferred_element_type=jnp.float32
    ) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return x * inv * w.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def _einsum_attention(q, k, v, q_offset: int = 0, flat_gqa: bool = False) -> jax.Array:
    """q: (B,S,Hq,dh), k/v: (B,T,Hkv,dh). Causal w.r.t. absolute positions
    (q position i attends to kv positions <= q_offset + i)."""
    b, s, hq, dh = q.shape
    if flat_gqa and k.shape[2] != hq:
        rep = hq // k.shape[2]
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    t, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, s, hkv, group, dh)
    logits = jnp.einsum(
        "bshgd,bthd->bhgst", qg, k, preferred_element_type=jnp.float32
    ) / math.sqrt(dh)
    q_pos = q_offset + jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = q_pos >= k_pos  # (s, t)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, hq, dh)


def _blockwise_attention(
    q, k, v, bq: int, bk: int, q_offset: int = 0, unroll: bool = False,
    flat_gqa: bool = False,
) -> jax.Array:
    """Memory-efficient causal attention: outer scan over query blocks,
    inner scan over KV blocks with online-softmax carry.  Pure jnp (and so
    differentiable + shardable); the Pallas flash kernel implements the same
    contract on TPU."""
    b, s, hq, dh = q.shape
    if flat_gqa and k.shape[2] != hq:
        rep = hq // k.shape[2]
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    t, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    s_pad, t_pad = (-s) % bq, (-t) % bk
    qp = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // bq, kp.shape[1] // bk
    qb = jnp.moveaxis(qp.reshape(b, nq, bq, hkv, group, dh), 1, 0)
    kb = jnp.moveaxis(kp.reshape(b, nk, bk, hkv, dh), 1, 0)
    vb = jnp.moveaxis(vp.reshape(b, nk, bk, hkv, dh), 1, 0)
    scale = 1.0 / math.sqrt(dh)

    def q_step(_, qi_and_block):
        qi, qblk = qi_and_block  # qblk: (b, bq, hkv, g, dh)

        def kv_step(carry, ki_and_blocks):
            m_prev, l_prev, acc = carry
            ki, kblk, vblk = ki_and_blocks
            logits = (
                jnp.einsum(
                    "bqhgd,bkhd->bhgqk", qblk, kblk,
                    preferred_element_type=jnp.float32,
                )
                * scale
            )
            q_pos = q_offset + qi * bq + jnp.arange(bq)[:, None]
            k_pos = ki * bk + jnp.arange(bk)[None, :]
            mask = (q_pos >= k_pos) & (k_pos < t)
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_cur = jnp.max(logits, axis=-1)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = alpha * l_prev + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, group, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, group, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, group, bq, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb), unroll=unroll
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.moveaxis(out, (1, 2), (2, 3))  # (b, bq, hkv, g, dh)
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(q_step, None, (jnp.arange(nq), qb), unroll=unroll)
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, nq * bq, hkv * group, dh)
    return out[:, :s]


def _blockwise_attention_unrolled(
    q, k, v, bq: int, bk: int, q_offset: int = 0
) -> jax.Array:
    """Python-unrolled blockwise attention with STATIC causal skipping: kv
    blocks entirely in the future of a query block are never computed —
    matching what the Pallas flash kernel does on TPU (the lax.scan variant
    masks them instead, which double-counts attention flops in analysis).
    Used when cfg.scan_unroll (roofline analysis mode)."""
    b, s, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    s_pad, t_pad = (-s) % bq, (-t) % bk
    qp = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // bq, kp.shape[1] // bk
    scale = 1.0 / math.sqrt(dh)
    out_blocks = []
    for qi in range(nq):
        qblk = qp[:, qi * bq : (qi + 1) * bq].reshape(b, bq, hkv, group, dh)
        m = jnp.full((b, hkv, group, bq), -1e30, jnp.float32)
        l = jnp.zeros((b, hkv, group, bq), jnp.float32)
        acc = jnp.zeros((b, hkv, group, bq, dh), jnp.float32)
        q_max = q_offset + (qi + 1) * bq - 1
        for ki in range(nk):
            if ki * bk > q_max:
                continue  # static causal skip
            kblk = kp[:, ki * bk : (ki + 1) * bk].reshape(b, bk, hkv, dh)
            vblk = vp[:, ki * bk : (ki + 1) * bk].reshape(b, bk, hkv, dh)
            logits = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qblk, kblk,
                preferred_element_type=jnp.float32,
            ) * scale
            q_pos = q_offset + qi * bq + jnp.arange(bq)[:, None]
            k_pos = ki * bk + jnp.arange(bk)[None, :]
            mask = (q_pos >= k_pos) & (k_pos < t)
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = alpha * l + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32,
            )
            m = m_new
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        o = jnp.moveaxis(o, (1, 2), (2, 3))  # (b, bq, hkv, g, dh)
        out_blocks.append(o.astype(q.dtype))
    out = jnp.concatenate(out_blocks, axis=1).reshape(b, nq * bq, hq, dh)
    return out[:, :s].reshape(b, s, hq, dh)


def attention(x, layer, cfg: TransformerConfig, positions) -> jax.Array:
    b, s, d = x.shape
    dh = cfg.dh
    q = (x @ layer["wq"].astype(x.dtype)).reshape(b, s, cfg.n_heads, dh)
    k = (x @ layer["wk"].astype(x.dtype)).reshape(b, s, cfg.n_kv_heads, dh)
    v = (x @ layer["wv"].astype(x.dtype)).reshape(b, s, cfg.n_kv_heads, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cfg.attn_flat_heads:
        # Materialize K/V at full query-head count and pin the head dim to
        # TP: heads shard cleanly (GSPMD pads 56 -> 64 rather than splitting
        # the GQA group axis into tiny partial-reduce groups).
        if cfg.n_kv_heads != cfg.n_heads:
            rep = cfg.n_heads // cfg.n_kv_heads
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        hd_spec = (cfg.batch_axes or None, None, cfg.tp_axis, None)
        q = _constrained(q, cfg, *hd_spec)
        k = _constrained(k, cfg, *hd_spec)
        v = _constrained(v, cfg, *hd_spec)
    # Clamp tiles to the (padded) sequence so oversized analysis blocks
    # never pad S upward (bq=8192 on S=4096 doubled the padded length and
    # quadrupled attention work — measured).
    bq = min(cfg.blockwise_q, s)
    bk = min(cfg.blockwise_kv, s)
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "blockwise" if s > 2048 else "einsum"
    if impl == "blockwise" and cfg.scan_unroll:
        o = _blockwise_attention_unrolled(q, k, v, bq, bk)
    elif impl == "blockwise":
        o = _blockwise_attention(
            q, k, v, bq, bk, unroll=False, flat_gqa=False,
        )
    else:
        o = _einsum_attention(q, k, v)
    return o.reshape(b, s, cfg.n_heads * dh) @ layer["wo"].astype(x.dtype)


def swiglu(x, layer, prefix: str = "w") -> jax.Array:
    g = x @ layer[f"{prefix}_gate"].astype(x.dtype)
    u = x @ layer[f"{prefix}_up"].astype(x.dtype)
    return (jax.nn.silu(g) * u) @ layer[f"{prefix}_down"].astype(x.dtype)


# --------------------------------------------------------------------------
# MoE: sort-based capacity dispatch (GShard-style, static shapes)
# --------------------------------------------------------------------------


def _moe_dispatch_group(xt, top_e, top_p, e: int, k: int, cap: int):
    """Per-group (one sequence) sort-based dispatch.  xt: (S, d), top_e/p:
    (S, k).  Returns (expert_in (E, C, d), st, slot, keep, sp) for combine.
    Runs under vmap over the batch axis, so sorts stay shard-local when the
    batch is data-sharded (no distributed sort — the pod-scale requirement).
    """
    s, d = xt.shape
    flat_e = top_e.reshape(-1)  # (S*k,)
    flat_t = jnp.repeat(jnp.arange(s), k)
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)  # group by expert
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    counts = jnp.bincount(se, length=e)  # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(s * k) - starts[se]
    keep = pos_in_e < cap  # capacity drop (overflow tokens pass through)
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)  # overflow -> trash row
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].set(xt[st])
    return buf[: e * cap].reshape(e, cap, d), st, slot, keep, sp


def moe_ffn(
    x: jax.Array, layer: Params, cfg: TransformerConfig, dropless: bool = False
) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).  GShard-style sort-based dispatch with
    static capacity, **grouped by batch row**: each sequence dispatches its
    own tokens (capacity = capacity_factor * S * k / E per group), so with
    the batch sharded over 'data' the argsort/scatter are shard-local and
    the only cross-device movement is the (B, E, C, d) buffer's expert axis
    (the MoE all-to-all, experts sharded over 'model').

    ``dropless=True`` sets capacity = S (no token ever dropped); used by the
    decode path, where a drop would silently skip the FFN for a live
    request."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    cap = s if dropless else max(1, min(int(m.capacity_factor * s * k / e), s))

    router_logits = (x @ layer["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)  # (B, S, E)
    top_p, top_e = jax.lax.top_k(probs, k)  # (B, S, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize

    expert_in, st, slot, keep, sp = jax.vmap(
        functools.partial(_moe_dispatch_group, e=e, k=k, cap=cap)
    )(x.reshape(b, s, d), top_e, top_p)  # expert_in: (B, E, C, d)

    # Grouped expert FFN over the stacked expert weights (EP over 'model').
    g = jnp.einsum("becd,edf->becf", expert_in, layer["moe_gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", expert_in, layer["moe_up"].astype(x.dtype))
    y = jnp.einsum(
        "becf,efd->becd", jax.nn.silu(g) * u, layer["moe_down"].astype(x.dtype)
    )
    y = y.reshape(b, e * cap, d)

    # Combine: weighted scatter-add back to token order, per group.
    def combine(y_g, st_g, slot_g, keep_g, sp_g):
        contrib = jnp.where(
            keep_g[:, None], y_g[jnp.minimum(slot_g, e * cap - 1)], 0.0
        )
        return (
            jnp.zeros((s, d), x.dtype)
            .at[st_g]
            .add(contrib * sp_g[:, None].astype(x.dtype))
        )

    out = jax.vmap(combine)(y, st, slot, keep, sp)
    return out.reshape(b, s, d)


def moe_aux_loss(router_logits: jax.Array, top_e: jax.Array, e: int) -> jax.Array:
    """Load-balancing auxiliary loss (Switch/GShard): E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    p_mean = jnp.mean(probs, axis=0)
    f = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=0
    )
    return e * jnp.sum(f * p_mean)


# --------------------------------------------------------------------------
# Forward / loss
# --------------------------------------------------------------------------


def _dense_layer(x, layer, cfg, positions):
    x = x + attention(rms_norm(x, layer["ln1"], cfg.norm_eps), layer, cfg, positions)
    x = x + swiglu(rms_norm(x, layer["ln2"], cfg.norm_eps), layer)
    return x


def _moe_layer(x, layer, cfg, positions, dropless: bool = False):
    """dropless=True on serving paths (prefill/decode): a capacity drop
    there would silently skip the FFN for a live request; training keeps
    the GShard static capacity."""
    x = x + attention(rms_norm(x, layer["ln1"], cfg.norm_eps), layer, cfg, positions)
    h = rms_norm(x, layer["ln2"], cfg.norm_eps)
    y = moe_ffn(h, layer, cfg, dropless=dropless)
    if cfg.moe.shared_expert:
        y = y + swiglu(h, layer)
    return x + y


def forward(params: Params, tokens: jax.Array, cfg: TransformerConfig) -> jax.Array:
    """tokens: (B, S) int32 -> logits (B, S, vocab) in f32."""
    b, s = tokens.shape
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = _constrained(x, cfg, *_res_spec(cfg))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    if cfg.moe:
        dense_stack = params.get("dense_layers")

        def block(x, blk_params):
            if dense_stack is not None:
                dl = blk_params["dense"]

                def inner(x, one_dense):
                    return _dense_layer(x, one_dense, cfg, positions), None

                x, _ = _scan(cfg, inner, x, dl)
            x = _moe_layer(x, blk_params["moe"], cfg, positions)
            return _constrained(x, cfg, *_res_spec(cfg)), None

        blk_tree = {"moe": params["moe_layers"]}
        if dense_stack is not None:
            blk_tree["dense"] = dense_stack
        x, _ = _scan(
            cfg,
            jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable),
            x,
            blk_tree,
        )
    else:

        def block(x, layer):
            x = _dense_layer(x, layer, cfg, positions)
            return _constrained(x, cfg, *_res_spec(cfg)), None

        x, _ = _scan(
            cfg,
            jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable),
            x,
            params["layers"],
        )

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    return _constrained(logits, cfg, cfg.batch_axes or None, cfg.seq_axis, cfg.tp_axis)


def loss_fn(
    params: Params, tokens: jax.Array, labels: jax.Array, cfg: TransformerConfig
) -> jax.Array:
    logits = forward(params, tokens, cfg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # Label-logit extraction via iota-compare + masked max instead of
    # take_along_axis: with logits vocab-sharded over 'model' (TP head) this
    # stays elementwise + reduce (psum), whereas a gather on the sharded
    # vocab axis would force GSPMD to all-gather the (B, S, V) logits.
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    hit = vocab_iota == labels[..., None]
    label_logit = jnp.max(jnp.where(hit, logits, -jnp.inf), axis=-1)
    return jnp.mean(logz - label_logit)


# --------------------------------------------------------------------------
# Serving: prefill + single-token decode against a KV cache
# --------------------------------------------------------------------------


def _layer_kv(x, layer, cfg, positions):
    b, s, _ = x.shape
    h = rms_norm(x, layer["ln1"], cfg.norm_eps)
    k = (h @ layer["wk"].astype(x.dtype)).reshape(b, s, cfg.n_kv_heads, cfg.dh)
    v = (h @ layer["wv"].astype(x.dtype)).reshape(b, s, cfg.n_kv_heads, cfg.dh)
    k, v = (
        _constrained(k, cfg, cfg.batch_axes or None, cfg.kv_axes, None, None),
        _constrained(v, cfg, cfg.batch_axes or None, cfg.kv_axes, None, None),
    )
    return rope(k, positions, cfg.rope_theta), v


def prefill(
    params: Params, tokens: jax.Array, cfg: TransformerConfig
) -> Tuple[Params, jax.Array]:
    """Full-sequence forward that also returns the per-layer KV cache
    (stacked (n_layers_effective, B, S, Hkv, dh)) and last-position logits."""
    b, s = tokens.shape
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = _constrained(x, cfg, *_res_spec(cfg))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    caches_k, caches_v = [], []

    def run_dense_stack(x, stack):
        def step(x, layer):
            k, v = _layer_kv(x, layer, cfg, positions)
            return _dense_layer(x, layer, cfg, positions), (k, v)

        return _scan(cfg, step, x, stack)

    if cfg.moe:
        if params.get("dense_layers") is not None:

            def blk(x, p):
                x, (kd, vd) = run_dense_stack(x, p["dense"])
                km, vm = _layer_kv(x, p["moe"], cfg, positions)
                # capped dispatch: dropless at prefill (cap = S = 32k)
                # inflates the (E, C, d) buffers to ~43 GB/device and was
                # measured 18x collective-worse; bounded-drop prefill is
                # the production standard.  Decode stays dropless (S = 1).
                x = _moe_layer(x, p["moe"], cfg, positions)
                return x, (kd, vd, km, vm)

            tree = {"dense": params["dense_layers"], "moe": params["moe_layers"]}
            x, (kd, vd, km, vm) = _scan(cfg, blk, x, tree)
            # Interleave dense + moe caches into layer order.
            nb, dp = kd.shape[0], kd.shape[1]
            kd = kd.reshape((nb * dp,) + kd.shape[2:])
            vd = vd.reshape((nb * dp,) + vd.shape[2:])
            # layer order per block: dense..., moe - concatenate per block.
            k_all = jnp.concatenate(
                [kd.reshape(nb, dp, *kd.shape[1:]), km[:, None]], axis=1
            ).reshape(nb * (dp + 1), *km.shape[1:])
            v_all = jnp.concatenate(
                [vd.reshape(nb, dp, *vd.shape[1:]), vm[:, None]], axis=1
            ).reshape(nb * (dp + 1), *vm.shape[1:])
        else:

            def blk(x, p):
                k, v = _layer_kv(x, p, cfg, positions)
                x = _moe_layer(x, p, cfg, positions)
                return x, (k, v)

            x, (k_all, v_all) = _scan(cfg, blk, x, params["moe_layers"])
    else:

        def blk(x, p):
            k, v = _layer_kv(x, p, cfg, positions)
            x = _dense_layer(x, p, cfg, positions)
            return x, (k, v)

        x, (k_all, v_all) = _scan(cfg, blk, x, params["layers"])

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits_last = (x[:, -1] @ head.astype(x.dtype)).astype(jnp.float32)
    cache = {"k": k_all, "v": v_all, "length": jnp.int32(s)}
    return cache, logits_last


def _decode_attention(q, cache_k, cache_v, length) -> jax.Array:
    """q: (B, 1, Hq, dh); cache: (B, T, Hkv, dh); positions >= length masked.
    With the cache length-sharded on 'model', GSPMD lowers this to
    flash-decoding split-K partials + psum."""
    b, _, hq, dh = q.shape
    t, hkv = cache_k.shape[1], cache_k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, dh)
    logits = jnp.einsum(
        "bhgd,bthd->bhgt", qg, cache_k, preferred_element_type=jnp.float32
    ) / math.sqrt(dh)
    mask = jnp.arange(t)[None, None, None, :] < length
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", probs.astype(cache_v.dtype), cache_v)
    return out.reshape(b, 1, hq * dh)


def _decode_attention_incremental(
    q, cache_k, cache_v, k_new, v_new, length
) -> jax.Array:
    """Decode attention over the PRE-update cache plus an explicit term for
    the token being generated (exact: softmax over [cache[<length], new]).
    Lets the cache update stay in-place (see decode_step.one_layer)."""
    b, _, hq, dh = q.shape
    t, hkv = cache_k.shape[1], cache_k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, dh)
    logits = jnp.einsum(
        "bhgd,bthd->bhgt", qg, cache_k, preferred_element_type=jnp.float32
    ) / math.sqrt(dh)
    mask = jnp.arange(t)[None, None, None, :] < length  # strictly past
    logits = jnp.where(mask, logits, -1e30)
    logit_new = jnp.einsum(
        "bhgd,bhd->bhg", qg, k_new[:, 0], preferred_element_type=jnp.float32
    ) / math.sqrt(dh)
    m = jnp.maximum(jnp.max(logits, axis=-1), logit_new)
    p = jnp.exp(logits - m[..., None])
    p_new = jnp.exp(logit_new - m)
    denom = jnp.sum(p, axis=-1) + p_new
    acc = jnp.einsum(
        "bhgt,bthd->bhgd", p.astype(cache_v.dtype), cache_v,
        preferred_element_type=jnp.float32,
    ) + p_new[..., None] * v_new[:, 0][:, :, None, :].astype(jnp.float32)
    out = (acc / denom[..., None]).astype(cache_v.dtype)
    return out.reshape(b, 1, hq * dh)


def decode_step(
    params: Params,
    cache: Params,
    token: jax.Array,  # (B,) int32
    cfg: TransformerConfig,
) -> Tuple[Params, jax.Array]:
    """One decode step: append the token's KV at position ``length`` and
    return next-token logits.  Cache layout (L, B, T_max, Hkv, dh).

    The full cache rides the scan CARRY (not stacked ys): XLA aliases while
    -loop carries in place, so with the cache donated the step runs with one
    cache buffer — stacking per-layer ys instead was measured to double the
    footprint (6.4 GB extra/device for phi3-mini decode_32k)."""
    b = token.shape[0]
    length = cache["length"]
    x = params["embed"].astype(cfg.dtype)[token][:, None, :]  # (B,1,d)
    x = _constrained(x, cfg, cfg.batch_axes or None, None, None)
    positions = jnp.full((b, 1), length, jnp.int32)

    def one_layer(x, layer, i, kf, vf):
        """kf/vf: full (L, B, T, Hkv, dh) cache; i: layer index.

        In-place discipline: the cache row is read BEFORE the update and the
        new token's attention term is added analytically
        (_decode_attention_incremental) — a read of the row *after* the
        dynamic-update forces XLA to keep two live cache versions
        (measured: +2x cache temp).  No sharding constraint on the carry
        either (a Sharding custom-call also breaks buffer aliasing); in/out
        jit shardings pin the layout."""
        h = rms_norm(x, layer["ln1"], cfg.norm_eps)
        q = (h @ layer["wq"].astype(x.dtype)).reshape(b, 1, cfg.n_heads, cfg.dh)
        k = (h @ layer["wk"].astype(x.dtype)).reshape(b, 1, cfg.n_kv_heads, cfg.dh)
        v = (h @ layer["wv"].astype(x.dtype)).reshape(b, 1, cfg.n_kv_heads, cfg.dh)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        k_old = jax.lax.dynamic_index_in_dim(kf, i, 0, keepdims=False)
        v_old = jax.lax.dynamic_index_in_dim(vf, i, 0, keepdims=False)
        attn_out = _decode_attention_incremental(q, k_old, v_old, k, v, length)
        kf = jax.lax.dynamic_update_slice(kf, k[None], (i, 0, length, 0, 0))
        vf = jax.lax.dynamic_update_slice(vf, v[None], (i, 0, length, 0, 0))
        x = x + attn_out @ layer["wo"].astype(x.dtype)
        return x, kf, vf

    def dense_step(x, layer, i, kf, vf):
        x, kf, vf = one_layer(x, layer, i, kf, vf)
        x = x + swiglu(rms_norm(x, layer["ln2"], cfg.norm_eps), layer)
        return x, kf, vf

    def moe_step(x, layer, i, kf, vf):
        x, kf, vf = one_layer(x, layer, i, kf, vf)
        h = rms_norm(x, layer["ln2"], cfg.norm_eps)
        y = moe_ffn(h, layer, cfg, dropless=True)
        if cfg.moe.shared_expert:
            y = y + swiglu(h, layer)
        return x + y, kf, vf

    carry0 = (x, cache["k"], cache["v"])
    if cfg.moe and params.get("dense_layers") is not None:
        dp = cfg.dense_per_block
        nb = cfg.n_blocks

        def blk(carry, xs):
            x, kf, vf = carry
            p_dense, p_moe, bi = xs

            def inner(carry2, xs2):
                x, kf, vf = carry2
                layer, j = xs2
                x, kf, vf = dense_step(x, layer, bi * (dp + 1) + j, kf, vf)
                return (x, kf, vf), None

            (x, kf, vf), _ = _scan(
                cfg, inner, (x, kf, vf), (p_dense, jnp.arange(dp))
            )
            x, kf, vf = moe_step(x, p_moe, bi * (dp + 1) + dp, kf, vf)
            return (x, kf, vf), None

        (x, k_new, v_new), _ = _scan(
            cfg, blk, carry0,
            (params["dense_layers"], params["moe_layers"], jnp.arange(nb)),
        )
    elif cfg.moe:

        def blk(carry, xs):
            x, kf, vf = carry
            layer, i = xs
            x, kf, vf = moe_step(x, layer, i, kf, vf)
            return (x, kf, vf), None

        (x, k_new, v_new), _ = _scan(
            cfg, blk, carry0, (params["moe_layers"], jnp.arange(cfg.n_blocks))
        )
    else:

        def blk(carry, xs):
            x, kf, vf = carry
            layer, i = xs
            x, kf, vf = dense_step(x, layer, i, kf, vf)
            return (x, kf, vf), None

        (x, k_new, v_new), _ = _scan(
            cfg, blk, carry0, (params["layers"], jnp.arange(cfg.n_layers))
        )

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, 0] @ head.astype(x.dtype)).astype(jnp.float32)
    new_cache = {"k": k_new, "v": v_new, "length": length + 1}
    return new_cache, logits


def make_cache(
    cfg: TransformerConfig, batch: int, max_len: int, dtype=None
) -> Params:
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.dh)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.int32(0),
    }
