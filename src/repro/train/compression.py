"""Gradient compression: int8 all-reduce with error feedback.

Data-parallel gradient all-reduce moves 4 bytes/param/step in f32.  At pod
scale the DP all-reduce is the collective-term ceiling for small models, so
we provide an explicit ``shard_map`` DP step that:

  1. adds the local error-feedback residual to the local gradient,
  2. quantizes to int8 with a per-leaf (per-tensor) scale = max|g|/127,
  3. all-reduces the int8 payload (psum) — 4x fewer bytes on the wire,
  4. dequantizes; the residual keeps what quantization dropped (error
     feedback makes the scheme convergent: Karimireddy et al. 2019).

The scale is itself psum-maxed first (1 float per leaf) so every shard uses
the same quantization grid — required for correctness of int8 psum.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro import compat

Pytree = Any


def quantize_leaf(g: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.round(g.astype(jnp.float32) / scale)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    grads: Pytree, residual: Pytree, axis_name: str
) -> Tuple[Pytree, Pytree]:
    """Inside shard_map: returns (mean-reduced grads, new residual)."""
    n = compat.axis_size(axis_name)

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        amax = jnp.max(jnp.abs(g32))
        amax = jax.lax.pmax(amax, axis_name)  # shared grid
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = quantize_leaf(g32, scale)
        new_r = g32 - dequantize_leaf(q, scale)  # error feedback
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return dequantize_leaf(summed, scale) / n, new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        treedef.unflatten([x[0] for x in out]),
        treedef.unflatten([x[1] for x in out]),
    )


def init_residual(params: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def wire_bytes(params: Pytree, compressed: bool) -> int:
    """Bytes per DP all-reduce hop for reporting (f32 vs int8 payload)."""
    n = sum(l.size for l in jax.tree_util.tree_leaves(params))
    return n * (1 if compressed else 4)
