"""Sharded optimizers: AdamW and Adafactor, plus schedules and clipping.

Implemented directly on pytrees (no optax dependency in the container).
Optimizer state mirrors the parameter tree, so whatever NamedSharding the
params carry, the states inherit it (FSDP: states shard with the weights).

Adafactor stores row/col second-moment factors for rank>=2 leaves —
O(n+m) instead of O(n*m) state — which is what makes 400B-param optimizer
state fit a pod (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


# --------------------------------------------------------------------------
# Schedules
# --------------------------------------------------------------------------


def cosine_schedule(
    base_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        prog = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(step < warmup_steps, warm, cos)

    return lr


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree: Pytree, max_norm: float) -> Tuple[Pytree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), norm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)


def adamw_init(params: Pytree) -> Pytree:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    cfg: AdamWConfig, grads: Pytree, state: Pytree, params: Pytree
) -> Tuple[Pytree, Pytree, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    lr = cfg._lr(step)
    bc1 = 1 - cfg.b1**t
    bc2 = 1 - cfg.b2**t

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu = cfg.b2 * nu + (1 - cfg.b2) * g32 * g32
        u = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        if p.ndim >= 2:  # no decay on norms/biases
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    new = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([x[0] for x in new])
    new_state = {
        "mu": treedef.unflatten([x[1] for x in new]),
        "nu": treedef.unflatten([x[2] for x in new]),
        "step": step,
    }
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------------------------------
# Adafactor (factored second moments)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-2
    decay: float = 0.8  # beta2 = 1 - t^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params: Pytree) -> Pytree:
    def leaf(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

    return {
        "v": jax.tree_util.tree_map(leaf, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(
    cfg: AdafactorConfig, grads: Pytree, state: Pytree, params: Pytree
) -> Tuple[Pytree, Pytree, dict]:
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay)
    lr = cfg._lr(step)

    def upd(g, v, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + cfg.eps
        if _factored(p.shape):
            vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), cfg.eps)
            # rank-general: vr/denom is p.shape[:-1]; expand to [..., None],
            # vc expands on axis -2 (stacked (layers, ..., n, m) leaves too).
            u = (
                g32
                * jax.lax.rsqrt(vr / denom)[..., None]
                * jax.lax.rsqrt(jnp.expand_dims(vc, -2))
            )
            new_v = {"vr": vr, "vc": vc}
        else:
            vv = beta2 * v["v"] + (1 - beta2) * g2
            u = g32 * jax.lax.rsqrt(vv)
            new_v = {"v": vv}
        # update clipping (RMS(u) <= clip_threshold)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        if cfg.weight_decay and p.ndim >= 2:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    new = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_p = treedef.unflatten([x[0] for x in new])
    new_state = {"v": treedef.unflatten([x[1] for x in new]), "step": step}
    return new_p, new_state, {"lr": lr}


# --------------------------------------------------------------------------
# Uniform facade
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Optimizer:
    kind: str  # "adamw" | "adafactor"
    config: Any

    def init(self, params: Pytree) -> Pytree:
        return adamw_init(params) if self.kind == "adamw" else adafactor_init(params)

    def update(self, grads, state, params):
        if self.kind == "adamw":
            return adamw_update(self.config, grads, state, params)
        return adafactor_update(self.config, grads, state, params)


def adamw(**kw) -> Optimizer:
    return Optimizer("adamw", AdamWConfig(**kw))


def adafactor(**kw) -> Optimizer:
    return Optimizer("adafactor", AdafactorConfig(**kw))
