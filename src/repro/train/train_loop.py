"""Train-step builder: grad accumulation, clipping, metrics, watchdog.

``build_train_step`` turns any ``loss_fn(params, batch) -> scalar`` into a
jit-able ``step(state, batch) -> (state, metrics)`` with:

  * microbatch accumulation under ``lax.scan`` (global batch stays constant
    while per-device activation memory scales 1/n_microbatches);
  * global-norm clipping + optimizer update (train/optimizer.py);
  * loss/grad-norm metrics.

``Watchdog`` is the host-side straggler monitor: per-step wall times feed an
EWMA; a step slower than ``threshold`` x EWMA is flagged (on real pods this
is the signal to evict/restart a slow host — here it logs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer

Pytree = Any
LossFn = Callable[[Pytree, Dict[str, jax.Array]], jax.Array]


@dataclasses.dataclass
class TrainState:
    params: Pytree
    opt_state: Pytree
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten
)


def make_train_state(params: Pytree, opt: Optimizer) -> TrainState:
    return TrainState(params=params, opt_state=opt.init(params), step=jnp.zeros((), jnp.int32))


def build_train_step(
    loss_fn: LossFn,
    opt: Optimizer,
    n_microbatches: int = 1,
    donate: bool = True,
):
    """Returns jit-able ``step(state, batch)``.  ``batch`` leaves must have a
    leading global-batch axis divisible by ``n_microbatches``."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state.params
        if n_microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def resh(x):
                b = x.shape[0]
                assert b % n_microbatches == 0
                return x.reshape(n_microbatches, b // n_microbatches, *x.shape[1:])

            micro = jax.tree_util.tree_map(resh, batch)

            def acc_step(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = grads_of(params, mb)
                grad_acc = jax.tree_util.tree_map(jnp.add, grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), zeros), micro
            )
            loss = loss / n_microbatches
            grads = jax.tree_util.tree_map(lambda g: g / n_microbatches, grads)

        new_params, new_opt, info = opt.update(grads, state.opt_state, params)
        metrics = {"loss": loss, **info}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return step


@dataclasses.dataclass
class Watchdog:
    """EWMA step-time straggler detector (host side)."""

    threshold: float = 2.0
    alpha: float = 0.1
    ewma: Optional[float] = None
    flagged: int = 0
    _t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int, log=print) -> float:
        dt = time.monotonic() - self._t0
        if self.ewma is None:
            self.ewma = dt
        elif dt > self.threshold * self.ewma:
            self.flagged += 1
            log(
                f"[watchdog] step {step}: {dt * 1e3:.1f}ms > "
                f"{self.threshold:.1f}x EWMA {self.ewma * 1e3:.1f}ms — straggler"
            )
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return dt
