"""Async, atomic, mesh-shape-independent checkpoints.

Layout on disk (one directory per step):

    <dir>/step_000100.tmp/...      — written here first
    <dir>/step_000100/             — atomic rename on completion
        manifest.json              — tree structure, shapes, dtypes, step
        arr_00000.npy ...          — one .npy per leaf (full, unsharded)

Properties required at pod scale (DESIGN.md §5):

  * **atomic** — a crash mid-write never corrupts the latest checkpoint
    (readers only ever see fully-renamed directories);
  * **async** — ``save_async`` snapshots to host memory synchronously
    (device->host copy) and writes in a background thread, so the train
    loop blocks only for the copy, not the disk;
  * **mesh-shape-independent** — leaves are stored unsharded; ``restore``
    re-shards onto ANY mesh via ``jax.device_put`` with the target
    NamedSharding: elastic up/down-scaling on restart;
  * **self-pruning** — keep the newest ``keep`` checkpoints.

On a real multi-host pod each host would write only the shards it owns
(process-local addressable_shards) — noted here; in this single-process
container full-array writes are exact.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_paths(tree: Pytree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(k), v) for k, v in flat], treedef


def save(dirpath: str, step: int, tree: Pytree, keep: int = 3) -> str:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    flat, _ = _flatten_with_paths(tree)
    host = [(k, np.asarray(v)) for k, v in flat]
    return _write(dirpath, step, tree, host, keep)


def save_async(dirpath: str, step: int, tree: Pytree, keep: int = 3) -> threading.Thread:
    """Device->host copy now; disk write in a daemon thread."""
    flat, _ = _flatten_with_paths(tree)
    host = [(k, np.asarray(v)) for k, v in flat]  # blocks on transfer only
    t = threading.Thread(
        target=_write, args=(dirpath, step, tree, host, keep), daemon=True
    )
    t.start()
    return t


def _write(dirpath, step, tree, host_leaves, keep) -> str:
    os.makedirs(dirpath, exist_ok=True)
    final = os.path.join(dirpath, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": []}
    for i, (key, arr) in enumerate(host_leaves):
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _prune(dirpath, keep)
    return final


def _prune(dirpath: str, keep: int):
    steps = sorted(list_steps(dirpath))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(dirpath, f"step_{s:08d}"), ignore_errors=True)


def list_steps(dirpath: str) -> List[int]:
    if not os.path.isdir(dirpath):
        return []
    out = []
    for name in os.listdir(dirpath):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(dirpath, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(dirpath: str) -> Optional[int]:
    steps = list_steps(dirpath)
    return steps[-1] if steps else None


def restore(
    dirpath: str,
    like: Pytree,
    step: Optional[int] = None,
    sharding_fn: Optional[Callable[[str, np.ndarray], Any]] = None,
) -> Tuple[Pytree, int]:
    """Restore into the structure of ``like``.  ``sharding_fn(key, arr)``
    may return a jax.sharding.Sharding to place each leaf (reshard-on-restore
    — the mesh NOW may differ from the mesh that saved).  Partially-written
    (.tmp) checkpoints are invisible by construction."""
    if step is None:
        step = latest_step(dirpath)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {dirpath}")
    path = os.path.join(dirpath, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = _flatten_with_paths(like)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    leaves = []
    for key, ref in flat_like:
        meta = by_key[key]
        arr = np.load(os.path.join(path, meta["file"]))
        if list(arr.shape) != list(ref.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {ref.shape}")
        if sharding_fn is not None:
            sh = sharding_fn(key, arr)
            leaves.append(jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
