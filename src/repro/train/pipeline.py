"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

Layers are split into S contiguous stages (one per pipe shard); a global
batch is cut into M microbatches that flow through stages with
``collective_permute`` handoffs.  Schedule: plain GPipe (fill, steady state,
drain — S+M-1 ticks); bubble fraction = (S-1)/(S+M-1).

Implementation notes
--------------------
* Everything runs inside one ``shard_map`` over the 'pipe' axis: each shard
  holds its stage's layer stack (leading n_layers/S axis) and scans over it.
* The tick loop is a ``lax.scan`` over S+M-1 ticks, carrying a rolling
  (M, ...) microbatch buffer; shard i computes real work only for ticks in
  [i, i+M) — selected by masks (no data-dependent control flow).
* The backward pass comes from jax.grad through the whole scan — the
  forward activations are rematerialized per-stage (jax.checkpoint around
  the stage body), which is exactly GPipe's activation recomputation.

This module is exercised by tests/test_pipeline.py at small scale and by the
pp variant configs in the dry-run; the default production mesh keeps
pipe=1 (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from repro import compat
from jax.sharding import Mesh, PartitionSpec as P

Pytree = Any


def stage_params(params_stacked: Pytree, stage: jax.Array, n_stages: int) -> Pytree:
    """Slice a (n_layers, ...) stacked layer tree to this stage's
    (n_layers/S, ...) block.  Runs inside shard_map."""

    def slc(x):
        per = x.shape[0] // n_stages
        return jax.lax.dynamic_slice_in_dim(x, stage * per, per, axis=0)

    return jax.tree_util.tree_map(slc, params_stacked)


def gpipe_apply(
    layer_fn: Callable[[jax.Array, Pytree], jax.Array],
    params_stacked: Pytree,  # (n_layers, ...) leaves, replicated or sharded
    x: jax.Array,            # (M, mb, ...) microbatched activations
    n_stages: int,
    axis: str = "pipe",
) -> jax.Array:
    """Run the pipeline inside shard_map over ``axis``; returns final
    activations (M, mb, ...) valid on the LAST stage (replicated out by the
    caller's out_spec or used directly for the loss there)."""
    stage = jax.lax.axis_index(axis)
    m = x.shape[0]
    my_layers = stage_params(params_stacked, stage, n_stages)

    def stage_body(h):
        def scan_layer(h, layer):
            return layer_fn(h, layer), None

        h, _ = jax.lax.scan(scan_layer, h, my_layers)
        return h

    stage_body = jax.checkpoint(stage_body)

    n_ticks = n_stages + m - 1
    first, last = stage == 0, stage == n_stages - 1

    def tick(carry, t):
        buf, out = carry  # buf: (M, mb, ...) input queue view; out: results
        mb_idx = t - stage  # which microbatch this stage works on at tick t
        active = (mb_idx >= 0) & (mb_idx < m)
        h_in = jax.lax.dynamic_index_in_dim(buf, jnp.clip(mb_idx, 0, m - 1), 0, keepdims=False)
        h_out = stage_body(h_in)
        h_out = jnp.where(active, h_out, h_in)
        # pass result to the next stage's buffer slot (ring permute).
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        h_next = jax.lax.ppermute(h_out, axis, perm)
        # Non-first stages overwrite their queue slot for microbatch t+1-stage.
        recv_idx = jnp.clip(mb_idx + 1, 0, m - 1)
        buf = jnp.where(
            first,
            buf,
            jax.lax.dynamic_update_index_in_dim(buf, h_next, recv_idx, 0),
        )
        out = jnp.where(
            last & active,
            jax.lax.dynamic_update_index_in_dim(out, h_out, jnp.clip(mb_idx, 0, m - 1), 0),
            out,
        )
        return (buf, out), None

    out0 = jnp.zeros_like(x)
    (buf, out), _ = jax.lax.scan(tick, (x, out0), jnp.arange(n_ticks))
    # Results live on the last stage only; broadcast so the out_spec's
    # "replicated" claim is true (one (M, mb, ...) all-reduce).
    return jax.lax.psum(jnp.where(last, out, jnp.zeros_like(out)), axis)


def build_gpipe_fn(
    mesh: Mesh,
    layer_fn: Callable[[jax.Array, Pytree], jax.Array],
    n_stages: int,
    axis: str = "pipe",
    batch_axes: Tuple[str, ...] = (),
):
    """shard_map wrapper: params replicated over 'pipe' (each stage slices
    its block), activations microbatched on the host side."""

    def fn(params_stacked, x):
        return gpipe_apply(layer_fn, params_stacked, x, n_stages, axis)

    in_specs = (P(), P(None, batch_axes if batch_axes else None))
    return compat.shard_map(
        fn, mesh=mesh, in_specs=in_specs,
        out_specs=P(None, batch_axes if batch_axes else None),
        check_vma=False,
    )


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_stages + n_micro - 1)
