"""Flat proximity-graph build + batched beam search (docs/DESIGN.md §15).

The fourth encoding: a single-layer Vamana-style navigable graph instead of
a literal multi-layer HNSW.  Both sides are expressed in fixed shapes so the
whole thing jits:

* Build: exact-kNN candidate pools (streamed in doc tiles, or exchanged
  around the shard ring under ``shard_map``), Vamana robust pruning
  (``alpha``-slack occlusion) down to ``degree`` forward edges, then a
  deterministic reverse-edge pass that fills ``reverse_degree`` extra slots
  (nearest sources first) so the graph stays navigable where forward
  pruning alone would strand nodes.

* Search: batched best-first beam search as a fixed-iteration
  ``lax.fori_loop``.  Two fixed-size lists per query ride the carry: the
  traversal list (raw scores — masked nodes stay traversable, preserving
  connectivity under filters) and the result list (filter bits applied, so
  masked nodes are never emitted).  The visited set is a dense (B, N) bool
  bitmap.  Neighbor blocks are gathered as one static (B, beam*degree)
  slab per iteration and scored through ``fused_topk_gathered`` on the
  kernel path (XLA einsum on the fallback path), so candidate scoring
  never leaves the fused machinery.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.types import GraphConfig
from repro.kernels.fused_topk import ops as fused_ops

NO_EDGE = jnp.int32(-1)
NEG_INF = jnp.float32(-jnp.inf)
_PRUNE_BLOCK = 4096  # rows robust-pruned per step: bounds the (nb, M, dim)
                     # candidate-vector gather that dominates build memory


# --------------------------------------------------------------------------
# Build: candidate pools
# --------------------------------------------------------------------------


def _merge_topk(run_s, run_i, blk_s, blk_i, m: int):
    """Merge a scored block into the running (., m) top-m lists."""
    s = jnp.concatenate([run_s, blk_s], axis=1)
    i = jnp.concatenate([run_i, blk_i], axis=1)
    top_s, pos = lax.top_k(s, m)
    return top_s, jnp.take_along_axis(i, pos, axis=1)


def _pool_step(v_rows, row_gids, block, block_gids, run_s, run_i, m: int):
    """Score ``v_rows`` against one candidate block and merge into the
    running exact-kNN pools (self-edges masked)."""
    s = v_rows @ block.T  # (n, nb)
    s = jnp.where(row_gids[:, None] == block_gids[None, :], NEG_INF, s)
    blk_i = jnp.broadcast_to(block_gids[None, :], s.shape)
    return _merge_topk(run_s, run_i, s, blk_i, m)


def _knn_pools(v_rows, row_gids, v_all, base_gid, m: int, tile: int):
    """(n, m) exact top-m cosine pools for ``v_rows`` against ``v_all``
    (global ids ``base_gid + arange``), streamed in doc tiles."""
    n = v_rows.shape[0]
    n_all = v_all.shape[0]
    run_s = jnp.full((n, m), NEG_INF, jnp.float32)
    run_i = jnp.full((n, m), NO_EDGE, jnp.int32)
    for t0 in range(0, n_all, tile):
        t1 = min(t0 + tile, n_all)
        gids = base_gid + jnp.arange(t0, t1, dtype=jnp.int32)
        run_s, run_i = _pool_step(
            v_rows, row_gids, v_all[t0:t1], gids, run_s, run_i, m)
    return run_s, run_i


# --------------------------------------------------------------------------
# Build: Vamana robust prune
# --------------------------------------------------------------------------


def _prune_block(vecs, cand_s, cand_i, v_all, degree: int, alpha: float):
    """Robust-prune one block of rows down to ``degree`` forward edges.

    Vamana's occlusion rule in cosine form (unit rows: d^2/2 = 1 - sim):
    after selecting s, candidate c is dropped when
    ``alpha * (1 - sim(s, c)) <= (1 - sim(row, c))`` — c is closer to an
    already-kept neighbor than to the row itself, up to the alpha slack.
    """
    nb, m = cand_i.shape
    cvecs = v_all[jnp.maximum(cand_i, 0)]  # (nb, m, dim)
    d_row = 1.0 - cand_s  # distance proxy row -> candidate
    rows = jnp.arange(nb)[:, None]

    def step(t, carry):
        alive, sel_s, sel_i = carry
        score = jnp.where(alive, cand_s, NEG_INF)
        best = jnp.max(score, axis=1)
        j = jnp.argmax(score, axis=1)  # (nb,)
        got = best > NEG_INF
        pick_i = jnp.where(got, jnp.take_along_axis(cand_i, j[:, None], 1)[:, 0], NO_EDGE)
        sel_i = sel_i.at[:, t].set(pick_i)
        sel_s = sel_s.at[:, t].set(jnp.where(got, best, NEG_INF))
        sel_vec = jnp.take_along_axis(cvecs, j[:, None, None], axis=1)[:, 0]
        sim_sel = jnp.einsum("bd,bmd->bm", sel_vec, cvecs)
        occluded = alpha * (1.0 - sim_sel) <= d_row
        alive = alive & ~(occluded & got[:, None])
        alive = alive.at[rows, j[:, None]].set(False)
        return alive, sel_s, sel_i

    alive0 = (cand_i >= 0) & (cand_s > NEG_INF)
    sel_s0 = jnp.full((nb, degree), NEG_INF, jnp.float32)
    sel_i0 = jnp.full((nb, degree), NO_EDGE, jnp.int32)
    _, sel_s, sel_i = lax.fori_loop(0, degree, step, (alive0, sel_s0, sel_i0))
    return sel_s, sel_i


def _prune_all(v_rows, cand_s, cand_i, v_all, degree: int, alpha: float):
    n = v_rows.shape[0]
    outs, outi = [], []
    for b0 in range(0, n, _PRUNE_BLOCK):
        b1 = min(b0 + _PRUNE_BLOCK, n)
        s, i = _prune_block(
            v_rows[b0:b1], cand_s[b0:b1], cand_i[b0:b1], v_all, degree, alpha)
        outs.append(s)
        outi.append(i)
    return jnp.concatenate(outs, 0), jnp.concatenate(outi, 0)


# --------------------------------------------------------------------------
# Build: reverse edges + entry points
# --------------------------------------------------------------------------


def _reverse_edges(fwd_i, fwd_s, n_total: int, r_rev: int):
    """(n_total, r_rev) reverse adjacency from the full forward lists.

    For every forward edge src->dst, dst gains a reverse slot pointing back
    at src; each node keeps its ``r_rev`` highest-scoring sources (ties by
    edge position, so the pass is deterministic).  Sort-based: no
    data-dependent shapes, safe under jit / shard_map.
    """
    n, rf = fwd_i.shape
    src = jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32)[:, None], (n, rf)).reshape(-1)
    dst = fwd_i.reshape(-1)
    score = fwd_s.reshape(-1)
    valid = dst >= 0
    # Stable two-pass lexsort: group by dst, best-scoring sources first.
    ord1 = jnp.argsort(-score)  # jax argsort is stable
    dst1 = jnp.where(valid[ord1], dst[ord1], jnp.int32(n_total))
    ord2 = jnp.argsort(dst1)
    order = ord1[ord2]
    sdst = dst1[ord2]
    ssrc = src[order]
    first = jnp.searchsorted(sdst, sdst, side="left")
    rank = jnp.arange(sdst.shape[0]) - first
    keep = (sdst < n_total) & (rank < r_rev)
    out = jnp.full((n_total, r_rev), NO_EDGE, jnp.int32)
    out = out.at[
        jnp.where(keep, sdst, jnp.int32(n_total)),
        jnp.where(keep, rank, 0),
    ].set(ssrc, mode="drop")
    return out


def _entry_points(v_all, n_entries: int):
    """Medoid (max dot with the corpus mean) + deterministic strided seeds."""
    n = v_all.shape[0]
    mean = jnp.mean(v_all, axis=0)
    medoid = jnp.argmax(v_all @ mean).astype(jnp.int32)
    k = min(n_entries, n)
    stride = max(1, n // max(1, k))
    seeds = (jnp.arange(1, n_entries, dtype=jnp.int32) * stride) % max(n, 1)
    return jnp.concatenate([medoid[None], seeds])


# --------------------------------------------------------------------------
# Build: local + sharded entry points
# --------------------------------------------------------------------------


def build_graph(v, config: GraphConfig):
    """Local (single-host) graph build: (neighbors (N, R) int32, entry)."""
    v = jnp.asarray(v, jnp.float32)
    n = v.shape[0]
    gids = jnp.arange(n, dtype=jnp.int32)
    m = min(config.ef_construction, max(1, n - 1))
    cand_s, cand_i = _knn_pools(v, gids, v, 0, m, config.build_tile)
    fwd_s, fwd_i = _prune_all(v, cand_s, cand_i, v, config.degree,
                              config.alpha)
    rev = _reverse_edges(fwd_i, fwd_s, n, config.reverse_degree)
    neighbors = jnp.concatenate([fwd_i, rev], axis=1)
    return neighbors, _entry_points(v, config.entries)


def build_graph_sharded(v_local, config: GraphConfig, axes, n_total: int):
    """Graph build inside ``shard_map``: neighbor-exchange rounds.

    Candidate pools circulate doc blocks around the shard ring
    (``ppermute``) so every shard scores its rows against the whole corpus
    one block at a time with GLOBAL ids; pruning gathers candidate vectors
    from an ``all_gather``-replicated copy (the pool phase never needs it
    resident, the prune phase does), and the reverse pass runs on the
    all-gathered forward lists so every shard computes the identical global
    answer and keeps its own row slice.  Matches the local build up to
    exact score ties (merge order differs).
    """
    v_local = jnp.asarray(v_local, jnp.float32)
    n_local = v_local.shape[0]
    n_shards = n_total // n_local
    flat = jnp.int32(0)
    for name in axes:
        flat = flat * lax.psum(1, name) + lax.axis_index(name)
    base = (flat * n_local).astype(jnp.int32)
    row_gids = base + jnp.arange(n_local, dtype=jnp.int32)
    m = min(config.ef_construction, max(1, n_total - 1))

    run_s = jnp.full((n_local, m), NEG_INF, jnp.float32)
    run_i = jnp.full((n_local, m), NO_EDGE, jnp.int32)
    if len(axes) == 1 and n_shards > 1:
        # Ring exchange: after step k every shard holds the block that
        # started (flat + k) shards to the right.
        perm = [(i, (i - 1) % n_shards) for i in range(n_shards)]
        block = v_local
        for step in range(n_shards):
            src = (flat + step) % n_shards
            src_base = (src * n_local).astype(jnp.int32)
            gids = src_base + jnp.arange(n_local, dtype=jnp.int32)
            run_s, run_i = _pool_step(
                v_local, row_gids, block, gids, run_s, run_i, m)
            if step + 1 < n_shards:
                block = lax.ppermute(block, axes[0], perm)
        v_all = lax.all_gather(v_local, axes, axis=0, tiled=True)
    else:
        # Multi-axis meshes (or a single shard): tile the gathered corpus.
        v_all = lax.all_gather(v_local, axes, axis=0, tiled=True)
        run_s, run_i = _knn_pools(
            v_local, row_gids, v_all, 0, m, config.build_tile)

    fwd_s, fwd_i = _prune_all(
        v_local, run_s, run_i, v_all, config.degree, config.alpha)
    fwd_i_all = lax.all_gather(fwd_i, axes, axis=0, tiled=True)
    fwd_s_all = lax.all_gather(fwd_s, axes, axis=0, tiled=True)
    rev_all = _reverse_edges(fwd_i_all, fwd_s_all, n_total,
                             config.reverse_degree)
    rev = lax.dynamic_slice(
        rev_all, (base, 0), (n_local, config.reverse_degree))
    neighbors = jnp.concatenate([fwd_i, rev], axis=1)
    return neighbors, _entry_points(v_all, config.entries)


# --------------------------------------------------------------------------
# Search: batched fixed-iteration beam traversal
# --------------------------------------------------------------------------


def _gather_bits(filt, ids):
    """(B, m) keep-bits for global ``ids`` (-1 = invalid) from a (N,) or
    (B, N) predicate bitmap."""
    safe = jnp.maximum(ids, 0)
    if filt.ndim == 1:
        bits = filt[safe]
    else:
        bits = jnp.take_along_axis(filt, safe, axis=1)
    return (bits != 0) & (ids >= 0)


def _dedup_block(ids, valid):
    """Drop later duplicates inside one gathered block (keeps the first
    valid occurrence) so no id can enter the lists twice per round."""
    m = ids.shape[1]
    eq = ids[:, :, None] == ids[:, None, :]  # (B, m, m): [., j, k]
    earlier = jnp.tril(jnp.ones((m, m), bool), k=-1)[None]
    dup = jnp.any(eq & earlier & valid[:, None, :], axis=2)
    return valid & ~dup


def _score_block(q, vectors, ids, valid, n_docs: int, use_kernel: bool):
    """Exact cosine scores for one gathered id block: (B, m) scores with
    invalid slots pinned to (-inf, -1)."""
    b, m = ids.shape
    safe = jnp.maximum(ids, 0)
    rows = vectors[safe]  # (B, m, dim)
    if use_kernel:
        row_ids = jnp.where(valid, ids, jnp.int32(n_docs))
        return fused_ops.fused_topk_gathered(
            q, rows, row_ids, depth=m, n_docs=n_docs)
    s = jnp.einsum("bd,bmd->bm", q, rows)
    s = jnp.where(valid & (ids < n_docs), s, NEG_INF)
    return s, jnp.where(s > NEG_INF, ids, NO_EDGE)


def search_graph(vectors, neighbors, entry, q, depth: int, *, ef: int,
                 beam: int, iters: int, n_docs: int, use_kernel: bool,
                 filt=None, with_stats: bool = False):
    """Batched best-first beam search over the flat graph.

    Two fixed-size lists per query: the TRAVERSAL list of ``ef`` raw-scored
    candidates (filter bits ignored, so masked nodes route the walk) and
    the RESULT list of ``depth`` filtered candidates (masked nodes pinned
    to (-inf, -1), never emitted).  Each of the ``iters`` iterations
    expands the best ``beam`` unexpanded traversal candidates, gathers
    their adjacency rows as one (B, beam*R) slab, dedups against the
    visited bitmap, scores the slab, and merges both lists.  Every shape
    is static, so the loop compiles once per (B, depth) and reuses the
    executable across query batches.
    """
    q = jnp.asarray(q, jnp.float32)
    b = q.shape[0]
    n = vectors.shape[0]
    r = neighbors.shape[1]
    m = beam * r
    brows = jnp.arange(b)[:, None]

    init_i = jnp.broadcast_to(entry[None, :].astype(jnp.int32),
                              (b, entry.shape[0]))
    init_valid = _dedup_block(init_i, init_i < n_docs)
    init_s, init_ids = _score_block(q, vectors, init_i, init_valid,
                                    n_docs, use_kernel)
    visited = jnp.zeros((b, n), bool).at[
        brows, jnp.maximum(init_i, 0)].max(init_valid)

    def _padded(s, i, width):
        pad = width - s.shape[1]
        if pad > 0:
            s = jnp.concatenate(
                [s, jnp.full((b, pad), NEG_INF, jnp.float32)], axis=1)
            i = jnp.concatenate(
                [i, jnp.full((b, pad), NO_EDGE, jnp.int32)], axis=1)
            return s, i
        top_s, pos = lax.top_k(s, width)
        return top_s, jnp.take_along_axis(i, pos, axis=1)

    def _masked(s, i):
        if filt is None:
            return s, i
        keep = _gather_bits(filt, i)
        return jnp.where(keep, s, NEG_INF), jnp.where(keep, i, NO_EDGE)

    cand_s, cand_i = _padded(init_s, init_ids, ef)
    cand_f = jnp.zeros((b, ef), bool)
    res_s, res_i = _padded(*_masked(init_s, init_ids), depth)
    scored = jnp.sum(init_valid, axis=1, dtype=jnp.int32)

    def body(_, carry):
        cand_s, cand_i, cand_f, res_s, res_i, visited, scored = carry
        avail = jnp.where((~cand_f) & (cand_i >= 0), cand_s, NEG_INF)
        pick_s, pos = lax.top_k(avail, beam)  # positions into the cand list
        live = pick_s > NEG_INF  # (b, beam)
        frontier = jnp.where(
            live, jnp.take_along_axis(cand_i, pos, axis=1), NO_EDGE)
        cand_f = cand_f.at[brows, pos].set(True)

        nbr = neighbors[jnp.maximum(frontier, 0)].reshape(b, m)
        valid = (nbr >= 0) & jnp.repeat(live, r, axis=1)
        seen = visited[brows, jnp.maximum(nbr, 0)]
        valid = _dedup_block(nbr, valid & ~seen)
        blk_s, blk_i = _score_block(q, vectors, nbr, valid, n_docs,
                                    use_kernel)
        visited = visited.at[brows, jnp.maximum(nbr, 0)].max(valid)
        scored = scored + jnp.sum(valid, axis=1, dtype=jnp.int32)

        new_s, new_i = _merge_topk(cand_s, cand_i, blk_s, blk_i, ef)
        # Expanded flags travel with the re-sort: redo the top-k gather on
        # the concatenated flag row (new entries start unexpanded).
        all_s = jnp.concatenate([cand_s, blk_s], axis=1)
        all_f = jnp.concatenate([cand_f, jnp.zeros((b, m), bool)], axis=1)
        _, fpos = lax.top_k(all_s, ef)
        cand_f = jnp.take_along_axis(all_f, fpos, axis=1)
        cand_s, cand_i = new_s, new_i

        mblk_s, mblk_i = _masked(blk_s, blk_i)
        res_s, res_i = _merge_topk(res_s, res_i, mblk_s, mblk_i, depth)
        return cand_s, cand_i, cand_f, res_s, res_i, visited, scored

    carry = (cand_s, cand_i, cand_f, res_s, res_i, visited, scored)
    carry = lax.fori_loop(0, iters, body, carry)
    res_s, res_i = carry[3], carry[4]
    res_s = jnp.where(res_i >= 0, res_s, NEG_INF)
    if with_stats:
        return res_s, res_i, carry[6]
    return res_s, res_i
