"""Pod-scale sharded ANN search (docs/DESIGN.md §5).

Lucene/Elasticsearch scale by sharding the inverted index across nodes: every
query fans out, each shard returns its local top-d, and a coordinator merges.
We reproduce that architecture with ``shard_map`` over the full device mesh:

  1. the corpus (tf matrix / signatures / reduced points + original vectors)
     is sharded over the flattened mesh axes on the document dimension;
  2. each shard scores locally (one GEMM over its slice) and takes a local
     top-d;
  3. *local exact rerank*: each shard recomputes exact cosine for its own
     candidates from its local original vectors - this keeps the rerank
     gather local (no cross-shard vector movement);
  4. one all-gather of (score, global_id) pairs - d*(4+4) bytes per shard,
     negligible next to the index scan - and a replicated global top-k.

The per-shard match phase runs the SAME stage objects as single-device
search (:mod:`repro.core.pipeline`): ``make_sharded_search`` builds the
method's matcher from its config and calls it on each shard's local index
slice, so every encoding — fake words, lexical LSH, k-d scan, brute force —
gets the fan-out/merge architecture from one code path.

Build is also distributed — for EVERY encoding (:func:`build_sharded`, the
pod entry of the staged ``core/builder.py`` BuildPipeline): fake-words and
LSH postings are row-parallel, document-frequency statistics ``psum`` so
idf matches a single-node build exactly, and the kd-tree reduction fits
from psum'd global moments so every shard holds the identical model while
its rows never leave the shard.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import pca
from repro.core import pipeline as pl
from repro.core.blockmax import BlockMaxIndex
from repro.core.types import (
    BruteForceConfig,
    FakeWordsConfig,
    FakeWordsIndex,
    FlatIndex,
    GraphConfig,
    GraphIndex,
    KdTreeConfig,
    KdTreeIndex,
    LexicalLshConfig,
    LshIndex,
    QuantizedStore,
)


def flat_axis_index(axes: Sequence[str]) -> jax.Array:
    """Row-major linear index of this shard over multiple mesh axes."""
    idx = jnp.int32(0)
    for name in axes:
        idx = idx * compat.axis_size(name) + jax.lax.axis_index(name)
    return idx


def flat_axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    size = 1
    for name in axes:
        size *= mesh.shape[name]
    return size


# --------------------------------------------------------------------------
# Sharding specs (document dimension) for every index type
# --------------------------------------------------------------------------


def _replicated_tree(model):
    """P() for every leaf of a nested reduction-model pytree."""
    return jax.tree_util.tree_map(lambda _: P(), model)


def _pspec_tree(
    kind: str,
    axes: Sequence[str],
    scored: bool = False,
    vectors: bool = True,
    reduction_spec=None,
    lifted: bool = True,
    vq: bool = False,
    tf: bool = True,
    pq=None,
):
    """The one place the per-type doc-dimension spec trees are written;
    :func:`index_pspec` / :func:`config_pspec` just derive the presence
    flags (from an instance or a config) and delegate here.

    ``pq`` is the spec placed at the quantized-postings slot: an exact
    :class:`QuantizedPostings` spec (from an instance, static metadata
    matching) or a bare prefix ``P`` that shard_map broadcasts over the
    q/scale leaves (from a config, where the packed column counts are not
    yet known)."""
    axes = tuple(axes)
    doc = P(axes, None)
    vec = doc if vectors else None
    # int8 rerank store: rows doc-sharded, per-doc scales shard with them.
    vqs = QuantizedStore(q=doc, scale=P(axes)) if vq else None
    if kind == "fake-words":
        return FakeWordsIndex(
            tf=doc if tf else None, idf=P(), norm=P(axes), df=P(),
            scored=doc if scored else None, vectors=vec, vq=vqs, pq=pq,
        )
    if kind == "lexical-lsh":
        return LshIndex(sig=doc, vectors=vec, vq=vqs)
    if kind == "kd-tree":
        return KdTreeIndex(
            reduced=doc, reduction=reduction_spec,
            lifted=doc if lifted else None, vectors=vec, vq=vqs,
        )
    if kind == "bruteforce":
        return FlatIndex(vectors=vec, vq=vqs, pq=pq)
    if kind == "hnsw":
        # Adjacency rows shard with the docs they belong to (neighbor ids
        # stay GLOBAL); the entry points are replicated like idf/df.
        return GraphIndex(vectors=doc, neighbors=doc, entry=P(), vq=vqs)
    raise ValueError(f"unknown index kind {kind!r}")


_TREE_BACKEND_MSG = (
    "kd-tree 'tree' backend cannot shard on documents; use backend='scan' "
    "(identical results, docs/DESIGN.md §3)"
)


def index_pspec(index, axes: Sequence[str]):
    """Doc-dimension sharding spec tree matching an index's present leaves.
    Works for every index type the pipeline serves."""
    doc = P(tuple(axes), None)
    if isinstance(index, FakeWordsIndex):
        return _pspec_tree(
            "fake-words", axes,
            scored=index.scored is not None,
            vectors=index.vectors is not None,
            vq=index.vq is not None,
            tf=index.tf is not None,
            pq=(
                dataclasses.replace(index.pq, q=doc, scale=doc)
                if index.pq is not None else None
            ),
        )
    if isinstance(index, LshIndex):
        return _pspec_tree(
            "lexical-lsh", axes, vectors=index.vectors is not None,
            vq=index.vq is not None,
        )
    if isinstance(index, KdTreeIndex):
        if index.split_dim is not None:
            raise ValueError(_TREE_BACKEND_MSG)
        return _pspec_tree(
            "kd-tree", axes,
            vectors=index.vectors is not None,
            reduction_spec=_replicated_tree(index.reduction),
            lifted=index.lifted is not None,
            vq=index.vq is not None,
        )
    if isinstance(index, FlatIndex):
        return _pspec_tree(
            "bruteforce", axes,
            vectors=index.vectors is not None,
            vq=index.vq is not None,
            pq=(
                dataclasses.replace(index.pq, q=doc, scale=doc)
                if index.pq is not None else None
            ),
        )
    if isinstance(index, GraphIndex):
        return _pspec_tree("hnsw", axes, vq=index.vq is not None)
    raise TypeError(f"unknown index {type(index)}")


def config_pspec(
    config,
    axes: Sequence[str],
    keep_vectors: bool = True,
    quantized_store: bool = False,
    postings_bits: int = 0,
):
    """Spec tree from a method config (when no index instance is at hand —
    e.g. dryrun cells that eval_shape through the sharded search).
    ``quantized_store`` marks the int8 rerank store present (built with
    ``rerank_store='int8'``, in which case fp32 vectors are absent).
    ``postings_bits`` (0 | 8 | 4) marks the primary postings encoding
    (docs/DESIGN.md §12); the packed-postings spec is a bare prefix ``P``
    since the packed column counts depend on the data dims."""
    doc = P(tuple(axes), None)
    if isinstance(config, FakeWordsConfig):
        # dot-int8 stores quantized tf natively (no separate pq leaf);
        # classic quantizes `scored` away; dot-int4 packs tf away.
        quant = postings_bits > 0 and (
            config.scoring == "classic" or postings_bits == 4
        )
        return _pspec_tree(
            "fake-words", axes,
            scored=config.scoring == "classic" and postings_bits == 0,
            vectors=keep_vectors,
            vq=quantized_store,
            tf=not (config.scoring == "dot" and postings_bits == 4),
            pq=doc if quant else None,
        )
    if isinstance(config, LexicalLshConfig):
        return _pspec_tree(
            "lexical-lsh", axes, vectors=keep_vectors, vq=quantized_store
        )
    if isinstance(config, KdTreeConfig):
        if config.backend == "tree":
            raise ValueError(_TREE_BACKEND_MSG)
        red = (
            pca.PcaModel(mean=P(), components=P())
            if config.reduction == "pca"
            else pca.PpaPcaPpaModel(
                ppa1=pca.PpaModel(mean=P(), top=P()),
                pca=pca.PcaModel(mean=P(), components=P()),
                ppa2=pca.PpaModel(mean=P(), top=P()),
            )
        )
        return _pspec_tree(
            "kd-tree", axes, vectors=keep_vectors, reduction_spec=red,
            vq=quantized_store,
        )
    if isinstance(config, BruteForceConfig):
        # fp32 vectors stay unless quantized postings replace them and no
        # exact rerank store asked to keep them (mirrors FlatPostings).
        return _pspec_tree(
            "bruteforce", axes,
            vectors=postings_bits == 0 or keep_vectors,
            vq=quantized_store,
            pq=doc if postings_bits > 0 else None,
        )
    if isinstance(config, GraphConfig):
        # The unit rows are the match operand: always present (like the
        # brute-force store), whatever the rerank-store choice.
        return _pspec_tree("hnsw", axes, vq=quantized_store)
    raise TypeError(f"unknown config {type(config)}")


# --------------------------------------------------------------------------
# Distributed build
# --------------------------------------------------------------------------


def build_sharded(
    mesh: Mesh,
    vectors: jax.Array,
    config,
    axes: Sequence[str],
    keep_vectors: bool = True,
    rerank_store: Optional[str] = None,
    primary_postings: str = "fp32",
    postings_group: int = 32,
):
    """Build ANY encoding's index with its doc-sharded leaves distributed
    over ``axes`` — the pod-scale entry of the staged
    :class:`repro.core.builder.BuildPipeline` (docs/DESIGN.md §8).

    Fake-words and LSH postings are embarrassingly row-parallel; the k-d
    tree's reduction fits from psum'd global moments so every shard holds
    the identical (replicated) model; global statistics (df -> idf) psum.
    No stage materializes the full corpus on any shard, and the result
    matches :func:`repro.core.builder.BuildPipeline.build_local`
    bit-for-bit (fp-tolerance for the eigendecomposed reduction).

    ``rerank_store``: "exact" | "int8" | "none" (None derives from
    ``keep_vectors``).  ``primary_postings``: "fp32" | "int8" | "int4" —
    the packed primary-postings encoding, quantized row-locally per shard
    (bitwise identical to the single-node build; docs/DESIGN.md §12)."""
    from repro.core import builder

    if rerank_store is None:
        rerank_store = "exact" if keep_vectors else "none"
    bp = builder.make_build_pipeline(
        config, rerank_store, primary_postings, postings_group
    )
    return bp.build_sharded(mesh, vectors, tuple(axes))


def build_fakewords_sharded(
    mesh: Mesh,
    vectors: jax.Array,
    config: FakeWordsConfig,
    axes: Sequence[str],
    keep_vectors: bool = True,
) -> FakeWordsIndex:
    """Deprecated alias: the fake-words special case of the generic
    :func:`build_sharded` (kept for callers of the pre-BuildPipeline
    API)."""
    return build_sharded(mesh, vectors, config, axes, keep_vectors)


# --------------------------------------------------------------------------
# Distributed search
# --------------------------------------------------------------------------


def make_sharded_search(
    mesh: Mesh,
    config,
    axes: Sequence[str],
    k: int = 10,
    depth: int = 100,
    rerank: bool = True,
    keep_vectors: bool = True,
    score_tile: int = 262_144,
    tile_unroll: bool = False,
    use_kernel: Optional[bool] = None,
    blockmax_keep: Optional[int] = None,
    rerank_store: Optional[str] = None,
    postings_bits: int = 0,
    filtered: bool = False,
):
    """Returns a jit-able ``search(index, q_rep, queries) -> (scores, ids)``
    closed over the mesh, for ANY method config (fake words / lexical LSH /
    kd-scan / brute force).  ``index`` leaves must be doc-sharded (see
    :func:`shard_index` / :func:`build_fakewords_sharded`); ``q_rep`` is the
    method's replicated query representation (encode outside the mesh with
    ``AnnIndex.encode_queries`` or the pipeline's encoder).

    The local match phase IS the method's pipeline matcher stage
    (:func:`repro.core.pipeline.make_matcher`) running on each shard's local
    slice: with ``use_kernel`` (the default on TPU) that's the fused
    streaming score->top-k Pallas kernel (docs/DESIGN.md §4); otherwise the
    XLA realization, which for fake-words shards larger than ``score_tile``
    docs streams tile-by-tile with a running top-d merge.

    With ``blockmax_keep`` set (fake-words / LSH), the returned callable
    becomes ``search(index, bm, q_rep, queries)`` (``bm`` built by
    ``blockmax.build_blockmax`` and placed by :func:`shard_blockmax`): each
    shard runs the two-stage pruned match through the
    :class:`repro.core.pipeline.BlockMaxMatcher` stage — bound pass over its
    local block upper bounds, then exact scoring of the kept blocks through
    the fused gathered streaming top-k kernel — so the pod also gets the
    ~(1 - beta) scan-byte cut.  The df-prune mask is not applied on this
    path (like the single-node ``pruned_search``).

    ``rerank_store`` ("exact" | "int8" | "none"; None derives from
    ``keep_vectors``) must name the store the index was built with: with
    "int8" the local rerank gathers from the int8
    :class:`repro.core.types.QuantizedStore` (~4x fewer HBM gather bytes
    per shard, docs/DESIGN.md §8) instead of the fp32 originals.

    ``filtered=True`` appends a trailing ``filt`` argument — a (N,) per-doc
    predicate bitmap (nonzero = keep) sharded WITH the postings on the doc
    dimension (``P(axes)``): each shard slices its own bits and threads
    them into the matcher's single in-kernel filtered pass
    (docs/DESIGN.md §13), so the bitmap never replicates and no
    cross-shard traffic is added beyond the existing (score, id) gather."""
    axes = tuple(axes)
    from repro.kernels.fused_topk import ops as fused

    if isinstance(config, GraphConfig):
        raise TypeError(
            "graph search cannot run shard-local: adjacency edges cross "
            "shard boundaries, so per-shard traversal + merge is not the "
            "same algorithm.  Serve graphs segmented "
            "(SegmentedAnnIndex) or single-device; the sharded BUILD "
            "(build_sharded) is supported and returns doc-sharded leaves "
            "you can all-gather onto one device."
        )
    if rerank_store is None:
        rerank_store = "exact" if keep_vectors else "none"
    if rerank and rerank_store == "none" and not isinstance(config, BruteForceConfig):
        raise ValueError("rerank=True needs rerank_store 'exact' or 'int8'")
    kernel_local = fused.resolve_use_kernel(use_kernel)
    matcher = pl.make_matcher(config, score_tile=score_tile, tile_unroll=tile_unroll)

    def merge_global(index, loc_s, loc_i, queries):
        shard = flat_axis_index(axes)
        n_local = index.num_docs
        valid = loc_i >= 0
        if rerank:
            # Exact rerank against the *local* store — fp32 originals or the
            # int8 quantized store — so there is no cross-shard vector
            # movement.  -1 padding slots would otherwise gather doc 0 and
            # earn a real cosine score; candidate_scores masks them to -inf.
            loc_s = pl.candidate_scores(
                index, queries, loc_i, quantized=rerank_store == "int8"
            )
        # Invalid slots keep id -1 (never ``-1 + shard * n_local``).
        glob_i = jnp.where(valid, loc_i + shard * n_local, -1)
        # Tiny collective: d*(score,id) per shard.
        all_s = jax.lax.all_gather(loc_s, axes, axis=1, tiled=True)
        all_i = jax.lax.all_gather(glob_i, axes, axis=1, tiled=True)
        top_s, pos = jax.lax.top_k(all_s, k)
        top_i = jnp.take_along_axis(all_i, pos, axis=-1)
        return top_s, top_i

    def local_search(index, q_rep, queries, filt=None):
        loc_s, loc_i = matcher(
            index, q_rep, depth, use_kernel=kernel_local, filt=filt
        )
        return merge_global(index, loc_s, loc_i, queries)

    def local_search_blockmax(index, bm, q_rep, queries, filt=None):
        n_keep = min(blockmax_keep, bm.num_blocks)
        # Cap on gathered candidates, NOT n_local: a ragged shard whose kept
        # blocks carry padded rows legitimately returns -1 slots when depth
        # exceeds its valid candidate count (merge_global masks them).
        d_local = min(depth, n_keep * bm.block_size)
        loc_s, loc_i = pl.BlockMaxMatcher(n_keep=n_keep)(
            index, q_rep, d_local, bm=bm, use_kernel=kernel_local, filt=filt
        )
        return merge_global(index, loc_s, loc_i, queries)

    index_spec = config_pspec(
        config, axes,
        keep_vectors=rerank_store == "exact",
        quantized_store=rerank_store == "int8",
        postings_bits=postings_bits,
    )
    if blockmax_keep is not None:
        # Prefix spec: BlockMaxIndex's one array leaf (ub) shards on the
        # block dimension; its block_size/mode are static metadata.
        in_specs = (index_spec, P(axes, None), P(), P())
        body = local_search_blockmax
    else:
        in_specs = (index_spec, P(), P())
        body = local_search
    if filtered:
        # The (N,) bitmap shards exactly like the doc rows it annotates.
        in_specs = in_specs + (P(axes),)
    # After the full all-gather + top_k the outputs are bitwise-replicated,
    # but the static VMA checker cannot prove it; disable the check.
    fn = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(fn)


def build_blockmax_sharded(
    mesh: Mesh,
    index,
    axes: Sequence[str],
    block_size: int = 256,
    mode: Optional[str] = None,
    signed_store: bool = False,
) -> BlockMaxIndex:
    """Per-shard block upper bounds over an already-sharded index
    (fake-words or LSH).

    Each shard blocks ITS OWN doc range (padding its last block locally), so
    local block ids always line up with local doc rows and no global
    ``n_local % block_size`` alignment is required — a shard whose doc count
    is ragged against the block size simply carries out-of-range row ids in
    its padded tail, which the pruned stage-2 masks to (-inf, -1)."""
    from repro.core import blockmax as bmx

    axes = tuple(axes)

    def local_build(idx) -> BlockMaxIndex:
        return bmx.build_blockmax(
            idx, block_size, mode=mode, signed_store=signed_store
        )

    fn = compat.shard_map(
        local_build,
        mesh=mesh,
        in_specs=(index_pspec(index, axes),),
        out_specs=P(axes, None),  # prefix: the one array leaf (ub)
    )
    return fn(index)


def shard_blockmax(
    mesh: Mesh, bm: BlockMaxIndex, axes: Sequence[str]
) -> BlockMaxIndex:
    """Place block upper bounds onto the mesh, block rows sharded like the
    doc dimension.  Blocks must not straddle shards: the local doc count has
    to be a multiple of ``block_size`` (then global block b lives exactly on
    shard ``b // n_blocks_local`` and local block ids line up with local doc
    rows)."""
    axes = tuple(axes)
    n_shards = flat_axis_size(mesh, axes)
    assert bm.ub.shape[0] % n_shards == 0, (
        f"{bm.ub.shape[0]} blocks not divisible by {n_shards} shards "
        "(need n_local % block_size == 0)"
    )
    return BlockMaxIndex(
        ub=jax.device_put(bm.ub, NamedSharding(mesh, P(axes, None))),
        block_size=bm.block_size,
        mode=bm.mode,
    )


def shard_index(mesh: Mesh, index, axes: Sequence[str]):
    """Place a host-built index (any type) onto the mesh with doc-dimension
    sharding; replicated stats / reduction models stay replicated."""
    specs = index_pspec(index, tuple(axes))
    return jax.tree_util.tree_map(
        lambda s, x: jax.device_put(x, NamedSharding(mesh, s)),
        specs,
        index,
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------------------
# Packed segmented search over a pod (docs/DESIGN.md §14)
# --------------------------------------------------------------------------


def make_packed_segmented_search(
    mesh: Mesh,
    reader,
    axes: Sequence[str],
    k: int = 10,
    depth: int = 100,
    rerank: bool = False,
    filter_mask=None,
    score_tile: int = 262_144,
    use_kernel: Optional[bool] = None,
):
    """Compose the packed single-launch segmented path with the pod
    fan-out: pack a :class:`repro.core.segments.SegmentedAnnIndex`
    snapshot into its superbuffer (``core/packed.py``), doc-shard the
    packed leaves over ``axes``, and serve through
    :func:`make_sharded_search`'s filtered path with the composed
    liveDocs ∧ row-validity [∧ predicate] bitmap sharded WITH the rows.

    The packed layout concatenates segments in global-id order, so packed
    row g IS global doc id g — and ``make_sharded_search`` emits
    ``local row + shard_offset``, so the pod returns the reader's global
    doc ids with no remap.  ``filter_mask`` is the same (max_doc,)
    global-id predicate bitmap ``SegmentedAnnIndex.search`` takes.

    Returns ``(search_fn, sharded_index, sharded_filt)``; call as
    ``search_fn(sharded_index, q_rep, queries, sharded_filt)`` with
    ``q_rep = reader.encode_queries(queries)``.
    """
    from repro.core import packed as packed_mod

    axes = tuple(axes)
    pk = reader.packed_segments()
    if pk is None:
        raise ValueError(
            "packed single-launch path unavailable for this snapshot: "
            f"{reader._packed_err}"
        )
    n_shards = flat_axis_size(mesh, axes)
    if pk.bucket % n_shards:
        raise ValueError(
            f"packed bucket {pk.bucket} rows not divisible by {n_shards} "
            "shards; choose a mesh whose flattened size divides the "
            "bucket ladder rung"
        )
    view = pk.view
    if reader.quantized_rerank:
        rerank_store = "int8"
    elif getattr(view, "vectors", None) is not None:
        rerank_store = "exact"
    else:
        rerank_store = "none"
    pq = getattr(view, "pq", None)
    search_fn = make_sharded_search(
        mesh, reader.config, axes, k=k, depth=depth, rerank=rerank,
        score_tile=score_tile, use_kernel=use_kernel,
        rerank_store=rerank_store,
        postings_bits=0 if pq is None else pq.bits,
        filtered=True,
    )
    filt = pk.live
    if filter_mask is not None:
        fm = jnp.asarray(filter_mask)
        if fm.ndim != 1 or fm.shape[0] != reader.max_doc:
            raise ValueError(
                "pod-sharded filtering takes a (max_doc,) per-doc bitmap "
                f"(got shape {fm.shape}, max_doc={reader.max_doc})"
            )
        filt = filt & packed_mod._pad_mask_cols(fm, pk.bucket)
    sharded_index = shard_index(mesh, view, axes)
    sharded_filt = jax.device_put(filt, NamedSharding(mesh, P(axes)))
    return search_fn, sharded_index, sharded_filt
