"""Pod-scale sharded ANN search (docs/DESIGN.md §5).

Lucene/Elasticsearch scale by sharding the inverted index across nodes: every
query fans out, each shard returns its local top-d, and a coordinator merges.
We reproduce that architecture with ``shard_map`` over the full device mesh:

  1. the corpus (tf matrix / signatures / reduced points + original vectors)
     is sharded over the flattened mesh axes on the document dimension;
  2. each shard scores locally (one GEMM over its slice) and takes a local
     top-d;
  3. *local exact rerank*: each shard recomputes exact cosine for its own
     candidates from its local original vectors - this keeps the rerank
     gather local (no cross-shard vector movement);
  4. one all-gather of (score, global_id) pairs - d*(4+4) bytes per shard,
     negligible next to the index scan - and a replicated global top-k.

Build is also distributed: document-frequency statistics are ``psum``-ed so
idf matches a single-node build exactly.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import bruteforce, fakewords
from repro.core.blockmax import BlockMaxIndex
from repro.core.types import FakeWordsConfig, FakeWordsIndex


def flat_axis_index(axes: Sequence[str]) -> jax.Array:
    """Row-major linear index of this shard over multiple mesh axes."""
    idx = jnp.int32(0)
    for name in axes:
        idx = idx * compat.axis_size(name) + jax.lax.axis_index(name)
    return idx


def flat_axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    size = 1
    for name in axes:
        size *= mesh.shape[name]
    return size


# --------------------------------------------------------------------------
# Distributed build
# --------------------------------------------------------------------------


def build_fakewords_sharded(
    mesh: Mesh,
    vectors: jax.Array,
    config: FakeWordsConfig,
    axes: Sequence[str],
    keep_vectors: bool = True,
) -> FakeWordsIndex:
    """Build a FakeWordsIndex whose doc-sharded leaves live distributed over
    ``axes``; idf/df are computed globally (psum) and replicated."""
    axes = tuple(axes)
    n_shards = flat_axis_size(mesh, axes)
    n = vectors.shape[0]
    assert n % n_shards == 0, f"corpus size {n} not divisible by {n_shards} shards"

    def local_build(v):
        v = bruteforce.l2_normalize(v)
        tf = fakewords.encode(v, config.quantization, config.store_dtype)
        df_local = jnp.sum(tf > 0, axis=0).astype(jnp.int32)
        df = jax.lax.psum(df_local, axes)
        idf = 1.0 + jnp.log(n / (df.astype(jnp.float32) + 1.0))
        doc_len = jnp.sum(tf.astype(jnp.float32), axis=-1)
        norm = jax.lax.rsqrt(jnp.maximum(doc_len, 1.0))
        scored = None
        if config.scoring == "classic":
            scored = (
                jnp.sqrt(tf.astype(jnp.float32)) * (idf**2)[None, :] * norm[:, None]
            ).astype(jnp.bfloat16)
        return FakeWordsIndex(
            tf=tf,
            idf=idf,
            norm=norm,
            df=df,
            scored=scored,
            vectors=v if keep_vectors else None,
        )

    out_specs = FakeWordsIndex(
        tf=P(axes, None),
        idf=P(),
        norm=P(axes),
        df=P(),
        scored=P(axes, None) if config.scoring == "classic" else None,
        vectors=P(axes, None) if keep_vectors else None,
    )
    fn = compat.shard_map(
        local_build, mesh=mesh, in_specs=P(axes, None), out_specs=out_specs
    )
    return fn(vectors)


# --------------------------------------------------------------------------
# Distributed search
# --------------------------------------------------------------------------


def _local_topk_tiled(
    score_tile_fn, n_local: int, batch: int, depth: int, tile: int,
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Streaming local top-d: score ``tile`` docs at a time and merge into a
    running (B, depth) best set.  The (B, n_local) score matrix never
    materializes in HBM — the index scan streams at full bandwidth (§Perf
    iteration C2: cuts the cell's HBM traffic ~2.7x at web1b scale).

    score_tile_fn(start) -> (B, tile) scores for docs [start, start+tile).
    """
    n_tiles = -(-n_local // tile)
    d = min(depth, tile)
    init_s = jnp.full((batch, depth), -jnp.inf, jnp.float32)
    init_i = jnp.full((batch, depth), -1, jnp.int32)

    def body(carry, t_idx):
        best_s, best_i = carry
        start = t_idx * tile
        s = score_tile_fn(start).astype(jnp.float32)  # (B, tile)
        ids = start + jnp.arange(tile, dtype=jnp.int32)[None, :]
        valid = ids < n_local
        s = jnp.where(valid, s, -jnp.inf)
        loc_s, pos = jax.lax.top_k(s, d)
        loc_i = jnp.take_along_axis(jnp.broadcast_to(ids, s.shape), pos, axis=-1)
        all_s = jnp.concatenate([best_s, loc_s], axis=-1)
        all_i = jnp.concatenate([best_i, loc_i], axis=-1)
        top_s, top_pos = jax.lax.top_k(all_s, depth)
        return (top_s, jnp.take_along_axis(all_i, top_pos, axis=-1)), None

    (best_s, best_i), _ = jax.lax.scan(
        body, (init_s, init_i), jnp.arange(n_tiles, dtype=jnp.int32),
        unroll=unroll,  # analysis mode: HLO cost analysis counts a while
        #                 body once; roofline lowers the unrolled loop
    )
    return best_s, best_i


def _kernel_query_and_docs(index: FakeWordsIndex, q_tf, config: FakeWordsConfig):
    """Per-scoring-mode (query tile, stored matrix) operands for the fused
    streaming top-k kernel, keep-mask folded into the query."""
    if config.scoring == "classic":
        return fakewords.classic_query(index, q_tf, config.df_max_ratio), index.scored
    if config.signed_store:
        # index.tf holds the SIGNED (N, m) matrix; fold the sign-split keep
        # mask down to m terms.
        keep = fakewords.df_prune_mask(
            index.df, index.num_docs, config.df_max_ratio)
        m = index.tf.shape[1]
        keep_m = keep[:m] & keep[m:] if keep.shape[0] == 2 * m else keep[:m]
        qv = (fakewords.signed_query(q_tf) * keep_m).astype(jnp.int8)
        return qv, index.tf
    return fakewords.dot_query(
        index, q_tf, config.df_max_ratio, dtype=jnp.int8), index.tf


def make_sharded_search(
    mesh: Mesh,
    config: FakeWordsConfig,
    axes: Sequence[str],
    k: int = 10,
    depth: int = 100,
    rerank: bool = True,
    keep_vectors: bool = True,
    score_tile: int = 262_144,
    tile_unroll: bool = False,
    use_kernel: Optional[bool] = None,
    blockmax_keep: Optional[int] = None,
):
    """Returns a jit-able ``search(index, q_tf, queries) -> (scores, ids)``
    closed over the mesh.  ``index`` leaves must be sharded as produced by
    :func:`build_fakewords_sharded`; queries are replicated.

    The local match phase has three realizations: with ``use_kernel`` (the
    default on TPU) every shard runs the fused streaming score->top-k Pallas
    kernel (docs/DESIGN.md §4) — the index streams HBM->VMEM once and only
    (B, d) survives; otherwise shards larger than ``score_tile`` docs stream
    tile-by-tile with an XLA running top-d merge, and small shards fall back
    to the dense GEMM + top_k reference.

    With ``blockmax_keep`` set, the returned callable becomes
    ``search(index, bm, q_tf, queries)`` (``bm`` built by
    ``blockmax.build_blockmax`` and placed by :func:`shard_blockmax`): each
    shard runs the two-stage pruned match — bound pass over its local block
    upper bounds, then exact scoring of the kept blocks through the fused
    gathered streaming top-k kernel — so the pod also gets the ~(1 - beta)
    scan-byte cut.  The df-prune mask is not applied on this path (like the
    single-node ``pruned_search``)."""
    axes = tuple(axes)
    from repro.core import blockmax as bmx
    from repro.kernels.fused_topk import ops as fused

    kernel_local = fused.resolve_use_kernel(use_kernel)

    def dense_match(index: FakeWordsIndex, q_tf):
        n_local = index.tf.shape[0]
        d_local = min(depth, n_local)
        if kernel_local:
            qv, docs = _kernel_query_and_docs(index, q_tf, config)
            return fused.fused_topk(qv, docs, d_local)
        if n_local > 2 * score_tile:
            qv, docs = _kernel_query_and_docs(index, q_tf, config)
            if config.scoring == "classic":
                def tile_scores(start):
                    rows = jax.lax.dynamic_slice_in_dim(
                        docs, start, score_tile, axis=0)
                    return jnp.einsum("bt,nt->bn", qv, rows,
                                      preferred_element_type=jnp.float32)
            else:
                qv = qv.astype(jnp.int32)

                def tile_scores(start):
                    rows = jax.lax.dynamic_slice_in_dim(
                        docs, start, score_tile, axis=0)
                    return jnp.einsum(
                        "bt,nt->bn", qv, rows.astype(jnp.int32),
                        preferred_element_type=jnp.int32)

            return _local_topk_tiled(
                tile_scores, n_local, q_tf.shape[0], d_local, score_tile,
                unroll=tile_unroll)
        if config.scoring == "classic":
            scores = fakewords.classic_scores(index, q_tf, config.df_max_ratio)
        else:
            scores = fakewords.dot_scores(index, q_tf, config.df_max_ratio)
        return jax.lax.top_k(scores, d_local)  # (B, d_local)

    def merge_global(index: FakeWordsIndex, loc_s, loc_i, queries):
        shard = flat_axis_index(axes)
        n_local = index.tf.shape[0]
        valid = loc_i >= 0
        if rerank:
            # Exact rerank against *local* originals: no cross-shard gather.
            # -1 padding slots would otherwise gather doc 0 and earn a real
            # cosine score; mask them back to -inf.
            cand = index.vectors[jnp.maximum(loc_i, 0)]  # (B, d_local, dim)
            loc_s = jnp.einsum("bd,bcd->bc", queries, cand)
            loc_s = jnp.where(valid, loc_s, -jnp.inf)
        # Invalid slots keep id -1 (never ``-1 + shard * n_local``).
        glob_i = jnp.where(valid, loc_i + shard * n_local, -1)
        # Tiny collective: d*(score,id) per shard.
        all_s = jax.lax.all_gather(loc_s, axes, axis=1, tiled=True)
        all_i = jax.lax.all_gather(glob_i, axes, axis=1, tiled=True)
        top_s, pos = jax.lax.top_k(all_s, k)
        top_i = jnp.take_along_axis(all_i, pos, axis=-1)
        return top_s, top_i

    def local_search(index: FakeWordsIndex, q_tf, queries):
        loc_s, loc_i = dense_match(index, q_tf)
        return merge_global(index, loc_s, loc_i, queries)

    def local_search_blockmax(index: FakeWordsIndex, bm, q_tf, queries):
        n_keep = min(blockmax_keep, bm.num_blocks)
        # Cap on gathered candidates, NOT n_local: a ragged shard whose kept
        # blocks carry padded rows legitimately returns -1 slots when depth
        # exceeds its valid candidate count (merge_global masks them).
        d_local = min(depth, n_keep * bm.block_size)
        loc_s, loc_i = bmx.pruned_topk(
            index, bm, q_tf, n_keep, d_local, use_kernel=kernel_local)
        return merge_global(index, loc_s, loc_i, queries)

    index_spec = FakeWordsIndex(
        tf=P(axes, None),
        idf=P(),
        norm=P(axes),
        df=P(),
        scored=P(axes, None) if config.scoring == "classic" else None,
        vectors=P(axes, None) if keep_vectors else None,
    )
    if blockmax_keep is not None:
        # Prefix spec: BlockMaxIndex's one array leaf (ub) shards on the
        # block dimension; its block_size/mode are static metadata.
        in_specs = (index_spec, P(axes, None), P(), P())
        body = local_search_blockmax
    else:
        in_specs = (index_spec, P(), P())
        body = local_search
    # After the full all-gather + top_k the outputs are bitwise-replicated,
    # but the static VMA checker cannot prove it; disable the check.
    fn = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(fn)


def _index_pspec(index: FakeWordsIndex, axes: Sequence[str]) -> FakeWordsIndex:
    """Doc-dimension sharding spec tree matching an index's present leaves."""
    axes = tuple(axes)
    return FakeWordsIndex(
        tf=P(axes, None),
        idf=P(),
        norm=P(axes),
        df=P(),
        scored=P(axes, None) if index.scored is not None else None,
        vectors=P(axes, None) if index.vectors is not None else None,
    )


def build_blockmax_sharded(
    mesh: Mesh,
    index: FakeWordsIndex,
    axes: Sequence[str],
    block_size: int = 256,
    mode: Optional[str] = None,
    signed_store: bool = False,
) -> BlockMaxIndex:
    """Per-shard block upper bounds over an already-sharded index.

    Each shard blocks ITS OWN doc range (padding its last block locally), so
    local block ids always line up with local doc rows and no global
    ``n_local % block_size`` alignment is required — a shard whose doc count
    is ragged against the block size simply carries out-of-range row ids in
    its padded tail, which the pruned stage-2 masks to (-inf, -1)."""
    from repro.core import blockmax as bmx

    axes = tuple(axes)

    def local_build(idx: FakeWordsIndex) -> BlockMaxIndex:
        return bmx.build_blockmax(
            idx, block_size, mode=mode, signed_store=signed_store
        )

    fn = compat.shard_map(
        local_build,
        mesh=mesh,
        in_specs=(_index_pspec(index, axes),),
        out_specs=P(axes, None),  # prefix: the one array leaf (ub)
    )
    return fn(index)


def shard_blockmax(
    mesh: Mesh, bm: BlockMaxIndex, axes: Sequence[str]
) -> BlockMaxIndex:
    """Place block upper bounds onto the mesh, block rows sharded like the
    doc dimension.  Blocks must not straddle shards: the local doc count has
    to be a multiple of ``block_size`` (then global block b lives exactly on
    shard ``b // n_blocks_local`` and local block ids line up with local doc
    rows)."""
    axes = tuple(axes)
    n_shards = flat_axis_size(mesh, axes)
    assert bm.ub.shape[0] % n_shards == 0, (
        f"{bm.ub.shape[0]} blocks not divisible by {n_shards} shards "
        "(need n_local % block_size == 0)"
    )
    return BlockMaxIndex(
        ub=jax.device_put(bm.ub, NamedSharding(mesh, P(axes, None))),
        block_size=bm.block_size,
        mode=bm.mode,
    )


def shard_index(mesh: Mesh, index: FakeWordsIndex, axes: Sequence[str]) -> FakeWordsIndex:
    """Place a host-built index onto the mesh with doc-dimension sharding."""
    axes = tuple(axes)

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec)) if x is not None else None

    return FakeWordsIndex(
        tf=put(index.tf, P(axes, None)),
        idf=put(index.idf, P()),
        norm=put(index.norm, P(axes)),
        df=put(index.df, P()),
        scored=put(index.scored, P(axes, None)),
        vectors=put(index.vectors, P(axes, None)),
    )
