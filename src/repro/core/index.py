"""AnnIndex facade: one entry point over the three paper encodings.

    idx = AnnIndex.build(vectors, FakeWordsConfig(quantization=50))
    scores, ids = idx.search(queries, k=10, depth=100, rerank=True)

All state lives in pytree index containers, so an AnnIndex can be sharded
(``jax.device_put`` with a NamedSharding) and searched under ``jit`` /
``shard_map`` - see ``core/distributed.py`` for the pod-scale path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import bruteforce, fakewords, kdtree, lexical_lsh
from repro.core.types import (
    FakeWordsConfig,
    FakeWordsIndex,
    KdTreeConfig,
    KdTreeIndex,
    LexicalLshConfig,
    LshIndex,
)

AnyConfig = Union[FakeWordsConfig, LexicalLshConfig, KdTreeConfig]
AnyIndex = Union[FakeWordsIndex, LshIndex, KdTreeIndex]


@dataclasses.dataclass
class AnnIndex:
    config: AnyConfig
    index: AnyIndex

    @classmethod
    def build(
        cls, vectors: jax.Array, config: AnyConfig, keep_vectors: bool = True
    ) -> "AnnIndex":
        vectors = bruteforce.l2_normalize(jnp.asarray(vectors))
        if isinstance(config, FakeWordsConfig):
            idx = fakewords.build(vectors, config, keep_vectors, normalized=True)
        elif isinstance(config, LexicalLshConfig):
            idx = lexical_lsh.build(vectors, config, keep_vectors, normalized=True)
        elif isinstance(config, KdTreeConfig):
            idx = kdtree.build(vectors, config, keep_vectors, normalized=True)
        else:
            raise TypeError(f"unknown config {type(config)}")
        return cls(config=config, index=idx)

    @property
    def method(self) -> str:
        return {
            FakeWordsIndex: "fake-words",
            LshIndex: "lexical-lsh",
            KdTreeIndex: "kd-tree",
        }[type(self.index)]

    def nbytes(self) -> int:
        return self.index.nbytes()

    def encode_queries(self, queries: jax.Array) -> jax.Array:
        """Method-specific query representation (tf row / signature /
        reduced point)."""
        q = bruteforce.l2_normalize(jnp.asarray(queries))
        if isinstance(self.config, FakeWordsConfig):
            return fakewords.encode_queries(q, self.config, normalized=True)
        if isinstance(self.config, LexicalLshConfig):
            return lexical_lsh.encode(q, self.config)
        return kdtree.reduce_queries(self.index, q, normalized=True)

    def search(
        self,
        queries: jax.Array,
        k: int = 10,
        depth: int = 100,
        rerank: bool = False,
    ) -> Tuple[jax.Array, jax.Array]:
        queries = bruteforce.l2_normalize(jnp.asarray(queries))
        if isinstance(self.config, FakeWordsConfig):
            q_tf = fakewords.encode_queries(queries, self.config, normalized=True)
            return fakewords.search(
                self.index,
                q_tf,
                queries,
                k=k,
                depth=depth,
                scoring=self.config.scoring,
                rerank=rerank,
                df_max_ratio=self.config.df_max_ratio,
            )
        if isinstance(self.config, LexicalLshConfig):
            sig_q = lexical_lsh.encode(queries, self.config)
            return lexical_lsh.search(
                self.index, sig_q, queries, k=k, depth=depth, rerank=rerank
            )
        return kdtree.search(
            self.index,
            queries,
            k=k,
            depth=depth,
            backend=self.config.backend,
            rerank=rerank,
            normalized=True,
        )
