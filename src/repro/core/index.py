"""AnnIndex facade: the single entry point over every encoding.

    idx = AnnIndex.build(vectors, FakeWordsConfig(quantization=50))
    scores, ids = idx.search(queries, k=10, depth=100, rerank=True)

An AnnIndex owns a staged :class:`repro.core.pipeline.SearchPipeline`
(query encoder -> matcher [-> blockmax prune] -> exact reranker), so every
method — fake words, lexical LSH, k-d tree, brute force — is a stage
configuration, not a bespoke ``search()``.  The serving layer
(``serve/ann_service.py``) and the pod path (``core/distributed.py``) run
the same stage objects.  Construction is staged the same way
(:class:`repro.core.builder.BuildPipeline`, docs/DESIGN.md §8):
``AnnIndex.build`` runs the method's transform/postings/rerank-store
stages locally, or — with ``mesh=`` — row-parallel under ``shard_map``
with no full-corpus materialization on any shard; ``rerank_store="int8"``
swaps the fp32 rerank operand for the quantized store (~4x fewer rerank
gather bytes).

All state lives in pytree index containers, so an AnnIndex can be sharded
(``jax.device_put`` with a NamedSharding) and searched under ``jit`` /
``shard_map`` - see ``core/distributed.py`` for the pod-scale path.

Persistence: :meth:`AnnIndex.save` / :meth:`AnnIndex.load` round-trip any
index type (all array leaves as npz + the method config as JSON), so an
index built offline ships to a serving process bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pca
from repro.core import pipeline as pl
from repro.core.blockmax import BlockMaxIndex, build_blockmax
from repro.core.types import (
    BruteForceConfig,
    DocMetadata,
    FakeWordsConfig,
    FakeWordsIndex,
    FlatIndex,
    GraphConfig,
    GraphIndex,
    KdTreeConfig,
    KdTreeIndex,
    LexicalLshConfig,
    LshIndex,
    QuantizedPostings,
    QuantizedStore,
    SearchParams,
    next_epoch,
)

# The single-index persistence format this module reads and writes.  The
# segmented commit-point format (core/segments.py) is format_version 2 and
# uses directories of these v1 segment dirs plus a ``segments_N.json``
# commit file; AnnIndex.load reads v1 only (and points the caller at
# SegmentedAnnIndex.load for v2 commit points).
FORMAT_VERSION = 1

AnyConfig = Union[
    FakeWordsConfig, LexicalLshConfig, KdTreeConfig, BruteForceConfig,
    GraphConfig,
]
AnyIndex = Union[FakeWordsIndex, LshIndex, KdTreeIndex, FlatIndex, GraphIndex]

_METHOD_BY_INDEX = {
    FakeWordsIndex: "fake-words",
    LshIndex: "lexical-lsh",
    KdTreeIndex: "kd-tree",
    FlatIndex: "bruteforce",
    GraphIndex: "hnsw",
}
_CONFIG_BY_METHOD = {
    "fake-words": FakeWordsConfig,
    "lexical-lsh": LexicalLshConfig,
    "kd-tree": KdTreeConfig,
    "bruteforce": BruteForceConfig,
    "hnsw": GraphConfig,
}


@dataclasses.dataclass
class AnnIndex:
    """One retrieval architecture for every encoding — and the immutable
    *segment* unit of the Lucene-style mutable index
    (:mod:`repro.core.segments`: ``IndexWriter`` flushes buffered rows into
    fresh AnnIndex segments and merges compact them; an AnnIndex itself
    never changes after build).

    ``use_kernel`` / ``blockmax_keep`` / ``blockmax_block_size`` are the
    uniform serving knobs: kernel routing (None = Pallas on TPU, XLA
    elsewhere) and two-stage blockmax pruning (docs/DESIGN.md §6; fake-words
    and LSH indexes only).  Per-call ``SearchParams`` select (k, depth,
    rerank).

    ``epoch`` is the process-unique snapshot identity
    (:func:`repro.core.types.next_epoch`): the serving layer folds it into
    its result-cache key, so swapping a service's index — or refreshing a
    segmented one — can never serve another index's cached results.  Not
    persisted: a loaded copy is a distinct snapshot.
    """

    config: AnyConfig
    index: AnyIndex
    use_kernel: Optional[bool] = None
    blockmax_keep: Optional[int] = None
    blockmax_block_size: int = 256
    bm: Optional[BlockMaxIndex] = None
    # Rerank from the int8 + per-doc-scale store (index.vq) instead of the
    # fp32 originals.  None = auto: quantized iff the index carries ONLY the
    # int8 store (built with rerank_store="int8").
    quantized_rerank: Optional[bool] = None
    epoch: Optional[int] = None
    # Per-doc predicate source for filtered search (docs/DESIGN.md §13).
    # Masks built from it ((N,) / (B, N) nonzero = keep) feed search(filt=).
    metadata: Optional[DocMetadata] = None

    def __post_init__(self):
        if self.epoch is None:
            self.epoch = next_epoch()
        self.pipeline: pl.SearchPipeline = pl.build_pipeline(self.config)
        if self.quantized_rerank is None:
            self.quantized_rerank = (
                self.index.vq is not None and self.index.vectors is None
            )
        if self.quantized_rerank:
            if self.index.vq is None:
                raise ValueError(
                    "quantized_rerank=True but the index has no int8 store "
                    "(build with rerank_store='int8')"
                )
            self.pipeline = dataclasses.replace(
                self.pipeline, reranker=pl.QuantizedCosineReranker()
            )
        if self.blockmax_keep is not None and self.bm is None:
            if not isinstance(self.index, (FakeWordsIndex, LshIndex)):
                raise ValueError(
                    f"blockmax pruning is not supported for {self.method}"
                )
            self.bm = build_blockmax(
                self.index,
                self.blockmax_block_size,
                signed_store=getattr(self.config, "signed_store", False),
            )

    @classmethod
    def build(
        cls,
        vectors: jax.Array,
        config: AnyConfig,
        keep_vectors: bool = True,
        use_kernel: Optional[bool] = None,
        blockmax_keep: Optional[int] = None,
        blockmax_block_size: int = 256,
        rerank_store: Optional[str] = None,
        primary_postings: Optional[str] = None,
        postings_group: int = 32,
        memory_budget_bytes: Optional[int] = None,
        mesh=None,
        shard_axes=("data",),
        normalized: bool = False,
        metadata=None,
    ) -> "AnnIndex":
        """Build any encoding through the staged
        :class:`repro.core.builder.BuildPipeline` (docs/DESIGN.md §8) — the
        single build entry point, locally or (with ``mesh``) row-parallel
        under ``shard_map`` with no full-corpus materialization on any
        shard.

        ``rerank_store``: "exact" (fp32 originals, the default), "int8"
        (quantized store + per-doc scale; rerank gathers ~4x fewer bytes),
        or "none".  ``keep_vectors=False`` is back-compat shorthand for
        "none".  ``normalized=True`` marks the rows as already
        unit-normalized (the segment-merge path rebuilds from stored
        normalized originals and must not renormalize — 1-ulp drift would
        break segmented-vs-monolithic score parity).

        ``primary_postings``: "fp32" (default) | "int8" | "int4" — the
        packed match-stage store with dequant fused into the score stage
        (docs/DESIGN.md §12); ``postings_group`` is the int4 scale-group
        width (32 or 64).  ``memory_budget_bytes`` picks the
        {postings} x {rerank store} x {blockmax keep-fraction} read path
        from the recall-ordered frontier table
        (:mod:`repro.core.memory_budget`); knobs set explicitly alongside
        it are pinned, the budget fills only the unset ones.

        ``metadata``: per-doc structured fields for filtered search — a
        ``{field: (N,) ints}`` mapping or a prebuilt
        :class:`repro.core.types.DocMetadata`; predicate bitmaps built from
        it (``idx.metadata.eq_mask(...)`` etc.) feed ``search(filt=)``."""
        from repro.core import builder

        if memory_budget_bytes is not None:
            from repro.core import memory_budget as mb

            n, dim = vectors.shape
            plan = mb.plan_for_budget(
                config, n, dim, memory_budget_bytes,
                primary_postings=primary_postings,
                rerank_store=(
                    rerank_store if rerank_store is not None
                    else (None if keep_vectors else "none")
                ),
                group=postings_group,
            )
            primary_postings = plan["primary_postings"]
            rerank_store = plan["rerank_store"]
            if (
                blockmax_keep is None
                and plan["keep_frac"] < 1.0
                and isinstance(config, (FakeWordsConfig, LexicalLshConfig))
            ):
                n_blocks = -(-n // blockmax_block_size)
                blockmax_keep = max(1, int(plan["keep_frac"] * n_blocks))
        if rerank_store is None:
            rerank_store = "exact" if keep_vectors else "none"
        if primary_postings is None:
            primary_postings = "fp32"
        bp = builder.make_build_pipeline(
            config, rerank_store, primary_postings, postings_group
        )
        idx = bp.build(vectors, mesh=mesh, axes=shard_axes, normalized=normalized)
        return cls(
            config=config,
            index=idx,
            use_kernel=use_kernel,
            blockmax_keep=blockmax_keep,
            blockmax_block_size=blockmax_block_size,
            quantized_rerank=rerank_store == "int8",
            metadata=builder.build_metadata(metadata, vectors.shape[0]),
        )

    @property
    def method(self) -> str:
        return _METHOD_BY_INDEX[type(self.index)]

    def nbytes(self) -> int:
        return self.index.nbytes()

    @property
    def num_docs(self) -> int:
        return self.index.num_docs

    def encode_queries(self, queries: jax.Array) -> jax.Array:
        """Method-specific query representation (tf row / signature /
        reduced point / identity)."""
        return self.pipeline.encode(self.index, queries)

    def matcher_for(self, bm=None, keep: Optional[int] = None):
        """The effective match stage: blockmax pruning when a block-bound
        structure and keep count are given, else the method's dense matcher.
        The single source of truth for pruning-stage selection (the serving
        layer calls this with its own overrides)."""
        if bm is not None and keep is not None:
            return pl.BlockMaxMatcher(n_keep=min(keep, bm.num_blocks))
        return self.pipeline.matcher

    def _matcher(self):
        return self.matcher_for(self.bm, self.blockmax_keep)

    def search(
        self,
        queries: jax.Array,
        k: int = 10,
        depth: int = 100,
        rerank: bool = False,
        params: Optional[SearchParams] = None,
        use_kernel: Optional[bool] = None,
        filt: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        """Staged search: encode -> match [-> prune] -> optional rerank.
        ``params`` takes precedence WHOLESALE over the ``k``/``depth``/
        ``rerank`` kwargs (pass one style or the other, not both);
        ``use_kernel`` overrides the index-level kernel routing for this
        call.  ``filt`` ((N,) or (B, N), nonzero = keep) restricts the match
        stage to the bitmap's docs in the same single kernel pass
        (docs/DESIGN.md §13) — typically built from ``self.metadata``."""
        p = params if params is not None else SearchParams(k=k, depth=depth, rerank=rerank)
        uk = self.use_kernel if use_kernel is None else use_kernel
        pipe = dataclasses.replace(self.pipeline, matcher=self._matcher())
        return pipe.search(
            self.index, queries, p, bm=self.bm, use_kernel=uk, filt=filt
        )

    # ----------------------------------------------------------------------
    # Persistence: npz (all array leaves) + JSON (config + serving knobs)
    # ----------------------------------------------------------------------

    def save(self, path: str) -> None:
        """Write the index to ``path/`` (``config.json`` + ``index.npz``).
        Covers every index pytree, including the k-d tree's fitted reduction
        model; the blockmax structure is rebuilt deterministically on load."""
        os.makedirs(path, exist_ok=True)
        arrays = _named_arrays(self.index)
        packed: Dict[str, np.ndarray] = {}
        dtypes: Dict[str, str] = {}
        for name, arr in arrays.items():
            a, dtype_name = _to_numpy(arr)
            packed[name] = a
            dtypes[name] = dtype_name
        meta = {
            "format_version": FORMAT_VERSION,
            "method": self.method,
            "config": _config_to_json(self.config),
            "dtypes": dtypes,
            "use_kernel": self.use_kernel,
            "blockmax_keep": self.blockmax_keep,
            "blockmax_block_size": self.blockmax_block_size,
            "quantized_rerank": self.quantized_rerank,
        }
        pq = getattr(self.index, "pq", None)
        if pq is not None:
            # Static (non-array) packed-store metadata; the q/scale leaves
            # ride in the npz like every other array.
            meta["pq"] = {"bits": pq.bits, "group": pq.group, "cols": pq.cols}
        if self.metadata is not None:
            # Same split as pq: field names in the JSON, the (N, F) value
            # matrix in the npz under a reserved dotted name.
            meta["metadata"] = {"field_names": list(self.metadata.field_names)}
            packed["metadata.values"] = np.asarray(self.metadata.values)
        with open(os.path.join(path, "config.json"), "w") as f:
            json.dump(meta, f, indent=2)
        np.savez_compressed(os.path.join(path, "index.npz"), **packed)

    @classmethod
    def load(cls, path: str, **overrides) -> "AnnIndex":
        """Reconstruct a saved index.  ``overrides`` replace the persisted
        serving knobs (``use_kernel``, ``blockmax_keep``,
        ``blockmax_block_size``).  Validates ``format_version`` up front so
        an index written by a newer format fails with a clear error instead
        of a KeyError deep in ``_rebuild_index``."""
        meta_path = os.path.join(path, "config.json")
        if not os.path.exists(meta_path):
            from repro.core import segments as seg

            if seg.find_commits(path):
                raise ValueError(
                    f"{path!r} holds a segmented commit point "
                    "(segments_N.json), not a single-index save; open it "
                    "with SegmentedAnnIndex.load / IndexWriter.open "
                    "(repro.core.segments)"
                )
        with open(meta_path) as f:
            meta = json.load(f)
        version = meta.get("format_version", 1)
        if version != FORMAT_VERSION:
            raise ValueError(
                f"index at {path!r} has format_version {version}, but this "
                f"build reads format_version {FORMAT_VERSION}"
                + (
                    " — it was written by a newer version of the code; "
                    "upgrade to load it"
                    if version > FORMAT_VERSION
                    else ""
                )
            )
        config = _config_from_json(meta["method"], meta["config"])
        with np.load(os.path.join(path, "index.npz")) as z:
            metadata = None
            if "metadata" in meta:
                metadata = DocMetadata(
                    values=jnp.asarray(z["metadata.values"]),
                    field_names=tuple(meta["metadata"]["field_names"]),
                )
            arrays = {
                name: _from_numpy(z[name], meta["dtypes"][name])
                for name in z.files
                if name != "metadata.values"
            }
        index = _rebuild_index(meta["method"], config, arrays, meta.get("pq"))
        knobs = {
            "metadata": metadata,
            "use_kernel": meta.get("use_kernel"),
            "blockmax_keep": meta.get("blockmax_keep"),
            "blockmax_block_size": meta.get("blockmax_block_size", 256),
            "quantized_rerank": meta.get("quantized_rerank"),
        }
        knobs.update(overrides)
        return cls(config=config, index=index, **knobs)


# --------------------------------------------------------------------------
# (De)serialization helpers
# --------------------------------------------------------------------------


def _named_arrays(obj, prefix: str = "") -> Dict[str, jax.Array]:
    """Dotted-name -> array map over a (possibly nested) index dataclass;
    None leaves are skipped and restored as absent fields."""
    out: Dict[str, jax.Array] = {}
    for f in dataclasses.fields(obj):
        v = getattr(obj, f.name)
        if v is None or f.metadata.get("static"):
            continue
        name = f"{prefix}{f.name}"
        if dataclasses.is_dataclass(v) and not isinstance(v, (jax.Array, np.ndarray)):
            out.update(_named_arrays(v, name + "."))
        else:
            out[name] = v
    return out


def _to_numpy(arr) -> Tuple[np.ndarray, str]:
    """npz-safe realization: bfloat16 (no native numpy dtype) round-trips
    through a uint16 view; everything else saves as-is."""
    a = np.asarray(arr)
    if a.dtype.name == "bfloat16":
        return a.view(np.uint16), "bfloat16"
    return a, a.dtype.name


def _from_numpy(a: np.ndarray, dtype_name: str) -> jax.Array:
    if dtype_name == "bfloat16":
        return jnp.asarray(a).view(jnp.bfloat16)
    return jnp.asarray(a)


def _config_to_json(config: AnyConfig) -> dict:
    d = dataclasses.asdict(config)
    if isinstance(config, FakeWordsConfig):
        d["store_dtype"] = np.dtype(config.store_dtype).name
    return d


def _config_from_json(method: str, d: dict) -> AnyConfig:
    cls = _CONFIG_BY_METHOD[method]
    if cls is FakeWordsConfig and "store_dtype" in d:
        d = dict(d, store_dtype=np.dtype(d["store_dtype"]))
    return cls(**d)


def _rebuild_reduction(config: KdTreeConfig, arrays: Dict[str, jax.Array]):
    if config.reduction == "pca":
        return pca.PcaModel(
            mean=arrays["reduction.mean"],
            components=arrays["reduction.components"],
        )
    return pca.PpaPcaPpaModel(
        ppa1=pca.PpaModel(
            mean=arrays["reduction.ppa1.mean"], top=arrays["reduction.ppa1.top"]
        ),
        pca=pca.PcaModel(
            mean=arrays["reduction.pca.mean"],
            components=arrays["reduction.pca.components"],
        ),
        ppa2=pca.PpaModel(
            mean=arrays["reduction.ppa2.mean"], top=arrays["reduction.ppa2.top"]
        ),
    )


def _rebuild_vq(arrays: Dict[str, jax.Array]) -> Optional[QuantizedStore]:
    if "vq.q" in arrays:
        return QuantizedStore(q=arrays["vq.q"], scale=arrays["vq.scale"])
    return None


def _rebuild_pq(
    arrays: Dict[str, jax.Array], pq_meta: Optional[dict]
) -> Optional[QuantizedPostings]:
    if "pq.q" not in arrays:
        return None
    assert pq_meta is not None, "packed postings arrays without pq metadata"
    return QuantizedPostings(
        q=arrays["pq.q"], scale=arrays["pq.scale"],
        bits=int(pq_meta["bits"]), group=int(pq_meta["group"]),
        cols=int(pq_meta["cols"]),
    )


def _rebuild_index(
    method: str, config: AnyConfig, arrays: Dict[str, jax.Array],
    pq_meta: Optional[dict] = None,
) -> AnyIndex:
    g = arrays.get
    vq = _rebuild_vq(arrays)
    pq = _rebuild_pq(arrays, pq_meta)
    if method == "fake-words":
        return FakeWordsIndex(
            tf=g("tf"), idf=arrays["idf"], norm=arrays["norm"],
            df=arrays["df"], scored=g("scored"), vectors=g("vectors"), vq=vq,
            pq=pq,
        )
    if method == "lexical-lsh":
        return LshIndex(sig=arrays["sig"], vectors=g("vectors"), vq=vq)
    if method == "kd-tree":
        return KdTreeIndex(
            reduced=arrays["reduced"],
            reduction=_rebuild_reduction(config, arrays),
            split_dim=g("split_dim"), split_val=g("split_val"), perm=g("perm"),
            lifted=g("lifted"), vectors=g("vectors"), vq=vq,
        )
    if method == "bruteforce":
        return FlatIndex(vectors=g("vectors"), vq=vq, pq=pq)
    if method == "hnsw":
        return GraphIndex(
            vectors=arrays["vectors"], neighbors=arrays["neighbors"],
            entry=arrays["entry"], vq=vq,
        )
    raise ValueError(f"unknown method {method!r}")
