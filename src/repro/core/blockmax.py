"""Block upper-bound pruning - WAND/BlockMax-WAND adapted to TPU tiles.

Lucene never scores documents that share no query term, and WAND-style
engines additionally skip whole postings blocks whose term-score upper bounds
cannot beat the current k-th best.  A dense GEMM scores everything, so we
recover the skipping *architecturally*: documents are grouped into fixed-size
blocks, each block stores per-term tf upper bounds, and at query time we

  1. score every block's upper bound with one small GEMM
     (n_blocks x 2m) @ (2m,)  ->  optimistic block scores,
  2. keep only the top ``beta``-fraction of blocks (static shape!),
  3. gather those blocks' rows and run the exact scoring GEMM on them.

This turns the paper's "filter high-frequency terms" latency trick into a
second, stronger roofline lever: the index-scan GEMM is memory-bound, and
block pruning cuts its bytes by ~(1 - beta) at a small recall cost that the
benchmark sweeps (see docs/DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import FakeWordsIndex


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BlockMaxIndex:
    """Per-block upper bounds over a FakeWordsIndex, block = ``block_size``
    consecutive docs.  ub[b,t] = max over docs in block b of the *scored*
    matrix entry (classic mode) so the block bound is exact."""

    ub: jax.Array  # (n_blocks, 2m) bfloat16
    block_size: int = dataclasses.field(metadata=dict(static=True))


def build_blockmax(index: FakeWordsIndex, block_size: int = 256) -> BlockMaxIndex:
    assert index.scored is not None, "blockmax requires classic scoring matrix"
    n, t = index.scored.shape
    n_pad = (-n) % block_size
    scored = index.scored
    if n_pad:
        scored = jnp.concatenate(
            [scored, jnp.zeros((n_pad, t), scored.dtype)], axis=0
        )
    blocks = scored.reshape(-1, block_size, t)
    ub = jnp.max(blocks, axis=1)
    return BlockMaxIndex(ub=ub, block_size=block_size)


@functools.partial(jax.jit, static_argnames=("n_keep", "depth", "use_kernel"))
def pruned_search(
    index: FakeWordsIndex,
    bm: BlockMaxIndex,
    q_tf: jax.Array,
    n_keep: int,
    depth: int,
    use_kernel: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Two-stage blockmax search: upper-bound GEMM -> keep n_keep blocks ->
    exact scoring on the gathered rows.  Returns (scores, doc_ids) at depth.

    ``use_kernel`` routes stage 2 through the fused gathered-candidates
    streaming top-k kernel (docs/DESIGN.md §4): the (B, n_keep*block_size)
    stage-2 score matrix never materializes.  Default: kernel on TPU."""
    from repro.kernels.fused_topk import ops as fused

    bsz = bm.block_size
    qv = q_tf.astype(jnp.bfloat16)  # (B, 2m)
    # Stage 1: optimistic block scores (tiny GEMM).
    block_ub = jnp.einsum(
        "bt,nt->bn", qv, bm.ub, preferred_element_type=jnp.float32
    )  # (B, n_blocks)
    _, keep_blocks = jax.lax.top_k(block_ub, n_keep)  # (B, n_keep)
    # Stage 2: gather kept blocks' scored rows and score exactly.
    # row ids: (B, n_keep, bsz)
    row_ids = keep_blocks[:, :, None] * bsz + jnp.arange(bsz)[None, None, :]
    row_ids = row_ids.reshape(q_tf.shape[0], -1)  # (B, n_keep*bsz)
    rows = index.scored[jnp.minimum(row_ids, index.num_docs - 1)]  # (B,R,2m)
    if fused.resolve_use_kernel(use_kernel):
        return fused.fused_topk_gathered(
            qv, rows, row_ids, depth, index.num_docs
        )
    valid = row_ids < index.num_docs
    scores = jnp.einsum(
        "bt,brt->br", qv, rows, preferred_element_type=jnp.float32
    )
    scores = jnp.where(valid, scores, -jnp.inf)
    d_s, pos = jax.lax.top_k(scores, depth)
    d_i = jnp.take_along_axis(row_ids, pos, axis=-1)
    d_i = jnp.where(d_s > -jnp.inf, d_i, -1)
    return d_s, d_i
