"""Block upper-bound pruning - WAND/BlockMax-WAND adapted to TPU tiles.

Lucene never scores documents that share no query term, and WAND-style
engines additionally skip whole postings blocks whose term-score upper bounds
cannot beat the current k-th best.  A dense GEMM scores everything, so we
recover the skipping *architecturally*: documents are grouped into fixed-size
blocks, each block stores per-term upper bounds, and at query time we

  1. score every block's upper bound with one small operation
     (n_blocks x T) against the query  ->  optimistic block scores,
  2. keep only the top ``beta``-fraction of blocks (static shape!),
  3. gather those blocks' rows and score them exactly — through the fused
     gathered streaming top-k kernel (docs/DESIGN.md §4), so the stage-2
     score matrix never materializes.

The bound structure generalizes over every scoring mode (docs/DESIGN.md §6):

  * classic — ub[b,t] = max over docs in block b of the precomputed
    ``scored`` entry (non-negative), bound = one small bf16 GEMM against the
    query tf row.  Exact-admissible.
  * dot     — per-term SIGNED doc values s = tf+ - tf- can be negative, so a
    single max is not admissible.  Store ub = [max(s); max(-s)] per block;
    because the sign-split query encoding satisfies q+ = relu(u) and
    q- = relu(-u) (a feature is positive or negative, never both), the bound
    is q_tf @ ub.T — still a single small GEMM via the ``[u; -u]`` lift.
  * lsh     — per-block per-slot presence bitmaps: bit (v & 31) of
    ``ub[b, s]`` is set iff some doc in block b holds MinHash value v in
    slot s.  The bound counts query slots whose value's bit is present —
    a superset test, so collisions only loosen the bound (admissible).

This turns the paper's "filter high-frequency terms" latency trick into a
second, stronger roofline lever: the index-scan GEMM is memory-bound, and
block pruning cuts its bytes by ~(1 - beta) at a small recall cost that the
benchmark sweeps (see docs/DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fakewords
from repro.core.types import FakeWordsIndex, LshIndex

AnyBlockIndex = Union[FakeWordsIndex, LshIndex]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BlockMaxIndex:
    """Per-block upper-bound structure, block = ``block_size`` consecutive
    docs.  ``ub`` layout depends on ``mode``:

      classic: (n_blocks, 2m) bf16 max of the scored matrix (exact bound);
      dot:     (n_blocks, 2m) int8 ``[max(s); max(-s)]`` over the signed
               per-term doc values s = tf+ - tf-;
      lsh:     (n_blocks, S) uint32 per-slot presence bitmaps.
    """

    ub: jax.Array
    block_size: int = dataclasses.field(metadata=dict(static=True))
    mode: str = dataclasses.field(default="classic", metadata=dict(static=True))

    @property
    def num_blocks(self) -> int:
        return self.ub.shape[0]


def _block_reduce_max(x: jax.Array, block_size: int, pad_value=0) -> jax.Array:
    n, t = x.shape
    n_pad = (-n) % block_size
    if n_pad:
        x = jnp.concatenate(
            [x, jnp.full((n_pad, t), pad_value, x.dtype)], axis=0
        )
    return jnp.max(x.reshape(-1, block_size, t), axis=1)


def _dequantized_f32(pq) -> jax.Array:
    """f32 effective per-element values of a packed postings store, matching
    the score stage's arithmetic (docs/DESIGN.md §12): int8 contributes
    ``scale * q`` as an exact f32 product; int4 contributes the bf16-cast
    canonical dequant (the actual kernel operand), widened to f32.  Block
    maxima over THESE values give admissible bounds on quantized scores."""
    from repro.kernels import common

    if pq.bits == 8:
        return pq.q.astype(jnp.float32) * pq.scale
    deq = common.dequant_int4(pq.q, pq.scale, pq.group, jnp.bfloat16)
    return deq[:, : pq.cols].astype(jnp.float32)


def _lsh_block_bitmap(sig: jax.Array, block_size: int) -> jax.Array:
    from repro.core import lexical_lsh

    n, s = sig.shape
    n_pad = (-n) % block_size
    if n_pad:
        sig = jnp.concatenate(
            [sig, jnp.full((n_pad, s), lexical_lsh.SENTINEL, sig.dtype)], axis=0
        )
    bits = jnp.where(
        sig != lexical_lsh.SENTINEL,
        jnp.left_shift(jnp.uint32(1), sig & jnp.uint32(31)),
        jnp.uint32(0),
    )
    blocks = bits.reshape(-1, block_size, s)
    return jax.lax.reduce(blocks, np.uint32(0), jax.lax.bitwise_or, (1,))


def build_blockmax(
    index: AnyBlockIndex,
    block_size: int = 256,
    mode: Optional[str] = None,
    signed_store: bool = False,
) -> BlockMaxIndex:
    """Build per-block upper bounds for any index / scoring mode.

    ``mode`` defaults to "lsh" for an LshIndex, else "classic" when the
    FakeWordsIndex carries a ``scored`` matrix and "dot" otherwise.
    ``signed_store`` marks a dot-mode index whose ``tf`` already holds the
    SIGNED (N, m) matrix (FakeWordsConfig.signed_store)."""
    if isinstance(index, LshIndex) or mode == "lsh":
        return BlockMaxIndex(
            ub=_lsh_block_bitmap(index.sig, block_size),
            block_size=block_size, mode="lsh",
        )
    if mode is None:
        # A packed store alongside tf is quantized-classic (dot-int4 drops
        # tf; dot-int8 stores quantized tf natively with no pq leaf).
        classic = index.scored is not None or (
            index.pq is not None and index.tf is not None
        )
        mode = "classic" if classic else "dot"
    if mode == "classic":
        if index.pq is not None:
            # Bounds from the DEQUANTIZED maxima, f32: per-doc/group scales
            # vary inside a block, so max does not commute with dequant.
            return BlockMaxIndex(
                ub=_block_reduce_max(_dequantized_f32(index.pq), block_size),
                block_size=block_size, mode="classic",
            )
        assert index.scored is not None, "classic blockmax requires scored matrix"
        return BlockMaxIndex(
            ub=_block_reduce_max(index.scored, block_size),
            block_size=block_size, mode="classic",
        )
    assert mode == "dot", f"unknown blockmax mode {mode}"
    if index.pq is not None:
        deq = _dequantized_f32(index.pq)  # (N, m) signed or (N, 2m) split
        if deq.shape[1] * 2 == index.df.shape[0]:
            s = deq  # hand-built signed packed store, already (N, m)
        else:
            m = deq.shape[1] // 2
            s = deq[:, :m] - deq[:, m:]
        ub = jnp.concatenate(
            [_block_reduce_max(s, block_size), _block_reduce_max(-s, block_size)],
            axis=-1,
        )
        return BlockMaxIndex(ub=ub, block_size=block_size, mode="dot")
    tf = index.tf
    if signed_store:
        s = tf.astype(jnp.int8)
    else:
        m = tf.shape[1] // 2
        s = (tf[:, :m].astype(jnp.int32) - tf[:, m:].astype(jnp.int32)).astype(
            jnp.int8
        )
    ub = jnp.concatenate(
        [_block_reduce_max(s, block_size), _block_reduce_max(-s, block_size)],
        axis=-1,
    )
    return BlockMaxIndex(ub=ub, block_size=block_size, mode="dot")


def block_bounds(bm: BlockMaxIndex, q: jax.Array) -> jax.Array:
    """Stage 1: (B, n_blocks) optimistic block score upper bounds.

    ``q`` is the mode's match-phase query representation: the (B, 2m) tf row
    for classic AND dot (the dot bound's ``[relu(u); relu(-u)]`` operand IS
    the sign-split encoding), or the (B, S) uint32 signature for lsh."""
    if bm.mode == "classic":
        return jnp.einsum(
            "bt,nt->bn", q.astype(jnp.bfloat16), bm.ub,
            preferred_element_type=jnp.float32,
        )
    if bm.mode == "dot":
        if jnp.issubdtype(bm.ub.dtype, jnp.floating):
            # Quantized store: dequantized maxima are f32, not int8.
            return jnp.einsum(
                "bt,nt->bn", q.astype(jnp.float32), bm.ub,
                preferred_element_type=jnp.float32,
            )
        return jnp.einsum(
            "bt,nt->bn", q.astype(jnp.int32), bm.ub.astype(jnp.int32),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32)
    from repro.core import lexical_lsh

    member = (bm.ub[None, :, :] >> (q & jnp.uint32(31))[:, None, :]) & jnp.uint32(1)
    valid = (q != lexical_lsh.SENTINEL)[:, None, :]
    return jnp.sum(
        jnp.where(valid, member, jnp.uint32(0)), axis=-1, dtype=jnp.int32
    ).astype(jnp.float32)


def _stage2_operands(
    index: AnyBlockIndex, bm: BlockMaxIndex, q: jax.Array
) -> Tuple[jax.Array, jax.Array, str]:
    """(query operand, stored matrix to gather from, kernel mode).  With a
    packed postings store the matrix slot carries the
    :class:`repro.core.types.QuantizedPostings` itself and the mode is
    "quantized" — stage 2 gathers packed rows + scales and dequantizes in
    the score stage."""
    pq = getattr(index, "pq", None)
    if bm.mode == "classic":
        if pq is not None:
            return q.astype(jnp.bfloat16), pq, "quantized"
        return q.astype(jnp.bfloat16), index.scored, "gemm"
    if bm.mode == "dot":
        m = bm.ub.shape[1] // 2
        u = fakewords.signed_query(q)
        if pq is not None:
            if pq.cols == m:  # signed store: packed matrix already (N, m)
                return u.astype(jnp.bfloat16), pq, "quantized"
            return (
                jnp.concatenate([u, -u], axis=-1).astype(jnp.bfloat16),
                pq, "quantized",
            )
        if index.tf.shape[1] == m:  # signed store: tf already (N, m) signed
            return u.astype(jnp.int8), index.tf, "gemm"
        return jnp.concatenate([u, -u], axis=-1).astype(jnp.int8), index.tf, "gemm"
    return q, index.sig, "lsh"


def pruned_topk(
    index: AnyBlockIndex,
    bm: BlockMaxIndex,
    q: jax.Array,
    n_keep: int,
    depth: int,
    use_kernel: Optional[bool] = None,
    filt: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Two-stage blockmax search core (un-jitted: usable inside shard_map).

    ``n_keep`` is clamped to the block count and ``depth`` to the gathered
    candidate count (the former crashed ``lax.top_k`` and the latter the
    gathered top-k before); when clamped, the output is padded back to the
    requested ``depth`` with (-inf, -1) so shapes stay caller-visible.

    ``filt`` is a per-doc predicate bitmap ((N,) | (B, N), nonzero = keep)
    masked inside the stage-2 gathered score pass.  Stage-1 bounds stay
    UNfiltered: filtering only removes docs, so an unfiltered block maximum
    remains an admissible overestimate — at beta=1.0 every block is kept
    and the filtered result equals the dense filtered paths exactly."""
    from repro.kernels.fused_topk import ops as fused
    from repro.kernels.fused_topk import ref as fused_ref

    bsz = bm.block_size
    n_keep = min(n_keep, bm.num_blocks)
    eff_depth = min(depth, n_keep * bsz)
    n_docs = index.num_docs
    b = q.shape[0]

    _, keep_blocks = jax.lax.top_k(block_bounds(bm, q), n_keep)  # (B, n_keep)
    row_ids = keep_blocks[:, :, None] * bsz + jnp.arange(bsz)[None, None, :]
    row_ids = row_ids.reshape(b, -1).astype(jnp.int32)  # (B, n_keep*bsz)
    qv, mat, mode = _stage2_operands(index, bm, q)
    if mode == "quantized":
        if fused.resolve_use_kernel(use_kernel):
            d_s, d_i = fused.postings_topk_gathered(
                mat, qv, row_ids, eff_depth, n_docs, filt=filt
            )
        else:
            safe = jnp.minimum(row_ids, n_docs - 1)
            d_s, d_i = fused_ref.quantized_gathered_topk_ref(
                qv, mat.q[safe], mat.scale[safe], row_ids, eff_depth,
                n_docs, mat.bits, mat.group,
                filt=fused.gather_filt(filt, row_ids, n_docs),
            )
    elif fused.resolve_use_kernel(use_kernel):
        rows = mat[jnp.minimum(row_ids, n_docs - 1)]  # (B, R, T)
        d_s, d_i = fused.fused_topk_gathered(
            qv, rows, row_ids, eff_depth, n_docs, mode=mode,
            filt=fused.gather_filt(filt, row_ids, n_docs),
        )
    else:
        rows = mat[jnp.minimum(row_ids, n_docs - 1)]  # (B, R, T)
        d_s, d_i = fused_ref.gathered_topk_ref(
            qv, rows, row_ids, eff_depth, n_docs, mode=mode,
            filt=fused.gather_filt(filt, row_ids, n_docs),
        )
    if eff_depth < depth:
        pad = depth - eff_depth
        d_s = jnp.concatenate(
            [d_s, jnp.full((b, pad), -jnp.inf, d_s.dtype)], axis=-1
        )
        d_i = jnp.concatenate(
            [d_i, jnp.full((b, pad), -1, d_i.dtype)], axis=-1
        )
    return d_s, d_i


@functools.partial(jax.jit, static_argnames=("n_keep", "depth", "use_kernel"))
def pruned_search(
    index: AnyBlockIndex,
    bm: BlockMaxIndex,
    q_tf: jax.Array,
    n_keep: int,
    depth: int,
    use_kernel: Optional[bool] = None,
    filt: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Two-stage blockmax search: upper-bound pass -> keep n_keep blocks ->
    exact scoring on the gathered rows.  Returns (scores, doc_ids) at depth;
    works for classic, dot/int8 and LSH indexes (``bm.mode`` selects).

    ``use_kernel`` routes stage 2 through the fused gathered-candidates
    streaming top-k kernel (docs/DESIGN.md §4): the (B, n_keep*block_size)
    stage-2 score matrix never materializes.  Default: kernel on TPU.
    Ties break on the lowest doc id on both paths, so at beta=1.0 the ids
    equal the dense reference paths exactly.

    (:class:`repro.core.pipeline.BlockMaxMatcher` is the same two-stage
    match as a pipeline stage; this wrapper is the jitted standalone form.)"""
    return pruned_topk(index, bm, q_tf, n_keep, depth, use_kernel, filt=filt)
