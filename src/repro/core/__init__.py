"""Core ANN library: the paper's contribution as composable JAX modules."""
from repro.core.types import (  # noqa: F401
    BruteForceConfig,
    FakeWordsConfig,
    FakeWordsIndex,
    FlatIndex,
    KdTreeConfig,
    KdTreeIndex,
    LexicalLshConfig,
    LshIndex,
    QuantizedStore,
    SearchParams,
)
from repro.core.index import AnnIndex  # noqa: F401
from repro.core.pipeline import SearchPipeline  # noqa: F401
from repro.core.builder import BuildPipeline, make_build_pipeline  # noqa: F401
from repro.core.segments import (  # noqa: F401
    IndexWriter,
    SegmentedAnnIndex,
    TieredMergePolicy,
)
