"""Exact cosine top-k - the paper's "ground truth by brute force".

Two flavors:
  * ``exact_topk`` - single GEMM + lax.top_k; fine up to ~1M x 1K dims on one
    device.
  * ``exact_topk_tiled`` - streams the corpus in document tiles with a running
    top-k merge; bounds peak memory to O(B * (tile + k)) scores, which is what
    you want for 10^8-document shards (and mirrors the Pallas
    ``cosine_score`` kernel's tiling).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def l2_normalize(x: jax.Array, eps: float = 1e-12, axis: int = -1) -> jax.Array:
    """Unit-normalize so inner product == cosine (paper §2, fake-words
    validity condition)."""
    n = jnp.linalg.norm(x, axis=axis, keepdims=True)
    return x / jnp.maximum(n, eps)


@functools.partial(jax.jit, static_argnames=("k", "normalized", "use_kernel"))
def exact_topk(
    corpus: jax.Array,
    queries: jax.Array,
    k: int,
    normalized: bool = False,
    use_kernel: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Exact cosine top-k: returns (scores (B,k), ids (B,k)).

    ``use_kernel`` routes through the fused streaming score->top-k Pallas
    kernel (docs/DESIGN.md §4): the corpus streams HBM->VMEM once and the
    (B, N) score matrix never materializes.  Default: kernel on TPU."""
    from repro.kernels.fused_topk import ops as fused

    c = corpus if normalized else l2_normalize(corpus)
    q = queries if normalized else l2_normalize(queries)
    if fused.resolve_use_kernel(use_kernel):
        return fused.cosine_topk(c, q, k)
    scores = q @ c.T  # (B, N)
    return jax.lax.top_k(scores, k)


def _merge_topk(
    scores_a: jax.Array,
    ids_a: jax.Array,
    scores_b: jax.Array,
    ids_b: jax.Array,
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Merge two (B, *) candidate sets into the best k of their union."""
    s = jnp.concatenate([scores_a, scores_b], axis=-1)
    i = jnp.concatenate([ids_a, ids_b], axis=-1)
    top_s, pos = jax.lax.top_k(s, k)
    top_i = jnp.take_along_axis(i, pos, axis=-1)
    return top_s, top_i


@functools.partial(jax.jit, static_argnames=("k", "tile", "normalized"))
def exact_topk_tiled(
    corpus: jax.Array,
    queries: jax.Array,
    k: int,
    tile: int = 4096,
    normalized: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Streaming exact top-k over corpus tiles (running-merge pattern)."""
    n, dim = corpus.shape
    b = queries.shape[0]
    c = corpus if normalized else l2_normalize(corpus)
    q = queries if normalized else l2_normalize(queries)

    n_pad = (-n) % tile
    if n_pad:
        c = jnp.concatenate([c, jnp.zeros((n_pad, dim), c.dtype)], axis=0)
    n_tiles = c.shape[0] // tile
    c_tiles = c.reshape(n_tiles, tile, dim)

    init_s = jnp.full((b, k), -jnp.inf, dtype=jnp.float32)
    init_i = jnp.full((b, k), -1, dtype=jnp.int32)

    def body(carry, xs):
        best_s, best_i = carry
        t_idx, c_t = xs
        s = (q @ c_t.T).astype(jnp.float32)  # (B, tile)
        ids = t_idx * tile + jnp.arange(tile, dtype=jnp.int32)[None, :]
        # Mask padded docs.
        valid = ids < n
        s = jnp.where(valid, s, -jnp.inf)
        ids = jnp.broadcast_to(ids, s.shape)
        local_s, pos = jax.lax.top_k(s, min(k, tile))
        local_i = jnp.take_along_axis(ids, pos, axis=-1)
        return _merge_topk(best_s, best_i, local_s, local_i, k), None

    (best_s, best_i), _ = jax.lax.scan(
        body, (init_s, init_i), (jnp.arange(n_tiles, dtype=jnp.int32), c_tiles)
    )
    return best_s, best_i


def rerank_exact(
    vectors: jax.Array,
    queries: jax.Array,
    cand_ids: jax.Array,
    k: int,
    normalized: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Refinement step the paper describes (d > k) but does not implement:
    gather the d candidates' original vectors, compute exact cosine, rerank,
    return the exact top-k.  ``cand_ids`` is (B, d); id -1 = padding."""
    v = vectors if normalized else l2_normalize(vectors)
    q = queries if normalized else l2_normalize(queries)
    cand = v[jnp.maximum(cand_ids, 0)]  # (B, d, dim)
    scores = jnp.einsum("bd,bcd->bc", q, cand)
    scores = jnp.where(cand_ids >= 0, scores, -jnp.inf)
    top_s, pos = jax.lax.top_k(scores, k)
    top_i = jnp.take_along_axis(cand_ids, pos, axis=-1)
    return top_s, top_i
