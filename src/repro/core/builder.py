"""Staged index construction: one build architecture for every encoding
(docs/DESIGN.md §8) — the build-side mirror of ``core/pipeline.py``.

The paper's three Lucene encodings and the brute-force oracle share one
logical build recipe:

    normalize rows -> transform vectors     (tf rows / MinHash signatures /
                                             fitted reduction -> points)
                   -> assemble postings     (index container + global stats)
                   -> attach rerank store   (fp32 originals / int8+scale /
                                             none)

A :class:`BuildPipeline` makes that recipe structural.  Each stage is a
frozen (hashable, jit-static) dataclass:

  * **VectorTransform** — ``transform(v_norm, axes=None, n_total=None) ->
    (realization, fitted_model_or_None)``: the method's document
    realization.  Row-local for fake words (quantized tf rows), lexical LSH
    (MinHash signatures) and brute force (identity); the k-d tree's
    reduction fits from ``psum``-able moments (``core/pca.py``) so with
    ``axes`` set every shard fits the IDENTICAL model from global
    statistics while its rows stay shard-resident.
  * **Postings** — ``postings(realization, model, v_norm, store, n_total,
    axes=None) -> index``: assembles the index container.  Global
    statistics (fake-words df -> idf) are ``psum``-ed under ``axes`` so a
    sharded build matches the single-host build bit-for-bit.
  * **RerankStore** — ``store(v_norm) -> {"vectors": ..., "vq": ...}``: the
    exact-rerank operand.  :class:`ExactRerankStore` keeps the fp32
    originals; :class:`QuantizedRerankStore` keeps an int8 + per-doc-scale
    :class:`repro.core.types.QuantizedStore` (~4x fewer rerank gather
    bytes, score error bounded by ``||q||_1 * scale/2``);
    :class:`NoRerankStore` keeps neither.  Row-local, so it shards freely.

Because every stage takes ``axes`` explicitly, the SAME pipeline object
builds single-host (``build_local``) or row-parallel under ``shard_map``
over a mesh (``build_sharded``) — no stage ever materializes the full
corpus on one shard, and the per-method ``build()`` functions are thin
wrappers over these stages (exact parity), the same way PR 3's
SearchPipeline absorbed the per-method ``search()`` functions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bruteforce, pca
from repro.core.types import (
    BruteForceConfig,
    DocMetadata,
    FakeWordsConfig,
    FakeWordsIndex,
    FlatIndex,
    GraphConfig,
    GraphIndex,
    KdTreeConfig,
    KdTreeIndex,
    LexicalLshConfig,
    LshIndex,
    QuantizedPostings,
    QuantizedStore,
)

AnyConfig = Union[
    FakeWordsConfig, LexicalLshConfig, KdTreeConfig, BruteForceConfig,
    GraphConfig,
]

RERANK_STORES = ("exact", "int8", "none")
PRIMARY_POSTINGS = ("fp32", "int8", "int4")
POSTINGS_GROUPS = (32, 64)

_QUANT_POSTINGS_MSG = (
    "quantized primary postings support fake-words (classic/dot) and "
    "brute-force; the LSH signature store is categorical (uint32 MinHash "
    "buckets — scaling them is meaningless), the kd-tree reduced store "
    "is already ~8 f32 columns with a mixed-magnitude L2-lift column, and "
    "the graph matcher gathers tiny neighbor blocks (bytes moved scale "
    "with beam*degree, not N — use rerank_store='int8' for the memory "
    "knob instead) (docs/DESIGN.md §12)"
)

_TREE_BUILD_MSG = (
    "kd-tree 'tree' backend builds host-side (numpy) and cannot shard on "
    "documents; use backend='scan' (identical results, docs/DESIGN.md §3)"
)


# --------------------------------------------------------------------------
# Vector transforms
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TfTransform:
    """Fake words: sign-split quantized term-frequency rows (row-local)."""

    config: FakeWordsConfig

    def __call__(self, v: jax.Array, axes=None, n_total=None):
        from repro.core import fakewords

        return fakewords.encode(v, self.config.quantization, self.config.store_dtype), None


@dataclasses.dataclass(frozen=True)
class MinHashTransform:
    """Lexical LSH: MinHash signatures (row-local)."""

    config: LexicalLshConfig

    def __call__(self, v: jax.Array, axes=None, n_total=None):
        from repro.core import lexical_lsh

        return lexical_lsh.encode(v, self.config), None


@dataclasses.dataclass(frozen=True)
class ReductionTransform:
    """k-d tree: fit PPA/PCA from (psum-able) global moments, project rows.
    The fitted model rides along as the transform's aux output and lands in
    the index pytree (queries project through it at search time)."""

    config: KdTreeConfig

    def __call__(self, v: jax.Array, axes=None, n_total=None):
        model, reduced = pca.fit_reduction(
            v, self.config.dims, self.config.reduction, self.config.ppa_remove,
            axes=axes, n_total=n_total,
        )
        return reduced.astype(jnp.float32), model


@dataclasses.dataclass(frozen=True)
class IdentityTransform:
    """Brute force: the unit-normalized rows themselves."""

    def __call__(self, v: jax.Array, axes=None, n_total=None):
        return v, None


# --------------------------------------------------------------------------
# Primary-postings quantization (docs/DESIGN.md §12)
# --------------------------------------------------------------------------


def quantize_postings(
    mat: jax.Array, bits: int = 8, group: int = 32
) -> QuantizedPostings:
    """Quantize a posting matrix row-locally (shards and segments freely).

    bits=8: symmetric per-doc scale = max|row|/127, q = round(mat/scale)
    int8.  Because the scale is constant per row it factorizes out of the
    query dot, so dequantization is ONE multiply per (query, doc) after the
    reduction — the fused kernel applies it at merge time.

    bits=4: grouped scale over ``group`` consecutive columns (the term/dim
    axis is zero-padded to a multiple of ``group`` first, so groups align);
    scale = max|group|/7, nibble = clip(round(v/scale), -8, 7) + 8, adjacent
    column pairs packed low|high into one uint8.  Zero pad columns encode as
    nibble 8 and dequantize to exactly 0.  Per-element reconstruction error
    is bounded by scale/2 (round-to-nearest within a covered range).
    """
    m = mat.astype(jnp.float32)
    n, t = m.shape
    if bits == 8:
        amax = jnp.max(jnp.abs(m), axis=-1, keepdims=True)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.round(m / scale).astype(jnp.int8)
        return QuantizedPostings(q=q, scale=scale, bits=8, group=0, cols=t)
    assert bits == 4, f"bits must be 8 or 4, got {bits}"
    tg = ((t + group - 1) // group) * group
    if tg != t:
        m = jnp.pad(m, ((0, 0), (0, tg - t)))
    grouped = m.reshape(n, tg // group, group)
    amax = jnp.max(jnp.abs(grouped), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 7.0  # (n, tg/group) f32
    nib = jnp.clip(jnp.round(grouped / scale[:, :, None]), -8, 7) + 8
    nib = nib.reshape(n, tg).astype(jnp.uint8)
    packed = (nib[:, 0::2] | (nib[:, 1::2] << 4)).astype(jnp.uint8)
    return QuantizedPostings(q=packed, scale=scale, bits=4, group=group, cols=t)


def dequantize_postings(pq: QuantizedPostings, dtype=jnp.float32) -> jax.Array:
    """Reconstruct the (N, cols) posting matrix in ``dtype``.

    Runs the CANONICAL dequant ordering (``repro.kernels.common``) both the
    Pallas kernel and the XLA reference scoring paths implement: f32
    (nibble - 8) * group_scale (int4) / f32 value * doc_scale (int8), THEN
    cast to the compute dtype.  Materializes the full matrix — for blockmax
    bounds / tests / error analysis, never on the streaming read path.
    """
    from repro.kernels import common

    if pq.bits == 8:
        return (pq.q.astype(jnp.float32) * pq.scale).astype(dtype)
    deq = common.dequant_int4(pq.q, pq.scale, pq.group, dtype)
    return deq[:, : pq.cols]


@dataclasses.dataclass(frozen=True)
class PostingsQuantizer:
    """BuildPipeline quantize stage: packs the method's match-stage posting
    matrix (classic ``scored`` / dot ``tf`` / brute-force vectors) into a
    :class:`QuantizedPostings` store.  Row-local, so it shards freely."""

    bits: int = 8
    group: int = 32

    def __call__(self, mat: jax.Array) -> QuantizedPostings:
        return quantize_postings(mat, self.bits, self.group)


# --------------------------------------------------------------------------
# Postings assembly
# --------------------------------------------------------------------------


def live_df(tf: jax.Array, live: Optional[jax.Array] = None) -> jax.Array:
    """Per-term document frequency over the (optionally live-masked) rows.
    Integer sum, so accumulating it per shard (psum) or per segment
    (docs/DESIGN.md §11) matches the single-host count bit-for-bit."""
    present = tf > 0
    if live is not None:
        present = present & live[:, None]
    return jnp.sum(present, axis=0).astype(jnp.int32)


def idf_from_df(df: jax.Array, n_total) -> jax.Array:
    """Lucene ClassicSimilarity idf = 1 + ln(N / (df + 1))."""
    return 1.0 + jnp.log(n_total / (df.astype(jnp.float32) + 1.0))


def classic_scored(tf: jax.Array, idf: jax.Array, norm: jax.Array) -> jax.Array:
    """Per-(doc, term) classic scoring matrix sqrt(tf_d)*idf^2*norm_d (bf16)
    so query scoring is one GEMM.  Row-local given idf: the ONE formula both
    the build stage and the segmented stats refresh (docs/DESIGN.md §11)
    evaluate, so a segment rescored under global statistics matches a
    monolithic build bit-for-bit."""
    tf_f = tf.astype(jnp.float32)
    return (jnp.sqrt(tf_f) * (idf**2)[None, :] * norm[:, None]).astype(
        jnp.bfloat16
    )


@dataclasses.dataclass(frozen=True)
class FakeWordsPostings:
    """df/idf/norm statistics + optional precomputed classic scoring matrix.
    df is the ONE global statistic: psum'd under ``axes`` (integer sum, so
    sharded idf/scored match the single-host build bit-for-bit).

    With a ``quantizer`` (docs/DESIGN.md §12) the match-stage store is
    packed AFTER the statistics: classic quantizes the scored matrix (df/idf
    are computed pre-quantization, so global scoring is unchanged) and drops
    the bf16 ``scored`` leaf; dot int8 is a no-op (the native int8 ``tf`` IS
    the int8 store); dot int4 packs ``tf`` and drops the leaf (``df`` then
    freezes Lucene-style until a merge rebuilds it)."""

    config: FakeWordsConfig
    quantizer: Optional[PostingsQuantizer] = None

    def __call__(self, tf, model, v, store, n_total, axes=None) -> FakeWordsIndex:
        df = live_df(tf)
        if axes is not None:
            df = jax.lax.psum(df, axes)
        idf = idf_from_df(df, n_total)
        doc_len = jnp.sum(tf.astype(jnp.float32), axis=-1)
        norm = jax.lax.rsqrt(jnp.maximum(doc_len, 1.0))
        scored = pq = None
        if self.config.scoring == "classic":
            scored = classic_scored(tf, idf, norm)
            if self.quantizer is not None:
                pq = self.quantizer(scored)
                scored = None
        elif self.quantizer is not None and self.quantizer.bits == 4:
            pq = self.quantizer(tf)
            tf = None
        return FakeWordsIndex(
            tf=tf, idf=idf, norm=norm, df=df, scored=scored, pq=pq, **store
        )


@dataclasses.dataclass(frozen=True)
class LshPostings:
    """Signatures carry their own statistics: pure container assembly."""

    def __call__(self, sig, model, v, store, n_total, axes=None) -> LshIndex:
        return LshIndex(sig=sig, **store)


@dataclasses.dataclass(frozen=True)
class KdTreePostings:
    """Reduced points + precomputed scan-kernel lift; the faithful tree
    arrays (backend='tree') are host-side numpy and local-build only."""

    config: KdTreeConfig

    def __call__(self, reduced, model, v, store, n_total, axes=None) -> KdTreeIndex:
        from repro.kernels.fused_topk import ops as fused

        split_dim = split_val = perm = None
        if self.config.backend == "tree":
            if axes is not None:
                raise ValueError(_TREE_BUILD_MSG)
            from repro.core import kdtree

            sd, sv, pm, _ = kdtree._build_arrays(
                np.asarray(reduced), self.config.leaf_size
            )
            split_dim, split_val, perm = (
                jnp.asarray(sd), jnp.asarray(sv), jnp.asarray(pm)
            )
        return KdTreeIndex(
            reduced=reduced,
            reduction=model,
            split_dim=split_dim,
            split_val=split_val,
            perm=perm,
            lifted=fused.lift_l2(reduced),
            **store,
        )


@dataclasses.dataclass(frozen=True)
class GraphPostings:
    """Flat proximity-graph stage (docs/DESIGN.md §15): exact-kNN candidate
    pools -> Vamana robust prune -> reverse-edge fill -> fixed-degree int32
    adjacency + entry points.  The unit rows are the match operand (neighbor
    blocks gather from them), so they are kept regardless of the rerank
    store, like :class:`FlatPostings`.  Under ``axes`` the candidate pools
    circulate the shard ring as neighbor-exchange rounds
    (``graph.build_graph_sharded``)."""

    config: GraphConfig

    def __call__(self, rep, model, v, store, n_total, axes=None) -> GraphIndex:
        from repro.core import graph

        if axes is None:
            neighbors, entry = graph.build_graph(v, self.config)
        else:
            neighbors, entry = graph.build_graph_sharded(
                v, self.config, axes=axes, n_total=n_total)
        return GraphIndex(
            vectors=v, neighbors=neighbors, entry=entry, vq=store["vq"]
        )


@dataclasses.dataclass(frozen=True)
class FlatPostings:
    """Brute force: the normalized rows ARE the match operand, so the exact
    fp32 vectors are kept regardless of the rerank-store choice — unless a
    ``quantizer`` replaces the match operand with packed int8/int4 postings
    (docs/DESIGN.md §12), in which case the fp32 rows survive only if the
    rerank store keeps them."""

    quantizer: Optional[PostingsQuantizer] = None

    def __call__(self, rep, model, v, store, n_total, axes=None) -> FlatIndex:
        if self.quantizer is None:
            return FlatIndex(vectors=v, vq=store["vq"])
        return FlatIndex(
            vectors=store["vectors"], vq=store["vq"], pq=self.quantizer(v)
        )


# --------------------------------------------------------------------------
# Metadata stage (docs/DESIGN.md §13)
# --------------------------------------------------------------------------


def build_metadata(metadata, n_docs: int) -> Optional[DocMetadata]:
    """Normalize the build-time ``metadata=`` argument into a
    :class:`repro.core.types.DocMetadata` store: ``None`` passes through, a
    ``{field: (N,) ints}`` mapping stacks into the (N, F) matrix, an
    existing DocMetadata is validated.  Row-local (doc-axis only), so it
    shards and segments exactly like the rerank stores."""
    if metadata is None:
        return None
    md = (
        metadata
        if isinstance(metadata, DocMetadata)
        else DocMetadata.from_fields(metadata)
    )
    if md.num_docs != n_docs:
        raise ValueError(
            f"metadata has {md.num_docs} rows but the corpus has {n_docs}"
        )
    return md


# --------------------------------------------------------------------------
# Rerank stores
# --------------------------------------------------------------------------


def quantize_store(v: jax.Array) -> QuantizedStore:
    """Symmetric per-doc int8 quantization: scale = max|v_row|/127,
    q = round(v/scale).  Row-local (shards freely)."""
    amax = jnp.max(jnp.abs(v.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.round(v / scale[:, None]).astype(jnp.int8)
    return QuantizedStore(q=q, scale=scale.astype(jnp.float32))


@dataclasses.dataclass(frozen=True)
class ExactRerankStore:
    """Keep the fp32 unit-normalized originals (the PR-3 default)."""

    def __call__(self, v: jax.Array) -> dict:
        return {"vectors": v, "vq": None}


@dataclasses.dataclass(frozen=True)
class QuantizedRerankStore:
    """int8 + per-doc scale instead of fp32 originals: ~4x fewer rerank
    gather bytes at a bounded score error (docs/DESIGN.md §8)."""

    def __call__(self, v: jax.Array) -> dict:
        return {"vectors": None, "vq": quantize_store(v)}


@dataclasses.dataclass(frozen=True)
class NoRerankStore:
    """No rerank operand (build-time opt-out; rerank=True will fail)."""

    def __call__(self, v: jax.Array) -> dict:
        return {"vectors": None, "vq": None}


_STORES = {
    "exact": ExactRerankStore(),
    "int8": QuantizedRerankStore(),
    "none": NoRerankStore(),
}


# --------------------------------------------------------------------------
# The pipeline
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BuildPipeline:
    """normalize -> transform -> postings -> rerank store.

    Frozen and hashable, like :class:`repro.core.pipeline.SearchPipeline`:
    a build pipeline is a static description of *how* to build; all array
    state flows through the call.  ``build_local`` and ``build_sharded``
    run the SAME stage objects — the only difference is ``axes`` (which
    turns the global-statistic reductions into psums under ``shard_map``).
    """

    config: AnyConfig
    transform: Any
    postings: Any
    store: Any = ExactRerankStore()

    def _assemble(self, v, n_total, axes=None):
        rep, model = self.transform(v, axes=axes, n_total=n_total)
        return self.postings(rep, model, v, self.store(v), n_total, axes=axes)

    def build_local(self, vectors: jax.Array, normalized: bool = False):
        """Single-host build (what the per-method ``build()`` wrappers
        call)."""
        v = jnp.asarray(vectors)
        v = v if normalized else bruteforce.l2_normalize(v)
        return self._assemble(v, n_total=v.shape[0])

    def sharded_build_fn(
        self, mesh, axes: Sequence[str], n_total: int, normalized: bool = False
    ):
        """The ``shard_map``-wrapped per-shard build: ``fn(vectors) ->
        index`` with doc-sharded leaves.  Reusable across calls (jit caches
        one compilation) — ``build_sharded`` is the one-shot convenience."""
        from repro import compat
        from repro.core import distributed

        axes = tuple(axes)
        if isinstance(self.config, KdTreeConfig) and self.config.backend == "tree":
            raise ValueError(_TREE_BUILD_MSG)

        def local_build(v):
            # Normalization is row-local, so honoring ``normalized`` here
            # keeps the sharded branch argument-for-argument equal to
            # build_local.
            v = v if normalized else bruteforce.l2_normalize(v)
            return self._assemble(v, n_total=n_total, axes=axes)

        quantizer = getattr(self.postings, "quantizer", None)
        out_specs = distributed.config_pspec(
            self.config, axes,
            keep_vectors=isinstance(self.store, ExactRerankStore)
            or (isinstance(self.config, BruteForceConfig) and quantizer is None),
            quantized_store=isinstance(self.store, QuantizedRerankStore),
            postings_bits=quantizer.bits if quantizer is not None else 0,
        )
        # Replicated leaves (idf/df, reduction model) come out of psums the
        # static replication checker cannot always prove; disable it — the
        # sharded==local parity tests are the real guarantee.
        return compat.shard_map(
            local_build, mesh=mesh, in_specs=jax.sharding.PartitionSpec(axes, None),
            out_specs=out_specs, check_vma=False,
        )

    def build_sharded(
        self,
        mesh,
        vectors: jax.Array,
        axes: Sequence[str],
        normalized: bool = False,
    ):
        """Row-parallel build under ``shard_map``: every doc-sharded leaf is
        computed from shard-local rows; global statistics (df, reduction
        moments) travel through psums.  No stage materializes the full
        corpus on any shard."""
        from repro.core import distributed

        n = vectors.shape[0]
        n_shards = distributed.flat_axis_size(mesh, tuple(axes))
        assert n % n_shards == 0, (
            f"corpus size {n} not divisible by {n_shards} shards"
        )
        return self.sharded_build_fn(mesh, axes, n, normalized=normalized)(vectors)

    def build(
        self,
        vectors: jax.Array,
        mesh=None,
        axes: Sequence[str] = ("data",),
        normalized: bool = False,
    ):
        """Single entry point: local when ``mesh`` is None, else sharded."""
        if mesh is None:
            return self.build_local(vectors, normalized=normalized)
        return self.build_sharded(mesh, vectors, axes, normalized=normalized)


def make_build_pipeline(
    config: AnyConfig,
    rerank_store: str = "exact",
    primary_postings: str = "fp32",
    postings_group: int = 32,
) -> BuildPipeline:
    """Every method is a stage configuration (the build-side analog of
    ``pipeline.build_pipeline``).  ``rerank_store``: "exact" | "int8" |
    "none".  ``primary_postings``: "fp32" (store the match operand as
    built) | "int8" (per-doc scale) | "int4" (grouped scale, group size
    ``postings_group`` in {32, 64}) — docs/DESIGN.md §12."""
    if rerank_store not in _STORES:
        raise ValueError(
            f"rerank_store must be one of {RERANK_STORES}, got {rerank_store!r}"
        )
    if primary_postings not in PRIMARY_POSTINGS:
        raise ValueError(
            f"primary_postings must be one of {PRIMARY_POSTINGS}, "
            f"got {primary_postings!r}"
        )
    store = _STORES[rerank_store]
    quantizer = None
    if primary_postings != "fp32":
        if isinstance(config, (LexicalLshConfig, KdTreeConfig, GraphConfig)):
            raise ValueError(_QUANT_POSTINGS_MSG)
        if postings_group not in POSTINGS_GROUPS:
            raise ValueError(
                f"postings_group must be one of {POSTINGS_GROUPS}, "
                f"got {postings_group}"
            )
        quantizer = PostingsQuantizer(
            bits=8 if primary_postings == "int8" else 4, group=postings_group
        )
    if isinstance(config, FakeWordsConfig):
        return BuildPipeline(
            config, TfTransform(config), FakeWordsPostings(config, quantizer),
            store,
        )
    if isinstance(config, LexicalLshConfig):
        return BuildPipeline(config, MinHashTransform(config), LshPostings(), store)
    if isinstance(config, KdTreeConfig):
        return BuildPipeline(config, ReductionTransform(config), KdTreePostings(config), store)
    if isinstance(config, BruteForceConfig):
        return BuildPipeline(
            config, IdentityTransform(), FlatPostings(quantizer), store
        )
    if isinstance(config, GraphConfig):
        return BuildPipeline(
            config, IdentityTransform(), GraphPostings(config), store
        )
    raise TypeError(f"unknown config {type(config)}")
