"""Fake-words ANN encoding (paper §2, after Amato et al. 2016).

A unit-normalized vector w = (w_1..w_m) becomes a bag of synthetic terms where
feature i's term tau_i appears round(Q * w_i) times.  Term frequency is then
proportional to the feature value, so Lucene's tf-idf match score approximates
the inner product (== cosine on unit vectors).

TPU adaptation (docs/DESIGN.md §3): negative features are handled by sign-splitting
into 2m terms (Amato et al.'s CReLU-style trick); the posting lists become a
dense (N, 2m) int8 term-frequency matrix and the inverted-index scoring loop
becomes an int8 GEMM on the MXU.  Lucene semantics preserved:

  * ClassicSimilarity: score(q,d) = sum_t tf_q(t) * sqrt(tf_d(t)) * idf(t)^2
    * norm(d), idf(t) = 1 + ln(N/(df(t)+1)), norm(d) = 1/sqrt(doc_len(d)).
    (queryNorm and coord are rank-preserving constants; dropped.)
  * High-df term filtering at search time = zeroing pruned query columns
    (identical to Lucene dropping those terms from the query).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bruteforce
from repro.core.types import FakeWordsConfig, FakeWordsIndex


# --------------------------------------------------------------------------
# Encoding
# --------------------------------------------------------------------------


def encode(vectors: jax.Array, quantization: int, dtype=jnp.int8) -> jax.Array:
    """Sign-split quantized term frequencies: (N, m) floats -> (N, 2m) ints.

    Columns [0, m) = round(Q * relu(w)); columns [m, 2m) = round(Q * relu(-w)).
    Assumes unit-normalized input (|w_i| <= 1 => tf <= Q <= 127 fits int8).
    """
    q = jnp.asarray(quantization, vectors.dtype)
    pos = jnp.round(q * jnp.maximum(vectors, 0.0))
    neg = jnp.round(q * jnp.maximum(-vectors, 0.0))
    tf = jnp.concatenate([pos, neg], axis=-1)
    return tf.astype(dtype)


def doc_stats(tf: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(df, idf, norm) from a term-frequency matrix, Lucene-style."""
    n = tf.shape[0]
    tf_f = tf.astype(jnp.float32)
    df = jnp.sum(tf > 0, axis=0).astype(jnp.int32)  # (2m,)
    idf = 1.0 + jnp.log(n / (df.astype(jnp.float32) + 1.0))
    doc_len = jnp.sum(tf_f, axis=-1)  # (N,)
    norm = jax.lax.rsqrt(jnp.maximum(doc_len, 1.0))
    return df, idf, norm


def build(
    vectors: jax.Array,
    config: FakeWordsConfig,
    keep_vectors: bool = True,
    normalized: bool = False,
) -> FakeWordsIndex:
    """Build the fake-words index.  Unlike Lucene's O(Q) repeated-token
    indexing cost per feature, we store tf directly (O(1) per feature).

    Thin wrapper over the shared staged :class:`repro.core.builder.
    BuildPipeline` (TfTransform -> FakeWordsPostings -> rerank store);
    the same stages build row-parallel on a mesh via
    ``BuildPipeline.build_sharded`` / ``distributed.build_sharded``."""
    from repro.core import builder

    bp = builder.make_build_pipeline(
        config, "exact" if keep_vectors else "none"
    )
    return bp.build_local(vectors, normalized=normalized)


def encode_queries(
    queries: jax.Array, config: FakeWordsConfig, normalized: bool = False
) -> jax.Array:
    q = queries if normalized else bruteforce.l2_normalize(queries)
    return encode(q, config.quantization, jnp.int32)


def df_prune_mask(df: jax.Array, num_docs: int, df_max_ratio: float) -> jax.Array:
    """Boolean keep-mask over terms (True = keep).  The paper's search-time
    high-frequency filtering; also the df-pruning roofline lever."""
    if df_max_ratio >= 1.0:
        return jnp.ones_like(df, dtype=bool)
    return df <= jnp.int32(df_max_ratio * num_docs)


# --------------------------------------------------------------------------
# Scoring
# --------------------------------------------------------------------------


def classic_query(
    index: FakeWordsIndex,
    q_tf: jax.Array,
    df_max_ratio: float = 1.0,
    num_docs: Optional[int] = None,
) -> jax.Array:
    """bf16 classic-mode query operand with the df-prune keep-mask folded in
    (the single source of truth for every classic scoring path).

    ``num_docs`` overrides the prune threshold's collection size: a segment
    of a :class:`repro.core.segments.SegmentedAnnIndex` masks against the
    GLOBAL live-doc count (its ``df`` leaf already holds the global df), not
    its own row count."""
    assert index.scored is not None or index.pq is not None, (
        "index was built with scoring='dot'"
    )
    n = index.num_docs if num_docs is None else num_docs
    keep = df_prune_mask(index.df, n, df_max_ratio)
    return (q_tf * keep).astype(jnp.bfloat16)


def signed_query(q_tf: jax.Array, dtype=jnp.int32) -> jax.Array:
    """Signed quantized query u = q+ - q- (B, m) from the sign-split
    (B, 2m) encoding.  This is the operand for scoring against a SIGNED
    stored matrix, and ``[relu(u); relu(-u)]`` == the sign-split encoding
    itself — which is why blockmax dot bounds stay one GEMM against q_tf."""
    m = q_tf.shape[-1] // 2
    return (q_tf[:, :m].astype(jnp.int32) - q_tf[:, m:].astype(jnp.int32)).astype(dtype)


def dot_query(
    index: FakeWordsIndex,
    q_tf: jax.Array,
    df_max_ratio: float = 1.0,
    dtype=jnp.int32,
    num_docs: Optional[int] = None,
) -> jax.Array:
    """Dot-mode query operand: the [u; -u] sign-split lift (u = q+ - q-)
    with the keep-mask folded in.  ``dtype`` is int32 for the XLA einsum,
    int8 for the MXU integer kernel path.  ``num_docs`` overrides the prune
    threshold's collection size (see :func:`classic_query`)."""
    n = index.num_docs if num_docs is None else num_docs
    keep = df_prune_mask(index.df, n, df_max_ratio)
    u = signed_query(q_tf)
    return (jnp.concatenate([u, -u], axis=-1) * keep).astype(dtype)


def classic_scores(
    index: FakeWordsIndex, q_tf: jax.Array, df_max_ratio: float = 1.0
) -> jax.Array:
    """Lucene ClassicSimilarity scores for all docs: (B, N).

    scored[d,t] already folds sqrt(tf_d)*idf^2*norm_d; the query side
    contributes its own tf (repeated query tokens sum in Lucene)."""
    qv = classic_query(index, q_tf, df_max_ratio)
    return jnp.einsum(
        "bt,nt->bn", qv, index.scored, preferred_element_type=jnp.float32
    )


def dot_scores(
    index: FakeWordsIndex, q_tf: jax.Array, df_max_ratio: float = 1.0
) -> jax.Array:
    """Idealized integer-dot scores: <T_d, t_q>/Q^2 ~= cosine.

    With u = q+ - q- (the signed quantized query, m dims), the signed dot
    (d+ - d-) . u equals [d+; d-] . [u; -u], so scoring stays a single GEMM
    over the stored sign-split (N, 2m) matrix with the query lifted to
    [u; -u]."""
    qv = dot_query(index, q_tf, df_max_ratio)
    return jnp.einsum(
        "bt,nt->bn",
        qv,
        index.tf.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)


def search(
    index: FakeWordsIndex,
    q_tf: jax.Array,
    queries: Optional[jax.Array],
    k: int = 10,
    depth: int = 100,
    scoring: str = "classic",
    rerank: bool = False,
    df_max_ratio: float = 1.0,
    use_kernel: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Two-phase search: match depth-d candidates on the fake-words index,
    optionally exact-rerank to k using the stored original vectors.

    Thin wrapper over the shared staged pipeline
    (:class:`repro.core.pipeline.FakeWordsMatcher` + exact rerank);
    ``use_kernel`` routes the match phase through the fused streaming
    score->top-k Pallas kernel (docs/DESIGN.md §4), which never writes the
    (B, N) score matrix to HBM.  Default: kernel on TPU, XLA elsewhere."""
    from repro.core import pipeline as pl

    matcher = pl.FakeWordsMatcher(scoring=scoring, df_max_ratio=df_max_ratio)
    return pl.match_rerank(
        matcher, index, q_tf, queries, k, depth, rerank, use_kernel=use_kernel
    )
