"""Packed single-launch segmented search (docs/DESIGN.md §14).

The per-segment loop in :mod:`repro.core.segments` is faithful Lucene — and
pays Lucene's launch tax on an accelerator: a 16-segment NRT index costs 16
matcher dispatches, 16 device round-trips, and a host-side merge per query
batch.  This module packs every live segment's stat view into ONE padded
superbuffer so the fused streaming top-k launches once per batch regardless
of segment count:

  * **Layout.**  Per-doc leaves (postings, signatures, reduced points,
    rerank stores) concatenate in GLOBAL-ID ORDER with no inter-segment
    padding, so packed row ``g`` IS global doc id ``g`` — the offset remap
    is the identity by construction and the kernel emits global ids
    directly.  Global leaves (df/idf, the fitted reduction) come from the
    stat views, which already share them across segments.
  * **Bucket ladder.**  Only the tail pads, up to a small geometric ladder
    (powers of two and 1.5x steps, ≤ 33% overhead), so executable shapes
    recur across flush/merge/refresh cycles instead of recompiling per
    corpus size.  Tail rows are zeros and can never rank: they are masked
    through the same in-kernel ``filt`` bitmap that masks deletes (dynamic
    content, static shape — no recompile per add), or via the kernels'
    static ``n_docs`` ragged-row bound for shape-static callers.
  * **Executable cache.**  A bounded, explicitly keyed LRU of AOT-compiled
    executables (:class:`ExecutableCache`); the key is (static knobs,
    pytree structure, leaf avals), so refresh cycles within one bucket are
    zero-compile.  ``EXEC_CACHE.compiles`` makes the recompile-guard test
    honest.
  * **Donated incremental repack.**  For stats-static encodings (dot-mode
    fake words, LSH, brute force) a refresh that only appends segments
    reuses the previous snapshot's packed buffers via a donated
    ``dynamic_update_slice`` — the superbuffer is updated in place instead
    of re-concatenated (classic/kd views rebuild per-row state under new
    global stats, so they repack fully).

Parity: per-row scores are row-local reductions, so packing rows does not
change them; global-id ordering + ``lax.top_k``'s stable ties reproduce the
loop's segment-major merge tie-break; the rerank gathers the identical rows
into the identical candidate positions and runs the identical einsum.  The
per-segment loop remains available (``search(packed=False)``) as the
reference path and serves any layout this module rejects.
"""
from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import (
    BruteForceConfig,
    FakeWordsConfig,
    KdTreeConfig,
    LexicalLshConfig,
    QuantizedPostings,
    QuantizedStore,
)

__all__ = [
    "PackedUnsupported",
    "PackedSegments",
    "ExecutableCache",
    "EXEC_CACHE",
    "bucket_rows",
    "pack_segments",
    "packed_search",
    "packed_blockmax",
]


class PackedUnsupported(ValueError):
    """This snapshot cannot ride the packed single-launch path (mixed
    per-segment store layouts, per-segment statistics, ...); callers fall
    back to the per-segment loop."""


# --------------------------------------------------------------------------
# Bucket ladder
# --------------------------------------------------------------------------

BUCKET_FLOOR = 256


def bucket_rows(n: int, floor: int = BUCKET_FLOOR) -> int:
    """Round a row count up the geometric ladder {floor, ..., 2^k, 3·2^k-1}
    (powers of two interleaved with their 1.5x midpoints).  Worst-case pad
    overhead is 33%; in exchange, every snapshot whose total lands in the
    same rung reuses the same compiled executables."""
    if n <= floor:
        return floor
    p = 1 << (n - 1).bit_length()  # next power of two >= n
    mid = 3 * (p // 4)             # 1.5 * previous power of two
    return mid if mid >= n else p


def _append_block(n: int, room: int = 1 << 30, floor: int = 128) -> int:
    """Pad an appended segment block to a power of two so the donated
    incremental-repack executable recompiles per block RUNG, not per flush
    size.  Near the top of the bucket the preferred rung may overhang the
    remaining ``room`` even though the rows themselves fit; halve down to
    the largest rung that fits (>= 8 rows, the f32 sublane) instead of
    forcing callers into a full repack — each smaller rung costs at most
    one extra compile per encoding, ever.  Returns 0 when no aligned rung
    can hold ``n`` rows in ``room``."""
    block = max(floor, 1 << (n - 1).bit_length())
    while block > room and block >= 16:
        block //= 2
    if block > room or block < n:
        return 0
    return block


# --------------------------------------------------------------------------
# Leaf packing
# --------------------------------------------------------------------------


def _cat_pad(parts: Sequence[jax.Array], rows: int) -> jax.Array:
    """Concatenate per-segment per-doc leaves along rows and zero-pad the
    tail to ``rows``.  Zero padding is load-bearing: pad rows are masked at
    search time, and the donated append path overwrites tail rows assuming
    they hold zeros."""
    x = parts[0] if len(parts) == 1 else jnp.concatenate(list(parts), axis=0)
    pad = rows - x.shape[0]
    if pad < 0:
        raise PackedUnsupported(
            f"segment rows {x.shape[0]} exceed bucket {rows}"
        )
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0
    )


def _all_or_none(views: Sequence[Any], name: str) -> Optional[List[Any]]:
    vals = [getattr(v, name) for v in views]
    if all(v is None for v in vals):
        return None
    if any(v is None for v in vals):
        raise PackedUnsupported(
            f"mixed per-segment presence of {name!r} (some segments carry "
            "it, some do not) — per-segment loop only"
        )
    return vals


def _pack_vq(views: Sequence[Any], rows: int) -> Optional[QuantizedStore]:
    vqs = _all_or_none(views, "vq")
    if vqs is None:
        return None
    return QuantizedStore(
        q=_cat_pad([s.q for s in vqs], rows),
        scale=_cat_pad([s.scale for s in vqs], rows),
    )


def _pack_pq(views: Sequence[Any], rows: int) -> Optional[QuantizedPostings]:
    pqs = _all_or_none(views, "pq")
    if pqs is None:
        return None
    meta = {(p.bits, p.group, p.cols, p.q.shape[1:]) for p in pqs}
    if len(meta) > 1:
        raise PackedUnsupported(
            f"segments disagree on quantized-postings layout: {sorted(meta)}"
        )
    return dataclasses.replace(
        pqs[0],
        q=_cat_pad([p.q for p in pqs], rows),
        scale=_cat_pad([p.scale for p in pqs], rows),
    )


def _packed_view(config, views: Sequence[Any], rows: int):
    """One synthetic index view with every per-doc leaf packed to ``rows``;
    global leaves (df/idf/reduction) carry over from the stat views."""
    v0 = views[0]
    repl: Dict[str, Any] = {"vq": _pack_vq(views, rows)}
    if isinstance(config, FakeWordsConfig):
        repl["pq"] = _pack_pq(views, rows)
        repl["norm"] = _cat_pad([v.norm for v in views], rows)
        for name in ("tf", "scored", "vectors"):
            vals = _all_or_none(views, name)
            repl[name] = None if vals is None else _cat_pad(vals, rows)
        return dataclasses.replace(v0, **repl)
    if isinstance(config, LexicalLshConfig):
        repl["sig"] = _cat_pad([v.sig for v in views], rows)
        vecs = _all_or_none(views, "vectors")
        repl["vectors"] = None if vecs is None else _cat_pad(vecs, rows)
        return dataclasses.replace(v0, **repl)
    if isinstance(config, KdTreeConfig):
        from repro.kernels.fused_topk import ops as fused

        repl["reduced"] = _cat_pad([v.reduced for v in views], rows)
        repl["lifted"] = _cat_pad(
            [
                v.lifted if v.lifted is not None else fused.lift_l2(v.reduced)
                for v in views
            ],
            rows,
        )
        repl["split_dim"] = repl["split_val"] = repl["perm"] = None
        vecs = _all_or_none(views, "vectors")
        repl["vectors"] = None if vecs is None else _cat_pad(vecs, rows)
        return dataclasses.replace(v0, **repl)
    if isinstance(config, BruteForceConfig):
        repl["pq"] = _pack_pq(views, rows)
        vecs = _all_or_none(views, "vectors")
        repl["vectors"] = None if vecs is None else _cat_pad(vecs, rows)
        if repl["vectors"] is None and repl["pq"] is None:
            raise PackedUnsupported(
                "brute-force segments carry neither vectors nor postings"
            )
        return dataclasses.replace(v0, **repl)
    raise PackedUnsupported(
        f"no packed layout for config type {type(config).__name__}"
    )


def _doc_leaf_paths(config, view) -> List[Tuple[str, ...]]:
    """Attribute paths of every per-doc leaf present on a packed view (the
    leaves the donated incremental repack must update in place)."""
    names = {
        FakeWordsConfig: ("tf", "scored", "norm", "vectors"),
        LexicalLshConfig: ("sig", "vectors"),
        KdTreeConfig: ("reduced", "lifted", "vectors"),
        BruteForceConfig: ("vectors",),
    }[type(config)]
    paths: List[Tuple[str, ...]] = [
        (n,) for n in names if getattr(view, n, None) is not None
    ]
    for store in ("vq", "pq"):
        s = getattr(view, store, None)
        if s is not None:
            paths += [(store, "q"), (store, "scale")]
    return paths


def _get_path(view, path: Tuple[str, ...]):
    x = view
    for p in path:
        x = getattr(x, p)
    return x


def _replace_paths(view, updates: Dict[Tuple[str, ...], jax.Array]):
    """Rebuild a view with the given (possibly nested) leaves replaced."""
    top: Dict[str, Any] = {}
    nested: Dict[str, Dict[str, Any]] = {}
    for path, val in updates.items():
        if len(path) == 1:
            top[path[0]] = val
        else:
            nested.setdefault(path[0], {})[path[1]] = val
    for store, fields in nested.items():
        top[store] = dataclasses.replace(getattr(view, store), **fields)
    return dataclasses.replace(view, **top)


# --------------------------------------------------------------------------
# Executable cache
# --------------------------------------------------------------------------


class ExecutableCache:
    """Bounded LRU of AOT-compiled executables, explicitly keyed.

    jit's implicit cache already avoids recompiles — per live function
    object.  The packed path rebuilds its staged closures per snapshot, so
    it needs a cache keyed on what ACTUALLY determines the executable:
    static knobs + pytree structure + leaf avals.  AOT ``lower().compile()``
    on miss makes ``compiles`` an honest counter (a cache hit can never
    silently recompile), which is what the recompile-guard test asserts
    against."""

    def __init__(self, capacity: int = 64):
        self.capacity = capacity
        self._entries: "OrderedDict[Any, Any]" = OrderedDict()
        self.hits = 0
        self.compiles = 0
        self.evictions = 0

    @staticmethod
    def _avals(args) -> Tuple[Any, Tuple]:
        flat, treedef = jax.tree_util.tree_flatten(args)
        return treedef, tuple(
            (tuple(x.shape), jnp.result_type(x).name) for x in flat
        )

    def get(self, key, build_fn, args, donate_argnums: Tuple[int, ...] = ()):
        """The compiled executable for ``key`` + the avals of ``args``;
        builds (and AOT-compiles) via ``build_fn()`` on miss."""
        full_key = (key, donate_argnums, self._avals(args))
        hit = self._entries.get(full_key)
        if hit is not None:
            self._entries.move_to_end(full_key)
            self.hits += 1
            return hit
        jitted = jax.jit(build_fn(), donate_argnums=donate_argnums)
        try:
            exe = jitted.lower(*args).compile()
        except Exception:
            # AOT lowering is an optimization (pins avals, honest compile
            # accounting); a backend that rejects it still serves via the
            # plain jit path.
            exe = jitted
        self.compiles += 1
        self._entries[full_key] = exe
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return exe

    def clear(self) -> None:
        self._entries.clear()
        self.hits = self.compiles = self.evictions = 0

    def stats(self) -> dict:
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "compiles": self.compiles,
            "evictions": self.evictions,
        }


#: Process-wide cache shared by every packed reader (snapshots of one
#: writer land in the same rungs, so sharing is the point).
EXEC_CACHE = ExecutableCache(
    capacity=int(os.environ.get("REPRO_PACKED_CACHE", "64"))
)


# --------------------------------------------------------------------------
# Packed snapshot state
# --------------------------------------------------------------------------


@dataclasses.dataclass
class PackedSegments:
    """One snapshot's packed superbuffer + the masks that make it honest.

    ``view`` is a synthetic single-segment index view with ``bucket`` rows:
    rows [0, n_rows) are the segments' rows in global-id order, rows
    [n_rows, bucket) are zero padding.  ``live`` composes liveDocs ∧
    row-validity into the one bitmap the kernels take."""

    view: Any
    bucket: int
    n_rows: int                    # reader.max_doc (deleted rows included)
    n_live: int                    # reader.num_docs (live rows only)
    live: jax.Array                # (bucket,) bool: live ∧ row < n_rows
    any_deleted: bool
    seg_names: Tuple[str, ...]
    seg_rows: Tuple[int, ...]
    appends: int = 0               # donated incremental repacks absorbed
    bm_cache: Dict[int, Any] = dataclasses.field(default_factory=dict)

    @property
    def full(self) -> bool:
        """No pad rows and no deletes: the packed view needs no masking at
        all and dispatches the exact unfiltered monolithic call graph."""
        return (not self.any_deleted) and self.n_rows == self.bucket


def _stats_static(config) -> bool:
    """Encodings whose stat views keep per-doc leaves untouched across
    refreshes (only GLOBAL leaves move), making append-only incremental
    repack sound.  Classic fake words rebuild ``scored``/``pq`` per row
    under new global idf; the kd reduction refits — both repack fully."""
    if isinstance(config, (LexicalLshConfig, BruteForceConfig)):
        return True
    return isinstance(config, FakeWordsConfig) and config.scoring != "classic"


def _global_leaf_updates(config, views) -> Dict[Tuple[str, ...], jax.Array]:
    """Global (non-per-doc) leaves an incremental repack must refresh from
    the new stat views: dot-mode fake words re-derive df/idf over the new
    live set."""
    if isinstance(config, FakeWordsConfig):
        return {("df",): views[0].df, ("idf",): views[0].idf}
    return {}


def _live_bitmap(segments, n_rows: int, bucket: int) -> jax.Array:
    live = np.zeros(bucket, bool)
    base = 0
    for s in segments:
        live[base : base + s.num_docs] = s.live
        base += s.num_docs
    assert base == n_rows
    return jnp.asarray(live)


def _try_append(
    config, views, segments, prior: "PackedSegments",
    names: Tuple[str, ...], rows: Tuple[int, ...], bucket: int, n_rows: int,
) -> Optional["PackedSegments"]:
    """Absorb an append-only refresh into the prior snapshot's buffers via
    a donated dynamic_update_slice; None when ineligible (full repack)."""
    k = len(prior.seg_names)
    if not (
        _stats_static(config)
        and bucket == prior.bucket
        and len(names) > k
        and names[:k] == prior.seg_names
        and rows[:k] == prior.seg_rows
    ):
        return None
    offset = prior.n_rows
    new_rows = n_rows - offset
    block = _append_block(new_rows, room=bucket - offset)
    if not block:
        return None  # no aligned rung fits: dynamic_update_slice clamps
        # starts, so an overhanging block must never be risked
    paths = _doc_leaf_paths(config, prior.view)
    new_view = _packed_view(config, views[k:], block)
    old_leaves = tuple(_get_path(prior.view, p) for p in paths)
    new_leaves = tuple(_get_path(new_view, p) for p in paths)
    if any(o.shape[1:] != n.shape[1:] or o.dtype != n.dtype
           for o, n in zip(old_leaves, new_leaves)):
        return None

    def build():
        def append(old, new, off):
            return tuple(
                jax.lax.dynamic_update_slice_in_dim(o, nw, off, axis=0)
                for o, nw in zip(old, new)
            )
        return append

    off_dev = jnp.int32(offset)
    exe = EXEC_CACHE.get(
        ("append", type(config).__name__, tuple(paths)),
        build, (old_leaves, new_leaves, off_dev), donate_argnums=(0,),
    )
    updated = exe(old_leaves, new_leaves, off_dev)
    view = _replace_paths(prior.view, dict(zip(paths, updated)))
    view = _replace_paths(view, _global_leaf_updates(config, views))
    # The prior snapshot's buffers are donated: neuter it so a stale reader
    # lazily repacks instead of touching freed memory.
    prior.view = None
    any_del = any(s.del_count for s in segments)
    return PackedSegments(
        view=view, bucket=bucket, n_rows=n_rows,
        n_live=sum(s.num_live for s in segments),
        live=_live_bitmap(segments, n_rows, bucket),
        any_deleted=any_del, seg_names=names, seg_rows=rows,
        appends=prior.appends + 1,
    )


def pack_segments(
    config,
    views: Sequence[Any],
    segments: Sequence[Any],
    global_stats: bool = True,
    prior: Optional["PackedSegments"] = None,
) -> PackedSegments:
    """Pack a snapshot's stat views into one superbuffer.  Raises
    :class:`PackedUnsupported` for layouts the single-launch path cannot
    serve exactly (per-segment statistics, mixed store presence)."""
    if not segments:
        raise PackedUnsupported("no segments to pack")
    if not global_stats and not isinstance(
        config, (LexicalLshConfig, BruteForceConfig)
    ):
        raise PackedUnsupported(
            "global_stats=False scores each segment under its own "
            "statistics — one packed launch cannot reproduce per-segment "
            "query operands"
        )
    names = tuple(s.name for s in segments)
    rows = tuple(s.num_docs for s in segments)
    n_rows = sum(rows)
    bucket = bucket_rows(n_rows)
    if prior is not None and prior.view is not None:
        inc = _try_append(
            config, views, segments, prior, names, rows, bucket, n_rows
        )
        if inc is not None:
            return inc
    view = _packed_view(config, views, bucket)
    return PackedSegments(
        view=view, bucket=bucket, n_rows=n_rows,
        n_live=sum(s.num_live for s in segments),
        live=_live_bitmap(segments, n_rows, bucket),
        any_deleted=any(s.del_count for s in segments),
        seg_names=names, seg_rows=rows,
    )


# --------------------------------------------------------------------------
# Blockmax over the packed view
# --------------------------------------------------------------------------


def packed_blockmax(pk: PackedSegments, config, block_size: int):
    """A BlockMaxIndex over the packed view (the monolithic builder applies
    unchanged — the packed view IS a monolithic index).  Pad/deleted rows
    may inflate stage-1 bounds (optimistic = admissible); stage 2 masks
    them through the live bitmap.  Cached per block size on the snapshot."""
    bm = pk.bm_cache.get(block_size)
    if bm is None:
        from repro.core import blockmax

        bm = blockmax.build_blockmax(
            pk.view, block_size,
            signed_store=getattr(config, "signed_store", False),
        )
        pk.bm_cache[block_size] = bm
    return bm


# --------------------------------------------------------------------------
# The single-launch search
# --------------------------------------------------------------------------


def _pad_mask_cols(fm: jax.Array, bucket: int) -> jax.Array:
    """Pad a (n_rows,) / (B, n_rows) predicate bitmap with zeros to the
    bucket width (pad rows are never keepable)."""
    pad = bucket - fm.shape[-1]
    if pad == 0:
        return fm != 0
    zeros = jnp.zeros(fm.shape[:-1] + (pad,), bool)
    return jnp.concatenate([fm != 0, zeros], axis=-1)


def packed_search(
    pk: PackedSegments,
    pipeline,
    matcher,
    q_norm: jax.Array,
    k: int,
    depth: int,
    rerank: bool,
    quantized: bool,
    use_kernel: Optional[bool],
    fm: Optional[jax.Array] = None,
    static_rows: bool = False,
    n_keep: Optional[int] = None,
    bm=None,
    cache: Optional[ExecutableCache] = None,
) -> Tuple[jax.Array, jax.Array]:
    """ONE compiled launch for the whole segmented snapshot.

    Mask selection (cheapest exact option first):
      * ``pk.full`` and no predicate — no mask at all: the exact unfiltered
        monolithic call graph.
      * no deletes, no predicate, ``static_rows=True`` — the kernels'
        static ``n_docs`` ragged-row bound (no bitmap streamed; executable
        keys on n_rows, so this is for shape-static callers like benches).
      * otherwise — liveDocs ∧ row-validity [∧ predicate] composed into the
        kernels' ``filt`` operand: dynamic content, static shape, so NRT
        refresh cycles never recompile.

    ``k``/``depth`` are the caller's logical knobs; output is
    (scores (B, k_out), ids (B, k_out)) with ``k_out = min(k, depth,
    live docs)`` — exactly the per-segment loop's output width.
    """
    cache = EXEC_CACHE if cache is None else cache
    bucket = pk.bucket
    d_eff = min(depth, pk.n_live)
    k_out = min(k, d_eff)
    if k_out <= 0:
        raise ValueError("packed search over zero live docs")
    q_rep = pipeline.encoder(pk.view, q_norm)

    use_filt = (fm is not None) or pk.any_deleted or (
        pk.n_rows < bucket and not static_rows
    )
    n_docs = None
    if not use_filt and pk.n_rows < bucket:
        n_docs = pk.n_rows  # static_rows: kernel-side ragged bound
    fm_arg = None
    if fm is not None:
        fm_arg = _pad_mask_cols(jnp.asarray(fm), bucket)

    def build():
        def fn(view, live, fm_in, q_rep_in, q_norm_in, bm_in):
            filt = None
            if use_filt:
                filt = live if fm_in is None else (
                    fm_in & (live if fm_in.ndim == 1 else live[None, :])
                )
            if n_keep is not None:
                from repro.core import pipeline as pl

                keep = min(n_keep, bm_in.num_blocks)
                s, i = pl.BlockMaxMatcher(n_keep=keep)(
                    view, q_rep_in, depth, bm=bm_in,
                    use_kernel=use_kernel, filt=filt,
                )
            else:
                s, i = matcher(
                    view, q_rep_in, depth, use_kernel=use_kernel,
                    filt=filt, n_docs=n_docs,
                )
            if rerank:
                rows = view.vq.q if quantized else view.vectors
                safe = jnp.clip(i, 0, rows.shape[0] - 1)
                cand = rows[safe]  # (B, d, dim)
                rs = jnp.einsum(
                    "bd,bcd->bc", q_norm_in, cand.astype(jnp.float32)
                )
                if quantized:
                    rs = rs * view.vq.scale[safe]
                rs = jnp.where(i >= 0, rs, -jnp.inf)
                out_s, pos = jax.lax.top_k(rs, k_out)
                return out_s, jnp.take_along_axis(i, pos, axis=-1)
            return s[:, :k_out], i[:, :k_out]
        return fn

    args = (pk.view, pk.live, fm_arg, q_rep, q_norm, bm)
    key = (
        "search", matcher, depth, k_out, rerank, quantized, use_kernel,
        use_filt, n_docs, n_keep,
    )
    exe = cache.get(key, build, args)
    return exe(*args)
