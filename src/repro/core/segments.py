"""Lucene-style segmented mutable index: IndexWriter / commit / merge over
immutable AnnIndex segments (docs/DESIGN.md §11).

The paper's whole premise is riding Lucene's native machinery, and the most
Lucene part of Lucene is the segmented index lifecycle that lets a real
deployment ingest documents while serving: immutable segments + sidecar
live-docs bitsets for deletes + generation-numbered commit points +
background merges.  This module reproduces that lifecycle on top of the
staged Build/Search pipelines:

  * :class:`repro.core.index.AnnIndex` is the immutable **segment** unit —
    ``IndexWriter.add`` buffers rows and flushes them through the method's
    :class:`repro.core.builder.BuildPipeline` into a fresh segment; a built
    segment never changes.
  * ``IndexWriter.delete(ids)`` flips bits in a per-segment **liveDocs**
    mask (Lucene's ``.liv`` sidecar).  Deleted docs are masked to
    ``(-inf, -1)`` *inside the match stage*
    (:class:`repro.core.pipeline.LiveDocsMatcher`), not post-filtered, so
    ``depth`` semantics survive deletes exactly.
  * ``IndexWriter.commit`` atomically persists a generation-numbered commit
    point: per-segment v1 index dirs + per-generation live files + a
    ``segments_N.json`` manifest written last via ``os.replace``
    (``format_version: 2``; a plain v1 ``AnnIndex.save`` dir loads as a
    single-segment index for read-compat).
  * A tiered :class:`TieredMergePolicy` compacts small adjacent segments by
    rebuilding their live rows through the same BuildPipeline stages —
    deleted rows drop out and global doc ids remap, exactly like a Lucene
    merge.
  * :class:`SegmentedAnnIndex` is the point-in-time **reader**:
    multi-segment search runs the method's jit'd matcher per segment and
    merges per-segment top-k on global ids — the same fan-out/merge
    architecture ``core/distributed.py`` uses across shards, here across
    segments.
  * ``IndexWriter.refresh()`` is the NRT reader hook: flush + snapshot, and
    every visible mutation advances the snapshot **epoch**
    (:func:`repro.core.types.next_epoch`) — the serving layer's
    cache-invalidation key (``serve/ann_service.py``).

**Exact global-statistics scoring.**  Lucene's IndexSearcher scores every
leaf with collection-level statistics; we do the same so a segmented search
is *bitwise identical* to a monolithic build of the equivalent live corpus:

  * fake words — document frequency is recounted over live rows per segment
    and summed (exact integer sum); idf and the classic ``scored`` matrix
    are re-derived per segment from the global (df, live-N) through the
    same :func:`repro.core.builder.classic_scored` formula the build stage
    evaluates (row-local, so bitwise);
  * k-d tree — the reduction refits on the concatenated live originals
    (the one encoding whose "statistic" is a fitted model) and every
    segment's rows re-project through the shared model (row-local matmuls,
    so bitwise);
  * lexical LSH / brute force — signatures and unit vectors carry no
    collection statistics.

Stats views rebuild lazily per snapshot (Lucene rebuilds per-leaf scorers
per reader the same way); ``global_stats=False`` trades exact parity for
per-segment statistics with no refresh cost.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import re
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bruteforce, builder, pca
from repro.core import index as index_mod
from repro.core import packed as packed_mod
from repro.core import pipeline as pl
from repro.core.index import AnnIndex, AnyConfig
from repro.core.types import (
    DocMetadata,
    FakeWordsConfig,
    KdTreeConfig,
    LexicalLshConfig,
    SearchParams,
    next_epoch,
)

SEGMENTS_FORMAT_VERSION = 2

_METHOD_BY_CONFIG = {v: k for k, v in index_mod._CONFIG_BY_METHOD.items()}

_COMMIT_RE = re.compile(r"^segments_(\d+)\.json$")

_NEEDS_VECTORS_MSG = (
    "requires the fp32 original vectors on every segment "
    "(rerank_store='exact')"
)

#: Packed single-launch segmented search (docs/DESIGN.md §14) is the
#: default serving path; REPRO_PACKED=0 flips the default back to the
#: per-segment reference loop (search(packed=...) overrides per call).
_PACKED_DEFAULT = os.environ.get("REPRO_PACKED", "1").lower() not in (
    "0", "false", "off",
)


def find_commits(path: str) -> List[Tuple[int, str]]:
    """(generation, filename) for every commit point under ``path``,
    ascending.  Empty when the directory holds no segmented commits (e.g. a
    v1 single-index save, or nothing at all)."""
    if not os.path.isdir(path):
        return []
    out = []
    for name in os.listdir(path):
        m = _COMMIT_RE.match(name)
        if m:
            out.append((int(m.group(1)), name))
    return sorted(out)


def _bucket(n: int) -> int:
    """Round a deleted-doc count up to the next power of two so the
    FilterMask's static depth inflation doesn't recompile per delete."""
    return 0 if n <= 0 else 1 << (n - 1).bit_length()


def _concat_metadata(
    parts: Sequence[Optional[DocMetadata]], rows_kept=None
) -> Optional[DocMetadata]:
    """Concatenate per-chunk metadata (flush: buffered adds; merge: the
    merged segments' live rows via ``rows_kept`` boolean selectors).  All
    chunks must agree on presence and field set — metadata over part of a
    segment cannot answer a predicate over all of it."""
    parts = list(parts)
    if all(p is None for p in parts):
        return None
    if any(p is None for p in parts):
        raise ValueError(
            "metadata must cover either all rows or none (some adds/"
            "segments carry metadata and some do not)"
        )
    names = parts[0].field_names
    if any(p.field_names != names for p in parts):
        raise ValueError(
            f"inconsistent metadata fields: {[p.field_names for p in parts]}"
        )
    if rows_kept is None:
        vals = [np.asarray(p.values) for p in parts]
    else:
        vals = [np.asarray(p.values)[k] for p, k in zip(parts, rows_kept)]
    return DocMetadata(
        values=jnp.asarray(np.concatenate(vals, axis=0)), field_names=names
    )


# --------------------------------------------------------------------------
# Segments
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Segment:
    """One immutable index + its mutable sidecar live-docs mask.

    ``ann`` never changes after build (the Lucene segment invariant); all
    mutation is bit-flips in ``live`` (True = live).  ``name`` is the
    stable on-disk directory name assigned at flush time.

    ``source`` holds the unit-normalized original rows host-side when the
    index itself does not carry them (rerank_store "int8"/"none"): merges
    rebuild from these and the kd-tree's global-stats refit reads them, so
    the writer no longer forces rerank_store="exact".  None when
    ``ann.index.vectors`` is present (no duplicate copy) — read through
    :meth:`source_rows`.  Persisted once per segment as ``source.npz``.
    """

    ann: AnnIndex
    live: np.ndarray
    name: str
    source: Optional[np.ndarray] = None

    def source_rows(self) -> Optional[np.ndarray]:
        """Unit-normalized original rows (merge/refit operand), whichever
        store carries them; None if the segment kept neither."""
        if self.ann.index.vectors is not None:
            return np.asarray(self.ann.index.vectors)
        return self.source

    @property
    def num_docs(self) -> int:
        """Total rows, deleted included (Lucene maxDoc)."""
        return self.ann.num_docs

    @property
    def num_live(self) -> int:
        return int(self.live.sum())

    @property
    def del_count(self) -> int:
        return self.num_docs - self.num_live

    def snapshot(self) -> "Segment":
        """Point-in-time copy: shares the immutable index, copies the
        mutable live mask — later writer deletes don't leak into an open
        reader."""
        return Segment(
            ann=self.ann, live=self.live.copy(), name=self.name,
            source=self.source,
        )


# --------------------------------------------------------------------------
# Merge policy
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TieredMergePolicy:
    """Lucene-style tiered merging over ADJACENT segments.

    Segments land in exponential size tiers (tier t holds up to
    ``floor_docs * merge_factor**t`` live docs); a run of ``merge_factor``
    adjacent same-tier segments merges into one segment of the next tier,
    so the segment count stays O(merge_factor * log(N / floor_docs)) under
    a steady add stream.  A segment whose delete ratio reaches
    ``expunge_ratio`` is rewritten alone (deletes drop out).  Only adjacent
    runs merge: unlike Lucene we guarantee global doc order == add order,
    which is what makes segmented search results identical to a monolithic
    build of the live corpus.
    """

    merge_factor: int = 8
    floor_docs: int = 1024
    expunge_ratio: float = 0.5

    def __post_init__(self):
        if self.merge_factor < 2:
            raise ValueError("merge_factor must be >= 2")
        if not (0.0 < self.expunge_ratio <= 1.0):
            raise ValueError("expunge_ratio must be in (0, 1]")

    def tier(self, num_live: int) -> int:
        t, cap = 0, max(1, self.floor_docs)
        while num_live > cap:
            cap *= self.merge_factor
            t += 1
        return t

    def find_merge(self, segments: Sequence[Segment]) -> Optional[Tuple[int, int]]:
        """The next ``[start, end)`` range to merge, or None when the
        geometry is stable.  Called in a loop by ``IndexWriter``."""
        for i, seg in enumerate(segments):
            if seg.num_docs and seg.del_count / seg.num_docs >= self.expunge_ratio:
                return (i, i + 1)
        tiers = [self.tier(s.num_live) for s in segments]
        start = 0
        while start < len(tiers):
            end = start
            while end < len(tiers) and tiers[end] == tiers[start]:
                end += 1
            if end - start >= self.merge_factor:
                return (start, start + self.merge_factor)
            start = end
        return None


# --------------------------------------------------------------------------
# Per-segment search (jit'd per segment, merged on global ids)
# --------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("matcher", "depth", "use_kernel", "native")
)
def _segment_match(
    matcher: pl.FilterMask,
    view,
    live: jax.Array,
    base: jax.Array,
    q_rep: jax.Array,
    depth: int,
    use_kernel: Optional[bool],
    native: bool = False,
):
    """One segment's contribution: mask-restricted match (the method's own
    matcher stage inside a FilterMask) on global ids.  ``native=False`` is
    the historical deletes path (depth inflation + re-reduce, bitwise what
    shipped); ``native=True`` threads the mask into the score stage as the
    kernels' in-tile filter operand — ONE kernel pass, used whenever a
    predicate bitmap is composed in (docs/DESIGN.md §13)."""
    s, i = matcher(view, q_rep, depth, live, use_kernel=use_kernel, native=native)
    return s, jnp.where(i >= 0, i + base, -1)


@functools.partial(
    jax.jit, static_argnames=("k", "depth", "rerank", "quantized", "bases")
)
def _merge_candidates(
    parts_s,
    parts_i,
    q_norm,
    stores,
    k: int,
    depth: int,
    rerank: bool,
    quantized: bool,
    bases: Tuple[int, ...],
):
    """Merge per-segment candidate lists exactly like the monolithic path:
    global top-``depth`` by MATCH score first (so the rerank sees precisely
    the candidate set a monolithic depth-d match would produce), then the
    rerank over the merged list.  Segment-major concatenation +
    ``lax.top_k``'s stable ties reproduce the lowest-global-id tie-break
    bit-for-bit.

    The rerank assembles the merged candidates' stored rows into ONE
    ``(B, depth, dim)`` tensor — each segment contributes its owned
    positions — and runs the same einsum as the monolithic reranker.
    Unlike the distributed path's local-rerank-then-merge (which avoids
    cross-shard vector movement), segments share a process, and scoring in
    the merged candidate positions is what makes the rerank scores bitwise
    equal to a monolithic build (XLA's reduction for a gathered-candidate
    dot is position-dependent at the last bit)."""
    all_s = jnp.concatenate(parts_s, axis=1)
    all_i = jnp.concatenate(parts_i, axis=1)
    top_s, pos = jax.lax.top_k(all_s, depth)
    top_i = jnp.take_along_axis(all_i, pos, axis=-1)
    if not rerank:
        return top_s[:, :k], top_i[:, :k]
    cand = scale = None
    for base, store in zip(bases, stores):
        rows = store[0] if quantized else store
        n = rows.shape[0]
        own = (top_i >= base) & (top_i < base + n)
        safe = jnp.clip(top_i - base, 0, n - 1)
        part = rows[safe]  # (B, depth, dim)
        cand = part if cand is None else jnp.where(own[:, :, None], part, cand)
        if quantized:
            sc = store[1][safe]  # (B, depth)
            scale = sc if scale is None else jnp.where(own, sc, scale)
    s = jnp.einsum("bd,bcd->bc", q_norm, cand.astype(jnp.float32))
    if quantized:
        s = s * scale
    s = jnp.where(top_i >= 0, s, -jnp.inf)
    out_s, p2 = jax.lax.top_k(s, k)
    return out_s, jnp.take_along_axis(top_i, p2, axis=-1)


# --------------------------------------------------------------------------
# The reader
# --------------------------------------------------------------------------


class SegmentedAnnIndex:
    """Point-in-time multi-segment reader (Lucene DirectoryReader).

    Immutable snapshot: segments share their (immutable) per-segment
    AnnIndexes with the writer but own copies of the live masks, and
    ``epoch`` identifies the snapshot for cache invalidation.  Search fans
    out the method's matcher per segment (deleted docs masked inside the
    match stage) and merges per-segment top-k on global ids — the shard
    fan-out/merge architecture of ``core/distributed.py``, across segments.

    Doc ids are segment-stable: global id = segment base (sum of preceding
    segments' row counts, deleted included) + local row.  Ids survive
    deletes; merges compact and remap them (like Lucene).
    """

    def __init__(
        self,
        config: AnyConfig,
        segments: Sequence[Segment],
        use_kernel: Optional[bool] = None,
        global_stats: bool = True,
        epoch: Optional[int] = None,
    ):
        if isinstance(config, KdTreeConfig) and config.backend == "tree":
            raise ValueError(
                "segmented kd-tree requires backend='scan' (identical "
                "results, docs/DESIGN.md §3); the host-built tree arrays "
                "cannot re-derive shared global statistics"
            )
        self.config = config
        self.segments = list(segments)
        self.use_kernel = use_kernel
        self.global_stats = global_stats
        self.epoch = next_epoch() if epoch is None else epoch
        self.pipeline = pl.build_pipeline(config)
        # Quantized rerank iff every segment carries ONLY the int8 store
        # (writer segments built with rerank_store="int8", or v1
        # read-compat of a monolithic int8-rerank index).
        self.quantized_rerank = bool(self.segments) and all(
            s.ann.index.vectors is None and s.ann.index.vq is not None
            for s in self.segments
        )
        self._views: Optional[List[Any]] = None
        self._live_dev: Optional[List[jax.Array]] = None
        self._n_live = int(sum(s.num_live for s in self.segments))
        # Packed single-launch state (docs/DESIGN.md §14): built lazily;
        # _packed_prior is the previous snapshot's pack, handed over by
        # IndexWriter.refresh() so append-only refreshes can absorb it via
        # a donated incremental repack instead of re-concatenating.
        self._packed: Optional[packed_mod.PackedSegments] = None
        self._packed_prior: Optional[packed_mod.PackedSegments] = None
        self._packed_err: Optional[str] = None

    # -- shape/identity ----------------------------------------------------

    @property
    def method(self) -> str:
        return _METHOD_BY_CONFIG[type(self.config)]

    @property
    def num_docs(self) -> int:
        """LIVE docs (Lucene ``numDocs``); ``max_doc`` counts deleted too."""
        return self._n_live

    @property
    def max_doc(self) -> int:
        return sum(s.num_docs for s in self.segments)

    @property
    def num_segments(self) -> int:
        return len(self.segments)

    @property
    def del_count(self) -> int:
        return self.max_doc - self._n_live

    def nbytes(self) -> int:
        return sum(s.ann.nbytes() + s.live.nbytes for s in self.segments)

    def live_global_ids(self) -> np.ndarray:
        """Stable global ids of the live docs in corpus (add) order — the
        id mapping between this reader and a monolithic build of the
        equivalent live corpus (monolithic id j <-> live_global_ids()[j])."""
        parts, base = [], 0
        for s in self.segments:
            parts.append(np.flatnonzero(s.live) + base)
            base += s.num_docs
        return (
            np.concatenate(parts) if parts else np.zeros((0,), np.int64)
        ).astype(np.int64)

    # -- global collection statistics (Lucene IndexSearcher-level) ---------

    def _ensure_views(self) -> Tuple[List[Any], List[pl.FilterMask]]:
        if self._views is None:
            self._live_dev = [jnp.asarray(s.live) for s in self.segments]
            self._views = (
                self._stat_views() if self.global_stats
                else [s.ann.index for s in self.segments]
            )
        base = pl.make_matcher(self.config)
        if self.global_stats and isinstance(base, pl.FakeWordsMatcher):
            base = dataclasses.replace(base, df_num_docs=self._n_live)
        matchers = [
            pl.FilterMask(inner=base, extra=_bucket(s.del_count))
            for s in self.segments
        ]
        return self._views, matchers

    # -- packed single-launch path (docs/DESIGN.md §14) ---------------------

    def packed_segments(self) -> Optional[packed_mod.PackedSegments]:
        """This snapshot's packed superbuffer, built lazily and cached on
        the reader.  None when the layout cannot ride the single-launch
        path (mixed store presence, per-segment statistics, ...) — the
        reason is kept in ``_packed_err`` and search falls back to the
        per-segment loop."""
        if self._packed is not None:
            return self._packed
        if self._packed_err is not None:
            return None
        views, _ = self._ensure_views()
        prior, self._packed_prior = self._packed_prior, None
        try:
            self._packed = packed_mod.pack_segments(
                self.config, views, self.segments, self.global_stats,
                prior=prior,
            )
        except packed_mod.PackedUnsupported as e:
            self._packed_err = str(e)
            return None
        return self._packed

    def _packed_matcher(self):
        base = pl.make_matcher(self.config)
        if self.global_stats and isinstance(base, pl.FakeWordsMatcher):
            # df_max_ratio >= 1 keeps every term regardless of collection
            # size, so df_num_docs stays unset and the matcher's static
            # identity survives refreshes (zero recompiles per cycle).  A
            # real prune ratio needs the live count for parity with the
            # loop and accepts a recompile when it changes.
            if base.df_max_ratio < 1.0:
                base = dataclasses.replace(base, df_num_docs=self._n_live)
        return base

    # -- metadata (predicate source for filtered search) --------------------

    def global_metadata(self) -> Optional[DocMetadata]:
        """The segments' per-doc metadata concatenated in global-id order
        (deleted rows included, so row g answers for global doc id g) —
        build predicate bitmaps from it and pass them to
        ``search(filter_mask=)``.  None when no segment carries metadata;
        mixed coverage raises (a predicate over half the corpus is a bug)."""
        mds = [s.ann.metadata for s in self.segments]
        if all(md is None for md in mds):
            return None
        if any(md is None for md in mds):
            raise ValueError(
                "some segments carry doc metadata and some do not; "
                "metadata-filtered search needs every segment covered"
            )
        names = mds[0].field_names
        if any(md.field_names != names for md in mds):
            raise ValueError(
                f"segments carry inconsistent metadata fields: "
                f"{[md.field_names for md in mds]}"
            )
        return DocMetadata(
            values=jnp.concatenate([md.values for md in mds], axis=0),
            field_names=names,
        )

    def _stat_views(self) -> List[Any]:
        segs = self.segments
        if isinstance(self.config, FakeWordsConfig):
            df = None
            for s, live in zip(segs, self._live_dev):
                # dot-int4 packed tf away: its df freezes at the build-time
                # count (Lucene-style) until a merge rebuilds the segment.
                d = (
                    builder.live_df(s.ann.index.tf, live)
                    if s.ann.index.tf is not None else s.ann.index.df
                )
                df = d if df is None else df + d
            idf = builder.idf_from_df(df, self._n_live)
            views = []
            for s in segs:
                idx = s.ann.index
                if self.config.scoring != "classic":
                    views.append(dataclasses.replace(idx, df=df, idf=idf))
                    continue
                scored = builder.classic_scored(idx.tf, idf, idx.norm)
                if idx.pq is not None:
                    # Quantized-classic keeps tf precisely for this: rebuild
                    # scores under GLOBAL stats, then re-quantize row-locally
                    # — each row's scale/codes depend only on that row, so a
                    # segment view is bitwise the monolithic quantized build.
                    views.append(dataclasses.replace(
                        idx, df=df, idf=idf, scored=None,
                        pq=builder.quantize_postings(
                            scored, idx.pq.bits, idx.pq.group or 32
                        ),
                    ))
                else:
                    views.append(
                        dataclasses.replace(idx, df=df, idf=idf, scored=scored)
                    )
            return views
        if isinstance(self.config, KdTreeConfig):
            if any(s.source_rows() is None for s in segs):
                raise ValueError(
                    "global-stats refresh for a segmented kd-tree "
                    + _NEEDS_VECTORS_MSG
                    + " or a source sidecar; pass global_stats=False to "
                    "score each segment under its own fitted reduction"
                )
            from repro.kernels.fused_topk import ops as fused

            live_rows = [s.source_rows()[s.live] for s in segs]
            v_live = jnp.asarray(np.concatenate(live_rows, axis=0))
            model, _ = pca.fit_reduction(
                v_live, self.config.dims, self.config.reduction,
                self.config.ppa_remove,
            )
            views = []
            for s in segs:
                red = pca.apply_reduction(
                    model, jnp.asarray(s.source_rows())
                ).astype(jnp.float32)
                views.append(
                    dataclasses.replace(
                        s.ann.index, reduced=red, reduction=model,
                        lifted=fused.lift_l2(red),
                    )
                )
            return views
        # LSH signatures and brute-force unit vectors carry no collection
        # statistics: the stored index IS the view.
        return [s.ann.index for s in segs]

    # -- search ------------------------------------------------------------

    def encode_queries(self, queries: jax.Array) -> jax.Array:
        views, _ = self._ensure_views()
        if not views:
            raise ValueError("cannot encode against an empty segmented index")
        return self.pipeline.encode(views[0], queries)

    def search(
        self,
        queries: jax.Array,
        k: int = 10,
        depth: int = 100,
        rerank: bool = False,
        params: Optional[SearchParams] = None,
        use_kernel: Optional[bool] = None,
        filter_mask: Optional[jax.Array] = None,
        packed: Optional[bool] = None,
        blockmax_keep: Optional[int] = None,
        blockmax_block_size: int = 256,
    ) -> Tuple[jax.Array, jax.Array]:
        """Multi-segment staged search: encode once (the global-stats view
        carries any fitted model) -> per-segment live-masked match [+ local
        rerank gather] -> merge on global ids.  Same signature and — for a
        healthy snapshot — bitwise the same results as ``AnnIndex.search``
        over the equivalent live corpus (ids mapped through
        :meth:`live_global_ids`).

        ``filter_mask`` ((max_doc,) or (B, max_doc), nonzero = keep,
        indexed by GLOBAL doc id — e.g. built from
        :meth:`global_metadata`): each segment slices its own rows,
        composes liveDocs ∧ predicate into ONE mask, and runs a single
        in-kernel filtered pass (docs/DESIGN.md §13).  A mask that filters
        every doc returns padded (-inf, -1) rows, never NaNs.

        ``packed`` selects the single-launch path over the packed
        superbuffer (docs/DESIGN.md §14): None follows the process default
        (on unless REPRO_PACKED=0, falling back silently to the loop for
        unsupported layouts), True raises when unsupported, False forces
        the per-segment reference loop.  ``blockmax_keep`` enables
        two-stage blockmax pruning over the packed view (fake-words and
        LSH encodings; approximate by design, docs/DESIGN.md §6)."""
        p = params if params is not None else SearchParams(k=k, depth=depth, rerank=rerank)
        if self._n_live == 0:
            raise ValueError("segmented index has no live docs to search")
        uk = self.use_kernel if use_kernel is None else use_kernel
        views, matchers = self._ensure_views()
        q_norm = bruteforce.l2_normalize(jnp.asarray(queries))
        fm = None
        if filter_mask is not None:
            fm = jnp.asarray(filter_mask)
            if fm.shape[-1] != self.max_doc:
                raise ValueError(
                    f"filter_mask covers {fm.shape[-1]} docs but the index "
                    f"has max_doc={self.max_doc} (masks index GLOBAL ids, "
                    "deleted rows included)"
                )
        want_packed = _PACKED_DEFAULT if packed is None else bool(packed)
        if blockmax_keep is not None and not want_packed:
            raise ValueError(
                "blockmax_keep rides the packed single-launch path; "
                "packed=False forces the per-segment reference loop"
            )
        if want_packed:
            pk = self.packed_segments()
            if pk is None:
                if packed or blockmax_keep is not None:
                    raise ValueError(
                        "packed single-launch path unavailable for this "
                        f"snapshot: {self._packed_err}"
                    )
                # default-on: serve via the per-segment reference loop
            else:
                if p.rerank and not self.quantized_rerank and (
                    pk.view.vectors is None
                ):
                    raise ValueError(
                        "rerank=True " + _NEEDS_VECTORS_MSG
                        + " or the int8 store on every segment"
                    )
                bm = None
                if blockmax_keep is not None:
                    if not isinstance(
                        self.config, (FakeWordsConfig, LexicalLshConfig)
                    ):
                        raise ValueError(
                            "blockmax pruning supports fake-words and LSH "
                            "encodings only (docs/DESIGN.md §6)"
                        )
                    bm = packed_mod.packed_blockmax(
                        pk, self.config, blockmax_block_size
                    )
                return packed_mod.packed_search(
                    pk, self.pipeline, self._packed_matcher(), q_norm,
                    p.k, p.depth, rerank=p.rerank,
                    quantized=self.quantized_rerank, use_kernel=uk,
                    fm=fm, n_keep=blockmax_keep, bm=bm,
                )
        q_rep = self.pipeline.encoder(views[0], q_norm)
        d_eff = min(p.depth, self._n_live)
        k_eff = min(p.k, d_eff)
        parts_s, parts_i, stores, bases = [], [], [], []
        base = 0
        for seg, view, live, matcher in zip(
            self.segments, views, self._live_dev, matchers
        ):
            if fm is None:
                seg_mask, native = live, False
            else:
                pred = fm[..., base : base + seg.num_docs] != 0
                seg_mask = pred & (live if pred.ndim == 1 else live[None, :])
                native = True
            s, gid = _segment_match(
                matcher, view, seg_mask, jnp.int32(base), q_rep, p.depth, uk,
                native=native,
            )
            parts_s.append(s)
            parts_i.append(gid)
            bases.append(base)
            base += seg.num_docs
            if p.rerank:
                idx = seg.ann.index
                if self.quantized_rerank:
                    stores.append((idx.vq.q, idx.vq.scale))
                elif idx.vectors is not None:
                    stores.append(idx.vectors)
                else:
                    raise ValueError(
                        "rerank=True " + _NEEDS_VECTORS_MSG
                        + " or the int8 store on every segment"
                    )
        return _merge_candidates(
            tuple(parts_s), tuple(parts_i), q_norm, tuple(stores),
            k_eff, d_eff, p.rerank, self.quantized_rerank, tuple(bases),
        )

    # -- persistence (read side; IndexWriter.commit writes) ----------------

    @classmethod
    def load(
        cls,
        path: str,
        generation: Optional[int] = None,
        **overrides,
    ) -> "SegmentedAnnIndex":
        """Open a commit point (latest generation by default).  A plain v1
        ``AnnIndex.save`` directory loads as a single fully-live segment
        (read-compat), so every pre-segmentation index remains servable."""
        commits = find_commits(path)
        if not commits:
            if os.path.exists(os.path.join(path, "config.json")):
                if generation is not None:
                    raise FileNotFoundError(
                        f"{path!r} is a v1 single-index save with no commit "
                        f"generations; cannot load generation {generation}"
                    )
                ann = AnnIndex.load(path)
                seg = Segment(
                    ann=ann,
                    live=np.ones(ann.num_docs, bool),
                    name="seg0",
                )
                return cls(
                    ann.config, [seg],
                    use_kernel=overrides.get("use_kernel", ann.use_kernel),
                    global_stats=overrides.get("global_stats", True),
                )
            raise FileNotFoundError(
                f"no segments_N.json commit point (and no v1 config.json) "
                f"under {path!r}"
            )
        if generation is None:
            generation, fname = commits[-1]
        else:
            by_gen = dict(commits)
            if generation not in by_gen:
                raise FileNotFoundError(
                    f"no commit generation {generation} under {path!r} "
                    f"(have {sorted(by_gen)})"
                )
            fname = by_gen[generation]
        with open(os.path.join(path, fname)) as f:
            meta = json.load(f)
        version = meta.get("format_version", 2)
        if version > SEGMENTS_FORMAT_VERSION:
            raise ValueError(
                f"commit point {fname!r} has format_version {version}, but "
                f"this build reads <= {SEGMENTS_FORMAT_VERSION} — it was "
                "written by a newer version of the code; upgrade to load it"
            )
        config = index_mod._config_from_json(meta["method"], meta["config"])
        segments = []
        for e in meta["segments"]:
            ann = AnnIndex.load(os.path.join(path, e["name"]))
            if e.get("live_file"):
                with np.load(os.path.join(path, e["live_file"])) as z:
                    live = z["live"].astype(bool)
            else:
                live = np.ones(ann.num_docs, bool)
            source = None
            src_file = os.path.join(path, e["name"], "source.npz")
            if ann.index.vectors is None and os.path.exists(src_file):
                with np.load(src_file) as z:
                    source = z["source"]
            segments.append(
                Segment(ann=ann, live=live, name=e["name"], source=source)
            )
        return cls(
            config, segments,
            use_kernel=overrides.get("use_kernel", meta.get("use_kernel")),
            global_stats=overrides.get(
                "global_stats", meta.get("global_stats", True)
            ),
        )


# --------------------------------------------------------------------------
# The writer
# --------------------------------------------------------------------------


class IndexWriter:
    """Lucene IndexWriter for AnnIndex segments: buffer adds, flush through
    the BuildPipeline, flip liveDocs bits on delete, merge by policy, and
    atomically commit generation-numbered points.

    Doc ids: ``add`` assigns consecutive global ids (segment base + row).
    Ids are stable across adds and deletes; a merge compacts its range and
    REMAPS every id after it (exactly Lucene's contract).  ``refresh()``
    returns a point-in-time :class:`SegmentedAnnIndex` whose ``epoch``
    advances only when something actually changed — an unchanged refresh
    returns the same snapshot, so serving caches stay warm.

    Any ``rerank_store`` ("exact" | "int8" | "none") and any
    ``primary_postings`` ("fp32" | "int8" | "int4") work: when the built
    segment does not carry the fp32 originals, the writer keeps them as a
    host-side ``Segment.source`` sidecar (normalized once, persisted as
    ``source.npz``), so merges still rebuild live rows bit-for-bit and the
    kd-tree's global-stats refit still reads them.
    """

    def __init__(
        self,
        config: AnyConfig,
        path: Optional[str] = None,
        rerank_store: str = "exact",
        use_kernel: Optional[bool] = None,
        merge_policy: Optional[TieredMergePolicy] = TieredMergePolicy(),
        max_buffered_docs: Optional[int] = None,
        global_stats: bool = True,
        primary_postings: str = "fp32",
        postings_group: int = 32,
    ):
        if rerank_store not in ("exact", "int8", "none"):
            raise ValueError(f"unknown rerank_store {rerank_store!r}")
        if isinstance(config, KdTreeConfig) and config.backend == "tree":
            raise ValueError(
                "segmented kd-tree requires backend='scan' "
                "(docs/DESIGN.md §3/§11)"
            )
        self.config = config
        self.path = path
        self.rerank_store = rerank_store
        self.primary_postings = primary_postings
        self.postings_group = postings_group
        self.use_kernel = use_kernel
        self.merge_policy = merge_policy
        self.max_buffered_docs = max_buffered_docs
        self.global_stats = global_stats
        self._segments: List[Segment] = []
        self._buf: List[np.ndarray] = []
        self._buf_live: List[np.ndarray] = []
        self._buf_md: List[Optional[DocMetadata]] = []
        self._seg_counter = 0
        self._changed = False
        self._reader: Optional[SegmentedAnnIndex] = None
        # Latest commit generation THIS writer has read or written.  The
        # commit-lineage guard (Lucene's write.lock analog): committing
        # into a directory whose commits this writer never saw would reuse
        # segment names against another writer's dirs.
        self._last_gen = 0

    @classmethod
    def open(cls, path: str, **kwargs) -> "IndexWriter":
        """Open the latest commit point under ``path`` for further writes
        (a plain v1 ``AnnIndex.save`` dir opens as one segment: the upgrade
        path from a frozen index to an online one)."""
        reader = SegmentedAnnIndex.load(path)
        kwargs.setdefault("use_kernel", reader.use_kernel)
        kwargs.setdefault("global_stats", reader.global_stats)
        if reader.segments:
            # Continue the store choice the existing segments were built
            # with, so new flushes/merges stay homogeneous.
            idx = reader.segments[0].ann.index
            if idx.vectors is not None:
                kwargs.setdefault("rerank_store", "exact")
            elif getattr(idx, "vq", None) is not None:
                kwargs.setdefault("rerank_store", "int8")
            else:
                kwargs.setdefault("rerank_store", "none")
            pq = getattr(idx, "pq", None)
            if pq is not None:
                kwargs.setdefault("primary_postings", f"int{pq.bits}")
                kwargs.setdefault("postings_group", pq.group or 32)
        w = cls(reader.config, path=path, **kwargs)
        w._segments = reader.segments
        commits = find_commits(path)
        w._last_gen = commits[-1][0] if commits else 0
        nums = [
            int(m.group(1))
            for m in (re.match(r"^seg(\d+)$", s.name) for s in w._segments)
            if m
        ]
        w._seg_counter = max(nums) + 1 if nums else 0
        return w

    # -- counts ------------------------------------------------------------

    @property
    def buffered_docs(self) -> int:
        return sum(len(c) for c in self._buf)

    @property
    def total_docs(self) -> int:
        """Total assigned doc ids (segments + buffer, deleted included)."""
        return sum(s.num_docs for s in self._segments) + self.buffered_docs

    @property
    def num_segments(self) -> int:
        return len(self._segments)

    def _next_name(self) -> str:
        name = f"seg{self._seg_counter}"
        self._seg_counter += 1
        return name

    # -- mutation ----------------------------------------------------------

    def add(self, vectors, metadata=None) -> np.ndarray:
        """Buffer rows; returns their assigned global doc ids.  Buffered
        rows become searchable at the next flush/refresh/commit.

        ``metadata``: per-row structured fields for filtered search — a
        ``{field: (n,) ints}`` mapping or a prebuilt
        :class:`repro.core.types.DocMetadata` with one row per added
        vector.  All adds into one flush (and, via merges, one index) must
        agree on the field set; rows ride into the built segment's
        ``AnnIndex.metadata`` and survive flush/merge/commit."""
        rows = np.asarray(vectors, np.float32)
        if rows.ndim == 1:
            rows = rows[None, :]
        if rows.ndim != 2 or rows.shape[0] == 0:
            raise ValueError(f"add expects (n, dim) rows, got {rows.shape}")
        md = builder.build_metadata(metadata, rows.shape[0])
        start = self.total_docs
        self._buf.append(rows)
        self._buf_live.append(np.ones(rows.shape[0], bool))
        self._buf_md.append(md)
        if (
            self.max_buffered_docs is not None
            and self.buffered_docs >= self.max_buffered_docs
        ):
            self.flush()
        return np.arange(start, start + rows.shape[0], dtype=np.int64)

    def delete(self, ids) -> int:
        """Flip liveDocs bits for the given global doc ids (buffered rows
        included).  Returns the number of newly deleted docs; deleting a
        dead id is a no-op, an unknown id raises."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        bases = np.cumsum([0] + [s.num_docs for s in self._segments])
        flushed_total = int(bases[-1])
        newly = 0
        for gid in ids:
            gid = int(gid)
            if gid < 0 or gid >= self.total_docs:
                raise IndexError(
                    f"unknown doc id {gid} (have {self.total_docs} docs)"
                )
            if gid < flushed_total:
                si = int(np.searchsorted(bases, gid, side="right")) - 1
                seg, loc = self._segments[si], gid - int(bases[si])
                if seg.live[loc]:
                    seg.live[loc] = False
                    newly += 1
                    self._changed = True
            else:
                off = gid - flushed_total
                for chunk in self._buf_live:
                    if off < len(chunk):
                        if chunk[off]:
                            chunk[off] = False
                            newly += 1
                        break
                    off -= len(chunk)
        return newly

    def flush(self) -> bool:
        """Build buffered rows into a fresh immutable segment through the
        method's BuildPipeline, then let the merge policy react.  Returns
        True when a segment was written."""
        if not self._buf:
            return False
        rows = np.concatenate(self._buf, axis=0)
        live = np.concatenate(self._buf_live, axis=0)
        md = _concat_metadata(self._buf_md)
        ann = self._build_segment(jnp.asarray(rows), normalized=False, metadata=md)
        self._segments.append(
            Segment(
                ann=ann, live=live, name=self._next_name(),
                source=self._source_sidecar(ann, rows, normalized=False),
            )
        )
        self._buf, self._buf_live, self._buf_md = [], [], []
        self._changed = True
        self.maybe_merge()
        return True

    def _build_segment(
        self, rows: jax.Array, normalized: bool, metadata=None
    ) -> AnnIndex:
        return AnnIndex.build(
            rows, self.config,
            rerank_store=self.rerank_store, use_kernel=self.use_kernel,
            primary_postings=self.primary_postings,
            postings_group=self.postings_group,
            normalized=normalized,
            metadata=metadata,
        )

    @staticmethod
    def _source_sidecar(
        ann: AnnIndex, rows: np.ndarray, normalized: bool
    ) -> Optional[np.ndarray]:
        """Host-side normalized originals when the built index dropped them
        (the exact rows a rerank_store='exact' build would have stored, so
        merge results stay bitwise independent of the store choice)."""
        if ann.index.vectors is not None:
            return None
        if not normalized:
            rows = np.asarray(bruteforce.l2_normalize(jnp.asarray(rows)))
        return np.asarray(rows, np.float32)

    # -- merging -----------------------------------------------------------

    def maybe_merge(self) -> int:
        """Run the merge policy to a fixed point; returns merges done."""
        if self.merge_policy is None:
            return 0
        done = 0
        while True:
            rng = self.merge_policy.find_merge(self._segments)
            if rng is None:
                return done
            self._merge_range(*rng)
            done += 1

    def force_merge(self, max_segments: int = 1) -> None:
        """Compact to at most ``max_segments`` segments and expunge every
        delete (a full merge with ``max_segments=1`` leaves one fully-live
        segment identical to a monolithic build of the live corpus)."""
        self.flush()
        if max_segments < 1:
            raise ValueError("max_segments must be >= 1")
        while len(self._segments) > max_segments:
            # Cheapest adjacent pair first (Lucene's smallest-merge bias).
            sizes = [s.num_live for s in self._segments]
            i = min(
                range(len(sizes) - 1), key=lambda j: sizes[j] + sizes[j + 1]
            )
            self._merge_range(i, i + 2)
        for i in range(len(self._segments) - 1, -1, -1):
            if self._segments[i].del_count:
                self._merge_range(i, i + 1)

    def _merge_range(self, start: int, end: int) -> None:
        """Rebuild segments [start, end) as one: concatenate their live
        normalized originals (add order preserved) and run the same
        BuildPipeline with ``normalized=True`` — deleted rows drop out and
        ids after the range remap, exactly like a Lucene merge."""
        group = self._segments[start:end]
        for s in group:
            if s.source_rows() is None:
                raise ValueError(
                    "merging " + _NEEDS_VECTORS_MSG
                    + f" or a source sidecar; segment {s.name!r} has neither"
                )
        rows = np.concatenate(
            [s.source_rows()[s.live] for s in group], axis=0
        )
        if rows.shape[0] == 0:
            # Every row dead: drop the segments outright.
            del self._segments[start:end]
            self._changed = True
            return
        md = _concat_metadata(
            [s.ann.metadata for s in group], rows_kept=[s.live for s in group]
        )
        ann = self._build_segment(jnp.asarray(rows), normalized=True, metadata=md)
        merged = Segment(
            ann=ann, live=np.ones(rows.shape[0], bool),
            name=self._next_name(),
            source=self._source_sidecar(ann, rows, normalized=True),
        )
        self._segments[start:end] = [merged]
        self._changed = True

    # -- visibility --------------------------------------------------------

    def refresh(self) -> SegmentedAnnIndex:
        """Near-real-time reader (Lucene openIfChanged): flush the buffer
        and return a point-in-time snapshot.  The epoch advances IFF
        something changed; an unchanged refresh returns the cached reader,
        so epoch-keyed serving caches stay warm."""
        self.flush()
        if self._reader is None or self._changed:
            old = self._reader
            self._reader = SegmentedAnnIndex(
                self.config,
                [s.snapshot() for s in self._segments],
                use_kernel=self.use_kernel,
                global_stats=self.global_stats,
            )
            if old is not None:
                # Hand the old snapshot's packed buffers to the new reader:
                # an append-only refresh absorbs them via a donated
                # incremental repack (core/packed.py).  The old reader
                # lazily repacks if searched again after donation.
                self._reader._packed_prior = old._packed
                old._packed = None
            self._changed = False
        return self._reader

    def commit(self, path: Optional[str] = None) -> int:
        """Flush + durably persist a generation-numbered commit point.

        Layout: one v1 index dir per segment (written once — segments are
        immutable, so later commits reuse them), a per-generation live file
        per segment carrying deletes, and ``segments_{gen}.json`` written
        LAST via write-to-temp + ``os.replace`` — a reader either sees the
        complete new generation or the previous one, never a torn commit.
        Superseded segment dirs / live files are left for older generations
        (no GC, like Lucene without a deletion policy)."""
        path = path if path is not None else self.path
        if path is None:
            raise ValueError("commit needs a path (or IndexWriter(path=...))")
        self.path = path
        self.flush()
        os.makedirs(path, exist_ok=True)
        commits = find_commits(path)
        on_disk = commits[-1][0] if commits else 0
        if on_disk != self._last_gen:
            # Lineage guard (Lucene's write.lock analog): this directory
            # holds commits this writer never read — committing would reuse
            # segment names against another writer's dirs and silently
            # corrupt the new generation.
            raise ValueError(
                f"{path!r} holds commit generation {on_disk}, but this "
                f"writer last saw generation {self._last_gen}; open the "
                "directory with IndexWriter.open(path) (or commit to a "
                "fresh directory) instead of committing over a foreign "
                "commit history"
            )
        gen = on_disk + 1
        entries = []
        for seg in self._segments:
            seg_dir = os.path.join(path, seg.name)
            if not os.path.exists(os.path.join(seg_dir, "config.json")):
                seg.ann.save(seg_dir)
            if seg.source is not None:
                src_file = os.path.join(seg_dir, "source.npz")
                if not os.path.exists(src_file):
                    np.savez_compressed(src_file, source=seg.source)
            entry = {
                "name": seg.name,
                "num_docs": seg.num_docs,
                "del_count": seg.del_count,
                "live_file": None,
            }
            if seg.del_count:
                live_file = os.path.join(seg.name, f"live_gen{gen}.npz")
                np.savez_compressed(
                    os.path.join(path, live_file), live=seg.live
                )
                entry["live_file"] = live_file
            entries.append(entry)
        meta = {
            "format_version": SEGMENTS_FORMAT_VERSION,
            "generation": gen,
            "method": _METHOD_BY_CONFIG[type(self.config)],
            "config": index_mod._config_to_json(self.config),
            "total_docs": sum(s.num_docs for s in self._segments),
            "num_live": sum(s.num_live for s in self._segments),
            "segments": entries,
            "use_kernel": self.use_kernel,
            "global_stats": self.global_stats,
        }
        final = os.path.join(path, f"segments_{gen}.json")
        tmp = final + ".tmp"
        with open(tmp, "w") as f:
            json.dump(meta, f, indent=2)
        os.replace(tmp, final)
        self._last_gen = gen
        return gen
