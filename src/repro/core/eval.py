"""Evaluation metrics: the paper's R@(k,d) plus helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def recall_at(truth_ids: jax.Array, retrieved_ids: jax.Array) -> jax.Array:
    """R@(k,d): fraction of the true top-k (truth_ids: (B,k)) present among
    the retrieved top-d (retrieved_ids: (B,d)), averaged over queries.
    Ground truth comes from exact brute force (paper §3); -1 ids are padding.
    """
    hits = (truth_ids[:, :, None] == retrieved_ids[:, None, :]) & (
        truth_ids[:, :, None] >= 0
    )
    per_query = jnp.sum(jnp.any(hits, axis=-1), axis=-1) / truth_ids.shape[1]
    return jnp.mean(per_query)


def recall_curve(truth_ids: jax.Array, retrieved_ids: jax.Array, depths) -> dict:
    """R@(k,d) for several retrieval depths d from one deep retrieval."""
    return {d: float(recall_at(truth_ids, retrieved_ids[:, :d])) for d in depths}


def overlap(a_ids: jax.Array, b_ids: jax.Array) -> jax.Array:
    """Mean fraction of shared ids between two (B,k) result sets."""
    hits = (a_ids[:, :, None] == b_ids[:, None, :]) & (a_ids[:, :, None] >= 0)
    return jnp.mean(jnp.sum(jnp.any(hits, axis=-1), axis=-1) / a_ids.shape[1])
