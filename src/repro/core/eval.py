"""Evaluation metrics: the paper's R@(k,d) plus helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def recall_at(
    truth_ids: jax.Array, retrieved_ids: jax.Array, filter_mask=None
) -> jax.Array:
    """R@(k,d): fraction of the true top-k (truth_ids: (B,k)) present among
    the retrieved top-d (retrieved_ids: (B,d)), averaged over queries.
    Ground truth comes from exact brute force (paper §3); -1 ids are padding
    and are excluded from BOTH the hit count and the denominator (dividing
    by the row width would understate recall on padded truth rows).

    ``filter_mask`` ((N,) or (B, N), nonzero = keep) restates the ground
    truth over the *filtered* corpus: truth entries a filtered search could
    never return are treated exactly like -1 padding (out of hit count AND
    denominator) — otherwise filtered A/Bs understate recall the same way
    padded truth rows used to (the PR 2 fix, generalized).  For honest
    filtered recall the truth should already be filtered-exact top-k;
    this parameter additionally makes UNfiltered truth usable as a
    conservative proxy by scoring only its in-filter entries.
    """
    valid = truth_ids >= 0
    if filter_mask is not None:
        mask = jnp.asarray(filter_mask)
        safe = jnp.maximum(truth_ids, 0)
        if mask.ndim == 1:
            bits = mask[safe]
        else:
            bits = jnp.take_along_axis(mask, safe, axis=1)
        valid = valid & (bits != 0)
    hits = (truth_ids[:, :, None] == retrieved_ids[:, None, :]) & valid[:, :, None]
    n_valid = jnp.maximum(jnp.sum(valid, axis=-1), 1)
    per_query = jnp.sum(jnp.any(hits, axis=-1), axis=-1) / n_valid
    return jnp.mean(per_query)


def recall_curve(truth_ids: jax.Array, retrieved_ids: jax.Array, depths) -> dict:
    """R@(k,d) for several retrieval depths d from one deep retrieval."""
    return {d: float(recall_at(truth_ids, retrieved_ids[:, :d])) for d in depths}


def overlap(a_ids: jax.Array, b_ids: jax.Array) -> jax.Array:
    """Mean fraction of shared ids between two (B,k) result sets; -1 padding
    in ``a_ids`` is excluded from both numerator and denominator."""
    valid = a_ids >= 0
    hits = (a_ids[:, :, None] == b_ids[:, None, :]) & valid[:, :, None]
    n_valid = jnp.maximum(jnp.sum(valid, axis=-1), 1)
    return jnp.mean(jnp.sum(jnp.any(hits, axis=-1), axis=-1) / n_valid)
