"""Typed configuration and index containers for the ANN core.

The paper (Teofili & Lin, 2019) adapts Lucene's inverted index to dense-vector
ANN search via three encodings: "fake words", "lexical LSH" and k-d trees over
dimensionality-reduced vectors.  Each encoding gets a config dataclass here and
an index container (a pytree of device arrays) so that the whole index can be
sharded with ``jax.device_put`` / ``NamedSharding`` and passed through ``jit``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Iterable, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Index epochs
# --------------------------------------------------------------------------

_EPOCHS = itertools.count(1)


def next_epoch() -> int:
    """Process-unique, monotonically increasing index epoch.

    Every searchable index snapshot (an ``AnnIndex``, or a
    ``SegmentedAnnIndex`` refresh) carries a distinct epoch, and every
    mutation the ``IndexWriter`` makes visible (flush / delete / merge)
    advances it — so the epoch is the cache-invalidation hook for online
    index updates: the serving layer folds it into its result-cache key
    (docs/DESIGN.md §11) and a swapped or refreshed index can never serve
    another index's cached results.  Lives here (the dependency-free leaf
    module) so ``core/index.py``, ``core/segments.py`` and
    ``serve/ann_service.py`` share one counter without import cycles.
    """
    return next(_EPOCHS)


# --------------------------------------------------------------------------
# Method configs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FakeWordsConfig:
    """Fake-words encoding (Amato et al. 2016, as used in the paper).

    quantization: Q.  tf(tau_i, d) = round(Q * w_i) for the sign-split
        feature; the paper sweeps Q in {30,40,50,60,70}.
    df_max_ratio: search-time high-document-frequency term filtering.  Terms
        whose document frequency exceeds ``df_max_ratio * N`` are dropped from
        the *query* (the paper's "filter highly-frequent terms at search
        time"); 1.0 disables it.
    scoring: "classic" = Lucene ClassicSimilarity (tf-idf variant:
        sum_t tf_q(t) * sqrt(tf_d(t)) * idf(t)^2 * norm(d));
        "dot" = raw quantized inner product (idealized mode,
        <T_d, t_q>/Q^2 ~= cosine on unit vectors).
    store_dtype: dtype for the stored term-frequency matrix.  Q <= 127 keeps
        the paper's whole sweep inside int8 (the MXU's fast integer path).
    """

    quantization: int = 50
    df_max_ratio: float = 1.0
    scoring: str = "classic"  # "classic" | "dot"
    store_dtype: Any = jnp.int8
    # dot mode only: store the SIGNED quantized matrix (pos - neg, (N, m))
    # instead of the sign-split (N, 2m).  Mathematically identical scores
    # ((d+ - d-).(q+ - q-) == [d+;d-].[u;-u]) at HALF the index bytes and
    # half the scan GEMM width — a beyond-paper optimization (§Perf C3).
    signed_store: bool = False

    def __post_init__(self) -> None:
        if not (1 <= self.quantization <= 127):
            raise ValueError(f"quantization must be in [1,127], got {self.quantization}")
        if self.scoring not in ("classic", "dot"):
            raise ValueError(f"scoring must be 'classic' or 'dot', got {self.scoring}")
        if self.signed_store and self.scoring != "dot":
            raise ValueError("signed_store requires scoring='dot'")
        # Canonicalize to a numpy dtype so configs compare/hash equal however
        # the dtype was spelled (jnp.int8 vs np.dtype("int8") vs "int8") —
        # load()ed configs must equal built ones.
        import numpy as _np

        object.__setattr__(self, "store_dtype", _np.dtype(self.store_dtype))


@dataclasses.dataclass(frozen=True)
class LexicalLshConfig:
    """Lexical LSH encoding.

    Each feature is rounded to ``decimals`` decimal places and tagged with its
    feature index (``2_0.4``), optionally aggregated into ``ngram``-grams, then
    MinHashed with ``hashes`` hash functions into ``buckets`` buckets
    (Lucene's MinHashFilter).  The paper's settings: (b=300,h=1) and
    (b=50,h=30), with n in {1,2}.
    """

    buckets: int = 300
    hashes: int = 1
    ngram: int = 1
    decimals: int = 1
    seed: int = 0x5EED

    def __post_init__(self) -> None:
        if self.ngram not in (1, 2, 3):
            raise ValueError("ngram in {1,2,3} supported")
        if self.buckets < 1 or self.hashes < 1:
            raise ValueError("buckets and hashes must be >= 1")


@dataclasses.dataclass(frozen=True)
class KdTreeConfig:
    """k-d tree over dimensionality-reduced vectors.

    Lucene's BKD point index handles at most 8 dimensions, so the paper first
    reduces 300-d embeddings with PCA (Wold et al.) or PPA->PCA->PPA
    (Mu et al. / Raunak).  ``backend``:
      * "tree"  - faithful array-based k-d tree with batched while_loop
                  traversal (documented as TPU-hostile; see DESIGN.md §3);
      * "scan"  - the TPU-idiomatic equivalent: brute-scan of the reduced
                  matrix (a streaming matmul).  Identical results (exact NN in
                  the reduced space), roofline-friendly.
    """

    dims: int = 8
    reduction: str = "pca"  # "pca" | "ppa-pca-ppa"
    ppa_remove: int = 3  # top components removed by PPA (d/100 per Mu et al.)
    backend: str = "scan"  # "tree" | "scan"
    leaf_size: int = 32

    def __post_init__(self) -> None:
        if self.dims > 8:
            raise ValueError("Lucene BKD supports at most 8 dims (paper constraint)")
        if self.reduction not in ("pca", "ppa-pca-ppa"):
            raise ValueError(f"unknown reduction {self.reduction}")
        if self.backend not in ("tree", "scan"):
            raise ValueError(f"unknown backend {self.backend}")


@dataclasses.dataclass(frozen=True)
class BruteForceConfig:
    """Exact cosine scan over the stored vectors — the paper's brute-force
    oracle as a first-class method.  Identity query encoding; the match phase
    is the fused streaming cosine top-k (or its XLA reference)."""


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    """Flat navigable-graph encoding (user-facing method name ``"hnsw"``).

    A single-layer Vamana-style proximity graph rather than a literal
    multi-layer HNSW: fixed-degree int32 adjacency arrays and a
    fixed-iteration batched beam search keep every shape static, which is
    what jit / Pallas / shard_map want (docs/DESIGN.md §15 justifies the
    choice).  Search cost per query is ~``entries + iters*beam*degree``
    scored rows — sublinear in N, unlike every other encoding here.

    degree:          forward edges per node (alpha-pruned nearest-out).
    reverse_degree:  extra slots filled with reverse edges (makes the
                     graph near-undirected; rescues connectivity that
                     forward pruning alone can lose).  Total fixed degree
                     = degree + reverse_degree; absent edges are -1.
    ef_construction: exact-kNN candidate pool size per node at build time.
    alpha:           Vamana robust-prune slack (1.0 = pure greedy prune).
    ef:              default search-time candidate list size (overridable
                     per matcher; static under jit).
    beam:            nodes expanded per traversal iteration (static).
    iters:           traversal iterations; 0 derives ``ceil(2*ef/beam)``.
    entries:         entry points seeding the search (medoid + strided).
    build_tile:      doc-tile size for the streaming exact-kNN pass.
    """

    degree: int = 16
    reverse_degree: int = 16
    ef_construction: int = 64
    alpha: float = 1.2
    ef: int = 64
    beam: int = 4
    iters: int = 0
    entries: int = 4
    build_tile: int = 2048

    def __post_init__(self) -> None:
        if self.degree < 1:
            raise ValueError(f"degree must be >= 1, got {self.degree}")
        if self.reverse_degree < 0:
            raise ValueError("reverse_degree must be >= 0")
        if self.ef_construction < self.degree:
            raise ValueError(
                f"ef_construction {self.ef_construction} < degree {self.degree}")
        if self.alpha < 1.0:
            raise ValueError(f"alpha must be >= 1.0, got {self.alpha}")
        if self.ef < 1 or self.beam < 1 or self.entries < 1:
            raise ValueError("ef, beam and entries must be >= 1")
        if self.iters < 0:
            raise ValueError("iters must be >= 0 (0 = derive from ef/beam)")
        if self.build_tile < 1:
            raise ValueError("build_tile must be >= 1")

    @property
    def total_degree(self) -> int:
        return self.degree + self.reverse_degree

    @property
    def search_iters(self) -> int:
        if self.iters:
            return self.iters
        return max(1, -(-2 * self.ef // self.beam))


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Two-phase search parameters: retrieve depth-d candidates, optionally
    exact-rerank them down to k (the refinement the paper describes but did
    not implement)."""

    k: int = 10
    depth: int = 100
    rerank: bool = False


# --------------------------------------------------------------------------
# Index containers (pytrees of arrays)
# --------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DocMetadata:
    """Per-document structured metadata — the predicate source for filtered
    kNN (docs/DESIGN.md §13).

    values:      (N, F) int32; column f holds field ``field_names[f]``.
                 Integer-coded by the caller (categorical codes, bucketed
                 timestamps, price cents, ...); a (N,) per-field layout
                 would fragment the gather, one matrix keeps it a slice.
    field_names: static tuple of F field names (pytree metadata, like
                 ``QuantizedPostings.bits``), so the container stays
                 jit-traceable and save/load can persist names without an
                 array sidecar.

    The ``*_mask`` helpers build (N,) bool predicate bitmaps that feed the
    match stage's ``filt`` operand (kernels mask them to -inf inside the
    tile loop); compose predicates with ``&`` / ``|`` on the bitmaps.
    """

    values: jax.Array
    field_names: Tuple[str, ...] = dataclasses.field(metadata=dict(static=True))

    @classmethod
    def from_fields(cls, fields: Mapping[str, Any]) -> "DocMetadata":
        """Build from a ``{field_name: (N,) int array}`` mapping (insertion
        order fixes the column order)."""
        names = tuple(fields.keys())
        cols = [jnp.asarray(fields[n]).astype(jnp.int32) for n in names]
        return cls(values=jnp.stack(cols, axis=1), field_names=names)

    @property
    def num_docs(self) -> int:
        return self.values.shape[0]

    def _col(self, field: str) -> jax.Array:
        return self.values[:, self.field_names.index(field)]

    def eq_mask(self, field: str, value: int) -> jax.Array:
        """(N,) bool: field == value."""
        return self._col(field) == jnp.int32(value)

    def in_mask(self, field: str, values: Iterable[int]) -> jax.Array:
        """(N,) bool: field in values (small static value set)."""
        col = self._col(field)
        out = jnp.zeros(col.shape, bool)
        for v in values:
            out = out | (col == jnp.int32(v))
        return out

    def range_mask(
        self, field: str, lo: Optional[int] = None, hi: Optional[int] = None
    ) -> jax.Array:
        """(N,) bool: lo <= field < hi (either bound optional)."""
        col = self._col(field)
        out = jnp.ones(col.shape, bool)
        if lo is not None:
            out = out & (col >= jnp.int32(lo))
        if hi is not None:
            out = out & (col < jnp.int32(hi))
        return out

    def nbytes(self) -> int:
        return self.values.size * self.values.dtype.itemsize


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedStore:
    """int8 symmetric per-doc quantized rerank store (docs/DESIGN.md §8).

    q:     (N, dim) int8, q[d] = round(v[d] / scale[d]).
    scale: (N,) float32 per-doc scale = max_i |v[d,i]| / 127 (symmetric:
           zero maps to zero, so dequantization is one multiply).

    v̂[d] = q[d] * scale[d] reconstructs within scale[d]/2 per component,
    so a unit query's rerank score error is bounded by
    ``||q_norm||_1 * scale[d] / 2`` — while the rerank gather moves ~4x
    fewer HBM bytes than the fp32 original vectors.
    """

    q: jax.Array
    scale: jax.Array

    @property
    def num_docs(self) -> int:
        return self.q.shape[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedPostings:
    """Packed int8/int4 primary postings + dequantization scales — the
    match-stage analogue of :class:`QuantizedStore` (docs/DESIGN.md §12).

    The fp32/bf16 posting matrix is quantized at build time and never
    streamed again: the fused kernel unpacks and rescales tiles in VMEM.

    bits == 8 (per-doc scale):
      q:     (N, T) int8, q[d,t] = round(mat[d,t] / scale[d]).
      scale: (N, 1) float32 per-doc scale = max_t |mat[d,t]| / 127.
      Dequantization factorizes out of the dot (scale is constant per row),
      so scores are computed as (q_query @ q.T) * scale — applied once per
      (query, doc) AFTER the reduction, exactly like the kernel does.

    bits == 4 (grouped scale, ``group`` columns per scale):
      q:     (N, Tg/2) uint8; column pairs packed as low | (high << 4) with
             Tg = round_up(T, group); nibble = clip(round(mat/gs), -8, 7)+8,
             so the 0-pad columns encode as nibble 8 and dequantize to 0.
      scale: (N, Tg/group) float32 per-group scale = max |group| / 7.
      Dequantized value = (nibble - 8) * scale[d, t // group].

    ``cols`` is the logical (pre-padding) column count T.  ``bits``,
    ``group`` and ``cols`` are static pytree metadata (like
    ``BlockMaxIndex.block_size``) so the container stays jit-traceable.
    """

    q: jax.Array
    scale: jax.Array
    bits: int = dataclasses.field(metadata=dict(static=True))
    group: int = dataclasses.field(default=0, metadata=dict(static=True))
    cols: int = dataclasses.field(default=0, metadata=dict(static=True))

    @property
    def num_docs(self) -> int:
        return self.q.shape[0]

    def nbytes(self) -> int:
        return (self.q.size * self.q.dtype.itemsize
                + self.scale.size * self.scale.dtype.itemsize)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FakeWordsIndex:
    """Sign-split quantized term-frequency index.

    tf:      (N, 2m) integer term frequencies; columns [0,m) hold
             round(Q*relu(w)), columns [m,2m) hold round(Q*relu(-w)).
    idf:     (2m,) float32 Lucene idf = 1 + ln(N / (df + 1)).
    norm:    (N,) float32 Lucene field norm = 1/sqrt(doc_len);
             doc_len = sum_t tf(t, d).
    df:      (2m,) int32 document frequency per fake term.
    scored:  (N, 2m) bfloat16 precomputed sqrt(tf)*idf^2*norm (classic mode
             scoring matrix) or None in dot mode / when the classic matrix
             is stored quantized (``pq``).
    vectors: (N, dim) original float vectors kept for exact reranking, or
             None if reranking is disabled at build time.
    vq:      int8 :class:`QuantizedStore` rerank alternative (or None); built
             by the ``rerank_store="int8"`` BuildPipeline stage.
    pq:      :class:`QuantizedPostings` primary-postings store (or None):
             classic mode quantizes ``scored`` (which is then dropped);
             dot mode's int8 store is the native int8 ``tf`` itself, and
             int4 packs ``tf`` (the ``tf`` leaf is then dropped and ``df``
             is frozen Lucene-style — docs/DESIGN.md §12).

    ``tf`` may be None only when ``pq`` carries the dot-mode int4 store.
    """

    tf: Optional[jax.Array]
    idf: jax.Array
    norm: jax.Array
    df: jax.Array
    scored: Optional[jax.Array] = None
    vectors: Optional[jax.Array] = None
    vq: Optional[QuantizedStore] = None
    pq: Optional[QuantizedPostings] = None

    @property
    def num_docs(self) -> int:
        return self.norm.shape[0]

    @property
    def num_terms(self) -> int:
        return self.idf.shape[0]

    def nbytes(self) -> int:
        total = 0
        for leaf in jax.tree_util.tree_leaves(self):
            total += leaf.size * leaf.dtype.itemsize
        return total


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LshIndex:
    """MinHash signature index.

    sig:     (N, h*b) uint32 signatures; SENTINEL marks empty buckets.
    vectors: (N, dim) originals for reranking (optional).
    vq:      int8 :class:`QuantizedStore` rerank alternative (optional).
    """

    sig: jax.Array
    vectors: Optional[jax.Array] = None
    vq: Optional[QuantizedStore] = None

    SENTINEL = jnp.uint32(0xFFFFFFFF)

    @property
    def num_docs(self) -> int:
        return self.sig.shape[0]

    def nbytes(self) -> int:
        total = 0
        for leaf in jax.tree_util.tree_leaves(self):
            total += leaf.size * leaf.dtype.itemsize
        return total


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KdTreeIndex:
    """Reduced-space index.

    reduced:   (N, dims) float32 reduced vectors (the "points" in the BKD
               tree).
    reduction: fitted reduction model pytree (PcaModel or PpaPcaPpaModel) used
               to project queries.
    split_*:   array-encoded balanced k-d tree (backend="tree"); ``perm`` maps
               leaf slots back to original doc ids (-1 = padding).
    lifted:    (N, dims+1) float32 ``[d; -||d||^2]`` scan operand precomputed
               at build time so the fused-kernel scan path streams it
               directly instead of re-materializing the lift per search.
    """

    reduced: jax.Array
    reduction: Any
    split_dim: Optional[jax.Array] = None  # (n_internal,) int32
    split_val: Optional[jax.Array] = None  # (n_internal,) float32
    perm: Optional[jax.Array] = None  # (n_leaves, leaf_size) int32 doc ids
    lifted: Optional[jax.Array] = None  # (N, dims+1) f32 scan-kernel operand
    vectors: Optional[jax.Array] = None
    vq: Optional[QuantizedStore] = None

    @property
    def num_docs(self) -> int:
        return self.reduced.shape[0]

    def nbytes(self) -> int:
        total = 0
        for leaf in jax.tree_util.tree_leaves(self):
            total += leaf.size * leaf.dtype.itemsize
        return total


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FlatIndex:
    """Brute-force "index": just the unit-normalized original vectors.

    vectors: (N, dim) float32.  Exists so the exact-cosine oracle rides the
    same AnnIndex -> SearchPipeline -> AnnService path as the three paper
    encodings (one retrieval architecture for every method).  ``vectors``
    is the match operand unless ``pq`` holds int8/int4 quantized postings
    (docs/DESIGN.md §12), in which case the match stage streams the packed
    store and ``vectors`` may be None (when the rerank store dropped the
    originals too); ``vq`` is the optional int8 rerank store so the
    quantized-rerank knob is uniform across methods.
    """

    vectors: Optional[jax.Array]
    vq: Optional[QuantizedStore] = None
    pq: Optional[QuantizedPostings] = None

    @property
    def num_docs(self) -> int:
        if self.vectors is not None:
            return self.vectors.shape[0]
        assert self.pq is not None  # invariant: vectors dropped only with pq
        return self.pq.num_docs

    def nbytes(self) -> int:
        total = 0
        for leaf in jax.tree_util.tree_leaves(self):
            total += leaf.size * leaf.dtype.itemsize
        return total


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphIndex:
    """Flat proximity-graph index (docs/DESIGN.md §15).

    vectors:   (N, dim) float32 unit rows — the match operand (neighbor
               blocks are gathered from it and scored exactly) and the
               rerank store, so graph scores ARE exact cosines and the
               only approximation is which rows get visited.
    neighbors: (N, degree+reverse_degree) int32 adjacency; -1 = no edge.
               Row-major fixed degree keeps the per-iteration gather a
               static-shape (B, beam*R) slab for ``fused_topk_gathered``.
    entry:     (entries,) int32 search entry points: the medoid (row whose
               dot with the corpus mean is largest) followed by
               deterministic strided rows.
    vq:        optional int8 rerank store (uniform quantized-rerank knob).
    """

    vectors: jax.Array
    neighbors: jax.Array
    entry: jax.Array
    vq: Optional[QuantizedStore] = None

    @property
    def num_docs(self) -> int:
        return self.vectors.shape[0]

    @property
    def total_degree(self) -> int:
        return self.neighbors.shape[1]

    def nbytes(self) -> int:
        total = 0
        for leaf in jax.tree_util.tree_leaves(self):
            total += leaf.size * leaf.dtype.itemsize
        return total


SearchResult = Tuple[jax.Array, jax.Array]  # (scores (B,k), ids (B,k))
