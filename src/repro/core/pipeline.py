"""Staged retrieval pipeline: one search architecture for every encoding.

The paper's three Lucene encodings (fake words, lexical LSH, k-d trees) and
the brute-force oracle all share one logical flow:

    encode query  ->  match candidates  ->  [optional blockmax prune]
                  ->  optional exact rerank

This module makes that flow structural.  A :class:`SearchPipeline` composes
three pluggable stages, each a frozen (hashable, jit-static) dataclass:

  * **QueryEncoder** — ``encoder(index, q_norm) -> q_rep``: the method's
    query representation (tf row / MinHash signature / reduced point /
    identity for brute force).  Takes the index so reductions fitted at
    build time (k-d tree PCA) travel with the index pytree.
  * **Matcher** — ``matcher(index, q_rep, depth, *, bm=None, use_kernel=None)
    -> (scores (B, d), ids (B, d))``: the approximate match phase.  Every
    matcher has two realizations selected by ``use_kernel`` (default: the
    fused streaming score->top-k Pallas kernel on TPU, the XLA reference
    elsewhere — docs/DESIGN.md §4).  :class:`BlockMaxMatcher` is the pruning
    stage: it consumes a ``BlockMaxIndex`` (``bm``) and routes the kept
    blocks through the fused gathered kernel (docs/DESIGN.md §6).
  * **Reranker** — ``reranker(index, queries, cand_ids, k)``: exact cosine
    over the stored original vectors (the refinement the paper describes).

Because stages take the index pytree as an explicit argument, the *same*
stage objects run single-device under ``jit`` and per-shard under
``shard_map`` (core/distributed.py), and a new encoding is a ~50-line
encoder+matcher pair, not a new module.  ``repro.core.index.AnnIndex`` builds
and owns a pipeline; the per-method ``search()`` functions are thin wrappers
over these stages.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import bruteforce
from repro.core.types import (
    BruteForceConfig,
    FakeWordsConfig,
    GraphConfig,
    KdTreeConfig,
    LexicalLshConfig,
    SearchParams,
)

AnyConfig = Union[
    FakeWordsConfig, LexicalLshConfig, KdTreeConfig, BruteForceConfig,
    GraphConfig,
]


# --------------------------------------------------------------------------
# Query encoders
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TfRowEncoder:
    """Fake-words: sign-split quantized term-frequency row (B, 2m) int32."""

    config: FakeWordsConfig

    def __call__(self, index, q_norm: jax.Array) -> jax.Array:
        from repro.core import fakewords

        return fakewords.encode_queries(q_norm, self.config, normalized=True)


@dataclasses.dataclass(frozen=True)
class MinHashEncoder:
    """Lexical LSH: MinHash signature (B, h*b) uint32."""

    config: LexicalLshConfig

    def __call__(self, index, q_norm: jax.Array) -> jax.Array:
        from repro.core import lexical_lsh

        return lexical_lsh.encode(q_norm, self.config)


@dataclasses.dataclass(frozen=True)
class ReducedPointEncoder:
    """k-d tree: project through the reduction fitted at build time."""

    def __call__(self, index, q_norm: jax.Array) -> jax.Array:
        from repro.core import kdtree

        return kdtree.reduce_queries(index, q_norm, normalized=True)


@dataclasses.dataclass(frozen=True)
class IdentityEncoder:
    """Brute force: the unit-normalized query itself."""

    def __call__(self, index, q_norm: jax.Array) -> jax.Array:
        return q_norm


# --------------------------------------------------------------------------
# Matchers
# --------------------------------------------------------------------------


def _use_kernel(use_kernel: Optional[bool]) -> bool:
    from repro.kernels.fused_topk import ops as fused

    return fused.resolve_use_kernel(use_kernel)


def lookup_filt_bits(mask: jax.Array, ids: jax.Array) -> jax.Array:
    """Per-candidate keep bits of a per-doc bitmap ((N,) shared or (B, N)
    per-query) at candidate id positions; id -1 slots read doc 0 (callers
    AND with ``ids >= 0``)."""
    safe = jnp.maximum(ids, 0)
    bits = mask[safe] if mask.ndim == 1 else jnp.take_along_axis(mask, safe, axis=1)
    return bits != 0


def mask_and_topk(
    s: jax.Array, i: jax.Array, keep: jax.Array, depth: int, n: int
) -> Tuple[jax.Array, jax.Array]:
    """THE shared mask-then-re-reduce tail of every post-hoc candidate
    filter (deletes AND predicate bitmaps): kept slots retain the inner
    stage's (score, id); dropped slots become (-inf, -1); the survivors
    re-reduce to the top ``min(depth, n)``.  Equal-score ties keep the
    inner stage's lowest-doc-id order (``lax.top_k`` is stable)."""
    s = jnp.where(keep, s, -jnp.inf)
    i = jnp.where(keep, i, -1)
    d_out = min(depth, n)
    top_s, pos = jax.lax.top_k(s, d_out)
    return top_s, jnp.take_along_axis(i, pos, axis=-1)


def _dense_filtered_topk(
    scores: jax.Array, depth: int, filt: Optional[jax.Array]
) -> Tuple[jax.Array, jax.Array]:
    """Dense-matrix XLA top-k with the kernel's filter contract: masked
    slots take (-inf, -1).  ``filt=None`` is exactly ``jax.lax.top_k``."""
    from repro.kernels.fused_topk import ref as fused_ref

    if filt is None:
        return jax.lax.top_k(scores, depth)
    s, i = jax.lax.top_k(fused_ref.apply_filt(scores, filt), depth)
    return s, jnp.where(s == -jnp.inf, -1, i)


def _streaming_topk_tiled(
    score_tile_fn, n_local: int, batch: int, depth: int, tile: int,
    unroll: bool = False, filt: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Streaming top-d over document tiles with a running merge: the
    (B, n_local) score matrix never materializes in HBM (§Perf C2).  The XLA
    realization of the fused kernel's memory behavior, used for shards too
    large for a dense GEMM when the Pallas kernel is off.

    score_tile_fn(start) -> (B, tile) scores for docs [start, start+tile).
    Ties break on the lowest doc id: earlier tiles enter the merge first and
    ``lax.top_k`` prefers the earlier position on equal scores.
    """
    n_tiles = -(-n_local // tile)
    d = min(depth, tile)
    init_s = jnp.full((batch, depth), -jnp.inf, jnp.float32)
    init_i = jnp.full((batch, depth), -1, jnp.int32)
    if filt is not None:
        f_full = filt if filt.ndim == 2 else filt[None, :]
        pad = n_tiles * tile - f_full.shape[1]
        if pad:  # pre-pad so per-tile slices never clamp
            f_full = jnp.concatenate(
                [f_full, jnp.zeros((f_full.shape[0], pad), f_full.dtype)],
                axis=1,
            )

    def body(carry, t_idx):
        best_s, best_i = carry
        start = t_idx * tile
        s = score_tile_fn(start).astype(jnp.float32)  # (B, tile)
        ids = start + jnp.arange(tile, dtype=jnp.int32)[None, :]
        valid = ids < n_local
        if filt is not None:
            f_tile = jax.lax.dynamic_slice_in_dim(f_full, start, tile, axis=1)
            valid = valid & (f_tile != 0)
        s = jnp.where(valid, s, -jnp.inf)
        loc_s, pos = jax.lax.top_k(s, d)
        loc_i = jnp.take_along_axis(jnp.broadcast_to(ids, s.shape), pos, axis=-1)
        if filt is not None:
            loc_i = jnp.where(loc_s == -jnp.inf, -1, loc_i)
        all_s = jnp.concatenate([best_s, loc_s], axis=-1)
        all_i = jnp.concatenate([best_i, loc_i], axis=-1)
        top_s, top_pos = jax.lax.top_k(all_s, depth)
        return (top_s, jnp.take_along_axis(all_i, top_pos, axis=-1)), None

    (best_s, best_i), _ = jax.lax.scan(
        body, (init_s, init_i), jnp.arange(n_tiles, dtype=jnp.int32),
        unroll=unroll,  # analysis mode: HLO cost analysis counts a while
        #                 body once; roofline lowers the unrolled loop
    )
    return best_s, best_i


@dataclasses.dataclass(frozen=True)
class FakeWordsMatcher:
    """Classic (tf-idf) or dot (quantized integer) scoring over the stored
    term-frequency matrix; df-prune keep-mask folded into the query operand.

    ``score_tile`` (when set) bounds the XLA fallback's working set: shards
    larger than ``2 * score_tile`` docs stream tile-by-tile with a running
    top-d merge instead of materializing the dense (B, N) score matrix.

    ``df_num_docs`` (when set) is the collection size the df-prune keep-mask
    thresholds against instead of the index's own row count — the segmented
    index (docs/DESIGN.md §11) scores every segment with GLOBAL collection
    statistics, Lucene-IndexSearcher style.
    """

    scoring: str = "classic"
    df_max_ratio: float = 1.0
    signed_store: bool = False
    score_tile: Optional[int] = None
    tile_unroll: bool = False
    df_num_docs: Optional[int] = None

    def operands(self, index, q_tf: jax.Array, dtype) -> Tuple[jax.Array, jax.Array]:
        """(query operand, stored matrix) for this scoring mode; ``dtype``
        is the dot-mode query dtype (int8 for the MXU kernel, int32 for the
        XLA einsum)."""
        from repro.core import fakewords

        n = self.df_num_docs if self.df_num_docs is not None else index.num_docs
        if self.scoring == "classic":
            return (
                fakewords.classic_query(
                    index, q_tf, self.df_max_ratio, num_docs=n),
                index.scored,
            )
        if self.signed_store:
            # index.tf holds the SIGNED (N, m) matrix; fold the sign-split
            # keep mask down to m terms.
            keep = fakewords.df_prune_mask(index.df, n, self.df_max_ratio)
            m = index.tf.shape[1]
            keep_m = keep[:m] & keep[m:] if keep.shape[0] == 2 * m else keep[:m]
            qv = (fakewords.signed_query(q_tf) * keep_m).astype(dtype)
            return qv, index.tf
        return (
            fakewords.dot_query(
                index, q_tf, self.df_max_ratio, dtype=dtype, num_docs=n),
            index.tf,
        )

    def quantized_query(self, index, q_tf: jax.Array) -> jax.Array:
        """bf16 query operand for the packed-postings path (docs/DESIGN.md
        §12): both scoring modes dequantize the store to the query dtype in
        the score stage, so the query itself must be float."""
        from repro.core import fakewords

        n = self.df_num_docs if self.df_num_docs is not None else index.num_docs
        if self.scoring == "classic":
            return fakewords.classic_query(
                index, q_tf, self.df_max_ratio, num_docs=n)
        if index.pq.cols * 2 == index.df.shape[0]:
            # Genuinely signed packed store (N, m); the pipeline-built
            # signed_store index still stores the sign-split 2m columns.
            keep = fakewords.df_prune_mask(index.df, n, self.df_max_ratio)
            m = index.pq.cols
            keep_m = keep[:m] & keep[m:]
            return (fakewords.signed_query(q_tf) * keep_m).astype(jnp.bfloat16)
        return fakewords.dot_query(
            index, q_tf, self.df_max_ratio, dtype=jnp.bfloat16, num_docs=n)

    def _dense_scores(self, qv: jax.Array, docs: jax.Array) -> jax.Array:
        if self.scoring == "classic":
            return jnp.einsum(
                "bt,nt->bn", qv, docs, preferred_element_type=jnp.float32
            )
        return jnp.einsum(
            "bt,nt->bn", qv, docs.astype(jnp.int32),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32)

    def __call__(
        self, index, q_tf: jax.Array, depth: int,
        bm=None, use_kernel: Optional[bool] = None,
        filt: Optional[jax.Array] = None,
        n_docs: Optional[int] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        from repro.kernels.fused_topk import ops as fused

        # n_docs: logical row count when the stored matrix carries tail
        # padding (core/packed.py bucket ladder); None = every stored row.
        nd = index.num_docs if n_docs is None else n_docs
        ndk = None if nd == index.num_docs else nd
        d = min(depth, nd)
        if index.pq is not None:
            from repro.kernels.fused_topk import ref as fused_ref

            qv = self.quantized_query(index, q_tf)
            pq = index.pq
            if _use_kernel(use_kernel):
                return fused.postings_topk(pq, qv, d, filt=filt, n_docs=ndk)
            if self.score_tile is not None and index.num_docs > 2 * self.score_tile:
                return fused_ref.streaming_topk_quantized_ref(
                    qv, pq.q, pq.scale, d, pq.bits, pq.group,
                    tile=self.score_tile, filt=filt, n_docs=ndk,
                )
            return fused_ref.quantized_topk_ref(
                qv, pq.q, pq.scale, d, pq.bits, pq.group, filt=filt,
                n_docs=ndk)
        if _use_kernel(use_kernel):
            qv, docs = self.operands(index, q_tf, dtype=jnp.int8)
            return fused.fused_topk(qv, docs, d, filt=filt, n_docs=ndk)
        qv, docs = self.operands(index, q_tf, dtype=jnp.int32)
        if self.score_tile is not None and index.num_docs > 2 * self.score_tile:
            def tile_scores(start):
                rows = jax.lax.dynamic_slice_in_dim(
                    docs, start, self.score_tile, axis=0)
                return self._dense_scores(qv, rows)

            return _streaming_topk_tiled(
                tile_scores, nd, q_tf.shape[0], d,
                self.score_tile, unroll=self.tile_unroll, filt=filt,
            )
        scores = self._dense_scores(qv, docs)
        if ndk is not None:
            scores = scores[:, :nd]
            filt = None if filt is None else filt[..., :nd]
        return _dense_filtered_topk(scores, d, filt)


@dataclasses.dataclass(frozen=True)
class LshMatcher:
    """MinHash signature-collision counting (integer compare+reduce)."""

    def __call__(
        self, index, sig_q: jax.Array, depth: int,
        bm=None, use_kernel: Optional[bool] = None,
        filt: Optional[jax.Array] = None,
        n_docs: Optional[int] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        from repro.core import lexical_lsh
        from repro.kernels.fused_topk import ops as fused

        nd = index.num_docs if n_docs is None else n_docs
        ndk = None if nd == index.num_docs else nd
        d = min(depth, nd)
        if _use_kernel(use_kernel):
            return fused.lsh_topk(sig_q, index.sig, d, filt=filt, n_docs=ndk)
        scores = lexical_lsh.match_scores(sig_q, index.sig).astype(jnp.float32)
        if ndk is not None:
            scores = scores[:, :nd]
            filt = None if filt is None else filt[..., :nd]
        return _dense_filtered_topk(scores, d, filt)


@dataclasses.dataclass(frozen=True)
class KdScanMatcher:
    """Exact L2 NN in the reduced space as a streaming matmul (the
    TPU-idiomatic equivalent of the paper's BKD tree; kdtree.py §b)."""

    def __call__(
        self, index, q_reduced: jax.Array, depth: int,
        bm=None, use_kernel: Optional[bool] = None,
        filt: Optional[jax.Array] = None,
        n_docs: Optional[int] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        from repro.kernels.fused_topk import ops as fused

        nd = index.num_docs if n_docs is None else n_docs
        ndk = None if nd == index.num_docs else nd
        d = min(depth, nd)
        if _use_kernel(use_kernel):
            lifted = (
                index.lifted if index.lifted is not None
                else fused.lift_l2(index.reduced)
            )
            return fused.scan_l2_topk(
                lifted, q_reduced, d, filt=filt, n_docs=ndk)
        d_norm2 = jnp.sum(index.reduced**2, axis=-1)  # (N,)
        dots = q_reduced @ index.reduced.T  # (B, N)
        neg_d2 = 2.0 * dots - d_norm2[None, :]
        if ndk is not None:
            neg_d2 = neg_d2[:, :nd]
            filt = None if filt is None else filt[..., :nd]
        return _dense_filtered_topk(neg_d2, d, filt)


@dataclasses.dataclass(frozen=True)
class KdTreeMatcher:
    """Faithful batched k-d tree DFS (the paper's data structure; documented
    TPU-hostile, kept for fidelity).  Ignores ``use_kernel``."""

    def __call__(
        self, index, q_reduced: jax.Array, depth: int,
        bm=None, use_kernel: Optional[bool] = None,
        filt: Optional[jax.Array] = None,
        n_docs: Optional[int] = None,  # unused: host DFS has no padded rows
    ) -> Tuple[jax.Array, jax.Array]:
        from repro.core import kdtree

        n = index.num_docs
        s, i = kdtree.tree_search(index, q_reduced, min(depth, n))
        if filt is None:
            return s, i
        # The host DFS cannot thread a bitmap through its visit order; mask
        # its depth candidates post-hoc (best-effort, like a post-filter —
        # use the scan backend for exact filtered kd search).
        keep = (i >= 0) & lookup_filt_bits(filt, i)
        return mask_and_topk(s, i, keep, min(depth, n), n)


@dataclasses.dataclass(frozen=True)
class CosineMatcher:
    """Exact cosine over the stored unit vectors (brute-force oracle)."""

    def __call__(
        self, index, q_norm: jax.Array, depth: int,
        bm=None, use_kernel: Optional[bool] = None,
        filt: Optional[jax.Array] = None,
        n_docs: Optional[int] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        from repro.kernels.fused_topk import ops as fused

        nd = index.num_docs if n_docs is None else n_docs
        ndk = None if nd == index.num_docs else nd
        d = min(depth, nd)
        if index.pq is not None:
            from repro.kernels.fused_topk import ref as fused_ref

            if _use_kernel(use_kernel):
                return fused.postings_topk(
                    index.pq, q_norm, d, filt=filt, n_docs=ndk)
            return fused_ref.quantized_topk_ref(
                q_norm, index.pq.q, index.pq.scale, d,
                index.pq.bits, index.pq.group, filt=filt, n_docs=ndk,
            )
        if _use_kernel(use_kernel):
            return fused.cosine_topk(
                index.vectors, q_norm, d, filt=filt, n_docs=ndk)
        scores = q_norm @ index.vectors.T  # (B, N)
        if ndk is not None:
            scores = scores[:, :nd]
            filt = None if filt is None else filt[..., :nd]
        return _dense_filtered_topk(scores, d, filt)


@dataclasses.dataclass(frozen=True)
class GraphMatcher:
    """Batched beam search over the flat proximity graph (docs/DESIGN.md
    §15) — the repo's first sublinear match stage: per-query work is
    ~``iters * beam * total_degree`` scored rows, independent of N.

    ``ef`` / ``beam`` / ``iters`` are static fields (the matcher is a
    jit-static argument), so the traversal compiles to one fixed-iteration
    ``fori_loop`` executable per query-batch shape.  ``filt`` (liveDocs ∧
    predicate) is consulted INSIDE traversal: masked nodes stay traversable
    (connectivity survives low selectivity) but are never emitted.
    """

    ef: int = 64
    beam: int = 4
    iters: int = 32

    def __call__(
        self, index, q_norm: jax.Array, depth: int,
        bm=None, use_kernel: Optional[bool] = None,
        filt: Optional[jax.Array] = None,
        n_docs: Optional[int] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        from repro.core import graph

        assert bm is None, "graph search has no blockmax stage"
        nd = index.num_docs if n_docs is None else n_docs
        d = min(depth, nd)
        return graph.search_graph(
            index.vectors, index.neighbors, index.entry, q_norm, d,
            ef=self.ef, beam=self.beam, iters=self.iters, n_docs=nd,
            use_kernel=_use_kernel(use_kernel), filt=filt,
        )


@dataclasses.dataclass(frozen=True)
class BlockMaxMatcher:
    """Two-stage blockmax pruning (docs/DESIGN.md §6) as a matcher stage:
    optimistic block-bound pass -> keep ``n_keep`` blocks -> exact scoring of
    the gathered rows through the fused gathered streaming top-k kernel.
    Mode (classic / dot-int8 / LSH presence bitmaps) travels with ``bm``."""

    n_keep: int

    def __call__(
        self, index, q_rep: jax.Array, depth: int,
        bm=None, use_kernel: Optional[bool] = None,
        filt: Optional[jax.Array] = None,
        n_docs: Optional[int] = None,  # padded rows ride the filt bitmap
    ) -> Tuple[jax.Array, jax.Array]:
        from repro.core import blockmax

        assert bm is not None, "BlockMaxMatcher needs a BlockMaxIndex (bm=)"
        return blockmax.pruned_topk(
            index, bm, q_rep, self.n_keep, depth, use_kernel=use_kernel,
            filt=filt,
        )


@dataclasses.dataclass(frozen=True)
class FilterMask:
    """Per-doc predicate masking as a match-stage wrapper — Lucene liveDocs
    generalized to arbitrary bitmaps (docs/DESIGN.md §11, §13).

    Masked docs come back as ``(-inf, -1)`` INSIDE the match stage — never
    post-filtered from its output — so ``depth`` semantics survive.  Two
    realizations, selected per call:

      * ``native=True`` — the bitmap threads straight into the inner
        matcher's score stage (the kernels' ``filt`` operand / the XLA
        refs' pre-top-k mask): ONE kernel pass, exact at any selectivity.
        This is the predicate-filter path.
      * ``native=False`` (default) — depth inflation: ask the inner matcher
        for ``depth + extra`` candidates (``extra`` is a bucketed upper
        bound on the masked-out count, so at least ``depth`` kept
        candidates are present whenever that many exist) and re-reduce to
        the top ``depth`` kept docs via :func:`mask_and_topk`.  This is the
        historical liveDocs/deletes path, kept because the delete stream
        mutates the mask without re-specializing the inner match.

    Equal-score ties keep the inner matcher's lowest-doc-id order
    (``lax.top_k`` is stable), so a segment with deletes returns exactly
    what a segment never containing the dead rows would.

    ``mask`` is an explicit ``(N,)`` (or per-query ``(B, N)``) bool/int
    operand (nonzero = keep) rather than an index leaf: the segment index
    stays immutable while its mask mutates, exactly like Lucene's sidecar
    ``.liv`` bitsets.  ``extra`` is bucketed (next power of two) by the
    caller so a delete stream does not recompile per delete.
    """

    inner: Any
    extra: int = 0

    def __call__(
        self, index, q_rep: jax.Array, depth: int, mask: jax.Array,
        bm=None, use_kernel: Optional[bool] = None, native: bool = False,
    ) -> Tuple[jax.Array, jax.Array]:
        n = index.num_docs
        if native:
            return self.inner(
                index, q_rep, min(depth, n), bm=bm, use_kernel=use_kernel,
                filt=mask,
            )
        d_in = min(depth + self.extra, n)
        s, i = self.inner(index, q_rep, d_in, bm=bm, use_kernel=use_kernel)
        keep = (i >= 0) & lookup_filt_bits(mask, i)
        return mask_and_topk(s, i, keep, depth, n)


# Backwards-compatible name for the deletes-only wrapper this generalizes.
LiveDocsMatcher = FilterMask


# --------------------------------------------------------------------------
# Rerankers
# --------------------------------------------------------------------------


def candidate_scores(
    index, queries: jax.Array, cand_ids: jax.Array, quantized: bool = False
) -> jax.Array:
    """(B, d) cosine of each candidate against its query; id -1 = padding,
    masked to -inf.  The ONE rerank-gather both rerankers and the
    distributed local-rerank merge share.  ``quantized`` reads the int8
    :class:`repro.core.types.QuantizedStore` (``index.vq``) — the gather
    moves ~4x fewer HBM bytes and dequantizes with one per-doc multiply —
    instead of the fp32 originals."""
    safe = jnp.maximum(cand_ids, 0)
    if quantized:
        assert index.vq is not None, (
            "quantized rerank requires the index to carry an int8 store "
            "(build with rerank_store='int8')"
        )
        cand = index.vq.q[safe]  # (B, d, dim) int8 gather
        s = jnp.einsum("bd,bcd->bc", queries, cand.astype(jnp.float32))
        s = s * index.vq.scale[safe]
    else:
        assert index.vectors is not None, (
            "rerank requires the index to keep original vectors "
            "(build with keep_vectors=True / rerank_store='exact')"
        )
        s = jnp.einsum("bd,bcd->bc", queries, index.vectors[safe])
    return jnp.where(cand_ids >= 0, s, -jnp.inf)


@dataclasses.dataclass(frozen=True)
class ExactCosineReranker:
    """Gather the depth-d candidates' original vectors, exact cosine, top-k
    (id -1 = padding, masked to -inf)."""

    def __call__(
        self, index, queries: jax.Array, cand_ids: jax.Array, k: int
    ) -> Tuple[jax.Array, jax.Array]:
        assert index.vectors is not None, (
            "rerank requires the index to keep original vectors "
            "(build with keep_vectors=True)"
        )
        return bruteforce.rerank_exact(
            index.vectors, queries, cand_ids, k, normalized=True
        )


@dataclasses.dataclass(frozen=True)
class QuantizedCosineReranker:
    """Rerank from the int8 + per-doc-scale store (docs/DESIGN.md §8): same
    tie semantics as :class:`ExactCosineReranker`, score error bounded by
    ``||q||_1 * scale/2`` per candidate, ~4x fewer gather bytes."""

    def __call__(
        self, index, queries: jax.Array, cand_ids: jax.Array, k: int
    ) -> Tuple[jax.Array, jax.Array]:
        scores = candidate_scores(index, queries, cand_ids, quantized=True)
        top_s, pos = jax.lax.top_k(scores, k)
        return top_s, jnp.take_along_axis(cand_ids, pos, axis=-1)


def default_reranker(index):
    """Exact rerank when fp32 originals are stored, else the int8 store."""
    if getattr(index, "vectors", None) is None and index.vq is not None:
        return QuantizedCosineReranker()
    return ExactCosineReranker()


# --------------------------------------------------------------------------
# The pipeline
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SearchPipeline:
    """encode -> match [-> blockmax prune] -> optional exact rerank.

    Frozen and hashable: a pipeline is a jit-static description of *how* to
    search; all array state stays in the index pytree (and optional ``bm``)
    passed to every call — which is exactly what lets the same pipeline run
    per-shard under ``shard_map``.
    """

    encoder: Any
    matcher: Any
    reranker: Any = ExactCosineReranker()

    def encode(self, index, queries: jax.Array) -> jax.Array:
        """Unit-normalize + method-specific query representation."""
        return self.encoder(index, bruteforce.l2_normalize(jnp.asarray(queries)))

    def search(
        self,
        index,
        queries: jax.Array,
        params: SearchParams = SearchParams(),
        bm=None,
        use_kernel: Optional[bool] = None,
        filt: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        """End-to-end staged search (jitted; pipeline and params static).
        ``filt`` is a per-doc predicate bitmap ((N,) or (B, N), nonzero =
        keep) applied INSIDE the match stage's score pass."""
        q_norm = bruteforce.l2_normalize(jnp.asarray(queries))
        return _pipeline_search(self, index, q_norm, params, bm, use_kernel, filt)


@functools.partial(
    jax.jit, static_argnames=("pipe", "params", "use_kernel")
)
def _pipeline_search(
    pipe: SearchPipeline,
    index,
    q_norm: jax.Array,
    params: SearchParams,
    bm=None,
    use_kernel: Optional[bool] = None,
    filt: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    q_rep = pipe.encoder(index, q_norm)
    matcher = pipe.matcher
    d_s, d_i = matcher(
        index, q_rep, params.depth, bm=bm, use_kernel=use_kernel, filt=filt
    )
    if not params.rerank:
        return d_s[:, : params.k], d_i[:, : params.k]
    return pipe.reranker(index, q_norm, d_i, params.k)


@functools.partial(
    jax.jit,
    static_argnames=("matcher", "k", "depth", "rerank", "use_kernel", "reranker"),
)
def match_rerank(
    matcher,
    index,
    q_rep: jax.Array,
    queries: Optional[jax.Array],
    k: int,
    depth: int,
    rerank: bool,
    bm=None,
    use_kernel: Optional[bool] = None,
    reranker=None,
    filt: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Match + optional exact rerank from an already-encoded query — the
    shared tail of every per-method ``search()`` wrapper (queries must be
    unit-normalized when reranking).  ``reranker`` defaults to the store
    the index carries (fp32 originals, else the int8 quantized store).
    ``filt`` masks inside the match stage (one pass); rerank only re-scores
    survivors, so filtered docs can never resurface."""
    d_s, d_i = matcher(index, q_rep, depth, bm=bm, use_kernel=use_kernel,
                       filt=filt)
    if not rerank:
        return d_s[:, :k], d_i[:, :k]
    assert queries is not None
    if reranker is None:
        reranker = default_reranker(index)
    return reranker(index, queries, d_i, k)


# --------------------------------------------------------------------------
# Builders: every method is a stage configuration
# --------------------------------------------------------------------------


def make_encoder(config: AnyConfig):
    if isinstance(config, FakeWordsConfig):
        return TfRowEncoder(config)
    if isinstance(config, LexicalLshConfig):
        return MinHashEncoder(config)
    if isinstance(config, KdTreeConfig):
        return ReducedPointEncoder()
    if isinstance(config, (BruteForceConfig, GraphConfig)):
        return IdentityEncoder()
    raise TypeError(f"unknown config {type(config)}")


def make_matcher(
    config: AnyConfig,
    score_tile: Optional[int] = None,
    tile_unroll: bool = False,
):
    """The dense match stage for a method config.  ``score_tile`` activates
    the tiled-streaming XLA fallback for huge (sharded) fake-words corpora."""
    if isinstance(config, FakeWordsConfig):
        return FakeWordsMatcher(
            scoring=config.scoring,
            df_max_ratio=config.df_max_ratio,
            signed_store=config.signed_store,
            score_tile=score_tile,
            tile_unroll=tile_unroll,
        )
    if isinstance(config, LexicalLshConfig):
        return LshMatcher()
    if isinstance(config, KdTreeConfig):
        return KdTreeMatcher() if config.backend == "tree" else KdScanMatcher()
    if isinstance(config, BruteForceConfig):
        return CosineMatcher()
    if isinstance(config, GraphConfig):
        return GraphMatcher(
            ef=config.ef, beam=config.beam, iters=config.search_iters)
    raise TypeError(f"unknown config {type(config)}")


def build_pipeline(
    config: AnyConfig,
    score_tile: Optional[int] = None,
    tile_unroll: bool = False,
) -> SearchPipeline:
    return SearchPipeline(
        encoder=make_encoder(config),
        matcher=make_matcher(config, score_tile, tile_unroll),
    )
