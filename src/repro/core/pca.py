"""Dimensionality reduction for the k-d tree path.

The paper reduces 300-d embeddings to <= 8 dims (Lucene's BKD limit) with
either plain PCA (Wold et al. 1987) or the PPA->PCA->PPA pipeline of Raunak
(2017), where PPA is the "all-but-the-top" post-processing of Mu et al.
(2017): subtract the mean, remove the projections onto the top-D principal
components (D ~ dim/100).

All fits are exact eigendecompositions of the (dim x dim) covariance - dim is
300 here, so this is tiny; for a pod-scale corpus only the covariance
accumulation streams over the (sharded) data, which is a single
``psum``-able matmul.

Distributed fits (the BuildPipeline's kd-tree path, docs/DESIGN.md §8):
every fit here accepts ``axes``/``n_total``.  With ``axes`` set the call
runs *inside* ``shard_map`` over doc-sharded rows and the moments are
``psum``-ed — mean from the psum'd row sum, covariance from the psum'd
centered Gram matrix — so every shard fits the IDENTICAL model from global
statistics while its points stay shard-resident.  With ``axes=None`` the
exact same code path is the single-host fit (psum of one shard == local
sum), so local and sharded builds share one numerical recipe.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def _mean_cov(
    x: jax.Array,
    axes: Optional[Sequence[str]] = None,
    n_total: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Global (mean, covariance) of doc-sharded rows.

    Two psums: the row sum (-> global mean, replicated on every shard),
    then the centered Gram matrix ``sum (x - mean)(x - mean)^T`` — centering
    against the GLOBAL mean commutes with the shard sum, so the psum'd Gram
    equals the single-host centered Gram up to summation order (and bitwise
    on one shard).  dim x dim stays tiny; only the Gram matmul streams data.
    """
    if axes is None:
        mean = jnp.mean(x, axis=0)
        xc = x - mean
        return mean, (xc.T @ xc) / x.shape[0]
    assert n_total is not None, "sharded fit needs the global row count"
    mean = jax.lax.psum(jnp.sum(x, axis=0), axes) / n_total
    xc = x - mean
    return mean, jax.lax.psum(xc.T @ xc, axes) / n_total


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PcaModel:
    mean: jax.Array  # (dim,)
    components: jax.Array  # (dim, out_dim), columns = top eigenvectors


def pca_fit(
    x: jax.Array,
    out_dim: int,
    axes: Optional[Sequence[str]] = None,
    n_total: Optional[int] = None,
) -> PcaModel:
    """Fit PCA; returns projection onto the top ``out_dim`` components.
    ``axes`` runs the fit from psum'd moments inside ``shard_map``."""
    mean, cov = _mean_cov(x, axes, n_total)
    # eigh returns ascending eigenvalues; take the trailing columns.
    _, vecs = jnp.linalg.eigh(cov)
    comps = vecs[:, ::-1][:, :out_dim]
    return PcaModel(mean=mean, components=comps)


def pca_apply(model: PcaModel, x: jax.Array) -> jax.Array:
    return (x - model.mean) @ model.components


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PpaModel:
    """All-but-the-top (Mu et al.): remove mean + top-D components."""

    mean: jax.Array  # (dim,)
    top: jax.Array  # (dim, D)


def ppa_fit(
    x: jax.Array,
    remove: int,
    axes: Optional[Sequence[str]] = None,
    n_total: Optional[int] = None,
) -> PpaModel:
    mean, cov = _mean_cov(x, axes, n_total)
    _, vecs = jnp.linalg.eigh(cov)
    top = vecs[:, ::-1][:, :remove]
    return PpaModel(mean=mean, top=top)


def ppa_apply(model: PpaModel, x: jax.Array) -> jax.Array:
    xc = x - model.mean
    return xc - (xc @ model.top) @ model.top.T


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PpaPcaPpaModel:
    ppa1: PpaModel
    pca: PcaModel
    ppa2: PpaModel


def ppa_pca_ppa_fit(
    x: jax.Array,
    out_dim: int,
    remove: int = 3,
    axes: Optional[Sequence[str]] = None,
    n_total: Optional[int] = None,
) -> PpaPcaPpaModel:
    """Raunak (2017): PPA -> PCA(out_dim) -> PPA, fitted stage by stage.
    Sharded, each stage psums its own moments and then applies the (by
    construction replicated) stage model to the local rows — three fits,
    six tiny collectives, zero row movement."""
    ppa1 = ppa_fit(x, remove, axes, n_total)
    x1 = ppa_apply(ppa1, x)
    pca = pca_fit(x1, out_dim, axes, n_total)
    x2 = pca_apply(pca, x1)
    # Second PPA removes min(remove, out_dim - 1) comps of the reduced space.
    r2 = max(1, min(remove, out_dim - 1))
    ppa2 = ppa_fit(x2, r2, axes, n_total)
    return PpaPcaPpaModel(ppa1=ppa1, pca=pca, ppa2=ppa2)


def ppa_pca_ppa_apply(model: PpaPcaPpaModel, x: jax.Array) -> jax.Array:
    return ppa_apply(model.ppa2, pca_apply(model.pca, ppa_apply(model.ppa1, x)))


def fit_reduction(
    x: jax.Array,
    out_dim: int,
    kind: str,
    ppa_remove: int = 3,
    axes: Optional[Sequence[str]] = None,
    n_total: Optional[int] = None,
):
    """Dispatch helper used by the k-d tree index builder.  With ``axes``
    the fit runs from psum'd global moments inside ``shard_map`` (the
    BuildPipeline's distributed reduction path)."""
    if kind == "pca":
        model = pca_fit(x, out_dim, axes, n_total)
        return model, pca_apply(model, x)
    if kind == "ppa-pca-ppa":
        model = ppa_pca_ppa_fit(x, out_dim, ppa_remove, axes, n_total)
        return model, ppa_pca_ppa_apply(model, x)
    raise ValueError(f"unknown reduction kind {kind!r}")


def apply_reduction(model, x: jax.Array) -> jax.Array:
    if isinstance(model, PcaModel):
        return pca_apply(model, x)
    if isinstance(model, PpaPcaPpaModel):
        return ppa_pca_ppa_apply(model, x)
    raise TypeError(f"unknown reduction model {type(model)}")
