"""Dimensionality reduction for the k-d tree path.

The paper reduces 300-d embeddings to <= 8 dims (Lucene's BKD limit) with
either plain PCA (Wold et al. 1987) or the PPA->PCA->PPA pipeline of Raunak
(2017), where PPA is the "all-but-the-top" post-processing of Mu et al.
(2017): subtract the mean, remove the projections onto the top-D principal
components (D ~ dim/100).

All fits are exact eigendecompositions of the (dim x dim) covariance - dim is
300 here, so this is tiny; for a pod-scale corpus only the covariance
accumulation streams over the (sharded) data, which is a single
``psum``-able matmul.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PcaModel:
    mean: jax.Array  # (dim,)
    components: jax.Array  # (dim, out_dim), columns = top eigenvectors


def pca_fit(x: jax.Array, out_dim: int) -> PcaModel:
    """Fit PCA; returns projection onto the top ``out_dim`` components."""
    mean = jnp.mean(x, axis=0)
    xc = x - mean
    cov = (xc.T @ xc) / x.shape[0]
    # eigh returns ascending eigenvalues; take the trailing columns.
    _, vecs = jnp.linalg.eigh(cov)
    comps = vecs[:, ::-1][:, :out_dim]
    return PcaModel(mean=mean, components=comps)


def pca_apply(model: PcaModel, x: jax.Array) -> jax.Array:
    return (x - model.mean) @ model.components


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PpaModel:
    """All-but-the-top (Mu et al.): remove mean + top-D components."""

    mean: jax.Array  # (dim,)
    top: jax.Array  # (dim, D)


def ppa_fit(x: jax.Array, remove: int) -> PpaModel:
    mean = jnp.mean(x, axis=0)
    xc = x - mean
    cov = (xc.T @ xc) / x.shape[0]
    _, vecs = jnp.linalg.eigh(cov)
    top = vecs[:, ::-1][:, :remove]
    return PpaModel(mean=mean, top=top)


def ppa_apply(model: PpaModel, x: jax.Array) -> jax.Array:
    xc = x - model.mean
    return xc - (xc @ model.top) @ model.top.T


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PpaPcaPpaModel:
    ppa1: PpaModel
    pca: PcaModel
    ppa2: PpaModel


def ppa_pca_ppa_fit(x: jax.Array, out_dim: int, remove: int = 3) -> PpaPcaPpaModel:
    """Raunak (2017): PPA -> PCA(out_dim) -> PPA, fitted stage by stage."""
    ppa1 = ppa_fit(x, remove)
    x1 = ppa_apply(ppa1, x)
    pca = pca_fit(x1, out_dim)
    x2 = pca_apply(pca, x1)
    # Second PPA removes min(remove, out_dim - 1) comps of the reduced space.
    r2 = max(1, min(remove, out_dim - 1))
    ppa2 = ppa_fit(x2, r2)
    return PpaPcaPpaModel(ppa1=ppa1, pca=pca, ppa2=ppa2)


def ppa_pca_ppa_apply(model: PpaPcaPpaModel, x: jax.Array) -> jax.Array:
    return ppa_apply(model.ppa2, pca_apply(model.pca, ppa_apply(model.ppa1, x)))


def fit_reduction(
    x: jax.Array, out_dim: int, kind: str, ppa_remove: int = 3
):
    """Dispatch helper used by the k-d tree index builder."""
    if kind == "pca":
        model = pca_fit(x, out_dim)
        return model, pca_apply(model, x)
    if kind == "ppa-pca-ppa":
        model = ppa_pca_ppa_fit(x, out_dim, ppa_remove)
        return model, ppa_pca_ppa_apply(model, x)
    raise ValueError(f"unknown reduction kind {kind!r}")


def apply_reduction(model, x: jax.Array) -> jax.Array:
    if isinstance(model, PcaModel):
        return pca_apply(model, x)
    if isinstance(model, PpaPcaPpaModel):
        return ppa_pca_ppa_apply(model, x)
    raise TypeError(f"unknown reduction model {type(model)}")
