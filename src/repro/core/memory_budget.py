"""Memory-budget planner: pick the read-path encoding that fits (§12).

The quantized read path (docs/DESIGN.md §12) gives three independent
memory/recall levers:

  * primary postings: fp32 (native store) | int8 | int4  — match-stage bytes;
  * rerank store:     exact (fp32 originals) | int8 | none — rerank bytes;
  * blockmax keep-fraction beta — match-stage bytes actually *streamed*.

``plan_for_budget`` walks a recall-ordered frontier table (best recall
first) and returns the first configuration whose resident bytes fit the
budget — so a caller states ONE number (``AnnIndex.build(...,
memory_budget_bytes=)`` / ``serve.py --memory-budget``) and gets the most
accurate read path that fits.  Knobs the caller pinned explicitly are
respected: the planner only fills the ones left unset.

The default frontier is analytic (ordered by the error bounds in
docs/DESIGN.md §12 and confirmed by the A/B rows in BENCH_6.json);
``load_frontier`` re-orders it from a measured ``BENCH_6.json`` so the
table tracks the benchmarked recall on the corpus actually served.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.types import (
    BruteForceConfig,
    FakeWordsConfig,
    KdTreeConfig,
    LexicalLshConfig,
)

# Recall-ordered (best first) read-path configurations.  Each entry is the
# knob triple the planner may select; keep_frac scales the blockmax keep
# count (1.0 = no pruning).  int8 postings sit above fp32+int8-rerank
# variants with pruning because per-doc-scale int8 keeps recall@10 within
# ~0.02 of fp32 (BENCH_6.json) while beta-pruning costs recall directly.
DEFAULT_FRONTIER: Tuple[Dict, ...] = (
    dict(primary_postings="fp32", rerank_store="exact", keep_frac=1.0),
    dict(primary_postings="fp32", rerank_store="int8", keep_frac=1.0),
    dict(primary_postings="int8", rerank_store="int8", keep_frac=1.0),
    dict(primary_postings="int8", rerank_store="none", keep_frac=1.0),
    dict(primary_postings="int4", rerank_store="int8", keep_frac=1.0),
    dict(primary_postings="int4", rerank_store="none", keep_frac=1.0),
    dict(primary_postings="int4", rerank_store="none", keep_frac=0.5),
    dict(primary_postings="int4", rerank_store="none", keep_frac=0.25),
)


def postings_bytes_per_doc(
    config, dim: int, primary_postings: str, group: int = 32
) -> int:
    """Resident match-stage bytes per document for an encoding choice.

    Mirrors what the builder actually stores (core/builder.py): fake-words
    classic keeps the int8 tf alongside the packed store (segment merges
    rebuild scores from it); dot-int8 IS the native int8 tf; int4 packs two
    values per byte plus one f32 scale per ``group`` columns."""
    if isinstance(config, FakeWordsConfig):
        t = dim if config.signed_store else 2 * dim
        tf_b = t  # int8 tf
        if config.scoring == "classic":
            if primary_postings == "fp32":
                return tf_b + 2 * t  # bf16 scored
            if primary_postings == "int8":
                return tf_b + t + 4  # int8 rows + f32 per-doc scale
            return tf_b + _int4_bytes(t, group)
        if primary_postings == "int4":
            return _int4_bytes(t, group)
        return tf_b  # fp32 and int8 are both the native int8 tf
    if isinstance(config, BruteForceConfig):
        if primary_postings == "fp32":
            return 4 * dim
        if primary_postings == "int8":
            return dim + 4
        return _int4_bytes(dim, group)
    if isinstance(config, (LexicalLshConfig, KdTreeConfig)):
        if primary_postings != "fp32":
            raise ValueError(
                f"{type(config).__name__} has no quantized primary postings"
            )
        if isinstance(config, LexicalLshConfig):
            return 4 * config.hashes  # uint32 MinHash signature row
        return 4 * config.dims * 2  # reduced + lifted rows, f32
    raise TypeError(f"unknown config {type(config)}")


def _int4_bytes(cols: int, group: int) -> int:
    tg = -(-cols // group) * group
    return tg // 2 + (tg // group) * 4  # packed nibbles + f32 group scales


def rerank_bytes_per_doc(dim: int, rerank_store: str) -> int:
    if rerank_store == "exact":
        return 4 * dim
    if rerank_store == "int8":
        return dim + 4
    return 0


def estimate_bytes(
    config,
    n_docs: int,
    dim: int,
    primary_postings: str = "fp32",
    rerank_store: str = "exact",
    group: int = 32,
) -> int:
    """Analytic resident-bytes estimate for a (postings, rerank) choice.
    Per-doc stores only; replicated statistics (idf/df/norm, reduction
    models) are O(T) and negligible at the corpus sizes a budget matters."""
    return n_docs * (
        postings_bytes_per_doc(config, dim, primary_postings, group)
        + rerank_bytes_per_doc(dim, rerank_store)
    )


def load_frontier(bench_path: str) -> List[Dict]:
    """Recall-ordered frontier from a measured BENCH_6.json: every quantized
    A/B row becomes an entry (recall desc), falling back to the analytic
    order for rerank/pruning variants the benchmark did not sweep."""
    with open(bench_path) as f:
        bench = json.load(f)
    rows = bench.get("quantized_ab", [])
    measured = sorted(rows, key=lambda r: -r["recall_at_10"])
    out: List[Dict] = []
    for r in measured:
        for entry in DEFAULT_FRONTIER:
            if entry["primary_postings"] == r["postings"] and entry not in out:
                out.append(entry)
    for entry in DEFAULT_FRONTIER:
        if entry not in out:
            out.append(entry)
    return out


def plan_for_budget(
    config,
    n_docs: int,
    dim: int,
    budget_bytes: int,
    primary_postings: Optional[str] = None,
    rerank_store: Optional[str] = None,
    keep_frac: Optional[float] = None,
    group: int = 32,
    frontier: Optional[Sequence[Dict]] = None,
) -> Dict:
    """First frontier entry that fits ``budget_bytes`` — best recall first.

    Caller-pinned knobs (non-None ``primary_postings`` / ``rerank_store`` /
    ``keep_frac``) filter the frontier instead of being overridden.  Raises
    with the smallest achievable footprint when nothing fits, so the error
    names the budget the caller would need."""
    entries = list(frontier if frontier is not None else DEFAULT_FRONTIER)
    if isinstance(config, (LexicalLshConfig, KdTreeConfig)):
        entries = [e for e in entries if e["primary_postings"] == "fp32"]
    candidates = [
        e for e in entries
        if (primary_postings is None or e["primary_postings"] == primary_postings)
        and (rerank_store is None or e["rerank_store"] == rerank_store)
        and (keep_frac is None or e["keep_frac"] == keep_frac)
    ]
    if not candidates:
        raise ValueError(
            "no frontier entry matches the pinned knobs "
            f"(primary_postings={primary_postings}, rerank_store={rerank_store}, "
            f"keep_frac={keep_frac})"
        )
    best_short = None
    for e in candidates:
        # keep_frac cuts bytes *streamed*, not resident bytes: only entries
        # whose resident stores fit count, pruning is a latency lever that
        # rides along with the selected entry.
        cost = estimate_bytes(
            config, n_docs, dim, e["primary_postings"], e["rerank_store"], group
        )
        if cost <= budget_bytes:
            return dict(e, estimated_bytes=cost)
        if best_short is None or cost < best_short:
            best_short = cost
    raise ValueError(
        f"memory budget {budget_bytes} bytes is below the smallest read path "
        f"({best_short} bytes) for this corpus; raise the budget or shrink "
        "the corpus/shard"
    )
