"""Lexical LSH ANN encoding (paper §2).

Each feature w_i is rounded to the first decimal place and tagged with its
feature index (e.g. w = {0.12, 0.43, 0.74} -> tokens ``1_0.1 2_0.4 3_0.7``),
optionally aggregated into n-grams, then passed through MinHash (Lucene's
MinHashFilter) into ``b`` buckets with ``h`` hash functions.  A vector is
represented by its LSH signature tokens; matching counts signature collisions.

TPU adaptation (docs/DESIGN.md §3): token strings become 32-bit token ids (the
string is only ever a carrier for identity); a document's signature set is a
dense (h*b,) uint32 row with a sentinel for empty buckets, and match scoring
is an integer equality-popcount over signature slots - a VPU-friendly
compare+reduce realized by the ``lsh_match`` Pallas kernel (jnp fallback
here).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import LexicalLshConfig, LshIndex

_GOLDEN = np.uint32(0x9E3779B9)
_SENTINEL = np.uint32(0xFFFFFFFF)
SENTINEL = _SENTINEL  # public alias (blockmax bitmaps, kernels)


def mix32(x: jax.Array) -> jax.Array:
    """splitmix32 finalizer - a cheap, well-dispersed 32-bit hash."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * np.uint32(0x846CA68B)
    return x ^ (x >> 16)


def hash_seeds(hashes: int, seed: int) -> jax.Array:
    """Derive per-hash-function seeds deterministically from ``seed``."""
    base = jnp.arange(1, hashes + 1, dtype=jnp.uint32) * _GOLDEN
    return mix32(base + np.uint32(seed & 0xFFFFFFFF))


def tokenize(vectors: jax.Array, config: LexicalLshConfig) -> jax.Array:
    """Quantize + tag features -> (N, T) uint32 token ids.

    Token for feature i with rounded value r = round(w_i, decimals) is the
    hash of (i, r) - the integer realization of the string ``i_r``.  n-grams
    combine ``n`` adjacent feature tokens into one id.
    """
    scale = float(10**config.decimals)
    codes = jnp.round(vectors * scale).astype(jnp.int32)  # (N, m)
    # Lift signed codes to uint32 (offset keeps distinct codes distinct).
    ucodes = (codes + jnp.int32(1 << 16)).astype(jnp.uint32)
    feat = jnp.arange(vectors.shape[-1], dtype=jnp.uint32)
    toks = mix32(feat * _GOLDEN + ucodes)  # (N, m)
    for _ in range(config.ngram - 1):
        toks = mix32(toks[..., :-1] * _GOLDEN ^ toks[..., 1:])
    return toks


def minhash_signatures(tokens: jax.Array, config: LexicalLshConfig) -> jax.Array:
    """MinHash tokens into (N, h*b) uint32 signatures.

    For hash function k, every token gets hv = mix32(tok ^ seed_k); it lands
    in bucket hv % b and the bucket keeps the minimum hv (Lucene
    MinHashFilter with hashCount=h, bucketCount=b).  Empty buckets hold the
    sentinel (never matches: queries and docs hash identically, so a shared
    empty bucket carries no evidence of similarity).
    """
    n, _ = tokens.shape
    b, h = config.buckets, config.hashes
    seeds = hash_seeds(h, config.seed)
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]

    sigs = []
    for k in range(h):
        hv = mix32(tokens ^ seeds[k])  # (N, T)
        bucket = (hv % np.uint32(b)).astype(jnp.int32)
        sig_k = jnp.full((n, b), _SENTINEL, dtype=jnp.uint32)
        sig_k = sig_k.at[rows, bucket].min(hv)
        sigs.append(sig_k)
    return jnp.concatenate(sigs, axis=-1)  # (N, h*b)


def encode(vectors: jax.Array, config: LexicalLshConfig) -> jax.Array:
    return minhash_signatures(tokenize(vectors, config), config)


def build(
    vectors: jax.Array,
    config: LexicalLshConfig,
    keep_vectors: bool = True,
    normalized: bool = False,
) -> LshIndex:
    """Thin wrapper over the staged :class:`repro.core.builder.BuildPipeline`
    (MinHashTransform -> LshPostings -> rerank store); fully row-local, so
    the same stages shard trivially (``BuildPipeline.build_sharded``)."""
    from repro.core import builder

    bp = builder.make_build_pipeline(
        config, "exact" if keep_vectors else "none"
    )
    return bp.build_local(vectors, normalized=normalized)


def match_scores(
    sig_q: jax.Array, sig_d: jax.Array, doc_tile: int = 1024
) -> jax.Array:
    """(B, N) collision counts: #slots where signatures agree (non-sentinel).

    jnp reference realization, tiled over documents to bound the (B, tile, S)
    broadcast-compare working set; the Pallas ``lsh_match`` kernel is the TPU
    hot path.
    """
    b, s = sig_q.shape
    n = sig_d.shape[0]
    n_pad = (-n) % doc_tile
    if n_pad:
        pad = jnp.full((n_pad, s), _SENTINEL, dtype=sig_d.dtype)
        sig_d = jnp.concatenate([sig_d, pad], axis=0)
    tiles = sig_d.reshape(-1, doc_tile, s)
    valid_q = sig_q != _SENTINEL  # (B, S)

    def body(_, tile):
        eq = (sig_q[:, None, :] == tile[None, :, :]) & valid_q[:, None, :]
        return None, jnp.sum(eq, axis=-1, dtype=jnp.int32)  # (B, tile)

    _, per_tile = jax.lax.scan(body, None, tiles)
    scores = jnp.moveaxis(per_tile, 0, 1).reshape(b, -1)
    return scores[:, :n]


def search(
    index: LshIndex,
    sig_q: jax.Array,
    queries: Optional[jax.Array],
    k: int = 10,
    depth: int = 100,
    rerank: bool = False,
    use_kernel: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Signature-collision search — a thin wrapper over the shared staged
    pipeline (:class:`repro.core.pipeline.LshMatcher` + exact rerank).
    ``use_kernel`` streams the signature matrix through the fused
    compare+reduce->top-k Pallas kernel (docs/DESIGN.md §4) instead of
    materializing (B, N) collision counts.  Default: kernel on TPU."""
    from repro.core import pipeline as pl

    return pl.match_rerank(
        pl.LshMatcher(), index, sig_q, queries, k, depth, rerank,
        use_kernel=use_kernel,
    )
