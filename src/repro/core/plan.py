"""Composable query plans over the match stage (docs/DESIGN.md §13).

Three pieces, each usable on its own:

* :func:`combine_by_id` — the shared running-merge primitive: given (B, M)
  candidate ids with one value per entry, combine entries that share a doc
  id (sum or max), dedup keep-first, and re-reduce to top-k.  Both fusion
  and multi-vector aggregation are this one operation with different
  per-entry values.
* :func:`fuse` / :class:`FusionStage` — merge the top-k of N sub-plans on
  global doc ids.  ``rrf`` scores each entry w_p / (rrf_k + rank_p) from
  its *rank* (scale-free, the hybrid default); ``wsum`` sums w_p * score_p
  (only meaningful when the sub-plans' scores are commensurable).
* :func:`aggregate_by_doc` / :class:`MultiVectorPlan` — multi-vector docs:
  the index stores one row per *vector*, ``doc_map`` sends vector ids to
  doc ids, and the depth-level candidates aggregate per doc (``max`` =
  max-sim, ``sum``) inside the merge before the final top-k — not as a
  post-hoc pass over an already-truncated k.

Plans are plain frozen dataclasses; a leaf :class:`QueryPlan` wraps any
``search(queries) -> (scores, ids)`` callable returning *global* doc ids,
so the same tree runs over flat, segmented, and sharded indexes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "combine_by_id",
    "fuse",
    "aggregate_by_doc",
    "QueryPlan",
    "FusionStage",
    "MultiVectorPlan",
]

DEFAULT_RRF_K = 60.0


def combine_by_id(
    ids: jax.Array, vals: jax.Array, k: int, agg: str = "sum"
) -> Tuple[jax.Array, jax.Array]:
    """Combine (B, M) per-entry values by doc id, then top-k.

    Entries with id -1 are padding: they contribute nothing and can never
    surface (their combined value is pinned to -inf).  Duplicate ids keep
    the combined value on their *first* occurrence; later occurrences are
    pinned to -inf so each doc appears at most once in the output.  O(M^2)
    per query — M here is a handful of top-k lists, not the corpus.
    """
    ids = jnp.asarray(ids)
    vals = jnp.asarray(vals, jnp.float32)
    n_entries = ids.shape[1]
    valid = ids >= 0
    same = (ids[:, :, None] == ids[:, None, :]) & valid[:, :, None] & valid[:, None, :]
    if agg == "sum":
        total = jnp.sum(jnp.where(same, vals[:, None, :], 0.0), axis=-1)
    elif agg == "max":
        total = jnp.max(jnp.where(same, vals[:, None, :], -jnp.inf), axis=-1)
    else:
        raise ValueError(f"unknown agg {agg!r} (expected 'sum' or 'max')")
    earlier = jnp.tril(jnp.ones((n_entries, n_entries), bool), k=-1)
    is_dup = jnp.any(same & earlier[None, :, :], axis=-1)
    total = jnp.where(valid & ~is_dup, total, -jnp.inf)
    top_s, pos = jax.lax.top_k(total, min(k, n_entries))
    top_i = jnp.take_along_axis(ids, pos, axis=1)
    top_i = jnp.where(top_s == -jnp.inf, -1, top_i)
    return top_s, top_i


def fuse(
    results: Sequence[Tuple[jax.Array, jax.Array]],
    k: int,
    method: str = "rrf",
    weights: Optional[Sequence[float]] = None,
    rrf_k: float = DEFAULT_RRF_K,
) -> Tuple[jax.Array, jax.Array]:
    """Fuse N (scores, ids) result lists (each (B, k_p), rank-ordered as
    top_k emits them) into one (B, k) list on shared doc ids.

    rrf:  score(doc) = sum_p  w_p / (rrf_k + rank_p(doc)),  rank from 1.
    wsum: score(doc) = sum_p  w_p * score_p(doc).
    A doc missing from a sub-plan's list simply contributes no term.
    """
    if not results:
        raise ValueError("fuse() needs at least one sub-result")
    if weights is None:
        weights = [1.0] * len(results)
    all_ids, all_vals = [], []
    for (s, i), w in zip(results, weights):
        if method == "rrf":
            ranks = jnp.arange(1, i.shape[1] + 1, dtype=jnp.float32)
            v = jnp.broadcast_to((w / (rrf_k + ranks))[None, :], i.shape)
        elif method == "wsum":
            v = w * jnp.asarray(s, jnp.float32)
        else:
            raise ValueError(f"unknown fusion method {method!r}")
        all_ids.append(jnp.asarray(i))
        all_vals.append(jnp.where(i >= 0, v, 0.0))
    return combine_by_id(
        jnp.concatenate(all_ids, axis=1),
        jnp.concatenate(all_vals, axis=1),
        k,
        agg="sum",
    )


def aggregate_by_doc(
    scores: jax.Array,
    vec_ids: jax.Array,
    doc_map: jax.Array,
    k: int,
    agg: str = "max",
) -> Tuple[jax.Array, jax.Array]:
    """Multi-vector aggregation: map (B, D) vector-level candidates through
    ``doc_map`` ((N_vec,) int32, vector id -> doc id) and combine per doc —
    ``max`` is max-sim, ``sum`` adds all matching vectors' scores.  Runs on
    the *depth*-level candidates so a doc whose best vector ranks below k
    can still win after aggregation."""
    doc_map = jnp.asarray(doc_map)
    vec_ids = jnp.asarray(vec_ids)
    safe = jnp.maximum(vec_ids, 0)
    doc_ids = jnp.where(vec_ids >= 0, doc_map[safe], -1)
    return combine_by_id(doc_ids, scores, k, agg=agg)


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Leaf plan: any ``search(queries) -> (scores, ids)`` callable that
    returns global doc ids (a bound AnnIndex/SegmentedAnnIndex search, a
    sharded search closure, ...), plus the weight its results carry in an
    enclosing :class:`FusionStage`."""

    search: Callable[[Any], Tuple[jax.Array, jax.Array]]
    weight: float = 1.0
    label: str = ""
    search_at: Optional[
        Callable[[Any, int], Tuple[jax.Array, jax.Array]]
    ] = None

    def run(self, queries) -> Tuple[jax.Array, jax.Array]:
        return self.search(queries)

    def run_at(self, queries, k: int) -> Tuple[jax.Array, jax.Array]:
        """Run with at least ``k`` candidates, for enclosing plans that
        discover mid-merge they need a deeper list (see
        :class:`MultiVectorPlan`).  Falls back to the fixed-depth
        ``search`` when no depth-aware callable was supplied — callers
        detect the unchanged width and stop asking."""
        if self.search_at is None:
            return self.search(queries)
        return self.search_at(queries, k)


@dataclasses.dataclass(frozen=True)
class FusionStage:
    """Fusion node: run every sub-plan on the same queries and merge their
    top-k lists with :func:`fuse`."""

    plans: Tuple[Any, ...]
    k: int = 10
    method: str = "rrf"
    rrf_k: float = DEFAULT_RRF_K

    def run(self, queries) -> Tuple[jax.Array, jax.Array]:
        results = [p.run(queries) for p in self.plans]
        weights = [getattr(p, "weight", 1.0) for p in self.plans]
        return fuse(
            results, self.k, method=self.method, weights=weights,
            rrf_k=self.rrf_k,
        )


@dataclasses.dataclass(frozen=True)
class MultiVectorPlan:
    """Multi-vector node: run the inner plan in vector-id space, then
    aggregate to doc ids with :func:`aggregate_by_doc`.

    Aggregation collapses a doc's vectors into one entry, so a k_sub-deep
    vector list can fill fewer than k docs (worst case k_sub // n_vec_per_doc
    docs when every doc's vectors cluster together).  When the aggregated
    list is under-filled and the inner plan exposes ``run_at``, the inner
    search is re-run at a geometrically doubled candidate depth and
    re-reduced until k docs fill (or the vector corpus is exhausted, or the
    inner plan stops yielding deeper lists)."""

    inner: Any
    doc_map: Any
    k: int = 10
    agg: str = "max"

    def run(self, queries) -> Tuple[jax.Array, jax.Array]:
        s, i = self.inner.run(queries)
        top_s, top_i = aggregate_by_doc(s, i, self.doc_map, self.k, agg=self.agg)
        run_at = getattr(self.inner, "run_at", None)
        if run_at is None:
            return top_s, top_i
        n_vec = int(jnp.asarray(self.doc_map).shape[0])
        k_sub = int(i.shape[1])
        while k_sub < n_vec and (
            top_i.shape[1] < self.k
            or int(jnp.min(jnp.sum(top_i >= 0, axis=1))) < self.k
        ):
            k_sub = min(2 * k_sub, n_vec)
            s, i = run_at(queries, k_sub)
            top_s, top_i = aggregate_by_doc(
                s, i, self.doc_map, self.k, agg=self.agg
            )
            if int(i.shape[1]) < k_sub:
                break  # inner plan cannot go deeper
        return top_s, top_i
