"""k-d tree ANN over dimensionality-reduced vectors (paper §2, third method).

Lucene's BKD point index supports at most 8 dimensions, so the paper reduces
300-d embeddings (PCA or PPA->PCA->PPA) and indexes the reduced points.
Nearest-neighbor search is exact *in the reduced space* (L2); the recall
collapse the paper reports (R@(10,100) <= 0.03) comes from the reduction, not
the tree.

Two backends (DESIGN.md §3):

* ``tree``  - a faithful array-encoded balanced k-d tree searched with a
  batched ``lax.while_loop`` DFS + plane-distance pruning.  Correct, but
  data-dependent control flow with no MXU use: documented as TPU-hostile.
  Included because it IS the paper's data structure.
* ``scan``  - the TPU-idiomatic equivalent: brute-scan the (N, <=8) reduced
  matrix (a skinny, memory-bound streaming matmul).  Returns *identical*
  results (exact L2 NN in the reduced space) at full HBM streaming bandwidth.

Both return squared-L2 "scores" negated so that bigger = better, matching the
top-k convention used everywhere else.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bruteforce, pca
from repro.core.types import KdTreeConfig, KdTreeIndex


# --------------------------------------------------------------------------
# Host-side tree construction (numpy; indexes are built offline)
# --------------------------------------------------------------------------


def _build_arrays(points: np.ndarray, leaf_size: int):
    """Balanced implicit k-d tree: internal node i has children 2i+1 / 2i+2;
    leaves are contiguous slots of ``perm``.  Splits on the widest dimension
    at the median (Lucene BKD's split heuristic)."""
    n, dims = points.shape
    n_leaves = max(1, 1 << math.ceil(math.log2(max(1, math.ceil(n / leaf_size)))))
    depth = int(math.log2(n_leaves))
    n_internal = n_leaves - 1
    split_dim = np.zeros((max(n_internal, 1),), np.int32)
    split_val = np.zeros((max(n_internal, 1),), np.float32)
    cap = n_leaves * leaf_size
    if cap < n:
        leaf_size = math.ceil(n / n_leaves)
        cap = n_leaves * leaf_size
    perm = np.full((n_leaves, leaf_size), -1, np.int32)

    def rec(node: int, ids: np.ndarray, level: int):
        if level == depth:  # leaf
            leaf = node - n_internal
            perm[leaf, : len(ids)] = ids
            return
        pts = points[ids]
        dim = int(np.argmax(pts.max(axis=0) - pts.min(axis=0))) if len(ids) else 0
        order = ids[np.argsort(points[ids, dim], kind="stable")] if len(ids) else ids
        half = len(order) // 2
        val = float(points[order[half], dim]) if len(order) else 0.0
        split_dim[node] = dim
        split_val[node] = val
        rec(2 * node + 1, order[:half], level + 1)
        rec(2 * node + 2, order[half:], level + 1)

    rec(0, np.arange(n, dtype=np.int32), 0)
    return split_dim, split_val, perm, depth


def build(
    vectors: jax.Array,
    config: KdTreeConfig,
    keep_vectors: bool = True,
    normalized: bool = False,
) -> KdTreeIndex:
    """Thin wrapper over the staged :class:`repro.core.builder.BuildPipeline`
    (ReductionTransform -> KdTreePostings -> rerank store).  The reduction
    fits from psum-able moments (core/pca.py), so the scan backend also
    builds row-parallel on a mesh (``BuildPipeline.build_sharded``) with the
    identical model fitted on every shard."""
    from repro.core import builder

    bp = builder.make_build_pipeline(
        config, "exact" if keep_vectors else "none"
    )
    return bp.build_local(vectors, normalized=normalized)


def reduce_queries(index: KdTreeIndex, queries: jax.Array, normalized=False) -> jax.Array:
    q = queries if normalized else bruteforce.l2_normalize(queries)
    return pca.apply_reduction(index.reduction, q).astype(jnp.float32)


# --------------------------------------------------------------------------
# Backend (a): faithful batched tree traversal
# --------------------------------------------------------------------------


def _tree_knn_single(
    q: jax.Array,  # (dims,)
    reduced: jax.Array,  # (N, dims)
    split_dim: jax.Array,
    split_val: jax.Array,
    perm: jax.Array,  # (n_leaves, leaf_size)
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Single-query DFS with plane-distance pruning and a fixed-size stack."""
    n_leaves, leaf_size = perm.shape
    n_internal = n_leaves - 1
    depth = int(math.log2(n_leaves))
    stack_cap = 2 * depth + 4

    # best-k kept unsorted; worst tracked by max().
    best_d = jnp.full((k,), jnp.inf, jnp.float32)
    best_i = jnp.full((k,), -1, jnp.int32)
    stack_node = jnp.zeros((stack_cap,), jnp.int32)
    stack_pd2 = jnp.zeros((stack_cap,), jnp.float32)  # squared plane distance
    sp = jnp.int32(1)  # root pushed with plane-dist 0

    def scan_leaf(leaf, best_d, best_i):
        ids = perm[leaf]  # (leaf_size,)
        pts = reduced[jnp.maximum(ids, 0)]  # (leaf_size, dims)
        d2 = jnp.sum((pts - q[None, :]) ** 2, axis=-1)
        d2 = jnp.where(ids >= 0, d2, jnp.inf)
        all_d = jnp.concatenate([best_d, d2])
        all_i = jnp.concatenate([best_i, ids])
        neg_top, pos = jax.lax.top_k(-all_d, k)
        return -neg_top, all_i[pos]

    def cond(state):
        sp, *_ = state
        return sp > 0

    def body(state):
        sp, stack_node, stack_pd2, best_d, best_i = state
        sp = sp - 1
        node = stack_node[sp]
        pd2 = stack_pd2[sp]
        worst = jnp.max(best_d)
        prune = pd2 > worst

        def visit(args):
            sp, stack_node, stack_pd2, best_d, best_i = args
            is_leaf = node >= n_internal

            def leaf_fn(args):
                sp, sn, spd, bd, bi = args
                bd, bi = scan_leaf(node - n_internal, bd, bi)
                return sp, sn, spd, bd, bi

            def internal_fn(args):
                sp, sn, spd, bd, bi = args
                dim = split_dim[jnp.minimum(node, n_internal - 1)]
                val = split_val[jnp.minimum(node, n_internal - 1)]
                diff = q[dim] - val
                near = jnp.where(diff < 0, 2 * node + 1, 2 * node + 2)
                far = jnp.where(diff < 0, 2 * node + 2, 2 * node + 1)
                # push far (pruned on pop by plane distance), then near.
                sn = sn.at[sp].set(far)
                spd = spd.at[sp].set(diff * diff)
                sn = sn.at[sp + 1].set(near)
                spd = spd.at[sp + 1].set(jnp.float32(0))
                return sp + 2, sn, spd, bd, bi

            return jax.lax.cond(is_leaf, leaf_fn, internal_fn, args)

        return jax.lax.cond(
            prune,
            lambda a: a,
            visit,
            (sp, stack_node, stack_pd2, best_d, best_i),
        )

    state = (sp, stack_node, stack_pd2, best_d, best_i)
    _, _, _, best_d, best_i = jax.lax.while_loop(cond, body, state)
    order = jnp.argsort(best_d)
    return -best_d[order], best_i[order]


@functools.partial(jax.jit, static_argnames=("k",))
def tree_search(
    index: KdTreeIndex, q_reduced: jax.Array, k: int
) -> Tuple[jax.Array, jax.Array]:
    fn = functools.partial(
        _tree_knn_single,
        reduced=index.reduced,
        split_dim=index.split_dim,
        split_val=index.split_val,
        perm=index.perm,
        k=k,
    )
    return jax.vmap(fn)(q_reduced)


# --------------------------------------------------------------------------
# Backend (b): TPU-idiomatic reduced-space brute scan
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k", "use_kernel"))
def scan_search(
    index: KdTreeIndex,
    q_reduced: jax.Array,
    k: int,
    use_kernel: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Exact L2 NN in the reduced space as a streaming matmul:
    ||q - d||^2 = ||q||^2 + ||d||^2 - 2 q.d  (||q||^2 is rank-constant).

    Thin wrapper over :class:`repro.core.pipeline.KdScanMatcher`.
    ``use_kernel`` routes through the fused streaming score->top-k kernel
    via the [2q; 1] x [d; -||d||^2] lift (docs/DESIGN.md §4): the (B, N)
    negated-distance matrix never materializes.  Default: kernel on TPU."""
    from repro.core import pipeline as pl

    return pl.KdScanMatcher()(index, q_reduced, k, use_kernel=use_kernel)


def search(
    index: KdTreeIndex,
    queries: jax.Array,
    k: int = 10,
    depth: int = 100,
    backend: str = "scan",
    rerank: bool = False,
    normalized: bool = False,
    use_kernel: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    from repro.core import pipeline as pl

    q = queries if normalized else bruteforce.l2_normalize(queries)
    qr = reduce_queries(index, q, normalized=True)
    matcher = pl.KdTreeMatcher() if backend == "tree" else pl.KdScanMatcher()
    return pl.match_rerank(
        matcher, index, qr, q, k, depth, rerank, use_kernel=use_kernel
    )
