"""LM serving engine: continuous-batching decode over a shared KV cache.

A fixed pool of B slots; each slot holds one in-flight request.  Per step:

  1. admit queued requests into free slots (prefill writes their KV into the
     slot's cache region and emits the first token);
  2. one batched ``decode_step`` advances every active slot by a token;
  3. slots that emit EOS (or hit max_len) retire and free up.

All device work is two jit'd functions (slot prefill, batched decode);
admission/retirement is host-side bookkeeping — the standard
continuous-batching split (vLLM-style, minus paging: slots are fixed-length
KV regions, the right first cut for TPU where contiguous DMA wins).

Per-slot cache layout (L, B, T_max, Hkv, dh) matches models/transformer;
under pjit the cache shards batch->'data', length->'model' (flash-decoding
split-K; docs/DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm

Params = Dict[str, Any]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (plen,) int32
    max_new_tokens: int = 32
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineConfig:
    batch_slots: int = 8
    max_len: int = 512
    eos_id: int = 1
    greedy: bool = True


class DecodeEngine:
    """Host-side continuous batcher around jit'd prefill/decode."""

    def __init__(self, params: Params, cfg: tfm.TransformerConfig, ecfg: EngineConfig):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        b, t = ecfg.batch_slots, ecfg.max_len
        self.cache = tfm.make_cache(cfg, b, t)
        # Per-slot decode positions (the engine's cache['length'] is per-slot).
        self.cache["length"] = jnp.zeros((b,), jnp.int32)
        self.slot_req: List[Optional[Request]] = [None] * b
        self.queue: List[Request] = []
        self._retired: List[Request] = []
        self.steps = 0

        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn, static_argnames=("plen",))

    # -- device fns --------------------------------------------------------

    def _prefill_fn(self, params, cache, tokens, slot, plen: int):
        """Prefill one request of static length plen into cache slot."""
        c, logits = tfm.prefill(params, tokens[None, :], self.cfg)
        k = cache["k"].at[:, slot, :plen].set(c["k"][:, 0])
        v = cache["v"].at[:, slot, :plen].set(c["v"][:, 0])
        length = cache["length"].at[slot].set(plen)
        first = jnp.argmax(logits[0]).astype(jnp.int32)
        return {"k": k, "v": v, "length": length}, first

    def _decode_fn(self, params, cache, tokens, active):
        """Batched decode with PER-SLOT lengths.  tokens: (B,), active: (B,)
        bool.  Inactive slots decode at position 0 and their cache writes are
        masked out."""
        cfg = self.cfg
        b = tokens.shape[0]
        lengths = cache["length"]  # (B,)
        x = params["embed"].astype(cfg.dtype)[tokens][:, None, :]
        positions = lengths[:, None]

        def one_layer(x, layer, k_cache, v_cache):
            h = tfm.rms_norm(x, layer["ln1"], cfg.norm_eps)
            q = (h @ layer["wq"].astype(x.dtype)).reshape(b, 1, cfg.n_heads, cfg.dh)
            k = (h @ layer["wk"].astype(x.dtype)).reshape(b, 1, cfg.n_kv_heads, cfg.dh)
            v = (h @ layer["wv"].astype(x.dtype)).reshape(b, 1, cfg.n_kv_heads, cfg.dh)
            q = tfm.rope(q, positions, cfg.rope_theta)
            k = tfm.rope(k, positions, cfg.rope_theta)
            # per-slot scatter at (slot, length) — masked for inactive slots
            onehot = (
                jnp.arange(k_cache.shape[1])[None, :] == lengths[:, None]
            ) & active[:, None]
            k_cache = jnp.where(onehot[:, :, None, None], k, k_cache)
            v_cache = jnp.where(onehot[:, :, None, None], v, v_cache)
            # attention masked per-slot to positions < length+1
            t = k_cache.shape[1]
            hkv, group = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
            qg = q.reshape(b, hkv, group, cfg.dh)
            logits = jnp.einsum(
                "bhgd,bthd->bhgt", qg, k_cache, preferred_element_type=jnp.float32
            ) / np.sqrt(cfg.dh)
            mask = jnp.arange(t)[None, None, None, :] <= lengths[:, None, None, None]
            logits = jnp.where(mask, logits, -1e30)
            probs = jax.nn.softmax(logits, axis=-1)
            attn = jnp.einsum("bhgt,bthd->bhgd", probs.astype(v_cache.dtype), v_cache)
            attn = attn.reshape(b, 1, cfg.n_heads * cfg.dh)
            x = x + attn @ layer["wo"].astype(x.dtype)
            return x, k_cache, v_cache

        def dense_step(x, layer, kc, vc):
            x, kc, vc = one_layer(x, layer, kc, vc)
            x = x + tfm.swiglu(tfm.rms_norm(x, layer["ln2"], cfg.norm_eps), layer)
            return x, (kc, vc)

        def moe_step(x, layer, kc, vc):
            x, kc, vc = one_layer(x, layer, kc, vc)
            x = x + tfm.moe_ffn(
                tfm.rms_norm(x, layer["ln2"], cfg.norm_eps), layer, cfg, dropless=True
            )
            return x, (kc, vc)

        if cfg.moe and params.get("dense_layers") is not None:
            dp, nb = cfg.dense_per_block, cfg.n_blocks
            k_all = cache["k"].reshape(nb, dp + 1, *cache["k"].shape[1:])
            v_all = cache["v"].reshape(nb, dp + 1, *cache["v"].shape[1:])

            def blk(x, xs):
                p_dense, p_moe, kc, vc = xs

                def inner(x, one):
                    layer, kci, vci = one
                    x, (kci, vci) = dense_step(x, layer, kci, vci)
                    return x, (kci, vci)

                x, (kcd, vcd) = jax.lax.scan(inner, x, (p_dense, kc[:dp], vc[:dp]))
                x, (kcm, vcm) = moe_step(x, p_moe, kc[dp], vc[dp])
                return x, (
                    jnp.concatenate([kcd, kcm[None]], 0),
                    jnp.concatenate([vcd, vcm[None]], 0),
                )

            x, (k_new, v_new) = jax.lax.scan(
                blk, x, (params["dense_layers"], params["moe_layers"], k_all, v_all)
            )
            k_new = k_new.reshape(cache["k"].shape)
            v_new = v_new.reshape(cache["v"].shape)
        elif cfg.moe:
            def blk(x, xs):
                layer, kc, vc = xs
                x, (kc, vc) = moe_step(x, layer, kc, vc)
                return x, (kc, vc)
            x, (k_new, v_new) = jax.lax.scan(
                blk, x, (params["moe_layers"], cache["k"], cache["v"])
            )
        else:
            def blk(x, xs):
                layer, kc, vc = xs
                x, (kc, vc) = dense_step(x, layer, kc, vc)
                return x, (kc, vc)
            x, (k_new, v_new) = jax.lax.scan(
                blk, x, (params["layers"], cache["k"], cache["v"])
            )

        x = tfm.rms_norm(x, params["final_ln"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (x[:, 0] @ head.astype(x.dtype)).astype(jnp.float32)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        new_len = jnp.where(active, lengths + 1, lengths)
        return {"k": k_new, "v": v_new, "length": new_len}, next_tok

    # -- host-side batching --------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.ecfg.batch_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                toks = jnp.asarray(req.prompt, jnp.int32)
                self.cache, first = self._prefill(
                    self.params, self.cache, toks, slot, plen=len(req.prompt)
                )
                # Autoregressive decode needs the sampled token on host to
                # feed the next step — one sync per admit is the design.
                req.out_tokens.append(int(first))  # reprolint: disable=hostsync
                self.slot_req[slot] = req

    def step(self) -> int:
        """One engine tick; returns number of active slots."""
        self._admit()
        active_mask = np.array([r is not None for r in self.slot_req])
        if not active_mask.any():
            return 0
        toks = np.zeros(self.ecfg.batch_slots, np.int32)
        for i, r in enumerate(self.slot_req):
            if r is not None:
                toks[i] = r.out_tokens[-1]
        self.cache, next_tok = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(active_mask)
        )
        # Per-step sync is inherent to autoregressive decode: the sampled
        # token is next step's input and gates EOS/retirement on host.
        next_np = np.asarray(next_tok)  # reprolint: disable=hostsync
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            tok = int(next_np[i])  # reprolint: disable=hostsync  (host copy above)
            r.out_tokens.append(tok)
            done = tok == self.ecfg.eos_id or len(r.out_tokens) >= r.max_new_tokens
            total = len(r.prompt) + len(r.out_tokens)
            if done or total >= self.ecfg.max_len:
                r.done = True
                self._retired.append(r)
                self.slot_req[i] = None  # retire; slot reusable
                # zero the slot's length so a new request starts clean
                self.cache["length"] = self.cache["length"].at[i].set(0)
        self.steps += 1
        # active_mask is host numpy (built above), not a device array.
        return int(active_mask.sum())  # reprolint: disable=hostsync

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive the engine until the queue and slots drain (or max_steps
        ticks taken WITHIN this call — ``self.steps`` is cumulative across
        calls, so bounding on it made a second run() return immediately);
        returns the requests retired since the last run(), including any
        retired by direct step() calls in between (drained here so they are
        neither leaked nor double-returned)."""
        done: List[Request] = list(self._retired)
        self._retired.clear()
        taken = 0
        while (self.queue or any(self.slot_req)) and taken < max_steps:
            self.step()
            taken += 1
            done.extend(self._retired)
            self._retired.clear()
        return done
