"""Batched ANN query service over any AnnIndex — single-device, sharded, or
segmented (near-real-time).

The serving-side realization of the paper: a query stream is micro-batched
(latency/throughput knob), encoded through the index's pipeline encoder
(tf row / MinHash signature / reduced point / identity), and searched
through the SAME staged pipeline as offline search — single-device under
``jit``, pod-sharded via ``core/distributed.py`` (local match stage +
local top-d + local rerank + tiny all-gather merge, the Lucene
query-fan-out/merge architecture), or across the segments of a mutable
:class:`repro.core.segments.SegmentedAnnIndex`, one jit'd function per
batch (per segment, when segmented).

Every encoding — fake words, lexical LSH, k-d scan, brute force — serves
through one code path; there are no per-method branches here.  An index
built offline ships in via ``AnnIndex.load`` (see ``core/index.py``) or
``SegmentedAnnIndex.load`` (a commit point).  Indexes carrying the int8
:class:`repro.core.types.QuantizedStore` rerank automatically through the
quantized gather (single-device AND sharded), and
``AnnServiceConfig.cache_size`` enables the per-shard LRU result cache
keyed on the encoded query representation (docs/DESIGN.md §8).

**Online serving** (docs/DESIGN.md §11): construct with ``writer=`` (an
:class:`repro.core.segments.IndexWriter`) and call :meth:`AnnService.refresh`
after ingesting — the service re-points at the writer's latest NRT
snapshot.  Every searchable snapshot carries a process-unique **epoch**
(:func:`repro.core.types.next_epoch`) that joins the result-cache key, so
a refresh (or an explicit :meth:`AnnService.set_index` swap) can never
serve another index generation's cached results.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import queue as queue_mod
import threading
import time
from concurrent.futures import Future
from typing import Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import bruteforce, distributed
from repro.core import packed as packed_mod
from repro.core import pipeline as pl
from repro.core.index import AnnIndex, AnyConfig, AnyIndex
from repro.core.segments import IndexWriter, SegmentedAnnIndex
from repro.core.types import FakeWordsIndex, LshIndex


@dataclasses.dataclass
class AnnServiceConfig:
    k: int = 10
    depth: int = 100
    rerank: bool = True
    max_batch: int = 64       # micro-batch size (pad to this)
    # Async micro-batcher (docs/DESIGN.md §14): a queued request launches
    # once the coalesced batch reaches ``max_batch`` rows OR the OLDEST
    # queued request has waited ``max_wait_s`` — the batching window is the
    # latency the SLO donates to throughput.  ``queue_depth`` bounds the
    # admission queue; search_async raises queue.Full past it
    # (backpressure — shed at the door, don't grow tail latency).
    max_wait_s: float = 0.002
    queue_depth: int = 256
    # Route the match phase through the fused streaming score->top-k Pallas
    # kernel (docs/DESIGN.md §4).  None = kernel on TPU, XLA elsewhere.
    use_kernel: Optional[bool] = None
    # Two-stage blockmax pruning (docs/DESIGN.md §6): keep this many blocks
    # per query (per shard when sharded) in the match phase.  None disables.
    # Cuts streamed index bytes ~(1 - kept/total) at a small recall cost.
    # Fake-words and LSH indexes only (segmented serving rides the packed
    # superbuffer, docs/DESIGN.md §14).
    blockmax_keep: Optional[int] = None
    blockmax_block_size: int = 256
    # Latency ring-buffer length for stats() p50/p99 (per-batch wall times).
    latency_window: int = 1024
    # Per-shard result cache (ROADMAP follow-up): LRU over the last
    # ``cache_size`` micro-batches, keyed on the hash of the ENCODED query
    # representation bytes + the effective SearchParams/knobs + the index
    # EPOCH (so swapping or refreshing the index invalidates) — a repeated
    # query stream skips the match+rerank entirely on this serving shard.
    # 0 disables.  Hit/miss counters surface in stats().
    cache_size: int = 0


class AnnService:
    """Single-device, sharded, or segmented search service over any
    AnnIndex / SegmentedAnnIndex."""

    def __init__(
        self,
        index: Union[AnnIndex, SegmentedAnnIndex, AnyIndex, None] = None,
        config: Optional[AnyConfig] = None,
        service: Optional[AnnServiceConfig] = None,
        mesh: Optional[Mesh] = None,
        shard_axes: Sequence[str] = (),
        writer: Optional[IndexWriter] = None,
    ):
        if writer is not None:
            if index is not None:
                raise ValueError("pass index= or writer=, not both")
            index = writer.refresh()
        self.writer = writer
        if index is None:
            raise ValueError("AnnService needs an index or a writer")
        if isinstance(index, (AnnIndex, SegmentedAnnIndex)):
            # AnnService(ann) / AnnService(ann, service_cfg) forms.
            if service is None and isinstance(config, AnnServiceConfig):
                config, service = None, config
            if config is not None and config != index.config:
                raise ValueError(
                    "method config passed alongside an AnnIndex disagrees "
                    f"with the index's own config ({config} != {index.config})"
                )
            ann = index
        else:
            ann = AnnIndex(config=config, index=index)
        self.scfg = service if service is not None else AnnServiceConfig()
        self.mesh = mesh
        self.shard_axes = tuple(shard_axes)
        # One lock covers every snapshot swap (_bind) and every search —
        # the async worker thread and caller threads share this service.
        self._lock = threading.RLock()
        self._bind(ann)
        self.queries_served = 0
        self.batches = 0
        self._lat_s = collections.deque(maxlen=self.scfg.latency_window)
        # Per-REQUEST enqueue->result wall times for the async path; kept
        # apart from the per-batch ring so SLO percentiles are honest
        # (queue wait included, batch fan-in not averaged away).
        self._req_lat_s = collections.deque(maxlen=self.scfg.latency_window)
        self._cache: "collections.OrderedDict[bytes, Tuple[np.ndarray, np.ndarray]]" = (
            collections.OrderedDict()
        )
        self.cache_hits = 0
        self.cache_misses = 0
        self.async_launches = 0
        self.rejected = 0
        self._queue: Optional["queue_mod.Queue"] = None
        self._worker: Optional[threading.Thread] = None

    def _bind(self, ann: Union[AnnIndex, SegmentedAnnIndex]) -> None:
        """Point the service at a searchable snapshot and derive the
        effective serving knobs.  Called from __init__ and on every
        set_index / refresh swap; the snapshot's epoch in the cache key is
        what keeps previously cached results unreachable."""
        self.ann = ann
        self.index = getattr(ann, "index", ann)  # back-compat alias
        self.config = ann.config
        self._segmented = isinstance(ann, SegmentedAnnIndex)
        # Effective serving knobs: the service config overrides, else the
        # index-level settings (an AnnIndex built/loaded with blockmax_keep
        # or use_kernel serves with them by default).
        if self.scfg.blockmax_keep is not None:
            self._bm_keep = self.scfg.blockmax_keep
            self._bm_block = self.scfg.blockmax_block_size
        else:
            self._bm_keep = getattr(ann, "blockmax_keep", None)
            self._bm_block = getattr(ann, "blockmax_block_size", 256)
        self._uk = (
            self.scfg.use_kernel if self.scfg.use_kernel is not None
            else ann.use_kernel
        )
        if self._segmented:
            if self.mesh is not None:
                raise ValueError(
                    "segmented serving is single-process; shard the corpus "
                    "with mesh= over a monolithic index instead"
                )
            if self._bm_keep is not None:
                from repro.core.types import FakeWordsConfig, LexicalLshConfig

                # Segmented blockmax rides the packed superbuffer
                # (docs/DESIGN.md §14); the bm index is built lazily per
                # snapshot inside the packed path, not here.
                if not isinstance(
                    ann.config, (FakeWordsConfig, LexicalLshConfig)
                ):
                    raise ValueError(
                        f"blockmax pruning is not supported for {ann.method}"
                    )
            self._bm = None
            self._search = None
            self._search_filtered = None
            return
        self._bm = None
        if self._bm_keep is not None:
            if not isinstance(ann.index, (FakeWordsIndex, LshIndex)):
                raise ValueError(
                    f"blockmax pruning is not supported for {ann.method}"
                )
            signed = getattr(ann.config, "signed_store", False)
            if self.mesh is not None:
                self._bm = distributed.build_blockmax_sharded(
                    self.mesh, ann.index, self.shard_axes, self._bm_block,
                    signed_store=signed,
                )
            elif ann.bm is not None and ann.bm.block_size == self._bm_block:
                self._bm = ann.bm
            else:
                from repro.core import blockmax

                self._bm = blockmax.build_blockmax(
                    ann.index, self._bm_block, signed_store=signed,
                )
        if self.mesh is not None:
            # The rerank gather must read the store the index was built
            # with: int8 quantized, fp32 originals, or none.
            if ann.quantized_rerank:
                rs = "int8"
            else:
                rs = "exact" if ann.index.vectors is not None else "none"
            # Quantized primary postings change the index spec tree: the
            # sharded search must shard the packed store + scales too.
            pq = getattr(ann.index, "pq", None)
            sharded_args = dict(
                k=self.scfg.k, depth=self.scfg.depth, rerank=self.scfg.rerank,
                use_kernel=self._uk,
                blockmax_keep=self._bm_keep,
                rerank_store=rs,
                postings_bits=pq.bits if pq is not None else 0,
            )
            self._search = distributed.make_sharded_search(
                self.mesh, ann.config, self.shard_axes, **sharded_args
            )
            # The filtered variant takes a trailing doc-sharded bitmap
            # operand (docs/DESIGN.md §13); built eagerly but compiled only
            # on the first filtered query.
            self._search_filtered = distributed.make_sharded_search(
                self.mesh, ann.config, self.shard_axes, filtered=True,
                **sharded_args,
            )
        else:
            self._search = None
            self._search_filtered = None

    # -- online index updates ----------------------------------------------

    def set_index(self, index: Union[AnnIndex, SegmentedAnnIndex]) -> int:
        """Swap the served index for a new snapshot.  Returns the new
        epoch; the epoch-keyed cache makes the old index's cached results
        unreachable (no eviction sweep needed)."""
        if not isinstance(index, (AnnIndex, SegmentedAnnIndex)):
            raise TypeError(
                "set_index takes an AnnIndex or SegmentedAnnIndex"
            )
        with self._lock:
            self._bind(index)
        return self.ann.epoch

    def refresh(self) -> int:
        """Near-real-time visibility: pull the writer's latest snapshot
        (flushing its buffered adds) and serve it.  Returns the serving
        epoch — unchanged when the writer had nothing new, so the result
        cache stays warm across no-op refreshes."""
        if self.writer is None:
            raise ValueError(
                "refresh() needs a service constructed with writer="
            )
        with self._lock:
            self._bind(self.writer.refresh())
        return self.ann.epoch

    # -- serving -----------------------------------------------------------

    def _matcher(self):
        """The effective match stage for single-device serving."""
        return self.ann.matcher_for(self._bm, self._bm_keep)

    # Keying syncs on the tiny encoder output by design — see docstring;
    # only paid when the result cache is on.
    # reprolint: disable=hostsync
    def _cache_key(self, q_rep, q, filt=None) -> bytes:
        """Result-cache key: the encoded query representation's bytes plus
        every knob that changes the result — INCLUDING the index epoch, so
        a swapped/refreshed index can never serve a stale entry.  When
        reranking, the raw normalized queries join the hash — distinct
        queries can collide on a quantized rep (tf row / signature), and
        their exact rerank scores would differ.  A filter bitmap's bytes
        join the hash too (plus a presence flag in the knob tuple, so an
        all-ones mask can never alias the unfiltered entry).  Note
        np.asarray(q_rep) blocks on the (tiny) encoder before the search
        dispatch; that host sync is the price of rep-level keying and only
        paid when the cache is enabled."""
        h = hashlib.sha1(np.asarray(q_rep).tobytes())
        if self.scfg.rerank and q is not None:
            h.update(np.asarray(q).tobytes())
        if filt is not None:
            h.update(np.asarray(filt).tobytes())
        h.update(
            repr((self.scfg.k, self.scfg.depth, self.scfg.rerank,
                  self._bm_keep, self._bm_block, self._uk,
                  getattr(self.ann, "epoch", 0), filt is not None)).encode()
        )
        return h.digest()

    def search_batch(
        self,
        queries: np.ndarray,
        filter: Optional[np.ndarray] = None,
        plan=None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(B, dim) -> (scores (B,k), ids (B,k)); pads to max_batch so the
        jit cache holds exactly one entry.

        ``filter``: per-doc predicate bitmap (nonzero = keep) applied
        inside the match stage's single kernel pass (docs/DESIGN.md §13) —
        (N,) shared across the batch, or (B, N) per query (single-device
        and segmented; the sharded path takes the shared (N,) form, which
        shards with the postings).  Segmented indexes take GLOBAL doc ids
        (max_doc space, e.g. from ``ann.global_metadata()``).  Filter bytes
        join the result-cache key, so filtered and unfiltered streams cache
        independently.

        ``plan``: a composed query plan (:mod:`repro.core.plan` —
        FusionStage / MultiVectorPlan / QueryPlan) run as ONE batch in
        place of this service's own index search; sub-plan leaves carry
        their own filters and indexes.  Plan results bypass the result
        cache (a plan's identity isn't hashable state)."""
        with self._lock:
            return self._search_batch(queries, filter, plan)

    def _search_batch(
        self,
        queries: np.ndarray,
        filter: Optional[np.ndarray] = None,
        plan=None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        b = queries.shape[0]
        if plan is not None:
            if filter is not None:
                raise ValueError(
                    "pass filters on the plan's leaves, not alongside plan="
                )
            t0 = time.perf_counter()
            s, ids = plan.run(jnp.asarray(queries))
            # Result hand-off: callers take numpy.
            s_np, i_np = np.asarray(s), np.asarray(ids)  # reprolint: disable=hostsync
            self.batches += 1
            self._lat_s.append(time.perf_counter() - t0)
            self.queries_served += b
            return s_np, i_np
        mb = self.scfg.max_batch
        pad = (-b) % mb
        if pad:
            queries = np.concatenate(
                [queries, np.zeros((pad, queries.shape[1]), queries.dtype)], 0
            )
        fm = None
        if filter is not None:
            # Host-side caller input (predicate bitmap), not a device array.
            fm = np.asarray(filter)  # reprolint: disable=hostsync
            if fm.ndim == 2:
                if self.mesh is not None:
                    raise ValueError(
                        "sharded filtered serving takes a shared (N,) mask "
                        "(it shards with the postings); per-query (B, N) "
                        "masks are single-device/segmented only"
                    )
                if pad:
                    # Padded queries get all-zero mask rows; their padded
                    # (-inf, -1) results are trimmed with the batch below.
                    fm = np.concatenate(
                        [fm, np.zeros((pad, fm.shape[1]), fm.dtype)], 0
                    )
        use_cache = self.scfg.cache_size > 0
        out_s, out_i = [], []
        for i in range(0, queries.shape[0], mb):
            t0 = time.perf_counter()
            q_np = queries[i : i + mb]
            fl = fm if fm is None or fm.ndim == 1 else fm[i : i + mb]
            fl_dev = jnp.asarray(fl) if fl is not None else None
            if self._segmented:
                # The segmented reader encodes per search (its global-stats
                # view owns any fitted model), so key on the raw query
                # bytes; the epoch in the key still pins the snapshot.
                key = self._cache_key(q_np, None, fl) if use_cache else None
                q = q_rep = None
            else:
                q = bruteforce.l2_normalize(jnp.asarray(q_np))
                q_rep = self.ann.pipeline.encoder(self.ann.index, q)
                key = self._cache_key(q_rep, q, fl) if use_cache else None
            if use_cache and key in self._cache:
                self._cache.move_to_end(key)
                s_np, i_np = self._cache[key]
                self.cache_hits += 1
            else:
                if self._segmented:
                    s, ids = self.ann.search(
                        jnp.asarray(q_np), k=self.scfg.k,
                        depth=self.scfg.depth, rerank=self.scfg.rerank,
                        use_kernel=self._uk, filter_mask=fl_dev,
                        blockmax_keep=self._bm_keep,
                        blockmax_block_size=self._bm_block,
                    )
                elif self._search is not None:
                    args = (self.ann.index,) + (
                        (self._bm,) if self._bm is not None else ()
                    ) + (q_rep, q)
                    if fl_dev is not None:
                        s, ids = self._search_filtered(*args, fl_dev)
                    else:
                        s, ids = self._search(*args)
                else:
                    s, ids = pl.match_rerank(
                        self._matcher(), self.ann.index, q_rep, q,
                        self.scfg.k, self.scfg.depth, self.scfg.rerank,
                        bm=self._bm, use_kernel=self._uk,
                        reranker=self.ann.pipeline.reranker,
                        filt=fl_dev,
                    )
                # Hand-off point: blocking here keeps device compute inside
                # the wall time recorded below.
                s_np = np.asarray(s)   # reprolint: disable=hostsync
                i_np = np.asarray(ids)  # reprolint: disable=hostsync
                if use_cache:
                    self.cache_misses += 1
                    self._cache[key] = (s_np, i_np)
                    while len(self._cache) > self.scfg.cache_size:
                        self._cache.popitem(last=False)
            out_s.append(s_np)
            out_i.append(i_np)
            self.batches += 1
            self._lat_s.append(time.perf_counter() - t0)
        self.queries_served += b
        return np.concatenate(out_s)[:b], np.concatenate(out_i)[:b]

    # ``search`` is the public name (filter= / plan= per docs/DESIGN.md
    # §13); ``search_batch`` predates it and stays as the primary def.
    search = search_batch

    # -- async micro-batching loop (docs/DESIGN.md §14) ---------------------

    def start_async(self) -> None:
        """Start the admission queue + micro-batcher worker.  Callers then
        submit single queries through :meth:`search_async`; the worker
        coalesces arrivals into one ``search_batch`` launch once the batch
        reaches ``max_batch`` rows or the oldest request has waited
        ``max_wait_s`` (the SLO's batching window)."""
        if self._worker is not None:
            return
        self._queue = queue_mod.Queue(maxsize=self.scfg.queue_depth)
        self._stop = threading.Event()
        self._worker = threading.Thread(
            target=self._batch_loop, name="ann-batcher", daemon=True
        )
        self._worker.start()

    def stop_async(self, drain: bool = True) -> None:
        """Stop the worker.  ``drain=True`` serves everything already
        admitted first; pending futures are failed otherwise."""
        if self._worker is None:
            return
        if not drain:
            self._stop.set()
        self._queue.put(None)  # wake the worker
        self._worker.join()
        self._worker = None
        # Fail anything still queued (drain=False, or raced past the
        # sentinel) rather than leaving callers blocked forever.
        while True:
            try:
                req = self._queue.get_nowait()
            except queue_mod.Empty:
                break
            if req is not None:
                req[3].set_exception(RuntimeError("service stopped"))
        self._queue = None

    def search_async(
        self, query: np.ndarray, filter: Optional[np.ndarray] = None
    ) -> "Future[Tuple[np.ndarray, np.ndarray]]":
        """Admit one query ((dim,) or (b, dim)) to the micro-batcher;
        resolves to this request's (scores, ids) rows.  Raises
        ``queue.Full`` when the admission queue is at ``queue_depth``
        (backpressure: the caller sheds or retries — queueing deeper would
        only grow everyone's tail latency)."""
        if self._queue is None:
            raise RuntimeError("call start_async() first")
        # Caller-side numpy inputs: coercion + coalescing key are host work.
        q = np.asarray(query)  # reprolint: disable=hostsync
        if q.ndim == 1:
            q = q[None, :]
        fkey = None if filter is None else np.asarray(filter).tobytes()  # reprolint: disable=hostsync
        fut: "Future[Tuple[np.ndarray, np.ndarray]]" = Future()
        try:
            self._queue.put_nowait((q, filter, fkey, fut, time.perf_counter()))
        except queue_mod.Full:
            # Admission counters are bumped from arbitrary caller threads;
            # without the lock, concurrent += drops increments.
            with self._lock:
                self.rejected += 1
            raise
        return fut

    def _batch_loop(self) -> None:
        carry = None
        while True:
            req = carry if carry is not None else self._queue.get()
            carry = None
            if req is None:
                return
            if self._stop.is_set():
                req[3].set_exception(RuntimeError("service stopped"))
                continue
            batch = [req]
            rows = req[0].shape[0]
            deadline = req[4] + self.scfg.max_wait_s
            # Coalesce until max_batch rows or the OLDEST request's wait
            # hits the window; only same-filter requests share a launch
            # (one bitmap operand per batch).  Backlog already sitting in
            # the queue coalesces unconditionally (it costs nothing and is
            # what keeps throughput up when arrivals outrun launches);
            # the deadline only governs how long to wait for MORE.
            while rows < self.scfg.max_batch:
                try:
                    nxt = self._queue.get_nowait()
                except queue_mod.Empty:
                    wait = deadline - time.perf_counter()
                    if wait <= 0:
                        break
                    try:
                        nxt = self._queue.get(timeout=wait)
                    except queue_mod.Empty:
                        break
                if nxt is None or self._stop.is_set():
                    carry = nxt
                    break
                if nxt[2] != req[2]:
                    carry = nxt  # different filter: next launch
                    break
                batch.append(nxt)
                rows += nxt[0].shape[0]
            try:
                qs = np.concatenate([r[0] for r in batch], axis=0)
                s, ids = self.search_batch(qs, filter=req[1])
                done = time.perf_counter()
                # Stats are read by caller threads (stats()/reset_latency()
                # hold the lock); mutate them under it too.  Future
                # resolution stays OUTSIDE the lock: set_result runs done-
                # callbacks on this thread, and a callback that re-enters
                # the service must not find the lock held.
                with self._lock:
                    self.async_launches += 1
                    for r in batch:
                        self._req_lat_s.append(done - r[4])
                off = 0
                for r in batch:
                    n = r[0].shape[0]
                    r[3].set_result((s[off : off + n], ids[off : off + n]))
                    off += n
            except Exception as e:  # propagate to every caller in the batch
                for r in batch:
                    if not r[3].done():
                        r[3].set_exception(e)

    def reset_latency(self) -> None:
        """Drop recorded batch latencies (e.g. after a warmup/compile batch,
        whose wall time is orders of magnitude above steady state and would
        otherwise dominate the p99)."""
        with self._lock:
            self._lat_s.clear()
            self._req_lat_s.clear()

    @staticmethod
    # Stats path: the ring holds Python floats from perf_counter, never
    # device arrays — np.percentile here is pure host math.
    # reprolint: disable=hostsync
    def _pcts(ring) -> Tuple[Optional[float], Optional[float]]:
        ms = np.asarray(ring, np.float64) * 1e3
        if not ms.size:
            return None, None
        return (
            round(float(np.percentile(ms, 50)), 3),
            round(float(np.percentile(ms, 99)), 3),
        )

    def _packed_stats(self) -> dict:
        """Observability for the packed single-launch path: process-wide
        executable-cache counters plus this snapshot's bucket-ladder
        occupancy.  Reports only what is already built — never forces a
        pack (packed state is lazy and stays None until first search)."""
        out = dict(
            (f"exec_cache_{k}", v)
            for k, v in packed_mod.EXEC_CACHE.stats().items()
        )
        pk = getattr(self.ann, "_packed", None)
        if pk is not None:
            out["packed_bucket"] = pk.bucket
            out["packed_rows"] = pk.n_rows
            out["packed_live"] = pk.n_live
            out["packed_occupancy"] = round(pk.n_rows / pk.bucket, 4)
            out["packed_appends"] = pk.appends
        else:
            out["packed_bucket"] = None
            err = getattr(self.ann, "_packed_err", None)
            if err is not None:
                out["packed_unsupported"] = err
        return out

    def stats(self) -> dict:
        lat_p50, lat_p99 = self._pcts(self._lat_s)
        req_p50, req_p99 = self._pcts(self._req_lat_s)
        return {
            "queries": self.queries_served,
            "batches": self.batches,
            "index_bytes": self.ann.nbytes(),
            "num_docs": self.ann.num_docs,
            "method": self.ann.method,
            "epoch": getattr(self.ann, "epoch", None),
            "segments": getattr(self.ann, "num_segments", None),
            # Per-BATCH device wall times (one search_batch call each).
            "lat_p50_ms": lat_p50,
            "lat_p99_ms": lat_p99,
            # Per-REQUEST enqueue->result times on the async path: queue
            # wait + batching window + launch — the number an SLO is
            # written against.
            "req_p50_ms": req_p50,
            "req_p99_ms": req_p99,
            "async_launches": self.async_launches,
            "rejected": self.rejected,
            "queue_depth": self._queue.qsize() if self._queue is not None else 0,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_entries": len(self._cache),
            **self._packed_stats(),
        }
