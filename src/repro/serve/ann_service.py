"""Batched ANN query service over a sharded fake-words index.

The serving-side realization of the paper: a query stream is micro-batched
(latency/throughput knob), encoded to fake-words term vectors, and searched
against the pod-sharded index (core/distributed.py: local GEMM + local
top-d + rerank + tiny all-gather merge).  This is the Lucene
query-fan-out/merge architecture, one jit'd function per batch.

Also provides the single-node service used by examples and benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import blockmax, bruteforce, distributed, fakewords
from repro.core.types import FakeWordsConfig, FakeWordsIndex


@dataclasses.dataclass
class AnnServiceConfig:
    k: int = 10
    depth: int = 100
    rerank: bool = True
    max_batch: int = 64       # micro-batch size (pad to this)
    max_wait_s: float = 0.002  # batching window in a real deployment
    # Route the match phase through the fused streaming score->top-k Pallas
    # kernel (docs/DESIGN.md §4).  None = kernel on TPU, XLA elsewhere.
    use_kernel: Optional[bool] = None
    # Two-stage blockmax pruning (docs/DESIGN.md §6): keep this many blocks
    # per query (per shard when sharded) in the match phase.  None disables.
    # Cuts streamed index bytes ~(1 - kept/total) at a small recall cost.
    blockmax_keep: Optional[int] = None
    blockmax_block_size: int = 256


class AnnService:
    """Single- or multi-device fake-words search service."""

    def __init__(
        self,
        index: FakeWordsIndex,
        config: FakeWordsConfig,
        service: AnnServiceConfig,
        mesh: Optional[Mesh] = None,
        shard_axes: Sequence[str] = (),
    ):
        self.index = index
        self.config = config
        self.scfg = service
        self.mesh = mesh
        self._bm = None
        if service.blockmax_keep is not None:
            if mesh is not None:
                self._bm = distributed.build_blockmax_sharded(
                    mesh, index, shard_axes, service.blockmax_block_size,
                    signed_store=config.signed_store,
                )
            else:
                self._bm = blockmax.build_blockmax(
                    index, service.blockmax_block_size,
                    signed_store=config.signed_store,
                )
        if mesh is not None:
            self._search = distributed.make_sharded_search(
                mesh, config, shard_axes,
                k=service.k, depth=service.depth, rerank=service.rerank,
                use_kernel=service.use_kernel,
                blockmax_keep=service.blockmax_keep,
            )
        else:
            self._search = None
        self.queries_served = 0
        self.batches = 0

    def _encode(self, queries: jax.Array) -> Tuple[jax.Array, jax.Array]:
        q = bruteforce.l2_normalize(queries)
        return fakewords.encode_queries(q, self.config, normalized=True), q

    def search_batch(self, queries: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(B, dim) -> (scores (B,k), ids (B,k)); pads to max_batch so the
        jit cache holds exactly one entry."""
        b = queries.shape[0]
        mb = self.scfg.max_batch
        pad = (-b) % mb
        if pad:
            queries = np.concatenate(
                [queries, np.zeros((pad, queries.shape[1]), queries.dtype)], 0
            )
        out_s, out_i = [], []
        for i in range(0, queries.shape[0], mb):
            chunk = jnp.asarray(queries[i : i + mb])
            q_tf, q = self._encode(chunk)
            if self._search is not None:
                if self._bm is not None:
                    s, ids = self._search(self.index, self._bm, q_tf, q)
                else:
                    s, ids = self._search(self.index, q_tf, q)
            elif self._bm is not None:
                d_s, d_i = blockmax.pruned_search(
                    self.index, self._bm, q_tf,
                    n_keep=self.scfg.blockmax_keep, depth=self.scfg.depth,
                    use_kernel=self.scfg.use_kernel,
                )
                if self.scfg.rerank:
                    s, ids = bruteforce.rerank_exact(
                        self.index.vectors, q, d_i, self.scfg.k,
                        normalized=True,
                    )
                else:
                    s, ids = d_s[:, : self.scfg.k], d_i[:, : self.scfg.k]
            else:
                s, ids = fakewords.search(
                    self.index, q_tf, q,
                    k=self.scfg.k, depth=self.scfg.depth,
                    scoring=self.config.scoring, rerank=self.scfg.rerank,
                    df_max_ratio=self.config.df_max_ratio,
                    use_kernel=self.scfg.use_kernel,
                )
            out_s.append(np.asarray(s))
            out_i.append(np.asarray(ids))
            self.batches += 1
        self.queries_served += b
        return np.concatenate(out_s)[:b], np.concatenate(out_i)[:b]

    def stats(self) -> dict:
        return {
            "queries": self.queries_served,
            "batches": self.batches,
            "index_bytes": self.index.nbytes(),
            "num_docs": self.index.num_docs,
        }
