"""Batched ANN query service over any AnnIndex — single-device or sharded.

The serving-side realization of the paper: a query stream is micro-batched
(latency/throughput knob), encoded through the index's pipeline encoder
(tf row / MinHash signature / reduced point / identity), and searched
through the SAME staged pipeline as offline search — single-device under
``jit``, or pod-sharded via ``core/distributed.py`` (local match stage +
local top-d + local rerank + tiny all-gather merge, the Lucene
query-fan-out/merge architecture), one jit'd function per batch.

Every encoding — fake words, lexical LSH, k-d scan, brute force — serves
through one code path; there are no per-method branches here.  An index
built offline ships in via ``AnnIndex.load`` (see ``core/index.py``).
Indexes carrying the int8 :class:`repro.core.types.QuantizedStore` rerank
automatically through the quantized gather (single-device AND sharded),
and ``AnnServiceConfig.cache_size`` enables the per-shard LRU result
cache keyed on the encoded query representation (docs/DESIGN.md §8).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import bruteforce, distributed
from repro.core import pipeline as pl
from repro.core.index import AnnIndex, AnyConfig, AnyIndex
from repro.core.types import FakeWordsIndex, LshIndex


@dataclasses.dataclass
class AnnServiceConfig:
    k: int = 10
    depth: int = 100
    rerank: bool = True
    max_batch: int = 64       # micro-batch size (pad to this)
    max_wait_s: float = 0.002  # batching window in a real deployment
    # Route the match phase through the fused streaming score->top-k Pallas
    # kernel (docs/DESIGN.md §4).  None = kernel on TPU, XLA elsewhere.
    use_kernel: Optional[bool] = None
    # Two-stage blockmax pruning (docs/DESIGN.md §6): keep this many blocks
    # per query (per shard when sharded) in the match phase.  None disables.
    # Cuts streamed index bytes ~(1 - kept/total) at a small recall cost.
    # Fake-words and LSH indexes only.
    blockmax_keep: Optional[int] = None
    blockmax_block_size: int = 256
    # Latency ring-buffer length for stats() p50/p99 (per-batch wall times).
    latency_window: int = 1024
    # Per-shard result cache (ROADMAP follow-up): LRU over the last
    # ``cache_size`` micro-batches, keyed on the hash of the ENCODED query
    # representation bytes + the effective SearchParams/knobs — so a repeated
    # query stream skips the match+rerank entirely on this serving shard.
    # 0 disables.  Hit/miss counters surface in stats().
    cache_size: int = 0


class AnnService:
    """Single- or multi-device search service over any AnnIndex."""

    def __init__(
        self,
        index: Union[AnnIndex, AnyIndex],
        config: Optional[AnyConfig] = None,
        service: Optional[AnnServiceConfig] = None,
        mesh: Optional[Mesh] = None,
        shard_axes: Sequence[str] = (),
    ):
        if isinstance(index, AnnIndex):
            # AnnService(ann) / AnnService(ann, service_cfg) forms.
            if service is None and isinstance(config, AnnServiceConfig):
                config, service = None, config
            if config is not None and config != index.config:
                raise ValueError(
                    "method config passed alongside an AnnIndex disagrees "
                    f"with the index's own config ({config} != {index.config})"
                )
            ann = index
        else:
            ann = AnnIndex(config=config, index=index)
        self.ann = ann
        self.index = ann.index      # back-compat aliases
        self.config = ann.config
        self.scfg = service if service is not None else AnnServiceConfig()
        self.mesh = mesh
        # Effective serving knobs: the service config overrides, else the
        # index-level settings (an AnnIndex built/loaded with blockmax_keep
        # or use_kernel serves with them by default).
        if self.scfg.blockmax_keep is not None:
            self._bm_keep = self.scfg.blockmax_keep
            self._bm_block = self.scfg.blockmax_block_size
        else:
            self._bm_keep = ann.blockmax_keep
            self._bm_block = ann.blockmax_block_size
        self._uk = (
            self.scfg.use_kernel if self.scfg.use_kernel is not None
            else ann.use_kernel
        )
        self._bm = None
        if self._bm_keep is not None:
            if not isinstance(ann.index, (FakeWordsIndex, LshIndex)):
                raise ValueError(
                    f"blockmax pruning is not supported for {ann.method}"
                )
            signed = getattr(ann.config, "signed_store", False)
            if mesh is not None:
                self._bm = distributed.build_blockmax_sharded(
                    mesh, ann.index, shard_axes, self._bm_block,
                    signed_store=signed,
                )
            elif ann.bm is not None and ann.bm.block_size == self._bm_block:
                self._bm = ann.bm
            else:
                from repro.core import blockmax

                self._bm = blockmax.build_blockmax(
                    ann.index, self._bm_block, signed_store=signed,
                )
        if mesh is not None:
            # The rerank gather must read the store the index was built
            # with: int8 quantized, fp32 originals, or none.
            if ann.quantized_rerank:
                rs = "int8"
            else:
                rs = "exact" if ann.index.vectors is not None else "none"
            self._search = distributed.make_sharded_search(
                mesh, ann.config, shard_axes,
                k=self.scfg.k, depth=self.scfg.depth, rerank=self.scfg.rerank,
                use_kernel=self._uk,
                blockmax_keep=self._bm_keep,
                rerank_store=rs,
            )
        else:
            self._search = None
        self.queries_served = 0
        self.batches = 0
        self._lat_s = collections.deque(maxlen=self.scfg.latency_window)
        self._cache: "collections.OrderedDict[bytes, Tuple[np.ndarray, np.ndarray]]" = (
            collections.OrderedDict()
        )
        self.cache_hits = 0
        self.cache_misses = 0

    def _matcher(self):
        """The effective match stage for single-device serving."""
        return self.ann.matcher_for(self._bm, self._bm_keep)

    def _cache_key(self, q_rep, q) -> bytes:
        """Result-cache key: the encoded query representation's bytes plus
        every knob that changes the result.  When reranking, the raw
        normalized queries join the hash — distinct queries can collide on
        a quantized rep (tf row / signature), and their exact rerank scores
        would differ.  Note np.asarray(q_rep) blocks on the (tiny) encoder
        before the search dispatch; that host sync is the price of rep-level
        keying and only paid when the cache is enabled."""
        h = hashlib.sha1(np.asarray(q_rep).tobytes())
        if self.scfg.rerank:
            h.update(np.asarray(q).tobytes())
        h.update(
            repr((self.scfg.k, self.scfg.depth, self.scfg.rerank,
                  self._bm_keep, self._bm_block, self._uk)).encode()
        )
        return h.digest()

    def search_batch(self, queries: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(B, dim) -> (scores (B,k), ids (B,k)); pads to max_batch so the
        jit cache holds exactly one entry."""
        b = queries.shape[0]
        mb = self.scfg.max_batch
        pad = (-b) % mb
        if pad:
            queries = np.concatenate(
                [queries, np.zeros((pad, queries.shape[1]), queries.dtype)], 0
            )
        use_cache = self.scfg.cache_size > 0
        out_s, out_i = [], []
        for i in range(0, queries.shape[0], mb):
            t0 = time.perf_counter()
            q = bruteforce.l2_normalize(jnp.asarray(queries[i : i + mb]))
            q_rep = self.ann.pipeline.encoder(self.ann.index, q)
            key = self._cache_key(q_rep, q) if use_cache else None
            if use_cache and key in self._cache:
                self._cache.move_to_end(key)
                s_np, i_np = self._cache[key]
                self.cache_hits += 1
            else:
                if self._search is not None:
                    if self._bm is not None:
                        s, ids = self._search(self.ann.index, self._bm, q_rep, q)
                    else:
                        s, ids = self._search(self.ann.index, q_rep, q)
                else:
                    s, ids = pl.match_rerank(
                        self._matcher(), self.ann.index, q_rep, q,
                        self.scfg.k, self.scfg.depth, self.scfg.rerank,
                        bm=self._bm, use_kernel=self._uk,
                        reranker=self.ann.pipeline.reranker,
                    )
                s_np = np.asarray(s)   # np.asarray blocks: wall time
                i_np = np.asarray(ids)  # below covers device compute
                if use_cache:
                    self.cache_misses += 1
                    self._cache[key] = (s_np, i_np)
                    while len(self._cache) > self.scfg.cache_size:
                        self._cache.popitem(last=False)
            out_s.append(s_np)
            out_i.append(i_np)
            self.batches += 1
            self._lat_s.append(time.perf_counter() - t0)
        self.queries_served += b
        return np.concatenate(out_s)[:b], np.concatenate(out_i)[:b]

    def reset_latency(self) -> None:
        """Drop recorded batch latencies (e.g. after a warmup/compile batch,
        whose wall time is orders of magnitude above steady state and would
        otherwise dominate the p99)."""
        self._lat_s.clear()

    def stats(self) -> dict:
        lat_ms = np.asarray(self._lat_s, np.float64) * 1e3
        return {
            "queries": self.queries_served,
            "batches": self.batches,
            "index_bytes": self.ann.nbytes(),
            "num_docs": self.ann.num_docs,
            "method": self.ann.method,
            "lat_p50_ms": round(float(np.percentile(lat_ms, 50)), 3) if lat_ms.size else None,
            "lat_p99_ms": round(float(np.percentile(lat_ms, 99)), 3) if lat_ms.size else None,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_entries": len(self._cache),
        }
