"""Rule ``hostsync`` — no device->host synchronization in hot paths.

A host sync (``.item()``, ``np.asarray(device_array)``,
``float()``/``int()`` on an array, ``.block_until_ready()``) blocks the
Python thread on the device stream and collapses the async dispatch
pipeline (docs/DESIGN.md §13/§15).  In serving code a stray sync turns a
~50us launch into a millisecond-scale stall.

Scope:

  * every function body in ``hot_path_globs`` files (``serve/*``,
    ``core/packed.py``);
  * ``__call__`` methods of matcher-layer classes
    (``matcher_class_patterns``) in ``matcher_call_globs`` files.

Module scope (import-time constant building) is exempt — syncing once at
import is not a hot path.  Deliberate materialization points (the tail of
a batch where results go back to Python callers) stay, with a waiver
stating why, e.g.::

    s_np = np.asarray(s)  # reprolint: disable=hostsync  (result hand-off)

Flagged forms:

  * ``x.item()``, ``x.tolist()``, ``x.block_until_ready()``,
    ``jax.device_get(x)``;
  * ``np.asarray(x)`` / ``np.array(x)`` where ``x`` is not a literal
    display / comprehension (wrapping a fresh Python list is host-side
    already);
  * ``float(x)`` / ``int(x)`` where ``x`` is not provably host-native
    (literals, ``len()``, ``.shape``/``.ndim``/``.size`` access,
    ``time.*``/``os.*`` calls, and arithmetic over those).
"""
from __future__ import annotations

import ast
import fnmatch
from typing import List, Optional

from tools.reprolint.framework import FileContext, Finding, Rule, call_name

_SYNC_METHODS = {
    "item": "materializes a scalar on the host",
    "tolist": "copies the whole array to host",
    "block_until_ready": "blocks on the device stream",
}
_NP_WRAPPERS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
_DEVICE_GET = {"jax.device_get", "device_get"}
_HOST_CALL_ROOTS = ("time.", "os.", "math.", "random.")
_HOST_SAFE_CALLS = {
    "len", "round", "min", "max", "abs", "sum", "range", "sorted", "id",
    "ord", "hash", "str", "repr", "bool", "int", "float",
}
_HOST_ATTRS = {"ndim", "size", "nbytes", "maxsize", "qsize"}


def _is_literal_display(node: ast.expr) -> bool:
    return isinstance(node, (
        ast.List, ast.Tuple, ast.Dict, ast.Set,
        ast.ListComp, ast.GeneratorExp, ast.Constant,
    ))


def _host_native(node: ast.expr) -> bool:
    """True when the expression provably never holds a device array."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.BinOp):
        return _host_native(node.left) and _host_native(node.right)
    if isinstance(node, ast.UnaryOp):
        return _host_native(node.operand)
    if isinstance(node, ast.Compare):
        return True  # bool result
    if isinstance(node, ast.IfExp):
        return _host_native(node.body) and _host_native(node.orelse)
    if isinstance(node, ast.Attribute):
        return node.attr in _HOST_ATTRS
    if isinstance(node, ast.Subscript):
        # x.shape[0] is a Python int
        return (
            isinstance(node.value, ast.Attribute)
            and node.value.attr == "shape"
        )
    if isinstance(node, ast.Call):
        name = call_name(node) or ""
        if name in _HOST_SAFE_CALLS:
            return True
        if any(name.startswith(r) for r in _HOST_CALL_ROOTS):
            return True
        if name.endswith(".get") or name.endswith(".total_seconds"):
            return True
    return False


class HostSyncRule(Rule):
    name = "hostsync"

    def _hot_function(self, ctx: FileContext, node: ast.AST) -> bool:
        fn = ctx.enclosing_function(node)
        if fn is None:
            return False  # module scope: import-time is not hot
        if ctx.matches(ctx.config.hot_path_globs):
            return True
        if ctx.matches(ctx.config.matcher_call_globs):
            # only __call__ of matcher-layer classes is hot here
            cur: Optional[ast.AST] = fn
            while cur is not None and isinstance(
                cur, (ast.FunctionDef, ast.AsyncFunctionDef)
            ):
                if cur.name == "__call__":
                    cls = ctx.enclosing_class(cur)
                    if cls is not None and any(
                        fnmatch.fnmatch(cls.name, p)
                        for p in ctx.config.matcher_class_patterns
                    ):
                        return True
                cur = ctx.enclosing_function(cur)
        return False

    def check(self, ctx: FileContext) -> List[Finding]:
        if not (
            ctx.matches(ctx.config.hot_path_globs)
            or ctx.matches(ctx.config.matcher_call_globs)
        ):
            return []
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if not self._hot_function(ctx, node):
                continue
            name = call_name(node) or ""

            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _SYNC_METHODS and not node.args:
                out.append(self.finding(
                    ctx, node.lineno,
                    f".{node.func.attr}() in a hot path "
                    f"({_SYNC_METHODS[node.func.attr]}) — keep the value on "
                    "device or move the sync to the result hand-off and "
                    "waive it there",
                ))
                continue

            if name in _DEVICE_GET and node.args:
                out.append(self.finding(
                    ctx, node.lineno,
                    "jax.device_get() in a hot path forces a transfer — "
                    "keep the value on device",
                ))
                continue

            if name in _NP_WRAPPERS and node.args \
                    and not _is_literal_display(node.args[0]):
                out.append(self.finding(
                    ctx, node.lineno,
                    f"{name}(...) on a non-literal value in a hot path: if "
                    "the operand is a device array this blocks until it is "
                    "materialized — keep math in jnp, or waive the "
                    "deliberate hand-off points",
                ))
                continue

            if name in ("float", "int") and len(node.args) == 1 \
                    and not _host_native(node.args[0]):
                out.append(self.finding(
                    ctx, node.lineno,
                    f"{name}(...) on a possibly-device value in a hot path "
                    "synchronizes — hoist it out of the steady-state loop "
                    "or waive with justification",
                ))
        return out
