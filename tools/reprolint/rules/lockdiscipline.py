"""Rule ``lockdiscipline`` — guarded attributes touched only under the lock.

The async serving worker (``AnnService._batch_loop``) shares mutable state
with caller threads; the contract (config ``lock_contracts``) says which
methods run on the worker thread and which attribute is the lock.  The rule
computes:

  1. the **worker-reachable** methods: BFS over the intra-class
     ``self.method()`` call graph from ``worker_entries``;
  2. the **guarded set**: every ``self.<attr>`` the worker-reachable
     methods mutate, plus ``extra_guarded`` (state mutated from many
     *caller* threads, like admission-control counters), minus
     ``threadsafe_attrs`` (queue.Queue / threading.Event are internally
     synchronized);
  3. **lock-held contexts**: statements lexically inside
     ``with self._lock`` — plus private helper methods whose intra-class
     call sites are *all* lock-held (fixed point), e.g. ``_search_batch``
     called only from ``search_batch``'s locked region.

Any mutation of a guarded attribute outside a lock-held context (and
outside ``exempt_methods`` — construction and worker lifecycle run before
or after concurrency) is a finding.  Mutation means assignment,
``+=``, subscript/attribute stores through ``self.<attr>``, or calling a
mutating method (``append``/``clear``/``pop``/...) on it.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set

from tools.reprolint.framework import FileContext, Finding, Rule

_MUTATING_METHODS = {
    "append", "appendleft", "extend", "extendleft", "insert", "add",
    "update", "clear", "pop", "popleft", "popitem", "remove", "discard",
    "setdefault", "sort", "reverse", "fill",
}


def _self_attr(node: ast.expr) -> Optional[str]:
    """'x' for ``self.x``, else None."""
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


def _self_attr_root(node: ast.expr) -> Optional[str]:
    """'x' for ``self.x``, ``self.x[i]``, ``self.x.y`` ... chains."""
    cur = node
    while isinstance(cur, (ast.Subscript, ast.Attribute)):
        got = _self_attr(cur)
        if got is not None:
            return got
        cur = cur.value
    return None


@dataclasses.dataclass
class _Mutation:
    attr: str
    line: int
    locked: bool
    method: str


@dataclasses.dataclass
class _CallSite:
    callee: str
    locked: bool


class LockDisciplineRule(Rule):
    name = "lockdiscipline"

    def check(self, ctx: FileContext) -> List[Finding]:
        out: List[Finding] = []
        for contract in ctx.config.lock_contracts:
            if not ctx.matches((contract.path_glob,)):
                continue
            cls = next(
                (
                    n for n in ast.walk(ctx.tree)
                    if isinstance(n, ast.ClassDef)
                    and n.name == contract.class_name
                ),
                None,
            )
            if cls is None:
                out.append(self.finding(
                    ctx, 1,
                    f"lock contract names class {contract.class_name!r} "
                    "which does not exist in this file — update "
                    "reprolint config",
                ))
                continue
            out.extend(self._check_class(ctx, cls, contract))
        return out

    def _check_class(self, ctx, cls, contract) -> List[Finding]:
        methods: Dict[str, ast.FunctionDef] = {
            n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }

        def is_locked(node: ast.AST, method: ast.AST) -> bool:
            cur = ctx.parent(node)
            while cur is not None and cur is not method:
                if isinstance(cur, ast.With):
                    for item in cur.items:
                        expr = item.context_expr
                        if isinstance(expr, ast.Call):
                            expr = expr.func  # self._lock() style (no-op here)
                        if _self_attr(expr) == contract.lock_attr:
                            return True
                cur = ctx.parent(cur)
            return False

        # Pass 1: mutations + intra-class call sites per method.
        mutations: List[_Mutation] = []
        calls: Dict[str, List[_CallSite]] = {m: [] for m in methods}
        for mname, mnode in methods.items():
            for node in ast.walk(mnode):
                if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                    targets = (
                        node.targets if isinstance(node, ast.Assign)
                        else [node.target]
                    )
                    for tgt in targets:
                        attr = _self_attr_root(tgt)
                        if attr is not None:
                            mutations.append(_Mutation(
                                attr, node.lineno,
                                is_locked(node, mnode), mname,
                            ))
                elif isinstance(node, ast.Call):
                    f = node.func
                    if isinstance(f, ast.Attribute):
                        # self.helper(...) -> intra-class call edge
                        if isinstance(f.value, ast.Name) \
                                and f.value.id == "self" \
                                and f.attr in methods:
                            calls[f.attr].append(_CallSite(
                                mname, is_locked(node, mnode)
                            ))
                        # self.attr.append(...) -> mutation of self.attr
                        elif f.attr in _MUTATING_METHODS:
                            attr = _self_attr_root(f.value)
                            if attr is not None:
                                mutations.append(_Mutation(
                                    attr, node.lineno,
                                    is_locked(node, mnode), mname,
                                ))

        # Pass 2: worker-reachable methods (call graph BFS from entries).
        worker: Set[str] = set()
        frontier = [m for m in contract.worker_entries if m in methods]
        while frontier:
            m = frontier.pop()
            if m in worker:
                continue
            worker.add(m)
            for callee, sites in calls.items():
                if any(s.callee == m for s in sites):
                    frontier.append(callee)

        # Pass 3: guarded attribute set.
        guarded: Set[str] = set(contract.extra_guarded)
        for mut in mutations:
            if mut.method in worker:
                guarded.add(mut.attr)
        guarded -= set(contract.threadsafe_attrs)
        guarded.discard(contract.lock_attr)

        # Pass 4: lock-held helper propagation to a fixed point.  Only
        # private helpers qualify (public methods have external callers the
        # AST cannot see); worker entries run with no lock by definition.
        lock_held: Set[str] = set(contract.exempt_methods) & set(methods)
        changed = True
        while changed:
            changed = False
            for mname in methods:
                if mname in lock_held:
                    continue
                if not mname.startswith("_") or mname.startswith("__"):
                    continue
                if mname in contract.worker_entries:
                    continue
                sites = calls.get(mname, [])
                if sites and all(
                    s.locked or s.callee in lock_held for s in sites
                ):
                    lock_held.add(mname)
                    changed = True

        # Pass 5: report unguarded mutations of guarded attributes.
        findings: List[Finding] = []
        for mut in mutations:
            if mut.attr not in guarded:
                continue
            if mut.method in contract.exempt_methods:
                continue
            if mut.locked or mut.method in lock_held:
                continue
            where = (
                "on the worker thread" if mut.method in worker
                else "from caller threads"
            )
            findings.append(self.finding(
                ctx, mut.line,
                f"{contract.class_name}.{mut.method} mutates "
                f"self.{mut.attr} {where} without holding "
                f"self.{contract.lock_attr}; it is shared with "
                + ("caller" if mut.method in worker else "the worker")
                + " thread state — wrap the mutation in "
                f"`with self.{contract.lock_attr}:`",
            ))
        return findings
