"""Rule ``retrace`` — jit / cache-key hygiene (the recompile-churn class).

The executable-cache work (docs/DESIGN.md §14) fixed a whole family of
"silently recompiles every call" bugs by hand; this rule flags the static
shapes of that family:

  * **jit-in-loop** — ``jax.jit(...)`` / ``.lower(...)`` / ``.compile()``
    inside a ``for``/``while`` body: a fresh jitted callable (or AOT
    executable) per iteration defeats jit's identity-keyed cache.
  * **local-jit** — ``jax.jit`` applied to a function or lambda defined in
    the enclosing *function* scope: every call of the enclosing function
    builds a new closure object, so the jit cache can never hit.  Builder
    functions (``make_*`` / ``build*`` / ``_bind`` — configurable) are the
    blessed exception: they construct the closure once per snapshot/bind
    and hold on to it.
  * **closure-unhashable** — a jitted nested function closing over a name
    bound to a list/dict/set display in the enclosing function: mutating the
    captured object silently changes semantics without retracing (and such
    values can never participate in a cache key).
  * **closure-array** — a jitted nested function closing over a name bound
    to an ``np.*``/``jnp.*`` array construction in the enclosing function:
    the array is baked into the traced graph as a constant, so every fresh
    closure re-traces and re-constant-folds it (pass it as an argument
    instead).

Python-scalar cache-key churn (the ``df_num_docs`` class) is only partially
visible statically; the dynamic trace audit
(:mod:`tools.reprolint.trace_audit`) owns that end of the family.
"""
from __future__ import annotations

import ast
import fnmatch
from typing import Dict, List, Optional, Set

from tools.reprolint.framework import (
    FileContext,
    Finding,
    Rule,
    call_name,
    dotted_name,
)

_JIT_NAMES = {"jax.jit", "jit", "pjit", "jax.pjit"}
_ARRAY_CTORS = (
    "np.array", "np.asarray", "np.zeros", "np.ones", "np.full",
    "np.arange", "jnp.array", "jnp.asarray", "jnp.zeros", "jnp.ones",
    "jnp.full", "jnp.arange", "numpy.array", "numpy.asarray",
)


def _is_jit_call(node: ast.Call) -> bool:
    name = call_name(node)
    if name in _JIT_NAMES:
        return True
    # functools.partial(jax.jit, ...) used as a decorator factory
    if name in ("functools.partial", "partial") and node.args:
        return dotted_name(node.args[0]) in _JIT_NAMES
    return False


def _jitted_target(node: ast.Call) -> Optional[ast.AST]:
    """The function being jitted, skipping through functools.partial."""
    name = call_name(node)
    if name in ("functools.partial", "partial"):
        return None  # decorator factory: target is the decorated def
    return node.args[0] if node.args else None


class _Scope:
    """Names bound in one function scope, by how they were bound."""

    def __init__(self, fn: ast.AST):
        self.fn = fn
        self.local_defs: Set[str] = set()        # nested def / lambda names
        self.unhashable: Dict[str, int] = {}     # name -> assign line
        self.arrays: Dict[str, int] = {}         # name -> assign line
        self.params: Set[str] = set()
        args = getattr(fn, "args", None)
        if args is not None:
            for a in (
                args.posonlyargs + args.args + args.kwonlyargs
                + ([args.vararg] if args.vararg else [])
                + ([args.kwarg] if args.kwarg else [])
            ):
                self.params.add(a.arg)


def _scan_scope(ctx: FileContext, fn: ast.AST) -> _Scope:
    scope = _Scope(fn)
    for node in ast.walk(fn):
        if node is not fn and isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef)
        ):
            if ctx.enclosing_function(node) is fn:
                scope.local_defs.add(node.name)
        if isinstance(node, ast.Assign) and ctx.enclosing_function(node) is fn:
            for tgt in node.targets:
                if not isinstance(tgt, ast.Name):
                    continue
                val = node.value
                if isinstance(val, ast.Lambda):
                    scope.local_defs.add(tgt.id)
                elif isinstance(val, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                                      ast.DictComp, ast.SetComp)):
                    scope.unhashable[tgt.id] = node.lineno
                elif isinstance(val, ast.Call) and call_name(val) in _ARRAY_CTORS:
                    scope.arrays[tgt.id] = node.lineno
    return scope


def _free_names(fn: ast.AST) -> Set[str]:
    """Names loaded in ``fn`` but not bound inside it (approximate)."""
    bound: Set[str] = set()
    loaded: Set[str] = set()
    args = getattr(fn, "args", None)
    if args is not None:
        for a in (
            args.posonlyargs + args.args + args.kwonlyargs
            + ([args.vararg] if args.vararg else [])
            + ([args.kwarg] if args.kwarg else [])
        ):
            bound.add(a.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name):
            if isinstance(node.ctx, ast.Store):
                bound.add(node.id)
            else:
                loaded.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node is not fn:
                bound.add(node.name)
    return loaded - bound


class RetraceRule(Rule):
    name = "retrace"

    def check(self, ctx: FileContext) -> List[Finding]:
        out: List[Finding] = []
        scopes: Dict[ast.AST, _Scope] = {}

        def scope_for(fn: ast.AST) -> _Scope:
            if fn not in scopes:
                scopes[fn] = _scan_scope(ctx, fn)
            return scopes[fn]

        builder_pats = ctx.config.retrace_builder_patterns

        def in_builder(fn: Optional[ast.AST]) -> bool:
            while fn is not None:
                if any(
                    fnmatch.fnmatch(fn.name, p) for p in builder_pats
                ):
                    return True
                fn = ctx.enclosing_function(fn)
            return False

        def check_closure(target_fn: ast.AST, line: int) -> None:
            """closure-unhashable / closure-array on a jitted nested fn."""
            encl = ctx.enclosing_function(target_fn)
            if encl is None or in_builder(encl):
                return
            scope = _scan_scope(ctx, encl)
            free = _free_names(target_fn)
            for nm in sorted(free & set(scope.unhashable)):
                out.append(self.finding(
                    ctx, line,
                    f"jitted function closes over unhashable local "
                    f"{nm!r} (list/dict/set built at line "
                    f"{scope.unhashable[nm]}); mutation silently skips "
                    "retracing — pass it as a static arg or freeze it",
                ))
            for nm in sorted(free & set(scope.arrays)):
                out.append(self.finding(
                    ctx, line,
                    f"jitted function captures array {nm!r} by closure "
                    f"(constructed at line {scope.arrays[nm]}); it is baked "
                    "into the trace as a constant and re-traced per "
                    "closure — pass it as an operand",
                ))

        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call) or not _is_jit_call(node):
                continue
            name = call_name(node)
            encl = ctx.enclosing_function(node)

            # jit-in-loop: any jit construction lexically inside a loop.
            cur = ctx.parent(node)
            loop = None
            while cur is not None and not isinstance(
                cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module)
            ):
                if isinstance(cur, (ast.For, ast.While)):
                    loop = cur
                    break
                cur = ctx.parent(cur)
            if loop is not None:
                out.append(self.finding(
                    ctx, node.lineno,
                    f"{name}(...) constructed inside a "
                    f"{type(loop).__name__.lower()} loop: a fresh jitted "
                    "callable per iteration retraces every time — hoist it "
                    "or route through an explicit executable cache",
                ))
                continue

            target = _jitted_target(node)
            if target is None or encl is None or in_builder(encl):
                # Decorator factories check the decorated def below;
                # module-scope and builder-scope jits are the blessed forms.
                if isinstance(target, (ast.FunctionDef, ast.Lambda)):
                    check_closure(target, node.lineno)
                continue

            scope = scope_for(encl)
            if isinstance(target, ast.Lambda):
                out.append(self.finding(
                    ctx, node.lineno,
                    "jax.jit over a lambda inside a non-builder function: "
                    "a new closure (and a full retrace) per call — hoist it "
                    "to module scope or a make_*/build* builder",
                ))
            elif isinstance(target, ast.Name) and target.id in scope.local_defs:
                out.append(self.finding(
                    ctx, node.lineno,
                    f"jax.jit over locally-defined {target.id!r} inside a "
                    "non-builder function: the jit cache keys on the "
                    "closure object, which is rebuilt (and retraced) every "
                    "call — hoist it or use a make_*/build* builder",
                ))
                fdef = next(
                    (
                        n for n in ast.walk(encl)
                        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and n.name == target.id
                    ),
                    None,
                )
                if fdef is not None:
                    check_closure(fdef, node.lineno)

        # Decorated defs: @jax.jit / @functools.partial(jax.jit, ...) on a
        # NESTED def — closure checks apply (module-level defs have no
        # enclosing function locals to capture).
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            jitted = False
            for dec in node.decorator_list:
                if (dotted_name(dec) in _JIT_NAMES) or (
                    isinstance(dec, ast.Call) and _is_jit_call(dec)
                ):
                    jitted = True
            if not jitted:
                continue
            encl = ctx.enclosing_function(node)
            if encl is None:
                continue
            if not in_builder(encl):
                out.append(self.finding(
                    ctx, node.lineno,
                    f"@jit-decorated def {node.name!r} nested inside a "
                    "non-builder function: rebuilt (and retraced) on every "
                    "call of the enclosing function",
                ))
            check_closure(node, node.lineno)
        return out
